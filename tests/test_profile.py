"""profile → calibrate → replay (repro.profile, DESIGN.md §11).

Pins, in order: the trace event schema round-trip; the execution shim's
profiler sink (eager calls timed, traced calls never); the engine's
step instrumentation (events + request reconstruction) and its
zero-cost-when-disabled guarantee (bit-identical tokens AND
jaxpr-identical step, via the registered tracing contract); the
least-squares fit recovering synthetic cost parameters; the replay
simulator's step accounting and its predicted-vs-measured error bound
on a real smoke serve run; and the fitted table's consumption by
``execution.autotune(calibration=)`` / ``hw.project(calibration=)``.
"""
import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.profile as P
from repro.core import execution as X
from repro.models import transformer as T
from repro.models.registry import get_config
from repro.serve.engine import ContinuousBatcher, Request


def _event(entry="execution.execute", spec="exact/jnp/none", cls="decode",
           wall=100.0, **meta):
    return P.TraceEvent(entry_point=entry, exec_spec=spec, shape_class=cls,
                        mesh=None, wall_us=wall, meta=meta)


# ---------------------------------------------------------------------------
# Trace schema
# ---------------------------------------------------------------------------


class TestTraceSchema:
    def test_round_trip(self):
        ev = P.TraceEvent("serve.decode_step", "mode:off", "decode",
                          {"model": 4}, 812.4, 101.2, {"occupancy": 2})
        d = ev.to_json()
        assert d["v"] == P.TRACE_SCHEMA_VERSION
        P.validate_event(d)
        assert P.event_from_json(d) == ev
        # through an actual JSON string (what the trace file holds)
        assert P.event_from_json(json.loads(json.dumps(d))) == ev

    def test_file_round_trip(self, tmp_path):
        path = tmp_path / "trace.jsonl"
        with P.Profiler(path) as prof:
            prof.record(_event(wall=1.0, m=1, k=2, n=3))
            prof.record(_event(entry="serve.prefill", cls="prefill", wall=2.0))
        events = P.read_trace(path)
        assert events == prof.events
        assert len(events) == 2

    @pytest.mark.parametrize("mutate", [
        lambda d: d.pop("v"),
        lambda d: d.update(v=99),
        lambda d: d.pop("wall_us"),
        lambda d: d.update(wall_us=-1.0),
        lambda d: d.update(entry_point=""),
        lambda d: d.update(mesh="tp4"),
    ])
    def test_rejects_malformed(self, mutate):
        d = _event().to_json()
        mutate(d)
        with pytest.raises(ValueError):
            P.validate_event(d)

    def test_required_fields_are_the_issue_contract(self):
        # (entry_point, exec_spec, shape_class, mesh, wall_us) is the
        # recorded tuple the observability layer promises
        for f in ("entry_point", "exec_spec", "shape_class", "mesh", "wall_us"):
            assert f in P.trace.REQUIRED_FIELDS


# ---------------------------------------------------------------------------
# Execution-shim sink
# ---------------------------------------------------------------------------


class TestKernelSink:
    def setup_method(self):
        self.spec = X.CiMExecSpec(formulation="exact", backend="jnp")
        k = jax.random.PRNGKey(0)
        self.x = jnp.sign(jax.random.normal(k, (4, 64))).astype(jnp.float32)
        self.w = jnp.sign(jax.random.normal(k, (64, 32))).astype(jnp.float32)

    def test_eager_execute_records(self):
        prof = P.Profiler()
        prev = P.set_profiler(prof)
        try:
            X.execute(self.spec, self.x, self.w)
        finally:
            P.set_profiler(prev)
        (e,) = prof.events
        assert e.entry_point == "execution.execute"
        assert e.exec_spec == "exact/jnp/none"
        assert e.shape_class == "decode"
        assert e.meta["macs"] == 4 * 64 * 32
        assert e.wall_us > 0 and e.dispatch_us <= e.wall_us

    def test_traced_execute_never_records(self):
        prof = P.Profiler()
        prev = P.set_profiler(prof)
        try:
            jax.jit(lambda a, b: X.execute(self.spec, a, b))(self.x, self.w)
        finally:
            P.set_profiler(prev)
        assert prof.events == []

    def test_uninstall_restores_previous(self):
        assert P.current_profiler() is None
        p1, p2 = P.Profiler(), P.Profiler()
        assert P.set_profiler(p1) is None
        assert P.set_profiler(p2) is p1
        assert P.set_profiler(None) is p2
        assert P.current_profiler() is None


# ---------------------------------------------------------------------------
# Engine instrumentation
# ---------------------------------------------------------------------------


def _serve(cfg, params, profile=None, seed=0, n=5):
    b = ContinuousBatcher(params, cfg, n_slots=3, s_max=32, seed=seed,
                          profile=profile)
    reqs = [Request(i, [1 + i % 7] * (1 + i % 3), max_new=2 + i % 3)
            for i in range(n)]
    for r in reqs:
        b.submit(r)
    b.run()
    return b, reqs


@pytest.fixture(scope="module")
def smoke_setup():
    cfg = get_config("smollm-135m", smoke=True)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


class TestEngineInstrumentation:
    def test_profiled_run_emits_schema_events(self, smoke_setup, tmp_path):
        cfg, params = smoke_setup
        path = tmp_path / "serve.jsonl"
        _serve(cfg, params, profile=str(path))
        events = P.read_trace(path)  # validates every line
        kinds = {e.entry_point for e in events}
        assert {"serve.prefill", "serve.decode_step"} <= kinds
        decode = [e for e in events if e.entry_point == "serve.decode_step"]
        assert all(e.shape_class == "decode" for e in decode)
        assert all(e.meta["occupancy"] >= 1 for e in decode)
        assert all(e.meta["arch"] == cfg.name for e in decode)

    def test_requests_reconstructed_from_trace(self, smoke_setup):
        cfg, params = smoke_setup
        prof = P.Profiler()
        _, reqs = _serve(cfg, params, profile=prof)
        got = P.requests_from_trace(prof.events)
        assert [(r.rid, r.prompt_len, r.max_new) for r in got] == \
            [(r.rid, len(r.prompt), r.max_new) for r in reqs]

    def test_disabled_profiler_bit_identical(self, smoke_setup):
        cfg, params = smoke_setup
        _, plain = _serve(cfg, params, profile=None, seed=3)
        _, prof = _serve(cfg, params, profile=P.Profiler(), seed=3)
        assert [r.generated for r in plain] == [r.generated for r in prof]

    def test_disabled_wrap_is_the_same_object(self):
        def step(x):
            return x

        assert P.wrap_step(step, None, "serve.decode_step") is step

    def test_disabled_step_jaxpr_identical(self):
        # the registered contract traces the production fused decode fn
        # raw and through the disabled wrapper and requires ONE equation
        # count — plus zero host callbacks in the step
        from repro.analysis import run_contract

        findings, meta = run_contract("profile.step_instrumentation.disabled")
        assert findings == [], [f.message for f in findings]
        assert len(set(meta["eqn_counts"].values())) == 1


# ---------------------------------------------------------------------------
# Calibration fit
# ---------------------------------------------------------------------------


class TestCalibrationFit:
    def _synthetic_events(self, fixed=50.0, per_mmac=3.0, per_mb=8.0,
                          bpw=2.0, cls="decode"):
        shapes = [(1, 256, 256), (4, 256, 512), (8, 512, 256),
                  (2, 512, 512), (6, 128, 1024)]
        return [
            _event(cls=cls,
                   wall=fixed + per_mmac * (m * k * n) * 1e-6
                   + per_mb * (k * n * bpw) * 1e-6,
                   m=m, k=k, n=n, macs=m * k * n,
                   weight_bytes=int(k * n * bpw))
            for m, k, n in shapes
        ]

    def test_fit_recovers_synthetic_params(self):
        fit = P.fit_kernel(self._synthetic_events())
        assert fit.fixed_us == pytest.approx(50.0, rel=1e-3)
        assert fit.us_per_mmac == pytest.approx(3.0, rel=1e-3)
        assert fit.us_per_mb == pytest.approx(8.0, rel=1e-3)
        assert fit.bytes_per_weight == pytest.approx(2.0)
        assert fit.residual_pct < 0.1
        # and the model predicts a held-out shape
        assert fit.predict_us(3, 384, 384) == pytest.approx(
            50.0 + 3.0 * 3 * 384 * 384 * 1e-6 + 8.0 * 384 * 384 * 2 * 1e-6,
            rel=1e-3)

    def test_fit_clamps_rates_nonnegative(self):
        # constant walls regardless of size: rates must go to ~0, never
        # negative (clamp-and-refit NNLS)
        events = [_event(wall=100.0, m=m, k=k, n=n)
                  for m, k, n in [(1, 64, 64), (8, 512, 512), (4, 256, 128)]]
        fit = P.fit_kernel(events)
        assert fit.us_per_mmac >= 0 and fit.us_per_mb >= 0
        assert fit.fixed_us == pytest.approx(100.0, rel=1e-3)

    def test_calibrate_groups_and_round_trips(self, tmp_path):
        events = (self._synthetic_events(cls="decode")
                  + self._synthetic_events(fixed=20.0, cls="prefill"))
        table = P.calibrate(events, backend="cpu",
                            tile_winners={"blocked/pallas/bitplane_u8":
                                          {"decode": (8, 256, 128)}})
        assert set(table.kernels) == {"exact/jnp/none|decode",
                                      "exact/jnp/none|prefill"}
        path = tmp_path / "calib.json"
        table.save(path)
        again = P.CalibrationTable.load(path)
        assert again == table

    def test_load_rejects_wrong_version(self, tmp_path):
        table = P.calibrate(self._synthetic_events(), backend="cpu")
        d = table.to_json()
        d["version"] = 99
        with pytest.raises(ValueError, match="version"):
            P.CalibrationTable.from_json(d)

    def test_decode_boundary_matches_execution(self):
        # the table dispatches on M like the execution API; a drifted
        # copy of the boundary would silently mis-class predictions
        # (sys.modules: the package re-exports the calibrate *function*,
        # which shadows the submodule as an attribute)
        import sys

        C = sys.modules["repro.profile.calibrate"]
        assert C.DECODE_M_MAX == X.DECODE_M_MAX

    def test_engine_fit_subtracts_kernel_share(self):
        decode = [P.TraceEvent("serve.decode_step", "mode:off", "decode",
                               None, 1000.0, 0.0,
                               {"arch": "a1", "occupancy": occ})
                  for occ in (1, 2, 4)]
        fits = P.fit_engines(decode, kernel_model=lambda a, occ: 100.0 * occ)
        fit = fits["a1|tp1"]
        assert fit.decode_fixed_us == pytest.approx(1000.0 - 200.0)
        assert fit.n_decode == 3


# ---------------------------------------------------------------------------
# Replay
# ---------------------------------------------------------------------------


class TestReplay:
    def _table(self, decode_fixed=1000.0, prefill=2000.0, arch="smollm-135m"):
        return P.CalibrationTable(
            version=P.CALIBRATION_VERSION, backend="cpu",
            default_spec="exact/jnp/none",
            kernels={"exact/jnp/none|decode":
                     P.KernelFit(10.0, 1.0, 1.0, 2.0, 5, 0.5)},
            engines={f"{arch}|tp1": P.EngineFit(
                arch, "tp1", "mode:off", decode_fixed, prefill, 10, 3, 1.0)},
        )

    def test_step_accounting_matches_engine(self, smoke_setup):
        """The simulator predicts the EXACT decode-step / prefill-batch
        counts the real engine runs for the same workload."""
        cfg, params = smoke_setup
        prof = P.Profiler()
        b, _ = _serve(cfg, params, profile=prof)
        reqs = P.requests_from_trace(prof.events)
        pred = P.simulate(self._table(), "smollm-135m", reqs,
                          n_slots=3, s_max=32)
        assert pred["decode_steps"] == b.decode_steps
        assert pred["prefill_batches"] == sum(
            1 for e in prof.events if e.entry_point == "serve.prefill")
        assert pred["tokens"] == sum(
            1 for e in prof.events if e.entry_point == "serve.decode_step"
            for _ in range(e.meta["occupancy"])) + sum(
            e.meta["filled"] for e in prof.events
            if e.entry_point == "serve.prefill")

    def test_dependency_graph_is_a_chain(self):
        reqs = P.requests_like_bench(64, 4, 3)
        pred = P.simulate(self._table(), "smollm-135m", reqs)
        graph = pred["graph"]
        assert list(graph[0]["deps"]) == []
        for prev, node in zip(graph, graph[1:]):
            assert list(node["deps"]) == [prev["nid"]]
            assert node["start_us"] == pytest.approx(
                prev["start_us"] + prev["us"])

    def test_replay_error_bound_on_smoke_arch(self, smoke_setup):
        """End-to-end: profile a smoke serve run, calibrate on it,
        replay the same workload — the predicted decode-step p50 must
        land within 50% of the measured p50 (loose: shared CI hosts),
        and the step counts must match exactly."""
        cfg, params = smoke_setup
        prof = P.Profiler()
        b, _ = _serve(cfg, params, profile=prof, n=6)
        table = P.calibrate(prof.events, backend=jax.default_backend())
        reqs = P.requests_from_trace(prof.events)
        pred = P.simulate(table, cfg.name, reqs, n_slots=3, s_max=32)
        cmp = P.compare_to_measured(pred, prof.events)
        assert cmp["measured_steps"] == pred["decode_steps"]
        assert cmp["p50_error_pct"] <= 50.0, cmp

    def test_predict_decode_step_with_kernel_model(self):
        table = self._table(decode_fixed=500.0)
        us = P.predict_decode_step_us(table, "smollm-135m", 4,
                                      kernel_model=lambda a, occ: 10.0 * occ)
        assert us == pytest.approx(540.0)


# ---------------------------------------------------------------------------
# Downstream consumption (autotune / hw.project)
# ---------------------------------------------------------------------------


class TestCalibrationConsumers:
    def _pallas_spec(self):
        spec = X.CiMExecSpec(formulation="blocked", backend="pallas",
                             packing="bitplane_u8").resolve()
        try:
            entry = X.get_backend(spec)
        except KeyError:
            pytest.skip("no pallas packed backend registered")
        if entry.tiles is None:
            pytest.skip("packed backend has no tile table")
        return spec

    def test_autotune_installs_calibrated_winners(self):
        spec = self._pallas_spec()
        decode_tiles = tuple(X.tiles_for(spec, 4, 1024, 512))
        table = P.CalibrationTable(
            version=P.CALIBRATION_VERSION, backend="cpu",
            default_spec=spec.name, kernels={},
            tile_winners={spec.name: {"decode": decode_tiles}})
        X.clear_tile_cache()
        try:
            report = X.autotune(spec, calibration=table)
            assert report["decode"]["tiles"] == decode_tiles
            assert report["decode"]["source"] == "calibration"
            assert tuple(X.tiles_for(spec, 2, 1024, 512)) == decode_tiles
        finally:
            X.clear_tile_cache()

    def test_autotune_rejects_invalid_calibrated_tiles(self):
        spec = self._pallas_spec()
        bad = P.CalibrationTable(
            version=P.CALIBRATION_VERSION, backend="cpu",
            default_spec=spec.name, kernels={},
            tile_winners={spec.name: {"decode": (4, 3, 7)}})
        with pytest.raises(ValueError, match="invalid"):
            X.autotune(spec, calibration=bad)

    def test_autotune_rejects_unknown_spec_in_table(self):
        spec = self._pallas_spec()
        empty = P.CalibrationTable(
            version=P.CALIBRATION_VERSION, backend="cpu",
            default_spec=spec.name, kernels={}, tile_winners={})
        with pytest.raises(ValueError, match="no tile winners"):
            X.autotune(spec, calibration=empty)

    def test_project_accepts_fitted_table(self):
        from repro import hw

        table = P.CalibrationTable(
            version=P.CALIBRATION_VERSION, backend="cpu",
            default_spec="exact/jnp/none",
            kernels={"exact/jnp/none|decode":
                     P.KernelFit(10.0, 2.0, 1.0, 2.0, 9, 1.0),
                     "exact/jnp/none|prefill":
                     P.KernelFit(50.0, 1.0, 1.0, 2.0, 9, 1.0)})
        arr = hw.ArraySpec()
        base = hw.project("smollm-135m", "decode_32k", arr)
        assert base["calibrated"] is None
        p = hw.project("smollm-135m", "decode_32k", arr, calibration=table)
        cal = p["calibrated"]
        assert cal["source"]["version"] == P.CALIBRATION_VERSION
        assert cal["source"]["backend"] == "cpu"
        assert cal["time_us"] > 0 and cal["tok_s"] > 0
        assert cal["cim_speedup_vs_host"] > 0
        # analytic projection itself unchanged by the calibration arg
        assert p["tok_s"] == base["tok_s"]


# ---------------------------------------------------------------------------
# Benchmark validator
# ---------------------------------------------------------------------------


class TestBenchValidator:
    def _result(self):
        import sys
        from pathlib import Path

        sys.path.insert(0, str(Path(__file__).resolve().parents[1]))
        from benchmarks.bench_calibrate import validate_result

        events = [_event(wall=100.0 + m, m=m, k=256, n=256)
                  for m in (1, 4, 8)]
        engine = [P.TraceEvent("serve.decode_step", "mode:off", "decode",
                               None, 1000.0, 0.0,
                               {"arch": "a1", "occupancy": 2})] * 3
        table = P.calibrate(events + engine, backend="cpu")
        return validate_result, {
            "bench": "calibrate", "smoke": True,
            "backend": {"platform": "cpu", "device_kind": "cpu",
                        "device_count": 1, "interpret": True},
            "error_bound_pct": 40.0,
            "kernel_sweep": {"specs": ["exact/jnp/none"], "repeats": 3,
                             "n_events": 3},
            "fit_residuals": {"kernels": {}, "engines": {}},
            "table": table.to_json(),
            "replay": {"a1": {"predicted_p50_us": 1000.0,
                              "measured_p50_us": 1000.0,
                              "p50_error_pct": 0.0, "within_bound": True}},
            "validated": True,
        }

    def test_accepts_well_formed(self):
        validate, d = self._result()
        validate(d)

    def test_rejects_unvalidated_and_inconsistent(self):
        validate, d = self._result()
        bad = dict(d, validated=False)
        bad["replay"] = {"a1": dict(d["replay"]["a1"], within_bound=False,
                                    p50_error_pct=90.0)}
        with pytest.raises(ValueError, match="exceeded"):
            validate(bad)
        for field in ("table", "replay", "validated"):
            broken = {k: v for k, v in d.items() if k != field}
            with pytest.raises(ValueError, match="missing"):
                validate(broken)

    def test_rejects_legacy_string_backend(self):
        """The backend field must be the provenance block, not the old
        bare platform string — artifacts must say whether they were
        produced under interpret mode."""
        validate, d = self._result()
        with pytest.raises(ValueError, match="provenance"):
            validate(dict(d, backend="cpu"))


# ---------------------------------------------------------------------------
# Backend provenance + measured-traffic replay closure
# ---------------------------------------------------------------------------


class TestBackendBlock:
    def test_block_shape(self):
        b = P.backend_block()
        assert set(b) == {"platform", "device_kind", "device_count",
                          "interpret"}
        assert b["platform"] == jax.default_backend()
        assert b["interpret"] == (jax.default_backend() != "tpu")
        assert b["device_count"] >= 1

    def test_bench_mac_refuses_compiled_claim_under_interpret(self):
        """bench_mac's validator must refuse a compiled-speedup claim in
        an artifact whose backend block says interpret mode — interpret
        timings measure the emulator, not the kernel."""
        import sys
        from pathlib import Path

        sys.path.insert(0, str(Path(__file__).resolve().parents[1]))
        from benchmarks.bench_mac import validate_result

        row = {"m": 8, "k": 512, "n": 256, "formulation": "exact",
               "backend": "pallas", "packing": "bitplane_u8",
               "shape_class": "decode", "us": 10.0, "weight_gbs": 1.0,
               "bit_identical": True, "speedup_vs_prepad": 1.5}
        d = {"bench": "mac", "smoke": True,
             "backend": {"platform": "cpu", "device_kind": "cpu",
                         "device_count": 1, "interpret": True},
             "k": 512, "n": 256, "block": 16, "adc_max": 8,
             "rows": [row], "decode_speedup_max": 1.5,
             "decode_speedup_min": 1.5, "all_bit_identical": True}
        validate_result(d)  # no claim: fine under interpret
        with pytest.raises(ValueError, match="interpret"):
            validate_result(dict(d, compiled_speedup=2.0))
        stream = {"rows": 1, "ratio_min": 0.5, "ratio_max": 0.9,
                  "bit_identical": True}
        validate_result(dict(d, stream=stream))
        with pytest.raises(ValueError, match="interpret"):
            validate_result(dict(d, stream=dict(stream,
                                                compiled_speedup=2.0)))
        with pytest.raises(ValueError, match="provenance"):
            validate_result(dict(d, backend="cpu"))


class TestTrafficReplayClosure:
    def test_replays_committed_artifact_within_bound(self):
        """The loop-closing check on the committed BENCH_traffic.json:
        rebuilding the Poisson workload and replaying it through the
        row's own measured segment times reproduces the measured goodput
        and TTFT within the artifact's stated bound."""
        import json
        from pathlib import Path

        path = Path(__file__).resolve().parents[1] / "BENCH_traffic.json"
        bench = json.loads(path.read_text())
        predicted, cmp = P.replay_traffic_bench(bench, "1")
        bound = float(bench["replay_check"]["error_bound_pct"])
        assert cmp["goodput_error_pct"] <= bound, cmp
        assert cmp["ttft_error_pct"] <= bound, cmp
        # the discrete schedule must agree exactly, not approximately
        assert cmp["predicted_tokens"] == cmp["measured_tokens"]
        assert predicted["decode_steps"] == bench["rows"]["1"]["decode_steps"]

    def test_rejects_multi_replica_row(self):
        bench = {"rows": {"2": {"replicas": 2}}, "arch": "a", "seed": 0,
                 "n_slots": 4, "s_max": 64}
        with pytest.raises(ValueError, match="replicas"):
            P.replay_traffic_bench(bench, "2")

    def test_table_from_traffic_row(self):
        row = {"tok_latency_us": {"p50": 1500.0}, "ttft_us": {"p50": 9000.0},
               "queue_wait_us": {"p50": 2000.0}, "decode_steps": 30,
               "prefill_batches": 8}
        table = P.table_from_traffic_row(row, "smollm-135m")
        fit = next(iter(table.engines.values()))
        assert fit.decode_fixed_us == 1500.0
        assert fit.prefill_us == 7000.0
        assert fit.n_decode == 30 and fit.n_prefill == 8
