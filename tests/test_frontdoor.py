"""Async serving front door (repro.serve.frontdoor) — DESIGN.md §12.

Covers, against the REAL network stack (TCP loopback, HTTP upgrade,
RFC 6455 frames — never an in-process shortcut):

  * streamed-token order and completeness vs ``generate()``;
  * cancellation mid-stream: the slot frees, survivors' tokens are
    untouched (engine-level identity pinned in TestEngineCancel too);
  * admission control: queue-full rejection over WS and HTTP 429;
  * router-vs-single-engine greedy token identity across 2 replicas;
  * the Poisson arrival model shared with replay.simulate;
  * the ``serve.frontdoor.step_passthrough`` tracing contract — the
    async layer leaves the fused step's jaxpr untouched.
"""
import asyncio

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import transformer as T
from repro.models.layers import QuantConfig
from repro.models.registry import get_config
from repro.serve.engine import ContinuousBatcher, Request, generate


def setup():
    cfg = get_config("smollm-135m", smoke=True).replace(
        quant=QuantConfig(mode="off"))
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def solo_tokens(params, cfg, prompt, max_new, s_max=32):
    """Greedy reference stream for one prompt (the engine-independent
    ground truth every serving path must reproduce)."""
    out = generate(params, jnp.asarray([prompt], jnp.int32), cfg,
                   max_new=max_new, s_max=s_max)
    return np.asarray(out)[0].tolist()


# ---------------------------------------------------------------------------
# Engine-level cancellation (satellite: ContinuousBatcher.cancel)
# ---------------------------------------------------------------------------


class TestEngineCancel:
    def test_cancel_queued_request_never_runs(self):
        cfg, params = setup()
        b = ContinuousBatcher(params, cfg, n_slots=1, s_max=32)
        a, q = Request(0, [3, 1, 4], max_new=4), Request(1, [9, 8], max_new=4)
        b.submit(a)
        b.submit(q)
        assert b.cancel(1) is True
        assert q.done and q.cancelled and q.generated == []
        b.run()
        assert a.generated == solo_tokens(params, cfg, [3, 1, 4], 4)

    def test_cancel_active_slot_preserves_survivor_tokens(self):
        """Cancel one request mid-decode: its slot frees for the queued
        request, and the survivor's token stream is bit-identical to
        solo generate() — the cancel perturbed no other row."""
        cfg, params = setup()
        prompts = {0: [3, 1, 4], 1: [9, 8], 2: [2, 7, 1, 8]}
        b = ContinuousBatcher(params, cfg, n_slots=2, s_max=32)
        reqs = {rid: Request(rid, p, max_new=10 if rid == 0 else 6)
                for rid, p in prompts.items()}
        for r in reqs.values():
            b.submit(r)
        b.step()  # prefill rid 0+1 into slots, first decode step
        b.step()
        assert len(reqs[0].generated) >= 1 and not reqs[0].done
        assert b.cancel(0) is True
        assert reqs[0].done and reqs[0].cancelled and reqs[0].truncated
        assert None in b.slot_req or any(
            r is reqs[2] for r in b.slot_req)  # slot freed (or refilled)
        b.run()
        for rid in (1, 2):
            r = reqs[rid]
            assert r.done and not r.cancelled
            assert r.generated == solo_tokens(
                params, cfg, prompts[rid], r.max_new)
        # the cancelled stream is a greedy prefix — decode never diverged
        full = solo_tokens(params, cfg, prompts[0], 10)
        assert reqs[0].generated == full[: len(reqs[0].generated)]

    def test_cancel_unknown_or_finished_rid_is_false(self):
        cfg, params = setup()
        b = ContinuousBatcher(params, cfg, n_slots=1, s_max=32)
        assert b.cancel(7) is False
        r = Request(0, [5], max_new=2)
        b.submit(r)
        b.run()
        assert r.done
        assert b.cancel(0) is False

    def test_stats_counts_prefill_batches(self):
        cfg, params = setup()
        b = ContinuousBatcher(params, cfg, n_slots=2, s_max=32)
        for i in range(3):
            b.submit(Request(i, [1 + i, 2], max_new=2))
        b.run()
        s = b.stats()
        assert s["prefill_batches"] >= 1
        # fused discipline: one fetch per decode step + one per prefill
        assert s["host_syncs"] == s["decode_steps"] + s["prefill_batches"]


# ---------------------------------------------------------------------------
# Poisson arrival model (satellite: profile.replay.poisson_requests)
# ---------------------------------------------------------------------------


class TestPoissonModel:
    def test_deterministic_per_seed(self):
        from repro.profile import poisson_requests

        a = poisson_requests(100.0, seed=3, n_requests=12)
        b = poisson_requests(100.0, seed=3, n_requests=12)
        c = poisson_requests(100.0, seed=4, n_requests=12)
        assert a == b
        assert a != c

    def test_arrivals_monotone_rate_scaled(self):
        from repro.profile import poisson_requests

        fast = poisson_requests(1000.0, seed=0, n_requests=64)
        slow = poisson_requests(10.0, seed=0, n_requests=64)
        for reqs in (fast, slow):
            arr = [r.arrival_us for r in reqs]
            assert all(b > a for a, b in zip(arr, arr[1:]))
            assert all(1 <= r.prompt_len <= 4 and 2 <= r.max_new <= 8
                       for r in reqs)
        # same seed => same exponential draws, scaled by 1/rate
        assert slow[-1].arrival_us == pytest.approx(
            fast[-1].arrival_us * 100.0, rel=1e-9)

    def test_bad_args_raise(self):
        from repro.profile import poisson_requests

        with pytest.raises(ValueError):
            poisson_requests(0.0)
        with pytest.raises(ValueError):
            poisson_requests(10.0, max_new=1)

    def test_simulate_is_arrival_aware(self):
        """The same workload offered up front vs trickled in: the
        simulated clock must wait for late arrivals (first node starts
        no earlier than the first arrival; total spans the last)."""
        import repro.profile as P

        table = P.CalibrationTable(
            version=P.CALIBRATION_VERSION, backend="cpu",
            default_spec="exact/jnp/none",
            kernels={"exact/jnp/none|decode":
                     P.KernelFit(10.0, 1.0, 1.0, 2.0, 5, 0.5)},
            engines={"smollm-135m|tp1": P.EngineFit(
                "smollm-135m", "tp1", "mode:off", 1000.0, 2000.0, 10, 3, 1.0)},
        )
        offline = [P.ReplayRequest(i, 2, 4) for i in range(4)]
        spaced = [P.ReplayRequest(i, 2, 4, arrival_us=5e5 * (i + 1))
                  for i in range(4)]
        pred_off = P.simulate(table, "smollm-135m", offline)
        pred_sp = P.simulate(table, "smollm-135m", spaced)
        assert pred_off["tokens"] == pred_sp["tokens"]
        assert pred_sp["graph"][0]["start_us"] >= 5e5
        assert pred_sp["total_us"] >= 4 * 5e5
        assert pred_sp["tok_s"] < pred_off["tok_s"]

    def test_poisson_requests_feed_simulate(self):
        """The shared currency end-to-end: poisson_requests output is
        directly consumable by replay.simulate."""
        import repro.profile as P

        table = P.CalibrationTable(
            version=P.CALIBRATION_VERSION, backend="cpu",
            default_spec="exact/jnp/none",
            kernels={},
            engines={"smollm-135m|tp1": P.EngineFit(
                "smollm-135m", "tp1", "mode:off", 1000.0, 2000.0, 10, 3, 1.0)},
        )
        reqs = P.poisson_requests(200.0, seed=1, n_requests=8)
        pred = P.simulate(table, "smollm-135m", reqs)
        assert pred["tokens"] == sum(r.max_new for r in reqs)
        assert pred["decode_steps"] > 0


# ---------------------------------------------------------------------------
# The wire protocol (stdlib HTTP + RFC 6455)
# ---------------------------------------------------------------------------


class TestProtocol:
    def test_ws_accept_key_rfc_vector(self):
        from repro.serve.frontdoor.protocol import ws_accept_key

        # RFC 6455 §1.3's worked example
        assert (ws_accept_key("dGhlIHNhbXBsZSBub25jZQ==")
                == "s3pPLMBiTxaQ9kYGzzhZRbK+xOo=")

    @pytest.mark.parametrize("size", [5, 200, 70000])
    @pytest.mark.parametrize("mask", [False, True])
    def test_frame_roundtrip(self, size, mask):
        """Encode -> decode at every length-encoding tier (7-bit, 126
        extended-16, 127 extended-64), masked and unmasked."""
        from repro.serve.frontdoor.protocol import (
            OP_TEXT,
            ws_encode_frame,
            ws_read_frame,
        )

        payload = bytes(i % 251 for i in range(size))
        frame = ws_encode_frame(OP_TEXT, payload, mask=mask)

        async def roundtrip():
            reader = asyncio.StreamReader()
            reader.feed_data(frame)
            reader.feed_eof()
            return await ws_read_frame(reader)

        opcode, out = asyncio.run(roundtrip())
        assert opcode == OP_TEXT and out == payload

    def test_fragmented_frame_rejected(self):
        from repro.serve.frontdoor.protocol import (
            ProtocolError,
            ws_read_frame,
        )

        async def read_fin0():
            reader = asyncio.StreamReader()
            reader.feed_data(bytes([0x01, 0x01, 0x41]))  # FIN=0 text frame
            reader.feed_eof()
            return await ws_read_frame(reader)

        with pytest.raises(ProtocolError):
            asyncio.run(read_fin0())

    def test_http_request_parse_and_response(self):
        from repro.serve.frontdoor.protocol import (
            http_response,
            read_http_request,
        )

        async def parse():
            reader = asyncio.StreamReader()
            reader.feed_data(
                b"POST /v1/generate HTTP/1.1\r\nHost: x\r\n"
                b"Content-Length: 2\r\n\r\n{}")
            reader.feed_eof()
            return await read_http_request(reader)

        req = asyncio.run(parse())
        assert req.method == "POST" and req.path == "/v1/generate"
        assert req.json() == {}
        resp = http_response(429, b'{"error": "queue_full"}')
        assert resp.startswith(b"HTTP/1.1 429 Too Many Requests\r\n")
        assert resp.endswith(b'{"error": "queue_full"}')


# ---------------------------------------------------------------------------
# The front door over real sockets
# ---------------------------------------------------------------------------


async def _make_door(params, cfg, *, replicas=1, n_slots=2, s_max=32,
                     queue_limit=16):
    from repro.serve.frontdoor import (
        EngineWorker,
        FrontDoor,
        ReplicaRouter,
        SLOTracker,
    )

    tracker = SLOTracker()
    workers = [
        EngineWorker(
            f"r{i}",
            ContinuousBatcher(params, cfg, n_slots=n_slots, s_max=s_max),
            tracker)
        for i in range(replicas)
    ]
    door = FrontDoor(ReplicaRouter(workers, queue_limit=queue_limit), tracker)
    await door.start()
    return door


class TestFrontDoor:
    def test_streamed_tokens_match_generate(self):
        """One WS request: token messages arrive in index order, and the
        complete stream equals solo generate() exactly."""
        cfg, params = setup()

        async def scenario():
            from repro.serve.frontdoor.client import WSClient

            door = await _make_door(params, cfg)
            try:
                ws = await WSClient.connect(door.host, door.port)
                await ws.send({"type": "generate", "prompt": [3, 1, 4],
                               "max_new": 6})
                msgs = []
                while True:
                    m = await ws.recv()
                    msgs.append(m)
                    if m["type"] in ("done", "error"):
                        break
                await ws.close()
                return msgs
            finally:
                await door.stop()

        msgs = asyncio.run(scenario())
        assert msgs[0]["type"] == "admitted"
        toks = [m for m in msgs if m["type"] == "token"]
        assert [m["index"] for m in toks] == list(range(len(toks)))
        assert msgs[-1]["type"] == "done"
        assert msgs[-1]["cancelled"] is False
        cfg2, params2 = setup()
        assert ([m["token"] for m in toks]
                == solo_tokens(params2, cfg2, [3, 1, 4], 6))

    def test_cancel_mid_stream_is_clean_and_survivor_exact(self):
        """Cancel one of two concurrent streams mid-decode: the
        cancelled stream ends with done{cancelled}, its delivered tokens
        are a greedy prefix, and the surviving stream is token-identical
        to generate()."""
        cfg, params = setup()

        async def scenario():
            from repro.serve.frontdoor.client import WSClient

            door = await _make_door(params, cfg, n_slots=2)
            try:
                w1 = await WSClient.connect(door.host, door.port)
                w2 = await WSClient.connect(door.host, door.port)
                victim, survivor = await asyncio.gather(
                    w1.generate([3, 1, 4], 20, cancel_after=2),
                    w2.generate([9, 8], 8),
                )
                await w1.close()
                await w2.close()
                return victim, survivor
            finally:
                await door.stop()

        victim, survivor = asyncio.run(scenario())
        assert victim["done"]["cancelled"] is True
        assert 2 <= len(victim["tokens"]) < 20
        full = solo_tokens(params, cfg, [3, 1, 4], 20)
        assert victim["tokens"] == full[: len(victim["tokens"])]
        assert survivor["done"]["cancelled"] is False
        assert survivor["tokens"] == solo_tokens(params, cfg, [9, 8], 8)

    def test_admission_rejected_when_saturated(self):
        """queue_limit 1: while one request is in flight, a second is
        rejected with queue_full over WS and 429 over HTTP; after the
        first finishes, admission opens again."""
        cfg, params = setup()

        async def scenario():
            from repro.serve.frontdoor.client import WSClient, http_json

            door = await _make_door(params, cfg, n_slots=1, queue_limit=1)
            try:
                w1 = await WSClient.connect(door.host, door.port)
                w2 = await WSClient.connect(door.host, door.port)
                first = asyncio.ensure_future(w1.generate([3, 1, 4], 12))
                # wait until the first request is admitted and in flight
                while door.router.in_flight == 0:
                    await asyncio.sleep(0.001)
                rejected_ws = None
                try:
                    await w2.generate([9, 8], 4)
                except RuntimeError as e:
                    rejected_ws = e.payload
                status_429, body = await http_json(
                    door.host, door.port, "POST", "/v1/generate",
                    {"prompt": [9, 8], "max_new": 4})
                await first
                retry = await w2.generate([9, 8], 4)
                await w1.close()
                await w2.close()
                _, stats = await http_json(
                    door.host, door.port, "GET", "/stats")
                return rejected_ws, status_429, body, retry, stats
            finally:
                await door.stop()

        rejected_ws, status_429, body, retry, stats = asyncio.run(scenario())
        assert rejected_ws is not None and rejected_ws["error"] == "queue_full"
        assert status_429 == 429 and body["error"] == "queue_full"
        assert retry["tokens"] == solo_tokens(params, cfg, [9, 8], 4)
        assert stats["slo"]["requests"]["rejected"] == 2

    def test_router_two_replicas_token_identity(self):
        """Six concurrent streams across 2 replicas: every request's
        greedy tokens equal single-engine generate(), and both replicas
        actually served work."""
        cfg, params = setup()
        prompts = [[3, 1, 4], [9, 8], [2, 7, 1, 8], [6], [5, 5, 5], [1, 2]]
        max_news = [4, 6, 3, 5, 4, 6]

        async def scenario():
            from repro.serve.frontdoor.client import WSClient, http_json

            door = await _make_door(params, cfg, replicas=2, n_slots=2,
                                    queue_limit=16)
            try:
                conns = [await WSClient.connect(door.host, door.port)
                         for _ in prompts]
                results = await asyncio.gather(*[
                    ws.generate(p, m)
                    for ws, p, m in zip(conns, prompts, max_news)])
                for ws in conns:
                    await ws.close()
                _, stats = await http_json(
                    door.host, door.port, "GET", "/stats")
                return results, stats
            finally:
                await door.stop()

        results, stats = asyncio.run(scenario())
        for res, p, m in zip(results, prompts, max_news):
            assert res["tokens"] == solo_tokens(params, cfg, p, m), p
        steps = [r["decode_steps"] for r in stats["router"]["replicas"]]
        assert all(s > 0 for s in steps), steps
        assert stats["slo"]["requests"]["completed"] == len(prompts)

    def test_oneshot_post_returns_token_ids(self):
        """POST /v1/generate: the body's "tokens" is the id list (the
        done payload's count rides as "n_tokens" — regression: the
        count used to clobber the list)."""
        cfg, params = setup()

        async def scenario():
            from repro.serve.frontdoor.client import http_json

            door = await _make_door(params, cfg)
            try:
                return await http_json(
                    door.host, door.port, "POST", "/v1/generate",
                    {"prompt": [3, 1, 4], "max_new": 5})
            finally:
                await door.stop()

        status, body = asyncio.run(scenario())
        assert status == 200
        assert body["tokens"] == solo_tokens(params, cfg, [3, 1, 4], 5)
        assert body["n_tokens"] == 5 and body["cancelled"] is False

    def test_healthz_stats_and_clean_shutdown(self):
        cfg, params = setup()

        async def scenario():
            from repro.serve.frontdoor.client import WSClient, http_json

            door = await _make_door(params, cfg, replicas=2)
            try:
                s1, health = await http_json(
                    door.host, door.port, "GET", "/healthz")
                ws = await WSClient.connect(door.host, door.port)
                await ws.generate([5], 2)
                await ws.close()
                s2, stats = await http_json(
                    door.host, door.port, "GET", "/stats")
                s3, missing = await http_json(
                    door.host, door.port, "GET", "/nope")
            finally:
                await door.stop()
            loads = [w.load for w in door.router.workers]
            return s1, health, s2, stats, s3, missing, loads

        s1, health, s2, stats, s3, missing, loads = asyncio.run(scenario())
        assert s1 == 200 and health["ok"] and health["replicas"] == 2
        assert s2 == 200
        assert stats["slo"]["tokens_out"] == 2
        assert stats["slo"]["slo_us"]["ttft"]["n"] == 1
        assert s3 == 404 and missing["error"] == "not_found"
        assert loads == [0, 0]

    def test_connection_drop_cancels_in_flight(self):
        """A client that vanishes mid-stream must not leak its slot:
        the request is cancelled at the next step boundary and the
        router drains to zero."""
        cfg, params = setup()

        async def scenario():
            from repro.serve.frontdoor.client import WSClient

            door = await _make_door(params, cfg, n_slots=1)
            try:
                ws = await WSClient.connect(door.host, door.port)
                await ws.send({"type": "generate", "prompt": [3, 1, 4],
                               "max_new": 24})
                # read two tokens then hang up without close handshake
                got = 0
                while got < 2:
                    m = await ws.recv()
                    if m["type"] == "token":
                        got += 1
                ws.writer.close()
                for _ in range(2000):
                    if door.router.in_flight == 0:
                        break
                    await asyncio.sleep(0.005)
                return door.router.in_flight, door.tracker.cancelled
            finally:
                await door.stop()

        in_flight, cancelled = asyncio.run(scenario())
        assert in_flight == 0
        assert cancelled == 1

    def test_protocol_error_closes_1002_and_frees_slot(self):
        """A malformed frame (here: fragmented, FIN=0) mid-stream must
        get a close frame with code 1002 — not a bare TCP reset — and
        the in-flight request's admission slot must be reclaimed."""
        cfg, params = setup()

        async def scenario():
            from repro.serve.frontdoor.client import WSClient
            from repro.serve.frontdoor.protocol import (
                OP_CLOSE,
                ws_close_code,
                ws_read_frame,
            )

            door = await _make_door(params, cfg, n_slots=1)
            try:
                ws = await WSClient.connect(door.host, door.port)
                await ws.send({"type": "generate", "prompt": [3, 1, 4],
                               "max_new": 24})
                got = 0
                while got < 2:
                    m = await ws.recv()
                    if m["type"] == "token":
                        got += 1
                # FIN=0 masked text frame, empty payload: fragmentation
                # is a deliberate non-goal, the server must refuse it
                ws.writer.write(bytes([0x01, 0x80, 0, 0, 0, 0]))
                await ws.writer.drain()
                # tokens already in flight may arrive first; the close
                # frame with the protocol-error code must follow
                code = None
                for _ in range(100):
                    opcode, payload = await asyncio.wait_for(
                        ws_read_frame(ws.reader), timeout=5)
                    if opcode == OP_CLOSE:
                        code = ws_close_code(payload)
                        break
                for _ in range(2000):
                    if door.router.in_flight == 0:
                        break
                    await asyncio.sleep(0.005)
                return code, door.router.in_flight, door.tracker.cancelled
            finally:
                await door.stop()

        code, in_flight, cancelled = asyncio.run(scenario())
        assert code == 1002
        assert in_flight == 0
        assert cancelled == 1


# ---------------------------------------------------------------------------
# Analysis: the async wrapper leaves the jitted step untouched
# ---------------------------------------------------------------------------


class TestPassthroughContract:
    def test_passthrough_is_identity(self):
        from repro.serve.frontdoor.worker import passthrough_step

        def f():
            return 1

        assert passthrough_step(f) is f

    def test_contract_jaxpr_identical_through_wrapper(self):
        """serve.frontdoor.step_passthrough: equation counts invariant
        across wrapped=(0,1), zero host callbacks — no findings."""
        from repro.analysis.jaxpr_audit import run_contract

        findings, meta = run_contract("serve.frontdoor.step_passthrough")
        assert findings == [], [f for f in findings]
        assert meta["skipped"] == []
        # one equation count, same across the wrapped axis (the audit
        # flags divergence as a finding; the count existing proves the
        # wrapped variant actually traced)
        assert len(meta["eqn_counts"]) >= 1
