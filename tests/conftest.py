import os

# Tests run against 8 *virtual* host devices so the tensor-parallel
# serving suite (tests/test_tp_serve.py, test_collectives.py) exercises
# real multi-device meshes on CPU CI. The flag must be appended BEFORE
# the first jax import — jax locks the device count at first init (the
# dry-run forces its own 512 in a fresh process). Single-device tests
# are unaffected: computations without sharded operands place on device
# 0. Keep XLA quiet and deterministic.
os.environ.setdefault("JAX_PLATFORMS", "cpu")
_flags = os.environ.get("XLA_FLAGS", "")
if "xla_cpu_multi_thread_eigen" not in _flags:
    _flags += " --xla_cpu_multi_thread_eigen=false"
if "xla_force_host_platform_device_count" not in _flags:
    _flags += " --xla_force_host_platform_device_count=8"
os.environ["XLA_FLAGS"] = _flags.strip()

import jax  # noqa: E402
import pytest  # noqa: E402

jax.config.update("jax_enable_x64", False)


@pytest.fixture(scope="session")
def tp_mesh():
    """Session-scoped 8-device ("data", "model") host mesh — the real
    multi-device fixture every TP/collective test runs on. Skips (rather
    than fails) when the environment overrode XLA_FLAGS without the
    forced-device-count flag, so partial-environment runs still pass."""
    if jax.device_count() < 8:
        pytest.skip(
            f"needs 8 virtual devices, have {jax.device_count()} "
            "(XLA_FLAGS=--xla_force_host_platform_device_count=8)"
        )
    from repro.launch.mesh import make_tp_mesh

    return make_tp_mesh(8)
