import os

# Tests run single-device (the dry-run, and ONLY the dry-run, forces 512
# host devices). Keep XLA quiet and deterministic.
os.environ.setdefault("JAX_PLATFORMS", "cpu")
os.environ.setdefault("XLA_FLAGS", "--xla_cpu_multi_thread_eigen=false")

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)
