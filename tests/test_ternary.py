"""Unit + property tests for ternary quantization and encodings."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # minimal installs: suite still collects
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import ternary as tern


def rand_ternary(key, shape):
    return jax.random.randint(key, shape, -1, 2).astype(jnp.int8)


class TestTernarize:
    def test_outputs_are_ternary(self):
        x = jax.random.normal(jax.random.PRNGKey(0), (64, 32))
        t, scale = tern.ternarize(x)
        assert set(np.unique(np.asarray(t))) <= {-1.0, 0.0, 1.0}
        assert float(scale) > 0

    def test_scale_is_conditional_mean(self):
        x = jax.random.normal(jax.random.PRNGKey(1), (1024,))
        t, scale = tern.ternarize(x)
        mask = np.asarray(t) != 0
        expected = np.abs(np.asarray(x))[mask].mean()
        np.testing.assert_allclose(float(scale), expected, rtol=1e-5)

    def test_per_channel(self):
        x = jax.random.normal(jax.random.PRNGKey(2), (128, 8)) * jnp.arange(1, 9) ** 2
        t, scale = tern.ternarize(x, axis=(0,))
        assert scale.shape == (1, 8)
        s = np.asarray(scale)[0]
        assert s[-1] > 4 * s[0]  # scales track per-channel magnitude

    def test_zero_input(self):
        t, scale = tern.ternarize(jnp.zeros((16,)))
        assert np.all(np.asarray(t) == 0)


class TestSTE:
    def test_forward_ternary_times_scale(self):
        x = jax.random.normal(jax.random.PRNGKey(3), (256,))
        y = tern.ste_ternarize(x)
        t, s = tern.ternarize(x)
        np.testing.assert_allclose(np.asarray(y), np.asarray(t * s), rtol=1e-6)

    def test_gradient_clipped_identity(self):
        x = jnp.array([-2.0, -0.5, 0.1, 0.5, 2.0])
        g = jax.grad(lambda v: tern.ste_ternarize(v).sum())(x)
        np.testing.assert_array_equal(np.asarray(g), [0.0, 1.0, 1.0, 1.0, 0.0])

    def test_unit_variant_unscaled(self):
        x = jax.random.normal(jax.random.PRNGKey(4), (64,))
        y = tern.ste_unit_ternarize(x)
        assert set(np.unique(np.asarray(y))) <= {-1.0, 0.0, 1.0}


class TestBitplanes:
    def test_encoding_table(self):
        # Fig. 3(a): W=+1 -> (1,0); W=-1 -> (0,1); W=0 -> (0,0)
        t = jnp.array([1, -1, 0], jnp.int8)
        m1, m2 = tern.to_bitplanes(t)
        np.testing.assert_array_equal(np.asarray(m1), [1, 0, 0])
        np.testing.assert_array_equal(np.asarray(m2), [0, 1, 0])
        np.testing.assert_array_equal(np.asarray(tern.from_bitplanes(m1, m2)), np.asarray(t))
        assert bool(tern.validate_bitplanes(m1, m2))

    def test_illegal_state_detected(self):
        assert not bool(tern.validate_bitplanes(jnp.ones((2,), jnp.uint8), jnp.ones((2,), jnp.uint8)))

    @pytest.mark.parametrize("shape,axis", [((64,), 0), ((48, 8), 0), ((8, 16, 4), 1)])
    def test_pack_roundtrip(self, shape, axis):
        t = rand_ternary(jax.random.PRNGKey(5), shape)
        p1, p2 = tern.pack_ternary(t, axis=axis)
        assert p1.shape[axis] == shape[axis] // 8
        out = tern.unpack_ternary(p1, p2, axis=axis)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(t))

    def test_pack_requires_multiple_of_8(self):
        with pytest.raises(ValueError):
            tern.pack_ternary(jnp.zeros((7,), jnp.int8))


@settings(max_examples=30, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(1, 6), st.integers(1, 6))
def test_pack_roundtrip_property(seed, rows8, cols):
    t = rand_ternary(jax.random.PRNGKey(seed), (rows8 * 8, cols))
    p1, p2 = tern.pack_ternary(t, axis=0)
    np.testing.assert_array_equal(
        np.asarray(tern.unpack_ternary(p1, p2, axis=0)), np.asarray(t)
    )


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_ternarize_idempotent_property(seed):
    """ternarize(t * s) == (t, ~s) for already-ternary inputs."""
    t = rand_ternary(jax.random.PRNGKey(seed), (128,)).astype(jnp.float32)
    t2, s2 = tern.ternarize(t)
    np.testing.assert_array_equal(np.asarray(t2), np.asarray(t))


def test_block_overflow_rate_sparse_inputs():
    """Paper: sparsity keeps ADC overflow rare. Dense random +-1 overflows
    much more often than 70%-sparse inputs."""
    key = jax.random.PRNGKey(7)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    dense_x = jax.random.choice(k1, jnp.array([-1, 1]), (64, 256)).astype(jnp.float32)
    dense_w = jax.random.choice(k2, jnp.array([-1, 1]), (256, 64)).astype(jnp.float32)
    sparse_x = dense_x * jax.random.bernoulli(k3, 0.3, dense_x.shape)
    sparse_w = dense_w * jax.random.bernoulli(k4, 0.3, dense_w.shape)
    dense_rate = float(tern.block_overflow_rate(dense_x, dense_w))
    sparse_rate = float(tern.block_overflow_rate(sparse_x, sparse_w))
    assert sparse_rate < dense_rate
    assert sparse_rate < 0.01
