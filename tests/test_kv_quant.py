"""Quantized KV cache (DESIGN.md §13): bf16 default bit-identity, int8
fused-vs-generate token identity across serving families, the ternary
greedy-prefix bound, per-slot capacity gains, TP sharded-cache equality,
and both halves of the ``serve.fused_decode_step.kvq`` tracing contract.

The accuracy bars, family by family:

  * ``cache_dtype="bf16"`` (default) — **bit-identical** to the pre-§13
    engine: the jaxpr of the fused step is string-equal under the
    default config and the explicit knob, and served tokens match
    per-request ``generate()``.
  * ``cache_dtype="int8"`` — **token-identical** to ``generate()`` under
    the same dtype on every family (per-(row, position) scales make the
    quantization a function of that row's written vector only, so
    co-batching cannot perturb it).
  * ``cache_dtype="ternary"`` — token-identical on the dense family;
    on MLA/hybrid the bar is a **greedy common-prefix bound**: 2-bit
    codes amplify benign batch-shape rounding differences into late
    argmax flips, so fused and solo decodes must agree on an initial
    prefix but may diverge after it.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import attention as A
from repro.models import transformer as T
from repro.models.layers import QuantConfig
from repro.models.registry import get_config
from repro.serve.engine import ContinuousBatcher, Request, generate

FAMILY_ARCHS = {
    "dense": "smollm-135m",
    "mla": "deepseek-v2-236b",
    "hybrid": "zamba2-2.7b",
}

PROMPTS = [[3, 1, 4], [9, 8], [2, 7, 1, 8, 2], [6]]
MAX_NEWS = [4, 5, 3, 4]


def _family_cfg(family, cache_dtype="bf16"):
    cfg = get_config(FAMILY_ARCHS[family], smoke=True)
    if family == "mla":
        cfg = cfg.replace(moe_capacity_factor=8.0)  # no smoke-size drops
    return cfg.replace(quant=QuantConfig(mode="off", cache_dtype=cache_dtype))


def _serve(params, cfg, mesh=None, **kw):
    b = ContinuousBatcher(params, cfg, n_slots=2, s_max=32, mesh=mesh, **kw)
    reqs = [Request(i, p, max_new=m) for i, (p, m) in
            enumerate(zip(PROMPTS, MAX_NEWS))]
    for r in reqs:
        b.submit(r)
    b.run()
    assert all(r.done for r in reqs)
    return [r.generated for r in reqs]


def _solos(params, cfg):
    return [
        np.asarray(generate(params, jnp.asarray([p], jnp.int32), cfg,
                            max_new=m, s_max=32))[0].tolist()
        for p, m in zip(PROMPTS, MAX_NEWS)
    ]


# ---------------------------------------------------------------------------
# bf16 default: bit-identical to the pre-§13 engine
# ---------------------------------------------------------------------------


class TestBF16Default:
    def test_default_jaxpr_unchanged_by_knob(self):
        """The fused decode step traces to the *string-identical* jaxpr
        under the default QuantConfig and the explicit
        ``cache_dtype="bf16"`` — the knob is a pure no-op until opted
        into, at trace granularity, not just token granularity."""
        from repro.serve.engine import _fused_step_point

        jaxprs = {}
        for label, cd in (("default", None), ("explicit", "bf16")):
            cfg = get_config("smollm-135m", smoke=True)
            qc = (QuantConfig(mode="off") if cd is None
                  else QuantConfig(mode="off", cache_dtype=cd))
            assert qc.cache_dtype == "bf16"
            build = _fused_step_point("off", cache_dtype=qc.cache_dtype)
            f, args = build(n_slots=3)
            jaxprs[label] = str(jax.make_jaxpr(f)(*args))
        assert jaxprs["default"] == jaxprs["explicit"]

    @pytest.mark.parametrize("family", sorted(FAMILY_ARCHS))
    def test_bf16_tokens_match_generate(self, family):
        cfg = _family_cfg(family)
        params = T.init_params(jax.random.PRNGKey(0), cfg)
        assert _serve(params, cfg) == _solos(params, cfg)

    def test_cache_dtype_validated(self):
        with pytest.raises(ValueError, match="cache_dtype"):
            QuantConfig(mode="off", cache_dtype="int4")

    def test_engine_kwarg_overrides_config(self):
        """ContinuousBatcher(cache_dtype=...) rewrites cfg.quant — the
        serving-time opt-in path the bench sweep drives."""
        cfg = _family_cfg("dense")
        params = T.init_params(jax.random.PRNGKey(0), cfg)
        b = ContinuousBatcher(params, cfg, n_slots=2, s_max=32,
                              cache_dtype="int8")
        assert b.cfg.quant.cache_dtype == "int8"
        caches = T.init_caches(b.cfg, 2, 32)
        k = jax.tree_util.tree_leaves(caches)[0]
        assert any(leaf.dtype == jnp.int8
                   for leaf in jax.tree_util.tree_leaves(caches))


# ---------------------------------------------------------------------------
# int8: token identity fused vs generate, every family
# ---------------------------------------------------------------------------


class TestInt8Identity:
    @pytest.mark.parametrize("family", sorted(FAMILY_ARCHS))
    def test_fused_tokens_match_generate(self, family):
        """The acceptance pin: int8-cached fused serving produces the
        same tokens as int8-cached per-request generate() — quantization
        error exists, but it is *identical* between the co-batched and
        solo decodes (per-row scales, row-local quantization)."""
        cfg = _family_cfg(family, cache_dtype="int8")
        params = T.init_params(jax.random.PRNGKey(0), cfg)
        assert _serve(params, cfg) == _solos(params, cfg)

    def test_cache_leaves_are_int8_with_f32_scales(self):
        cfg = _family_cfg("dense", cache_dtype="int8")
        caches = T.init_caches(cfg, 2, 32)
        for c in caches:
            if isinstance(c, A.QuantKVCache):
                assert c.k.dtype == jnp.int8 and c.v.dtype == jnp.int8
                assert c.k_scale.dtype == jnp.float32
                assert c.k_scale.shape == c.k.shape[:2]  # per (row, pos)


# ---------------------------------------------------------------------------
# ternary: dense exact, MLA/hybrid greedy-prefix bound
# ---------------------------------------------------------------------------


class TestTernary:
    def test_dense_tokens_match_generate(self):
        cfg = _family_cfg("dense", cache_dtype="ternary")
        params = T.init_params(jax.random.PRNGKey(0), cfg)
        assert _serve(params, cfg) == _solos(params, cfg)

    @pytest.mark.parametrize("family", ["mla", "hybrid"])
    def test_greedy_prefix_bound(self, family):
        """2-bit codes amplify benign batch-shape float differences into
        late greedy flips — fused and solo must still agree on an
        initial prefix of every request (full divergence would mean a
        real cache bug, not rounding)."""
        cfg = _family_cfg(family, cache_dtype="ternary")
        params = T.init_params(jax.random.PRNGKey(0), cfg)
        served = _serve(params, cfg)
        solos = _solos(params, cfg)
        for got, want in zip(served, solos):
            prefix = 0
            for a, b in zip(got, want):
                if a != b:
                    break
                prefix += 1
            assert prefix >= 2, (family, got, want)

    def test_pack_unpack_round_trip(self):
        t = jnp.asarray(np.random.default_rng(0).integers(-1, 2, (3, 8)),
                        jnp.int8)
        p = A.pack_ternary_kv(t)
        assert p.dtype == jnp.uint8 and p.shape == (3, 4)
        np.testing.assert_array_equal(
            np.asarray(A.unpack_ternary_kv(p, jnp.float32)), np.asarray(t))

    def test_odd_last_dim_rejected(self):
        with pytest.raises(ValueError, match="odd"):
            A.QuantKVCache.zeros(2, 8, 2, 15, cache_dtype="ternary")


# ---------------------------------------------------------------------------
# capacity: per-slot cache bytes shrink by ~2x (int8) / ~3.2x (ternary)
# ---------------------------------------------------------------------------


def _attn_cache_bytes(cfg, n_slots=2, s_max=32):
    # dense arch: the whole cache pytree IS the (stacked) attention cache
    caches = T.init_caches(cfg, n_slots, s_max)
    assert isinstance(caches, (A.KVCache, A.QuantKVCache))
    return sum(leaf.size * leaf.dtype.itemsize
               for leaf in jax.tree_util.tree_leaves(caches))


class TestCapacity:
    def test_per_slot_bytes_ratio(self):
        """Equal cache memory fits more slots: per-slot attention-cache
        bytes must shrink by the documented ratios. With per-position
        f32 scales the exact ratio is 4D/(2D+8) for int8 and 4D/(D+8)
        for ternary, D = n_kv*head_dim bytes per position per tensor —
        1.78x / 3.2x at the smoke arch's D=32, asymptotically 2x / 4x
        at production head counts (DESIGN.md §13)."""
        bytes_by_cd = {
            cd: _attn_cache_bytes(_family_cfg("dense", cache_dtype=cd))
            for cd in ("bf16", "int8", "ternary")
        }
        assert bytes_by_cd["bf16"] / bytes_by_cd["int8"] >= 1.7
        assert bytes_by_cd["bf16"] / bytes_by_cd["ternary"] >= 3.0

    def test_ssm_state_stays_exact(self):
        """Quantization applies to attention KV only — SSM recurrent
        state stays full precision (it is rewritten every step; scale
        drift would compound)."""
        cfg = get_config("mamba2-780m", smoke=True).replace(
            quant=QuantConfig(mode="off", cache_dtype="int8"))
        caches = T.init_caches(cfg, 2, 32)
        for leaf in jax.tree_util.tree_leaves(caches):
            assert leaf.dtype == jnp.float32


# ---------------------------------------------------------------------------
# TP: sharded quantized caches serve identically
# ---------------------------------------------------------------------------


class TestTPSharding:
    def test_tp_int8_tokens_match_unsharded(self, tp_mesh):
        """int8 cached serving under TP={1,2} == the unsharded engine,
        token by token — sharding the cache's sequence dim changes where
        the codes live, not what they decode to."""
        from repro.launch.mesh import make_tp_mesh

        cfg = _family_cfg("dense", cache_dtype="int8")
        params = T.init_params(jax.random.PRNGKey(0), cfg)
        base = _serve(params, cfg, None)
        for tp in (1, 2):
            assert _serve(params, cfg, make_tp_mesh(tp)) == base, tp

    def test_tp_ternary_serves_deterministically(self, tp_mesh):
        """Ternary under TP=2: the GSPMD partitioning reassociates the
        score reductions, which 2-bit codes amplify into greedy flips vs
        the unsharded engine (same bar as fused-vs-generate:
        prefix-bound, not equality). What IS pinned: the sharded run is
        deterministic, complete, and in-vocab."""
        from repro.launch.mesh import make_tp_mesh

        cfg = _family_cfg("dense", cache_dtype="ternary")
        params = T.init_params(jax.random.PRNGKey(0), cfg)
        a = _serve(params, cfg, make_tp_mesh(2))
        b = _serve(params, cfg, make_tp_mesh(2))
        assert a == b
        for toks, m in zip(a, MAX_NEWS):
            assert len(toks) == m
            assert all(0 <= t < cfg.vocab for t in toks)

    def test_cache_specs_cover_quantized_leaves(self, tp_mesh):
        """The generic cache_specs rule (first trailing dim divisible by
        the model size shards) applies unchanged to quantized leaves:
        int8 code tensors AND their per-(row, position) scale tensors
        both split on the sequence dim — each device stores half the
        codes and the matching half of the scales (smaller TP cache
        shards, satellite of DESIGN.md §13)."""
        from repro.dist.sharding import cache_specs
        from repro.launch.mesh import make_tp_mesh

        mesh = make_tp_mesh(2)
        cfg = _family_cfg("dense", cache_dtype="int8")
        caches = T.init_caches(cfg, 2, 32)
        specs = cache_specs(caches, mesh, batch=2)
        assert isinstance(caches, A.QuantKVCache)
        # stacked leaves are (L, B, S, ...): the sequence dim shards
        assert tuple(specs.k)[2] == "model"
        assert tuple(specs.k_scale)[2] == "model"
        assert tuple(specs.v)[2] == "model"
        assert tuple(specs.v_scale)[2] == "model"


# ---------------------------------------------------------------------------
# The kvq tracing contract: positive and negative halves
# ---------------------------------------------------------------------------


class TestKVQContract:
    def test_contract_clean(self, tp_mesh):
        """The registered contract over the real fused int8 step: zero
        findings, every (n_slots, tp) combination traced live."""
        from repro.analysis import run_contract

        findings, meta = run_contract("serve.fused_decode_step.kvq")
        assert not findings, findings
        assert not meta["skipped"], meta

    def test_stacked_dequant_trips_rule(self):
        """Sensitivity: a step that dequantizes the stacked cache up
        front (the exact regression the rule guards against) must be
        flagged — the auditor is not vacuously green."""
        from repro.analysis import check_jaxpr, get_trace_contract
        from repro.serve.engine import _KVQ_S_MAX, _fused_step_point

        point = get_trace_contract("serve.fused_decode_step.kvq")
        step, args = _fused_step_point(
            "off", cache_dtype="int8", s_max=_KVQ_S_MAX)(n_slots=2, tp=1)

        def bad_step(params, toks, caches, pos, starts, key):
            def roundtrip(leaf):
                if leaf.dtype == jnp.int8:
                    # materializes the rank-5 float cache copy
                    return leaf.astype(jnp.float32).astype(jnp.int8)
                return leaf
            caches = jax.tree_util.tree_map(roundtrip, caches)
            return step(params, toks, caches, pos, starts, key)

        closed = jax.make_jaxpr(bad_step)(*args)
        hits = check_jaxpr(closed, point.contract, "kvq.negative")
        assert any(f.rule == "kvq-stacked-dequant" for f in hits), hits
