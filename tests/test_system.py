"""End-to-end behaviour of the paper's system.

The headline functional claims:
  1. A ternary DNN trained with QAT (STE) learns (loss decreases).
  2. Running its inference through SiTe CiM array semantics (16-row ADC
     clamp) costs little accuracy vs the exact near-memory ternary
     execution.
  3. The sensing-error channel at the paper's measured rate (3.1e-3) is
     negligible (paper Section III.2).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import site_cim as sc
from repro.core.ternary import ternarize
from repro.data.pipeline import DataConfig, TokenPipeline
from repro.models import transformer as T
from repro.models.layers import QuantConfig
from repro.models.registry import get_config
from repro.optim.adamw import AdamWConfig
from repro.train.trainer import TrainConfig, Trainer


@pytest.fixture(scope="module")
def trained():
    """Train the smoke LM with the CiM forward for a handful of steps."""
    cfg = get_config("smollm-135m", smoke=True)
    pipe = TokenPipeline(DataConfig(vocab=cfg.vocab, seq_len=64, global_batch=8, seed=3))
    tr = Trainer(cfg, AdamWConfig(lr=2e-3), TrainConfig(num_steps=30, log_every=0), pipe)
    log = tr.run()
    return cfg, tr.state.params, pipe, log


def test_qat_training_learns(trained):
    cfg, params, pipe, log = trained
    first = np.mean([m["loss"] for m in log[:5]])
    last = np.mean([m["loss"] for m in log[-5:]])
    assert last < first - 0.1, (first, last)


def _eval_nll(params, cfg, pipe, n_batches=3):
    tot, cnt = 0.0, 0
    for i in range(100, 100 + n_batches):
        b = pipe.batch(i)
        logits = T.forward(params, {"tokens": jnp.asarray(b["tokens"])}, cfg)
        logits = logits.astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, jnp.asarray(b["labels"])[..., None], -1)[..., 0]
        tot += float((logz - gold).sum())
        cnt += b["labels"].size
    return tot / cnt


def test_cim_vs_exact_accuracy_gap_small(trained):
    """Claim 2: ADC-clamped CiM inference ~= exact ternary inference."""
    cfg, params, pipe, _ = trained
    nll_cim = _eval_nll(params, cfg.replace(quant=QuantConfig(mode="cim")), pipe)
    nll_exact = _eval_nll(params, cfg.replace(quant=QuantConfig(mode="ternary")), pipe)
    assert abs(nll_cim - nll_exact) < 0.05 * nll_exact, (nll_cim, nll_exact)


def test_sensing_error_negligible_mlp():
    """Claim 3 on a trained ternary classifier: accuracy with the paper's
    3.1e-3 sensing-error channel stays within 2% of the clean CiM run."""
    key = jax.random.PRNGKey(0)
    k1, k2, k3 = jax.random.split(key, 3)
    centers = jax.random.normal(k1, (4, 64)) * 2.0
    xs = centers[jnp.arange(2048) % 4] + jax.random.normal(k2, (2048, 64))
    ys = jnp.arange(2048) % 4

    w1 = jax.random.normal(k3, (64, 128)) * 0.1
    w2 = jax.random.normal(jax.random.PRNGKey(9), (128, 4)) * 0.1

    def fwd(w1, w2, x, mode="train", key=None, error_prob=0.0):
        xt, sx = ternarize(x)
        w1t, s1 = ternarize(w1, axis=(0,))
        if mode == "train":
            h = xt @ w1t
        else:
            cfgc = sc.SiTeCiMConfig(error_prob=error_prob)
            h = sc.site_cim_matmul(
                xt.astype(jnp.int32), w1t.astype(jnp.int32), cfgc, key=key
            ).astype(jnp.float32)
        h = jax.nn.relu(h * sx * s1)
        return h @ w2

    def loss(w1, w2):
        logits = fwd(w1, w2, xs)
        return -jnp.take_along_axis(jax.nn.log_softmax(logits), ys[:, None], 1).mean()

    g = jax.jit(jax.grad(loss, argnums=(0, 1)))
    for _ in range(60):
        g1, g2 = g(w1, w2)
        w1, w2 = w1 - 0.5 * g1, w2 - 0.5 * g2

    def acc(error_prob, key=None):
        logits = fwd(w1, w2, xs, mode="cim", key=key, error_prob=error_prob)
        return float((jnp.argmax(logits, -1) == ys).mean())

    clean = acc(0.0)
    noisy = acc(sc.SENSE_ERROR_PROB, key=jax.random.PRNGKey(11))
    assert clean > 0.8, clean
    assert abs(clean - noisy) < 0.02, (clean, noisy)


def test_nm_baseline_is_exact():
    """The NM baseline path equals a plain integer matmul (Section V)."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(5))
    x = jax.random.randint(k1, (16, 256), -1, 2)
    w = jax.random.randint(k2, (256, 32), -1, 2)
    np.testing.assert_array_equal(
        np.asarray(sc.nm_ternary_matmul(x, w)), np.asarray(x @ w)
    )
