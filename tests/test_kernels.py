"""Per-kernel allclose sweeps against the pure-jnp oracles (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:  # minimal installs: unit tests run, property tests are skipped
    from hypothesis import given, settings, strategies as st
except ImportError:
    given = settings = st = None

from repro.core.ternary import pack_ternary
from repro.kernels import ops, ref
from repro.kernels.packed_mac import packed_cim_matmul
from repro.kernels.ternary_mac import ternary_cim_matmul, ternary_exact_matmul


def rand_ternary(key, shape, dtype=jnp.bfloat16, p_zero=0.3):
    k1, k2 = jax.random.split(key)
    sign = jax.random.choice(k1, jnp.array([-1, 1]), shape)
    keep = jax.random.bernoulli(k2, 1 - p_zero, shape)
    return (sign * keep).astype(dtype)


SHAPES = [
    (128, 128, 128),
    (256, 384, 128),
    (128, 256, 256),
    (384, 128, 384),
]
DTYPES = [jnp.bfloat16, jnp.float32]


class TestCiMKernel:
    @pytest.mark.parametrize("m,k,n", SHAPES)
    @pytest.mark.parametrize("dtype", DTYPES)
    def test_matches_oracle(self, m, k, n, dtype):
        kx, kw = jax.random.split(jax.random.PRNGKey(m * 7 + k + n))
        x = rand_ternary(kx, (m, k), dtype)
        w = rand_ternary(kw, (k, n), dtype)
        out = ternary_cim_matmul(x, w, interpret=True)
        expect = ref.ref_cim_matmul(x, w)
        np.testing.assert_allclose(np.asarray(out), np.asarray(expect), atol=0)

    @pytest.mark.parametrize("bm,bk,bn", [(128, 128, 128), (256, 128, 128), (128, 384, 128)])
    def test_block_shape_sweep(self, bm, bk, bn):
        kx, kw = jax.random.split(jax.random.PRNGKey(42))
        x = rand_ternary(kx, (256, 384), jnp.bfloat16)
        w = rand_ternary(kw, (384, 256), jnp.bfloat16)
        out = ternary_cim_matmul(x, w, bm=bm, bk=bk, bn=bn, interpret=True)
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(ref.ref_cim_matmul(x, w)), atol=0
        )

    def test_dense_inputs_exercise_clamp(self):
        kx, kw = jax.random.split(jax.random.PRNGKey(3))
        x = rand_ternary(kx, (128, 128), p_zero=0.0)
        w = rand_ternary(kw, (128, 128), p_zero=0.0)
        out = np.asarray(ternary_cim_matmul(x, w, interpret=True))
        exact = np.asarray(x.astype(jnp.float32) @ w.astype(jnp.float32))
        assert (out != exact).any()  # clamp must bind somewhere
        np.testing.assert_allclose(out, np.asarray(ref.ref_cim_matmul(x, w)), atol=0)


class TestExactKernel:
    @pytest.mark.parametrize("m,k,n", [(128, 512, 128), (256, 1024, 128)])
    def test_matches_oracle(self, m, k, n):
        kx, kw = jax.random.split(jax.random.PRNGKey(m + k + n))
        x = rand_ternary(kx, (m, k))
        w = rand_ternary(kw, (k, n))
        out = ternary_exact_matmul(x, w, interpret=True)
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(ref.ref_exact_matmul(x, w)), atol=0
        )


class TestPackedKernel:
    @pytest.mark.parametrize("m,k,n", [(128, 256, 128), (128, 512, 256)])
    @pytest.mark.parametrize("cim", [True, False])
    def test_matches_oracle(self, m, k, n, cim):
        kx, kw = jax.random.split(jax.random.PRNGKey(m + k + n + cim))
        x = rand_ternary(kx, (m, k), jnp.float32)
        t = rand_ternary(kw, (k, n), jnp.int8)
        wp, wn = pack_ternary(t, axis=0)
        out = packed_cim_matmul(x, wp, wn, cim=cim, interpret=True)
        expect = ref.ref_packed_matmul(x, wp, wn, cim=cim)
        np.testing.assert_allclose(np.asarray(out), np.asarray(expect), atol=0)

    def test_packed_weights_8x_smaller(self):
        t = rand_ternary(jax.random.PRNGKey(0), (512, 128), jnp.int8)
        wp, wn = pack_ternary(t, axis=0)
        assert wp.nbytes + wn.nbytes == t.nbytes // 4  # int8 -> 2 bits


class TestOpsWrapper:
    def test_ragged_and_batched(self):
        kx, kw = jax.random.split(jax.random.PRNGKey(1))
        x = rand_ternary(kx, (2, 3, 100), jnp.float32)
        w = rand_ternary(kw, (100, 37), jnp.float32)
        out = ops.cim_matmul(x, w)
        x2 = jnp.pad(x.reshape(6, 100), ((0, 0), (0, 12)))
        w2 = jnp.pad(w, ((0, 12), (0, 0)))
        expect = ref.ref_cim_matmul(x2, w2).reshape(2, 3, 37)
        np.testing.assert_allclose(np.asarray(out), np.asarray(expect), atol=0)

    def test_pallas_and_jnp_paths_agree(self):
        kx, kw = jax.random.split(jax.random.PRNGKey(2))
        x = rand_ternary(kx, (64, 200), jnp.float32)
        w = rand_ternary(kw, (200, 50), jnp.float32)
        a = ops.cim_matmul(x, w, 16, 8, "jnp")
        b = ops.cim_matmul(x, w, 16, 8, "pallas")  # interpret on CPU
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=0)

    def test_ste_gradients(self):
        kx, kw = jax.random.split(jax.random.PRNGKey(3))
        x = rand_ternary(kx, (8, 64), jnp.float32)
        w = rand_ternary(kw, (64, 16), jnp.float32)
        gx, gw = jax.grad(lambda x, w: ops.cim_matmul(x, w).sum(), argnums=(0, 1))(x, w)
        # STE backward == exact-matmul backward
        np.testing.assert_allclose(np.asarray(gx), np.asarray(jnp.ones((8, 16)) @ w.T), rtol=1e-5)
        np.testing.assert_allclose(np.asarray(gw), np.asarray(x.T @ jnp.ones((8, 16))), rtol=1e-5)


if st is not None:

    @settings(max_examples=12, deadline=None)
    @given(st.integers(0, 2**31 - 1), st.sampled_from([128, 256]),
           st.sampled_from([128, 256, 384]), st.sampled_from([128, 256]))
    def test_kernel_oracle_property(seed, m, k, n):
        kx, kw = jax.random.split(jax.random.PRNGKey(seed))
        x = rand_ternary(kx, (m, k))
        w = rand_ternary(kw, (k, n))
        out = ternary_cim_matmul(x, w, interpret=True)
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(ref.ref_cim_matmul(x, w)), atol=0
        )
