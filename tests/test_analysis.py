"""repro.analysis: per-rule positive/negative fixtures for both
engines, the four acceptance injections (each reverted), and the
baseline ratchet's byte-reproducibility.

The injection tests are the teeth of the suite: each deliberately
introduces one regression class the auditor exists to catch — an extra
host fetch inside the fused decode step, a per-step pad on the uint8
planes, an f32 accumulator where the decode contract demands int32,
and jaxpr growth with the slot count — asserts the finding fires, then
reverts the injection and asserts the contract is green again.
"""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import (
    Finding,
    PrimRule,
    SkipTrace,
    TraceContract,
    audit,
    audit_invariance,
    forbid_convert,
    get_trace_contract,
    lint_source,
    run_contract,
    total_eqns,
)
from repro.analysis.report import (
    BASELINE_NAME,
    baseline_payload,
    build_report,
    canonical_json,
    diff_against_baseline,
    main as report_main,
    repo_root,
)


def rules(findings):
    return sorted({f.rule for f in findings})


# ---------------------------------------------------------------------------
# Jaxpr engine: one positive + one negative per rule
# ---------------------------------------------------------------------------


class TestJaxprRules:
    def test_pad_on_dtype(self):
        contract = TraceContract(no_pad_on_dtypes=("uint8",))
        x = jnp.zeros((4, 4), jnp.uint8)

        bad = audit(lambda a: jnp.pad(a, ((0, 4), (0, 0))), (x,), contract)
        assert rules(bad) == ["pad-on-dtype"]
        # padding a float is outside the forbidden dtype set
        ok = audit(lambda a: jnp.pad(a, ((0, 4), (0, 0))),
                   (x.astype(jnp.float32),), contract)
        assert not ok

    def test_max_host_callbacks(self):
        x = jnp.ones((3,), jnp.float32)
        sd = jax.ShapeDtypeStruct(x.shape, x.dtype)

        def two_fetches(a):
            a = jax.pure_callback(lambda v: np.asarray(v), sd, a)
            return jax.pure_callback(lambda v: np.asarray(v), sd, a)

        bad = audit(two_fetches, (x,), TraceContract(max_host_callbacks=1))
        assert rules(bad) == ["max-host-callbacks"]
        assert not audit(two_fetches, (x,), TraceContract(max_host_callbacks=2))
        assert not audit(lambda a: a + 1, (x,),
                         TraceContract(max_host_callbacks=0))

    def test_forbid_convert_scoped_to_pallas(self):
        contract = TraceContract(forbid_prims=(forbid_convert(),))
        x = jnp.ones((4,), jnp.int32)

        # scope is "pallas_call": a top-level int->f32 convert is allowed
        assert not audit(lambda a: a.astype(jnp.float32), (x,), contract)
        # unscoped variant fires anywhere
        anywhere = TraceContract(forbid_prims=(forbid_convert(within=None),))
        bad = audit(lambda a: a.astype(jnp.float32), (x,), anywhere)
        assert rules(bad) == ["no-f32-event-promotion"]
        # f32 -> bf16 is not an integer promotion
        assert not audit(lambda a: a.astype(jnp.bfloat16),
                         (x.astype(jnp.float32),), anywhere)

    def test_prim_rule_predicate_and_top_scope(self):
        x = jnp.ones((4,), jnp.float32)
        top_only = TraceContract(forbid_prims=(
            PrimRule(rule="no-top-sin", prim="sin", within="top"),))
        bad = audit(jnp.sin, (x,), top_only)
        assert rules(bad) == ["no-top-sin"]
        # the same sin nested under jit is outside "top"
        assert not audit(jax.jit(jnp.sin), (x,), top_only)

    def test_forbid_dtype_shapes(self):
        contract = TraceContract(
            forbid_dtype_shapes=(("float32", (4, 32)),))
        x = jnp.ones((4, 32), jnp.bfloat16)

        bad = audit(lambda a: a.astype(jnp.float32), (x,), contract)
        assert rules(bad) == ["forbid-dtype-shape"]
        assert not audit(lambda a: a + 1, (x,), contract)

    def test_max_eqns(self):
        x = jnp.ones((4,), jnp.float32)
        bad = audit(lambda a: jnp.sin(jnp.cos(a)) + 1, (x,),
                    TraceContract(max_eqns=1))
        assert rules(bad) == ["max-eqns"]
        assert not audit(jnp.sin, (x,), TraceContract(max_eqns=1))

    def test_total_eqns_recurses_into_pjit(self):
        x = jnp.ones((4,), jnp.float32)
        closed = jax.make_jaxpr(jax.jit(lambda a: jnp.sin(a) + 1))(x)
        # top level is a single pjit equation; the real work is inside
        assert len(closed.jaxpr.eqns) == 1
        assert total_eqns(closed) >= 3


class TestInvariance:
    def test_eqn_count_variant_detected(self):
        def build(n):
            x = jnp.ones((n, 8), jnp.float32)

            def per_row(a):  # per-slot python work leaks into the jaxpr
                return sum(jnp.sin(a[i]).sum() for i in range(n))

            return per_row, (x,)

        findings, meta = audit_invariance(build, {"n": (2, 4)})
        assert rules(findings) == ["eqn-count-variant"]
        assert len(set(meta["eqn_counts"].values())) == 2

    def test_batched_program_is_invariant(self):
        def build(n):
            x = jnp.ones((n, 8), jnp.float32)
            return (lambda a: jnp.sin(a).sum()), (x,)

        findings, meta = audit_invariance(build, {"n": (2, 4)})
        assert not findings
        assert len(set(meta["eqn_counts"].values())) == 1

    def test_skip_trace_is_metadata_not_finding(self):
        def build(n):
            if n > 2:
                raise SkipTrace("needs more devices")
            x = jnp.ones((n,), jnp.float32)
            return jnp.sin, (x,)

        findings, meta = audit_invariance(build, {"n": (2, 4)})
        assert not findings
        assert len(meta["skipped"]) == 1 and "devices" in meta["skipped"][0]


# ---------------------------------------------------------------------------
# Lint engine: synthetic sources, one positive + one negative per rule
# ---------------------------------------------------------------------------

_PRELUDE = "import jax\nimport jax.numpy as jnp\nimport numpy as np\n"


def lint(body):
    return lint_source(_PRELUDE + body, "synthetic.py")


class TestLintHostSync:
    def test_np_asarray_flagged_jnp_asarray_not(self):
        assert rules(lint("def f(x):\n    return np.asarray(x)\n")) \
            == ["host-sync"]
        assert not lint("def f(x):\n    return jnp.asarray(x)\n")

    def test_item_block_until_ready_device_get(self):
        assert rules(lint("def f(x):\n    return x.item()\n")) == ["host-sync"]
        assert rules(lint("def f(x):\n    x.block_until_ready()\n")) \
            == ["host-sync"]
        assert rules(lint("def f(x):\n    return jax.device_get(x)\n")) \
            == ["host-sync"]

    def test_int_of_jax_expression(self):
        assert rules(lint("def f(x):\n    return int(jnp.argmax(x))\n")) \
            == ["host-sync"]
        # int() of host-side python stays host-side
        assert not lint("def f(n):\n    return int(n) + 1\n")
        # device_count is a host query, not a tracer
        assert not lint("def f():\n    return int(jax.device_count())\n")

    def test_suppression_same_line_and_line_above(self):
        assert not lint(
            "def f(x):\n"
            "    return np.asarray(x)  # analysis: host-sync ok — documented\n")
        assert not lint(
            "def f(x):\n"
            "    # analysis: host-sync ok — documented fetch\n"
            "    return np.asarray(x)\n")
        # a marker for a different rule does not suppress
        assert rules(lint(
            "def f(x):\n"
            "    return np.asarray(x)  # analysis: tracer-branch ok\n")) \
            == ["host-sync"]


class TestLintTracerBranch:
    def test_branch_on_jnp_flagged(self):
        assert rules(lint("def f(x):\n    if jnp.any(x):\n        return x\n"
                          "    return -x\n")) == ["tracer-branch"]
        assert rules(lint("def f(x):\n    while jnp.all(x):\n        x = -x\n"
                          "    return x\n")) == ["tracer-branch"]

    def test_static_metadata_and_host_queries_exempt(self):
        assert not lint("def f(x):\n    if x.ndim == 2:\n        return x\n"
                        "    return x[None]\n")
        assert not lint("def f(tp):\n    if jax.device_count() < tp:\n"
                        "        return None\n    return tp\n")


class TestLintStaticArgs:
    def test_unhashable_static_default_flagged(self):
        src = ("def f(x, tiles=[8, 128]):\n    return x\n"
               "g = jax.jit(f, static_argnums=(1,))\n")
        assert rules(lint(src)) == ["static-arg-hazard"]

    def test_hashable_static_ok(self):
        src = ("def f(x, tiles=(8, 128)):\n    return x\n"
               "g = jax.jit(f, static_argnums=(1,))\n")
        assert not lint(src)


class TestLintDataclass:
    def test_unregistered_nonfrozen_flagged(self):
        src = ("import dataclasses\n"
               "@dataclasses.dataclass\n"
               "class Foo:\n    a: int = 0\n")
        assert rules(lint(src)) == ["dataclass-unregistered"]

    def test_frozen_and_registered_ok(self):
        assert not lint("import dataclasses\n"
                        "@dataclasses.dataclass(frozen=True)\n"
                        "class Foo:\n    a: int = 0\n")
        assert not lint("import dataclasses\n"
                        "@dataclasses.dataclass\n"
                        "class Foo:\n    a: int = 0\n"
                        "jax.tree_util.register_dataclass(Foo)\n")

    def test_marker_above_decorator_suppresses(self):
        assert not lint(
            "import dataclasses\n"
            "# analysis: dataclass-unregistered ok — host-side bookkeeping\n"
            "@dataclasses.dataclass\n"
            "class Foo:\n    a: int = 0\n")


# ---------------------------------------------------------------------------
# Acceptance injections — each introduces one forbidden regression,
# asserts the auditor catches it, reverts, and asserts green again.
# ---------------------------------------------------------------------------


class TestInjections:
    def test_extra_host_fetch_in_decode_step_caught(self, monkeypatch):
        """Injection 1: an extra device->host fetch inside the fused
        decode step (a pure_callback smuggled into decode_step) must
        trip max-host-callbacks=0; after reverting, the contract is
        green again."""
        import repro.models.transformer as T

        point = get_trace_contract("serve.fused_decode_step")
        orig = T.decode_step

        def leaky_decode_step(params, tokens, caches, positions, cfg, **kw):
            tokens = jax.pure_callback(
                lambda t: np.asarray(t),
                jax.ShapeDtypeStruct(tokens.shape, tokens.dtype), tokens)
            return orig(params, tokens, caches, positions, cfg, **kw)

        monkeypatch.setattr(T, "decode_step", leaky_decode_step)
        fn, args = point.build(n_slots=2, tp=1)
        bad = audit(fn, args, point.contract, name=point.name)
        assert "max-host-callbacks" in rules(bad), bad

        monkeypatch.undo()  # revert the injection
        fn, args = point.build(n_slots=2, tp=1)
        assert not audit(fn, args, point.contract, name=point.name)

    def test_pad_on_uint8_plane_caught(self):
        """Injection 2: de-canonicalized stored planes (pack only, no
        prepare-time pad to the canonical layout) force a per-step pad
        on the uint8 operands — exactly what the serving contract
        forbids. Canonical planes (the registered point) stay green."""
        from repro.core import ternary as tern
        from repro.core.execution import CiMExecSpec, execute_packed

        spec = CiMExecSpec(formulation="blocked", backend="pallas",
                           packing="bitplane_u8")
        k, n = 504, 250  # packable (8 | k) but not canonical multiples
        w = jax.random.choice(jax.random.PRNGKey(7),
                              jnp.asarray([-1, 0, 1], jnp.int8), (k, n))
        pos, neg = tern.pack_ternary(w, axis=0)
        x = jnp.ones((3, k), jnp.float32)

        def f(xv, p, q):
            lay = tern.PackedPlanes(pos=p, neg=q,
                                    scale=jnp.ones((n,), jnp.float32),
                                    k=k, n=n)
            return execute_packed(spec, xv, lay)

        contract = TraceContract(no_pad_on_dtypes=("uint8",))
        bad = audit(f, (x, pos, neg), contract)
        assert "pad-on-dtype" in rules(bad), bad

        # the revert: canonical planes via the registered point
        findings, _ = run_contract("execution.execute_packed.decode.pallas")
        assert not findings, findings

    def test_f32_accumulator_caught(self):
        """Injection 3: an f32 dot accumulator where the decode
        contract demands int32 — the prefill kernel (f32 accumulation
        by design) traced under the decode contract is the minimal
        reproduction, and the real decode kernel stays green under the
        same rule."""
        decode_rules = TraceContract(accum_dtype="int32")
        fn, args = get_trace_contract("kernels.packed_prefill_kernel").build()
        bad = audit(fn, args, decode_rules)
        assert "accum-dtype" in rules(bad), bad

        findings, _ = run_contract("kernels.packed_decode_kernel")
        assert not findings, findings

    def test_jaxpr_growth_with_n_slots_caught(self):
        """Injection 4: per-slot python work wrapped around the real
        fused step makes the equation count grow with n_slots — the
        invariance auditor must flag it; the unwrapped step is
        invariant (pinned by the registered contract, re-checked here
        on the same two combos)."""
        point = get_trace_contract("serve.fused_decode_step")

        def leaky_build(n_slots):
            fn, args = point.build(n_slots=n_slots, tp=1)

            def per_slot(*a):
                toks, caches = fn(*a)
                acc = jnp.float32(0)
                for s in range(n_slots):  # python loop over slots
                    acc = acc + jnp.sin(toks[s].astype(jnp.float32))
                return toks, caches, acc

            return per_slot, args

        findings, meta = audit_invariance(leaky_build, {"n_slots": (2, 4)})
        assert rules(findings) == ["eqn-count-variant"], findings

        def clean_build(n_slots):
            return point.build(n_slots=n_slots, tp=1)

        findings, meta = audit_invariance(clean_build, {"n_slots": (2, 4)},
                                          contract=point.contract)
        assert not findings, findings
        assert len(set(meta["eqn_counts"].values())) == 1


# ---------------------------------------------------------------------------
# Baseline ratchet
# ---------------------------------------------------------------------------


class TestBaselineRatchet:
    def test_lint_report_is_byte_reproducible(self):
        root = repo_root()
        a = build_report(root, audit=False)
        b = build_report(root, audit=False)
        assert canonical_json(a) == canonical_json(b)

    def test_committed_baseline_matches_tree(self):
        """The full report (both engines, all contracts) serializes to
        exactly the committed ANALYSIS_baseline.json — the CI gate's
        green state, pinned byte-for-byte."""
        root = repo_root()
        report = build_report(root)
        committed = (root / BASELINE_NAME).read_text()
        assert canonical_json(baseline_payload(report)) == committed

    def test_diff_directions(self):
        f1 = Finding("P1", "lint", "host-sync", "a.py:1", "m1").to_dict()
        f2 = Finding("P1", "lint", "host-sync", "b.py:2", "m2").to_dict()
        report = {"version": 1, "findings": [f1, f2]}
        new, fixed = diff_against_baseline(report,
                                           {"version": 1, "findings": [f1]})
        assert new == [f2] and fixed == []
        new, fixed = diff_against_baseline({"version": 1, "findings": [f1]},
                                           report)
        assert new == [] and fixed == [f2]

    def test_cli_check_ratchets_both_ways(self, tmp_path):
        """--check fails on a new finding (regression) AND on a stale
        baseline entry (must ratchet down); lint-only keeps the test
        fast — the full-audit path is covered above."""
        base = tmp_path / "base.json"
        assert report_main(["--no-audit", "--write-baseline",
                            "--baseline", str(base)]) == 0
        assert report_main(["--no-audit", "--check",
                            "--baseline", str(base)]) == 0

        payload = json.loads(base.read_text())
        stale = dict(payload["findings"][0]) if payload["findings"] else {
            "engine": "lint", "rule": "host-sync", "where": "x.py:1",
            "severity": "P1", "message": "m"}
        stale = {**stale, "where": "no/longer/there.py:1"}
        base.write_text(json.dumps(
            {"version": 1, "findings": payload["findings"] + [stale]}))
        assert report_main(["--no-audit", "--check",
                            "--baseline", str(base)]) == 1  # stale entry

        base.write_text(json.dumps({"version": 1, "findings": []}))
        rc = report_main(["--no-audit", "--check", "--baseline", str(base)])
        # current tree has lint findings (the ratcheted TrainConfig) —
        # against an empty baseline they are "new" and must fail
        assert rc == 1

    def test_cli_json_artifact(self, tmp_path):
        out = tmp_path / "report.json"
        assert report_main(["--no-audit", "--json", str(out)]) == 0
        payload = json.loads(out.read_text())
        assert payload["version"] == 1
        assert set(payload) == {"version", "findings", "summary", "contracts"}
