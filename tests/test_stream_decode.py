"""Streaming double-buffered decode kernel (DESIGN.md §14).

The contract pinned here:

  * **bit-equality, three ways**: for every ragged decode M and every
    buffer depth, ``packed_cim_matmul_decode_stream`` returns the same
    bits as ``packed_cim_matmul_decode`` and the jnp bitplane oracle —
    overlapping the plane DMA with the MAC must never change a single
    event count;
  * **dispatch**: the registered ``pallas_stream`` specs resolve through
    ``api.execute_packed`` / ``api.execute`` bit-equal to the ``pallas``
    and ``jnp`` backends across ragged shapes;
  * **layout versions**: the plane-interleaved version-1 storage
    round-trips exactly (interleave ∘ deinterleave = id), v1 planes
    serve under the legacy backend and v0 planes under the stream
    backend (each converts on the fly), and ``prepare_for_spec`` emits
    v1 for stream specs / v0 otherwise;
  * **TP**: ``execute_packed_tp`` over N-sharded planes is bit-identical
    to the single-device path for both the stream and legacy branches;
  * **contracts**: the ``execution.execute_packed.decode.stream`` trace
    point passes its own pins (positive half), and the DMA-eqn pin
    actually fires on a trace with a different buffer depth (negative
    half — the auditor is not vacuously green).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api
from repro.core import ternary as tern
from repro.core.execution import (
    clear_tile_cache,
    execute_packed_tp,
    set_shape_class_override,
)
from repro.kernels.packed_mac import (
    packed_cim_matmul_decode,
    packed_cim_matmul_decode_stream,
)
from repro.models import transformer as T
from repro.models.registry import get_config
from repro.quant.prepare import prepare_for_spec

RAGGED_M = (1, 2, 3, 5, 7)
STREAM_SPECS = [s for s in api.registered_specs()
                if s.backend == "pallas_stream"]


def rand_ternary(key, shape, p_zero=0.25, dtype=jnp.float32):
    k1, k2 = jax.random.split(key)
    sign = jax.random.choice(k1, jnp.array([-1, 1]), shape)
    keep = jax.random.bernoulli(k2, 1 - p_zero, shape)
    return (sign * keep).astype(dtype)


@pytest.fixture(autouse=True)
def _clean_tile_state():
    yield
    set_shape_class_override(None)
    clear_tile_cache()


# ---------------------------------------------------------------------------
# Kernel-level bit-equality: stream vs decode vs oracle
# ---------------------------------------------------------------------------


class TestStreamKernelBitEquality:
    @pytest.mark.parametrize("nbuf", [2, 3])
    @pytest.mark.parametrize("cim", [True, False], ids=["blocked", "exact"])
    def test_stream_equals_decode_and_oracle(self, cim, nbuf):
        """Multi-tile (K, N) grid, decode-tile M: the streaming kernel's
        rotated-scratch MAC returns the decode kernel's exact bits, and
        both match the unpacked jnp oracle."""
        m, k, n = 8, 1024, 256
        kx, kw = jax.random.split(jax.random.PRNGKey(3))
        x = rand_ternary(kx, (m, k), p_zero=0.1, dtype=jnp.int8)
        t = rand_ternary(kw, (k, n), p_zero=0.1, dtype=jnp.int8)
        p1, p2 = tern.pack_ternary(t, axis=0)
        base = np.asarray(packed_cim_matmul_decode(
            x, p1, p2, cim=cim, interpret=True))
        stream = np.asarray(packed_cim_matmul_decode_stream(
            x, tern.interleave_planes(p1, p2), cim=cim, nbuf=nbuf,
            interpret=True))
        np.testing.assert_array_equal(stream, base)
        if not cim:
            oracle = np.asarray(x.astype(jnp.int32) @ t.astype(jnp.int32))
            np.testing.assert_array_equal(stream, oracle)

    def test_single_k_tile(self):
        """nk == 1: the warm-up prefetch covers the whole loop — no
        in-flight tile ever outruns the buffer ring."""
        m, k, n = 4, 256, 128
        kx, kw = jax.random.split(jax.random.PRNGKey(9))
        x = rand_ternary(kx, (m, k), dtype=jnp.int8)
        t = rand_ternary(kw, (k, n), dtype=jnp.int8)
        p1, p2 = tern.pack_ternary(t, axis=0)
        np.testing.assert_array_equal(
            np.asarray(packed_cim_matmul_decode_stream(
                x, tern.interleave_planes(p1, p2), interpret=True)),
            np.asarray(packed_cim_matmul_decode(x, p1, p2, interpret=True)))

    def test_rejects_bad_nbuf(self):
        x = jnp.zeros((4, 256), jnp.int8)
        w = jnp.zeros((64, 128), jnp.uint8)
        with pytest.raises(AssertionError, match="buffer depth"):
            packed_cim_matmul_decode_stream(x, w, nbuf=4, interpret=True)


# ---------------------------------------------------------------------------
# Dispatch-level bit-equality across ragged shapes
# ---------------------------------------------------------------------------


class TestStreamDispatch:
    @pytest.mark.parametrize("spec", STREAM_SPECS, ids=lambda s: s.name)
    def test_registered_stream_specs_exist(self, spec):
        assert spec.packing == "bitplane_u8"

    @pytest.mark.parametrize("formulation", ["blocked", "exact"])
    def test_execute_packed_ragged_m_three_backends(self, formulation):
        """Ragged decode M sweep: pallas_stream == pallas == jnp through
        the public execute_packed, on ragged (K, N) (exercises the
        canonical-pad + slice-back path around the kernel)."""
        k, n = 96, 24
        kx, kw = jax.random.split(jax.random.PRNGKey(5))
        t = rand_ternary(kw, (k, n), p_zero=0.1, dtype=jnp.int8)
        p1, p2 = tern.pack_ternary(t, axis=0)
        outs = {}
        for backend in ("pallas_stream", "pallas", "jnp"):
            spec = api.CiMExecSpec(formulation=formulation, backend=backend,
                                   packing="bitplane_u8")
            rows = []
            for m in RAGGED_M:
                x = rand_ternary(jax.random.fold_in(kx, m), (m, k),
                                 p_zero=0.1)
                rows.append(np.asarray(api.execute_packed(spec, x, p1, p2)))
            outs[backend] = rows
        for m, a, b, c in zip(RAGGED_M, outs["pallas_stream"],
                              outs["pallas"], outs["jnp"]):
            np.testing.assert_array_equal(a, b, err_msg=f"stream≠pallas M={m}")
            np.testing.assert_array_equal(a, c, err_msg=f"stream≠jnp M={m}")

    def test_execute_dense_path(self):
        """api.execute (dense ternary weights, packing on the fly) under
        the stream backend matches the jnp reference."""
        spec = api.CiMExecSpec(formulation="blocked", backend="pallas_stream",
                               packing="bitplane_u8")
        ref = dataclasses.replace(spec, backend="jnp")
        k, n = 45, 19
        kx, kw = jax.random.split(jax.random.PRNGKey(11))
        w = rand_ternary(kw, (k, n), p_zero=0.1)
        for m in RAGGED_M:
            x = rand_ternary(jax.random.fold_in(kx, m), (m, k), p_zero=0.1)
            np.testing.assert_array_equal(
                np.asarray(api.execute(spec, x, w)),
                np.asarray(api.execute(ref, x, w)), err_msg=f"M={m}")


# ---------------------------------------------------------------------------
# Plane layout versions
# ---------------------------------------------------------------------------


class TestPlaneLayoutVersions:
    def test_interleave_roundtrip(self):
        kp, kn = jax.random.split(jax.random.PRNGKey(0))
        pos = jax.random.randint(kp, (2, 32, 24), 0, 256, jnp.int32)
        pos = pos.astype(jnp.uint8)
        neg = jax.random.randint(kn, (2, 32, 24), 0, 256, jnp.int32)
        neg = neg.astype(jnp.uint8)
        wi = tern.interleave_planes(pos, neg)
        assert wi.shape == (2, 64, 24)
        p, q = tern.deinterleave_planes(wi)
        np.testing.assert_array_equal(np.asarray(p), np.asarray(pos))
        np.testing.assert_array_equal(np.asarray(q), np.asarray(neg))

    def test_interleave_validation(self):
        with pytest.raises(ValueError, match="mismatch"):
            tern.interleave_planes(jnp.zeros((4, 8), jnp.uint8),
                                   jnp.zeros((3, 8), jnp.uint8))
        with pytest.raises(ValueError, match="not even"):
            tern.deinterleave_planes(jnp.zeros((5, 8), jnp.uint8))

    def test_packed_planes_views_cross_version(self):
        """A v0 and a v1 PackedPlanes over the same logical weights give
        identical answers from BOTH views (.planes() and
        .interleaved()), and iteration yields the legacy tuple."""
        kw = jax.random.PRNGKey(2)
        t = rand_ternary(kw, (64, 16), dtype=jnp.int8)
        p1, p2 = tern.pack_ternary(t, axis=0)
        scale = jnp.ones((16,), jnp.float32)
        v0 = tern.PackedPlanes(pos=p1, neg=p2, scale=scale, k=64, n=16)
        wi = tern.interleave_planes(p1, p2)
        v1 = tern.PackedPlanes(pos=wi, neg=wi[:0], scale=scale, k=64, n=16,
                               layout_version=tern.PLANE_LAYOUT_STREAM)
        assert v0.layout_version == tern.PLANE_LAYOUT_LEGACY
        for a, b in zip(v0.planes(), v1.planes()):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        np.testing.assert_array_equal(np.asarray(v0.interleaved()),
                                      np.asarray(v1.interleaved()))
        pos_it, neg_it, scale_it = v1
        np.testing.assert_array_equal(np.asarray(pos_it), np.asarray(p1))
        np.testing.assert_array_equal(np.asarray(neg_it), np.asarray(p2))

    def test_cross_version_execute_packed(self):
        """v1 stored planes serve under the legacy pallas backend and v0
        planes under the stream backend — same bits both ways (each
        backend converts views on the fly)."""
        stream = api.CiMExecSpec(formulation="blocked",
                                 backend="pallas_stream",
                                 packing="bitplane_u8")
        legacy = dataclasses.replace(stream, backend="pallas")
        cfg = get_config("smollm-135m", smoke=True)
        params = T.init_params(jax.random.PRNGKey(0), cfg)
        _, v1 = prepare_for_spec(params, stream)
        _, v0 = prepare_for_spec(params, legacy)
        lay1 = v1["blocks/attn/wq"].layer(0)
        lay0 = v0["blocks/attn/wq"].layer(0)
        assert lay1.layout_version == tern.PLANE_LAYOUT_STREAM
        assert lay0.layout_version == tern.PLANE_LAYOUT_LEGACY
        x = rand_ternary(jax.random.PRNGKey(1), (3, lay1.k), p_zero=0.1)
        outs = [np.asarray(api.execute_packed(s, x, lay))
                for s in (stream, legacy) for lay in (lay1, lay0)]
        for o in outs[1:]:
            np.testing.assert_array_equal(outs[0], o)

    def test_layer_propagates_layout_version(self):
        cfg = get_config("smollm-135m", smoke=True)
        params = T.init_params(jax.random.PRNGKey(0), cfg)
        spec = api.CiMExecSpec(formulation="blocked",
                               backend="pallas_stream",
                               packing="bitplane_u8")
        _, packed = prepare_for_spec(params, spec)
        entry = packed["blocks/attn/wq"]
        assert entry.layout_version == tern.PLANE_LAYOUT_STREAM
        lay = entry.layer(0)
        assert lay.layout_version == tern.PLANE_LAYOUT_STREAM
        # v1 stores one (L, K/4, N) array; neg is the 0-row placeholder
        assert entry.pos.shape[-2] == 2 * (entry.neg.shape[-2] or
                                           entry.pos.shape[-2] // 2)
        assert lay.neg.shape[-2] == 0


# ---------------------------------------------------------------------------
# TP: column-parallel stream execution
# ---------------------------------------------------------------------------


class TestStreamTP:
    @pytest.mark.parametrize("backend", ["pallas_stream", "pallas"])
    def test_execute_packed_tp_bit_equal(self, backend, tp_mesh):
        """N-sharded packed MAC == single-device packed MAC, bit for
        bit, for both the stream and legacy branches."""
        from repro.launch.mesh import make_tp_mesh

        mesh = make_tp_mesh(2)
        spec = api.CiMExecSpec(formulation="blocked", backend=backend,
                               packing="bitplane_u8")
        cfg = get_config("smollm-135m", smoke=True)
        params = T.init_params(jax.random.PRNGKey(0), cfg)
        _, packed = prepare_for_spec(params, spec, mesh=mesh)
        lay = packed["blocks/attn/wq"].layer(0)
        for m in (1, 3, 7):
            x = rand_ternary(jax.random.PRNGKey(m), (m, lay.k), p_zero=0.1)
            tp_out = np.asarray(execute_packed_tp(spec, x, lay, mesh))
            solo = np.asarray(api.execute_packed(spec, x, lay))
            np.testing.assert_array_equal(tp_out, solo, err_msg=f"M={m}")

    def test_execute_packed_tp_validation(self, tp_mesh):
        from repro.launch.mesh import make_tp_mesh

        mesh = make_tp_mesh(2)
        spec = api.CiMExecSpec(formulation="blocked", backend="pallas_stream",
                               packing="bitplane_u8")
        x = jnp.zeros((2, 64), jnp.float32)
        with pytest.raises(ValueError, match="PackedPlanes"):
            execute_packed_tp(spec, x, (x, x, x), mesh)
        dense = dataclasses.replace(spec, packing="none")
        with pytest.raises(ValueError, match="bitplane_u8"):
            execute_packed_tp(dense, x, None, mesh)


# ---------------------------------------------------------------------------
# Tracing contract: positive and negative halves
# ---------------------------------------------------------------------------


class TestStreamContract:
    def test_contract_passes(self):
        """Positive half: the registered stream decode trace point meets
        its own pins (int32 accum, no uint8 pad, dma_start==2,
        dma_wait==1)."""
        from repro.analysis import check_jaxpr
        from repro.analysis.contracts import get_trace_contract

        point = get_trace_contract("execution.execute_packed.decode.stream")
        fn, args = point.build()
        findings = check_jaxpr(jax.make_jaxpr(fn)(*args), point.contract,
                               "test.stream.positive")
        assert not findings, findings

    def test_dma_pin_fires_on_depth_change(self):
        """Negative half: a 3-deep buffer ring emits one more warm-up
        dma_start — the pinned count must flag it (the pin watches the
        rotation structure, not the grid)."""
        from repro.analysis import check_jaxpr
        from repro.analysis.contracts import get_trace_contract

        point = get_trace_contract("execution.execute_packed.decode.stream")
        x = jnp.ones((4, 512), jnp.int8)
        wi = jnp.zeros((128, 256), jnp.uint8)

        def f(xv, w):
            return packed_cim_matmul_decode_stream(xv, w, nbuf=3,
                                                   interpret=True)

        findings = check_jaxpr(jax.make_jaxpr(f)(x, wi), point.contract,
                               "test.stream.negative")
        assert any("dma_start" in f.message and f.rule == "prim-count"
                   for f in findings), findings

    def test_kernel_contract_registered(self):
        from repro.analysis.contracts import get_trace_contract

        point = get_trace_contract("kernels.packed_decode_stream_kernel")
        assert dict(point.contract.pin_prims) == {"dma_start": 2,
                                                  "dma_wait": 1}
        assert point.contract.accum_dtype == "int32"
