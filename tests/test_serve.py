"""Serving engine: generation, prefill consistency, continuous batching,
and the ragged-position decode contract (DESIGN.md §6)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.models import attention as A
from repro.models import transformer as T
from repro.models.layers import QuantConfig
from repro.models.registry import get_config
from repro.serve.engine import ContinuousBatcher, Request, generate, prefill, sample


def setup():
    cfg = get_config("smollm-135m", smoke=True).replace(quant=QuantConfig(mode="off"))
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


class TestSampling:
    def test_greedy_is_argmax(self):
        logits = jnp.array([[[0.1, 3.0, -1.0]]])
        assert int(sample(logits, jax.random.PRNGKey(0))[0, 0]) == 1

    def test_temperature_varies(self):
        logits = jnp.zeros((1, 1, 64))
        toks = {int(sample(logits, jax.random.PRNGKey(i), 1.0)[0, 0]) for i in range(16)}
        assert len(toks) > 1


class TestGenerate:
    def test_greedy_deterministic(self):
        cfg, params = setup()
        prompt = jnp.array([[1, 2, 3]], jnp.int32)
        a = generate(params, prompt, cfg, max_new=6, s_max=32)
        b = generate(params, prompt, cfg, max_new=6, s_max=32)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_prefill_equals_stepwise(self):
        cfg, params = setup()
        prompt = jnp.array([[5, 9, 2, 7]], jnp.int32)
        caches = T.init_caches(cfg, 1, 32)
        logits_pf, _ = prefill(params, prompt, caches, cfg)
        # step-by-step decode to the same position
        caches2 = T.init_caches(cfg, 1, 32)
        c = caches2
        for t in range(4):
            lg, c = T.decode_step(params, prompt[:, t : t + 1], c, jnp.int32(t), cfg)
        np.testing.assert_allclose(
            np.asarray(logits_pf, np.float32), np.asarray(lg, np.float32),
            rtol=3e-2, atol=3e-2,
        )


class TestContinuousBatcher:
    def test_all_requests_complete(self):
        cfg, params = setup()
        b = ContinuousBatcher(params, cfg, n_slots=2, s_max=32)
        reqs = [Request(i, [1 + i, 2, 3], max_new=3 + i) for i in range(5)]
        for r in reqs:
            b.submit(r)
        b.run()
        for r in reqs:
            assert r.done and len(r.generated) >= r.max_new

    def test_matches_unbatched_generation(self):
        """Slot-batched decode must produce the same greedy tokens as
        dedicated single-request generation."""
        cfg, params = setup()
        prompt = [3, 1, 4]
        solo = np.asarray(
            generate(params, jnp.asarray([prompt], jnp.int32), cfg, max_new=4, s_max=32)
        )[0]
        b = ContinuousBatcher(params, cfg, n_slots=2, s_max=32)
        r = Request(0, prompt, max_new=4)
        b.submit(r)
        # add a competing request so slots interleave
        b.submit(Request(1, [9, 8], max_new=4))
        b.run()
        np.testing.assert_array_equal(np.asarray(r.generated), solo)

    def test_ragged_workload_matches_generate(self):
        """Fused ragged decode: greedy tokens per request must match
        per-request generate() exactly — ragged prompt lengths AND
        heterogeneous max_new, more requests than slots (slots refill at
        heterogeneous positions)."""
        cfg, params = setup()
        prompts = [[3, 1, 4], [9, 8], [2, 7, 1, 8, 2], [6]]
        max_news = [4, 6, 3, 5]
        solos = [
            np.asarray(
                generate(params, jnp.asarray([p], jnp.int32), cfg, max_new=m, s_max=32)
            )[0]
            for p, m in zip(prompts, max_news)
        ]
        b = ContinuousBatcher(params, cfg, n_slots=2, s_max=32)
        reqs = [Request(i, p, max_new=m)
                for i, (p, m) in enumerate(zip(prompts, max_news))]
        for r in reqs:
            b.submit(r)
        b.run()
        for r, solo in zip(reqs, solos):
            assert r.done
            np.testing.assert_array_equal(np.asarray(r.generated), solo)

    def test_looped_baseline_matches_fused(self):
        """The per-slot-loop baseline and the fused step serve identical
        greedy tokens (both equal generate() row-by-row)."""
        cfg, params = setup()
        prompts = [[3, 1, 4], [9, 8], [5]]

        def serve(fused):
            b = ContinuousBatcher(params, cfg, n_slots=2, s_max=32, fused=fused)
            reqs = [Request(i, p, max_new=3 + i) for i, p in enumerate(prompts)]
            for r in reqs:
                b.submit(r)
            b.run()
            return [r.generated for r in reqs], b.stats()

        fused_toks, fused_stats = serve(True)
        looped_toks, looped_stats = serve(False)
        assert fused_toks == looped_toks
        # the fused step fetches once per decode step; the loop once per
        # active slot per step (plus one per prefill in both modes)
        assert fused_stats["host_syncs"] < looped_stats["host_syncs"]

    def test_cim_mode_ragged_completes(self):
        """Quantized serving completes under the fused step. (Exact
        equivalence to generate() holds for row-independent numerics;
        cim/ternary activation scales are per-tensor and couple batch
        rows — DESIGN.md §6.)"""
        cfg = get_config("smollm-135m", smoke=True)
        params = T.init_params(jax.random.PRNGKey(0), cfg)
        b = ContinuousBatcher(params, cfg, n_slots=2, s_max=32)
        reqs = [Request(i, [1 + i, 2], max_new=3) for i in range(3)]
        for r in reqs:
            b.submit(r)
        b.run()
        for r in reqs:
            assert r.done and len(r.generated) >= 3
            assert all(0 <= t < cfg.vocab for t in r.generated)

    def test_long_prompt_not_blocked_by_pow2_bucket(self):
        """A prompt in (s_max/2, s_max) must serve: the pow2 prefill
        bucket falls back to the exact length instead of overflowing."""
        cfg, params = setup()
        b = ContinuousBatcher(params, cfg, n_slots=2, s_max=16)
        r = Request(0, list(range(1, 10)), max_new=3)  # len 9, bucket 16
        b.submit(r)
        b.run()
        assert r.done and not r.truncated and len(r.generated) == 3

    def test_oversized_prompt_rejected_at_submit(self):
        cfg, params = setup()
        b = ContinuousBatcher(params, cfg, n_slots=2, s_max=8)
        try:
            b.submit(Request(0, list(range(8)), max_new=2))
        except ValueError:
            pass
        else:
            raise AssertionError("submit accepted an unservable prompt")

    def test_empty_prompt_rejected_at_submit(self):
        cfg, params = setup()
        b = ContinuousBatcher(params, cfg, n_slots=2, s_max=8)
        try:
            b.submit(Request(0, [], max_new=2))
        except ValueError:
            pass
        else:
            raise AssertionError("submit accepted an empty prompt")

    def test_prepare_weights_packs_planes_once(self):
        """prepare_weights=True under a bitplane spec: serving completes
        from folded weights (no per-forward packing warning), the stored
        planes land on .packed, and they are consumable by
        api.execute_packed (matching the unpacked execute)."""
        import warnings

        from repro import api

        cfg = get_config("smollm-135m", smoke=True)
        params = T.init_params(jax.random.PRNGKey(0), cfg)
        spec = api.CiMExecSpec(formulation="bitplane", backend="jnp",
                               packing="bitplane_u8")
        with warnings.catch_warnings():
            warnings.simplefilter("error")  # packed-per-forward must NOT warn
            b = ContinuousBatcher(params, cfg, n_slots=2, s_max=32,
                                  exec_spec=spec, prepare_weights=True)
        assert b.packed and b.cfg.quant.pre_quantized
        assert b.cfg.quant.exec_spec.packing == "none"
        r = Request(0, [3, 1, 4], max_new=3)
        b.submit(r)
        b.run()
        assert r.done and len(r.generated) == 3
        # stored planes have the execute_packed layout: uint8 (M1, M2)
        # plus the folded per-channel scale (the api.execute_packed
        # contract itself is pinned in tests/test_execution.py)
        for path, (p1, p2, scale) in b.packed.items():
            assert p1.dtype == jnp.uint8 and p2.dtype == jnp.uint8
            assert p1.shape == p2.shape

    def test_prepare_weights_requires_spec(self):
        cfg, params = setup()
        try:
            ContinuousBatcher(params, cfg, n_slots=2, s_max=8,
                              prepare_weights=True)
        except ValueError:
            pass
        else:
            raise AssertionError("prepare_weights without exec_spec accepted")

    def test_capacity_cut_marks_truncated(self):
        """A slot that runs out of cache before max_new finishes with
        truncated=True (left-pad dead zone counts against capacity)."""
        cfg, params = setup()
        b = ContinuousBatcher(params, cfg, n_slots=2, s_max=12)
        long_r = Request(0, list(range(1, 8)), max_new=20)   # len 7 -> s_pad 8
        short_r = Request(1, [5, 3], max_new=20)             # pad dead zone 6
        b.submit(long_r)
        b.submit(short_r)
        b.run()
        for r in (long_r, short_r):
            assert r.done and r.truncated and len(r.generated) < r.max_new

    def test_capacity_boundary_exact_one_decode_token(self):
        """Boundary pin for the slot-capacity check: a prompt of length
        s_max - 1 fills the cache up to the last position at prefill
        (slot_pos = s_max - 1 after the prompt writes), leaving room for
        exactly ONE decode write. The request must produce the prefill
        token plus exactly one decode token — two generated total — and
        finish truncated. The historical `slot_pos >= s_max - 1` finish
        check retired the slot a step early and silently wasted that
        last cache line."""
        cfg, params = setup()
        for fused in (True, False):
            b = ContinuousBatcher(params, cfg, n_slots=2, s_max=16,
                                  fused=fused)
            r = Request(0, list(range(1, 16)), max_new=8)  # len 15 == s_max-1
            b.submit(r)
            b.run()
            assert r.done and r.truncated, (fused, r.done, r.truncated)
            assert len(r.generated) == 2, (fused, r.generated)

    def test_temperature_sampling_runs_on_device(self):
        cfg, params = setup()
        b = ContinuousBatcher(params, cfg, n_slots=2, s_max=32, temperature=0.8,
                              seed=3)
        r = Request(0, [3, 1, 4], max_new=4)
        b.submit(r)
        b.run()
        assert r.done and len(r.generated) == 4


class TestRaggedDecodeContract:
    """Unit coverage for the scalar-vs-(B,) cache index pivot."""

    def test_per_row_cache_write_lands_at_own_offsets(self):
        buf = jnp.zeros((3, 8, 2), jnp.float32)
        new = jnp.ones((3, 1, 2), jnp.float32) * jnp.asarray(
            [[[1.0]], [[2.0]], [[3.0]]])
        out = np.array(A.write_cache_rows(buf, new, jnp.asarray([2, 5, 0])))
        # each row wrote at its own offset...
        assert (out[0, 2] == 1.0).all()
        assert (out[1, 5] == 2.0).all()
        assert (out[2, 0] == 3.0).all()
        # ...and touched nothing else
        out[0, 2] = out[1, 5] = out[2, 0] = 0.0
        assert (out == 0.0).all()

    def test_scalar_write_is_broadcast_of_vector_write(self):
        buf = jax.random.normal(jax.random.PRNGKey(0), (2, 6, 3))
        new = jax.random.normal(jax.random.PRNGKey(1), (2, 1, 3))
        a = A.write_cache_rows(buf, new, jnp.int32(4))
        b = A.write_cache_rows(buf, new, jnp.asarray([4, 4]))
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_decode_step_vector_index_matches_scalar(self):
        """decode_step with a broadcast (B,) index is bit-identical to the
        scalar-index path (logits and cache contents)."""
        cfg, params = setup()
        prompt = jnp.asarray([[5, 9, 2], [7, 1, 3]], jnp.int32)
        caches = T.init_caches(cfg, 2, 32)
        _, caches = T.decode_step(params, prompt, caches, jnp.int32(0), cfg)
        tok = jnp.asarray([[4], [8]], jnp.int32)
        lg_s, c_s = T.decode_step(params, tok, caches, jnp.int32(3), cfg)
        lg_v, c_v = T.decode_step(params, tok, caches, jnp.asarray([3, 3]), cfg)
        np.testing.assert_array_equal(
            np.asarray(lg_s, np.float32), np.asarray(lg_v, np.float32))
        for a, b in zip(jax.tree.leaves(c_s), jax.tree.leaves(c_v)):
            np.testing.assert_array_equal(
                np.asarray(a, np.float32), np.asarray(b, np.float32))

    def test_decode_step_heterogeneous_rows_match_single_row(self):
        """Rows decoding at different cache positions in one fused step
        produce the same logits/caches as each row stepped alone."""
        cfg, params = setup()
        full = jnp.asarray([[5, 9, 2, 7, 4], [7, 1, 3, 8, 6]], jnp.int32)
        # row caches at different depths: row 0 holds 4 tokens, row 1 holds 2
        rows, row_caches, depths = [], [], [4, 2]
        for r, depth in enumerate(depths):
            c = T.init_caches(cfg, 1, 32)
            _, c = T.decode_step(params, full[r : r + 1, :depth], c, jnp.int32(0), cfg)
            row_caches.append(c)
        merged = jax.tree.map(lambda a, b: jnp.concatenate([a, b], axis=1),
                              *row_caches)
        tok = jnp.asarray([[11], [13]], jnp.int32)
        idx = jnp.asarray(depths)
        lg, _ = T.decode_step(params, tok, merged, idx, cfg)
        for r, depth in enumerate(depths):
            lg_solo, _ = T.decode_step(
                params, tok[r : r + 1], row_caches[r], jnp.int32(depth), cfg)
            np.testing.assert_allclose(
                np.asarray(lg[r : r + 1], np.float32),
                np.asarray(lg_solo, np.float32), rtol=1e-5, atol=1e-5)

    def test_decode_jaxpr_size_independent_of_n_slots(self):
        """The fused step must not trace per-slot work. Migrated to the
        registered tracing contract (repro.analysis): the recursive
        equation count is identical across n_slots (and TP mesh sizes),
        and the step obeys the structural serving rules — zero host
        callbacks, no pad on uint8 planes."""
        from repro.analysis import run_contract

        findings, meta = run_contract("serve.fused_decode_step")
        assert not findings, findings
        # at least the single-device combos must have traced live
        assert len(meta["eqn_counts"]) >= 2, meta


class TestSSMCachedPrefill:
    def test_mamba2_cached_prefill_matches_stepwise(self):
        """mamba2_block with a cache and S > 1 (batched prefill) must
        agree with S = 1 token-by-token decode."""
        cfg = get_config("mamba2-780m", smoke=True).replace(
            quant=QuantConfig(mode="off"))
        params = T.init_params(jax.random.PRNGKey(0), cfg)
        prompt = jnp.asarray([[5, 9, 2, 7]], jnp.int32)
        caches = T.init_caches(cfg, 1, 32)
        lg_pf, c_pf = T.decode_step(params, prompt, caches, jnp.int32(0), cfg)
        c = T.init_caches(cfg, 1, 32)
        for t in range(4):
            lg, c = T.decode_step(params, prompt[:, t : t + 1], c, jnp.int32(t), cfg)
        np.testing.assert_allclose(
            np.asarray(lg_pf[:, -1:], np.float32), np.asarray(lg, np.float32),
            rtol=1e-5, atol=1e-5)
        for a, b in zip(jax.tree.leaves(c_pf), jax.tree.leaves(c)):
            np.testing.assert_allclose(
                np.asarray(a, np.float32), np.asarray(b, np.float32),
                rtol=1e-5, atol=1e-5)

    def test_mla_ragged_batcher_matches_generate(self):
        """deepseek-v2 (MLA attention): the per-row causal/start masks
        and vmapped latent-cache writes must reproduce generate()."""
        cfg = get_config("deepseek-v2-236b", smoke=True).replace(
            quant=QuantConfig(mode="off"), moe_capacity_factor=8.0)
        params = T.init_params(jax.random.PRNGKey(0), cfg)
        solo = np.asarray(
            generate(params, jnp.asarray([[3, 1, 4]], jnp.int32), cfg,
                     max_new=4, s_max=32))[0]
        b = ContinuousBatcher(params, cfg, n_slots=2, s_max=32)
        r = Request(0, [3, 1, 4], max_new=4)
        b.submit(r)
        b.submit(Request(1, [9, 8], max_new=5))
        b.run()
        np.testing.assert_array_equal(np.asarray(r.generated), solo)

    def test_hybrid_ragged_batcher_matches_generate(self):
        """zamba2 (ssm backbone + shared attention): the fused ragged
        batcher must reproduce generate() exactly — covers the per-row
        hybrid token-slice writes and the SSM pad masking."""
        cfg = get_config("zamba2-2.7b", smoke=True).replace(
            quant=QuantConfig(mode="off"))
        params = T.init_params(jax.random.PRNGKey(0), cfg)
        solo = np.asarray(
            generate(params, jnp.asarray([[3, 1, 4]], jnp.int32), cfg,
                     max_new=4, s_max=32))[0]
        b = ContinuousBatcher(params, cfg, n_slots=2, s_max=32)
        r = Request(0, [3, 1, 4], max_new=4)
        b.submit(r)
        b.submit(Request(1, [9, 8], max_new=5))
        b.run()
        np.testing.assert_array_equal(np.asarray(r.generated), solo)
