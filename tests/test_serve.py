"""Serving engine: generation, prefill consistency, continuous batching."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer as T
from repro.models.layers import QuantConfig
from repro.models.registry import get_config
from repro.serve.engine import ContinuousBatcher, Request, generate, prefill, sample


def setup():
    cfg = get_config("smollm-135m", smoke=True).replace(quant=QuantConfig(mode="off"))
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


class TestSampling:
    def test_greedy_is_argmax(self):
        logits = jnp.array([[[0.1, 3.0, -1.0]]])
        assert int(sample(logits, jax.random.PRNGKey(0))[0, 0]) == 1

    def test_temperature_varies(self):
        logits = jnp.zeros((1, 1, 64))
        toks = {int(sample(logits, jax.random.PRNGKey(i), 1.0)[0, 0]) for i in range(16)}
        assert len(toks) > 1


class TestGenerate:
    def test_greedy_deterministic(self):
        cfg, params = setup()
        prompt = jnp.array([[1, 2, 3]], jnp.int32)
        a = generate(params, prompt, cfg, max_new=6, s_max=32)
        b = generate(params, prompt, cfg, max_new=6, s_max=32)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_prefill_equals_stepwise(self):
        cfg, params = setup()
        prompt = jnp.array([[5, 9, 2, 7]], jnp.int32)
        caches = T.init_caches(cfg, 1, 32)
        logits_pf, _ = prefill(params, prompt, caches, cfg)
        # step-by-step decode to the same position
        caches2 = T.init_caches(cfg, 1, 32)
        c = caches2
        for t in range(4):
            lg, c = T.decode_step(params, prompt[:, t : t + 1], c, jnp.int32(t), cfg)
        np.testing.assert_allclose(
            np.asarray(logits_pf, np.float32), np.asarray(lg, np.float32),
            rtol=3e-2, atol=3e-2,
        )


class TestContinuousBatcher:
    def test_all_requests_complete(self):
        cfg, params = setup()
        b = ContinuousBatcher(params, cfg, n_slots=2, s_max=32)
        reqs = [Request(i, [1 + i, 2, 3], max_new=3 + i) for i in range(5)]
        for r in reqs:
            b.submit(r)
        b.run()
        for r in reqs:
            assert r.done and len(r.generated) >= r.max_new

    def test_matches_unbatched_generation(self):
        """Slot-batched decode must produce the same greedy tokens as
        dedicated single-request generation."""
        cfg, params = setup()
        prompt = [3, 1, 4]
        solo = np.asarray(
            generate(params, jnp.asarray([prompt], jnp.int32), cfg, max_new=4, s_max=32)
        )[0]
        b = ContinuousBatcher(params, cfg, n_slots=2, s_max=32)
        r = Request(0, prompt, max_new=4)
        b.submit(r)
        # add a competing request so slots interleave
        b.submit(Request(1, [9, 8], max_new=4))
        b.run()
        np.testing.assert_array_equal(np.asarray(r.generated), solo)
