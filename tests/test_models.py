"""Per-architecture smoke tests (reduced configs, real CPU execution):
forward shapes + finiteness, one train step, decode==forward consistency,
and the CiM-mode integration."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import transformer as T
from repro.models.layers import QuantConfig
from repro.models.registry import ARCH_IDS, SHAPES, cell_supported, get_config, input_specs


def make_batch(cfg, key, b=2, s=16):
    n_img = cfg.n_image_tokens if cfg.family == "vlm" else 0
    batch = {"tokens": jax.random.randint(key, (b, s - n_img if n_img else s), 0, cfg.vocab)}
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(key, (b, cfg.encoder_seq, cfg.d_model), jnp.bfloat16)
    if cfg.family == "vlm":
        batch["patches"] = jax.random.normal(key, (b, n_img, cfg.d_vision), jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
class TestArchSmoke:
    def test_forward_shapes_and_finite(self, arch):
        cfg = get_config(arch, smoke=True)  # paper technique ON (cim mode)
        assert cfg.quant.mode == "cim"
        params = T.init_params(jax.random.PRNGKey(0), cfg)
        batch = make_batch(cfg, jax.random.PRNGKey(1))
        logits = T.forward(params, batch, cfg)
        b = batch["tokens"].shape[0]
        total_s = batch["tokens"].shape[1] + (cfg.n_image_tokens if cfg.family == "vlm" else 0)
        assert logits.shape == (b, total_s, cfg.vocab)
        assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())

    def test_one_train_step(self, arch):
        import importlib

        ts = importlib.import_module("repro.train.train_step")
        from repro.optim.adamw import AdamWConfig

        cfg = get_config(arch, smoke=True)
        state = ts.init_train_state(jax.random.PRNGKey(0), cfg)
        batch = make_batch(cfg, jax.random.PRNGKey(1))
        batch["labels"] = jnp.zeros_like(batch["tokens"])
        new_state, metrics = ts.train_step(state, batch, cfg, AdamWConfig(lr=1e-3))
        assert np.isfinite(float(metrics["loss"]))
        assert np.isfinite(float(metrics["grad_norm"]))
        # params actually moved
        moved = jax.tree.map(
            lambda a, b: float(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)).max()),
            state.params, new_state.params)
        assert max(jax.tree.leaves(moved)) > 0

    def test_decode_matches_forward(self, arch):
        cfg = get_config(arch, smoke=True).replace(
            quant=QuantConfig(mode="off"), moe_capacity_factor=8.0
        )
        # hybrid only: bf16 accumulation order differs between the
        # chunked forward scan and step-by-step decode; zamba2's error
        # tail sits at 9.2e-2 on this XLA version (pure ssm stays 8e-2)
        tol = {"ssm": 8e-2, "hybrid": 1e-1}.get(cfg.family, 4e-2)
        params = T.init_params(jax.random.PRNGKey(0), cfg)
        caches = T.init_caches(cfg, 2, 32)
        toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, cfg.vocab)
        batch = {"tokens": toks}
        enc = None
        if cfg.family == "encdec":
            frames = jax.random.normal(jax.random.PRNGKey(2), (2, cfg.encoder_seq, cfg.d_model), jnp.bfloat16)
            batch["frames"] = frames
            enc = T.run_encoder(params, frames, cfg)
        fcfg = cfg.replace(family="dense") if cfg.family == "vlm" else cfg
        ref = T.forward(params, batch if cfg.family != "vlm" else {"tokens": toks}, fcfg)
        c, outs = caches, []
        for t in range(8):
            lg, c = T.decode_step(params, toks[:, t : t + 1], c, jnp.int32(t), cfg, enc)
            outs.append(lg)
        dec = jnp.concatenate(outs, axis=1)
        np.testing.assert_allclose(
            np.asarray(dec, np.float32), np.asarray(ref, np.float32), rtol=tol, atol=tol
        )

    def test_full_config_matches_assignment(self, arch):
        cfg = get_config(arch)
        spec = {
            "smollm-135m": (30, 576, 9, 3, 1536, 49152),
            "starcoder2-7b": (32, 4608, 36, 4, 18432, 49152),
            "starcoder2-15b": (40, 6144, 48, 4, 24576, 49152),
            "yi-34b": (60, 7168, 56, 8, 20480, 64000),
            "mamba2-780m": (48, 1536, None, None, 0, 50280),
            "zamba2-2.7b": (54, 2560, 32, 32, 10240, 32000),
            "deepseek-v2-236b": (60, 5120, 128, 128, 1536, 102400),
            "grok-1-314b": (64, 6144, 48, 8, 32768, 131072),
            "whisper-large-v3": (32, 1280, 20, 20, 5120, 51866),
            "llava-next-34b": (60, 7168, 56, 8, 20480, 64000),
        }[arch]
        L_, d, h, kv, ff, v = spec
        assert cfg.n_layers == L_ and cfg.d_model == d and cfg.d_ff == ff and cfg.vocab == v
        if h is not None:
            assert cfg.n_heads == h and cfg.n_kv_heads == kv

    def test_input_specs_defined_for_all_cells(self, arch):
        cfg = get_config(arch)
        for shape in SHAPES.values():
            if cell_supported(cfg, shape):
                continue  # documented skip
            specs = input_specs(cfg, shape)
            assert "tokens" in specs
            for v in specs.values():
                assert isinstance(v, jax.ShapeDtypeStruct)


def test_param_counts_in_range():
    """Sanity: full-config parameter counts are near the advertised sizes."""
    # NOTE: the model zoo uses gated (SwiGLU) MLPs uniformly; starcoder2
    # officially uses ungated GELU MLPs, so its count lands ~30% above the
    # advertised size (DESIGN.md §7) — bounds reflect our family.
    expect = {
        "smollm-135m": (0.11e9, 0.18e9),
        "starcoder2-7b": (6e9, 11e9),
        "starcoder2-15b": (13e9, 23e9),
        "yi-34b": (30e9, 38e9),
        "mamba2-780m": (0.6e9, 1.0e9),
        "zamba2-2.7b": (2.0e9, 3.4e9),
        "deepseek-v2-236b": (200e9, 260e9),
        "grok-1-314b": (280e9, 350e9),
    }
    for arch, (lo, hi) in expect.items():
        n = get_config(arch).param_count()
        assert lo < n < hi, (arch, n)


def test_moe_active_params():
    cfg = get_config("deepseek-v2-236b")
    # DeepSeek-V2: ~21B active of 236B total
    active = cfg.active_param_count()
    assert active < 0.2 * cfg.param_count()


def test_cim_mode_changes_output_vs_exact():
    """The ADC clamp must actually alter dense-layer outputs when the
    inputs are dense enough to overflow blocks."""
    cfg = get_config("smollm-135m", smoke=True)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab)}
    lo = T.forward(params, batch, cfg.replace(quant=QuantConfig(mode="cim")))
    lt = T.forward(params, batch, cfg.replace(quant=QuantConfig(mode="ternary")))
    loff = T.forward(params, batch, cfg.replace(quant=QuantConfig(mode="off")))
    assert not np.allclose(np.asarray(lt, np.float32), np.asarray(loff, np.float32))
    # cim == ternary except where clamping binds; at these sizes they may
    # coincide, but both must be finite and close to each other
    assert bool(jnp.isfinite(lo.astype(jnp.float32)).all())
