"""The declarative hardware API (repro.hw, DESIGN.md §7).

Covers the acceptance criteria of the hw redesign:
  * the six paper (tech, design) Fig 9/11 validation rows are
    bit-identical to the pre-registry ``cost_model.paper_validation_table``
    output (pinned literally below),
  * registering a new memory technology (cost parameters only) requires
    zero edits anywhere and immediately appears in ``bench_array.rows()``,
    ``api.spec_cost_summary``, and the system-level projection for a
    registry arch,
  * the legacy ``core/cost_model`` / ``core/accelerator`` modules forward
    into repro.hw (functions bit-identical, constants with a
    DeprecationWarning).
"""
import warnings

import pytest

from repro import api, hw

# ---------------------------------------------------------------------------
# Pinned: the exact pre-hw-registry paper_validation_table() floats.
# These are DERIVED from the registered technology parameters — the test
# guards both the parameters and the derivation against drift.
# ---------------------------------------------------------------------------
PINNED_VALIDATION = {
    "8T-SRAM": {
        "CiM-I": {
            "cim_latency_reduction_pct": 88.0,
            "cim_energy_reduction_pct": 74.0,
            "read_energy_overhead_pct": 21.999999999999996,
            "read_latency_overhead_pct": 7.000000000000006,
            "write_latency_overhead_pct": 4.0000000000000036,
            "cell_area_overhead_pct": 17.999999999999993,
            "macro_area_ratio": 1.3,
        },
        "CiM-II": {
            "cim_latency_reduction_pct": 80.0,
            "cim_energy_reduction_pct": 61.0,
            "read_energy_overhead_pct": 74.0,
            "read_latency_overhead_pct": 140.0,
            "write_latency_overhead_pct": 8.000000000000007,
            "cell_area_overhead_pct": 6.000000000000005,
            "macro_area_ratio": 1.21,
        },
    },
    "3T-eDRAM": {
        "CiM-I": {
            "cim_latency_reduction_pct": 88.0,
            "cim_energy_reduction_pct": 78.0,
            "read_energy_overhead_pct": 24.0,
            "read_latency_overhead_pct": 7.000000000000006,
            "write_latency_overhead_pct": 4.0000000000000036,
            "cell_area_overhead_pct": 34.00000000000001,
            "macro_area_ratio": 1.53,
        },
        "CiM-II": {
            "cim_latency_reduction_pct": 78.0,
            "cim_energy_reduction_pct": 63.0,
            "read_energy_overhead_pct": 43.99999999999999,
            "read_latency_overhead_pct": 160.0,
            "write_latency_overhead_pct": 10.000000000000009,
            "cell_area_overhead_pct": 6.000000000000005,
            "macro_area_ratio": 1.33,
        },
    },
    "3T-FEMFET": {
        "CiM-I": {
            "cim_latency_reduction_pct": 88.0,
            "cim_energy_reduction_pct": 78.0,
            "read_energy_overhead_pct": 16.999999999999993,
            "read_latency_overhead_pct": 18.999999999999993,
            "write_latency_overhead_pct": 10.000000000000009,
            "cell_area_overhead_pct": 34.00000000000001,
            "macro_area_ratio": 1.53,
        },
        "CiM-II": {
            "cim_latency_reduction_pct": 84.0,
            "cim_energy_reduction_pct": 62.0,
            "read_energy_overhead_pct": 78.99999999999999,
            "read_latency_overhead_pct": 80.0,
            "write_latency_overhead_pct": 3.0000000000000027,
            "cell_area_overhead_pct": 6.000000000000005,
            "macro_area_ratio": 1.33,
        },
    },
}


class TestPaperValidationPins:
    def test_six_rows_bit_identical(self):
        got = hw.paper_validation_table()
        assert got == PINNED_VALIDATION  # == on floats: bit-identity

    def test_new_technology_never_enters_validation_table(self, rram):
        assert rram.name in hw.technologies()
        assert rram.name not in hw.paper_validation_table()


# ---------------------------------------------------------------------------
# Registry round-trip: a hypothetical RRAM technology
# ---------------------------------------------------------------------------

@pytest.fixture()
def rram():
    """Register a hypothetical 1T1R RRAM ternary-synapse technology with
    cost parameters only — no repro.hw (or consumer) edits anywhere."""
    spec = hw.register_technology(hw.TechnologySpec(
        name="TEST-RRAM",
        t_read_ns=2.0, e_read_pj=6.0, t_write_ns=20.0, e_write_pj=80.0,
        t_nm_mac_ns=1.2, e_nm_mac_pj=22.0, leakage_mw=0.0,
        designs={
            "CiM-I": hw.DesignMetrics(0.10, 0.20, 1.10, 1.30, 1.05, 1.00,
                                      0.60, 1.40),
            "CiM-II": hw.DesignMetrics(0.18, 0.35, 2.00, 1.60, 1.06, 1.00,
                                       0.55, 1.25),
        },
    ))
    yield spec
    hw.unregister_technology("TEST-RRAM")


class TestRegistryRoundTrip:
    def test_appears_in_registry(self, rram):
        assert "TEST-RRAM" in hw.technologies()
        assert hw.cim_designs_of("TEST-RRAM") == ("CiM-I", "CiM-II")

    def test_appears_in_bench_array_rows(self, rram):
        from benchmarks import bench_array

        rows = bench_array.rows()
        mine = [r for r in rows if r["tech"] == "TEST-RRAM"]
        assert {r["design"] for r in mine} == {"CiM-I", "CiM-II"}
        # non-paper technologies carry cost rows but no figure tag
        assert all(r["figure"] == "" for r in mine)
        # and the paper rows are still all present
        assert sum(r["figure"] in ("Fig9", "Fig11") for r in rows) == 6

    def test_appears_in_spec_cost_summary(self, rram):
        spec = api.CiMExecSpec(formulation="blocked", flavor="I")
        cost = api.spec_cost_summary(spec, tech="TEST-RRAM")
        assert cost["tech"] == "TEST-RRAM" and cost["design"] == "CiM-I"
        # latency ratio 0.10 of the NM pass: 256 * max(2.0, 1.2) * 0.10
        assert cost["mac_pass_ns"] == pytest.approx(51.2)

    def test_appears_in_system_projection(self, rram):
        arr = hw.ArraySpec(technology="TEST-RRAM", design="CiM-I")
        p = hw.project("smollm-135m", "decode_32k", arr)
        assert p["tech"] == "TEST-RRAM" and p["tok_s"] > 0
        assert p["iso_capacity"]["speedup"] > 1
        # iso-area sizing is derived from the macro-area ratio (1.40)
        assert p["iso_area"]["nm_arrays"] == int(32 * 1.40)

    def test_paper_suite_runs_on_new_tech(self, rram):
        s = hw.average_speedup("TEST-RRAM", "CiM-I", "iso-capacity")
        assert s > 1

    def test_custom_macro_derives_iso_area_sizing(self):
        """The paper's pinned iso-area counts were measured at the
        32-array macro; a resized macro must derive from the macro-area
        ratio, so its iso-area NM baseline never has fewer arrays than
        the CiM macro (and iso-area speedup <= iso-capacity speedup)."""
        arr = hw.ArraySpec(design="CiM-I")
        big = hw.MacroSpec(n_arrays=64)
        assert hw.iso_area_nm_arrays(arr, big) == int(64 * 1.30)
        ia = hw.average_speedup("8T-SRAM", "CiM-I", "iso-area", big)
        ic = hw.average_speedup("8T-SRAM", "CiM-I", "iso-capacity", big)
        assert 1 < ia < ic

    def test_unknown_names_die_friendly(self):
        with pytest.raises(KeyError, match="register_technology"):
            hw.ArraySpec(technology="vapourware")
        with pytest.raises(KeyError, match="register_design"):
            hw.ArraySpec(design="CiM-IX")
        with pytest.raises(ValueError, match="registered"):
            hw.parse_array_spec("vapourware/CiM-I")

    def test_technology_requires_registered_designs(self):
        with pytest.raises(ValueError, match="register_design"):
            hw.register_technology(hw.TechnologySpec(
                name="TEST-BAD", t_read_ns=1, e_read_pj=1, t_write_ns=1,
                e_write_pj=1, t_nm_mac_ns=1, e_nm_mac_pj=1, leakage_mw=0,
                designs={"CiM-IX": hw.DesignMetrics(1, 1, 1, 1, 1, 1, 1, 1)},
            ))
        assert "TEST-BAD" not in hw.technologies()


# ---------------------------------------------------------------------------
# ArraySpec semantics
# ---------------------------------------------------------------------------

class TestArraySpec:
    def test_defaults_match_paper_geometry(self):
        a = hw.ArraySpec()
        assert (a.rows, a.cols, a.n_active, a.adc_max) == (256, 256, 16, 8)
        assert a.cycles_per_pass == 256          # NM: row-by-row
        assert a.with_design("CiM-I").cycles_per_pass == 16

    def test_validation(self):
        with pytest.raises(ValueError, match="n_active"):
            hw.ArraySpec(rows=256, n_active=24)
        with pytest.raises(ValueError, match="pcus"):
            hw.ArraySpec(pcus=48)
        with pytest.raises(ValueError, match="clock"):
            hw.ArraySpec(clock_ghz=0.0)

    def test_parse_round_trip(self):
        a = hw.ArraySpec(technology="3T-FEMFET", design="CiM-II",
                         rows=512, cols=256, n_active=32)
        assert hw.parse_array_spec(a.name) == a
        assert hw.parse_array_spec("8T-SRAM") == hw.ArraySpec()
        assert (hw.parse_array_spec("3T-eDRAM/CiM-I").design == "CiM-I")
        assert hw.parse_array_spec("8T-SRAM/CiM-I/128x64/a16/p16").pcus == 16

    def test_parse_malformed_tokens_friendly(self):
        with pytest.raises(ValueError, match="grammar"):
            hw.parse_array_spec("8T-SRAM/x256")
        with pytest.raises(ValueError, match="grammar"):
            hw.parse_array_spec("8T-SRAM/16x16x4")
        # geometry that ArraySpec itself rejects carries the spec text
        with pytest.raises(ValueError, match="96x100"):
            hw.parse_array_spec("8T-SRAM/CiM-I/96x100")

    def test_exec_spec_binding_overrides_design(self):
        # the ArraySpec carries tech+geometry; NM-vs-CiM comes from what
        # the execution spec actually computes
        arr = hw.ArraySpec(technology="3T-eDRAM", design="CiM-II")
        exact = api.spec_cost_summary(
            api.CiMExecSpec(formulation="exact"), array=arr)
        assert exact["design"] == "NM" and exact["tech"] == "3T-eDRAM"
        blocked = api.spec_cost_summary(
            api.CiMExecSpec(formulation="blocked", flavor="II"), array=arr)
        assert blocked["design"] == "CiM-II"
        assert blocked["array"] == arr.name

    def test_tech_and_array_mutually_exclusive(self):
        with pytest.raises(ValueError, match="not both"):
            api.spec_cost_summary(api.CiMExecSpec(formulation="blocked"),
                                  tech="3T-eDRAM", array=hw.ArraySpec())

    def test_cost_only_design_still_gets_bench_rows(self):
        """A CiM design with no execution flavor (cost-parameters-only
        registration) must not crash bench_array — it gets rows with an
        empty spec binding."""
        from benchmarks import bench_array

        hw.register_design(hw.DesignSpec("TEST-CiM-X", cim=True, flavor=None))
        hw.register_technology(hw.TechnologySpec(
            name="TEST-X", t_read_ns=1.0, e_read_pj=1.0, t_write_ns=1.0,
            e_write_pj=1.0, t_nm_mac_ns=1.0, e_nm_mac_pj=1.0, leakage_mw=0.0,
            designs={"TEST-CiM-X": hw.DesignMetrics(0.5, 0.5, 1.0, 1.0,
                                                    1.0, 1.0, 1.0, 1.2)},
        ))
        try:
            mine = [r for r in bench_array.rows() if r["tech"] == "TEST-X"]
            assert len(mine) == 1
            assert mine[0]["spec"] == "" and mine[0]["mac_pass_ns"] > 0
        finally:
            hw.unregister_technology("TEST-X")
            from repro.hw import registry as reg

            reg._DESIGNS.pop("TEST-CiM-X", None)

    def test_roofline_records_array_spec(self):
        from repro.launch import roofline as rl

        r = rl.Roofline(arch="a", shape="s", mesh="m", chips=1, flops=1.0,
                        bytes_accessed=1.0, coll_bytes=0.0,
                        coll_breakdown={}, model_flops=1.0,
                        array_spec="3T-FEMFET/CiM-I/256x256/a16")
        assert r.to_dict()["array_spec"] == "3T-FEMFET/CiM-I/256x256/a16"


# ---------------------------------------------------------------------------
# Deprecation shims: core/cost_model + core/accelerator forward into hw
# ---------------------------------------------------------------------------

class TestLegacyShims:
    def test_cost_model_functions_bit_identical(self):
        from repro.core import cost_model as cm

        assert cm.paper_validation_table() == hw.paper_validation_table()
        assert cm.flavor_comparison() == hw.flavor_comparison()
        old = cm.array_cost("3T-FEMFET", "CiM-II")
        new = hw.array_cost(
            hw.ArraySpec(technology="3T-FEMFET", design="CiM-II"))
        assert old == new

    def test_cost_model_constants_forward_with_warning(self):
        from repro.core import cost_model as cm

        with warnings.catch_warnings(record=True) as w:
            warnings.simplefilter("always")
            assert cm.TECHNOLOGIES == hw.PAPER_TECHNOLOGIES
            assert cm.N_ROWS == 256 and cm.N_ACTIVE == 16
            assert cm.CYCLES_PER_MAC_CIM == 16
            base = cm.TECH_BASE["8T-SRAM"]
            metrics = cm.ARRAY_METRICS["3T-eDRAM"]["CiM-I"]
        assert all(issubclass(x.category, DeprecationWarning) for x in w)
        assert len(w) >= 6
        assert base is hw.get_technology("8T-SRAM")
        assert metrics == hw.design_metrics("3T-eDRAM", "CiM-I")

    def test_accelerator_forwards(self):
        from repro.core import accelerator as acc
        from repro.hw import dnn_suite

        assert acc.get_benchmarks() is dnn_suite.get_benchmarks()
        assert acc.run_system("LSTM", "8T-SRAM", "CiM-I") == hw.run_system(
            "LSTM", "8T-SRAM", "CiM-I")
        with warnings.catch_warnings(record=True) as w:
            warnings.simplefilter("always")
            assert acc.N_ARRAYS == 32
            assert acc.ISO_AREA_NM_ARRAYS["CiM-I"]["3T-eDRAM"] == 48
        assert len(w) == 2
        assert all(issubclass(x.category, DeprecationWarning) for x in w)


# ---------------------------------------------------------------------------
# Registry-arch workload projection (hw.workload)
# ---------------------------------------------------------------------------

class TestWorkloadProjection:
    @pytest.mark.parametrize("arch,upper", [
        ("yi-34b", 1.01),           # dense
        ("mamba2-780m", 1.01),      # ssm
        ("zamba2-2.7b", 2.0),       # hybrid: the SHARED attention block
                                    # executes n_layers/6 times, so
                                    # execution MACs exceed unique params
        ("deepseek-v2-236b", 1.01), # moe + mla
        ("whisper-large-v3", 1.01), # encdec
        ("llava-next-34b", 1.01),   # vlm
    ])
    def test_gemms_track_active_params(self, arch, upper):
        """Per-token CiM MACs ~= the weight-bearing active parameters
        (embeddings/norms/routers stay digital, so strictly less —
        except where weight reuse re-executes the same parameters)."""
        from repro.models.registry import get_config

        cfg = get_config(arch)
        weights = sum(g.k * g.n * g.count for g in hw.arch_gemms(cfg))
        active = cfg.active_param_count()
        assert 0.6 * active < weights <= active * upper, (weights, active)

    def test_decode_projection_sane(self):
        arr = hw.ArraySpec(design="CiM-I")
        p = hw.project("yi-34b", "decode_32k", arr)
        # 128 rows decode one token each; ~33.5B active params -> MACs
        assert p["tokens_per_forward"] == 128
        assert p["macs_per_forward"] == pytest.approx(
            128 * 33.5e9, rel=0.05)
        assert p["tok_s"] > 0 and p["pj_per_token"] > 0
        # CiM I beats both NM baselines at the system level (paper Fig 12
        # territory once the Amdahl post-processing term is included)
        assert 1 < p["iso_area"]["speedup"] < p["iso_capacity"]["speedup"] < 10

    def test_encoder_cached_at_decode(self):
        from repro.models.registry import get_config

        cfg = get_config("whisper-large-v3")
        prefill = {g[0].name for g in hw.workload_layers(
            cfg, _shape("prefill_32k"))}
        decode = {g[0].name for g in hw.workload_layers(
            cfg, _shape("decode_32k"))}
        assert any(n.startswith("enc.") for n in prefill)
        assert not any(n.startswith("enc.") for n in decode)
        assert "cross.wq" in decode and "cross.wk" not in decode

    def test_moe_counts_active_experts_only(self):
        from repro.models.registry import get_config

        cfg = get_config("deepseek-v2-236b")
        gemms = {g.name: g for g in hw.arch_gemms(cfg)}
        assert gemms["expert.gate"].count == cfg.n_layers * (
            cfg.top_k + cfg.n_shared_experts)

    def test_projection_shape_validation(self):
        with pytest.raises(KeyError, match="decode_32k"):
            hw.project("yi-34b", "nope", hw.ArraySpec())


def _shape(name):
    from repro.models.registry import SHAPES

    return SHAPES[name]


# ---------------------------------------------------------------------------
# Launch-layer plumbing (hillclimb CLI validation)
# ---------------------------------------------------------------------------

class TestHillclimbValidation:
    def _err(self, capsys, argv):
        from repro.launch import hillclimb

        with pytest.raises(SystemExit) as e:
            hillclimb.main(argv)
        assert e.value.code == 2
        return capsys.readouterr().err

    def test_unknown_arch_friendly(self, capsys):
        err = self._err(capsys, ["--arch", "gpt-17", "--shape", "train_4k",
                                 "--name", "X"])
        assert "registered archs" in err and "yi-34b" in err

    def test_unknown_shape_friendly(self, capsys):
        err = self._err(capsys, ["--arch", "yi-34b", "--shape", "train_400k",
                                 "--name", "X"])
        assert "registered shapes" in err and "train_4k" in err

    def test_bad_array_spec_friendly(self, capsys):
        err = self._err(capsys, ["--arch", "yi-34b", "--shape", "train_4k",
                                 "--name", "X", "--array-spec", "unobtanium"])
        assert "unobtanium" in err and "8T-SRAM" in err

    def test_bad_calibration_friendly(self, capsys, tmp_path):
        bad = tmp_path / "cal.json"
        bad.write_text('{"version": 999}')
        err = self._err(capsys, ["--arch", "yi-34b", "--shape", "train_4k",
                                 "--name", "X", "--calibration", str(bad)])
        assert "calibration" in err


class TestHillclimbCalibratedScoring:
    """--calibration scoring: the fitted per-(spec, shape-class) costs
    rank perf candidates, and a noisy fit (high residual_pct) can never
    promote one (DESIGN.md §11 — measured costs beside the analytic
    roofline)."""

    S1 = "blocked/pallas/bitplane_u8"
    S2 = "blocked/pallas_stream/bitplane_u8"

    def _table(self, mmac_by_spec, resid=1.0):
        from repro.profile.calibrate import (
            CALIBRATION_VERSION, CalibrationTable, KernelFit)

        kern = {}
        for spec, (mmac, r) in mmac_by_spec.items():
            fit = KernelFit(fixed_us=10.0, us_per_mmac=mmac, us_per_mb=0.5,
                            bytes_per_weight=0.25, n_events=20,
                            residual_pct=r)
            kern[f"{spec}|decode"] = fit
            kern[f"{spec}|prefill"] = fit
        return CalibrationTable(version=CALIBRATION_VERSION, backend="cpu",
                                default_spec=self.S1, kernels=kern)

    def test_score_cell_costs_workload(self):
        from repro.launch.hillclimb import score_cell

        s = score_cell("smollm-135m", "decode_32k",
                       self._table({self.S1: (0.5, 1.0)}))
        assert s["trusted"] and s["predicted_us"] > 0 and s["layers"] > 0
        # scale the fitted per-MAC cost -> the score must follow
        s10 = score_cell("smollm-135m", "decode_32k",
                         self._table({self.S1: (5.0, 1.0)}))
        assert s10["predicted_us"] > s["predicted_us"]

    def test_calibrated_table_changes_ranking(self):
        """The pinned satellite contract: two candidate specs, two
        tables with the fitted costs swapped — the ranking flips with
        the table (residuals low, so both rankings are trusted)."""
        from repro.launch.hillclimb import rank_candidates

        cands = [("base", "smollm-135m", "decode_32k", self.S1),
                 ("stream", "smollm-135m", "decode_32k", self.S2)]
        r1 = rank_candidates(cands, self._table(
            {self.S1: (0.01, 1.0), self.S2: (0.5, 1.0)}))
        r2 = rank_candidates(cands, self._table(
            {self.S1: (0.5, 1.0), self.S2: (0.01, 1.0)}))
        assert [n for n, _ in r1] == ["base", "stream"]
        assert [n for n, _ in r2] == ["stream", "base"]
        assert all(s["trusted"] for _, s in r1 + r2)

    def test_high_residual_never_promotes(self):
        """A fit over the residual gate is untrusted and ranked last
        even when its predicted time is the fastest."""
        from repro.launch.hillclimb import rank_candidates

        cands = [("base", "smollm-135m", "decode_32k", self.S1),
                 ("fast-noisy", "smollm-135m", "decode_32k", self.S2)]
        ranked = rank_candidates(cands, self._table(
            {self.S1: (0.5, 1.0), self.S2: (1e-6, 60.0)}))
        assert [n for n, _ in ranked] == ["base", "fast-noisy"]
        assert not ranked[1][1]["trusted"]

    def test_missing_class_fit_is_untrusted(self):
        """predict borrowing the other shape class's fit still scores,
        but the extrapolation is flagged."""
        from repro.launch.hillclimb import score_cell
        from repro.profile.calibrate import (
            CALIBRATION_VERSION, CalibrationTable, KernelFit)

        table = CalibrationTable(
            version=CALIBRATION_VERSION, backend="cpu",
            default_spec=self.S1,
            kernels={f"{self.S1}|decode": KernelFit(
                10.0, 0.5, 0.5, 0.25, 20, 1.0)})
        s = score_cell("smollm-135m", "prefill_32k", table)
        assert s["predicted_us"] > 0 and not s["trusted"]
