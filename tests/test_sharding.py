"""Sharding rules + HLO analysis unit tests (logical — no big meshes;
the 512-device meshes are exercised only by launch/dryrun.py)."""
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.dist import sharding as shd
from repro.launch import hlo_analysis as ha
from repro.models import transformer as T
from repro.models.registry import get_config


class TestParamSpecs:
    def test_rules_cover_model(self):
        cfg = get_config("yi-34b", smoke=True)
        params = jax.eval_shape(lambda k: T.init_params(k, cfg), jax.random.PRNGKey(0))
        specs = shd.param_specs(params)
        flat_p = shd.tree_paths(params)
        flat_s = jax.tree.leaves(specs, is_leaf=lambda s: isinstance(s, P))
        assert len(flat_p) == len(flat_s)
        by_path = {p: s for (p, _), s in zip(flat_p, flat_s)}
        # attention projections are tensor-parallel
        assert any("model" in str(s) for p, s in by_path.items() if p.endswith("wq"))
        # stacked blocks keep layer dim unsharded
        wq_spec = next(s for p, s in by_path.items() if "blocks" in p and p.endswith("wq"))
        assert wq_spec[0] is None and wq_spec[2] == "model"
        # norms replicated
        norm_spec = next(s for p, s in by_path.items() if p.endswith("ln1"))
        assert all(a is None for a in norm_spec)

    def test_moe_expert_sharding(self):
        cfg = get_config("grok-1-314b", smoke=True)
        params = jax.eval_shape(lambda k: T.init_params(k, cfg), jax.random.PRNGKey(0))
        by_path = dict(shd.tree_paths(params))
        specs = shd.param_specs(params)
        flat_p = shd.tree_paths(params)
        flat_s = jax.tree.leaves(specs, is_leaf=lambda s: isinstance(s, P))
        for (p, leaf), s in zip(flat_p, flat_s):
            if "moe/w_gate" in p or "moe/w_down" in p:
                assert s[1] == "model", (p, s)  # expert dim (after layer dim)

    def test_rank_always_matches(self):
        for arch in ("deepseek-v2-236b", "zamba2-2.7b", "whisper-large-v3"):
            cfg = get_config(arch, smoke=True)
            params = jax.eval_shape(lambda k: T.init_params(k, cfg), jax.random.PRNGKey(0))
            specs = shd.param_specs(params)
            for (path, leaf), s in zip(
                shd.tree_paths(params),
                jax.tree.leaves(specs, is_leaf=lambda s: isinstance(s, P)),
            ):
                assert len(s) == leaf.ndim, (path, s, leaf.shape)


class TestCacheSpecs:
    def test_kv_cache_sharded_on_seq_and_batch(self):
        cfg = get_config("yi-34b", smoke=True)
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        caches = jax.eval_shape(lambda: T.init_caches(cfg, 16, 64))
        specs = shd.cache_specs(caches, mesh, batch=16)
        for s, leaf in zip(
            jax.tree.leaves(specs, is_leaf=lambda s: isinstance(s, P)),
            jax.tree.leaves(caches),
        ):
            assert len(s) == leaf.ndim
            assert "model" in tuple(a for a in s if a)  # something sharded


class TestActivationSharding:
    def test_disabled_is_identity(self):
        shd.disable_activation_sharding()
        x = jnp.ones((4, 8, 16))
        assert shd.shard_act(x, "btd") is x

    def test_batch_divisor_guard(self):
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        shd.enable_activation_sharding(multi_pod=False, batch_divisor=16)
        try:
            with shd.use_mesh(mesh):
                x = jnp.ones((1, 8, 16))  # batch 1 not divisible: no crash
                y = shd.shard_act(x, "btd")
                assert y.shape == x.shape
        finally:
            shd.disable_activation_sharding()


class TestHloAnalysis:
    def test_scan_trip_multiplier(self):
        def f(x, w):
            def body(c, wi):
                return jnp.tanh(c @ wi), None
            return jax.lax.scan(body, x, w)[0]

        xs = jax.ShapeDtypeStruct((64, 64), jnp.float32)
        ws = jax.ShapeDtypeStruct((12, 64, 64), jnp.float32)
        txt = jax.jit(f).lower(xs, ws).compile().as_text()
        c = ha.analyze(txt, 1)
        assert c.flops == 12 * 2 * 64**3

    def test_collective_accounting_formulas(self):
        hlo = """
HloModule m
ENTRY %main (p: f32[1024]) -> f32[1024] {
  %p = f32[1024]{0} parameter(0)
  %ar = f32[1024]{0} all-reduce(%p), replica_groups=[1,4]<=[4], to_apply=%add
  ROOT %ag = f32[1024]{0} all-gather(%ar), replica_groups=[2,2]<=[4], dimensions={0}
}
"""
        c = ha.analyze(hlo, 4)
        # all-reduce: 2 * 4096 * 3/4 = 6144 ; all-gather: 4096 * 1/2 = 2048
        assert c.coll["all-reduce"] == 6144
        assert c.coll["all-gather"] == 2048

    def test_dus_counts_update_only(self):
        def f(cache, upd, i):
            return jax.lax.dynamic_update_slice(cache, upd, (i, 0))

        cs = jax.ShapeDtypeStruct((4096, 64), jnp.float32)
        us = jax.ShapeDtypeStruct((1, 64), jnp.float32)
        txt = (
            jax.jit(f, donate_argnums=(0,))  # in-place update (cache pattern)
            .lower(cs, us, jax.ShapeDtypeStruct((), jnp.int32))
            .compile().as_text()
        )
        c = ha.analyze(txt, 1)
        assert c.hbm_bytes < 4096 * 64 * 4  # far less than the full cache


class TestFsdp:
    def test_big_weights_gain_data_axis(self):
        cfg = get_config("yi-34b")  # full config: big weights
        params = jax.eval_shape(lambda k: T.init_params(k, cfg), jax.random.PRNGKey(0))
        axis_sizes = {"model": 16, "data": 16}
        plain = shd.param_specs(params, axis_sizes=axis_sizes)
        fsdp = shd.param_specs(params, fsdp=True, axis_sizes=axis_sizes)
        found = 0
        for (path, leaf), sp, sf in zip(
            shd.tree_paths(params),
            jax.tree.leaves(plain, is_leaf=lambda s: isinstance(s, P)),
            jax.tree.leaves(fsdp, is_leaf=lambda s: isinstance(s, P)),
        ):
            axes_p = {a for a in jax.tree_util.tree_leaves(tuple(sp)) if a}
            axes_f = {a for a in jax.tree_util.tree_leaves(tuple(sf)) if a}
            if "data" in axes_f and "data" not in axes_p:
                found += 1
                # every sharded dim still divides
                for dim, ax in zip(leaf.shape, sf):
                    if ax is not None:
                        sz = 1
                        for a in (ax if isinstance(ax, tuple) else (ax,)):
                            sz *= axis_sizes.get(a, 1)
                        assert dim % sz == 0
        assert found > 3  # attention + mlp weights got the data axis

    def test_small_leaves_untouched(self):
        cfg = get_config("smollm-135m", smoke=True)
        params = jax.eval_shape(lambda k: T.init_params(k, cfg), jax.random.PRNGKey(0))
        axis_sizes = {"model": 16, "data": 16}
        fsdp = shd.param_specs(params, fsdp=True, axis_sizes=axis_sizes)
        for (path, leaf), sf in zip(
            shd.tree_paths(params),
            jax.tree.leaves(fsdp, is_leaf=lambda s: isinstance(s, P)),
        ):
            if leaf.size < (1 << 20):  # tiny smoke weights: no fsdp churn
                assert "data" not in {a for a in jax.tree_util.tree_leaves(tuple(sf)) if a}
