"""Validate the cost model against the paper's reported numbers.

Array level (Figs 9/11): exact — the normalized ratios are the model's
inputs, so the derived claims must match the text to the percent.
System level (Figs 12/13): the model *predicts* these from the array
constants + workload mapping with two calibrated constants; asserted
within 20% (observed max error ~17%, see EXPERIMENTS.md).
"""
import pytest

from repro.core import accelerator as acc
from repro.core import cost_model as cm

# Paper Section V text, per technology.
PAPER_ARRAY = {
    "CiM-I": {
        "8T-SRAM": dict(lat=88, en=74, read_en=22, read_lat=7, write_lat=4, cell=18),
        "3T-eDRAM": dict(lat=88, en=78, read_en=24, read_lat=7, write_lat=4, cell=34),
        "3T-FEMFET": dict(lat=88, en=78, read_en=17, read_lat=19, write_lat=10, cell=34),
    },
    "CiM-II": {
        "8T-SRAM": dict(lat=80, en=61, read_en=74, write_lat=8, cell=6),
        "3T-eDRAM": dict(lat=78, en=63, read_en=44, write_lat=10, cell=6),
        "3T-FEMFET": dict(lat=84, en=62, read_en=79, write_lat=3, cell=6),
    },
}


class TestArrayLevel:
    @pytest.mark.parametrize("design", ["CiM-I", "CiM-II"])
    @pytest.mark.parametrize("tech", cm.TECHNOLOGIES)
    def test_paper_claims(self, tech, design):
        got = cm.paper_validation_table()[tech][design]
        want = PAPER_ARRAY[design][tech]
        assert got["cim_latency_reduction_pct"] == pytest.approx(want["lat"], abs=1.5)
        assert got["cim_energy_reduction_pct"] == pytest.approx(want["en"], abs=1.5)
        assert got["read_energy_overhead_pct"] == pytest.approx(want["read_en"], abs=1.5)
        assert got["write_latency_overhead_pct"] == pytest.approx(want["write_lat"], abs=1.5)
        assert got["cell_area_overhead_pct"] == pytest.approx(want["cell"], abs=1.5)

    def test_flavor_comparison_section_v3(self):
        """CiM II vs I: 1.5/1.7/1.7x energy, 1.7/1.8/1.3x latency."""
        fc = cm.flavor_comparison()
        assert fc["8T-SRAM"]["energy_II_over_I"] == pytest.approx(1.5, abs=0.1)
        assert fc["3T-eDRAM"]["energy_II_over_I"] == pytest.approx(1.7, abs=0.1)
        assert fc["3T-FEMFET"]["energy_II_over_I"] == pytest.approx(1.7, abs=0.1)
        assert fc["8T-SRAM"]["latency_II_over_I"] == pytest.approx(1.7, abs=0.1)
        assert fc["3T-eDRAM"]["latency_II_over_I"] == pytest.approx(1.8, abs=0.1)
        assert fc["3T-FEMFET"]["latency_II_over_I"] == pytest.approx(1.3, abs=0.1)

    def test_macro_area_ranges(self):
        for tech in cm.TECHNOLOGIES:
            m1 = cm.ARRAY_METRICS[tech]["CiM-I"].macro_area_vs_nm
            m2 = cm.ARRAY_METRICS[tech]["CiM-II"].macro_area_vs_nm
            assert 1.3 <= m1 <= 1.53
            assert 1.21 <= m2 <= 1.33


class TestSystemLevel:
    @pytest.mark.parametrize("design", ["CiM-I", "CiM-II"])
    @pytest.mark.parametrize("baseline", ["iso-capacity", "iso-area"])
    @pytest.mark.parametrize("tech", cm.TECHNOLOGIES)
    def test_speedup_within_20pct(self, tech, design, baseline):
        got = acc.average_speedup(tech, design, baseline)
        want = acc.PAPER_SYSTEM_SPEEDUP[(design, baseline)][tech]
        assert abs(got - want) / want < 0.20, (got, want)

    @pytest.mark.parametrize("design", ["CiM-I", "CiM-II"])
    @pytest.mark.parametrize("tech", cm.TECHNOLOGIES)
    def test_energy_within_20pct(self, tech, design):
        got = acc.average_energy_reduction(tech, design)
        want = acc.PAPER_SYSTEM_ENERGY[design][tech]
        assert abs(got - want) / want < 0.20, (got, want)

    def test_energy_similar_across_baselines(self):
        """Paper: energy benefits are ~equal for iso-capacity and iso-area
        since total ops are the same."""
        a = acc.average_energy_reduction("8T-SRAM", "CiM-I", "iso-capacity")
        b = acc.average_energy_reduction("8T-SRAM", "CiM-I", "iso-area")
        assert abs(a - b) / a < 0.02

    def test_benchmark_suite_complete(self):
        assert set(acc.get_benchmarks()) == {"AlexNet", "ResNet34", "Inception", "LSTM", "GRU"}

    def test_mac_counts_sane(self):
        b = acc.get_benchmarks()
        # published MAC counts (approximate): AlexNet ~0.7G, ResNet34 ~3.6G
        alex = sum(l.macs for l in b["AlexNet"])
        rn = sum(l.macs for l in b["ResNet34"])
        assert 0.5e9 < alex < 1.2e9
        assert 2.5e9 < rn < 4.5e9
