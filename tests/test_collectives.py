"""Compressed all-reduce (shard_map manual collectives) on a multi-device
CPU mesh — this is the path that actually narrows the gradient wire
format (optim/compress.py only models the numerics under pjit autodiff)."""
import os
import subprocess
import sys
import textwrap

# needs >1 device: run the meat in a subprocess with forced host devices
_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.dist.collectives import mean_grads_int8

    mesh = jax.make_mesh((4,), ("data",))
    key = jax.random.PRNGKey(0)
    # 4 shards of local gradients
    g = jax.random.normal(key, (4, 512))
    keys = jax.random.split(jax.random.PRNGKey(1), 4)

    exact = np.asarray(g).mean(0)
    out = np.asarray(mean_grads_int8(mesh, g, keys))
    amax = np.abs(np.asarray(g)).max()
    err = np.abs(out - exact).max()
    assert err < 0.02 * amax, (err, amax)        # quantization-level error

    # unbiasedness: average over many rounding keys converges
    outs = []
    for i in range(48):
        ks = jax.random.split(jax.random.PRNGKey(100 + i), 4)
        outs.append(np.asarray(mean_grads_int8(mesh, g, ks)))
    bias = np.abs(np.mean(outs, 0) - exact).max()
    assert bias < 0.004 * amax, (bias, amax)
    print("OK")
""")


def test_int8_mean_reduce_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("JAX_PLATFORMS", None)
    out = subprocess.run(
        [sys.executable, "-c", _SCRIPT], env=env, capture_output=True,
        text=True, timeout=600,
    )
    assert out.returncode == 0, out.stderr[-2000:]
    assert "OK" in out.stdout
