"""Compressed all-reduce (shard_map manual collectives) on the real
multi-device host mesh — this is the path that actually narrows the
gradient/TP wire format (optim/compress.py only models the numerics
under pjit autodiff).

Historically these assertions hid in a subprocess (the suite ran
single-device); the session conftest now forces 8 virtual devices, so
they run in-process on the shared ``tp_mesh`` fixture, including the
hypothesis error-bound property sweep.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.dist.collectives import (
    compressed_psum_int8,
    mean_grads_int8,
    shard_map,
    tp_allreduce,
)

try:  # minimal installs: unit tests run, property tests are skipped
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover
    HAVE_HYPOTHESIS = False


def _property_sweep(f):
    """Hypothesis sweep when available; otherwise the test keeps its
    defaulted args and the skipif mark makes the skip VISIBLE in -rs
    (the CI tp-tests job greps for silent TP-suite skips — a vanished
    test would defeat it)."""
    if not HAVE_HYPOTHESIS:
        return f
    return settings(max_examples=20, deadline=None)(given(
        seed=st.integers(0, 2**16),
        size=st.sampled_from([64, 256, 1000]),
        scale=st.floats(1e-3, 1e3),
        shards=st.sampled_from([2, 4, 8]),
    )(f))


def _data_mesh(tp_mesh, n=4):
    """(n,)-device "data" mesh carved from the session fixture's pool."""
    return jax.sharding.Mesh(
        tp_mesh.devices.reshape(-1)[:n], ("data",)
    )


def test_int8_mean_reduce_error_bound(tp_mesh):
    mesh = _data_mesh(tp_mesh)
    g = jax.random.normal(jax.random.PRNGKey(0), (4, 512))
    keys = jax.random.split(jax.random.PRNGKey(1), 4)
    exact = np.asarray(g).mean(0)
    out = np.asarray(mean_grads_int8(mesh, g, keys))
    amax = np.abs(np.asarray(g)).max()
    err = np.abs(out - exact).max()
    assert err < 0.02 * amax, (err, amax)  # quantization-level error


def test_int8_mean_reduce_unbiased(tp_mesh):
    """Averaging over many stochastic-rounding keys converges to the
    exact mean (the rounding is unbiased)."""
    mesh = _data_mesh(tp_mesh)
    g = jax.random.normal(jax.random.PRNGKey(0), (4, 512))
    exact = np.asarray(g).mean(0)
    amax = np.abs(np.asarray(g)).max()
    outs = []
    for i in range(48):
        ks = jax.random.split(jax.random.PRNGKey(100 + i), 4)
        outs.append(np.asarray(mean_grads_int8(mesh, g, ks)))
    bias = np.abs(np.mean(outs, 0) - exact).max()
    assert bias < 0.004 * amax, (bias, amax)


def test_tp_allreduce_exact_matches_psum(tp_mesh):
    """compressed=False is the plain psum — bit-exact TP reduction
    (integer payloads, the CiM event-count case: any summation order is
    exact in f32)."""
    mesh = _data_mesh(tp_mesh)
    x = jnp.round(
        10 * jax.random.normal(jax.random.PRNGKey(2), (4, 64))
    ).astype(jnp.float32)

    f = shard_map(
        lambda s: tp_allreduce(s.reshape(s.shape[1:]), "data"),
        mesh=mesh, in_specs=(P("data"),), out_specs=P(),
    )
    np.testing.assert_array_equal(
        np.asarray(f(x)), np.asarray(x.sum(0)))


def test_tp_allreduce_compressed_requires_key(tp_mesh):
    mesh = _data_mesh(tp_mesh)
    x = jnp.ones((4, 8), jnp.float32)
    f = shard_map(
        lambda s: tp_allreduce(
            s.reshape(s.shape[1:]), "data", compressed=True),
        mesh=mesh, in_specs=(P("data"),), out_specs=P(),
    )
    try:
        f(x)
    except ValueError as e:
        assert "key" in str(e)
    else:
        raise AssertionError("compressed tp_allreduce without key accepted")


@pytest.mark.skipif(not HAVE_HYPOTHESIS, reason="hypothesis not installed")
@_property_sweep
def test_compressed_psum_error_bound_property(seed=0, size=64, scale=1.0,
                                              shards=2):
    """Property (previously skipped for want of a real mesh): for any
    payload, |compressed_psum - exact_sum| <= shards * (amax / 127) *
    1.5 — every shard rounds within one int8 level of the shared
    scale, and the errors add at worst linearly."""
    if jax.device_count() < 8:
        pytest.skip("needs the 8-device session mesh")
    mesh = jax.sharding.Mesh(np.asarray(jax.devices()[:shards]), ("data",))
    g = scale * jax.random.normal(
        jax.random.PRNGKey(seed), (shards, size), jnp.float32)
    keys = jax.random.split(jax.random.PRNGKey(seed + 1), shards)

    f = shard_map(
        lambda s, k: compressed_psum_int8(
            s.reshape(s.shape[1:]), k[0], "data"),
        mesh=mesh, in_specs=(P("data"), P("data")), out_specs=P(),
    )
    out = np.asarray(f(g, keys))
    exact = np.asarray(g, np.float64).sum(0)
    amax = np.abs(np.asarray(g)).max()
    bound = shards * (amax / 127.0) * 1.5
    assert np.abs(out - exact).max() <= bound
