"""The paper's functional claims: truth table, MAC semantics, ADC clamp,
sensing-error channel — unit + property tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:  # minimal installs: unit tests run, property tests are skipped
    from hypothesis import given, settings, strategies as st
except ImportError:
    given = settings = st = None

from repro.core import site_cim as sc


def rand_ternary(key, shape, p_zero=0.34):
    k1, k2 = jax.random.split(key)
    sign = jax.random.choice(k1, jnp.array([-1, 1]), shape)
    keep = jax.random.bernoulli(k2, 1 - p_zero, shape)
    return (sign * keep).astype(jnp.int32)


class TestScalarProduct:
    def test_truth_table(self):
        """Fig. 3(d): O = I * W for all nine ternary combinations."""
        for i in (-1, 0, 1):
            for w in (-1, 0, 1):
                o = sc.scalar_product(jnp.asarray(i), jnp.asarray(w))
                assert int(o) == i * w, (i, w)


class TestCiMMatmul:
    def test_no_clip_equals_exact(self):
        key = jax.random.PRNGKey(0)
        x = rand_ternary(key, (8, 128))
        w = rand_ternary(jax.random.PRNGKey(1), (128, 32))
        cfg = sc.SiTeCiMConfig(adc_max=16)  # a,b <= 16 so clamp never binds
        np.testing.assert_array_equal(
            np.asarray(sc.site_cim_matmul(x, w, cfg)),
            np.asarray(sc.nm_ternary_matmul(x, w)),
        )

    def test_three_formulations_agree(self):
        key = jax.random.PRNGKey(2)
        x = rand_ternary(key, (4, 96), p_zero=0.1)  # low sparsity -> clipping
        w = rand_ternary(jax.random.PRNGKey(3), (96, 16), p_zero=0.1)
        a = sc.site_cim_matmul(x, w)
        b = sc.site_cim_matmul_corrected(x, w)
        c = sc.site_cim_matmul_bitplane(x, w)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        np.testing.assert_array_equal(np.asarray(a), np.asarray(c))

    def test_adc_clamp_binds(self):
        """All-ones block: a = 16 > 8, so output must clamp to 8."""
        x = jnp.ones((1, 16), jnp.int32)
        w = jnp.ones((16, 1), jnp.int32)
        out = sc.site_cim_matmul(x, w)
        assert int(out[0, 0]) == sc.ADC_MAX  # not 16

    def test_clamp_per_block_not_global(self):
        # two blocks, each saturating at 8 -> total 16
        x = jnp.ones((1, 32), jnp.int32)
        w = jnp.ones((32, 1), jnp.int32)
        assert int(sc.site_cim_matmul(x, w)[0, 0]) == 2 * sc.ADC_MAX

    def test_negative_clamp(self):
        x = jnp.ones((1, 16), jnp.int32)
        w = -jnp.ones((16, 1), jnp.int32)
        assert int(sc.site_cim_matmul(x, w)[0, 0]) == -sc.ADC_MAX

    def test_padding_for_ragged_k(self):
        key = jax.random.PRNGKey(4)
        x = rand_ternary(key, (3, 45))
        w = rand_ternary(jax.random.PRNGKey(5), (45, 7))
        out = sc.site_cim_matmul(x, w, sc.SiTeCiMConfig(adc_max=16))
        np.testing.assert_array_equal(np.asarray(out), np.asarray(x @ w))

    def test_flavors_same_math(self):
        """CiM I and II differ in circuits/cost, not results (Section IV)."""
        key = jax.random.PRNGKey(6)
        x = rand_ternary(key, (5, 64))
        w = rand_ternary(jax.random.PRNGKey(7), (64, 9))
        np.testing.assert_array_equal(
            np.asarray(sc.site_cim_matmul(x, w, sc.PAPER_CIM_I)),
            np.asarray(sc.site_cim_matmul(x, w, sc.PAPER_CIM_II)),
        )


class TestSensingError:
    def test_error_rate_matches_config(self):
        key = jax.random.PRNGKey(8)
        x = rand_ternary(key, (64, 256))
        w = rand_ternary(jax.random.PRNGKey(9), (256, 64))
        cfg = sc.SiTeCiMConfig(error_prob=sc.SENSE_ERROR_PROB)
        clean = sc.site_cim_matmul(x, w)
        noisy = sc.site_cim_matmul(x, w, cfg, key=jax.random.PRNGKey(10))
        diff = np.asarray(clean) != np.asarray(noisy)
        # each output sums 16 block partials; P(any flip) ~ 16 * 3.1e-3
        rate = diff.mean()
        assert 0.2 * 16 * 3.1e-3 < rate < 5 * 16 * 3.1e-3
        # perturbations are +-1 ADC levels
        delta = np.abs(np.asarray(clean) - np.asarray(noisy))
        assert delta.max() <= 4  # a few coincident flips at most

    def test_error_requires_key(self):
        cfg = sc.SiTeCiMConfig(error_prob=0.1)
        with pytest.raises(ValueError):
            sc.site_cim_matmul(jnp.ones((1, 16)), jnp.ones((16, 1)), cfg)


if st is not None:

    @settings(max_examples=25, deadline=None)
    @given(st.integers(0, 2**31 - 1), st.integers(1, 8), st.integers(1, 8),
           st.integers(1, 6))
    def test_cim_matmul_property(seed, m, n, kb):
        """Property: CiM output == blockwise-clamped exact computation, and
        |cim - exact| <= sum of possible clamp losses."""
        k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
        x = rand_ternary(k1, (m, kb * 16), p_zero=0.2)
        w = rand_ternary(k2, (kb * 16, n), p_zero=0.2)
        cim = np.asarray(sc.site_cim_matmul(x, w))
        corr = np.asarray(sc.site_cim_matmul_corrected(x, w))
        exact = np.asarray(x @ w)
        np.testing.assert_array_equal(cim, corr)
        assert np.all(np.abs(cim) <= kb * sc.ADC_MAX)
        # clamping only shrinks magnitudes of block partials
        assert np.all(np.abs(cim - exact) <= kb * 8)

    @settings(max_examples=25, deadline=None)
    @given(st.integers(0, 2**31 - 1))
    def test_sign_symmetry_property(seed):
        """I -> -I flips the sign of every output (cross-coupling
        semantics)."""
        k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
        x = rand_ternary(k1, (4, 64))
        w = rand_ternary(k2, (64, 8))
        a = np.asarray(sc.site_cim_matmul(x, w))
        b = np.asarray(sc.site_cim_matmul(-x, w))
        np.testing.assert_array_equal(a, -b)
