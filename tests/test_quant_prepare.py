"""Offline ternarization / packing surgery + pre_quantized serving."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.ternary import unpack_ternary
from repro.models import transformer as T
from repro.models.layers import QuantConfig
from repro.models.registry import get_config
from repro.quant.prepare import pack_params, ternarize_params


def test_ternarize_params_only_touches_quantizable():
    cfg = get_config("smollm-135m", smoke=True)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    tp = ternarize_params(params)
    # embeddings / norms untouched
    np.testing.assert_array_equal(np.asarray(tp["embed"]), np.asarray(params["embed"]))
    np.testing.assert_array_equal(
        np.asarray(tp["final_norm"]), np.asarray(params["final_norm"]))
    # attention weights became {-s, 0, s} per channel
    wq = np.asarray(tp["blocks"]["attn"]["wq"][0], np.float32)
    per_col_vals = [np.unique(np.abs(wq[:, j])) for j in range(4)]
    for vals in per_col_vals:
        nz = vals[vals > 0]
        assert len(nz) <= 1  # single magnitude per output channel


def test_prequantized_forward_close_to_qat_forward():
    cfg = get_config("smollm-135m", smoke=True)  # cim mode
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab)
    ref = T.forward(params, {"tokens": toks}, cfg)
    tp = ternarize_params(params)
    cfg_pq = cfg.replace(quant=QuantConfig(mode="cim", pre_quantized=True))
    out = T.forward(tp, {"tokens": toks}, cfg_pq)
    # pre-quantized path must reproduce the QAT forward (same ternary
    # weights, scales folded) up to bf16 noise
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        rtol=8e-2, atol=8e-2,
    )


def test_pack_params_roundtrip():
    cfg = get_config("smollm-135m", smoke=True)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    folded, packed = pack_params(params)
    assert packed, "no weights packed"
    for path, (p1, p2, scale) in packed.items():
        k_axis = p1.ndim - 2
        t = unpack_ternary(p1, p2, axis=k_axis).astype(jnp.float32)
        assert set(np.unique(np.asarray(t))) <= {-1.0, 0.0, 1.0}
        # packed planes are 1/8 the K extent
        assert p1.shape[k_axis] * 8 == t.shape[k_axis]
