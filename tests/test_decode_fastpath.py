"""Decode-shaped ternary MAC fast path (DESIGN.md §9).

The contract pinned here:

  * shape-aware dispatch: every registered spec is **bit-equal** between
    the decode-tile path (auto, M <= DECODE_M_MAX) and the forced
    prefill-tile path (the pre-§9 behaviour) across ragged decode M;
  * the decode packed kernel's int32 a/b accumulation is bit-identical
    to the f32 prefill kernel (the event counts are small integers);
  * prepare-time canonical planes round-trip through execute_packed
    (both backends, solo and TP-sharded) and delete the per-step plane
    pad/relayout from the serving jaxpr — and on decode shapes the
    pallas kernel pads M only to the 8-row decode tile, never to 128
    (the acceptance jaxpr pin);
  * tile tables / autotune: winners are cached per (spec, shape-class)
    and picked up by later executes; the override lever restores the
    pre-§9 tiles for old-vs-new benchmarking.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api
from repro.core import ternary as tern
from repro.core.execution import (
    DECODE_M_MAX,
    clear_tile_cache,
    set_shape_class_override,
    shape_class,
    tiles_for,
)
from repro.kernels.packed_mac import packed_cim_matmul, packed_cim_matmul_decode
from repro.models import transformer as T
from repro.models.registry import get_config
from repro.quant.prepare import prepare_for_spec

ALL_SPECS = list(api.registered_specs())
RAGGED_M = (1, 2, 3, 5, 7)


def rand_ternary(key, shape, p_zero=0.25, dtype=jnp.float32):
    k1, k2 = jax.random.split(key)
    sign = jax.random.choice(k1, jnp.array([-1, 1]), shape)
    keep = jax.random.bernoulli(k2, 1 - p_zero, shape)
    return (sign * keep).astype(dtype)


@pytest.fixture(autouse=True)
def _clean_tile_state():
    yield
    set_shape_class_override(None)
    clear_tile_cache()


# ---------------------------------------------------------------------------
# Shape-sweep bit-equality: decode tiles vs the pre-§9 prefill path
# ---------------------------------------------------------------------------


class TestDecodeTileEquivalence:
    @pytest.mark.parametrize("spec", ALL_SPECS, ids=lambda s: s.name)
    def test_decode_bit_equal_to_prefill_tiles(self, spec):
        """For every registered (formulation, backend, packing) and
        every ragged decode M, the small-M tile path returns the same
        bits as the forced 128-row prefill path (integer event counts
        are exact under any tiling/accumulation order)."""
        k, n = 45, 19  # ragged K (not a block multiple) and ragged N
        kx, kw = jax.random.split(jax.random.PRNGKey(11))
        w = rand_ternary(kw, (k, n), p_zero=0.1)
        for m in RAGGED_M:
            x = rand_ternary(jax.random.fold_in(kx, m), (m, k), p_zero=0.1)
            decode = np.asarray(api.execute(spec, x, w))
            set_shape_class_override("prefill")
            try:
                prefill = np.asarray(api.execute(spec, x, w))
            finally:
                set_shape_class_override(None)
            np.testing.assert_array_equal(
                decode, prefill, err_msg=f"{spec.name} M={m}")

    @pytest.mark.parametrize("backend", ["jnp", "pallas"])
    @pytest.mark.parametrize("formulation", ["blocked", "exact"])
    def test_execute_packed_decode_bit_equal(self, formulation, backend):
        """Same sweep over the stored-plane fast path."""
        k, n = 96, 24
        spec = api.CiMExecSpec(formulation=formulation, backend=backend,
                               packing="bitplane_u8")
        kx, kw = jax.random.split(jax.random.PRNGKey(5))
        t = rand_ternary(kw, (k, n), p_zero=0.1, dtype=jnp.int8)
        p1, p2 = tern.pack_ternary(t, axis=0)
        for m in RAGGED_M:
            x = rand_ternary(jax.random.fold_in(kx, m), (m, k), p_zero=0.1)
            decode = np.asarray(api.execute_packed(spec, x, p1, p2))
            set_shape_class_override("prefill")
            try:
                prefill = np.asarray(api.execute_packed(spec, x, p1, p2))
            finally:
                set_shape_class_override(None)
            np.testing.assert_array_equal(
                decode, prefill, err_msg=f"{spec.name} M={m}")


# ---------------------------------------------------------------------------
# int32 vs f32 accumulation (the decode kernel's integer pipeline)
# ---------------------------------------------------------------------------


class TestInt32Accumulation:
    @pytest.mark.parametrize("cim", [True, False], ids=["blocked", "exact"])
    def test_decode_kernel_int32_equals_prefill_f32(self, cim):
        """packed_cim_matmul_decode (int8 operands, int32 a/b counts) ==
        packed_cim_matmul (bf16 operands, f32 accumulation), bit for
        bit, across a multi-tile (K, N) grid: the event counts are
        integers bounded by block, exact in both pipelines."""
        m, k, n = 8, 512, 256
        kx, kw = jax.random.split(jax.random.PRNGKey(7))
        x = rand_ternary(kx, (m, k), p_zero=0.1)
        t = rand_ternary(kw, (k, n), p_zero=0.1, dtype=jnp.int8)
        p1, p2 = tern.pack_ternary(t, axis=0)
        xp = jnp.pad(x, ((0, 128 - m), (0, 0)))
        f32 = np.asarray(packed_cim_matmul(
            xp.astype(jnp.bfloat16), p1, p2, cim=cim, interpret=True))[:m]
        i32 = np.asarray(packed_cim_matmul_decode(
            x.astype(jnp.int8), p1, p2, cim=cim, interpret=True))
        assert i32.dtype == np.int32
        np.testing.assert_array_equal(f32, i32.astype(np.float32))


# ---------------------------------------------------------------------------
# Prepare-time canonical planes
# ---------------------------------------------------------------------------


def _smoke_planes(backend):
    cfg = get_config("smollm-135m", smoke=True)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    spec = api.CiMExecSpec(formulation="blocked", backend=backend,
                           packing="bitplane_u8")
    _, packed = prepare_for_spec(params, spec)
    return spec, packed


class TestCanonicalPlanes:
    @pytest.mark.parametrize("backend", ["jnp", "pallas"])
    def test_roundtrip_through_execute_packed(self, backend):
        """Canonical (pre-padded) planes return the same bits as the
        dense-weight execute path, sliced back to the logical N."""
        spec, packed = _smoke_planes(backend)
        entry = packed["blocks/attn/wq"]
        assert isinstance(entry, tern.PackedPlanes)
        k_mult, n_mult = api.canonical_plane_layout(spec)
        assert entry.pos.shape[-2] * 8 % k_mult == 0
        assert entry.pos.shape[-1] % n_mult == 0
        lay = entry.layer(0)
        x = rand_ternary(jax.random.PRNGKey(1), (3, lay.k), p_zero=0.1)
        out = api.execute_packed(spec, x, lay)
        assert out.shape == (3, lay.n)
        t = tern.unpack_ternary(lay.pos, lay.neg, axis=0)
        t = t[: lay.k, : lay.n].astype(jnp.float32)
        expect = api.execute(
            dataclasses.replace(spec, packing="none"), x, t)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(expect))

    def test_legacy_tuple_layout_still_available(self):
        """canonical=False keeps the raw (p1, p2, scale) tuples at
        logical extents (the pack_params layout)."""
        cfg = get_config("smollm-135m", smoke=True)
        params = T.init_params(jax.random.PRNGKey(0), cfg)
        spec = api.CiMExecSpec(formulation="blocked", backend="jnp",
                               packing="bitplane_u8")
        _, packed = prepare_for_spec(params, spec, canonical=False)
        p1, p2, scale = packed["blocks/attn/wq"]
        assert isinstance(packed["blocks/attn/wq"], tuple)
        assert p1.shape[-2] * 8 == cfg.d_model

    def test_packed_planes_validation(self):
        spec, packed = _smoke_planes("jnp")
        entry = packed["blocks/attn/wq"]
        lay = entry.layer(0)
        x = rand_ternary(jax.random.PRNGKey(2), (2, lay.k))
        with pytest.raises(ValueError, match="stacked"):
            api.execute_packed(spec, x, entry)  # un-sliced stacked planes
        with pytest.raises(ValueError, match="alone"):
            api.execute_packed(spec, x, lay, lay.neg)
        with pytest.raises(ValueError, match="mismatch"):
            api.execute_packed(spec, x[:, :-8], lay)
        with pytest.raises(ValueError, match="stacked"):
            lay.layer(0)

    def test_sharded_canonical_planes_bit_equal(self, tp_mesh):
        """prepare_for_spec(mesh=...) lands the canonical planes
        N-sharded over "model" and execute_packed over the sharded
        planes is bit-equal to the replicated result."""
        from jax.sharding import NamedSharding
        from repro.launch.mesh import make_tp_mesh

        cfg = get_config("smollm-135m", smoke=True)
        params = T.init_params(jax.random.PRNGKey(0), cfg)
        spec = api.CiMExecSpec(formulation="blocked", backend="jnp",
                               packing="bitplane_u8")
        _, base = prepare_for_spec(params, spec)
        mesh = make_tp_mesh(2)
        _, packed = prepare_for_spec(params, spec, mesh=mesh)
        sharded = 0
        for path, entry in packed.items():
            ns = entry.pos.sharding
            assert isinstance(ns, NamedSharding), path
            if ns.spec[-1] == "model":
                sharded += 1
        assert sharded > 0, "no canonical plane picked up the model axis"
        lay_b, lay_s = base["blocks/attn/wq"].layer(0), \
            packed["blocks/attn/wq"].layer(0)
        x = rand_ternary(jax.random.PRNGKey(3), (4, lay_b.k), p_zero=0.1)
        np.testing.assert_array_equal(
            np.asarray(api.execute_packed(spec, x, lay_b)),
            np.asarray(api.execute_packed(spec, x, lay_s)))


# ---------------------------------------------------------------------------
# Jaxpr pins: no per-step plane pad, no M-to-128 pad on decode shapes
# ---------------------------------------------------------------------------


def _iter_eqns(jaxpr):
    # migrated to the shared recursive walker (repro.analysis)
    from repro.analysis import iter_eqns

    for eqn, _within in iter_eqns(jaxpr):
        yield eqn


def _trace_packed(spec, planes, m):
    x = rand_ternary(jax.random.PRNGKey(4), (m, planes.k), p_zero=0.1)

    def f(x, pos, neg):
        lay = tern.PackedPlanes(pos=pos, neg=neg, scale=planes.scale,
                                k=planes.k, n=planes.n)
        return api.execute_packed(spec, x, lay)

    return jax.make_jaxpr(f)(x, planes.pos, planes.neg)


class TestServingJaxpr:
    @pytest.mark.parametrize("backend", ["jnp", "pallas"])
    def test_canonical_planes_never_padded_per_step(self, backend):
        """The acceptance pin for prepare-time canonicalization: with
        canonical planes the traced step contains **no** pad on any
        uint8 (plane) operand — the pad moved to prepare time."""
        from repro.analysis import TraceContract, check_jaxpr

        spec, packed = _smoke_planes(backend)
        lay = packed["blocks/attn/wq"].layer(0)
        closed = _trace_packed(spec, lay, m=3)
        findings = check_jaxpr(
            closed, TraceContract(no_pad_on_dtypes=("uint8",)),
            f"decode_fastpath.{backend}")
        assert not findings, findings

    def test_decode_shape_pads_m_to_decode_tile_not_128(self):
        """The acceptance pin for shape-aware dispatch: on a decode
        shape (M=3) the pallas packed kernel consumes x padded to the
        8-row decode tile; under the forced pre-§9 prefill class the
        same trace pads M to 128 (sensitivity check)."""
        from repro.analysis import TraceContract, check_jaxpr
        from repro.core.execution import no_decode_m128_rule

        spec, packed = _smoke_planes("pallas")
        lay = packed["blocks/attn/wq"].layer(0)
        contract = TraceContract(forbid_prims=(no_decode_m128_rule(),))

        def m_dims(closed):
            dims = set()
            for e in _iter_eqns(closed.jaxpr):
                if e.primitive.name == "pallas_call":
                    dims |= {v.aval.shape[0] for v in e.invars
                             if getattr(v.aval, "ndim", 0) == 2}
            return dims

        decode = _trace_packed(spec, lay, m=3)
        assert not check_jaxpr(contract=contract, closed=decode,
                               where="decode_fastpath.m3"), "m=3 padded to 128"
        decode_dims = m_dims(decode)
        assert decode_dims, "no pallas_call traced"
        assert DECODE_M_MAX in decode_dims, decode_dims
        # sensitivity check: under the forced pre-§9 prefill class the
        # very same rule must fire (the auditor is not vacuously green)
        set_shape_class_override("prefill")
        try:
            prefill = _trace_packed(spec, lay, m=3)
        finally:
            set_shape_class_override(None)
        hits = check_jaxpr(contract=contract, closed=prefill,
                           where="decode_fastpath.m3.prefill_override")
        assert any(f.rule == "decode-m-pad-128" for f in hits), hits
        assert 128 in m_dims(prefill)


# ---------------------------------------------------------------------------
# Tile tables / autotune
# ---------------------------------------------------------------------------


class TestTileDispatch:
    def test_shape_class_boundary(self):
        assert shape_class(1) == "decode"
        assert shape_class(DECODE_M_MAX) == "decode"
        assert shape_class(DECODE_M_MAX + 1) == "prefill"

    def test_tiles_for_classes(self):
        spec = api.CiMExecSpec(formulation="blocked", backend="pallas",
                               packing="bitplane_u8")
        bm_d, _, _ = tiles_for(spec, 2, 256, 128)
        bm_p, _, _ = tiles_for(spec, 256, 256, 128)
        assert bm_d <= DECODE_M_MAX < bm_p
        # jnp backends have no tile dimension
        assert tiles_for(
            api.CiMExecSpec(formulation="blocked", backend="jnp"),
            2, 256, 128) is None

    def test_override_validation(self):
        with pytest.raises(ValueError, match="shape class"):
            set_shape_class_override("training")

    def test_autotune_caches_winner(self):
        spec = api.CiMExecSpec(formulation="blocked", backend="pallas",
                               packing="bitplane_u8")
        report = api.autotune(spec, shapes=((2, 256, 128),), repeats=1)
        assert set(report) == {"decode"}
        winner = tuple(report["decode"]["tiles"])
        assert winner in {tuple(map(int, c.split("x")))
                          for c in report["decode"]["candidates"]}
        # the winner is what tiles_for now answers — and clears cleanly
        assert tiles_for(spec, 2, 256, 128) == winner
        clear_tile_cache()
        assert tiles_for(spec, 2, 256, 128) == (8, 256, 128)

    def test_autotune_rejects_untiled_backend(self):
        with pytest.raises(ValueError, match="tile"):
            api.autotune(api.CiMExecSpec(formulation="blocked",
                                         backend="jnp"))

    def test_override_context_manager(self):
        """set_shape_class_override returns a handle restoring the
        *previous* value on exit — nested and exception-safe — while the
        historical imperative call keeps working."""
        spec = api.CiMExecSpec(formulation="blocked", backend="pallas",
                               packing="bitplane_u8")
        bm_d, _, _ = tiles_for(spec, 2, 256, 128)
        with set_shape_class_override("prefill"):
            bm_p, _, _ = tiles_for(spec, 2, 256, 128)
            assert bm_p > DECODE_M_MAX
            with set_shape_class_override("decode"):
                bm_n, _, _ = tiles_for(spec, 256, 256, 128)
                assert bm_n <= DECODE_M_MAX
            # inner exit restores the outer override, not None
            bm_back, _, _ = tiles_for(spec, 2, 256, 128)
            assert bm_back == bm_p
        assert tiles_for(spec, 2, 256, 128)[0] == bm_d
        # exception-safe restore
        with pytest.raises(RuntimeError):
            with set_shape_class_override("prefill"):
                raise RuntimeError("boom")
        assert tiles_for(spec, 2, 256, 128)[0] == bm_d
        # imperative style (ignore the handle) still behaves as before
        set_shape_class_override("prefill")
        assert tiles_for(spec, 2, 256, 128)[0] > DECODE_M_MAX
        set_shape_class_override(None)
        assert tiles_for(spec, 2, 256, 128)[0] == bm_d

    def test_tiles_for_thread_safety(self):
        """4 threads hammer tiles_for while the override flips and the
        tile cache is cleared/installed concurrently. Every answer must
        be a legal resolution for *some* instantaneous global state —
        never a torn read, KeyError, or RuntimeError from racing dict
        mutation."""
        import threading

        spec = api.CiMExecSpec(formulation="blocked", backend="pallas",
                               packing="bitplane_u8")
        legal = {tuple(tiles_for(spec, 2, 256, 128)),
                 tuple(tiles_for(spec, 256, 256, 128))}
        errors, stop = [], threading.Event()

        def reader():
            try:
                while not stop.is_set():
                    got = tiles_for(spec, 2, 256, 128)
                    if tuple(got) not in legal:
                        errors.append(f"illegal tiles {got}")
                        return
            except Exception as e:  # noqa: BLE001 — recorded for assert
                errors.append(repr(e))

        def toggler():
            # sole override writer: concurrent overlapping overrides are
            # last-exit-wins by design, so only one thread toggles
            try:
                while not stop.is_set():
                    with set_shape_class_override("prefill"):
                        tiles_for(spec, 2, 256, 128)
            except Exception as e:  # noqa: BLE001 — recorded for assert
                errors.append(repr(e))

        def clearer():
            try:
                while not stop.is_set():
                    clear_tile_cache()
            except Exception as e:  # noqa: BLE001 — recorded for assert
                errors.append(repr(e))

        threads = [threading.Thread(target=reader) for _ in range(2)]
        threads += [threading.Thread(target=toggler),
                    threading.Thread(target=clearer)]
        for t in threads:
            t.start()
        import time as _time

        _time.sleep(0.5)
        stop.set()
        for t in threads:
            t.join(timeout=10)
        assert not errors, errors
        # the toggler's last context-manager exit restored the override
        assert tiles_for(spec, 2, 256, 128)[0] <= DECODE_M_MAX
