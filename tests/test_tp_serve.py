"""Tensor-parallel serving: the multi-device differential harness.

Runs on the 8 virtual host devices the session conftest forces (the
``tp_mesh`` fixture skips when they are absent). The contract pinned
here (DESIGN.md §8):

  * fused TP={1,2,4} greedy decode is **token-identical** to the
    unsharded engine for every serving family (dense / MLA+MoE / SSM /
    hybrid) — in fp mode and in the quantized cim mode (whose ADC event
    counts are integers, so the TP partial-sum all-reduce is exact);
  * ``execute`` / ``execute_packed`` are **bit-equal** under sharded vs
    replicated operands for every registered spec (column/N sharding
    never splits the contraction);
  * ``execute_tp`` (explicit row-parallel shard_map path) is bit-equal
    to ``execute`` — whole ADC blocks per shard — and its
    int8-compressed variant stays inside the quantization error bound;
  * the PR-2 serving invariants survive sharding: jaxpr size of the
    fused step independent of n_slots AND mesh size, and
    host_syncs/decode_steps unchanged by TP;
  * the PR-2 known limit (per-tensor activation scale couples batch
    rows) is **retired** by ``QuantConfig(act_scale="per_row")``
    (DESIGN.md §9): quantized dense rows are bit-identical solo vs
    co-batched, and quantized fused serving is token-identical to
    per-request generate() — the former strict xfail, now passing.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import ternary as tern
from repro.core.execution import (
    CiMExecSpec,
    execute,
    execute_packed,
    execute_tp,
    registered_specs,
)
from repro.dist import sharding as shd
from repro.launch.mesh import make_tp_mesh
from repro.models import transformer as T
from repro.models.layers import QuantConfig, dense
from repro.models.registry import get_config
from repro.serve.engine import ContinuousBatcher, Request

# one smoke arch per serving family (the families the ragged-decode
# contract distinguishes: KV caches, latent MLA caches + MoE, SSM state,
# hybrid ssm+shared-attention)
FAMILY_ARCHS = {
    "dense": "smollm-135m",
    "mla": "deepseek-v2-236b",
    "ssm": "mamba2-780m",
    "hybrid": "zamba2-2.7b",
}

PROMPTS = [[3, 1, 4], [9, 8], [2, 7, 1, 8, 2], [6]]
MAX_NEWS = [4, 5, 3, 4]


def _family_cfg(family, quant=None):
    cfg = get_config(FAMILY_ARCHS[family], smoke=True)
    if family == "mla":
        cfg = cfg.replace(moe_capacity_factor=8.0)  # no smoke-size drops
    if quant is not None:
        cfg = cfg.replace(quant=quant)
    return cfg


def _serve(params, cfg, mesh, **kw):
    b = ContinuousBatcher(params, cfg, n_slots=2, s_max=32, mesh=mesh, **kw)
    reqs = [Request(i, p, max_new=m) for i, (p, m) in
            enumerate(zip(PROMPTS, MAX_NEWS))]
    for r in reqs:
        b.submit(r)
    b.run()
    assert all(r.done for r in reqs)
    return [r.generated for r in reqs], b.stats()


# ---------------------------------------------------------------------------
# Differential decode sweep
# ---------------------------------------------------------------------------


class TestTPTokenIdentity:
    @pytest.mark.parametrize("family", sorted(FAMILY_ARCHS))
    def test_fused_tp_decode_token_identical(self, family, tp_mesh):
        """TP={1,2,4} fused greedy decode == the unsharded engine,
        request by request, token by token (fp mode). The degenerate
        TP=1 mesh (sharding machinery on, nothing actually split) is
        pinned once on the dense family."""
        cfg = _family_cfg(family, QuantConfig(mode="off"))
        params = T.init_params(jax.random.PRNGKey(0), cfg)
        base, base_stats = _serve(params, cfg, None)
        for tp in ((1, 2, 4) if family == "dense" else (2, 4)):
            toks, stats = _serve(params, cfg, make_tp_mesh(tp))
            assert toks == base, (family, tp)
            # host-sync discipline unchanged by TP: still one fetch per
            # fused step / prefill batch, same step count
            assert stats == base_stats, (family, tp)

    def test_quantized_tp_decode_token_identical(self, tp_mesh):
        """cim mode under TP: ADC event counts are integers, the partial
        sums add exactly — quantized TP serving is token-identical too."""
        cfg = _family_cfg("dense")          # registry default: mode="cim"
        assert cfg.quant.mode == "cim"
        params = T.init_params(jax.random.PRNGKey(0), cfg)
        base, base_stats = _serve(params, cfg, None)
        toks, stats = _serve(params, cfg, make_tp_mesh(2))
        assert toks == base and stats == base_stats

    def test_prepared_bitplanes_serve_sharded(self, tp_mesh):
        """prepare_weights under a mesh: the stored 2-bit planes land
        N-sharded on the devices (each device holds only its weight
        shard) and serving from the folded weights stays token-identical
        to the unsharded prepared engine."""
        cfg = _family_cfg("dense")
        params = T.init_params(jax.random.PRNGKey(0), cfg)
        spec = CiMExecSpec(formulation="bitplane", backend="jnp",
                           packing="bitplane_u8")
        kw = dict(exec_spec=spec, prepare_weights=True)
        base, _ = _serve(params, cfg, None, **kw)

        mesh = make_tp_mesh(2)
        b = ContinuousBatcher(params, cfg, n_slots=2, s_max=32, mesh=mesh,
                              **kw)
        assert b.packed
        sharded = 0
        for path, (p1, p2, scale) in b.packed.items():
            ns = p1.sharding
            assert isinstance(ns, NamedSharding), path
            if ns.spec[-1] == "model":
                sharded += 1
                # each device addresses half the plane columns
                shard_shape = ns.shard_shape(p1.shape)
                assert shard_shape[-1] == p1.shape[-1] // 2, path
        assert sharded > 0, "no plane picked up the model axis"
        reqs = [Request(i, p, max_new=m) for i, (p, m) in
                enumerate(zip(PROMPTS, MAX_NEWS))]
        for r in reqs:
            b.submit(r)
        b.run()
        assert [r.generated for r in reqs] == base

    def test_compress_tp_serves_and_differs_in_wire_only(self, tp_mesh):
        """compress_tp=True (int8 TP all-reduce) completes the workload
        with the same serving discipline; tokens may differ from the
        exact engine (documented trade) but stay valid."""
        cfg = _family_cfg("dense")
        params = T.init_params(jax.random.PRNGKey(0), cfg)
        toks, stats = _serve(params, cfg, make_tp_mesh(2), compress_tp=True)
        # the engine scopes the TP-mesh switch to its own calls — nothing
        # leaks into the process after serving
        assert shd.tp_mesh() is None
        _, base_stats = _serve(params, cfg, None)
        assert stats == base_stats
        for t, m in zip(toks, MAX_NEWS):
            assert len(t) == m and all(0 <= x < cfg.vocab for x in t)

    def test_compress_tp_guards(self, tp_mesh):
        cfg = _family_cfg("dense", QuantConfig(mode="off"))
        params = T.init_params(jax.random.PRNGKey(0), cfg)
        with pytest.raises(ValueError, match="quantized"):
            ContinuousBatcher(params, cfg, n_slots=2, s_max=32,
                              mesh=make_tp_mesh(2), compress_tp=True)
        with pytest.raises(ValueError, match="mesh"):
            ContinuousBatcher(params, cfg, n_slots=2, s_max=32,
                              compress_tp=True)
        bad = jax.sharding.Mesh(np.asarray(jax.devices()[:2]), ("x",))
        with pytest.raises(ValueError, match="model"):
            ContinuousBatcher(params, cfg, n_slots=2, s_max=32, mesh=bad)
        # a packed spec without prepare_weights can never engage the
        # compressed route (dense() only routes unpacked MACs) — reject
        # instead of silently serving with exact collectives
        packed_spec = CiMExecSpec(formulation="blocked", backend="jnp",
                                  packing="bitplane_u8")
        with pytest.raises(ValueError, match="prepare_weights"):
            with pytest.warns(UserWarning):  # packed-per-forward warning
                ContinuousBatcher(params, cfg, n_slots=2, s_max=32,
                                  mesh=make_tp_mesh(2), compress_tp=True,
                                  exec_spec=packed_spec)


# ---------------------------------------------------------------------------
# execute / execute_packed under sharded operands
# ---------------------------------------------------------------------------


def _ternary_pair(m=8, k=64, n=32):
    kx, kw, mx, mw = jax.random.split(jax.random.PRNGKey(7), 4)
    x = (jnp.sign(jax.random.normal(kx, (m, k)))
         * (jax.random.uniform(mx, (m, k)) > 0.3)).astype(jnp.float32)
    w = (jnp.sign(jax.random.normal(kw, (k, n)))
         * (jax.random.uniform(mw, (k, n)) > 0.3)).astype(jnp.float32)
    return x, w


class TestShardedExecute:
    def test_execute_bit_equal_sharded_vs_replicated(self, tp_mesh):
        """Every registered (formulation, backend, packing): replicated x
        + N-sharded w == the single-device result, bit for bit (column
        sharding never re-associates the contraction)."""
        mesh = make_tp_mesh(2)
        x, w = _ternary_pair()
        for spec in registered_specs():
            base = np.asarray(execute(spec, x, w))
            xs = jax.device_put(x, NamedSharding(mesh, P()))
            ws = jax.device_put(w, NamedSharding(mesh, P(None, "model")))
            out = np.asarray(execute(spec, xs, ws))
            np.testing.assert_array_equal(base, out, err_msg=spec.name)

    def test_execute_packed_bit_equal_sharded_planes(self, tp_mesh):
        """Stored 2-bit planes sharded along N (the packed_specs layout)
        == replicated planes, bit for bit, for both packed kernels."""
        mesh = make_tp_mesh(2)
        x, w = _ternary_pair()
        p1, p2 = tern.pack_ternary(w.astype(jnp.int8), axis=0)
        ns = NamedSharding(mesh, P(None, "model"))
        for form in ("exact", "blocked"):
            for backend in ("jnp", "pallas"):
                spec = CiMExecSpec(formulation=form, backend=backend,
                                   packing="bitplane_u8")
                base = np.asarray(execute_packed(spec, x, p1, p2))
                out = np.asarray(execute_packed(
                    spec, x, jax.device_put(p1, ns), jax.device_put(p2, ns)))
                np.testing.assert_array_equal(base, out,
                                              err_msg=f"{form}/{backend}")

    def test_execute_tp_bit_equal(self, tp_mesh):
        """Explicit row-parallel shard_map MAC: whole ADC blocks per
        shard -> integer partials -> exact psum -> bit equality, for
        every unpacked jnp formulation at TP=2 and TP=4."""
        x, w = _ternary_pair()
        for form in ("exact", "blocked", "corrected", "bitplane", "fused"):
            spec = CiMExecSpec(formulation=form, backend="jnp")
            base = np.asarray(execute(spec, x, w))
            for tp in (2, 4):
                out = np.asarray(execute_tp(spec, x, w, make_tp_mesh(tp)))
                np.testing.assert_array_equal(base, out,
                                              err_msg=f"{form} tp={tp}")

    def test_execute_tp_rejects_packed_and_noisy(self, tp_mesh):
        x, w = _ternary_pair()
        mesh = make_tp_mesh(2)
        with pytest.raises(ValueError, match="packed|N-sharded"):
            execute_tp(CiMExecSpec(formulation="blocked", backend="jnp",
                                   packing="bitplane_u8"), x, w, mesh)
        with pytest.raises(ValueError, match="error"):
            execute_tp(CiMExecSpec(formulation="blocked", backend="jnp",
                                   error_prob=0.1), x, w, mesh)

    def test_execute_tp_compressed_error_bound(self, tp_mesh):
        """int8-compressed TP all-reduce: per-shard quantization error is
        bounded by (amax/127) per shard, summed over shards."""
        x, w = _ternary_pair(m=16, k=128, n=64)
        spec = CiMExecSpec(formulation="blocked", backend="jnp")
        base = np.asarray(execute(spec, x, w))
        for tp in (2, 4):
            out = np.asarray(execute_tp(spec, x, w, make_tp_mesh(tp),
                                        compressed=True))
            bound = tp * (np.abs(base).max() / 127.0 + 1e-6) * 1.5
            assert np.abs(out - base).max() <= bound, tp


# ---------------------------------------------------------------------------
# Invariant pins (jaxpr size, host syncs)
# ---------------------------------------------------------------------------


class TestTPInvariants:
    def test_jaxpr_size_independent_of_slots_and_mesh(self, tp_mesh):
        """The traced fused step is one batched program: its equation
        count must not grow with the slot count, and sharding is a
        compile-time property — tracing under different TP meshes yields
        the identical program. Migrated to the registered tracing
        contract, whose axes cover the n_slots × tp cross product and
        which additionally enforces the structural serving rules (zero
        host callbacks, no uint8 pads)."""
        from repro.analysis import run_contract

        findings, meta = run_contract("serve.fused_decode_step")
        assert not findings, findings
        # with 8 virtual devices every combo traces live — none skipped
        assert not meta["skipped"], meta
        assert len(meta["eqn_counts"]) == 6, meta

    def test_jaxpr_size_compressed_tp_mesh_independent(self, tp_mesh):
        """Even the explicit shard_map route (compress_tp) traces to the
        same equation count for every mesh size — the collective is one
        primitive regardless of how many devices sit under the axis.
        Checked both at the execute_tp level (registered contract) and
        through the dense() layer route (inline audit_invariance)."""
        from repro.analysis import TraceContract, audit_invariance, run_contract

        findings, meta = run_contract("execution.execute_tp.compressed")
        assert not findings, findings
        assert not meta["skipped"], meta

        x = jnp.ones((4, 64), jnp.float32)
        w = jnp.ones((64, 32), jnp.float32)
        qc = QuantConfig(mode="cim", tp_reduce="int8")

        def build(tp):
            mesh = make_tp_mesh(tp)

            def f(a, b):
                shd.set_tp_mesh(mesh)
                try:
                    return dense(a, b, qc, tp="row")
                finally:
                    shd.set_tp_mesh(None)

            return f, (x, w)

        findings, meta = audit_invariance(
            build, {"tp": (2, 4)},
            contract=TraceContract(max_host_callbacks=0),
            name="tp_serve.dense_row_compressed")
        assert not findings, findings

    def test_host_syncs_per_token_unchanged_by_tp(self, tp_mesh):
        """TP must not add device->host chatter: same decode_steps, same
        host_syncs, for the same workload (already asserted pairwise in
        the sweep; pinned here explicitly as the per-token ratio)."""
        cfg = _family_cfg("dense", QuantConfig(mode="off"))
        params = T.init_params(jax.random.PRNGKey(0), cfg)
        _, s1 = _serve(params, cfg, None)
        _, s2 = _serve(params, cfg, make_tp_mesh(2))
        tokens = sum(MAX_NEWS)
        assert s1["host_syncs"] / tokens == s2["host_syncs"] / tokens
        assert s1 == s2


# ---------------------------------------------------------------------------
# PR-2 caveat retired: per-row activation scales decouple batch rows
# ---------------------------------------------------------------------------


class TestPerRowActScale:
    """The former strict xfail (per-tensor activation scale couples
    co-batched rows), flipped deliberately by ``act_scale="per_row"``
    (DESIGN.md §9)."""

    def _rows(self):
        kx, kw = jax.random.split(jax.random.PRNGKey(3))
        x1 = jax.random.normal(kx, (1, 64), jnp.float32)
        mate = 5.0 * jax.random.normal(jax.random.PRNGKey(9), (1, 64),
                                       jnp.float32)
        w = jax.random.normal(kw, (64, 32), jnp.float32)
        return x1, jnp.concatenate([x1, mate], axis=0), w

    def test_quantized_dense_row_independent_of_batchmates(self):
        """A row's quantized dense() output is bit-identical whether it
        is computed alone or co-batched: per-row thresholds/scales make
        each (.., K) row's quantization a function of that row only."""
        qc = QuantConfig(mode="cim", act_scale="per_row")
        x1, x2, w = self._rows()
        solo = np.asarray(dense(x1, w, qc))[0]
        cobatched = np.asarray(dense(x2, w, qc))[0]
        np.testing.assert_array_equal(solo, cobatched)

    def test_per_tensor_default_still_couples(self):
        """The default per-tensor scale still couples rows (one amax over
        the batch) — the documented trade the per_row option retires; if
        this ever passes, the default granularity silently changed."""
        qc = QuantConfig(mode="cim")
        assert qc.act_scale == "per_tensor"
        x1, x2, w = self._rows()
        solo = np.asarray(dense(x1, w, qc))[0]
        cobatched = np.asarray(dense(x2, w, qc))[0]
        assert bool(np.any(solo != cobatched))

    def test_quantized_fused_serving_token_identical_to_generate(self):
        """The acceptance pin: under act_scale="per_row" the quantized
        (cim) fused batcher serves every request token-identically to
        per-request generate() — heterogeneous co-batched slots,
        left-padded batched prefill and all."""
        from repro.serve.engine import generate

        qc = QuantConfig(mode="cim", act_scale="per_row")
        cfg = _family_cfg("dense", qc)
        params = T.init_params(jax.random.PRNGKey(0), cfg)
        solos = [
            np.asarray(generate(params, jnp.asarray([p], jnp.int32), cfg,
                                max_new=m, s_max=32))[0].tolist()
            for p, m in zip(PROMPTS, MAX_NEWS)
        ]
        toks, _ = _serve(params, cfg, None)
        assert toks == solos
