"""The declarative execution API (repro.api / repro.core.execution).

The core contract: every registered (formulation, backend, packing)
combination is bit-exact against the bitplane circuit oracle
(``site_cim_matmul_bitplane``) on random ternary inputs — including K
not divisible by 16 and batched leading dims — and the deprecated
aliases forward into the same registry.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api
from repro.core import site_cim as sc
from repro.kernels import ops


def rand_ternary(key, shape, p_zero=0.25, dtype=jnp.int32):
    k1, k2 = jax.random.split(key)
    sign = jax.random.choice(k1, jnp.array([-1, 1]), shape)
    keep = jax.random.bernoulli(k2, 1 - p_zero, shape)
    return (sign * keep).astype(dtype)


# (leading dims, K, N): ragged K (not divisible by 16) and batched leads
CASES = [
    ((4,), 45, 7),
    ((2, 3), 64, 16),
    ((5,), 130, 9),
]

ALL_SPECS = list(api.registered_specs())


def _oracle(spec, x, w):
    """Bitplane circuit oracle. Non-clamping formulations compute the
    exact product, which equals the oracle with the clamp never binding
    (adc_max = block: a, b <= block)."""
    adc_max = spec.adc_max if spec.clamps else spec.block
    cfg = sc.SiTeCiMConfig(block=spec.block, adc_max=adc_max)
    return sc.site_cim_matmul_bitplane(x, w, cfg)


class TestEquivalenceSweep:
    @pytest.mark.parametrize("spec", ALL_SPECS, ids=lambda s: s.name)
    @pytest.mark.parametrize("lead,k,n", CASES)
    def test_bit_exact_vs_bitplane_oracle(self, spec, lead, k, n):
        kx, kw = jax.random.split(jax.random.PRNGKey(k * 31 + n))
        x = rand_ternary(kx, lead + (k,), p_zero=0.1)  # low sparsity: clamp binds
        w = rand_ternary(kw, (k, n), p_zero=0.1)
        out = api.execute(spec, x, w)
        expect = _oracle(spec, x, w)
        assert out.shape == lead + (n,)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(expect))

    @pytest.mark.parametrize("spec", ALL_SPECS, ids=lambda s: s.name)
    def test_float_dtype_round_trip(self, spec):
        kx, kw = jax.random.split(jax.random.PRNGKey(5))
        x = rand_ternary(kx, (6, 48), dtype=jnp.float32)
        w = rand_ternary(kw, (48, 10), dtype=jnp.float32)
        out = api.execute(spec, x, w)
        assert out.dtype == jnp.float32
        expect = _oracle(spec, x.astype(jnp.int32), w.astype(jnp.int32))
        np.testing.assert_array_equal(np.asarray(out), np.asarray(expect, np.float32))


class TestSpecAndRegistry:
    def test_spec_validation(self):
        with pytest.raises(ValueError):
            api.CiMExecSpec(formulation="blokced")  # typo dies early
        with pytest.raises(ValueError):
            api.CiMExecSpec(backend="cuda")
        with pytest.raises(ValueError):
            api.CiMExecSpec(packing="int4")
        with pytest.raises(ValueError):
            api.CiMExecSpec(flavor="III")

    def test_auto_backend_resolves(self):
        spec = api.CiMExecSpec(formulation="blocked", backend="auto")
        assert spec.resolve().backend in ("pallas", "jnp")

    def test_unregistered_combination_raises(self):
        spec = api.CiMExecSpec(formulation="bitplane", backend="pallas")
        with pytest.raises(KeyError):
            api.execute(spec, jnp.ones((1, 16)), jnp.ones((16, 1)))

    def test_register_new_formulation_without_touching_call_sites(self):
        """New kernels plug in as one registration; execute() dispatches."""

        def negated(x2, w, spec):
            return -jnp.einsum("mk,kn->mn", x2.astype(jnp.float32),
                               w.astype(jnp.float32))

        api.register_backend("negated/jnp/none", negated, clamps=False)
        try:
            spec = api.CiMExecSpec(formulation="negated", backend="jnp")
            x = jnp.ones((2, 16), jnp.int32)
            w = jnp.ones((16, 3), jnp.int32)
            out = api.execute(spec, x, w)
            np.testing.assert_array_equal(np.asarray(out), -16 * np.ones((2, 3)))
        finally:
            from repro.core import execution as xapi

            del xapi._REGISTRY[("negated", "jnp", "none")]

    def test_register_custom_backend_name(self):
        """backend/packing are open sets too: registered names validate."""

        def doubled(x2, w, spec):
            return 2.0 * jnp.einsum("mk,kn->mn", x2.astype(jnp.float32),
                                    w.astype(jnp.float32))

        api.register_backend("exact/mxu2/none", doubled, clamps=False)
        try:
            spec = api.CiMExecSpec(formulation="exact", backend="mxu2")
            out = api.execute(spec, jnp.ones((1, 16), jnp.int32),
                              jnp.ones((16, 2), jnp.int32))
            np.testing.assert_array_equal(np.asarray(out), [[32, 32]])
        finally:
            from repro.core import execution as xapi

            del xapi._REGISTRY[("exact", "mxu2", "none")]

    def test_error_prob_requires_key(self):
        spec = api.CiMExecSpec(formulation="blocked", backend="jnp", error_prob=0.1)
        with pytest.raises(ValueError):
            api.execute(spec, jnp.ones((1, 16)), jnp.ones((16, 1)))

    def test_sense_error_rejected_for_unclamped_formulations(self):
        """The error channel models the ADC; exact/fused have none."""
        spec = api.CiMExecSpec(formulation="exact", backend="jnp",
                               error_prob=3.1e-3)
        with pytest.raises(ValueError, match="ADC"):
            api.execute(spec, jnp.ones((1, 16)), jnp.ones((16, 1)),
                        key=jax.random.PRNGKey(0))

    def test_serving_rejects_noisy_spec_up_front(self):
        from repro.models.registry import get_config
        from repro.serve.engine import apply_exec_spec

        cfg = get_config("smollm-135m", smoke=True)
        clean = api.CiMExecSpec(formulation="blocked", backend="jnp")
        assert apply_exec_spec(cfg, clean).quant.exec_spec is clean
        noisy = dataclasses.replace(clean, error_prob=3.1e-3)
        with pytest.raises(ValueError):
            apply_exec_spec(cfg, noisy)

    def test_serving_spec_overrides_fp_mode(self):
        """mode="off" short-circuits dense(); apply_exec_spec must
        upgrade the mode so the requested spec actually executes."""
        from repro.models.layers import QuantConfig
        from repro.models.registry import get_config
        from repro.serve.engine import apply_exec_spec

        cfg = get_config("smollm-135m", smoke=True).replace(
            quant=QuantConfig(mode="off"))
        spec = api.CiMExecSpec(formulation="blocked", backend="jnp")
        out = apply_exec_spec(cfg, spec)
        assert out.quant.mode != "off"
        assert out.quant.exec_spec is spec

    def test_dense_threads_sense_error_key(self):
        from repro.models.layers import QuantConfig, dense

        x = jax.random.normal(jax.random.PRNGKey(0), (16, 256))
        w = jax.random.normal(jax.random.PRNGKey(1), (256, 32))
        spec = api.CiMExecSpec(formulation="blocked", backend="jnp",
                               error_prob=3.1e-3)
        qc = QuantConfig(mode="cim", exec_spec=spec)
        with pytest.raises(ValueError):
            dense(x, w, qc)  # no key
        noisy = dense(x, w, qc, key=jax.random.PRNGKey(2))
        clean = dense(x, w, QuantConfig(mode="cim"))
        assert noisy.shape == clean.shape
        assert bool(jnp.any(noisy != clean))  # the channel actually fired

    def test_sense_error_channel_statistics(self):
        kx, kw = jax.random.split(jax.random.PRNGKey(9))
        x = rand_ternary(kx, (64, 256))
        w = rand_ternary(kw, (256, 64))
        clean_spec = api.CiMExecSpec(formulation="blocked", backend="jnp")
        noisy_spec = dataclasses.replace(clean_spec, error_prob=3.1e-3)
        clean = np.asarray(api.execute(clean_spec, x, w))
        noisy = np.asarray(api.execute(noisy_spec, x, w, key=jax.random.PRNGKey(10)))
        rate = (clean != noisy).mean()
        assert 0.2 * 16 * 3.1e-3 < rate < 5 * 16 * 3.1e-3
        assert np.abs(clean - noisy).max() <= 4

    def test_ste_gradients_are_exact_matmul(self):
        kx, kw = jax.random.split(jax.random.PRNGKey(11))
        x = rand_ternary(kx, (8, 64), dtype=jnp.float32)
        w = rand_ternary(kw, (64, 16), dtype=jnp.float32)
        spec = api.CiMExecSpec(formulation="blocked", backend="jnp")
        gx, gw = jax.grad(lambda a, b: api.execute(spec, a, b).sum(),
                          argnums=(0, 1))(x, w)
        np.testing.assert_allclose(np.asarray(gx),
                                   np.asarray(jnp.ones((8, 16)) @ w.T), rtol=1e-5)
        np.testing.assert_allclose(np.asarray(gw),
                                   np.asarray(x.T @ jnp.ones((8, 16))), rtol=1e-5)


class TestExecutePacked:
    """Pre-packed plane fast path: consumes quant.prepare's storage
    format directly, no per-call pack."""

    def _data(self, k=96, n=8):
        kx, kw = jax.random.split(jax.random.PRNGKey(31))
        x = rand_ternary(kx, (2, 3, k), p_zero=0.1)
        t = rand_ternary(kw, (k, n), p_zero=0.1, dtype=jnp.int8)
        from repro.core.ternary import pack_ternary

        p1, p2 = pack_ternary(t, axis=0)
        return x, t.astype(jnp.int32), p1, p2

    @pytest.mark.parametrize("backend", ["jnp", "pallas"])
    @pytest.mark.parametrize("formulation", ["blocked", "exact"])
    def test_matches_dense_weight_path(self, backend, formulation):
        x, t, p1, p2 = self._data()
        spec = api.CiMExecSpec(formulation=formulation, backend=backend,
                               packing="bitplane_u8")
        out = api.execute_packed(spec, x, p1, p2)
        expect = api.execute(spec, x, t)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(expect))

    def test_validation(self):
        x, t, p1, p2 = self._data()
        with pytest.raises(ValueError, match="bitplane_u8"):
            api.execute_packed(
                api.CiMExecSpec(formulation="blocked", backend="jnp"), x, p1, p2)
        spec = api.CiMExecSpec(formulation="blocked", backend="jnp",
                               packing="bitplane_u8")
        with pytest.raises(ValueError, match="mismatch"):
            api.execute_packed(spec, x[..., :88], p1, p2)
        with pytest.raises(ValueError):
            api.execute_packed(
                dataclasses.replace(spec, formulation="bitplane"), x, p1, p2)

    def test_sense_channel(self):
        x, t, p1, p2 = self._data(k=256, n=64)
        spec = api.CiMExecSpec(formulation="blocked", backend="jnp",
                               packing="bitplane_u8", error_prob=3.1e-3)
        with pytest.raises(ValueError):
            api.execute_packed(spec, x, p1, p2)  # no key
        noisy = api.execute_packed(spec, x, p1, p2, key=jax.random.PRNGKey(1))
        clean = api.execute_packed(dataclasses.replace(spec, error_prob=0.0),
                                   x, p1, p2)
        assert bool(jnp.any(noisy != clean))


class TestDeprecatedAliases:
    """Every legacy entry point forwards to the registry."""

    def setup_method(self):
        kx, kw = jax.random.split(jax.random.PRNGKey(21))
        self.x = rand_ternary(kx, (4, 96), p_zero=0.1)
        self.w = rand_ternary(kw, (96, 8), p_zero=0.1)

    def test_site_cim_matmul(self):
        spec = api.CiMExecSpec(formulation="blocked", backend="jnp")
        np.testing.assert_array_equal(
            np.asarray(sc.site_cim_matmul(self.x, self.w)),
            np.asarray(api.execute(spec, self.x, self.w)),
        )

    def test_site_cim_matmul_corrected(self):
        spec = api.CiMExecSpec(formulation="corrected", backend="jnp")
        np.testing.assert_array_equal(
            np.asarray(sc.site_cim_matmul_corrected(self.x, self.w)),
            np.asarray(api.execute(spec, self.x, self.w)),
        )

    def test_nm_ternary_matmul(self):
        np.testing.assert_array_equal(
            np.asarray(sc.nm_ternary_matmul(self.x, self.w)),
            np.asarray(self.x @ self.w),
        )

    def test_ops_cim_matmul(self):
        x = self.x.astype(jnp.float32)
        w = self.w.astype(jnp.float32)
        spec = api.CiMExecSpec(formulation="blocked", backend="auto")
        np.testing.assert_array_equal(
            np.asarray(ops.cim_matmul(x, w)),
            np.asarray(api.execute(spec, x, w)),
        )

    def test_ops_exact_ternary_matmul(self):
        x = self.x.astype(jnp.float32)
        w = self.w.astype(jnp.float32)
        np.testing.assert_array_equal(
            np.asarray(ops.exact_ternary_matmul(x, w, backend="jnp")),
            np.asarray(x @ w),
        )

    def test_alias_nondefault_config_forwards(self):
        cfg = sc.SiTeCiMConfig(block=32, adc_max=4)
        spec = api.CiMExecSpec(formulation="blocked", backend="jnp",
                               block=32, adc_max=4)
        np.testing.assert_array_equal(
            np.asarray(sc.site_cim_matmul(self.x, self.w, cfg)),
            np.asarray(api.execute(spec, self.x, self.w)),
        )


class TestQuantConfigSpec:
    def test_mode_off_has_no_spec(self):
        from repro.models.layers import QuantConfig

        with pytest.raises(ValueError):
            QuantConfig(mode="off").resolved_spec()
        # a spec on an fp config would silently never execute — rejected
        with pytest.raises(ValueError):
            QuantConfig(mode="off",
                        exec_spec=api.CiMExecSpec(formulation="blocked"))

    def test_ste_backward_keeps_operand_dtype_for_exact(self):
        """§Perf A4: exact/fused backward dots stay at activation width
        so TP all-reduce payloads don't double; clamped backward is f32.
        Migrated to the registered tracing contract, with the blocked
        formulation kept as the positive control (the same rule must
        fire there, so the green exact result is not vacuous)."""
        from repro.analysis import TraceContract, audit, run_contract
        from repro.core.execution import _ste_backward_point

        findings, _meta = run_contract("execution.ste_backward.exact")
        assert not findings, findings

        fn, args = _ste_backward_point(formulation="blocked")()
        hits = audit(fn, args,
                     TraceContract(forbid_dtype_shapes=(("float32", (4, 32)),)),
                     name="execution.ste_backward.blocked")
        assert any(f.rule == "forbid-dtype-shape" for f in hits), hits

    def test_mode_ladder_resolves_to_specs(self):
        from repro.models.layers import QuantConfig

        assert QuantConfig(mode="ternary").resolved_spec().formulation == "exact"
        assert QuantConfig(mode="cim").resolved_spec().formulation == "blocked"
        assert QuantConfig(mode="cim", corrected=True).resolved_spec().formulation == "corrected"
        assert QuantConfig(mode="cim_fused").resolved_spec().formulation == "fused"
        qc = QuantConfig(mode="cim", block=32, adc_max=16)
        spec = qc.resolved_spec()
        assert (spec.block, spec.adc_max) == (32, 16)

    def test_explicit_spec_overrides_mode(self):
        from repro.models.layers import QuantConfig

        spec = api.CiMExecSpec(formulation="bitplane", backend="jnp")
        qc = QuantConfig(mode="cim", exec_spec=spec)
        assert qc.resolved_spec() is spec

    def test_dense_routes_through_api(self):
        """dense() under mode="cim" must produce clamped (not exact) MACs."""
        from repro.models.layers import QuantConfig, dense

        x = jnp.ones((1, 32), jnp.float32)          # dense +1s: clamp binds
        w = jnp.ones((32, 1), jnp.float32)
        qc = QuantConfig(mode="cim", quantize_activations=False)
        out = dense(x, w, qc)
        # ternarized w == w; per-block clamp: 2 blocks * 8 = 16 (not 32)
        assert float(out[0, 0]) == pytest.approx(16.0)

    def test_spec_cost_model_mapping(self):
        assert api.spec_design(api.CiMExecSpec(formulation="exact")) == "NM"
        assert api.spec_design(api.CiMExecSpec(formulation="blocked", flavor="I")) == "CiM-I"
        assert api.spec_design(api.CiMExecSpec(formulation="blocked", flavor="II")) == "CiM-II"
        cost = api.spec_cost_summary(api.CiMExecSpec(formulation="blocked"), "8T-SRAM")
        assert cost["design"] == "CiM-I"
        assert cost["mac_pass_ns"] > 0
