"""Trainer: checkpoint round-trip, crash recovery, grad compression,
optimizer correctness."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.pipeline import DataConfig, TokenPipeline
from repro.models.registry import get_config
from repro.optim import compress as gcomp
from repro.optim.adamw import AdamWConfig, clip_by_global_norm, init, update
from repro.optim.schedules import warmup_cosine
from repro.train import checkpoint as ckpt
from repro.train.trainer import FailureInjector, TrainConfig, Trainer


def small_cfg():
    return get_config("smollm-135m", smoke=True)


def make_pipe(cfg, seq=32, gb=4):
    return TokenPipeline(DataConfig(vocab=cfg.vocab, seq_len=seq, global_batch=gb))


class TestAdamW:
    def test_descends_quadratic(self):
        params = {"w": jnp.array([5.0, -3.0])}
        state = init(params)
        cfg = AdamWConfig(lr=0.1, weight_decay=0.0)
        for _ in range(200):
            grads = {"w": 2 * params["w"]}
            params, state, _ = update(cfg, grads, state, params)
        assert float(jnp.abs(params["w"]).max()) < 0.1

    def test_grad_clip(self):
        g = {"a": jnp.full((10,), 100.0)}
        clipped, norm = clip_by_global_norm(g, 1.0)
        assert float(norm) > 100
        assert np.isclose(float(jnp.linalg.norm(clipped["a"])), 1.0, rtol=1e-5)

    def test_schedule_shape(self):
        f = warmup_cosine(10, 100)
        assert float(f(jnp.int32(0))) == 0.0
        assert float(f(jnp.int32(10))) == pytest.approx(1.0)
        assert float(f(jnp.int32(100))) == pytest.approx(0.1, abs=1e-3)


class TestCheckpoint:
    def test_roundtrip_bf16(self):
        tree = {"a": jnp.arange(6, dtype=jnp.bfloat16).reshape(2, 3),
                "b": {"c": jnp.float32(3.5), "d": jnp.arange(4, dtype=jnp.int32)}}
        with tempfile.TemporaryDirectory() as d:
            ckpt.save(d, 7, tree)
            out, step = ckpt.restore(d, tree)
            assert step == 7
            for k, (x, y) in enumerate(zip(jax.tree.leaves(tree), jax.tree.leaves(out))):
                np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
                assert x.dtype == y.dtype

    def test_two_phase_commit_and_latest(self):
        tree = {"a": jnp.zeros((4,))}
        with tempfile.TemporaryDirectory() as d:
            ckpt.save(d, 1, tree)
            ckpt.save(d, 2, tree)
            assert ckpt.latest_step(d) == 2
            assert not any(p.endswith(".tmp") for p in os.listdir(d))

    def test_gc_old(self):
        tree = {"a": jnp.zeros((4,))}
        with tempfile.TemporaryDirectory() as d:
            for s in range(5):
                ckpt.save(d, s, tree)
            ckpt.gc_old(d, keep_last_n=2)
            steps = sorted(p for p in os.listdir(d) if p.startswith("step_"))
            assert len(steps) == 2

    def test_async_save(self):
        tree = {"a": jnp.ones((8,))}
        with tempfile.TemporaryDirectory() as d:
            fut = ckpt.save(d, 3, tree, async_=True)
            fut.result()
            out, step = ckpt.restore(d, tree)
            assert step == 3


class TestTrainerFaultTolerance:
    def test_failover_resumes_from_checkpoint(self):
        cfg = small_cfg()
        pipe = make_pipe(cfg)
        with tempfile.TemporaryDirectory() as d:
            tr = Trainer(cfg, AdamWConfig(lr=1e-3),
                         TrainConfig(num_steps=8, ckpt_dir=d, ckpt_every=3, log_every=0),
                         pipe, failure_injector=FailureInjector([5]))
            log = tr.run()
            assert tr.restarts == 1
            steps = [m["step"] for m in log]
            assert 5 in steps and steps[-1] == 7
            # step 3..4 replayed exactly once after recovery at ckpt step 3
            assert ckpt.latest_step(d) == 8

    def test_too_many_failures_raises(self):
        cfg = small_cfg()
        pipe = make_pipe(cfg)
        with tempfile.TemporaryDirectory() as d:
            tr = Trainer(cfg, AdamWConfig(), TrainConfig(num_steps=6, ckpt_dir=d,
                         ckpt_every=2, log_every=0, max_restarts=1), pipe,
                         failure_injector=FailureInjector([2, 3]))
            tr.failure_injector.fired = set()  # allow both to fire
            tr.failure_injector.fail_at = {2, 3}
            # first failure recovers, second exceeds max_restarts... but the
            # injector fires each step only once; re-arm to force repeats
            class Always:
                def __init__(self): self.count = 0
                def maybe_fail(self, step):
                    if step == 2 and self.count < 3:
                        self.count += 1
                        raise RuntimeError("boom")
            tr.failure_injector = Always()
            with pytest.raises(RuntimeError):
                tr.run()

    def test_resume_across_trainer_instances(self):
        cfg = small_cfg()
        pipe = make_pipe(cfg)
        with tempfile.TemporaryDirectory() as d:
            t1 = Trainer(cfg, AdamWConfig(lr=1e-3),
                         TrainConfig(num_steps=4, ckpt_dir=d, ckpt_every=2, log_every=0), pipe)
            t1.run()
            t2 = Trainer(cfg, AdamWConfig(lr=1e-3),
                         TrainConfig(num_steps=6, ckpt_dir=d, ckpt_every=2, log_every=0), pipe)
            assert t2.start_step == 4  # picked up the committed checkpoint
            log = t2.run()
            assert log[-1]["step"] == 5


class TestGradCompression:
    def test_int8_unbiased_roundtrip(self):
        g = jax.random.normal(jax.random.PRNGKey(0), (1000,))
        keys = jax.random.split(jax.random.PRNGKey(1), 64)
        decs = jnp.stack([gcomp.decode_int8(gcomp.encode_int8(g, k)) for k in keys])
        bias = jnp.abs(decs.mean(0) - g).max()
        amax = float(jnp.abs(g).max())
        assert float(bias) < 0.05 * amax  # stochastic rounding ~unbiased

    def test_error_feedback_reduces_drift(self):
        grads = {"w": jax.random.normal(jax.random.PRNGKey(2), (512,))}
        res = gcomp.init_residual(grads)
        total_dec = jnp.zeros((512,))
        total_g = jnp.zeros((512,))
        for i in range(32):
            key = jax.random.PRNGKey(i)
            dec, res = gcomp.compress_grads(grads, "int8", key, res)
            total_dec = total_dec + dec["w"]
            total_g = total_g + grads["w"]
        # cumulative compressed updates track cumulative true gradient
        rel = float(jnp.linalg.norm(total_dec - total_g) / jnp.linalg.norm(total_g))
        assert rel < 0.02

    def test_bf16_mode(self):
        grads = {"w": jnp.ones((16,)) * 1.2345678}
        dec, _ = gcomp.compress_grads(grads, "bf16")
        assert float(jnp.abs(dec["w"] - grads["w"]).max()) < 0.01

    def test_trainer_with_compression_trains(self):
        cfg = small_cfg()
        pipe = make_pipe(cfg)
        tr = Trainer(cfg, AdamWConfig(lr=1e-3),
                     TrainConfig(num_steps=3, log_every=0, grad_compression="int8"), pipe)
        log = tr.run()
        assert all(np.isfinite(m["loss"]) for m in log)


class TestData:
    def test_deterministic_and_seekable(self):
        cfg = small_cfg()
        p1 = make_pipe(cfg)
        p2 = make_pipe(cfg)
        b1 = p1.batch(17)
        b2 = p2.batch(17)
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])

    def test_host_slices_partition_batch(self):
        cfg = small_cfg()
        p = make_pipe(cfg, gb=8)
        full = p.batch(3)["tokens"]
        parts = [p.host_slice(3, h, 4)["tokens"] for h in range(4)]
        np.testing.assert_array_equal(np.concatenate(parts), full)

    def test_labels_are_shifted_tokens(self):
        cfg = small_cfg()
        p = make_pipe(cfg)
        b = p.batch(0)
        # tokens[t+1] == labels[t] by construction
        np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])
