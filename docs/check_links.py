"""Intra-repo markdown link checker (stdlib-only; the CI docs job runs it).

Scans every tracked ``*.md`` under the repo root, extracts inline
markdown links, and verifies:

  * relative-path targets exist on disk;
  * ``#anchor`` fragments (bare or on an ``.md`` target) resolve to a
    heading in the target file, using GitHub's slug rules (lowercase,
    spaces -> dashes, punctuation dropped);

External links (``http://``, ``https://``, ``mailto:``) are ignored —
this gate is about the repo's own docs not rotting.

Usage:
    python docs/check_links.py [ROOT]     # exit 1 + report on dead links
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

LINK_RE = re.compile(r"(?<!\!)\[[^\]]*\]\(([^)\s]+)\)")
HEADING_RE = re.compile(r"^#{1,6}\s+(.*)$", re.MULTILINE)
CODE_FENCE_RE = re.compile(r"^```.*?^```", re.MULTILINE | re.DOTALL)
SKIP_DIRS = {".git", "__pycache__", ".pytest_cache", "node_modules"}


def github_slug(heading: str) -> str:
    """GitHub's anchor slug for a heading: strip markdown emphasis/code
    ticks, lowercase, drop everything but word chars/spaces/dashes,
    spaces to dashes."""
    text = re.sub(r"[*_`]", "", heading.strip())
    text = re.sub(r"[^\w\- ]", "", text, flags=re.UNICODE)
    return text.lower().replace(" ", "-")


def heading_slugs(md_text: str) -> set:
    """All anchor slugs a markdown file exposes (fences excluded so a
    ``# comment`` inside a code block is not a heading)."""
    text = CODE_FENCE_RE.sub("", md_text)
    slugs = set()
    counts: dict = {}
    for m in HEADING_RE.finditer(text):
        slug = github_slug(m.group(1))
        n = counts.get(slug, 0)
        counts[slug] = n + 1
        slugs.add(slug if n == 0 else f"{slug}-{n}")
    return slugs


def markdown_files(root: Path):
    for path in sorted(root.rglob("*.md")):
        if not any(part in SKIP_DIRS for part in path.parts):
            yield path


def check(root: Path):
    """Return a list of ``(file, link, reason)`` problems."""
    problems = []
    slug_cache = {}

    def slugs_of(path: Path) -> set:
        if path not in slug_cache:
            slug_cache[path] = heading_slugs(path.read_text())
        return slug_cache[path]

    for md in markdown_files(root):
        text = CODE_FENCE_RE.sub("", md.read_text())
        for m in LINK_RE.finditer(text):
            link = m.group(1)
            if link.startswith(("http://", "https://", "mailto:")):
                continue
            target, _, anchor = link.partition("#")
            if target:
                dest = (md.parent / target).resolve()
                if not dest.exists():
                    problems.append((md, link, f"missing file {target}"))
                    continue
            else:
                dest = md
            if anchor:
                if dest.suffix != ".md" or dest.is_dir():
                    continue  # anchors into non-markdown: not checkable
                if anchor not in slugs_of(dest):
                    problems.append(
                        (md, link,
                         f"no heading for #{anchor} in {dest.name}"))
    return problems


def main(argv=None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    root = Path(argv[0]) if argv else Path(__file__).resolve().parents[1]
    problems = check(root)
    n_files = sum(1 for _ in markdown_files(root))
    if problems:
        for md, link, reason in problems:
            print(f"{md.relative_to(root)}: ({link}) -> {reason}")
        print(f"[check_links] {len(problems)} dead link(s) in {n_files} files")
        return 1
    print(f"[check_links] ok: {n_files} markdown files, no dead intra-repo links")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
