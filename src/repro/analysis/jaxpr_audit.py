"""The jaxpr auditor: trace a function, walk every equation recursively
(through pjit, scan, cond, custom_vjp, shard_map, pallas_call), and
check the declarative :class:`~repro.analysis.contracts.TraceContract`
rules against the program — plus equation-count invariance across the
registered configuration axes (re-trace per axis value, assert one
single count).

Findings are plain data (rule id, severity, stable message) so the CLI
report is byte-reproducible: messages embed only primitive names,
dtypes, shapes and counts — never object ids, jaxpr variable names, or
anything that varies between interpreter runs.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Any, Dict, Iterator, List, Optional, Tuple

import jax

from repro.analysis.contracts import (
    PrimRule,
    SkipTrace,
    TraceContract,
    TracePoint,
    get_trace_contract,
)

#: primitives that call back into the host from inside a traced program
HOST_CALLBACK_PRIMS = frozenset(
    {"pure_callback", "io_callback", "debug_callback", "outside_call"}
)


@dataclasses.dataclass(frozen=True, order=True)
class Finding:
    """One rule violation. ``where`` is a contract name (jaxpr engine)
    or a repo-relative ``path:line`` (lint engine)."""

    severity: str
    engine: str
    rule: str
    where: str
    message: str

    def to_dict(self) -> Dict[str, str]:
        return dataclasses.asdict(self)


# ---------------------------------------------------------------------------
# Recursive equation walk
# ---------------------------------------------------------------------------


def _sub_jaxprs(eqn) -> Iterator[Any]:
    """Every nested jaxpr hiding in an equation's params — pjit/scan
    carry ClosedJaxprs ("jaxpr"), cond a tuple of branches, custom_vjp
    a "call_jaxpr", pallas_call a raw Jaxpr body."""
    for val in eqn.params.values():
        items = val if isinstance(val, (list, tuple)) else (val,)
        for item in items:
            if hasattr(item, "eqns"):  # Jaxpr
                yield item
            elif hasattr(item, "jaxpr"):  # ClosedJaxpr
                yield item.jaxpr


def iter_eqns(jaxpr, within: Tuple[str, ...] = ()) -> Iterator[Tuple[Any, Tuple[str, ...]]]:
    """Yield ``(eqn, within)`` for every equation, depth-first;
    ``within`` is the stack of enclosing primitive names (empty at the
    top level)."""
    for eqn in jaxpr.eqns:
        yield eqn, within
        inner = within + (eqn.primitive.name,)
        for sub in _sub_jaxprs(eqn):
            yield from iter_eqns(sub, inner)


def total_eqns(closed) -> int:
    """Recursive equation count — the invariance metric. Stricter than
    the historical ``len(closed.jaxpr.eqns)``: growth hidden inside a
    pjit/scan body counts too."""
    jaxpr = getattr(closed, "jaxpr", closed)
    return sum(1 for _ in iter_eqns(jaxpr))


# ---------------------------------------------------------------------------
# Structural rule checks
# ---------------------------------------------------------------------------


def _dtype_name(dt) -> str:
    """Canonical dtype name whether ``dt`` is a np.dtype, a jnp scalar
    type, or a string."""
    import numpy as np

    try:
        return str(np.dtype(dt))
    except TypeError:
        return str(dt)


def _aval_str(v) -> str:
    aval = getattr(v, "aval", None)
    if aval is None or not hasattr(aval, "dtype"):
        return "?"
    return f"{aval.dtype}{list(aval.shape)}"


def _scope_ok(rule: PrimRule, within: Tuple[str, ...]) -> bool:
    if rule.within is None:
        return True
    if rule.within == "top":
        return not within
    return rule.within in within


def check_jaxpr(closed, contract: TraceContract, where: str) -> List[Finding]:
    """Run every structural rule of ``contract`` over one traced
    program. Returns deduplicated, deterministic findings."""
    jaxpr = getattr(closed, "jaxpr", closed)
    found: List[Finding] = []

    def emit(rule: str, message: str, severity: str = "P1") -> None:
        found.append(Finding(severity=severity, engine="jaxpr", rule=rule,
                             where=where, message=message))

    callbacks = 0
    pinned = {name: 0 for name, _ in contract.pin_prims}
    for eqn, within in iter_eqns(jaxpr):
        prim = eqn.primitive.name
        if prim in HOST_CALLBACK_PRIMS:
            callbacks += 1
        if prim in pinned:
            pinned[prim] += 1
        if contract.no_pad_on_dtypes and prim == "pad":
            hits = [_aval_str(v) for v in eqn.invars
                    if str(getattr(getattr(v, "aval", None), "dtype", ""))
                    in contract.no_pad_on_dtypes]
            for h in hits:
                emit("pad-on-dtype",
                     f"pad on {h} operand (depth {list(within)}) — "
                     f"forbidden dtypes {list(contract.no_pad_on_dtypes)}")
        if contract.accum_dtype and prim == "dot_general" and "pallas_call" in within:
            pref = eqn.params.get("preferred_element_type")
            got = _dtype_name(pref) if pref is not None else str(eqn.invars[0].aval.dtype)
            if got != contract.accum_dtype:
                emit("accum-dtype",
                     f"dot_general inside pallas_call accumulates in "
                     f"{got}, contract requires {contract.accum_dtype} "
                     f"(operands {[_aval_str(v) for v in eqn.invars]})")
        for rule in contract.forbid_prims:
            if rule.prim is not None and prim != rule.prim:
                continue
            if not _scope_ok(rule, within):
                continue
            if rule.when is not None and not rule.when(eqn):
                continue
            emit(rule.rule,
                 f"forbidden {prim} (depth {list(within)}, operands "
                 f"{[_aval_str(v) for v in eqn.invars]})"
                 + (f": {rule.reason}" if rule.reason else ""))
        for dtype_name, shape in contract.forbid_dtype_shapes:
            for v in eqn.outvars:
                aval = getattr(v, "aval", None)
                if aval is None or not hasattr(aval, "dtype"):
                    continue
                if str(aval.dtype) == dtype_name and tuple(aval.shape) == tuple(shape):
                    emit("forbid-dtype-shape",
                         f"{prim} produces {dtype_name}{list(shape)} "
                         f"(depth {list(within)}) — forbidden by contract")
    if contract.max_host_callbacks is not None and callbacks > contract.max_host_callbacks:
        emit("max-host-callbacks",
             f"{callbacks} host callback(s) in the traced program, "
             f"contract allows {contract.max_host_callbacks} — host "
             f"chatter inside the step breaks the one-fetch-per-step "
             f"serving discipline")
    n = total_eqns(jaxpr)
    if contract.max_eqns is not None and n > contract.max_eqns:
        emit("max-eqns", f"{n} equations > contract cap {contract.max_eqns}")
    for prim_name, expect in contract.pin_prims:
        if pinned[prim_name] != expect:
            emit("prim-count",
                 f"{pinned[prim_name]} {prim_name} eqn(s) in the traced "
                 f"program, contract pins exactly {expect} — the DMA/"
                 f"prefetch structure this count encodes has changed")
    # dedupe (identical sub-jaxprs can repeat a message) keeping order
    seen, unique = set(), []
    for f in found:
        if f not in seen:
            seen.add(f)
            unique.append(f)
    return unique


# ---------------------------------------------------------------------------
# Entry points
# ---------------------------------------------------------------------------


def audit(fn, args: tuple, contract: TraceContract, *, name: str = "<adhoc>") -> List[Finding]:
    """Trace ``fn(*args)`` with ``jax.make_jaxpr`` and check
    ``contract``'s structural rules. The direct, test-friendly entry
    point; registered contracts add the invariance axes on top
    (:func:`run_contract`)."""
    closed = jax.make_jaxpr(fn)(*args)
    return check_jaxpr(closed, contract, name)


def audit_invariance(
    build,
    axes: Dict[str, Tuple[Any, ...]],
    *,
    contract: Optional[TraceContract] = None,
    name: str = "<adhoc>",
) -> Tuple[List[Finding], Dict[str, Any]]:
    """Re-trace ``build(**combo)`` over the cross product of ``axes``
    and require a single recursive equation count; additionally run
    ``contract``'s structural rules (when given) on every variant.

    Returns ``(findings, meta)`` with ``meta["eqn_counts"]`` mapping
    the axis combo (as a stable string) to its count and
    ``meta["skipped"]`` listing combos a builder refused
    (:class:`SkipTrace`)."""
    contract = contract or TraceContract()
    findings: List[Finding] = []
    counts: Dict[str, int] = {}
    skipped: List[str] = []
    axis_names = sorted(axes)
    combos = list(itertools.product(*(axes[a] for a in axis_names))) or [()]
    for combo in combos:
        kv = dict(zip(axis_names, combo))
        label = ",".join(f"{k}={v}" for k, v in kv.items()) or "-"
        try:
            fn, args = build(**kv)
        except SkipTrace as e:
            skipped.append(f"{label}: {e}")
            continue
        closed = jax.make_jaxpr(fn)(*args)
        counts[label] = total_eqns(closed)
        findings.extend(check_jaxpr(closed, contract, name))
    if len(set(counts.values())) > 1:
        findings.append(Finding(
            severity="P1", engine="jaxpr", rule="eqn-count-variant",
            where=name,
            message=(
                "traced equation count varies with "
                f"{axis_names}: { {k: counts[k] for k in sorted(counts)} } "
                "— the program must stay one fixed batched trace "
                "(per-slot/per-shard python work is leaking into the jaxpr)"
            ),
        ))
    # dedupe across variants
    seen, unique = set(), []
    for f in findings:
        if f not in seen:
            seen.add(f)
            unique.append(f)
    meta = {"eqn_counts": {k: counts[k] for k in sorted(counts)},
            "skipped": sorted(skipped)}
    return unique, meta


def run_contract(point_or_name) -> Tuple[List[Finding], Dict[str, Any]]:
    """Run one registered :class:`TracePoint` (by object or name):
    structural rules on every axis combination plus equation-count
    invariance. The unit the CLI iterates and the migrated tests call."""
    point: TracePoint = (
        point_or_name if isinstance(point_or_name, TracePoint)
        else get_trace_contract(point_or_name)
    )
    return audit_invariance(point.build, dict(point.axes),
                            contract=point.contract, name=point.name)
