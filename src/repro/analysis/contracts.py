"""Declarative tracing contracts — the vocabulary and the registry.

A :class:`TraceContract` names the invariants one traced entry point
must satisfy (DESIGN.md §10): how many host callbacks its jaxpr may
contain, which dtypes must never be padded, which primitives are
forbidden (optionally only inside / outside Pallas kernel bodies), what
dtype Pallas dot accumulation must use, and which configuration axes
the equation count must be *invariant* to (the "one batched program"
serving discipline — jaxpr size independent of ``n_slots`` and mesh
size).

Contracts are declared **at the definition site**: ``serve/engine.py``,
``core/execution.py`` and ``kernels/packed_mac.py`` each call
:func:`register_trace_contract` next to the code whose discipline the
contract pins. One registry then drives three consumers —

  * the jaxpr auditor (``repro.analysis.jaxpr_audit.run_contract``),
  * the migrated invariant tests (tests/test_serve.py et al.), and
  * the ``python -m repro.analysis`` CLI / CI ratchet.

This module is deliberately dependency-free (no jax import): importing
it from kernel/serving modules at definition time costs nothing, and
builders defer every heavy import until the auditor actually runs them.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Any, Callable, Dict, Mapping, Optional, Tuple

#: severity ladder: P1 = contract violation / correctness-adjacent,
#: P2 = performance or tracing hazard, P3 = hygiene / informational
SEVERITIES = ("P1", "P2", "P3")


@dataclasses.dataclass(frozen=True)
class PrimRule:
    """Forbid (occurrences of) one primitive, optionally predicated.

    rule:   stable rule id for reports/baselines (kebab-case).
    prim:   primitive name to match (``"pad"``, ``"pallas_call"`` …);
            ``None`` matches every equation (predicate-only rules).
    within: ``None`` = anywhere; ``"pallas_call"`` (or any primitive
            name) = only inside that enclosing primitive's body;
            ``"top"`` = only outside every sub-jaxpr.
    when:   optional ``eqn -> bool`` refinement; the rule fires only
            where it returns True. Keep predicates pure functions of
            the equation (dtypes/shapes/params) so findings are
            deterministic across runs.
    reason: one line shown in the finding message.
    """

    rule: str
    prim: Optional[str] = None
    within: Optional[str] = None
    when: Optional[Callable[[Any], bool]] = None
    reason: str = ""


def forbid_convert(
    *,
    from_kinds: Tuple[str, ...] = ("int",),
    to: Tuple[str, ...] = ("float32", "float64"),
    within: Optional[str] = "pallas_call",
    rule: str = "no-f32-event-promotion",
    reason: str = "integer ADC event counts must stay integer",
) -> PrimRule:
    """A :class:`PrimRule` forbidding ``convert_element_type`` from an
    integer (or listed-kind) dtype to the listed float dtypes — the
    regression class where int8/int32 ADC event counts get silently
    promoted to f32 (cf. the sensing-error channel in RRAM ternary
    TNNs, Laborieux et al.). Default scope: inside Pallas kernel
    bodies, where the decode path's int32 accumulation contract lives.
    """

    def _is_kind(name: str, kinds) -> bool:
        for kind in kinds:
            # kind "int" covers every signed/unsigned width — both are
            # integer event carriers
            if kind == "int" and name.startswith(("int", "uint")):
                return True
            if name == kind:
                return True
        return False

    def _when(eqn) -> bool:
        new = str(eqn.params.get("new_dtype", ""))
        if new not in to:
            return False
        src = [str(v.aval.dtype) for v in eqn.invars
               if getattr(v, "aval", None) is not None]
        return any(_is_kind(d, from_kinds) for d in src)

    return PrimRule(
        rule=rule, prim="convert_element_type", within=within, when=_when,
        reason=reason,
    )


@dataclasses.dataclass(frozen=True)
class TraceContract:
    """The declarative rule set checked against one traced jaxpr.

    max_host_callbacks: cap on host-callback primitives
      (pure/io/debug callbacks) anywhere in the program — the fused
      decode step pins 0: its single host fetch happens *outside* the
      jitted step (DESIGN.md §6).
    no_pad_on_dtypes: dtype names whose operands must never be padded
      (``("uint8",)`` = the stored 2-bit planes enter kernels in their
      canonical layout, zero per-step relayout — DESIGN.md §9).
    forbid_prims: tuple of :class:`PrimRule`.
    forbid_dtype_shapes: ``((dtype_name, shape), ...)`` — no equation
      may *produce* an aval matching one of these (the §Perf A4
      operand-dtype backward pin).
    accum_dtype: every ``dot_general`` inside a Pallas kernel body must
      accumulate (``preferred_element_type``) in exactly this dtype.
    max_eqns: optional hard cap on the recursive equation count.
    pin_prims: ``((prim_name, exact_count), ...)`` — the recursive
      equation walk must contain *exactly* this many equations of each
      named primitive. This is how the streaming decode contract pins
      its DMA structure (``dma_start``/``dma_wait`` counts): the counts
      depend on the kernel's buffer rotation, not on grid size, so a
      kernel that stops prefetching (or starts blocking per tile)
      changes the pinned count before any benchmark notices.

    Equation-count *invariance* axes live on the :class:`TracePoint`
    (they parameterize the builder, not the rule set).
    """

    max_host_callbacks: Optional[int] = None
    no_pad_on_dtypes: Tuple[str, ...] = ()
    forbid_prims: Tuple[PrimRule, ...] = ()
    forbid_dtype_shapes: Tuple[Tuple[str, Tuple[int, ...]], ...] = ()
    accum_dtype: Optional[str] = None
    max_eqns: Optional[int] = None
    pin_prims: Tuple[Tuple[str, int], ...] = ()


class SkipTrace(Exception):
    """Raised by a builder when one axis combination cannot run here
    (e.g. a 4-way mesh on a 1-device host). Recorded as a skip in the
    run metadata — never a finding, never silently dropped."""


@dataclasses.dataclass(frozen=True)
class TracePoint:
    """A registered audit target: ``build(**axes)`` returns ``(fn,
    args)`` for ``jax.make_jaxpr``; ``axes`` maps axis name to the
    values swept for equation-count invariance (the auditor traces the
    full cross product and requires one single count)."""

    name: str
    build: Callable[..., Tuple[Callable, tuple]]
    contract: TraceContract
    axes: Mapping[str, Tuple[Any, ...]] = dataclasses.field(default_factory=dict)


_TRACE_REGISTRY: Dict[str, TracePoint] = {}

#: modules whose import populates the registry — the definition sites.
#: The CLI and the reproducibility test import these; adding a new
#: contract-bearing module means adding it here.
DEFAULT_CONTRACT_MODULES = (
    "repro.core.execution",
    "repro.kernels.packed_mac",
    "repro.serve.engine",
    "repro.serve.frontdoor.worker",
    "repro.profile.trace",
)


def register_trace_contract(
    name: str,
    build: Callable[..., Tuple[Callable, tuple]],
    contract: TraceContract,
    *,
    axes: Optional[Mapping[str, Tuple[Any, ...]]] = None,
) -> TracePoint:
    """Register ``name`` as an auditable trace point. Idempotent per
    name (module reloads overwrite); names are dotted, rooted at the
    defining package (``"serve.fused_decode_step"``)."""
    point = TracePoint(name=name, build=build, contract=contract,
                       axes=dict(axes or {}))
    _TRACE_REGISTRY[name] = point
    return point


def get_trace_contract(name: str) -> TracePoint:
    load_default_contracts()
    try:
        return _TRACE_REGISTRY[name]
    except KeyError:
        known = ", ".join(sorted(_TRACE_REGISTRY))
        raise KeyError(f"no trace contract {name!r} (known: {known})") from None


def registered_trace_contracts() -> Tuple[TracePoint, ...]:
    """Every registered point, sorted by name (deterministic reports)."""
    load_default_contracts()
    return tuple(_TRACE_REGISTRY[k] for k in sorted(_TRACE_REGISTRY))


def load_default_contracts() -> None:
    """Import the definition-site modules so their registrations run."""
    for mod in DEFAULT_CONTRACT_MODULES:
        importlib.import_module(mod)
