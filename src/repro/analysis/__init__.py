"""repro.analysis — static analysis for the tracing contracts that keep
the serving fast paths honest (DESIGN.md §10).

Two engines share one declarative vocabulary:

  * the **jaxpr auditor** (:mod:`repro.analysis.jaxpr_audit`) traces a
    function and checks :class:`TraceContract` rules — host-callback
    caps, pad-free dtypes, forbidden primitives, Pallas accumulation
    dtypes, equation-count invariance across config axes;
  * the **source linter** (:mod:`repro.analysis.lint`) flags host-sync
    idioms, tracer branching, static-arg hazards and unregistered
    dataclasses in jit-reachable code.

Contracts are registered at their definition sites
(``core/execution.py``, ``kernels/packed_mac.py``, ``serve/engine.py``)
and drive the tests, the ``python -m repro.analysis`` CLI, and the
``ANALYSIS_baseline.json`` CI ratchet alike.
"""
from repro.analysis.contracts import (
    PrimRule,
    SkipTrace,
    TraceContract,
    TracePoint,
    forbid_convert,
    get_trace_contract,
    register_trace_contract,
    registered_trace_contracts,
)
from repro.analysis.jaxpr_audit import (
    Finding,
    audit,
    audit_invariance,
    check_jaxpr,
    iter_eqns,
    run_contract,
    total_eqns,
)
from repro.analysis.lint import lint_paths, lint_source
from repro.analysis.report import build_report, diff_against_baseline

__all__ = [
    "Finding",
    "PrimRule",
    "SkipTrace",
    "TraceContract",
    "TracePoint",
    "audit",
    "audit_invariance",
    "build_report",
    "check_jaxpr",
    "diff_against_baseline",
    "forbid_convert",
    "get_trace_contract",
    "iter_eqns",
    "lint_paths",
    "lint_source",
    "register_trace_contract",
    "registered_trace_contracts",
    "run_contract",
    "total_eqns",
]
