"""``python -m repro.analysis`` — see repro.analysis.report.

The mesh-size invariance axes of the serving contracts re-trace under
2- and 4-way TP meshes, so the CLI forces virtual host devices *before*
the first jax import (same bootstrap discipline as ``launch.serve``;
jax locks the device count at first init). Keeps the report identical
between a laptop run and the CI job."""
import os
import sys

os.environ.setdefault("JAX_PLATFORMS", "cpu")
_flags = os.environ.get("XLA_FLAGS", "")
if "xla_force_host_platform_device_count" not in _flags:
    os.environ["XLA_FLAGS"] = (
        _flags + " --xla_force_host_platform_device_count=8"
    ).strip()

from repro.analysis.report import main  # noqa: E402

if __name__ == "__main__":
    sys.exit(main())
