"""Report assembly, the baseline ratchet, and the CLI behind
``python -m repro.analysis``.

The report is deterministic by construction (sorted findings, stable
messages, no timestamps): running the CLI twice on the same tree
produces byte-identical JSON, and ``ANALYSIS_baseline.json`` is exactly
the canonical serialization of the current findings. ``--check`` is the
CI gate — any finding not in the baseline fails (regression), and any
baseline entry no longer found also fails (the ratchet must shrink:
rerun with ``--write-baseline`` and commit the smaller file).
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path
from typing import Any, Dict, List, Optional, Tuple

from repro.analysis.jaxpr_audit import Finding

BASELINE_NAME = "ANALYSIS_baseline.json"


def repo_root(start: Optional[Path] = None) -> Path:
    """Nearest ancestor holding src/repro (the tree the lint walks and
    the baseline lives in)."""
    p = Path(start or __file__).resolve()
    for parent in (p, *p.parents):
        if (parent / "src" / "repro").is_dir():
            return parent
    raise FileNotFoundError("no src/repro above " + str(p))


def build_report(
    root: Optional[Path] = None, *, lint: bool = True, audit: bool = True
) -> Dict[str, Any]:
    """Run both engines and assemble the full report: sorted findings,
    per-severity/per-rule summary, and per-contract metadata (equation
    counts per axis combination, skipped combos)."""
    from repro.analysis import contracts as C
    from repro.analysis import jaxpr_audit as J
    from repro.analysis import lint as L

    root = Path(root) if root else repo_root()
    findings: List[Finding] = []
    contract_meta: Dict[str, Any] = {}
    if lint:
        findings.extend(L.lint_paths(root))
        findings.extend(L.docstring_findings(root))
    if audit:
        for point in C.registered_trace_contracts():
            f, meta = J.run_contract(point)
            findings.extend(f)
            contract_meta[point.name] = meta
    findings = sorted(set(findings))
    summary: Dict[str, Any] = {
        "total": len(findings),
        "by_severity": {},
        "by_rule": {},
    }
    for f in findings:
        summary["by_severity"][f.severity] = summary["by_severity"].get(f.severity, 0) + 1
        summary["by_rule"][f.rule] = summary["by_rule"].get(f.rule, 0) + 1
    summary["by_severity"] = dict(sorted(summary["by_severity"].items()))
    summary["by_rule"] = dict(sorted(summary["by_rule"].items()))
    return {
        "version": 1,
        "findings": [f.to_dict() for f in findings],
        "summary": summary,
        "contracts": contract_meta,
    }


def baseline_payload(report: Dict[str, Any]) -> Dict[str, Any]:
    """The ratcheted subset of a report — what the committed baseline
    pins byte-for-byte."""
    return {"version": report["version"], "findings": report["findings"]}


def canonical_json(payload: Dict[str, Any]) -> str:
    return json.dumps(payload, indent=2, sort_keys=True) + "\n"


def _key(d: Dict[str, str]) -> Tuple[str, str, str, str, str]:
    return (d["engine"], d["rule"], d["where"], d["severity"], d["message"])


def diff_against_baseline(
    report: Dict[str, Any], baseline: Dict[str, Any]
) -> Tuple[List[Dict], List[Dict]]:
    """(new findings not in the baseline, stale baseline entries no
    longer found)."""
    now = {_key(f): f for f in report["findings"]}
    base = {_key(f): f for f in baseline.get("findings", [])}
    new = [now[k] for k in sorted(now.keys() - base.keys())]
    fixed = [base[k] for k in sorted(base.keys() - now.keys())]
    return new, fixed


def _print_findings(findings: List[Dict], out=sys.stdout) -> None:
    for f in findings:
        print(f"  [{f['severity']}] {f['rule']} @ {f['where']}\n"
              f"      {f['message']}", file=out)


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Static analysis: jaxpr tracing contracts + source "
                    "lint, ratcheted against ANALYSIS_baseline.json.",
    )
    ap.add_argument("--check", action="store_true",
                    help="fail (exit 1) on any finding not in the "
                         "baseline, or any stale baseline entry")
    ap.add_argument("--write-baseline", action="store_true",
                    help="rewrite the baseline from this run's findings")
    ap.add_argument("--json", metavar="PATH",
                    help="write the full report (findings + summary + "
                         "per-contract metadata) to PATH")
    ap.add_argument("--baseline", metavar="PATH",
                    help=f"baseline file (default <root>/{BASELINE_NAME})")
    ap.add_argument("--root", metavar="PATH",
                    help="repo root (default: auto-detected)")
    ap.add_argument("--no-lint", action="store_true",
                    help="skip the source AST lint engine")
    ap.add_argument("--no-audit", action="store_true",
                    help="skip the jaxpr contract auditor")
    args = ap.parse_args(argv)

    root = Path(args.root) if args.root else repo_root()
    report = build_report(root, lint=not args.no_lint, audit=not args.no_audit)
    baseline_path = Path(args.baseline) if args.baseline else root / BASELINE_NAME

    if args.json:
        Path(args.json).write_text(canonical_json(report))

    s = report["summary"]
    print(f"repro.analysis: {s['total']} finding(s) "
          f"{s['by_severity'] or ''}  rules {s['by_rule'] or ''}")
    for name, meta in report["contracts"].items():
        counts = meta["eqn_counts"]
        uniq = sorted(set(counts.values()))
        tag = f"eqns={uniq[0]}" if len(uniq) == 1 else f"eqns VARY {counts}"
        skip = f" (skipped: {len(meta['skipped'])})" if meta["skipped"] else ""
        print(f"  contract {name}: {len(counts)} trace(s), {tag}{skip}")

    if args.write_baseline:
        baseline_path.write_text(canonical_json(baseline_payload(report)))
        print(f"wrote {baseline_path} ({s['total']} finding(s))")
        return 0

    if args.check:
        if not baseline_path.exists():
            print(f"ERROR: no baseline at {baseline_path} "
                  f"(run --write-baseline and commit it)", file=sys.stderr)
            return 1
        baseline = json.loads(baseline_path.read_text())
        new, fixed = diff_against_baseline(report, baseline)
        if new:
            print(f"\nFAIL: {len(new)} new finding(s) vs baseline:")
            _print_findings(new)
        if fixed:
            print(f"\nFAIL: {len(fixed)} baseline entr{'y' if len(fixed) == 1 else 'ies'} "
                  f"no longer found — ratchet down: rerun with "
                  f"--write-baseline and commit the smaller baseline:")
            _print_findings(fixed)
        if new or fixed:
            return 1
        print(f"check ok: findings match {baseline_path.name} exactly")
        return 0

    _print_findings(report["findings"])
    return 0
