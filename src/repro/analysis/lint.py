"""Source AST linter: host-sync and tracing hazards in jit-reachable
code (DESIGN.md §10).

Rules (ids are stable — they key the baseline ratchet):

  host-sync (P1)
      ``np.asarray``/``np.array``/``jax.device_get`` calls,
      ``.item()``/``.block_until_ready()`` method calls, and
      ``int(...)``/``float(...)`` whose argument contains a
      ``jnp.``/``jax.`` call — each of these blocks the host on device
      work. Inside jit-reachable code they either fail on tracers or
      (in host-side driver loops) silently serialize the pipeline. The
      serving discipline allows exactly the documented fetches, which
      carry a justification marker (below).

  tracer-branch (P2)
      ``if``/``while`` whose test calls a ``jnp.`` function — Python
      control flow cannot branch on tracer values; shape/dtype
      metadata (``.ndim``/``.shape``/``.size``/``.dtype``) is static
      and exempt.

  static-arg-hazard (P2)
      ``jax.jit(..., static_argnums=/static_argnames=)`` naming a
      parameter whose default or annotation is an unhashable container
      (list/dict/set) — hashing fails at call time, or worse, silently
      retraces forever with unhashable-wrapper types.

  dataclass-unregistered (P3)
      a non-frozen dataclass in jit-reachable code that the module
      never registers as a pytree (``register_pytree_node[_class]`` /
      ``register_dataclass``) — passed through jit it dies as a leaf
      of unknown type; as a static arg it is unhashable.

  docstring-missing (P3)
      a public function/class reachable from the export surfaces
      (``repro.api``, ``repro.hw``) without a docstring — these two
      modules ARE the documented API; an undocumented export is a
      docs bug, ratcheted like any other finding
      (:func:`docstring_findings`, a separate whole-surface pass).

Suppression — *at the offending line* (same line or the line above),
with a justification::

    toks = np.asarray(toks)  # analysis: host-sync ok — the one documented fetch per decode step

The marker is rule-scoped (``# analysis: <rule-id> ok``); a lint
finding without a marker is a real finding, and an unused marker costs
nothing. Scanned packages are the jit-reachable ones
(:data:`TRACED_PACKAGES`); launch/, configs/, hw/, data/ and analysis/
itself are host-side by design and excluded.
"""
from __future__ import annotations

import ast
import re
from pathlib import Path
from typing import Iterable, List, Optional, Tuple

from repro.analysis.jaxpr_audit import Finding

#: packages under src/repro whose code is reachable from a jit trace
TRACED_PACKAGES = (
    "core", "models", "kernels", "serve", "quant", "dist", "train", "optim",
    "profile",
)

_SUPPRESS_RE = re.compile(r"#\s*analysis:\s*([a-z0-9-]+)\s+ok\b")

#: attribute-call names that block on device values
_SYNC_METHODS = ("item", "block_until_ready")
#: numpy-module functions that force a device->host copy
_NP_SYNC_FUNCS = ("asarray", "array")
#: metadata attributes that are static at trace time (never tracers)
#: plus host-side jax runtime queries (device/topology introspection
#: returns python values, not tracers)
_STATIC_ATTRS = {
    "ndim", "shape", "size", "dtype",
    "device_count", "local_device_count", "devices", "local_devices",
    "default_backend", "process_index", "process_count",
}
_MUTABLE_ANNOTATIONS = {"list", "dict", "set", "List", "Dict", "Set"}

_SEVERITY = {
    "host-sync": "P1",
    "tracer-branch": "P2",
    "static-arg-hazard": "P2",
    "dataclass-unregistered": "P3",
    "docstring-missing": "P3",
}


def _dotted(node: ast.AST) -> str:
    """'np.asarray' for Attribute/Name chains, '' otherwise."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return ""


def _module_aliases(tree: ast.Module) -> Tuple[set, set]:
    """(numpy aliases, jax-ish aliases) bound by this module's imports.
    jax.numpy aliases count as jax-ish (device-side, NOT host-sync)."""
    np_names, jax_names = set(), set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                name = a.asname or a.name.split(".")[0]
                if a.name == "numpy":
                    np_names.add(name)
                elif a.name in ("jax", "jax.numpy") or a.name.startswith("jax."):
                    jax_names.add(name)
        elif isinstance(node, ast.ImportFrom) and node.module:
            if node.module == "jax" or node.module.startswith("jax."):
                for a in node.names:
                    jax_names.add(a.asname or a.name)
    return np_names, jax_names


def _contains_jax_call(node: ast.AST, jax_names: set) -> bool:
    """Does the subtree call a jax/jnp function (excluding static
    metadata access)?"""
    for sub in ast.walk(node):
        if isinstance(sub, ast.Call):
            dotted = _dotted(sub.func)
            root = dotted.split(".")[0] if dotted else ""
            leaf = dotted.split(".")[-1] if dotted else ""
            if root in jax_names and leaf not in _STATIC_ATTRS:
                return True
    return False


class _Linter(ast.NodeVisitor):
    def __init__(self, path: str, source: str):
        self.path = path
        self.lines = source.splitlines()
        self.tree = ast.parse(source)
        self.np_names, self.jax_names = _module_aliases(self.tree)
        self.findings: List[Finding] = []
        # every module-level / nested function def by name, for
        # static-arg resolution of jax.jit(fn, static_argnums=...)
        self.defs = {
            n.name: n for n in ast.walk(self.tree)
            if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))
        }
        self.registered = self._pytree_registered_names()

    # -- plumbing -----------------------------------------------------------

    def _suppressed(self, rule: str, lineno: int) -> bool:
        for ln in (lineno, lineno - 1):
            if 1 <= ln <= len(self.lines):
                m = _SUPPRESS_RE.search(self.lines[ln - 1])
                if m and m.group(1) in (rule, "all"):
                    return True
        return False

    def _emit(self, rule: str, node: ast.AST, message: str) -> None:
        lineno = getattr(node, "lineno", 0)
        # a decorated def/class anchors at the `class`/`def` keyword, but
        # the natural place for the marker is above the decorators
        first = min([lineno] + [d.lineno for d in
                                getattr(node, "decorator_list", [])])
        if self._suppressed(rule, lineno) or self._suppressed(rule, first):
            return
        self.findings.append(Finding(
            severity=_SEVERITY[rule], engine="lint", rule=rule,
            where=f"{self.path}:{lineno}", message=message,
        ))

    def _pytree_registered_names(self) -> set:
        """Class names this module registers as pytrees (decorator or
        call form)."""
        names: set = set()
        for node in ast.walk(self.tree):
            if isinstance(node, ast.Call):
                dotted = _dotted(node.func)
                if dotted.split(".")[-1] in (
                    "register_pytree_node", "register_pytree_node_class",
                    "register_dataclass", "register_static",
                ):
                    for arg in node.args:
                        if isinstance(arg, ast.Name):
                            names.add(arg.id)
            elif isinstance(node, ast.ClassDef):
                for dec in node.decorator_list:
                    target = dec.func if isinstance(dec, ast.Call) else dec
                    if _dotted(target).split(".")[-1] in (
                        "register_pytree_node_class", "register_dataclass",
                    ):
                        names.add(node.name)
        return names

    # -- host-sync ----------------------------------------------------------

    def visit_Call(self, node: ast.Call) -> None:
        dotted = _dotted(node.func)
        root = dotted.split(".")[0] if dotted else ""
        leaf = dotted.split(".")[-1] if dotted else ""
        if root in self.np_names and leaf in _NP_SYNC_FUNCS:
            self._emit("host-sync", node,
                       f"{dotted}(...) forces a device->host copy "
                       f"(blocks on device work; fails on tracers)")
        elif dotted == "jax.device_get" or leaf == "device_get" and root in self.jax_names:
            self._emit("host-sync", node,
                       f"{dotted}(...) is an explicit device->host fetch")
        elif isinstance(node.func, ast.Attribute) and node.func.attr in _SYNC_METHODS \
                and not node.args and not node.keywords:
            self._emit("host-sync", node,
                       f".{node.func.attr}() blocks the host on device work")
        elif isinstance(node.func, ast.Name) and node.func.id in ("int", "float") \
                and len(node.args) == 1 \
                and _contains_jax_call(node.args[0], self.jax_names):
            self._emit("host-sync", node,
                       f"{node.func.id}(<jax expression>) synchronously "
                       f"pulls a device scalar to the host")
        self._check_static_args(node)
        self.generic_visit(node)

    # -- tracer branching ----------------------------------------------------

    def _check_branch(self, node) -> None:
        if _contains_jax_call(node.test, self.jax_names):
            kind = "if" if isinstance(node, ast.If) else "while"
            self._emit("tracer-branch", node,
                       f"python `{kind}` on a jax expression — tracers "
                       f"cannot drive python control flow (use jnp.where/"
                       f"lax.cond, or hoist to static metadata)")

    def visit_If(self, node: ast.If) -> None:
        self._check_branch(node)
        self.generic_visit(node)

    def visit_While(self, node: ast.While) -> None:
        self._check_branch(node)
        self.generic_visit(node)

    # -- static-arg hazards --------------------------------------------------

    def _check_static_args(self, node: ast.Call) -> None:
        dotted = _dotted(node.func)
        if dotted.split(".")[-1] not in ("jit", "pjit"):
            return
        static_kw = {k.arg: k.value for k in node.keywords
                     if k.arg in ("static_argnums", "static_argnames")}
        if not static_kw:
            return
        target: Optional[ast.FunctionDef] = None
        if node.args and isinstance(node.args[0], ast.Name):
            target = self.defs.get(node.args[0].id)
        if target is None:
            return
        params = target.args.args
        flagged: List[str] = []
        for kind, val in static_kw.items():
            idxs: List[int] = []
            items = val.elts if isinstance(val, (ast.Tuple, ast.List)) else [val]
            for item in items:
                if kind == "static_argnums" and isinstance(item, ast.Constant) \
                        and isinstance(item.value, int) and item.value < len(params):
                    idxs.append(item.value)
                elif kind == "static_argnames" and isinstance(item, ast.Constant):
                    for i, p in enumerate(params):
                        if p.arg == item.value:
                            idxs.append(i)
            defaults = target.args.defaults
            off = len(params) - len(defaults)
            for i in idxs:
                ann = params[i].annotation
                ann_name = ""
                if isinstance(ann, ast.Name):
                    ann_name = ann.id
                elif isinstance(ann, ast.Subscript) and isinstance(ann.value, ast.Name):
                    ann_name = ann.value.id
                default = defaults[i - off] if i >= off else None
                if ann_name in _MUTABLE_ANNOTATIONS or isinstance(
                        default, (ast.List, ast.Dict, ast.Set)):
                    flagged.append(params[i].arg)
        if flagged:
            self._emit("static-arg-hazard", node,
                       f"static arg(s) {flagged} of `{target.name}` are "
                       f"unhashable containers — jit static args must "
                       f"hash (use tuples / frozen dataclasses)")

    # -- dataclass pytree registration --------------------------------------

    def visit_ClassDef(self, node: ast.ClassDef) -> None:
        is_dc, frozen = False, False
        for dec in node.decorator_list:
            target = dec.func if isinstance(dec, ast.Call) else dec
            if _dotted(target).split(".")[-1] == "dataclass":
                is_dc = True
                if isinstance(dec, ast.Call):
                    for k in dec.keywords:
                        if k.arg == "frozen" and isinstance(k.value, ast.Constant) \
                                and k.value.value is True:
                            frozen = True
        if is_dc and not frozen and node.name not in self.registered:
            self._emit("dataclass-unregistered", node,
                       f"non-frozen dataclass `{node.name}` is neither "
                       f"frozen (hashable static arg) nor registered as "
                       f"a pytree — it cannot cross a jit boundary")
        self.generic_visit(node)


def lint_source(source: str, path: str) -> List[Finding]:
    """Lint one module's source. ``path`` is the repo-relative path
    used in findings (tests pass synthetic paths)."""
    linter = _Linter(path, source)
    linter.visit(linter.tree)
    return sorted(linter.findings)


def lint_paths(root: Path, packages: Iterable[str] = TRACED_PACKAGES) -> List[Finding]:
    """Lint every ``.py`` file of the traced packages under
    ``root/src/repro`` (sorted walk — deterministic reports)."""
    findings: List[Finding] = []
    base = Path(root) / "src" / "repro"
    files = [base / "api.py"]
    for pkg in packages:
        files.extend(sorted((base / pkg).rglob("*.py")))
    for f in files:
        if not f.exists():
            continue
        rel = str(f.relative_to(Path(root)))
        findings.extend(lint_source(f.read_text(), rel))
    return sorted(findings)


# ---------------------------------------------------------------------------
# Docstring coverage over the public export surfaces
# ---------------------------------------------------------------------------

#: the export surfaces whose re-exported defs the docstring rule covers
_EXPORT_SURFACES = ("api.py", "hw/__init__.py")


def _surface_exports(tree: ast.Module) -> List[Tuple[str, str]]:
    """(module, exported-name) pairs an export surface re-exports from
    inside ``repro.`` (constants and third-party names drop out later —
    only def/class statements are docstring-checkable)."""
    out: List[Tuple[str, str]] = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.ImportFrom) or not node.module:
            continue
        if node.level or not node.module.startswith("repro"):
            continue
        for a in node.names:
            if a.name != "*" and not a.name.startswith("_"):
                out.append((node.module, a.name))
    return out


def _resolve_export(src_root: Path, module: str, name: str, _depth: int = 0):
    """Find the def/class statement behind ``from <module> import
    <name>``: the module file's top-level def, following at most one
    re-export level through a package ``__init__``. Returns
    ``(path, defnode)`` or None (constants, aliases, unresolvable)."""
    mod_path = src_root / Path(*module.split("."))
    if (mod_path / "__init__.py").exists():
        path = mod_path / "__init__.py"
    elif mod_path.with_suffix(".py").exists():
        path = mod_path.with_suffix(".py")
    else:
        return None
    try:
        tree = ast.parse(path.read_text())
    except SyntaxError:
        return None
    for node in tree.body:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)) and node.name == name:
            return path, node
    if _depth >= 1:
        return None
    for node in tree.body:  # one re-export hop (package __init__)
        if isinstance(node, ast.ImportFrom) and node.module \
                and not node.level and node.module.startswith("repro"):
            for a in node.names:
                if (a.asname or a.name) == name:
                    return _resolve_export(src_root, node.module, a.name,
                                           _depth + 1)
    return None


def docstring_findings(root: Path) -> List[Finding]:
    """The docstring-coverage pass (rule ``docstring-missing``, P3):
    every public function/class reachable from the export surfaces
    (``repro.api``, ``repro.hw``) must carry a docstring. Same
    suppression marker discipline as the AST rules."""
    src_root = Path(root) / "src"
    base = src_root / "repro"
    findings: List[Finding] = []
    seen = set()
    lines_cache: dict = {}
    for surface in _EXPORT_SURFACES:
        spath = base / surface
        if not spath.exists():
            continue
        surface_mod = "repro." + surface.replace("/__init__.py", "").replace(
            ".py", "").replace("/", ".")
        for module, name in _surface_exports(ast.parse(spath.read_text())):
            res = _resolve_export(src_root, module, name)
            if res is None:
                continue
            path, defnode = res
            key = (str(path), defnode.lineno)
            if key in seen:
                continue
            seen.add(key)
            if ast.get_docstring(defnode) is not None:
                continue
            if str(path) not in lines_cache:
                lines_cache[str(path)] = path.read_text().splitlines()
            lines = lines_cache[str(path)]
            first = min([defnode.lineno] + [d.lineno
                                           for d in defnode.decorator_list])
            if any(
                (m := _SUPPRESS_RE.search(lines[ln - 1]))
                and m.group(1) in ("docstring-missing", "all")
                for ln in (defnode.lineno, defnode.lineno - 1, first,
                           first - 1)
                if 1 <= ln <= len(lines)
            ):
                continue
            kind = "class" if isinstance(defnode, ast.ClassDef) else "function"
            findings.append(Finding(
                severity=_SEVERITY["docstring-missing"], engine="lint",
                rule="docstring-missing",
                where=f"{path.relative_to(Path(root))}:{defnode.lineno}",
                message=f"public {kind} `{name}` (exported via "
                        f"{surface_mod}) has no docstring",
            ))
    return sorted(findings)
