"""Quantization preparation: offline ternarization + 2-bit packing."""
from repro.quant.prepare import (  # noqa: F401
    pack_params,
    prepare_for_spec,
    ternarize_params,
)
