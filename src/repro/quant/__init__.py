"""Quantization preparation: offline ternarization + 2-bit packing."""
from repro.quant.prepare import pack_params, ternarize_params  # noqa: F401
