"""Offline model surgery for ternary serving.

``ternarize_params`` walks a trained parameter tree and replaces every
weight that the ternary/CiM dense path would quantize with its ternary
value times the per-channel scale (folded), so serving with
``QuantConfig(pre_quantized=True)`` skips the per-step STE re-quantization
entirely — the paper's deployment model (weights are programmed into the
CiM arrays once, not re-derived every inference).

``pack_params`` additionally converts the folded ternary weights to the
2-bit differential bitplane format (repro.core.ternary.pack_ternary),
the storage layout of the SiTe cell (M1/M2) and of the packed Pallas
kernel — 8x less HBM weight traffic than int8.

``prepare_for_spec`` is the execution-API entry point: given the
``CiMExecSpec`` the model will serve under, it performs whichever
surgery that spec's packing requires.
"""
from __future__ import annotations

import re
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core import ternary as tern
from repro.core.execution import (
    CiMExecSpec,
    _pad_axis,
    canonical_plane_layout,
)
from repro.dist.sharding import tree_paths

PyTree = Any

# weights the ternary dense path quantizes (matches layers/attention/moe)
_QUANT_RE = re.compile(
    r"(^|/)(wq|wk|wv|wo|w_dkv|w_uk|w_uv|w_in|w_out|w_gate|w_up|w_down|projector)$"
)
_NO_QUANT_RE = re.compile(r"(^|/)(embed|unembed|router|conv_w|conv_b)($|/)")


def _is_quantized_weight(path: str, leaf) -> bool:
    return bool(_QUANT_RE.search(path)) and leaf.ndim >= 2 and not _NO_QUANT_RE.search(path)


def ternarize_params(
    params: PyTree, factor: float = tern.TWN_THRESHOLD_FACTOR
) -> PyTree:
    """Fold ternarization into the stored weights (scale * {-1,0,1})."""
    flat = tree_paths(params)
    leaves, treedef = jax.tree_util.tree_flatten(params)
    out = []
    for (path, leaf), orig in zip(flat, leaves):
        if _is_quantized_weight(path, leaf):
            # quantize over the contraction dim ONLY: stacked-layer leaves
            # are (L, K, N) and dense() sees per-layer (K, N) slices, so
            # thresholds/scales must be per-(layer, out-channel)
            axis = (leaf.ndim - 2,)
            t, scale = tern.ternarize(leaf, axis=axis, factor=factor)
            out.append((t * scale).astype(leaf.dtype))
        else:
            out.append(orig)
    return jax.tree_util.tree_unflatten(treedef, out)


def pack_params(
    params: PyTree, factor: float = tern.TWN_THRESHOLD_FACTOR
) -> Tuple[PyTree, Dict[str, jax.Array]]:
    """Ternarize and 2-bit-pack the quantizable weights.

    Returns (params_with_scales, packed) where ``packed`` maps each weight
    path to (pos_plane, neg_plane, scale). The dense path consumes these
    via kernels.packed_cim_matmul on TPU.
    """
    flat = tree_paths(params)
    packed: Dict[str, jax.Array] = {}
    leaves, treedef = jax.tree_util.tree_flatten(params)
    out = []
    for (path, leaf), orig in zip(flat, leaves):
        # pack along the contraction (second-to-last) dim; stacked-layer
        # weights are (L, K, N), plain ones (K, N)
        k_axis = leaf.ndim - 2
        if _is_quantized_weight(path, leaf) and leaf.shape[k_axis] % 8 == 0:
            axis = (k_axis,)
            t, scale = tern.ternarize(leaf, axis=axis, factor=factor)
            p1, p2 = tern.pack_ternary(t.astype(jnp.int8), axis=k_axis)
            packed[path] = (p1, p2, scale)
            out.append((t * scale).astype(leaf.dtype))
        else:
            out.append(orig)
    return jax.tree_util.tree_unflatten(treedef, out), packed


def _canonicalize_packed(
    packed: Dict[str, Tuple], spec: CiMExecSpec
) -> Dict[str, tern.PackedPlanes]:
    """Pad each packed (p1, p2, scale) entry to the **canonical kernel
    layout** for ``spec`` (``execution.canonical_plane_layout``): plane
    rows to the tile K granularity, plane columns to the tile N
    granularity. Pad cells are (0, 0) bit pairs — weight 0, inert under
    the a/b event-count semantics — and the logical (K, N) are recorded
    on the :class:`repro.core.ternary.PackedPlanes` so
    ``api.execute_packed`` slices results back exactly. This moves the
    pad/relayout the serving step used to re-trace *every decode step*
    to prepare time, once.

    Specs resolving to the ``pallas_stream`` backend store the canonical
    planes **plane-interleaved** (layout version 1 — DESIGN.md §14): one
    (…, K/4, N) array whose byte-rows alternate pos/neg, the ordering
    the streaming decode kernel DMAs a whole (k, j) tile from in one
    contiguous copy. The version rides on the ``PackedPlanes`` metadata,
    so stored legacy planes round-trip unchanged and either layout feeds
    either backend (``PackedPlanes.planes()``/``.interleaved()``)."""
    k_mult, n_mult = canonical_plane_layout(spec)
    stream = spec.resolve().backend == "pallas_stream"
    rows = k_mult // 8
    out: Dict[str, tern.PackedPlanes] = {}
    for path, (p1, p2, scale) in packed.items():
        k, n = p1.shape[-2] * 8, p1.shape[-1]
        p1 = _pad_axis(_pad_axis(p1, rows, p1.ndim - 2), n_mult, p1.ndim - 1)
        p2 = _pad_axis(_pad_axis(p2, rows, p2.ndim - 2), n_mult, p2.ndim - 1)
        if stream:
            wi = tern.interleave_planes(p1, p2)
            out[path] = tern.PackedPlanes(
                pos=wi, neg=wi[..., :0, :], scale=scale, k=k, n=n,
                layout_version=tern.PLANE_LAYOUT_STREAM,
            )
        else:
            out[path] = tern.PackedPlanes(pos=p1, neg=p2, scale=scale, k=k, n=n)
    return out


def prepare_for_spec(
    params: PyTree,
    spec: CiMExecSpec,
    factor: float = tern.TWN_THRESHOLD_FACTOR,
    mesh=None,
    canonical: bool = True,
):
    """Offline surgery matched to the serving execution spec.

    packing="none"        -> ternarize + fold scales (pre_quantized path).
    packing="bitplane_u8" -> additionally emit the packed (M1, M2)
                             bitplanes per weight in the **canonical
                             kernel layout**: each ``packed[path]`` is a
                             :class:`repro.core.ternary.PackedPlanes`
                             whose planes are pre-padded to the packed
                             kernels' tile granularity with the logical
                             (K, N) recorded. Feed an entry (or its
                             ``.layer(i)`` slice for stacked weights) to
                             ``repro.api.execute_packed(spec, x, entry)``
                             (folding ``.scale`` after the MAC): the
                             serving jaxpr then contains no per-step
                             plane padding or relayout. ``canonical=
                             False`` keeps the raw ``(p1, p2, scale)``
                             tuples at logical extents (legacy layout).

    ``mesh``: place the surgery outputs for tensor-parallel serving —
    folded params land under ``dist.sharding.param_specs`` and packed
    planes under ``packed_specs`` (N-sharded: each device stores only
    the 2-bit plane columns its TP shard consumes; the canonical padded
    N is a tile multiple, so it divides typical TP degrees). The surgery
    itself runs replicated (it is one-off, and per-channel thresholds
    need the full K column anyway); only the *results* are sharded.

    Returns ``params`` for "none", ``(params, packed)`` for bitplane
    packing — mirroring :func:`ternarize_params` / :func:`pack_params`.
    """
    if spec.packing == "bitplane_u8":
        prepared, packed = pack_params(params, factor=factor)
        if canonical:
            packed = _canonicalize_packed(packed, spec)
        if mesh is not None:
            prepared, packed = _shard_prepared(prepared, packed, mesh)
        return prepared, packed
    prepared = ternarize_params(params, factor=factor)
    if mesh is not None:
        prepared, _ = _shard_prepared(prepared, None, mesh)
    return prepared


def _shard_prepared(params: PyTree, packed, mesh):
    """device_put the surgery outputs under the TP sharding rules."""
    from repro.dist import sharding as shd

    axis_sizes = shd.mesh_axis_sizes(mesh)
    params = jax.device_put(
        params,
        shd.named_shardings(mesh, shd.param_specs(params, axis_sizes=axis_sizes)),
    )
    if packed is not None:
        packed = jax.device_put(
            packed, shd.named_shardings(mesh, shd.packed_specs(packed, axis_sizes))
        )
    return params, packed
