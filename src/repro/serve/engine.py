"""Serving: prefill/decode steps, sampling, and a continuous batcher.

``serve_step`` is the unit the dry-run lowers for the decode shape cells:
one new token for every sequence in the batch against a seq_len-deep KV
cache. ``prefill`` reuses the same cached block path with S > 1.

The ``ContinuousBatcher`` keeps a fixed pool of slots; finished sequences
are immediately replaced from the queue (slot-level continuous batching,
the standard production serving discipline), demonstrated end-to-end in
examples/serve_ternary.py.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core.execution import CiMExecSpec
from repro.models import transformer as T

PyTree = Any


def apply_exec_spec(cfg: ArchConfig, spec: Optional[CiMExecSpec]) -> ArchConfig:
    """Serve the model under an explicit CiM execution spec (e.g. a
    packed-bitplane backend or flavor II) without touching the
    architecture config: the spec overrides the QuantConfig's
    mode-derived dispatch in every dense layer.

    The stochastic sensing-error channel needs a per-layer PRNG key,
    which the model-assembly code does not thread — noisy specs are for
    direct ``api.execute`` / ``layers.dense(key=...)`` calls (see
    benchmarks/bench_accuracy.py), so they are rejected here up front
    rather than crashing inside the first forward.
    """
    if spec is None:
        return cfg
    if spec.error_prob > 0.0:
        raise ValueError(
            "serving does not thread PRNG keys into dense layers; use a "
            "spec with error_prob=0 here and drive the sensing-error "
            "channel through api.execute/layers.dense directly"
        )
    if spec.packing != "none":
        # dense() holds dense weights, so a packed spec re-packs every
        # weight inside every forward — functionally correct (this is
        # the equivalence-test path) but it realizes none of the packed
        # format's weight-traffic savings; that needs
        # prepare_for_spec + api.execute_packed over stored planes
        warnings.warn(
            f"serving under packing={spec.packing!r} packs weights "
            "per-forward (functional path only); use "
            "quant.prepare.prepare_for_spec + api.execute_packed for "
            "the stored-plane fast path",
            stacklevel=2,
        )
    # mode="off" short-circuits dense() before the spec is consulted —
    # upgrade it so the requested spec actually executes (ternarizing
    # weights/activations on the fly, like any quantized mode)
    mode = "cim" if cfg.quant.mode == "off" else cfg.quant.mode
    return cfg.replace(
        quant=dataclasses.replace(cfg.quant, mode=mode, exec_spec=spec)
    )


def sample(logits: jax.Array, key: jax.Array, temperature: float = 0.0) -> jax.Array:
    """logits: (B, 1, V) -> token ids (B, 1)."""
    if temperature == 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    scaled = logits.astype(jnp.float32) / temperature
    flat = scaled[:, 0, :]
    toks = jax.random.categorical(key, flat, axis=-1)
    return toks[:, None].astype(jnp.int32)


def prefill(
    params, tokens: jax.Array, caches, cfg: ArchConfig, enc: Optional[jax.Array] = None
) -> Tuple[jax.Array, PyTree]:
    """Run the prompt through the cached path (index 0). Returns
    (last_logits (B, 1, V), caches)."""
    logits, caches = T.decode_step(params, tokens, caches, jnp.int32(0), cfg, enc)
    return logits[:, -1:, :], caches


def serve_step(
    params,
    tokens: jax.Array,
    caches,
    index: jax.Array,
    cfg: ArchConfig,
    enc: Optional[jax.Array] = None,
) -> Tuple[jax.Array, PyTree]:
    """One decode step: tokens (B, 1) at cache position ``index``."""
    return T.decode_step(params, tokens, caches, index, cfg, enc)


def make_jit_serve_step(cfg: ArchConfig, donate_caches: bool = True):
    def f(params, tokens, caches, index, enc=None):
        return serve_step(params, tokens, caches, index, cfg, enc)

    return jax.jit(f, donate_argnums=(2,) if donate_caches else ())


def fused_decode_fn(cfg: ArchConfig, temperature: float = 0.0):
    """The function the fused batcher jits for every decode step: one
    ragged-position ``decode_step`` over all slots plus on-device
    sampling — tokens out are the step's ONLY device->host payload.
    Module-level (not a closure inside the batcher) so the registered
    ``serve.fused_decode_step`` tracing contract audits the *same*
    function production serves with, not a test replica."""

    def step(params, tokens, caches, positions, start, key):
        logits, caches = T.decode_step(
            params, tokens, caches, positions, cfg, start=start)
        toks = sample(logits[:, -1:, :], key, temperature)[:, 0]
        return toks, caches

    return step


def generate(
    params,
    prompt: jax.Array,
    cfg: ArchConfig,
    max_new: int = 16,
    s_max: int = 128,
    temperature: float = 0.0,
    key: Optional[jax.Array] = None,
    enc: Optional[jax.Array] = None,
    exec_spec: Optional[CiMExecSpec] = None,
) -> jax.Array:
    """Greedy/temperature generation (host loop — example/test path)."""
    cfg = apply_exec_spec(cfg, exec_spec)
    b, s0 = prompt.shape
    caches = T.init_caches(cfg, b, s_max)
    logits, caches = prefill(params, prompt, caches, cfg, enc)
    key = key if key is not None else jax.random.PRNGKey(0)
    step_fn = make_jit_serve_step(cfg)
    out = []
    tok = sample(logits, key, temperature)
    out.append(tok)
    for i in range(max_new - 1):
        key, sub = jax.random.split(key)
        logits, caches = step_fn(params, tok, caches, jnp.int32(s0 + i), enc)
        tok = sample(logits, sub, temperature)
        out.append(tok)
    return jnp.concatenate(out, axis=1)


# ---------------------------------------------------------------------------
# Continuous batching
# ---------------------------------------------------------------------------

# analysis: dataclass-unregistered ok — host-side bookkeeping, never jitted
@dataclasses.dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new: int
    generated: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    # set when the slot hit cache capacity (s_max) before max_new tokens;
    # with left-padded batched prefill the pad dead zone counts against
    # capacity, so a short prompt co-batched with a long one can run out
    # of slots earlier than per-request generate() would
    truncated: bool = False
    # set by ContinuousBatcher.cancel(): the request was withdrawn (from
    # the queue, or mid-decode — its slot freed) before max_new tokens
    cancelled: bool = False


def _next_pow2(n: int, lo: int = 4) -> int:
    v = lo
    while v < n:
        v *= 2
    return v


class ContinuousBatcher:
    """Slot-pool continuous batcher over one fused, jitted decode step.

    Each slot owns a cache region (per-slot caches batched along axis 1 of
    every stacked cache leaf). Finished slots are refilled without
    stalling the others.

    The fused path (default) exploits the ragged-position decode contract
    (DESIGN.md §6) end-to-end:

      * **one** batched ``decode_step`` serves all slots at heterogeneous
        cache positions via a ``(n_slots,)`` position vector — no
        per-slot Python loop inside jit, so the traced program size and
        compile count are independent of ``n_slots``;
      * newly assigned slots prefill **together** in one left-padded
        batch (prompts right-aligned so every row's last real token sits
        in the last column; the per-row ``start`` vector masks the dead
        pad slots for the slot's lifetime); padded lengths are bucketed
        to powers of two to bound recompiles. The pad dead zone counts
        against the slot's s_max capacity, so a short prompt co-batched
        with a much longer one can hit the cache limit before max_new —
        such requests finish with ``truncated=True``;
      * sampling happens on device inside the jitted step — the host
        fetches exactly one small token vector per decode step
        (``host_syncs`` counts these).

    ``fused=False`` keeps the legacy per-slot-loop decode (a static
    Python loop of single-row steps inside jit, per-slot prefill, one
    host sync per active slot) as the measured baseline for
    ``benchmarks/bench_serve.py``.

    ``prepare_weights=True`` runs ``quant.prepare.prepare_for_spec`` once
    at construction so the per-step STE re-quantization is skipped
    (``pre_quantized``); for a bitplane-packed spec the stored 2-bit
    planes are kept on ``self.packed`` as canonical
    ``repro.core.ternary.PackedPlanes`` — pre-padded to the packed
    kernels' tile granularity with the logical (K, N) recorded, so
    ``api.execute_packed`` callers stream them across steps with zero
    per-step padding/relayout (DESIGN.md §9) — and the in-model dense
    path serves from the folded ternary weights (packing downgraded to
    "none" so nothing re-packs per forward).

    Quantized fused serving is **exactly** token-identical to
    per-request ``generate()`` when the quant config uses
    ``act_scale="per_row"`` (row-independent activation quantization);
    the default per-tensor scale couples co-batched rows through one
    amax (DESIGN.md §9).

    ``mesh`` turns on tensor-parallel serving (DESIGN.md §8): params are
    sharded under ``dist.sharding.param_specs`` (attention/FFN column- and
    row-parallel over the "model" axis), decode caches under
    ``cache_specs``, and any prepared 2-bit bitplanes under
    ``packed_specs`` (N-sharded — each device stores only its weight
    shard). The fused step stays ONE jitted dispatch with one host fetch
    per decode step; the GSPMD partitioner inserts the TP collectives, so
    token streams are identical to the unsharded engine (pinned in
    tests/test_tp_serve.py) and ``stats()`` is unchanged by TP.
    ``compress_tp=True`` additionally routes the row-parallel quantized
    MACs through the explicit shard_map path (``execution.execute_tp``)
    whose per-layer partial-sum all-reduce moves int8 instead of f32 —
    approximate (quantization-level error), opt-in, quantized modes only.

    ``cache_dtype`` overrides ``cfg.quant.cache_dtype`` (DESIGN.md §13):
    ``"int8"``/``"ternary"`` store the KV cache as codes + per-(row,
    position) f32 scales — 2x/4x the resident slots at equal cache
    memory, and proportionally smaller TP cache shards — with dequant
    fused into the attention contractions. ``"bf16"`` (the default via
    QuantConfig) is pinned bit-identical to the unquantized engine. The
    donated-buffer reset path (`_build_prefill_fused`'s in-jit
    ``T.init_caches``) follows the same config, so freed slots are
    rebuilt in cache_dtype layout with no host round-trip.
    """

    def __init__(
        self,
        params,
        cfg: ArchConfig,
        n_slots: int = 4,
        s_max: int = 128,
        exec_spec: Optional[CiMExecSpec] = None,
        temperature: float = 0.0,
        seed: int = 0,
        fused: bool = True,
        prepare_weights: bool = False,
        mesh=None,
        compress_tp: bool = False,
        profile=None,
        cache_dtype: Optional[str] = None,
    ):
        self.packed = None
        self.mesh = mesh
        self._compress_tp = bool(compress_tp)
        # opt-in measured-time observability (DESIGN.md §11): `profile`
        # is a repro.profile.Profiler, or a path to stream JSON-lines
        # events to, or None (the default — the step builders then get
        # the *unwrapped* jitted functions back from wrap_step, so the
        # disabled engine is bit- and jaxpr-identical to one built
        # before this feature existed).
        self.profiler = None
        self._owns_profiler = False
        if profile is not None:
            from repro.profile.trace import Profiler

            if isinstance(profile, Profiler):
                self.profiler = profile
            else:
                self.profiler = Profiler(profile)
                self._owns_profiler = True
        self._mesh_dict = (
            {str(k): int(v) for k, v in mesh.shape.items()}
            if mesh is not None else None
        )
        self._prefill_meta = {}
        if mesh is not None:
            from repro.dist import sharding as shd  # placement, below

            if "model" not in mesh.axis_names:
                raise ValueError(
                    f"TP serving shards over a 'model' mesh axis; got axes "
                    f"{mesh.axis_names} (use launch.mesh.make_tp_mesh)"
                )
        if compress_tp and mesh is None:
            raise ValueError("compress_tp=True requires a mesh (TP serving)")
        if prepare_weights and exec_spec is None:
            raise ValueError(
                "prepare_weights=True requires exec_spec (the surgery is "
                "matched to the spec's packing); for spec-less offline "
                "ternarization use quant.prepare.ternarize_params + "
                "QuantConfig(pre_quantized=True)"
            )
        params_placed = False
        if prepare_weights and exec_spec is not None:
            from repro.quant.prepare import prepare_for_spec

            # prepare_for_spec(mesh=...) owns placement of BOTH surgery
            # outputs (folded params under param_specs, planes under
            # packed_specs) — don't re-place the params below
            def _prepare():
                return prepare_for_spec(params, exec_spec, mesh=mesh)

            if self.profiler is not None:
                from repro.profile.trace import wrap_step

                _prepare = wrap_step(
                    _prepare, self.profiler, "serve.prepare",
                    exec_spec=exec_spec.name, shape_class="prepare",
                    mesh=self._mesh_dict)
            prepared = _prepare()
            params_placed = mesh is not None
            if exec_spec.packing == "bitplane_u8":
                params, self.packed = prepared
                # the in-model dense path serves the folded ternary
                # weights, so drop the packing; packed-only backends
                # (pallas_stream has no dense kernel — it exists to
                # stream stored planes) fall back to "auto" for the
                # dense path while self.packed keeps the stream layout
                # for api.execute_packed / execute_packed_tp consumers
                from repro.core.execution import get_backend

                dense_spec = dataclasses.replace(exec_spec, packing="none")
                try:
                    get_backend(dense_spec)
                except KeyError:
                    dense_spec = dataclasses.replace(dense_spec, backend="auto")
                exec_spec = dense_spec
            else:
                params = prepared
            cfg = cfg.replace(
                quant=dataclasses.replace(cfg.quant, pre_quantized=True)
            )
        self.cfg = cfg = apply_exec_spec(cfg, exec_spec)
        if cache_dtype is not None:
            # KV-cache storage precision override (DESIGN.md §13) —
            # validated by QuantConfig.__post_init__; None keeps the
            # config's own cache_dtype (default "bf16", bit-identical
            # to the pre-§13 engine)
            self.cfg = cfg = cfg.replace(
                quant=dataclasses.replace(cfg.quant, cache_dtype=cache_dtype)
            )
        if compress_tp:
            if cfg.quant.mode == "off":
                raise ValueError(
                    "compress_tp compresses the quantized dense path's TP "
                    "all-reduce; serve a quantized mode (or an exec_spec) "
                    "to use it"
                )
            spec_now = cfg.quant.exec_spec
            if spec_now is not None and spec_now.packing != "none":
                # dense() routes to execute_tp only for unpacked specs
                # (the packed planes shard over N, not K) — accepting
                # this would silently serve with exact collectives
                raise ValueError(
                    f"compress_tp cannot engage under packing="
                    f"{spec_now.packing!r}: use prepare_weights=True "
                    "(which folds the packing offline and downgrades the "
                    "in-model spec to packing='none') or an unpacked spec"
                )
            self.cfg = cfg = cfg.replace(
                quant=dataclasses.replace(cfg.quant, tp_reduce="int8")
            )
        if mesh is not None and not params_placed:
            axis_sizes = shd.mesh_axis_sizes(mesh)
            params = jax.device_put(
                params,
                shd.named_shardings(
                    mesh, shd.param_specs(params, axis_sizes=axis_sizes)),
            )
        self.params = params
        self.n_slots = n_slots
        self.s_max = s_max
        self.temperature = float(temperature)
        self.fused = fused
        self._key = jax.random.PRNGKey(seed)
        self.caches = T.init_caches(cfg, n_slots, s_max)
        self._cache_ns = None
        if mesh is not None:
            self._cache_ns = shd.named_shardings(
                mesh, shd.cache_specs(self.caches, mesh, batch=n_slots))
            self.caches = jax.device_put(self.caches, self._cache_ns)
        self.slot_req: List[Optional[Request]] = [None] * n_slots
        self.slot_pos = np.zeros((n_slots,), np.int32)    # next cache write slot
        self.slot_start = np.zeros((n_slots,), np.int32)  # left-pad dead zone
        self._last_tok = np.zeros((n_slots,), np.int32)
        self.queue: List[Request] = []
        self.decode_steps = 0
        self.host_syncs = 0
        self.prefill_batches = 0
        self._step_idx = 0
        self._prefill_idx = 0
        if not fused and self.temperature != 0.0:
            raise ValueError(
                "temperature sampling is only implemented for the fused "
                "decode path (the looped baseline is greedy-only)"
            )
        if fused:
            self._decode = self._build_decode_fused()
            self._prefill = self._build_prefill_fused()
        else:
            self._decode = self._build_decode_looped()

    # -- fused path ---------------------------------------------------------

    def _sample_on_device(self, last_logits, key):
        """last_logits: (B, V) -> (B,) int32, greedy or temperature —
        the module-level :func:`sample`, traced into the jitted step."""
        return sample(last_logits[:, None, :], key, self.temperature)[:, 0]

    def _jit_step(self, f, donate, entry_point=None, shape_class="decode",
                  meta_fn=None):
        """jit with the TP output shardings pinned: sampled tokens
        replicated (they are THE one host fetch of the step), caches kept
        under their cache_specs sharding so the donated-buffer layout is
        a fixpoint across steps (no per-step reshard, no recompiles).

        For ``compress_tp`` the call is additionally scoped under THIS
        batcher's mesh via the dist.sharding TP-mesh switch — installed
        around the call (where tracing happens) and restored after, so
        two batchers on different meshes in one process never read each
        other's mesh and nothing leaks once the batcher is done.

        With a profiler installed and ``entry_point`` named, the built
        step is wrapped with wall-time capture (repro.profile.trace);
        with no profiler ``wrap_step`` returns it unchanged."""
        if self._cache_ns is None:
            jitted = jax.jit(f, donate_argnums=donate)
        else:
            from jax.sharding import NamedSharding, PartitionSpec as P

            tok_ns = NamedSharding(self.mesh, P())
            jitted = jax.jit(f, donate_argnums=donate,
                             out_shardings=(tok_ns, self._cache_ns))
        if self._compress_tp:
            inner = jitted

            def scoped(*args):
                from repro.dist import sharding as shd

                prev = shd.tp_mesh()
                shd.set_tp_mesh(self.mesh)
                try:
                    return inner(*args)
                finally:
                    shd.set_tp_mesh(prev)

            jitted = scoped
        if self.profiler is None or entry_point is None:
            return jitted
        from repro.profile.trace import wrap_step

        return wrap_step(
            jitted, self.profiler, entry_point,
            exec_spec=self._spec_tag, shape_class=shape_class,
            mesh=self._mesh_dict, meta_fn=meta_fn)

    @property
    def _spec_tag(self) -> str:
        spec = self.cfg.quant.exec_spec
        return spec.name if spec is not None else f"mode:{self.cfg.quant.mode}"

    def _build_decode_fused(self):
        def meta(*_args):
            # called at record time, BEFORE _step_fused mutates slots —
            # occupancy is the number of rows this step decoded for
            return {
                "arch": self.cfg.name,
                "step": self._step_idx,
                "occupancy": sum(r is not None for r in self.slot_req),
                "n_slots": self.n_slots,
            }

        return self._jit_step(
            fused_decode_fn(self.cfg, self.temperature), (2,),
            entry_point="serve.decode_step", shape_class="decode",
            meta_fn=meta)

    def _build_prefill_fused(self):
        cfg, n, s_max = self.cfg, self.n_slots, self.s_max

        def pf(params, caches, tokens, start, fill_mask, key):
            # prefill all n_slots rows against fresh zero caches (dummy
            # rows compute garbage that the merge mask discards), then
            # select per row: filling slots take the new cache row,
            # in-flight slots keep theirs.
            fresh = T.init_caches(cfg, n, s_max)
            logits, new = T.decode_step(
                params, tokens, fresh, jnp.int32(0), cfg, start=start)
            # left-padding: the last column is every row's last real token
            toks = self._sample_on_device(logits[:, -1, :], key)

            def merge(old, nw):
                m = fill_mask.reshape((1, n) + (1,) * (old.ndim - 2))
                return jnp.where(m, nw.astype(old.dtype), old)

            return toks, jax.tree.map(merge, caches, new)

        def meta(*_args):
            # _fill_slots_fused stages the batch description here right
            # before invoking the step (replay.requests_from_trace
            # reconstructs the request mix from these events)
            return dict(self._prefill_meta)

        return self._jit_step(pf, (1,), entry_point="serve.prefill",
                              shape_class="prefill", meta_fn=meta)

    def _fill_slots_fused(self):
        newly = []
        for s in range(self.n_slots):
            if self.slot_req[s] is None and self.queue:
                self.slot_req[s] = self.queue.pop(0)
                newly.append(s)
        if not newly:
            return
        max_len = max(len(self.slot_req[s].prompt) for s in newly)
        s_pad = _next_pow2(max_len)  # bucketed: bounds prefill recompiles
        if s_pad >= self.s_max:
            # don't let the bucket make a servable prompt unservable:
            # fall back to the exact length (one extra compile, worth it)
            s_pad = max_len
        tokens = np.zeros((self.n_slots, s_pad), np.int32)
        start = np.zeros((self.n_slots,), np.int32)
        fill = np.zeros((self.n_slots,), bool)
        for s in newly:
            prompt = self.slot_req[s].prompt
            pad = s_pad - len(prompt)
            tokens[s, pad:] = prompt
            start[s] = pad
            fill[s] = True
        # decode steps draw even fold_in streams, prefill batches odd ones
        key = jax.random.fold_in(self._key, 2 * self._prefill_idx + 1)
        self._prefill_idx += 1
        if self.profiler is not None:
            self._prefill_meta = {
                "arch": self.cfg.name,
                "prompts": [
                    (self.slot_req[s].rid, len(self.slot_req[s].prompt),
                     self.slot_req[s].max_new)
                    for s in newly
                ],
                "s_pad": s_pad,
                "filled": len(newly),
            }
        toks, self.caches = self._prefill(
            self.params, self.caches, jnp.asarray(tokens), jnp.asarray(start),
            jnp.asarray(fill), key)
        # analysis: host-sync ok — the one documented fetch per fill batch
        toks = np.asarray(toks)
        self.host_syncs += 1
        self.prefill_batches += 1
        for s in newly:
            req = self.slot_req[s]
            req.generated.append(int(toks[s]))
            self._last_tok[s] = toks[s]
            self.slot_pos[s] = s_pad
            self.slot_start[s] = start[s]
            if len(req.generated) >= req.max_new:
                req.done = True
                self.slot_req[s] = None

    def _step_fused(self, active) -> int:
        tokens = jnp.asarray(self._last_tok[:, None])
        positions = jnp.asarray(self.slot_pos)
        start = jnp.asarray(self.slot_start)
        key = jax.random.fold_in(self._key, 2 * self._step_idx)
        toks, self.caches = self._decode(
            self.params, tokens, self.caches, positions, start, key)
        self.decode_steps += 1
        self._step_idx += 1
        # analysis: host-sync ok — the single documented fetch of this step
        toks = np.asarray(toks)
        self.host_syncs += 1
        for s in active:
            req = self.slot_req[s]
            req.generated.append(int(toks[s]))
            self._last_tok[s] = toks[s]
            self.slot_pos[s] += 1
            # capacity boundary: slot_pos is the NEXT cache write offset,
            # so decoding may continue while slot_pos <= s_max - 1 (the
            # last cache slot is usable); `>= s_max - 1` here wasted it
            if len(req.generated) >= req.max_new or self.slot_pos[s] >= self.s_max:
                req.done = True
                req.truncated = len(req.generated) < req.max_new
                self.slot_req[s] = None
        return len(active)

    # -- legacy per-slot-loop baseline (benchmarks/bench_serve.py) ----------

    def _build_decode_looped(self):
        cfg = self.cfg

        def step(params, tokens, caches, positions):
            # the pre-ragged-decode formulation: a static per-slot Python
            # loop of single-row steps inside jit — the traced program
            # grows linearly with n_slots and recompiles when it changes.
            b = tokens.shape[0]
            flat, treedef = jax.tree_util.tree_flatten(caches)
            row_caches = [
                jax.tree_util.tree_unflatten(
                    treedef,
                    [leaf[:, i : i + 1] if leaf.ndim > 1 else leaf for leaf in flat],
                )
                for i in range(b)
            ]
            outs = []
            for i in range(b):
                lg, nc = serve_step(
                    params, tokens[i : i + 1], row_caches[i], positions[i], cfg
                )
                outs.append((lg, nc))
            logits = jnp.concatenate([o[0] for o in outs], axis=0)
            merged = jax.tree.map(
                lambda *rows: jnp.concatenate(rows, axis=1), *[o[1] for o in outs]
            )
            return logits, merged

        return jax.jit(step)

    def _fill_slots_looped(self):
        for s in range(self.n_slots):
            if self.slot_req[s] is None and self.queue:
                req = self.queue.pop(0)
                self.slot_req[s] = req
                # prefill this slot alone (recompiles per prompt length)
                prompt = jnp.asarray(req.prompt, jnp.int32)[None]
                flat, treedef = jax.tree_util.tree_flatten(self.caches)
                row = jax.tree_util.tree_unflatten(
                    treedef,
                    [leaf[:, s : s + 1] if leaf.ndim > 1 else leaf for leaf in flat],
                )
                logits, row = prefill(self.params, prompt, row, self.cfg)
                flat_row = jax.tree_util.tree_leaves(row)
                new_flat = []
                for leaf, rl in zip(flat, flat_row):
                    if leaf.ndim > 1:
                        leaf = jax.lax.dynamic_update_slice_in_dim(leaf, rl, s, axis=1)
                    new_flat.append(leaf)
                self.caches = jax.tree_util.tree_unflatten(treedef, new_flat)
                # analysis: host-sync ok — looped baseline syncs per slot by design
                tok = int(jnp.argmax(logits[0, -1]))
                self.host_syncs += 1
                self.prefill_batches += 1  # looped prefill is per-slot
                req.generated.append(tok)
                self._last_tok[s] = tok
                self.slot_pos[s] = len(req.prompt)
                self.slot_start[s] = 0
                if len(req.generated) >= req.max_new:
                    req.done = True
                    self.slot_req[s] = None

    def _step_looped(self, active) -> int:
        tokens = jnp.asarray(self._last_tok[:, None])
        logits, self.caches = self._decode(
            self.params, tokens, self.caches, jnp.asarray(self.slot_pos))
        self.decode_steps += 1
        self._step_idx += 1
        toks = jnp.argmax(logits[:, 0, :], axis=-1)
        for s in active:
            req = self.slot_req[s]
            tok = int(toks[s])  # one host sync per active slot
            self.host_syncs += 1
            req.generated.append(tok)
            self._last_tok[s] = tok
            self.slot_pos[s] += 1
            # same capacity boundary as _step_fused: finish at s_max, not
            # s_max - 1 (the last cache slot is a legal write target)
            if len(req.generated) >= req.max_new or self.slot_pos[s] >= self.s_max:
                req.done = True
                req.truncated = len(req.generated) < req.max_new
                self.slot_req[s] = None
        return len(active)

    # -- shared driver ------------------------------------------------------

    def submit(self, req: Request):
        if not req.prompt:
            raise ValueError(
                "empty prompt: serving needs at least one prompt token "
                "(the first sampled token conditions on it)"
            )
        if len(req.prompt) >= self.s_max:
            raise ValueError(
                f"prompt length {len(req.prompt)} does not fit a cache of "
                f"s_max={self.s_max} (needs at least one decode slot)"
            )
        self.queue.append(req)

    def cancel(self, request_id: int) -> bool:
        """Withdraw a request by rid: drop it from the queue, or — if it
        is mid-decode — free its slot so the next fill reuses it.

        Freeing a slot is exactly the completion path (``slot_req[s] =
        None``): the row keeps riding the fused step as a dead lane until
        refilled, its sampled tokens discarded like any finished slot's,
        and no other row's cache state or token stream is perturbed
        (pinned by tests/test_frontdoor.py). The request finishes with
        ``done=True, cancelled=True`` and keeps whatever it generated.

        Host-side bookkeeping only — call it between steps (the async
        front door applies cancels at the step boundary; see
        repro.serve.frontdoor.worker). Returns False when rid is not in
        flight (already finished, or never submitted)."""
        for i, req in enumerate(self.queue):
            if req.rid == request_id:
                del self.queue[i]
                req.done = True
                req.cancelled = True
                return True
        for s in range(self.n_slots):
            req = self.slot_req[s]
            if req is not None and req.rid == request_id:
                req.done = True
                req.cancelled = True
                req.truncated = len(req.generated) < req.max_new
                self.slot_req[s] = None
                return True
        return False

    def _fill_slots(self):
        if self.fused:
            self._fill_slots_fused()
        else:
            self._fill_slots_looped()

    def step(self) -> int:
        """One decode step over all active slots; returns #active."""
        self._fill_slots()
        active = [s for s in range(self.n_slots) if self.slot_req[s] is not None]
        if not active:
            return 0
        if self.fused:
            return self._step_fused(active)
        return self._step_looped(active)

    def stats(self) -> Dict[str, int]:
        return {
            "decode_steps": self.decode_steps,
            "host_syncs": self.host_syncs,
            "prefill_batches": self.prefill_batches,
        }

    def run(self) -> None:
        try:
            while self.queue or any(r is not None for r in self.slot_req):
                self.step()
        finally:
            if self._owns_profiler and self.profiler is not None:
                # the batcher opened the trace file (profile=<path>), so
                # it releases the handle; events stay readable mid-run
                # because the profiler flushes per event
                self.profiler.close()


# ---------------------------------------------------------------------------
# Tracing contracts (repro.analysis — DESIGN.md §10)
#
# The serving invariants the paper's throughput claims rest on, declared
# next to the engine that must uphold them:
#
#   * the fused decode step is ONE batched traced program: its equation
#     count is invariant to the slot count and the TP mesh size (the
#     per-slot python work of the looped baseline must never leak back
#     into the trace);
#   * no host callbacks inside the step — the single documented host
#     fetch (`np.asarray(toks)`) happens outside the jit boundary;
#   * no pad on uint8 operands — stored 2-bit planes enter kernels in
#     their prepare-time canonical layout.
# ---------------------------------------------------------------------------

from repro.analysis.contracts import (  # noqa: E402
    PrimRule,
    SkipTrace,
    TraceContract,
    register_trace_contract,
)


def _fused_step_point(quant_mode: str, cache_dtype: str = "bf16",
                      s_max: int = 32):
    """Build (fn, args) tracing the production fused decode step on the
    smoke serving arch under ``quant_mode`` (weights) and ``cache_dtype``
    (KV cache — DESIGN.md §13). TP variants trace under an installed
    ("data", "model") mesh, exactly like the engine's ``compress_tp``
    scoping."""

    def build(n_slots: int = 3, tp: int = 1):
        if jax.device_count() < tp:
            raise SkipTrace(
                f"needs {tp} devices, have {jax.device_count()} "
                f"(XLA_FLAGS=--xla_force_host_platform_device_count=8)"
            )
        from repro.models.layers import QuantConfig
        from repro.models.registry import get_config

        cfg = get_config("smollm-135m", smoke=True).replace(
            quant=QuantConfig(mode=quant_mode, cache_dtype=cache_dtype))
        params = T.init_params(jax.random.PRNGKey(0), cfg)
        caches = T.init_caches(cfg, n_slots, s_max)
        step = fused_decode_fn(cfg)
        args = (params, jnp.zeros((n_slots, 1), jnp.int32), caches,
                jnp.zeros((n_slots,), jnp.int32),
                jnp.zeros((n_slots,), jnp.int32), jax.random.PRNGKey(1))
        if tp == 1:
            return step, args

        from repro.dist import sharding as shd
        from repro.launch.mesh import make_tp_mesh

        mesh = make_tp_mesh(tp)

        def step_under_mesh(*a):
            prev = shd.tp_mesh()
            shd.set_tp_mesh(mesh)
            try:
                return step(*a)
            finally:
                shd.set_tp_mesh(prev)

        return step_under_mesh, args

    return build


_FUSED_STEP_CONTRACT = TraceContract(
    max_host_callbacks=0,
    no_pad_on_dtypes=("uint8",),
)

register_trace_contract(
    "serve.fused_decode_step",
    _fused_step_point("off"),
    _FUSED_STEP_CONTRACT,
    axes={"n_slots": (2, 6), "tp": (1, 2, 4)},
)

register_trace_contract(
    "serve.fused_decode_step.cim",
    _fused_step_point("cim"),
    _FUSED_STEP_CONTRACT,
    axes={"n_slots": (2, 6)},
)


# Quantized KV cache (DESIGN.md §13): the fused step over an int8 cache
# must never materialize a full-precision copy of the *stacked* cache —
# dequant stays fused (codes into the contractions, scales onto the
# score/prob matrices). The per-layer compute-dtype code conversion is
# inherent to the jnp path (rank-4 int8, one layer's codes at a time);
# the regression this rule catches is cache-level dequant: an integer
# code tensor shaped like the *stacked* cache (rank 5 with the
# contract's s_max at axis 2 — picked to collide with no legitimate
# dimension of the smoke arch) converted to a float tensor. Matching on
# the eqn's integer *input* keeps legitimate rank-5 float activations
# (the GQA score dot_general also carries s_max) out of scope.
_KVQ_S_MAX = 48


def _kvq_stacked_dequant(eqn) -> bool:
    import numpy as np  # local: predicate must stay import-light

    def stacked(v, pred):
        aval = getattr(v, "aval", None)
        return (hasattr(aval, "dtype") and pred(aval.dtype)
                and len(aval.shape) == 5 and aval.shape[2] == _KVQ_S_MAX)

    # int/uint stacked codes in AND a float tensor of the same stacked
    # shape out = the cache-level dequant. Control-flow eqns (scan
    # carries the int8 cache in and float logits out) don't match: their
    # float outputs are not stacked-cache shaped.
    if not any(stacked(v, lambda d: d in (np.int8, np.uint8))
               for v in eqn.invars):
        return False
    return any(stacked(v, lambda d: np.issubdtype(d, np.floating))
               for v in eqn.outvars)


register_trace_contract(
    "serve.fused_decode_step.kvq",
    _fused_step_point("off", cache_dtype="int8", s_max=_KVQ_S_MAX),
    TraceContract(
        max_host_callbacks=0,
        # int8 codes and ternary-packed uint8 planes both enter the
        # attention contractions in their stored layout — zero relayout
        no_pad_on_dtypes=("uint8", "int8"),
        forbid_prims=(
            PrimRule(
                rule="kvq-stacked-dequant",
                when=_kvq_stacked_dequant,
                reason="full-precision copy of the stacked quantized KV "
                       "cache — dequant must stay fused in the attention "
                       "contractions (DESIGN.md §13)",
            ),
        ),
        # future Pallas attention kernels must accumulate f32
        accum_dtype="float32",
    ),
    axes={"n_slots": (2, 6), "tp": (1, 2)},
)
