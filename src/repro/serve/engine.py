"""Serving: prefill/decode steps, sampling, and a continuous batcher.

``serve_step`` is the unit the dry-run lowers for the decode shape cells:
one new token for every sequence in the batch against a seq_len-deep KV
cache. ``prefill`` reuses the same cached block path with S > 1.

The ``ContinuousBatcher`` keeps a fixed pool of slots; finished sequences
are immediately replaced from the queue (slot-level continuous batching,
the standard production serving discipline), demonstrated end-to-end in
examples/serve_ternary.py.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core.execution import CiMExecSpec
from repro.models import transformer as T

PyTree = Any


def apply_exec_spec(cfg: ArchConfig, spec: Optional[CiMExecSpec]) -> ArchConfig:
    """Serve the model under an explicit CiM execution spec (e.g. a
    packed-bitplane backend or flavor II) without touching the
    architecture config: the spec overrides the QuantConfig's
    mode-derived dispatch in every dense layer.

    The stochastic sensing-error channel needs a per-layer PRNG key,
    which the model-assembly code does not thread — noisy specs are for
    direct ``api.execute`` / ``layers.dense(key=...)`` calls (see
    benchmarks/bench_accuracy.py), so they are rejected here up front
    rather than crashing inside the first forward.
    """
    if spec is None:
        return cfg
    if spec.error_prob > 0.0:
        raise ValueError(
            "serving does not thread PRNG keys into dense layers; use a "
            "spec with error_prob=0 here and drive the sensing-error "
            "channel through api.execute/layers.dense directly"
        )
    if spec.packing != "none":
        # dense() holds dense weights, so a packed spec re-packs every
        # weight inside every forward — functionally correct (this is
        # the equivalence-test path) but it realizes none of the packed
        # format's weight-traffic savings; that needs
        # prepare_for_spec + api.execute_packed over stored planes
        warnings.warn(
            f"serving under packing={spec.packing!r} packs weights "
            "per-forward (functional path only); use "
            "quant.prepare.prepare_for_spec + api.execute_packed for "
            "the stored-plane fast path",
            stacklevel=2,
        )
    # mode="off" short-circuits dense() before the spec is consulted —
    # upgrade it so the requested spec actually executes (ternarizing
    # weights/activations on the fly, like any quantized mode)
    mode = "cim" if cfg.quant.mode == "off" else cfg.quant.mode
    return cfg.replace(
        quant=dataclasses.replace(cfg.quant, mode=mode, exec_spec=spec)
    )


def sample(logits: jax.Array, key: jax.Array, temperature: float = 0.0) -> jax.Array:
    """logits: (B, 1, V) -> token ids (B, 1)."""
    if temperature == 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    scaled = logits.astype(jnp.float32) / temperature
    flat = scaled[:, 0, :]
    toks = jax.random.categorical(key, flat, axis=-1)
    return toks[:, None].astype(jnp.int32)


def prefill(
    params, tokens: jax.Array, caches, cfg: ArchConfig, enc: Optional[jax.Array] = None
) -> Tuple[jax.Array, PyTree]:
    """Run the prompt through the cached path (index 0). Returns
    (last_logits (B, 1, V), caches)."""
    logits, caches = T.decode_step(params, tokens, caches, jnp.int32(0), cfg, enc)
    return logits[:, -1:, :], caches


def serve_step(
    params,
    tokens: jax.Array,
    caches,
    index: jax.Array,
    cfg: ArchConfig,
    enc: Optional[jax.Array] = None,
) -> Tuple[jax.Array, PyTree]:
    """One decode step: tokens (B, 1) at cache position ``index``."""
    return T.decode_step(params, tokens, caches, index, cfg, enc)


def make_jit_serve_step(cfg: ArchConfig, donate_caches: bool = True):
    def f(params, tokens, caches, index, enc=None):
        return serve_step(params, tokens, caches, index, cfg, enc)

    return jax.jit(f, donate_argnums=(2,) if donate_caches else ())


def generate(
    params,
    prompt: jax.Array,
    cfg: ArchConfig,
    max_new: int = 16,
    s_max: int = 128,
    temperature: float = 0.0,
    key: Optional[jax.Array] = None,
    enc: Optional[jax.Array] = None,
    exec_spec: Optional[CiMExecSpec] = None,
) -> jax.Array:
    """Greedy/temperature generation (host loop — example/test path)."""
    cfg = apply_exec_spec(cfg, exec_spec)
    b, s0 = prompt.shape
    caches = T.init_caches(cfg, b, s_max)
    logits, caches = prefill(params, prompt, caches, cfg, enc)
    key = key if key is not None else jax.random.PRNGKey(0)
    step_fn = make_jit_serve_step(cfg)
    out = []
    tok = sample(logits, key, temperature)
    out.append(tok)
    for i in range(max_new - 1):
        key, sub = jax.random.split(key)
        logits, caches = step_fn(params, tok, caches, jnp.int32(s0 + i), enc)
        tok = sample(logits, sub, temperature)
        out.append(tok)
    return jnp.concatenate(out, axis=1)


# ---------------------------------------------------------------------------
# Continuous batching
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new: int
    generated: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ContinuousBatcher:
    """Slot-pool continuous batcher over the jitted serve step.

    Each slot owns a cache region (per-slot caches batched along axis 0 of
    every cache leaf). Finished slots are refilled without stalling the
    others; per-slot position indices make the single fused decode step
    valid for heterogeneous progress.
    """

    def __init__(
        self,
        params,
        cfg: ArchConfig,
        n_slots: int = 4,
        s_max: int = 128,
        exec_spec: Optional[CiMExecSpec] = None,
    ):
        self.params = params
        self.cfg = cfg = apply_exec_spec(cfg, exec_spec)
        self.n_slots = n_slots
        self.s_max = s_max
        self.caches = T.init_caches(cfg, n_slots, s_max)
        self.slot_req: List[Optional[Request]] = [None] * n_slots
        self.slot_pos = jnp.zeros((n_slots,), jnp.int32)
        self.queue: List[Request] = []
        self._decode = self._build_decode()

    def _build_decode(self):
        cfg = self.cfg

        def step(params, tokens, caches, positions):
            # Slots progress heterogeneously, so each row decodes at its
            # own cache position: a small static per-slot loop (slot count
            # is tiny) keeps the fused step jit-compatible.
            b = tokens.shape[0]
            flat, treedef = jax.tree_util.tree_flatten(caches)
            row_caches = [
                jax.tree_util.tree_unflatten(
                    treedef, [leaf[:, i : i + 1] if leaf.ndim > 1 else leaf for leaf in flat]
                )
                for i in range(b)
            ]
            outs = []
            for i in range(b):
                lg, nc = serve_step(
                    params, tokens[i : i + 1], row_caches[i], positions[i], cfg
                )
                outs.append((lg, nc))
            logits = jnp.concatenate([o[0] for o in outs], axis=0)
            merged = jax.tree.map(
                lambda *rows: jnp.concatenate(rows, axis=1), *[o[1] for o in outs]
            )
            return logits, merged

        return jax.jit(step)

    def submit(self, req: Request):
        self.queue.append(req)

    def _fill_slots(self):
        for s in range(self.n_slots):
            if self.slot_req[s] is None and self.queue:
                req = self.queue.pop(0)
                self.slot_req[s] = req
                # prefill this slot alone
                prompt = jnp.asarray(req.prompt, jnp.int32)[None]
                flat, treedef = jax.tree_util.tree_flatten(self.caches)
                row = jax.tree_util.tree_unflatten(
                    treedef, [leaf[:, s : s + 1] if leaf.ndim > 1 else leaf for leaf in flat]
                )
                logits, row = prefill(self.params, prompt, row, self.cfg)
                flat_row = jax.tree_util.tree_leaves(row)
                new_flat = []
                for leaf, rl in zip(flat, flat_row):
                    if leaf.ndim > 1:
                        leaf = jax.lax.dynamic_update_slice_in_dim(leaf, rl, s, axis=1)
                    new_flat.append(leaf)
                self.caches = jax.tree_util.tree_unflatten(treedef, new_flat)
                tok = int(jnp.argmax(logits[0, -1]))
                req.generated.append(tok)
                self.slot_pos = self.slot_pos.at[s].set(len(req.prompt))

    def step(self) -> int:
        """One decode step over all active slots; returns #active."""
        self._fill_slots()
        active = [s for s in range(self.n_slots) if self.slot_req[s] is not None]
        if not active:
            return 0
        tokens = jnp.asarray(
            [
                [self.slot_req[s].generated[-1]] if self.slot_req[s] else [0]
                for s in range(self.n_slots)
            ],
            jnp.int32,
        )
        logits, self.caches = self._decode(self.params, tokens, self.caches, self.slot_pos)
        toks = jnp.argmax(logits[:, 0, :], axis=-1)
        for s in active:
            req = self.slot_req[s]
            req.generated.append(int(toks[s]))
            self.slot_pos = self.slot_pos.at[s].add(1)
            if len(req.generated) >= req.max_new or int(self.slot_pos[s]) >= self.s_max - 1:
                req.done = True
                self.slot_req[s] = None
        return len(active)

    def run(self) -> None:
        while self.queue or any(r is not None for r in self.slot_req):
            self.step()
