"""Serving engine: prefill/decode, sampling, continuous batching."""
from repro.serve.engine import (  # noqa: F401
    ContinuousBatcher, Request, generate, make_jit_serve_step, prefill,
    sample, serve_step,
)
