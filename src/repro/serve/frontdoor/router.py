"""Multi-replica request router (DESIGN.md §12).

Fans front-door requests across N :class:`EngineWorker` replicas —
each an independent :class:`~repro.serve.engine.ContinuousBatcher`,
optionally on its own disjoint ``("data", "model")`` device mesh (see
:func:`repro.launch.mesh.make_replica_meshes`): replication across the
``data`` axis composes with each replica's internal TP sharding on
``model``.

Policy, deliberately boring:

  * **least-loaded dispatch** — a new request goes to the healthy,
    non-draining replica with the fewest in-flight requests (ties break
    to the lowest index, making single-replica and N-replica runs
    deterministic for tests);
  * **bounded admission** — total in-flight across replicas is capped;
    over the cap, :meth:`ReplicaRouter.submit` raises
    :class:`QueueFull`, which the HTTP layer maps to 429. Backpressure
    is explicit: the client is told now, rather than parked on an
    unbounded queue distorting every TTFT behind it;
  * **health/drain** — a draining or dead replica receives nothing new;
    its in-flight requests finish (drain) or error out (dead).

Request ids are allocated router-wide, so a rid names one request
across every replica, trace event and stats endpoint.
"""
from __future__ import annotations

import itertools
from typing import Any, Dict, List, Optional

from repro.serve.frontdoor.worker import EngineWorker, TrackedRequest


class QueueFull(RuntimeError):
    """Admission control rejected the request (total in-flight at the
    cap). Maps to HTTP 429 at the front door."""


class NoReplicaAvailable(RuntimeError):
    """Every replica is draining or dead. Maps to HTTP 503-ish 429
    (the front door treats it as a rejection, not a crash)."""


class ReplicaRouter:
    """Least-loaded dispatch over N workers with a global admission cap.
    All methods run on the event loop."""

    def __init__(self, workers: List[EngineWorker], queue_limit: int = 64):
        if not workers:
            raise ValueError("router needs at least one replica")
        if queue_limit < 1:
            raise ValueError(f"queue_limit must be >= 1, got {queue_limit}")
        self.workers = list(workers)
        self.queue_limit = int(queue_limit)
        self._rids = itertools.count()
        self._owner: Dict[int, EngineWorker] = {}

    # -- dispatch -----------------------------------------------------------

    @property
    def in_flight(self) -> int:
        return sum(w.load for w in self.workers)

    def _pick(self) -> Optional[EngineWorker]:
        live = [w for w in self.workers if not w.draining]
        if not live:
            return None
        return min(live, key=lambda w: (w.load, self.workers.index(w)))

    def submit(self, prompt: List[int], max_new: int) -> TrackedRequest:
        """Admit one request or raise. QueueFull/NoReplicaAvailable are
        backpressure (429); ValueError is a bad request (400)."""
        if self.in_flight >= self.queue_limit:
            raise QueueFull(
                f"{self.in_flight} requests in flight >= limit {self.queue_limit}")
        w = self._pick()
        if w is None:
            raise NoReplicaAvailable("all replicas draining")
        rid = next(self._rids)
        t = w.submit(rid, prompt, max_new)
        self._owner[rid] = w
        return t

    def cancel(self, rid: int) -> bool:
        """Cancel wherever the request landed; False for unknown/already
        finished rids (cancellation is idempotent at the front door)."""
        w = self._owner.get(rid)
        if w is None:
            return False
        ok = w.cancel(rid)
        if not ok:
            # already finished: drop the stale ownership entry
            self._owner.pop(rid, None)
        return ok

    def forget(self, rid: int) -> None:
        """Drop ownership bookkeeping once a request's stream closed."""
        self._owner.pop(rid, None)

    # -- lifecycle ----------------------------------------------------------

    def drain(self) -> None:
        for w in self.workers:
            w.drain()

    def stop(self) -> None:
        for w in self.workers:
            w.stop()

    def stats(self) -> Dict[str, Any]:
        return {
            "replicas": [w.stats() for w in self.workers],
            "in_flight": self.in_flight,
            "queue_limit": self.queue_limit,
        }
