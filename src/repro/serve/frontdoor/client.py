"""Stdlib asyncio client for the front door — the other end of
:mod:`repro.serve.frontdoor.protocol`.

Used by the front-door tests and ``benchmarks/bench_traffic.py`` so the
benchmark drives the *real* network path (TCP, HTTP upgrade, RFC 6455
masked client frames), not an in-process shortcut. Not a general
HTTP/WebSocket client: it speaks exactly the front door's dialect.
"""
from __future__ import annotations

import asyncio
import json
from typing import Any, Dict, List, Optional, Tuple

from repro.serve.frontdoor.protocol import (
    ProtocolError,
    ws_client_handshake,
    ws_encode_frame,
    ws_recv_json,
    ws_send_json,
    OP_CLOSE,
)


async def _read_http_response(
    reader: asyncio.StreamReader,
) -> Tuple[int, Dict[str, str], bytes]:
    """(status, headers, body) of one HTTP/1.1 response."""
    head = await reader.readuntil(b"\r\n\r\n")
    lines = head.decode("latin-1").split("\r\n")
    parts = lines[0].split(" ", 2)
    if len(parts) < 2 or not parts[0].startswith("HTTP/1."):
        raise ProtocolError(f"bad status line: {lines[0]!r}")
    status = int(parts[1])
    headers: Dict[str, str] = {}
    for line in lines[1:]:
        if line and ":" in line:
            k, v = line.split(":", 1)
            headers[k.strip().lower()] = v.strip()
    body = await reader.readexactly(int(headers.get("content-length", "0")))
    return status, headers, body


async def http_json(
    host: str, port: int, method: str, path: str,
    body: Optional[Any] = None,
) -> Tuple[int, Any]:
    """One HTTP request -> (status, decoded JSON body)."""
    reader, writer = await asyncio.open_connection(host, port)
    try:
        payload = b"" if body is None else json.dumps(body).encode("utf-8")
        req = (
            f"{method} {path} HTTP/1.1\r\n"
            f"Host: {host}:{port}\r\n"
            "Content-Type: application/json\r\n"
            f"Content-Length: {len(payload)}\r\n"
            "Connection: close\r\n\r\n"
        ).encode("latin-1") + payload
        writer.write(req)
        await writer.drain()
        status, _, resp = await _read_http_response(reader)
        return status, json.loads(resp.decode("utf-8")) if resp else None
    finally:
        writer.close()
        try:
            await writer.wait_closed()
        except (ConnectionError, RuntimeError):
            pass


class WSClient:
    """One upgraded ``/v1/stream`` socket. Client frames are masked per
    RFC 6455 §5.1."""

    def __init__(self, reader: asyncio.StreamReader,
                 writer: asyncio.StreamWriter):
        self.reader = reader
        self.writer = writer

    @classmethod
    async def connect(cls, host: str, port: int,
                      path: str = "/v1/stream") -> "WSClient":
        reader, writer = await asyncio.open_connection(host, port)
        req, expect_accept = ws_client_handshake(host, port, path)
        writer.write(req)
        await writer.drain()
        status, headers, _ = await _read_http_response(reader)
        if status != 101:
            writer.close()
            raise ProtocolError(f"upgrade refused: HTTP {status}")
        if headers.get("sec-websocket-accept") != expect_accept:
            writer.close()
            raise ProtocolError("bad Sec-WebSocket-Accept")
        return cls(reader, writer)

    async def send(self, obj: Any) -> None:
        await ws_send_json(self.writer, obj, mask=True)

    async def recv(self) -> Optional[Any]:
        """Next server message, or None when the server closed."""
        return await ws_recv_json(self.reader, self.writer, mask=True)

    async def close(self) -> None:
        try:
            self.writer.write(ws_encode_frame(OP_CLOSE, b"", mask=True))
            await self.writer.drain()
        except (ConnectionError, RuntimeError):
            pass
        self.writer.close()
        try:
            await self.writer.wait_closed()
        except (ConnectionError, RuntimeError):
            pass

    # -- conveniences for tests / bench -------------------------------------

    async def generate(self, prompt: List[int], max_new: int,
                       cancel_after: Optional[int] = None) -> Dict[str, Any]:
        """Run one streamed request to completion; returns ``{"rid",
        "tokens": [...], "done": {...}}``. With ``cancel_after=k``, sends
        a cancel once ``k`` tokens arrived — the result then carries the
        partial stream and ``done["cancelled"] is True``.

        Raises RuntimeError on a server-side rejection (queue_full /
        bad_request) with the error payload attached."""
        await self.send({"type": "generate",
                         "prompt": list(prompt), "max_new": int(max_new)})
        rid: Optional[int] = None
        tokens: List[int] = []
        cancel_sent = False
        while True:
            msg = await self.recv()
            if msg is None:
                raise RuntimeError("server closed mid-stream")
            mtype = msg.get("type")
            if mtype == "admitted":
                rid = msg["rid"]
            elif mtype == "token":
                tokens.append(msg["token"])
                if (cancel_after is not None and not cancel_sent
                        and len(tokens) >= cancel_after):
                    await self.send({"type": "cancel", "rid": rid})
                    cancel_sent = True
            elif mtype == "done":
                return {"rid": rid, "tokens": tokens, "done": msg}
            elif mtype == "cancel_ack":
                continue
            elif mtype == "error":
                err = RuntimeError(f"request rejected: {msg.get('error')}")
                err.payload = msg  # type: ignore[attr-defined]
                raise err
            else:
                raise ProtocolError(f"unexpected message {mtype!r}")
