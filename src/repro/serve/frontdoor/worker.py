"""One engine replica under the async front door (DESIGN.md §12).

An :class:`EngineWorker` owns one :class:`~repro.serve.engine.
ContinuousBatcher` and drives its host loop from a dedicated
single-thread executor so the event loop never blocks on a jitted
dispatch: the coroutine :meth:`EngineWorker.run` awaits one
``batcher.step()`` at a time in the worker thread, then — back on the
event loop, with no step in flight — drains newly generated tokens into
per-request asyncio queues and applies any pending cancellations at the
step boundary (``ContinuousBatcher.cancel`` is host-side bookkeeping
and must not race a step that is reading the slot table).

The async layer adds **nothing** inside the jitted step: the only thing
it ever applies to the engine's step callable is
:func:`passthrough_step` (the identity), and the
``serve.frontdoor.step_passthrough`` tracing contract below pins that
the fused decode step's jaxpr is equation-for-equation identical when
passed through it. Every device->host fetch stays the engine's own
(one per fused step, one per prefill batch — DESIGN.md §6); the worker
reads only host-side python state (``Request.generated`` lists of
ints), so serving over the network changes neither the host-sync count
nor the traced program.
"""
from __future__ import annotations

import asyncio
import dataclasses
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Any, Dict, List, Optional, Set, Tuple

from repro.serve.engine import ContinuousBatcher, Request
from repro.serve.frontdoor.slo import RequestSLO, SLOTracker, now_us


def passthrough_step(fn):
    """The identity — and deliberately so. This is the single seam the
    front door applies to the engine's step callable before scheduling
    it on the worker thread; keeping it a named function (rather than
    nothing) gives the ``serve.frontdoor.step_passthrough`` contract a
    concrete subject: the fused step's jaxpr must be identical through
    this wrapper, so any future "just a little timing inside the step"
    change turns the analysis ratchet red instead of silently growing
    the traced program."""
    return fn


# analysis: dataclass-unregistered ok — event-loop bookkeeping, never jitted
@dataclasses.dataclass
class TrackedRequest:
    """Event-loop-side view of one in-flight engine request."""

    req: Request
    slo: RequestSLO
    stream: "asyncio.Queue[Tuple[str, Any]]"
    delivered: int = 0
    dispatched: bool = False


class EngineWorker:
    """Drives one batcher replica; owns its submission/cancel/token
    plumbing. All public methods run on the event loop."""

    def __init__(self, name: str, batcher: ContinuousBatcher,
                 tracker: SLOTracker, pace_us: float = 0.0):
        self.name = name
        self.batcher = batcher
        self.tracker = tracker
        # modeled per-step device latency (benchmarks/bench_traffic.py):
        # slept in the replica's worker thread AFTER each real engine
        # step, with the GIL released — the way accelerator compute
        # occupies a device without occupying the host. On a CPU host
        # the functional steps of every replica share the same cores, so
        # replica scaling is only observable against the modeled device
        # time; 0 disables (the production default).
        self.pace_us = float(pace_us)
        self._tracked: Dict[int, TrackedRequest] = {}
        self._pending_cancels: Set[int] = set()
        self._wake = asyncio.Event()
        self._stopping = False
        self.draining = False
        self.steps = 0
        # one thread: engine steps serialize per replica (the batcher is
        # not reentrant), replicas step concurrently across workers
        self._pool = ThreadPoolExecutor(
            max_workers=1, thread_name_prefix=f"engine-{name}")

    # -- submission / cancellation (event loop) -----------------------------

    @property
    def load(self) -> int:
        """In-flight request count (queued + active slots) — the
        router's least-loaded dispatch key."""
        return len(self._tracked)

    def submit(self, rid: int, prompt: List[int], max_new: int) -> TrackedRequest:
        """Hand one request to the engine. Raises ValueError for
        unservable prompts (empty / over s_max — the engine's own
        checks), RuntimeError when draining/stopped."""
        if self.draining or self._stopping:
            raise RuntimeError(f"replica {self.name} is draining")
        req = Request(rid, list(prompt), max_new=int(max_new))
        # batcher.submit validates before touching engine state, so a
        # rejected prompt leaves no tracking residue
        self.batcher.submit(req)
        t = TrackedRequest(
            req=req,
            slo=RequestSLO(rid=rid, replica=self.name,
                           prompt_len=len(req.prompt), max_new=req.max_new,
                           t_admit_us=now_us()),
            stream=asyncio.Queue(),
        )
        self._tracked[rid] = t
        self._wake.set()
        return t

    def cancel(self, rid: int) -> bool:
        """Request cancellation of ``rid``; applied at the next step
        boundary (the engine's slot table must not change under a
        running step). Returns False when rid is not in flight here."""
        if rid not in self._tracked:
            return False
        self._pending_cancels.add(rid)
        self._wake.set()
        return True

    def drain(self) -> None:
        """Stop accepting new requests; in-flight requests finish."""
        self.draining = True
        self._wake.set()

    def stop(self) -> None:
        """Drain and let :meth:`run` exit once in-flight work is done."""
        self.draining = True
        self._stopping = True
        self._wake.set()

    def stats(self) -> Dict[str, Any]:
        s = self.batcher.stats()
        s.update({
            "name": self.name,
            "load": self.load,
            "queue_len": len(self.batcher.queue),
            "slots_active": sum(r is not None for r in self.batcher.slot_req),
            "n_slots": self.batcher.n_slots,
            "draining": self.draining,
        })
        return s

    # -- the engine loop ----------------------------------------------------

    def _has_work(self) -> bool:
        return bool(self.batcher.queue) or any(
            r is not None for r in self.batcher.slot_req)

    async def run(self) -> None:
        """The replica's engine loop: step in the worker thread, drain
        tokens on the event loop, sleep when idle. Exits after
        :meth:`stop` once every in-flight request finished."""
        loop = asyncio.get_running_loop()
        step = passthrough_step(self.batcher.step)
        if self.pace_us > 0:
            real_step, pace_s = step, self.pace_us * 1e-6

            def step():
                real_step()
                time.sleep(pace_s)  # modeled device time, off the GIL
        try:
            while True:
                self._apply_cancels()
                if self._has_work():
                    await loop.run_in_executor(self._pool, step)
                    self.steps += 1
                    self._drain_tokens()
                elif self._stopping:
                    break
                else:
                    self._wake.clear()
                    # woken by submit/cancel/drain/stop
                    await self._wake.wait()
        except Exception as e:  # engine died: fail every open stream
            for t in list(self._tracked.values()):
                t.stream.put_nowait(("error", f"engine error: {e!r}"))
            self._tracked.clear()
            raise
        finally:
            self._pool.shutdown(wait=True)

    def _apply_cancels(self) -> None:
        """Engine-level cancel between steps; finalization (the 'done'
        sentinel with cancelled=True) rides the same drain path as
        normal completion."""
        if not self._pending_cancels:
            return
        for rid in sorted(self._pending_cancels):
            self.batcher.cancel(rid)
        self._pending_cancels.clear()
        self._drain_tokens()

    def _drain_tokens(self) -> None:
        """Move newly generated tokens from engine Requests into the
        per-request streams; finalize finished requests. Runs only when
        no step is in flight, so reading engine state is race-free."""
        now = now_us()
        in_queue = {r.rid for r in self.batcher.queue}
        for rid in list(self._tracked):
            t = self._tracked[rid]
            if not t.dispatched and rid not in in_queue:
                t.slo.mark_dispatch(now)
                t.dispatched = True
            gen = t.req.generated
            while t.delivered < len(gen):
                tok = gen[t.delivered]
                t.delivered += 1
                t.slo.mark_token(now)
                t.stream.put_nowait(("token", int(tok)))
            if t.req.done:
                t.slo.mark_done(cancelled=t.req.cancelled,
                                truncated=t.req.truncated, t_us=now)
                self.tracker.finish(t.slo)
                t.stream.put_nowait(("done", {
                    "rid": rid,
                    "tokens": t.slo.tokens,
                    "cancelled": t.req.cancelled,
                    "truncated": t.req.truncated,
                    "ttft_us": round(t.slo.ttft_us or 0.0, 1),
                    "queue_wait_us": round(t.slo.queue_wait_us or 0.0, 1),
                    "e2e_us": round(t.slo.e2e_us or 0.0, 1),
                    "replica": self.name,
                }))
                del self._tracked[rid]


# ---------------------------------------------------------------------------
# Tracing contract (repro.analysis — DESIGN.md §10/§12)
#
# The front door must be invisible to the traced program: the fused
# decode step passed through passthrough_step (the only wrapper the
# worker ever applies to the engine callable) has the identical jaxpr —
# one equation count across the wrapped axis, zero host callbacks.
# ---------------------------------------------------------------------------

from repro.analysis.contracts import (  # noqa: E402
    TraceContract,
    register_trace_contract,
)


def _passthrough_point():
    def build(wrapped: int = 0):
        import jax
        import jax.numpy as jnp

        from repro.models import transformer as T
        from repro.models.layers import QuantConfig
        from repro.models.registry import get_config
        from repro.serve.engine import fused_decode_fn

        n_slots = 3
        cfg = get_config("smollm-135m", smoke=True).replace(
            quant=QuantConfig(mode="off"))
        params = T.init_params(jax.random.PRNGKey(0), cfg)
        caches = T.init_caches(cfg, n_slots, 32)
        step = fused_decode_fn(cfg)
        if wrapped:
            step = passthrough_step(step)
        args = (params, jnp.zeros((n_slots, 1), jnp.int32), caches,
                jnp.zeros((n_slots,), jnp.int32),
                jnp.zeros((n_slots,), jnp.int32), jax.random.PRNGKey(1))
        return step, args

    return build


register_trace_contract(
    "serve.frontdoor.step_passthrough",
    _passthrough_point(),
    TraceContract(max_host_callbacks=0),
    axes={"wrapped": (0, 1)},
)
