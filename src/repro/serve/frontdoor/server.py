"""The async serving front door: HTTP + WebSocket over the router
(DESIGN.md §12).

One asyncio server, four routes:

  * ``GET /healthz``      — liveness + replica count;
  * ``GET /stats``        — SLO aggregates (p50/p99 TTFT, queue wait,
    per-token latency, goodput) and per-replica engine counters
    (decode_steps, host_syncs, prefill_batches, load);
  * ``POST /v1/generate`` — one-shot JSON: submit, wait, return every
    token. 429 + ``{"error": "queue_full"}`` when admission control
    rejects;
  * ``GET /v1/stream``    — WebSocket. Client sends ``{"type":
    "generate", "prompt": [...], "max_new": n}``; server answers
    ``admitted``, then one ``token`` message per generated token as the
    engine produces it, then ``done``. A client ``{"type": "cancel"}``
    (or dropping the connection) withdraws the request — the engine
    slot frees at the next step boundary and decode continues
    undisturbed for every other request.

The front door is pure host-side asyncio: it owns no device arrays and
never calls into jax. Engine work happens in the per-replica worker
threads (:mod:`repro.serve.frontdoor.worker`); this module only moves
ints and JSON between sockets and asyncio queues.
"""
from __future__ import annotations

import asyncio
from typing import Any, Dict, List, Optional, Tuple

from repro.serve.frontdoor.protocol import (
    CLOSE_PROTOCOL_ERROR,
    ProtocolError,
    http_response,
    is_ws_upgrade,
    json_response,
    read_http_request,
    ws_close_frame,
    ws_handshake_response,
    ws_recv_json,
    ws_send_json,
)
from repro.serve.frontdoor.router import (
    NoReplicaAvailable,
    QueueFull,
    ReplicaRouter,
)
from repro.serve.frontdoor.slo import SLOTracker
from repro.serve.frontdoor.worker import TrackedRequest


class FrontDoor:
    """Binds the router to a TCP port and speaks the wire protocol.

    ``port=0`` binds an ephemeral port (tests, bench) — read the real
    one from :attr:`port` after :meth:`start`.
    """

    def __init__(self, router: ReplicaRouter, tracker: SLOTracker,
                 host: str = "127.0.0.1", port: int = 0):
        self.router = router
        self.tracker = tracker
        self.host = host
        self.port = port
        self._server: Optional[asyncio.AbstractServer] = None
        self._worker_tasks: List[asyncio.Task] = []

    # -- lifecycle ----------------------------------------------------------

    async def start(self) -> Tuple[str, int]:
        """Start the replica engine loops and the TCP listener."""
        self._worker_tasks = [
            asyncio.create_task(w.run(), name=f"engine-{w.name}")
            for w in self.router.workers
        ]
        self._server = await asyncio.start_server(
            self._handle_conn, self.host, self.port)
        self.port = self._server.sockets[0].getsockname()[1]
        return self.host, self.port

    async def stop(self) -> None:
        """Clean shutdown: stop admitting, let in-flight requests finish,
        join every engine loop, close the listener."""
        self.router.stop()
        if self._worker_tasks:
            await asyncio.gather(*self._worker_tasks, return_exceptions=True)
            self._worker_tasks = []
        if self._server is not None:
            self._server.close()
            await self._server.wait_closed()
            self._server = None

    # -- connection handling ------------------------------------------------

    async def _handle_conn(self, reader: asyncio.StreamReader,
                           writer: asyncio.StreamWriter) -> None:
        try:
            while True:
                req = await read_http_request(reader)
                if req is None:
                    break  # peer closed the keep-alive connection
                if is_ws_upgrade(req):
                    if req.path != "/v1/stream":
                        writer.write(json_response(
                            404, {"error": "not_found", "path": req.path}))
                        await writer.drain()
                        break
                    writer.write(ws_handshake_response(req))
                    await writer.drain()
                    await self._ws_session(reader, writer)
                    break  # a socket never downgrades back to HTTP
                await self._http_request(req, writer)
        except ProtocolError as e:
            try:
                writer.write(json_response(
                    400, {"error": "bad_request", "detail": str(e)}))
                await writer.drain()
            except (ConnectionError, RuntimeError):
                pass
        except (ConnectionError, asyncio.IncompleteReadError):
            pass  # peer vanished; per-request cancel handled in the session
        finally:
            writer.close()
            try:
                await writer.wait_closed()
            except (ConnectionError, RuntimeError):
                pass

    # -- plain HTTP ---------------------------------------------------------

    async def _http_request(self, req, writer: asyncio.StreamWriter) -> None:
        if req.method == "GET" and req.path == "/healthz":
            writer.write(json_response(200, {
                "ok": True,
                "replicas": len(self.router.workers),
            }))
        elif req.method == "GET" and req.path == "/stats":
            writer.write(json_response(200, self.stats()))
        elif req.method == "POST" and req.path == "/v1/generate":
            writer.write(await self._generate_oneshot(req))
        elif req.path in ("/healthz", "/stats", "/v1/generate"):
            writer.write(http_response(405, b'{"error": "method_not_allowed"}'))
        else:
            writer.write(json_response(
                404, {"error": "not_found", "path": req.path}))
        await writer.drain()

    def stats(self) -> Dict[str, Any]:
        return {"slo": self.tracker.summary(), "router": self.router.stats()}

    def _submit(self, body: Dict[str, Any]) -> TrackedRequest:
        """Validate + admit. Raises ProtocolError (400), QueueFull /
        NoReplicaAvailable (429)."""
        try:
            prompt = [int(t) for t in body["prompt"]]
            max_new = int(body.get("max_new", 16))
        except (KeyError, TypeError, ValueError):
            raise ProtocolError(
                "body must be {'prompt': [int, ...], 'max_new': int}"
            ) from None
        try:
            t = self.router.submit(prompt, max_new)
        except ValueError as e:  # engine rejected the prompt shape
            raise ProtocolError(str(e)) from None
        self.tracker.admit()
        return t

    async def _generate_oneshot(self, req) -> bytes:
        try:
            t = self._submit(req.json())
        except (QueueFull, NoReplicaAvailable) as e:
            self.tracker.reject()
            return json_response(429, {"error": "queue_full", "detail": str(e)})
        except ProtocolError as e:
            return json_response(400, {"error": "bad_request", "detail": str(e)})
        tokens: List[int] = []
        while True:
            kind, payload = await t.stream.get()
            if kind == "token":
                tokens.append(payload)
            elif kind == "done":
                self.router.forget(t.req.rid)
                # the done payload's "tokens" field is the count — the
                # one-shot body carries the ids themselves
                return json_response(
                    200, {**payload, "n_tokens": payload["tokens"],
                          "tokens": tokens})
            else:  # engine error
                self.router.forget(t.req.rid)
                return json_response(500, {"error": "engine", "detail": payload})

    # -- WebSocket streaming ------------------------------------------------

    async def _ws_session(self, reader: asyncio.StreamReader,
                          writer: asyncio.StreamWriter) -> None:
        """One upgraded socket: sequential ``generate`` requests, tokens
        streamed as produced, ``cancel`` honored mid-stream, connection
        drop treated as cancel."""
        recv: asyncio.Task = asyncio.create_task(ws_recv_json(reader, writer))
        pump: Optional[asyncio.Task] = None
        active_rid: Optional[int] = None
        try:
            while True:
                waits = {recv} if pump is None else {recv, pump}
                done, _ = await asyncio.wait(
                    waits, return_when=asyncio.FIRST_COMPLETED)
                if pump is not None and pump in done:
                    exc = pump.exception()
                    if exc is not None:
                        # socket died mid-stream: withdraw the request so
                        # its slot frees at the next step boundary
                        self.router.cancel(active_rid)
                    self.router.forget(active_rid)
                    pump, active_rid = None, None
                    if exc is not None:
                        return
                if recv not in done:
                    continue
                msg = recv.result()
                if msg is None:
                    return  # peer closed/hung up; finally-cancel below
                recv = asyncio.create_task(ws_recv_json(reader, writer))
                mtype = msg.get("type") if isinstance(msg, dict) else None
                if mtype == "cancel":
                    rid = msg.get("rid", active_rid)
                    ok = rid is not None and self.router.cancel(rid)
                    await ws_send_json(writer, {
                        "type": "cancel_ack", "rid": rid, "cancelled": bool(ok)})
                elif mtype == "generate":
                    if pump is not None:
                        await ws_send_json(writer, {
                            "type": "error", "error": "busy",
                            "detail": "one active request per stream"})
                        continue
                    try:
                        t = self._submit(msg)
                    except (QueueFull, NoReplicaAvailable) as e:
                        self.tracker.reject()
                        await ws_send_json(writer, {
                            "type": "error", "error": "queue_full",
                            "detail": str(e)})
                        continue
                    except ProtocolError as e:
                        await ws_send_json(writer, {
                            "type": "error", "error": "bad_request",
                            "detail": str(e)})
                        continue
                    active_rid = t.req.rid
                    await ws_send_json(writer, {
                        "type": "admitted", "rid": active_rid,
                        "replica": t.slo.replica})
                    pump = asyncio.create_task(self._pump(t, writer))
                else:
                    await ws_send_json(writer, {
                        "type": "error", "error": "bad_request",
                        "detail": f"unknown message type {mtype!r}"})
        except ProtocolError:
            # malformed frame (fragmented, reserved bits, bad opcode,
            # non-JSON text): tell the peer why with close code 1002
            # before teardown — the finally below still reclaims the
            # admission slot of any in-flight request
            try:
                writer.write(ws_close_frame(CLOSE_PROTOCOL_ERROR))
                await writer.drain()
            except (ConnectionError, RuntimeError):
                pass
        except ConnectionError:
            pass
        finally:
            recv.cancel()
            if pump is not None:
                pump.cancel()
            if active_rid is not None:
                # connection died with a request in flight: free its slot
                self.router.cancel(active_rid)
                self.router.forget(active_rid)

    async def _pump(self, t: TrackedRequest,
                    writer: asyncio.StreamWriter) -> None:
        """Forward one request's stream (tokens, then done) to the
        socket as the engine produces them."""
        rid, idx = t.req.rid, 0
        while True:
            kind, payload = await t.stream.get()
            if kind == "token":
                await ws_send_json(writer, {
                    "type": "token", "rid": rid, "index": idx,
                    "token": payload})
                idx += 1
            elif kind == "done":
                await ws_send_json(writer, {"type": "done", **payload})
                return
            else:
                await ws_send_json(writer, {
                    "type": "error", "error": "engine", "rid": rid,
                    "detail": payload})
                return
