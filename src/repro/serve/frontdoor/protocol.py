"""Minimal HTTP/1.1 + WebSocket (RFC 6455) wire protocol over asyncio
streams — the front door's only network layer (DESIGN.md §12).

Stdlib-only by design: the serving CI installs jax + numpy and nothing
else, and the protocol surface the front door needs is tiny — parse one
request head, write one response, upgrade to a WebSocket and exchange
small single-frame text messages. Both the server side (handshake
accept, unmasked frames out, masked frames in) and the client side
(handshake offer, masked frames out — used by the tests and
``benchmarks/bench_traffic.py``) live here so the two ends can never
drift apart.

Deliberate non-goals: frame fragmentation (every message the front door
exchanges fits one frame; fragmented input raises), extensions,
compression, TLS. Control frames are handled per the RFC: ping is
answered with pong, close with close.
"""
from __future__ import annotations

import asyncio
import base64
import dataclasses
import hashlib
import json
import os
import struct
from typing import Any, Dict, Mapping, Optional, Tuple

#: RFC 6455 §1.3 — the fixed GUID appended to the client key
WS_GUID = "258EAFA5-E914-47DA-95CA-C5AB0DC85B11"

#: WebSocket frame opcodes (the subset the front door speaks)
OP_TEXT, OP_CLOSE, OP_PING, OP_PONG = 0x1, 0x8, 0x9, 0xA

#: request-head size cap: the front door's JSON bodies are token-id
#: lists, never bulk payloads — anything bigger is a client bug
MAX_HEAD_BYTES = 64 * 1024
MAX_BODY_BYTES = 4 * 1024 * 1024

_STATUS_TEXT = {
    200: "OK", 400: "Bad Request", 404: "Not Found",
    405: "Method Not Allowed", 429: "Too Many Requests",
    500: "Internal Server Error",
}


class ProtocolError(ValueError):
    """Malformed HTTP head or WebSocket frame."""


# analysis: dataclass-unregistered ok — wire-protocol host object, never jitted
@dataclasses.dataclass
class HTTPRequest:
    """One parsed request head (+ body when Content-Length was sent).
    Header names are lower-cased; values keep their wire form."""

    method: str
    path: str
    headers: Dict[str, str]
    body: bytes = b""

    def json(self) -> Any:
        """Decode the body as JSON (raises ProtocolError, not
        JSONDecodeError, so handlers map it to a 400 uniformly)."""
        try:
            return json.loads(self.body.decode("utf-8"))
        except (UnicodeDecodeError, json.JSONDecodeError) as e:
            raise ProtocolError(f"bad JSON body: {e}") from None


async def read_http_request(reader: asyncio.StreamReader) -> Optional[HTTPRequest]:
    """Read one request head (and its Content-Length body) from the
    stream. Returns None on a clean EOF before any bytes (keep-alive
    connection closed by the peer)."""
    try:
        head = await reader.readuntil(b"\r\n\r\n")
    except asyncio.IncompleteReadError as e:
        if not e.partial:
            return None
        raise ProtocolError("connection closed mid-request-head") from None
    except asyncio.LimitOverrunError:
        raise ProtocolError("request head exceeds stream limit") from None
    if len(head) > MAX_HEAD_BYTES:
        raise ProtocolError(f"request head over {MAX_HEAD_BYTES} bytes")
    lines = head.decode("latin-1").split("\r\n")
    parts = lines[0].split(" ")
    if len(parts) != 3 or not parts[2].startswith("HTTP/1."):
        raise ProtocolError(f"bad request line: {lines[0]!r}")
    method, path = parts[0].upper(), parts[1]
    headers: Dict[str, str] = {}
    for line in lines[1:]:
        if not line:
            continue
        if ":" not in line:
            raise ProtocolError(f"bad header line: {line!r}")
        k, v = line.split(":", 1)
        headers[k.strip().lower()] = v.strip()
    body = b""
    if "content-length" in headers:
        try:
            n = int(headers["content-length"])
        except ValueError:
            raise ProtocolError("bad Content-Length") from None
        if n < 0 or n > MAX_BODY_BYTES:
            raise ProtocolError(f"Content-Length {n} out of range")
        body = await reader.readexactly(n)
    return HTTPRequest(method=method, path=path, headers=headers, body=body)


def http_response(
    status: int,
    body: bytes = b"",
    *,
    content_type: str = "application/json",
    extra_headers: Mapping[str, str] = (),
) -> bytes:
    """Serialize one HTTP/1.1 response (Connection: keep-alive — the
    front door serves many requests per connection)."""
    lines = [
        f"HTTP/1.1 {status} {_STATUS_TEXT.get(status, 'Unknown')}",
        f"Content-Type: {content_type}",
        f"Content-Length: {len(body)}",
        "Connection: keep-alive",
    ]
    for k, v in dict(extra_headers).items():
        lines.append(f"{k}: {v}")
    return ("\r\n".join(lines) + "\r\n\r\n").encode("latin-1") + body


def json_response(status: int, payload: Any,
                  extra_headers: Mapping[str, str] = ()) -> bytes:
    return http_response(
        status, json.dumps(payload, sort_keys=True).encode("utf-8"),
        extra_headers=extra_headers)


# ---------------------------------------------------------------------------
# WebSocket handshake
# ---------------------------------------------------------------------------


def ws_accept_key(client_key: str) -> str:
    """Sec-WebSocket-Accept for a client's Sec-WebSocket-Key."""
    digest = hashlib.sha1((client_key + WS_GUID).encode("latin-1")).digest()
    return base64.b64encode(digest).decode("latin-1")


def is_ws_upgrade(req: HTTPRequest) -> bool:
    return (
        req.headers.get("upgrade", "").lower() == "websocket"
        and "upgrade" in req.headers.get("connection", "").lower()
        and "sec-websocket-key" in req.headers
    )


def ws_handshake_response(req: HTTPRequest) -> bytes:
    """The 101 Switching Protocols reply to a valid upgrade request."""
    key = req.headers["sec-websocket-key"]
    return (
        "HTTP/1.1 101 Switching Protocols\r\n"
        "Upgrade: websocket\r\n"
        "Connection: Upgrade\r\n"
        f"Sec-WebSocket-Accept: {ws_accept_key(key)}\r\n\r\n"
    ).encode("latin-1")


def ws_client_handshake(host: str, port: int, path: str) -> Tuple[bytes, str]:
    """(request bytes, expected Sec-WebSocket-Accept) for a client
    upgrade offer."""
    key = base64.b64encode(os.urandom(16)).decode("latin-1")
    req = (
        f"GET {path} HTTP/1.1\r\n"
        f"Host: {host}:{port}\r\n"
        "Upgrade: websocket\r\n"
        "Connection: Upgrade\r\n"
        f"Sec-WebSocket-Key: {key}\r\n"
        "Sec-WebSocket-Version: 13\r\n\r\n"
    ).encode("latin-1")
    return req, ws_accept_key(key)


# ---------------------------------------------------------------------------
# WebSocket framing
# ---------------------------------------------------------------------------


def ws_encode_frame(opcode: int, payload: bytes, *, mask: bool) -> bytes:
    """One FIN frame. Servers send unmasked, clients masked (RFC 6455
    §5.1 — a server MUST close on an unmasked client frame, so the
    client side here always masks)."""
    head = bytearray([0x80 | (opcode & 0x0F)])
    n = len(payload)
    mask_bit = 0x80 if mask else 0x00
    if n < 126:
        head.append(mask_bit | n)
    elif n < (1 << 16):
        head.append(mask_bit | 126)
        head += struct.pack(">H", n)
    else:
        head.append(mask_bit | 127)
        head += struct.pack(">Q", n)
    if mask:
        key = os.urandom(4)
        head += key
        payload = bytes(b ^ key[i % 4] for i, b in enumerate(payload))
    return bytes(head) + payload


async def ws_read_frame(reader: asyncio.StreamReader) -> Tuple[int, bytes]:
    """Read one frame -> (opcode, unmasked payload). Raises
    ProtocolError on fragmentation (FIN=0) or reserved bits; EOF mid-
    frame raises IncompleteReadError (callers treat it as a dropped
    peer)."""
    b0, b1 = await reader.readexactly(2)
    fin, opcode = b0 & 0x80, b0 & 0x0F
    if not fin or b0 & 0x70:
        raise ProtocolError("fragmented/reserved-bit WebSocket frame")
    masked, n = b1 & 0x80, b1 & 0x7F
    if n == 126:
        (n,) = struct.unpack(">H", await reader.readexactly(2))
    elif n == 127:
        (n,) = struct.unpack(">Q", await reader.readexactly(8))
    if n > MAX_BODY_BYTES:
        raise ProtocolError(f"WebSocket frame over {MAX_BODY_BYTES} bytes")
    key = await reader.readexactly(4) if masked else None
    payload = await reader.readexactly(n)
    if key is not None:
        payload = bytes(b ^ key[i % 4] for i, b in enumerate(payload))
    return opcode, payload


#: RFC 6455 §7.4.1 close codes the front door uses
CLOSE_NORMAL, CLOSE_PROTOCOL_ERROR = 1000, 1002


def ws_close_frame(code: int = CLOSE_NORMAL, reason: bytes = b"",
                   *, mask: bool = False) -> bytes:
    """One close frame with a status code payload (RFC 6455 §5.5.1 —
    the first two payload bytes are the code, big-endian). The server
    answers malformed frames with code 1002 before dropping the
    connection so conforming clients see *why* instead of a bare TCP
    reset."""
    return ws_encode_frame(OP_CLOSE, struct.pack(">H", code) + reason,
                           mask=mask)


def ws_close_code(payload: bytes) -> Optional[int]:
    """Status code of a close-frame payload (None when absent — an
    empty close payload is legal)."""
    if len(payload) < 2:
        return None
    return struct.unpack(">H", payload[:2])[0]


async def ws_send_json(writer: asyncio.StreamWriter, obj: Any,
                       *, mask: bool = False) -> None:
    data = json.dumps(obj, sort_keys=True).encode("utf-8")
    writer.write(ws_encode_frame(OP_TEXT, data, mask=mask))
    await writer.drain()


async def ws_recv_json(
    reader: asyncio.StreamReader, writer: asyncio.StreamWriter,
    *, mask: bool = False,
) -> Optional[Any]:
    """Next text message as decoded JSON, transparently answering pings.
    Returns None when the peer sent close (a close reply is echoed) or
    hung up."""
    while True:
        try:
            opcode, payload = await ws_read_frame(reader)
        except (asyncio.IncompleteReadError, ConnectionError):
            return None
        if opcode == OP_TEXT:
            try:
                return json.loads(payload.decode("utf-8"))
            except (UnicodeDecodeError, json.JSONDecodeError) as e:
                raise ProtocolError(f"bad JSON WebSocket message: {e}") from None
        if opcode == OP_PING:
            writer.write(ws_encode_frame(OP_PONG, payload, mask=mask))
            await writer.drain()
            continue
        if opcode == OP_CLOSE:
            try:
                writer.write(ws_encode_frame(OP_CLOSE, b"", mask=mask))
                await writer.drain()
            except ConnectionError:
                pass
            return None
        if opcode == OP_PONG:
            continue
        raise ProtocolError(f"unsupported WebSocket opcode {opcode:#x}")
