"""``repro.serve.frontdoor`` — the async serving front door
(DESIGN.md §12): a stdlib-asyncio HTTP + WebSocket server over N
:class:`~repro.serve.engine.ContinuousBatcher` replicas.

  * :mod:`.protocol` — HTTP/1.1 + RFC 6455 wire layer (server and
    client side, stdlib only);
  * :mod:`.worker`   — one engine replica: step in a worker thread,
    token/cancel plumbing at step boundaries, the
    ``serve.frontdoor.step_passthrough`` tracing contract;
  * :mod:`.router`   — least-loaded dispatch, bounded admission
    (QueueFull -> 429), replica drain/health;
  * :mod:`.slo`      — per-request TTFT / queue-wait / per-token
    latency, aggregated for ``/stats`` and emitted as
    ``frontdoor.request`` trace events;
  * :mod:`.server`   — the routes: /healthz, /stats, /v1/generate,
    /v1/stream (WebSocket);
  * :mod:`.client`   — the matching stdlib client (tests and
    ``benchmarks/bench_traffic.py``).
"""
from repro.serve.frontdoor.client import WSClient, http_json  # noqa: F401
from repro.serve.frontdoor.protocol import ProtocolError  # noqa: F401
from repro.serve.frontdoor.router import (  # noqa: F401
    NoReplicaAvailable,
    QueueFull,
    ReplicaRouter,
)
from repro.serve.frontdoor.server import FrontDoor  # noqa: F401
from repro.serve.frontdoor.slo import RequestSLO, SLOTracker  # noqa: F401
from repro.serve.frontdoor.worker import (  # noqa: F401
    EngineWorker,
    passthrough_step,
)
