"""Per-request SLO accounting for the front door (DESIGN.md §12).

Every admitted request is timed at four host-side marks:

  * ``t_admit``     — the router accepted it (queue entry);
  * ``t_dispatch``  — it left the engine queue for a slot (recorded at
    the end of the engine step that prefilled it — the worker observes
    slot assignment between steps, so this is step-granular by design);
  * ``t_first``     — its first token was delivered (TTFT);
  * ``t_done``      — it finished (completed, truncated, or cancelled).

Derived metrics: ``ttft_us = t_first - t_admit`` (what a streaming
client feels), ``queue_wait_us = t_dispatch - t_admit`` (admission →
slot, the backpressure signal), and per-token latency (inter-token
gaps after the first token — the decode cadence).

The tracker aggregates p50/p99 over completed requests for the
``/stats`` endpoint and, when a :class:`repro.profile.Profiler` is
installed, emits one ``frontdoor.request`` :class:`TraceEvent` per
finished request — the same versioned trace schema the engine's step
events use, so request-level SLOs land in the same JSON-lines file as
the step timings that explain them.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional

import numpy as np


def now_us() -> float:
    """Monotonic microseconds (one clock for every SLO mark)."""
    return time.perf_counter() * 1e6


# analysis: dataclass-unregistered ok — host-side timing record, never jitted
@dataclasses.dataclass
class RequestSLO:
    """The timing record of one front-door request."""

    rid: int
    replica: str
    prompt_len: int
    max_new: int
    t_admit_us: float
    t_dispatch_us: Optional[float] = None
    t_first_us: Optional[float] = None
    t_done_us: Optional[float] = None
    token_gaps_us: List[float] = dataclasses.field(default_factory=list)
    _t_last_tok_us: Optional[float] = None
    tokens: int = 0
    cancelled: bool = False
    truncated: bool = False

    def mark_dispatch(self, t_us: Optional[float] = None) -> None:
        if self.t_dispatch_us is None:
            self.t_dispatch_us = now_us() if t_us is None else t_us

    def mark_token(self, t_us: Optional[float] = None) -> None:
        t = now_us() if t_us is None else t_us
        self.tokens += 1
        if self.t_first_us is None:
            self.t_first_us = t
            # first token implies a slot: dispatch happened no later
            self.mark_dispatch(t)
        elif self._t_last_tok_us is not None:
            self.token_gaps_us.append(t - self._t_last_tok_us)
        self._t_last_tok_us = t

    def mark_done(self, *, cancelled: bool, truncated: bool,
                  t_us: Optional[float] = None) -> None:
        self.t_done_us = now_us() if t_us is None else t_us
        self.cancelled = cancelled
        self.truncated = truncated

    @property
    def ttft_us(self) -> Optional[float]:
        if self.t_first_us is None:
            return None
        return self.t_first_us - self.t_admit_us

    @property
    def queue_wait_us(self) -> Optional[float]:
        if self.t_dispatch_us is None:
            return None
        return self.t_dispatch_us - self.t_admit_us

    @property
    def e2e_us(self) -> Optional[float]:
        if self.t_done_us is None:
            return None
        return self.t_done_us - self.t_admit_us

    def to_json(self) -> Dict[str, Any]:
        return {
            "rid": self.rid,
            "replica": self.replica,
            "tokens": self.tokens,
            "ttft_us": round(self.ttft_us, 1) if self.ttft_us is not None else None,
            "queue_wait_us": round(self.queue_wait_us, 1)
            if self.queue_wait_us is not None else None,
            "e2e_us": round(self.e2e_us, 1) if self.e2e_us is not None else None,
            "cancelled": self.cancelled,
            "truncated": self.truncated,
        }


def _pct(values: List[float]) -> Dict[str, float]:
    if not values:
        return {"p50": 0.0, "p99": 0.0, "mean": 0.0, "n": 0}
    # analysis: host-sync ok — input is a host-side python float list
    arr = np.asarray(values, dtype=np.float64)
    return {
        "p50": round(float(np.percentile(arr, 50)), 1),
        "p99": round(float(np.percentile(arr, 99)), 1),
        "mean": round(float(arr.mean()), 1),
        "n": int(arr.size),
    }


class SLOTracker:
    """Aggregates finished :class:`RequestSLO` records and counts
    admissions/rejections — everything ``/stats`` reports. All mutation
    happens on the event loop (single-threaded); the worker threads
    never touch it."""

    def __init__(self, profiler=None, exec_spec: str = "mode:off",
                 mesh: Optional[Dict[str, int]] = None):
        self.profiler = profiler
        self.exec_spec = exec_spec
        self.mesh = mesh
        self.admitted = 0
        self.rejected = 0
        self.completed = 0
        self.cancelled = 0
        self.truncated = 0
        self.tokens_out = 0
        self._t0_us = now_us()
        self._ttft: List[float] = []
        self._queue_wait: List[float] = []
        self._tok_gaps: List[float] = []
        self._e2e: List[float] = []

    def reset(self) -> None:
        """Zero every counter and aggregate and restart the uptime
        clock — the traffic bench calls this after its warmup pass so
        compile time never pollutes the measured SLOs."""
        self.admitted = self.rejected = 0
        self.completed = self.cancelled = self.truncated = 0
        self.tokens_out = 0
        self._t0_us = now_us()
        self._ttft.clear()
        self._queue_wait.clear()
        self._tok_gaps.clear()
        self._e2e.clear()

    def admit(self) -> None:
        self.admitted += 1

    def reject(self) -> None:
        self.rejected += 1

    def finish(self, slo: RequestSLO) -> None:
        """Fold one finished request into the aggregates (and the trace
        file, when profiling)."""
        if slo.cancelled:
            self.cancelled += 1
        else:
            self.completed += 1
        if slo.truncated:
            self.truncated += 1
        self.tokens_out += slo.tokens
        if slo.ttft_us is not None:
            self._ttft.append(slo.ttft_us)
        if slo.queue_wait_us is not None:
            self._queue_wait.append(slo.queue_wait_us)
        self._tok_gaps.extend(slo.token_gaps_us)
        if slo.e2e_us is not None:
            self._e2e.append(slo.e2e_us)
        if self.profiler is not None:
            from repro.profile.trace import TraceEvent

            self.profiler.record(TraceEvent(
                entry_point="frontdoor.request",
                exec_spec=self.exec_spec,
                shape_class="request",
                mesh=self.mesh,
                wall_us=slo.e2e_us or 0.0,
                dispatch_us=slo.queue_wait_us or 0.0,
                meta=slo.to_json(),
            ))

    def summary(self) -> Dict[str, Any]:
        """The ``/stats`` SLO block: counters + p50/p99 aggregates."""
        wall_s = (now_us() - self._t0_us) * 1e-6
        return {
            "requests": {
                "admitted": self.admitted,
                "rejected": self.rejected,
                "completed": self.completed,
                "cancelled": self.cancelled,
                "truncated": self.truncated,
            },
            "tokens_out": self.tokens_out,
            "uptime_s": round(wall_s, 3),
            "goodput_tok_s": round(self.tokens_out / max(wall_s, 1e-9), 2),
            "slo_us": {
                "ttft": _pct(self._ttft),
                "queue_wait": _pct(self._queue_wait),
                "tok_latency": _pct(self._tok_gaps),
                "e2e": _pct(self._e2e),
            },
        }
