"""Gradient compression for cross-pod reduction (distributed-opt trick).

At multi-pod scale the inter-pod links are the scarcest resource. These
utilities compress gradients *before* the cross-pod reduction and decode
after:

  * int8 per-leaf linear quantization with stochastic rounding (unbiased),
  * bf16 cast (cheap 2x),
  * error-feedback residual accumulation so compression error does not
    bias long-run training (Karimireddy et al. style).

Scope note: under pjit autodiff XLA inserts the gradient all-reduce
inside the backward pass at the gradient dtype, so this module's
encode/decode round trip models the *numerics* (quantization error +
error feedback) of a compressed reduction. Actually narrowing the wire
format requires expressing the reduction as an explicit collective over
locally encoded payloads — provided by
``repro.dist.collectives.compressed_psum_int8`` (shard_map) and tested on
a multi-device mesh in tests/test_collectives.py.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


class Int8Encoded(NamedTuple):
    values: jax.Array   # int8 payload
    scale: jax.Array    # f32 per-leaf scale


def encode_int8(g: jax.Array, key: jax.Array) -> Int8Encoded:
    """Unbiased stochastic-rounding int8 quantization (per-leaf scale)."""
    gf = g.astype(jnp.float32)
    amax = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12)
    scale = amax / 127.0
    scaled = gf / scale
    noise = jax.random.uniform(key, g.shape, jnp.float32, -0.5, 0.5)
    q = jnp.clip(jnp.round(scaled + noise), -127, 127).astype(jnp.int8)
    return Int8Encoded(q, scale)


def decode_int8(enc: Int8Encoded, dtype=jnp.float32) -> jax.Array:
    return (enc.values.astype(jnp.float32) * enc.scale).astype(dtype)


def tree_encode_int8(grads: PyTree, key: jax.Array) -> PyTree:
    leaves, treedef = jax.tree_util.tree_flatten(grads)
    keys = jax.random.split(key, len(leaves))
    enc = [encode_int8(g, k) for g, k in zip(leaves, keys)]
    return jax.tree_util.tree_unflatten(treedef, enc)


def tree_decode_int8(enc_tree: PyTree) -> PyTree:
    return jax.tree.map(
        decode_int8, enc_tree, is_leaf=lambda x: isinstance(x, Int8Encoded)
    )


def compress_grads(
    grads: PyTree,
    method: Optional[str],
    key: Optional[jax.Array] = None,
    residual: Optional[PyTree] = None,
) -> Tuple[PyTree, Optional[PyTree]]:
    """Apply compression with optional error feedback.

    Returns (decoded_grads, new_residual). The round trip models the
    numerics of a compressed all-reduce; under pjit the encode/decode pair
    straddles the reduction so the collective payload is the small dtype.
    """
    if method is None or method == "none":
        return grads, residual
    if residual is not None:
        grads = jax.tree.map(
            lambda g, r: g.astype(jnp.float32) + r, grads, residual
        )
    if method == "bf16":
        dec = jax.tree.map(lambda g: g.astype(jnp.bfloat16).astype(jnp.float32), grads)
    elif method == "int8":
        assert key is not None
        enc = tree_encode_int8(grads, key)
        dec = tree_decode_int8(enc)
    else:
        raise ValueError(method)
    new_residual = jax.tree.map(
        lambda g, d: g.astype(jnp.float32) - d.astype(jnp.float32), grads, dec
    )
    return dec, new_residual


def init_residual(params: PyTree) -> PyTree:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
