"""LR schedules as pure step -> scale functions (multiplied onto cfg.lr)."""
from __future__ import annotations

import jax.numpy as jnp


def constant():
    return lambda step: jnp.ones((), jnp.float32)


def linear_warmup(warmup: int):
    def f(step):
        s = step.astype(jnp.float32)
        return jnp.minimum(1.0, s / max(warmup, 1))

    return f


def warmup_cosine(warmup: int, total: int, min_scale: float = 0.1):
    def f(step):
        s = step.astype(jnp.float32)
        warm = jnp.minimum(1.0, s / max(warmup, 1))
        prog = jnp.clip((s - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = min_scale + (1 - min_scale) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return warm * cos

    return f


def inverse_sqrt(warmup: int):
    def f(step):
        s = jnp.maximum(step.astype(jnp.float32), 1.0)
        return jnp.minimum(s / max(warmup, 1), jnp.sqrt(warmup / s))

    return f
