"""AdamW in pure JAX (pytree-native, shard-friendly).

Optimizer state mirrors the parameter tree leaf-for-leaf, so the same
partition specs apply (dist.sharding.param_specs) — optimizer state
shards wherever its parameter shards, plus optionally over 'data'
(ZeRO-1) via the launcher's out_shardings.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    schedule: Optional[Callable[[jax.Array], jax.Array]] = None  # step -> lr scale


class AdamWState(NamedTuple):
    step: jax.Array
    mu: PyTree
    nu: PyTree


def init(params: PyTree) -> AdamWState:
    # mu and nu must be distinct buffers (donation-safe).
    mu = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
    nu = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
    return AdamWState(jnp.zeros((), jnp.int32), mu, nu)


def global_norm(tree: PyTree) -> jax.Array:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree))
    )


def clip_by_global_norm(grads: PyTree, max_norm: float) -> Tuple[PyTree, jax.Array]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), norm


def update(
    cfg: AdamWConfig, grads: PyTree, state: AdamWState, params: PyTree
) -> Tuple[PyTree, AdamWState, jax.Array]:
    """Returns (new_params, new_state, grad_norm)."""
    if cfg.grad_clip:
        grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    else:
        gnorm = global_norm(grads)
    step = state.step + 1
    lr = jnp.asarray(cfg.lr, jnp.float32)
    if cfg.schedule is not None:
        lr = lr * cfg.schedule(step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def leaf(p, g, m, v):
        gf = g.astype(jnp.float32)
        m2 = b1 * m + (1 - b1) * gf
        v2 = b2 * v + (1 - b2) * gf * gf
        upd = (m2 / bc1) / (jnp.sqrt(v2 / bc2) + cfg.eps)
        upd = upd + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * upd).astype(p.dtype), m2, v2

    out = jax.tree.map(leaf, params, grads, state.mu, state.nu)
    new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    new_mu = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    new_nu = jax.tree.map(lambda t: t[2], out, is_leaf=lambda t: isinstance(t, tuple))
    return new_params, AdamWState(step, new_mu, new_nu), gnorm
