"""Optimizers, schedules, gradient compression."""
from repro.optim.adamw import AdamWConfig, AdamWState, init, update  # noqa: F401
from repro.optim import schedules, compress  # noqa: F401
