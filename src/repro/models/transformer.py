"""Model assembly: decoder LMs (dense / MoE / MLA), SSM, hybrid, enc-dec,
and VLM — all from one functional toolkit, scan-over-layers, cache-aware.

Entry points:
  * init_params(key, cfg)              — parameter pytree (stacked layers)
  * forward(params, batch, cfg)        — training/teacher-forced logits
  * init_caches(cfg, batch, s_max)     — decode caches
  * decode_step(params, tokens, caches, index, cfg) — one-token step
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.dist.sharding import shard_act
from repro.models import attention as attn
from repro.models import layers as L
from repro.models import moe as moe_lib
from repro.models import ssm as ssm_lib

PyTree = Any


def _dtype(cfg: ArchConfig):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


# ---------------------------------------------------------------------------
# Block init / apply
# ---------------------------------------------------------------------------

def init_block(key, cfg: ArchConfig, dtype):
    """One decoder layer's params, by family."""
    ks = jax.random.split(key, 4)
    if cfg.family == "ssm" or (cfg.family == "hybrid"):
        return {
            "ln1": jnp.ones((cfg.d_model,), dtype),
            "mamba": ssm_lib.init_mamba2(ks[0], cfg, dtype),
        }
    p = {"ln1": jnp.ones((cfg.d_model,), dtype), "ln2": jnp.ones((cfg.d_model,), dtype)}
    if cfg.mla:
        p["attn"] = attn.init_mla(ks[0], cfg, dtype)
    else:
        p["attn"] = attn.init_gqa(ks[0], cfg, dtype)
    if cfg.n_experts:
        p["moe"] = moe_lib.init_moe(ks[1], cfg, dtype)
    else:
        p["mlp"] = L.init_mlp(ks[1], cfg.d_model, cfg.d_ff, dtype)
    if cfg.family == "encdec":
        p["ln_x"] = jnp.ones((cfg.d_model,), dtype)
        p["cross"] = attn.init_cross(ks[2], cfg, dtype)
    return p


def apply_block(
    p: PyTree,
    x: jax.Array,
    cfg: ArchConfig,
    positions: jax.Array,
    cache: Optional[PyTree],
    cache_index,
    enc: Optional[jax.Array] = None,
    start: Optional[jax.Array] = None,
):
    """Returns (x, new_cache). ``cache_index`` is scalar or (B,) (ragged
    decode); ``start`` is the (B,) left-padding dead-zone boundary —
    attention masks cache slots below it, SSM blocks zero the padded
    columns' state/conv contributions (pad columns have positions < 0)."""
    if "mamba" in p:
        h = L.rms_norm(x, p["ln1"])
        valid = None
        if cache is not None and start is not None:
            valid = positions >= 0  # (B, S): left-pad columns are inert
        out, new_cache = ssm_lib.mamba2_block(p["mamba"], h, cfg, cache, valid=valid)
        return x + out, new_cache

    h = L.rms_norm(x, p["ln1"])
    if cfg.mla:
        a, new_cache = attn.mla_attention(
            p["attn"], h, cfg, positions, cache, cache_index, start)
    else:
        a, new_cache = attn.gqa_attention(
            p["attn"], h, cfg, positions, cache, cache_index, start)
    x = x + a
    x = shard_act(x, "btd")
    if enc is not None and "cross" in p:
        h = L.rms_norm(x, p["ln_x"])
        x = x + attn.cross_attention(p["cross"], h, enc, cfg)
    h = L.rms_norm(x, p["ln2"])
    if "moe" in p:
        x = x + moe_lib.moe_block(p["moe"], h, cfg)
    else:
        x = x + L.mlp(p["mlp"], h, cfg.quant)
    return shard_act(x, "btd"), new_cache


# ---------------------------------------------------------------------------
# Whole-model init
# ---------------------------------------------------------------------------

def init_params(key, cfg: ArchConfig) -> PyTree:
    dtype = _dtype(cfg)
    k_embed, k_blocks, k_head, k_extra = jax.random.split(key, 4)
    params: Dict[str, PyTree] = {
        "embed": (jax.random.normal(k_embed, (cfg.vocab, cfg.d_model)) * 0.02).astype(dtype),
        "final_norm": jnp.ones((cfg.d_model,), dtype),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = L.init_dense_weight(k_head, (cfg.d_model, cfg.vocab), dtype=dtype)

    if cfg.scan_layers:
        keys = jax.random.split(k_blocks, cfg.n_layers)
        params["blocks"] = jax.vmap(lambda k: init_block(k, cfg, dtype))(keys)
    else:
        keys = jax.random.split(k_blocks, cfg.n_layers)
        params["blocks"] = [init_block(k, cfg, dtype) for k in keys]

    if cfg.family == "hybrid":
        ke1, ke2 = jax.random.split(k_extra)
        params["shared_attn"] = {
            "ln1": jnp.ones((cfg.d_model,), dtype),
            "ln2": jnp.ones((cfg.d_model,), dtype),
            "attn": attn.init_gqa(ke1, cfg, dtype),
            "mlp": L.init_mlp(ke2, cfg.d_model, cfg.d_ff, dtype),
        }
    if cfg.family == "encdec":
        ke = jax.random.split(k_extra, cfg.n_encoder_layers + 1)
        enc_cfg = cfg  # same width
        params["enc_blocks"] = jax.vmap(
            lambda k: _init_encoder_block(k, enc_cfg, dtype)
        )(ke[: cfg.n_encoder_layers])
        params["enc_norm"] = jnp.ones((cfg.d_model,), dtype)
        params["enc_pos"] = (
            jax.random.normal(ke[-1], (cfg.encoder_seq, cfg.d_model)) * 0.02
        ).astype(dtype)
    if cfg.family == "vlm":
        params["projector"] = L.init_dense_weight(k_extra, (cfg.d_vision, cfg.d_model), dtype=dtype)
    return params


def _init_encoder_block(key, cfg: ArchConfig, dtype):
    k1, k2 = jax.random.split(key)
    return {
        "ln1": jnp.ones((cfg.d_model,), dtype),
        "ln2": jnp.ones((cfg.d_model,), dtype),
        "attn": attn.init_cross(k1, cfg, dtype),  # self-attn uses same shape set
        "mlp": L.init_mlp(k2, cfg.d_model, cfg.d_ff, dtype),
    }


def _encoder_block_apply(p, x, cfg):
    h = L.rms_norm(x, p["ln1"])
    x = x + attn.cross_attention(p["attn"], h, h, cfg)  # self-attention (no mask)
    h = L.rms_norm(x, p["ln2"])
    return x + L.mlp(p["mlp"], h, cfg.quant)


def run_encoder(params, frames: jax.Array, cfg: ArchConfig) -> jax.Array:
    """frames: (B, S_enc, D) precomputed frame embeddings (conv stub)."""
    x = frames + params["enc_pos"][None, : frames.shape[1], :].astype(frames.dtype)

    def body(carry, p):
        return _encoder_block_apply(p, carry, cfg), None

    x, _ = jax.lax.scan(body, x, params["enc_blocks"])
    return L.rms_norm(x, params["enc_norm"])


# ---------------------------------------------------------------------------
# Layer-stack execution (scan, remat, hybrid segments)
# ---------------------------------------------------------------------------

def _scan_stack(blocks, x, cfg, positions, caches, cache_index, enc=None, start=None):
    """Scan over stacked layer params; caches may be None."""
    if isinstance(blocks, list):  # scan_layers=False: unrolled python loop
        new_cs = []
        for i, p in enumerate(blocks):
            c = jax.tree.map(lambda a: a[i], caches) if caches is not None else None
            x, nc = apply_block(p, x, cfg, positions, c, cache_index, enc, start)
            new_cs.append(nc)
        if caches is None:
            return x, None
        return x, jax.tree.map(lambda *xs: jnp.stack(xs, 0), *new_cs)

    def body(carry, xs):
        if caches is None:
            p, c = xs, None
        else:
            p, c = xs
        y, new_c = apply_block(p, carry, cfg, positions, c, cache_index, enc, start)
        return y, (new_c if caches is not None else 0)

    if cfg.remat and caches is None:
        body = jax.checkpoint(body, prevent_cse=False)
    xs = blocks if caches is None else (blocks, caches)
    x, outs = jax.lax.scan(body, x, xs)
    new_caches = outs if caches is not None else None
    return x, new_caches


def _run_hybrid(params, x, cfg, positions, caches, cache_index, start=None):
    """zamba2: mamba backbone with a weight-shared attention block applied
    every ``hybrid_attn_every`` layers. caches = (ssm_caches_stacked,
    attn_caches_stacked_per_application) or None."""
    k = cfg.hybrid_attn_every
    n_seg = cfg.n_layers // k
    sp = params["shared_attn"]

    ssm_caches, attn_caches = caches if caches is not None else (None, None)
    new_ssm, new_attn = [], []
    for s in range(n_seg):
        seg_blocks = jax.tree.map(lambda a: a[s * k : (s + 1) * k], params["blocks"])
        seg_cache = (
            jax.tree.map(lambda a: a[s * k : (s + 1) * k], ssm_caches)
            if ssm_caches is not None
            else None
        )
        x, nc = _scan_stack(seg_blocks, x, cfg, positions, seg_cache, cache_index,
                            start=start)
        if nc is not None:
            new_ssm.append(nc)
        # shared attention block (weights reused; per-application KV cache)
        h = L.rms_norm(x, sp["ln1"])
        # cache class rides the pytree (KVCache or QuantKVCache — §13)
        ac = type(attn_caches)(*(a[s] for a in attn_caches)) if attn_caches is not None else None
        a, nac = attn.gqa_attention(sp["attn"], h, cfg, positions, ac, cache_index, start)
        x = x + a
        h = L.rms_norm(x, sp["ln2"])
        x = x + L.mlp(sp["mlp"], h, cfg.quant)
        if nac is not None:
            # write just the new-token slice into this application's cache
            attn_caches = type(attn_caches)(
                *(
                    _write_token_slice(stack, n, s, cache_index)
                    for stack, n in zip(attn_caches, tuple(nac))
                )
            )
    if caches is None:
        return x, None
    new_caches = (
        jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=0), *new_ssm),
        attn_caches,
    )
    return x, new_caches


# ---------------------------------------------------------------------------
# Forward (training / teacher-forced)
# ---------------------------------------------------------------------------

def embed_inputs(params, batch: Dict[str, jax.Array], cfg: ArchConfig) -> jax.Array:
    x = L.embed(batch["tokens"], params["embed"])
    if cfg.family == "vlm":
        patches = batch["patches"].astype(x.dtype)
        img = L.dense(patches, params["projector"], cfg.quant)
        x = jnp.concatenate([img, x], axis=1)
    return x


def forward(params, batch: Dict[str, jax.Array], cfg: ArchConfig) -> jax.Array:
    """Teacher-forced logits: (B, S_total, V)."""
    x = embed_inputs(params, batch, cfg).astype(_dtype(cfg))
    x = shard_act(x, "btd")
    b, s = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    enc = None
    if cfg.family == "encdec":
        enc = run_encoder(params, batch["frames"].astype(x.dtype), cfg)
    if cfg.family == "hybrid":
        x, _ = _run_hybrid(params, x, cfg, positions, None, None)
    else:
        x, _ = _scan_stack(params["blocks"], x, cfg, positions, None, None, enc)
    x = L.rms_norm(x, params["final_norm"])
    table = params["embed"].T if cfg.tie_embeddings else params["unembed"]
    qc = cfg.quant if cfg.quantize_unembed else L.QuantConfig(mode="off")
    logits = L.dense(x, table, qc)
    return shard_act(logits, "logits")


# ---------------------------------------------------------------------------
# Decode path
# ---------------------------------------------------------------------------

def _gqa_cache_zeros(cfg: ArchConfig, batch: int, s_max: int, dtype):
    """One layer's GQA cache honoring ``cfg.quant.cache_dtype``
    (DESIGN.md §13): bf16 keeps the exact pre-§13 buffers; int8/ternary
    build quantized codes + per-(row, position) scale leaves."""
    cd = cfg.quant.cache_dtype
    if cd == "bf16":
        return attn.KVCache.zeros(
            batch, s_max, cfg.n_kv_heads, cfg.resolved_head_dim, dtype)
    return attn.QuantKVCache.zeros(
        batch, s_max, cfg.n_kv_heads, cfg.resolved_head_dim, cd)


def init_caches(cfg: ArchConfig, batch: int, s_max: int, dtype=jnp.bfloat16):
    """Stacked decode caches for the whole layer stack. Attention caches
    follow ``cfg.quant.cache_dtype``; SSM conv/state caches stay exact
    f32 (they are small, fully rewritten each step, and carry recurrent
    state whose quantization error would compound)."""
    if cfg.family == "ssm":
        one = ssm_lib.SSMCache.zeros(batch, cfg, jnp.float32)
        return jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (cfg.n_layers,) + a.shape), one
        )
    if cfg.family == "hybrid":
        ssm_one = ssm_lib.SSMCache.zeros(batch, cfg, jnp.float32)
        ssm_stack = jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (cfg.n_layers,) + a.shape), ssm_one
        )
        n_seg = cfg.n_layers // cfg.hybrid_attn_every
        kv_one = _gqa_cache_zeros(cfg, batch, s_max, dtype)
        kv_stack = jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (n_seg,) + a.shape), kv_one
        )
        return (ssm_stack, kv_stack)
    if cfg.mla:
        cd = cfg.quant.cache_dtype
        if cd == "bf16":
            one = attn.MLACache.zeros(
                batch, s_max, cfg.kv_lora_rank, cfg.qk_rope_head_dim, dtype)
        else:
            one = attn.QuantMLACache.zeros(
                batch, s_max, cfg.kv_lora_rank, cfg.qk_rope_head_dim, cd)
    else:
        one = _gqa_cache_zeros(cfg, batch, s_max, dtype)
    return jax.tree.map(
        lambda a: jnp.broadcast_to(a[None], (cfg.n_layers,) + a.shape), one
    )


def _wrap_cache(cfg: ArchConfig, tree):
    if cfg.family in ("ssm",):
        return ssm_lib.SSMCache(*tree)
    quant = cfg.quant.cache_dtype != "bf16"
    if cfg.mla:
        return (attn.QuantMLACache if quant else attn.MLACache)(*tree)
    return (attn.QuantKVCache if quant else attn.KVCache)(*tree)


def _write_token_slice(stack: jax.Array, sl: jax.Array, layer, index) -> jax.Array:
    """Write a new-token cache slice (B, s, ...) into a stacked cache
    (L, B, S_max, ...) at (layer, :, index). Only the token slice moves —
    the decode-traffic discipline (DESIGN.md §Perf). ``index`` may be a
    (B,) vector (ragged decode): each batch row then lands at its own
    sequence offset via a vmapped per-row update."""
    sl = sl.astype(stack.dtype)
    if jnp.ndim(index) == 0:
        starts = (layer, 0, index) + (0,) * (stack.ndim - 3)
        return jax.lax.dynamic_update_slice(stack, sl[None], starts)
    return stack.at[layer].set(attn.write_cache_rows(stack[layer], sl, index))


def _write_full_state(stack: jax.Array, st: jax.Array, layer) -> jax.Array:
    """Replace a whole per-layer state (SSM: the state is small and fully
    rewritten every step by construction)."""
    starts = (layer,) + (0,) * (stack.ndim - 1)
    return jax.lax.dynamic_update_slice(stack, st[None].astype(stack.dtype), starts)


def decode_step(
    params,
    tokens: jax.Array,
    caches,
    index: jax.Array,
    cfg: ArchConfig,
    enc: Optional[jax.Array] = None,
    start: Optional[jax.Array] = None,
) -> Tuple[jax.Array, PyTree]:
    """One decode step. tokens: (B, S_step) (S_step=1 for pure decode);
    ``index`` is the write offset into the caches — a scalar (every row
    at the same position: prefill / ``generate()``) or a (B,) vector
    (ragged decode: continuous-batching slots at heterogeneous
    positions). Returns (logits, caches).

    ``start`` (optional, (B,)) is the left-padding dead-zone boundary of
    a batched ragged prefill: cache slots below ``start[i]`` hold pad
    garbage and stay masked; RoPE positions are computed in *logical*
    coordinates ``index - start`` so each row's first real token is
    position 0 regardless of padding (DESIGN.md §6).

    The stacked caches ride in the scan *carry* and receive in-place
    token-slice writes (attention) / state writes (SSM) at the current
    layer — never restacked through scan outputs.
    """
    x = L.embed(tokens, params["embed"]).astype(_dtype(cfg))
    b, s = x.shape[:2]
    idx = jnp.asarray(index, jnp.int32)
    base = idx if start is None else idx - start  # logical position of token 0
    positions = (
        jnp.broadcast_to(base, (b,))[:, None]
        + jnp.arange(s, dtype=jnp.int32)[None, :]
    )
    if cfg.family == "hybrid":
        x, new_caches = _run_hybrid(params, x, cfg, positions, caches, idx, start)
    else:
        stacks = tuple(caches)
        ssm_like = cfg.family == "ssm"

        # Scan reads each layer's cache as an xs slice (no carry mutation)
        # and emits only the new-token slice / new state as ys; one
        # vectorized dynamic-update-slice after the scan writes all layers
        # at once. XLA keeps both the xs reads and the final DUS in place,
        # so decode HBM traffic is O(cache read + token write).
        def body(y, xs):
            p, c = xs
            c = _wrap_cache(cfg, c)
            y, new_c = apply_block(p, y, cfg, positions, c, idx, enc, start)
            return y, tuple(new_c)

        x, token_slices = jax.lax.scan(body, x, (params["blocks"], stacks))
        if ssm_like:
            new_caches = _wrap_cache(cfg, token_slices)
        elif idx.ndim == 0:
            # token_slices leaves: (L, B, s, ...); write at seq pos `index`
            written = tuple(
                jax.lax.dynamic_update_slice(
                    stack,
                    ts.astype(stack.dtype),
                    (0, 0, idx) + (0,) * (stack.ndim - 3),
                )
                for stack, ts in zip(stacks, token_slices)
            )
            new_caches = _wrap_cache(cfg, written)
        else:
            # ragged decode: every row writes all layers at its own offset
            written = tuple(
                jax.vmap(
                    lambda stack_r, ts_r, i: jax.lax.dynamic_update_slice(
                        stack_r, ts_r, (0, i) + (0,) * (stack_r.ndim - 2)),
                    in_axes=(1, 1, 0),
                    out_axes=1,
                )(stack, ts.astype(stack.dtype), idx)
                for stack, ts in zip(stacks, token_slices)
            )
            new_caches = _wrap_cache(cfg, written)
    x = L.rms_norm(x, params["final_norm"])
    table = params["embed"].T if cfg.tie_embeddings else params["unembed"]
    logits = L.dense(x, table, L.QuantConfig(mode="off"))
    return logits, new_caches
