"""Token-choice top-k Mixture-of-Experts (deepseek-v2 / grok-1 style).

Dispatch uses the capacity-buffer scatter formulation (Switch-style):
tokens are scattered into a per-expert (E, C, D) buffer, expert FFNs run
as one batched einsum over E (the expert dimension shards over the
``model``/``expert`` mesh axis), and outputs are gathered back and
combined with the router gates. Overflowing tokens are dropped (standard
capacity-factor semantics); the residual path keeps them alive.

Expert FFN weights route through the ternary/CiM ``dense`` modes like any
other weight-bearing matmul (expert weights live in CiM arrays; routing
stays digital — DESIGN.md §5).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import layers as L


def moe_capacity(n_tokens: int, cfg: ArchConfig) -> int:
    cap = int(n_tokens * cfg.top_k * cfg.moe_capacity_factor / cfg.n_experts)
    return max(cap, 8)


def init_moe(key, cfg: ArchConfig, dtype=jnp.float32):
    d, e, f = cfg.d_model, cfg.n_experts, cfg.expert_d_ff
    ks = jax.random.split(key, 5)
    p = {
        "router": L.init_dense_weight(ks[0], (d, e), dtype=jnp.float32),
        "w_gate": (jax.random.normal(ks[1], (e, d, f)) * d**-0.5).astype(dtype),
        "w_up": (jax.random.normal(ks[2], (e, d, f)) * d**-0.5).astype(dtype),
        "w_down": (jax.random.normal(ks[3], (e, f, d)) * f**-0.5).astype(dtype),
    }
    if cfg.n_shared_experts:
        p["shared"] = L.init_mlp(ks[4], d, cfg.expert_d_ff * cfg.n_shared_experts, dtype)
    return p


def _expert_ffn(params, xe: jax.Array, qc: L.QuantConfig) -> jax.Array:
    """xe: (G, E, C, D) -> (G, E, C, D), batched over (groups, experts).

    Ternary modes quantize each expert weight per-channel; the batched
    einsum keeps the expert (or capacity) dim sharded.
    """
    wg, wu, wd = params["w_gate"], params["w_up"], params["w_down"]
    if qc.mode != "off":
        wg, wu, wd = _tern3(wg), _tern3(wu), _tern3(wd)

    def emm(x_, w_, spec):
        if qc.mode in ("cim", "cim_fused"):
            # kernel cost structure for expert weights held in CiM arrays
            # (blocked jnp form would create 5-D intermediates; the Pallas
            # kernel clamps per 16-block inside VMEM — see layers.dense)
            p = jnp.einsum(spec, x_, w_.astype(x_.dtype))
            m = jnp.einsum(spec, jnp.abs(x_), jnp.abs(w_).astype(x_.dtype))
            big = jnp.asarray(2.0**14, jnp.float32)
            pf, mf = p.astype(jnp.float32), m.astype(jnp.float32)
            out = jnp.minimum((mf + pf) * 0.5, big) - jnp.minimum((mf - pf) * 0.5, big)
            return out.astype(x_.dtype)
        return jnp.einsum(spec, x_, w_.astype(x_.dtype))

    g = emm(xe, wg, "gecd,edf->gecf")
    u = emm(xe, wu, "gecd,edf->gecf")
    h = L.swiglu(g, u)
    return emm(h, wd, "gecf,efd->gecd")


def _tern3(w: jax.Array) -> jax.Array:
    """Per-expert, per-out-channel ternarization with STE for (E, in, out).

    The per-column scale is folded back into the ternary weight so the
    batched expert einsum stays a single op (the CiM array applies the
    column scales in its digital periphery)."""
    from repro.core import ternary as tern

    t, scale = tern.ternarize(w, axis=(1,))
    w_t = t + (w - jax.lax.stop_gradient(w))  # value-exact STE
    return w_t * jax.lax.stop_gradient(scale)


def moe_block(params, x: jax.Array, cfg: ArchConfig) -> jax.Array:
    """x: (B, S, D) -> (B, S, D).

    Grouped (hierarchical) dispatch: tokens are partitioned into G groups
    aligned with the data-parallel shards, each group routes into its own
    per-expert capacity slice, and all routing arithmetic (cumsum,
    scatter, gather) stays *local to the group*. A global cumsum/scatter
    over the full token dim forces the partitioner into cross-device
    gathers (observed: 25x expert overcompute + a 56 TB all-reduce on
    grok-1 train — EXPERIMENTS.md §Perf). The expert einsum batches over
    (G, E) with E sharded over 'model' when divisible, else the capacity
    dim.
    """
    from repro.dist.sharding import batch_axes, model_axis_size, shard_act, _ACT_AXES

    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    qc = cfg.quant
    t = b * s
    groups = 1
    if _ACT_AXES is not None:
        div = _ACT_AXES.get("divisor", 1)
        if div > 1 and b % div == 0:
            groups = div
    tg = t // groups
    cap = moe_capacity(tg, cfg)
    xt = shard_act(x.reshape(groups, tg, d), "btd")

    logits = L.accum_einsum("gtd,de->gte", xt, params["router"].astype(xt.dtype))
    gates = jax.nn.softmax(logits, axis=-1)                     # (G, Tg, E)
    top_g, top_e = jax.lax.top_k(gates, k)                      # (G, Tg, K)
    top_g = top_g / jnp.maximum(top_g.sum(-1, keepdims=True), 1e-9)

    flat_e = top_e.reshape(groups, tg * k)                      # (G, Tg*K)
    flat_g = top_g.reshape(groups, tg * k)
    tok_id = jnp.broadcast_to(
        jnp.repeat(jnp.arange(tg), k)[None], (groups, tg * k))

    # position within the expert's group-local buffer (group-local cumsum)
    onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)         # (G, Tg*K, E)
    pos = jnp.cumsum(onehot, axis=1) - 1
    pos = jnp.take_along_axis(pos, flat_e[..., None], axis=2)[..., 0]
    keep = pos < cap
    slot = jnp.where(keep, flat_e * cap + pos, e * cap)         # overflow slot

    buf = jnp.zeros((groups, e * cap + 1, d), xt.dtype)
    gathered_in = jnp.take_along_axis(xt, tok_id[..., None], axis=1)
    buf = jax.vmap(lambda bu, sl, v: bu.at[sl].set(v))(buf, slot, gathered_in)
    xe = buf[:, : e * cap].reshape(groups, e, cap, d)

    msize = model_axis_size()
    if e % max(msize, 1) == 0:
        xe = shard_act(xe, "gecd")
    elif cap % max(msize, 1) == 0:
        xe = shard_act(xe, "gecd_cap")

    ye = _expert_ffn(params, xe, qc).reshape(groups, e * cap, d)
    ye = jnp.concatenate([ye, jnp.zeros((groups, 1, d), ye.dtype)], axis=1)
    out_g = jnp.take_along_axis(ye, slot[..., None], axis=1)
    out_g = out_g * (flat_g * keep.astype(jnp.float32))[..., None].astype(ye.dtype)
    out = jnp.zeros_like(xt)
    out = jax.vmap(lambda o, ti, v: o.at[ti].add(v))(out, tok_id, out_g)

    if cfg.n_shared_experts:
        out = out + L.mlp(params["shared"], xt.reshape(t, d), qc).reshape(groups, tg, d)
    return out.reshape(b, s, d)


def router_aux_loss(params, x: jax.Array, cfg: ArchConfig) -> jax.Array:
    """Load-balancing auxiliary loss (Switch-style)."""
    b, s, d = x.shape
    xt = x.reshape(b * s, d)
    logits = (xt.astype(jnp.float32) @ params["router"])
    gates = jax.nn.softmax(logits, axis=-1)
    top1 = jnp.argmax(gates, axis=-1)
    me = jnp.mean(jax.nn.one_hot(top1, cfg.n_experts, dtype=jnp.float32), axis=0)
    pe = jnp.mean(gates, axis=0)
    return cfg.n_experts * jnp.sum(me * pe)
