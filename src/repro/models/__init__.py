"""Model zoo: functional JAX models for the 10 assigned architectures."""
