"""Attention: GQA (llama-family) and MLA (deepseek-v2), with KV caches.

Weight-bearing projections route through ``layers.dense`` so the paper's
ternary/CiM modes apply; the score/value contractions are
activation-activation products and stay bf16 in every mode (CiM is a
weight-stationary paradigm — DESIGN.md §5).
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core import ternary as tern
from repro.models import layers as L


class KVCache(NamedTuple):
    k: jax.Array  # (B, S_max, H_kv, Dh)
    v: jax.Array  # (B, S_max, H_kv, Dh)

    @staticmethod
    def zeros(batch: int, s_max: int, n_kv: int, head_dim: int, dtype=jnp.bfloat16):
        return KVCache(
            jnp.zeros((batch, s_max, n_kv, head_dim), dtype),
            jnp.zeros((batch, s_max, n_kv, head_dim), dtype),
        )


class MLACache(NamedTuple):
    """Compressed MLA cache: latent kv (B, S, kv_lora) + rope key (B, S, Dr)."""
    ckv: jax.Array
    k_rope: jax.Array

    @staticmethod
    def zeros(batch: int, s_max: int, kv_lora: int, rope_dim: int, dtype=jnp.bfloat16):
        return MLACache(
            jnp.zeros((batch, s_max, kv_lora), dtype),
            jnp.zeros((batch, s_max, rope_dim), dtype),
        )


# ---------------------------------------------------------------------------
# Quantized KV caches (DESIGN.md §13)
#
# Storage: int8 symmetric codes, or ternary {-1,0,1} codes nibble-packed
# two per byte (uint8). One f32 scale per (row, position) — the same
# granularity as act_scale="per_row": each slot row quantizes
# independently, so continuous batching never couples co-resident
# requests through a shared amax. Dequantization is fused into the
# attention contractions: the codes enter the score/value einsums
# directly and the scale multiplies the (B, ..., Sk) score/prob
# matrices, so no full-precision copy of the stacked cache is ever
# materialized (pinned by the serve.fused_decode_step.kvq contract).
# ---------------------------------------------------------------------------

def quantize_kv(x: jax.Array, cache_dtype: str) -> Tuple[jax.Array, jax.Array]:
    """Quantize ``x`` (B, S, ...) per (row, position) over every trailing
    axis. Returns ``(codes, scale)`` with scale (B, S) f32:

      * ``"int8"``:    symmetric ``round(x/scale)`` in [-127, 127],
                       ``scale = amax/127`` (1.0 where the slice is all
                       zero — dead pad rows stay exactly zero);
      * ``"ternary"``: TWN codes in {-1,0,1} (:func:`~repro.core.
                       ternary.ternarize`) nibble-packed two per byte
                       along the last axis (uint8, last dim halved).
    """
    red = tuple(range(2, x.ndim))
    xf = x.astype(jnp.float32)
    if cache_dtype == "int8":
        amax = jnp.max(jnp.abs(xf), axis=red)
        scale = jnp.where(amax > 0, amax / 127.0, 1.0)
        q = jnp.round(xf / scale[(...,) + (None,) * len(red)])
        codes = jnp.clip(q, -127, 127).astype(jnp.int8)
        return codes, scale
    if cache_dtype == "ternary":
        t, scale = tern.ternarize(xf, axis=red)
        return pack_ternary_kv(t.astype(jnp.int8)), scale.reshape(x.shape[:2])
    raise ValueError(f"quantize_kv: unknown cache_dtype {cache_dtype!r}")


def pack_ternary_kv(t: jax.Array) -> jax.Array:
    """Pack ternary codes {-1,0,1} (int8) two per byte along the last
    axis: stored nibbles are ``t+1`` in {0,1,2}. Requires an even last
    dim (checked at cache construction)."""
    c = (t + 1).astype(jnp.uint8)
    return (c[..., 0::2] << 4) | c[..., 1::2]


def unpack_ternary_kv(p: jax.Array, dtype) -> jax.Array:
    """Inverse of :func:`pack_ternary_kv`: uint8 (..., D/2) -> codes
    (..., D) in {-1,0,1} as ``dtype`` (the attention compute dtype —
    codes are exactly representable in bf16)."""
    hi = ((p >> 4) & 0xF).astype(jnp.int8) - 1
    lo = (p & 0xF).astype(jnp.int8) - 1
    codes = jnp.stack([hi, lo], axis=-1).reshape(p.shape[:-1] + (2 * p.shape[-1],))
    return codes.astype(dtype)


def _kv_codes(buf: jax.Array, dtype) -> jax.Array:
    """Stored cache codes -> compute-dtype codes (int8 pass-through cast,
    uint8 nibble-unpack). The only dequant step besides the score-matrix
    scale multiply — it never touches f32 at cache shape."""
    if buf.dtype == jnp.uint8:
        return unpack_ternary_kv(buf, dtype)
    return buf.astype(dtype)


def _quant_zeros(shape: Tuple[int, ...], cache_dtype: str) -> jax.Array:
    if cache_dtype == "ternary":
        if shape[-1] % 2:
            raise ValueError(
                f"ternary cache_dtype packs 2 codes/byte along the last "
                f"axis; got odd trailing dim {shape[-1]} (shape {shape})"
            )
        # all-zero codes pack to nibble value 1 on both halves
        return jnp.full(shape[:-1] + (shape[-1] // 2,), 0x11, jnp.uint8)
    if cache_dtype == "int8":
        return jnp.zeros(shape, jnp.int8)
    raise ValueError(f"unknown quantized cache_dtype {cache_dtype!r}")


class QuantKVCache(NamedTuple):
    """Quantized GQA cache: codes + per-(row, position) f32 scales.

    ``k``/``v`` are int8 (B, S_max, H_kv, Dh) or ternary-packed uint8
    (B, S_max, H_kv, Dh/2); the storage mode is carried by the leaf
    dtype, so the pytree needs no static flag and generic cache
    plumbing (stacking, donation, sharding) treats every leaf
    uniformly."""
    k: jax.Array
    v: jax.Array
    k_scale: jax.Array  # (B, S_max) f32
    v_scale: jax.Array  # (B, S_max) f32

    @staticmethod
    def zeros(batch: int, s_max: int, n_kv: int, head_dim: int,
              cache_dtype: str = "int8"):
        val = _quant_zeros((batch, s_max, n_kv, head_dim), cache_dtype)
        sc = jnp.ones((batch, s_max), jnp.float32)
        return QuantKVCache(val, val, sc, sc)


class QuantMLACache(NamedTuple):
    """Quantized MLA cache: latent + rope-key codes with per-(row,
    position) scales (storage mode via leaf dtype, as QuantKVCache)."""
    ckv: jax.Array
    k_rope: jax.Array
    ckv_scale: jax.Array    # (B, S_max) f32
    krope_scale: jax.Array  # (B, S_max) f32

    @staticmethod
    def zeros(batch: int, s_max: int, kv_lora: int, rope_dim: int,
              cache_dtype: str = "int8"):
        sc = jnp.ones((batch, s_max), jnp.float32)
        return QuantMLACache(
            _quant_zeros((batch, s_max, kv_lora), cache_dtype),
            _quant_zeros((batch, s_max, rope_dim), cache_dtype),
            sc, sc,
        )


# ---------------------------------------------------------------------------
# Ragged cache writes
# ---------------------------------------------------------------------------

def write_cache_rows(buf: jax.Array, new: jax.Array, index: jax.Array) -> jax.Array:
    """Write ``new`` (B, s, ...) into ``buf`` (B, S_max, ...) at sequence
    offset ``index``.

    ``index`` is the ragged-decode contract's pivot (DESIGN.md §6): a
    scalar means every row writes at the same offset (prefill /
    ``generate()``) and lowers to one contiguous dynamic_update_slice; a
    ``(B,)`` vector means each row lands at its own offset (continuous
    batching over slots at heterogeneous progress) and lowers to a
    vmapped per-row dynamic_update_slice (a batched scatter — rows not
    addressed by their own offset are untouched).
    """
    new = new.astype(buf.dtype)
    if jnp.ndim(index) == 0:
        starts = (0, index) + (0,) * (buf.ndim - 2)
        return jax.lax.dynamic_update_slice(buf, new, starts)

    def row(buf_row, new_row, i):
        starts = (i,) + (0,) * (buf_row.ndim - 1)
        return jax.lax.dynamic_update_slice(buf_row, new_row, starts)

    return jax.vmap(row)(buf, new, index)


def _index_vector(index, b: int) -> jax.Array:
    """Normalize a scalar-or-(B,) cache index to a (B,) int32 vector."""
    return jnp.broadcast_to(jnp.asarray(index, jnp.int32), (b,))


# ---------------------------------------------------------------------------
# GQA
# ---------------------------------------------------------------------------

def init_gqa(key, cfg: ArchConfig, dtype=jnp.float32):
    d, h, hkv = cfg.d_model, cfg.n_heads, cfg.n_kv_heads
    hd = cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    return {
        "wq": L.init_dense_weight(ks[0], (d, h * hd), dtype=dtype),
        "wk": L.init_dense_weight(ks[1], (d, hkv * hd), dtype=dtype),
        "wv": L.init_dense_weight(ks[2], (d, hkv * hd), dtype=dtype),
        "wo": L.init_dense_weight(ks[3], (h * hd, d), dtype=dtype),
    }


def _sdpa(
    q,
    k,
    v,
    causal_offset,
    length: Optional[jax.Array] = None,
    start: Optional[jax.Array] = None,
    k_scale: Optional[jax.Array] = None,
    v_scale: Optional[jax.Array] = None,
):
    """q: (B, Sq, H, Dh); k, v: (B, Sk, Hkv, Dh). GQA via head grouping.

    causal_offset: position of q[0] relative to k[0] (None = no mask).
      Scalar, or (B,) for ragged decode where each row sits at its own
      cache position.
    length: (B,) valid KV length for decode (mask out at and beyond).
    start: (B,) first valid KV slot (mask out below) — left-padded
      batched prefill leaves dead pad slots at the front of each row's
      cache region; they stay masked for the slot's lifetime.
    k_scale/v_scale: (B, Sk) f32 per-(row, position) scales of a
      quantized cache (DESIGN.md §13) — then k/v carry int8 or
      ternary-packed uint8 codes. Dequantization stays fused: codes
      enter the contractions and the scale multiplies the score/prob
      matrices (constant per k-position, so it factors out of the Dh
      contraction); no full-precision cache copy is materialized.
    """
    if k_scale is not None:
        k = _kv_codes(k, q.dtype)
        v = _kv_codes(v, q.dtype)
    b, sq, h, dh = q.shape
    sk, hkv = k.shape[1], k.shape[2]
    g = h // hkv
    qg = q.reshape(b, sq, hkv, g, dh)
    # Context parallelism: shard QUERY rows over the model axis. Head
    # counts rarely divide a 16-way axis (starcoder2: 36 heads), in which
    # case the partitioner replicates the whole score computation; query
    # rows always divide for the training/prefill shapes and each row's
    # softmax is independent. (No-op when activation sharding is off or
    # sq doesn't divide.)
    from repro.dist.sharding import model_axis_size, shard_act

    msize = model_axis_size()
    if msize > 1 and sq % msize == 0 and sq > msize:
        qg = shard_act(qg, "bqhgd_sp")
    # bf16 operands, f32 accumulation (MXU-native; avoids materializing an
    # f32 copy of the KV cache) — see layers.accum_einsum
    scores = L.accum_einsum("bqhgd,bkhd->bhgqk", qg, k.astype(qg.dtype))
    if k_scale is not None:
        scores = scores * k_scale[:, None, None, None, :]
    scores = scores / jnp.sqrt(dh).astype(jnp.float32)
    if causal_offset is not None:
        off = jnp.asarray(causal_offset, jnp.int32)
        off = off[None] if off.ndim == 0 else off        # (1,) or (B,)
        qpos = off[:, None, None] + jnp.arange(sq, dtype=jnp.int32)[None, :, None]
        kpos = jnp.arange(sk, dtype=jnp.int32)[None, None, :]
        mask = kpos <= qpos                              # (1|B, sq, sk)
        scores = jnp.where(mask[:, None, None], scores, -1e30)
    if length is not None:
        valid = jnp.arange(sk)[None, :] < length[:, None]
        scores = jnp.where(valid[:, None, None, None, :], scores, -1e30)
    if start is not None:
        live = jnp.arange(sk)[None, :] >= start[:, None]
        scores = jnp.where(live[:, None, None, None, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    if v_scale is not None:
        probs = probs * v_scale[:, None, None, None, :]
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs.astype(v.dtype), v)
    return out.reshape(b, sq, h, dh)


def _sdpa_chunked(q, k, v, chunk: int,
                  k_scale: Optional[jax.Array] = None,
                  v_scale: Optional[jax.Array] = None):
    """Flash-style causal attention: scan over KV chunks with an online
    softmax — never materializes the (B, H, Sq, Sk) score matrix. Used for
    long training/prefill sequences (cfg.attn_chunk); numerics match
    :func:`_sdpa` to fp tolerance (tests/test_models.py).

    Optional k_scale/v_scale (B, Sk): quantized-cache codes in k/v, same
    fused-dequant contract as :func:`_sdpa`, applied per KV chunk inside
    the scan (the online softmax never sees a dequantized cache copy)."""
    if k_scale is not None:
        k = _kv_codes(k, q.dtype)
        v = _kv_codes(v, q.dtype)
    b, sq, h, dh = q.shape
    sk, hkv = k.shape[1], k.shape[2]
    g = h // hkv
    assert sk % chunk == 0, (sk, chunk)
    nc = sk // chunk
    qg = q.reshape(b, sq, hkv, g, dh)
    kc = k.reshape(b, nc, chunk, hkv, dh)
    vc = v.reshape(b, nc, chunk, hkv, dh)
    qpos = jnp.arange(sq)
    scaled = k_scale is not None

    def body(carry, blk):
        m_prev, l_prev, acc = carry
        if scaled:
            kb, vb, ci, ksb, vsb = blk
        else:
            kb, vb, ci = blk                   # (b, chunk, hkv, dh), idx
        s = L.accum_einsum("bqhgd,bkhd->bhgqk", qg, kb.astype(qg.dtype))
        if scaled:
            s = s * ksb[:, None, None, None, :]
        s = s / jnp.sqrt(dh).astype(jnp.float32)
        kpos = ci * chunk + jnp.arange(chunk)
        mask = kpos[None, :] <= qpos[:, None]
        s = jnp.where(mask[None, None, None], s, -1e30)
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m_prev - m_new)
        l_new = l_prev * alpha + p.sum(axis=-1)
        if scaled:
            p = p * vsb[:, None, None, None, :]
        acc = acc * alpha[..., None] + L.accum_einsum(
            "bhgqk,bkhd->bhgqd", p.astype(vb.dtype), vb)
        return (m_new, l_new, acc), None

    xs = (jnp.moveaxis(kc, 1, 0), jnp.moveaxis(vc, 1, 0), jnp.arange(nc))
    if scaled:
        xs = xs + (jnp.moveaxis(k_scale.reshape(b, nc, chunk), 1, 0),
                   jnp.moveaxis(v_scale.reshape(b, nc, chunk), 1, 0))
    m0 = jnp.full((b, hkv, g, sq), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((b, hkv, g, sq), jnp.float32)
    a0 = jnp.zeros((b, hkv, g, sq, dh), jnp.float32)
    (m_f, l_f, acc), _ = jax.lax.scan(body, (m0, l0, a0), xs)
    out = acc / jnp.maximum(l_f, 1e-30)[..., None]
    return jnp.moveaxis(out, -2, 1).reshape(b, sq, h, dh).astype(q.dtype)


def gqa_attention(
    params,
    x: jax.Array,
    cfg: ArchConfig,
    positions: jax.Array,
    cache: Optional[KVCache] = None,
    cache_index: Optional[jax.Array] = None,
    start: Optional[jax.Array] = None,
) -> Tuple[jax.Array, Optional[KVCache]]:
    """x: (B, S, D). With a cache: decode/prefill-append mode — new KV
    written at ``cache_index`` (scalar, or (B,) for ragged decode where
    every row writes at its own position); attention runs against the
    whole cache. ``start`` marks each row's first valid cache slot
    (left-padding dead zone — see DESIGN.md §6). A :class:`QuantKVCache`
    quantizes the new tokens on write and attends over codes + scales
    (DESIGN.md §13); the :class:`KVCache` path is untouched — bf16
    serving stays bit-identical."""
    b, s, d = x.shape
    h, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    qc = cfg.quant
    q = L.dense(x, params["wq"], qc).reshape(b, s, h, hd)
    k = L.dense(x, params["wk"], qc).reshape(b, s, hkv, hd)
    v = L.dense(x, params["wv"], qc).reshape(b, s, hkv, hd)
    q = L.apply_rope(q, positions, cfg.rope_theta)
    k = L.apply_rope(k, positions, cfg.rope_theta)

    if cache is None:
        if cfg.attn_chunk and s % cfg.attn_chunk == 0 and s > cfg.attn_chunk:
            out = _sdpa_chunked(q, k, v, cfg.attn_chunk)
        else:
            out = _sdpa(q, k, v, causal_offset=0)
        new_cache = None
    elif isinstance(cache, QuantKVCache):
        cd = "ternary" if cache.k.dtype == jnp.uint8 else "int8"
        k_q, k_s = quantize_kv(k, cd)
        v_q, v_s = quantize_kv(v, cd)
        k_all = write_cache_rows(cache.k, k_q, cache_index)
        v_all = write_cache_rows(cache.v, v_q, cache_index)
        ks_all = write_cache_rows(cache.k_scale, k_s, cache_index)
        vs_all = write_cache_rows(cache.v_scale, v_s, cache_index)
        new_cache = QuantKVCache(k_q, v_q, k_s, v_s)
        length = _index_vector(cache_index, b) + s
        out = _sdpa(
            q, k_all, v_all, causal_offset=cache_index, length=length,
            start=start, k_scale=ks_all, v_scale=vs_all,
        )
    else:
        k_all = write_cache_rows(cache.k, k, cache_index)
        v_all = write_cache_rows(cache.v, v, cache_index)
        # Return only the new-token KV: the caller owns the stacked cache
        # and writes just this slice (avoids restacking the full per-layer
        # cache through the layer scan — decode HBM traffic stays
        # O(read cache + write one token), see DESIGN.md).
        new_cache = KVCache(k.astype(cache.k.dtype), v.astype(cache.v.dtype))
        length = _index_vector(cache_index, b) + s
        out = _sdpa(
            q, k_all, v_all, causal_offset=cache_index, length=length, start=start
        )
    out = out.reshape(b, s, h * hd)
    return L.dense(out, params["wo"], qc, tp="row"), new_cache


# ---------------------------------------------------------------------------
# MLA (deepseek-v2): low-rank joint KV compression + decoupled rope key
# ---------------------------------------------------------------------------

def init_mla(key, cfg: ArchConfig, dtype=jnp.float32):
    d, h = cfg.d_model, cfg.n_heads
    r = cfg.kv_lora_rank
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    ks = jax.random.split(key, 6)
    p = {
        # queries (full rank — q_lora omitted when q_lora_rank == 0)
        "wq": L.init_dense_weight(ks[0], (d, h * (dn + dr)), dtype=dtype),
        # joint KV down-projection + decoupled rope key
        "w_dkv": L.init_dense_weight(ks[1], (d, r + dr), dtype=dtype),
        # up-projections from the latent
        "w_uk": L.init_dense_weight(ks[2], (r, h * dn), dtype=dtype),
        "w_uv": L.init_dense_weight(ks[3], (r, h * dv), dtype=dtype),
        "wo": L.init_dense_weight(ks[4], (h * dv, d), dtype=dtype),
        "kv_norm": jnp.ones((r,), dtype),
    }
    return p


def mla_attention(
    params,
    x: jax.Array,
    cfg: ArchConfig,
    positions: jax.Array,
    cache: Optional[MLACache] = None,
    cache_index: Optional[jax.Array] = None,
    start: Optional[jax.Array] = None,
) -> Tuple[jax.Array, Optional[MLACache]]:
    b, s, d = x.shape
    h = cfg.n_heads
    r = cfg.kv_lora_rank
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    qc = cfg.quant

    q = L.dense(x, params["wq"], qc).reshape(b, s, h, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = L.apply_rope(q_rope, positions, cfg.rope_theta)

    dkv = L.dense(x, params["w_dkv"], qc)
    ckv, k_rope = dkv[..., :r], dkv[..., r:]
    ckv = L.rms_norm(ckv, params["kv_norm"])
    k_rope = L.apply_rope(k_rope[:, :, None, :], positions, cfg.rope_theta)[:, :, 0, :]

    ckv_scale = krope_scale = None
    if cache is not None and isinstance(cache, QuantMLACache):
        cd = "ternary" if cache.ckv.dtype == jnp.uint8 else "int8"
        ckv_q, ckv_s = quantize_kv(ckv, cd)
        kr_q, kr_s = quantize_kv(k_rope, cd)
        ckv_all = write_cache_rows(cache.ckv, ckv_q, cache_index)
        krope_all = write_cache_rows(cache.k_rope, kr_q, cache_index)
        ckv_scale = write_cache_rows(cache.ckv_scale, ckv_s, cache_index)
        krope_scale = write_cache_rows(cache.krope_scale, kr_s, cache_index)
        new_cache = QuantMLACache(ckv_q, kr_q, ckv_s, kr_s)
        offset = cache_index
        sk = ckv_all.shape[1]
        length = _index_vector(cache_index, b) + s
    elif cache is not None:
        ckv_all = write_cache_rows(cache.ckv, ckv, cache_index)
        krope_all = write_cache_rows(cache.k_rope, k_rope, cache_index)
        # new-token slices only; caller writes them into the stacked cache
        new_cache = MLACache(ckv.astype(cache.ckv.dtype), k_rope.astype(cache.k_rope.dtype))
        offset = cache_index
        sk = ckv_all.shape[1]
        length = _index_vector(cache_index, b) + s
    else:
        ckv_all, krope_all, new_cache, offset, sk, length = ckv, k_rope, None, 0, s, None
        start = None

    # Absorbed-weight form: score = q_nope^T W_uk ckv + q_rope^T k_rope.
    # (decode-efficient: cache stays compressed; W_uk is absorbed into q.)
    # bf16 operands + f32 accumulation: no f32 copy of the latent cache.
    w_uk = params["w_uk"].reshape(r, h, dn).astype(x.dtype)
    q_lat = L.accum_einsum("bqhd,rhd->bqhr", q_nope, w_uk)
    if ckv_scale is not None:
        # quantized latent cache: codes into the contractions, per-(row,
        # position) scales onto the (B, H, Sq, Sk) score parts — the two
        # score terms carry independent scales, so they are applied
        # before the sum (DESIGN.md §13)
        ckv_f = _kv_codes(ckv_all, x.dtype)
        krope_f = _kv_codes(krope_all, q_rope.dtype)
        scores = (L.accum_einsum("bqhr,bkr->bhqk", q_lat.astype(x.dtype), ckv_f)
                  * ckv_scale[:, None, None, :])
        scores = scores + (
            L.accum_einsum("bqhd,bkd->bhqk", q_rope, krope_f)
            * krope_scale[:, None, None, :])
    else:
        scores = L.accum_einsum("bqhr,bkr->bhqk", q_lat.astype(x.dtype),
                                ckv_all.astype(x.dtype))
        scores = scores + L.accum_einsum(
            "bqhd,bkd->bhqk", q_rope, krope_all.astype(q_rope.dtype))
    scores = scores / jnp.sqrt(dn + dr).astype(jnp.float32)
    off = jnp.asarray(offset, jnp.int32)
    off = off[None] if off.ndim == 0 else off            # (1,) or (B,)
    qpos = off[:, None] + jnp.arange(s, dtype=jnp.int32)[None, :]
    kpos = jnp.arange(sk, dtype=jnp.int32)[None, None, :]
    scores = jnp.where((kpos <= qpos[:, :, None])[:, None], scores, -1e30)
    if length is not None:
        valid = jnp.arange(sk)[None, :] < length[:, None]
        scores = jnp.where(valid[:, None, None, :], scores, -1e30)
    if start is not None:
        live = jnp.arange(sk)[None, :] >= start[:, None]
        scores = jnp.where(live[:, None, None, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)

    # values from the latent: v = ckv W_uv, attended in latent space first.
    if ckv_scale is not None:
        lat = L.accum_einsum(
            "bhqk,bkr->bqhr",
            (probs * ckv_scale[:, None, None, :]).astype(x.dtype), ckv_f)
    else:
        lat = L.accum_einsum("bhqk,bkr->bqhr", probs.astype(x.dtype),
                             ckv_all.astype(x.dtype))
    w_uv = params["w_uv"].reshape(r, h, dv).astype(x.dtype)
    out = L.accum_einsum("bqhr,rhd->bqhd", lat.astype(x.dtype), w_uv)
    out = out.reshape(b, s, h * dv).astype(x.dtype)
    return L.dense(out, params["wo"], qc, tp="row"), new_cache


# ---------------------------------------------------------------------------
# Cross attention (whisper decoder)
# ---------------------------------------------------------------------------

def init_cross(key, cfg: ArchConfig, dtype=jnp.float32):
    d, h = cfg.d_model, cfg.n_heads
    hd = cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    return {
        "wq": L.init_dense_weight(ks[0], (d, h * hd), dtype=dtype),
        "wk": L.init_dense_weight(ks[1], (d, h * hd), dtype=dtype),
        "wv": L.init_dense_weight(ks[2], (d, h * hd), dtype=dtype),
        "wo": L.init_dense_weight(ks[3], (h * hd, d), dtype=dtype),
    }


def cross_attention(params, x: jax.Array, enc: jax.Array, cfg: ArchConfig) -> jax.Array:
    b, s, d = x.shape
    se = enc.shape[1]
    h, hd = cfg.n_heads, cfg.resolved_head_dim
    qc = cfg.quant
    q = L.dense(x, params["wq"], qc).reshape(b, s, h, hd)
    k = L.dense(enc, params["wk"], qc).reshape(b, se, h, hd)
    v = L.dense(enc, params["wv"], qc).reshape(b, se, h, hd)
    out = _sdpa(q, k, v, causal_offset=None)
    return L.dense(out.reshape(b, s, h * hd), params["wo"], qc, tp="row")
