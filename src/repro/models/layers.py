"""Shared neural-net building blocks (pure JAX, functional).

Every weight-bearing matmul flows through :func:`dense`, which implements
the paper's technique as a first-class mode switch:

  * quant_mode="off"     — bf16/f32 matmul (fp baseline),
  * quant_mode="ternary" — STE-ternarized weights & activations, exact
                           matmul (the software-level ternary DNN the
                           paper's accelerator executes),
  * quant_mode="cim"     — STE-ternarized weights & activations computed
                           with the SiTe CiM array semantics (16-row block
                           ADC clamp) via the execution API
                           (repro.api.execute with a CiMExecSpec).

Every ternary MAC goes through ``repro.core.execution.execute``: the
``QuantConfig`` mode (plus an optional explicit ``exec_spec`` override)
resolves to a declarative ``CiMExecSpec``, and the registry picks the
kernel. Scales: output = (x_t @ w_t) * sx * sw  — activation scale
(per-tensor by default, per-row under ``QuantConfig.act_scale=
"per_row"`` for row-independent batched serving) and per-output-channel
weight scale, both folded after the ternary MAC, which is exactly where
the TiM-DNN peripheral applies them.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import ternary as tern
from repro.core.execution import CiMExecSpec, execute as exec_mac

Param = jax.Array

# --- einsum accumulation strategy -----------------------------------------
# TPU MXU consumes bf16 operands with f32 accumulation natively
# (preferred_element_type) — no f32 copies of big operands (KV caches!).
# XLA:CPU *compiles* that form but cannot execute it, so CPU execution
# falls back to f32 casts. The dry-run (compile-only) forces native mode
# to produce the TPU-target HLO.
_NATIVE_ACCUM: bool | None = None  # None = auto (native unless CPU)


def set_native_accum(on: bool | None) -> None:
    global _NATIVE_ACCUM
    _NATIVE_ACCUM = on


def _native() -> bool:
    if _NATIVE_ACCUM is not None:
        return _NATIVE_ACCUM
    return jax.default_backend() != "cpu"


def accum_einsum(spec: str, *ops: jax.Array) -> jax.Array:
    """einsum with f32 accumulation; bf16-native on TPU, f32-cast on CPU."""
    if _native():
        return jnp.einsum(spec, *ops, preferred_element_type=jnp.float32)
    return jnp.einsum(spec, *[o.astype(jnp.float32) for o in ops])


@dataclasses.dataclass(frozen=True)
class QuantConfig:
    """Paper-technique mode switch.

    mode:
      off       — fp baseline.
      ternary   — STE-quantized weights/activations, exact matmul (the
                  software-level ternary DNN).
      cim       — SiTe CiM array semantics via the blocked jnp formulation
                  (bit-exact per-16-block ADC clamp). XLA materializes the
                  (tokens, K/16, N) block intermediates in HBM — this is
                  the faithful *naive* lowering and the §Perf baseline.
      cim_fused — cost-faithful stand-in for the Pallas CiM kernel
                  (kernels/ternary_mac.py): two full-depth dots (signed +
                  magnitude) + elementwise combine; on TPU the per-block
                  clamp happens inside the kernel's VMEM tiles, so no
                  block intermediates reach HBM. Clamp numerics are
                  validated against the oracle in tests/test_kernels.py;
                  this mode's HLO reproduces the kernel's FLOP/byte
                  structure for the dry-run/roofline.
    """
    mode: str = "off"            # off | ternary | cim | cim_fused
    block: int = 16              # N_A rows per CiM cycle
    adc_max: int = 8             # 3-bit ADC + extra SA
    quantize_activations: bool = True
    # Activation-scale granularity. "per_tensor" (default, the TiM-DNN
    # peripheral's single scale) couples every row of a batched MAC
    # through one amax — co-batched serving rows then perturb each other.
    # "per_row" scales each (..., K) row independently (the per-input
    # granularity RRAM ternary-TNN work like Laborieux et al. uses):
    # fused-batch rows become numerically independent, so quantized
    # fused/TP serving is exactly token-identical to per-request
    # generate() (DESIGN.md §9; pinned in tests/test_tp_serve.py).
    act_scale: str = "per_tensor"   # per_tensor | per_row
    corrected: bool = False      # clip-as-correction formulation (perf opt)
    # TWN threshold factor: delta = factor * E[|w|] (Li et al.)
    threshold_factor: float = tern.TWN_THRESHOLD_FACTOR
    # Explicit execution spec. When set it overrides the mode-derived
    # spec entirely (new backends/formulations plug in here without any
    # layer-code change); when None, ``resolved_spec`` derives one from
    # (mode, block, adc_max, corrected).
    exec_spec: Optional[CiMExecSpec] = None
    # Serving: weights were ternarized offline (quant.prepare) — skip the
    # per-step STE re-quantization (which costs ~4 passes over every
    # weight). Per-channel scales are folded into the stored weights.
    pre_quantized: bool = False
    # TP serving: how the row-parallel (contraction-dim-sharded) dense
    # layers all-reduce their partial sums. "none" leaves it to the GSPMD
    # partitioner (exact, implicit). "int8" routes the MAC through the
    # explicit shard_map path (execution.execute_tp) with the
    # int8-compressed collective — 4x less TP wire traffic for
    # quantization-level error. Needs dist.sharding.set_tp_mesh (the
    # serving engine installs it for compress_tp=True); inference-only.
    tp_reduce: str = "none"      # none | int8
    # KV-cache storage precision (DESIGN.md §13). "bf16" stores the
    # cache full-precision (bit-identical to the pre-§13 engine, pinned
    # by test). "int8" stores symmetric int8 codes + one f32 scale per
    # (row, position); "ternary" stores {-1,0,1} codes nibble-packed two
    # per byte + the TWN per-(row, position) scale — 2x / 4x slot
    # capacity at equal cache memory. Orthogonal to ``mode`` (the cache
    # holds activations, not weights); SSM conv/state caches stay exact.
    cache_dtype: str = "bf16"    # bf16 | int8 | ternary

    def __post_init__(self):
        if self.mode not in ("off", "ternary", "cim", "cim_fused"):
            raise ValueError(self.mode)
        if self.cache_dtype not in ("bf16", "int8", "ternary"):
            raise ValueError(
                f"unknown cache_dtype {self.cache_dtype!r} "
                "(bf16 | int8 | ternary)"
            )
        if self.tp_reduce not in ("none", "int8"):
            raise ValueError(f"unknown tp_reduce {self.tp_reduce!r}")
        if self.act_scale not in ("per_tensor", "per_row"):
            raise ValueError(
                f"unknown act_scale {self.act_scale!r} (per_tensor | per_row)"
            )
        if self.tp_reduce != "none" and self.mode == "off":
            raise ValueError(
                "tp_reduce compresses the quantized dense path's TP "
                "all-reduce; mode='off' runs no ternary MAC to compress"
            )
        if self.mode == "off" and self.exec_spec is not None:
            # dense() short-circuits to the fp matmul on mode="off" and
            # would never consult the spec — reject rather than ignore
            raise ValueError(
                "exec_spec has no effect with mode='off'; pick a "
                "quantized mode (serve.engine.apply_exec_spec upgrades "
                "the mode for you)"
            )

    def resolved_spec(self) -> CiMExecSpec:
        """The CiMExecSpec this config executes ternary MACs under."""
        if self.exec_spec is not None:
            return self.exec_spec
        if self.mode == "off":
            # fp baseline executes no ternary MAC — fabricating a CiM
            # spec here would attribute CiM semantics/costs to a model
            # that never runs them (dense() short-circuits before this)
            raise ValueError("mode='off' has no CiM execution spec")
        if self.mode == "ternary":
            # operand-dtype exact dot (bf16 TP all-reduces — §Perf A4)
            return CiMExecSpec(formulation="exact", backend="jnp",
                               block=self.block, adc_max=self.adc_max)
        if self.mode == "cim_fused":
            return CiMExecSpec(formulation="fused", backend="jnp",
                               block=self.block, adc_max=self.adc_max)
        formulation = "corrected" if self.corrected else "blocked"
        backend = "jnp" if self.corrected else "auto"
        return CiMExecSpec(formulation=formulation, backend=backend,
                           block=self.block, adc_max=self.adc_max)


def _ternarize_weight(
    w: jax.Array, factor: float = tern.TWN_THRESHOLD_FACTOR
) -> Tuple[jax.Array, jax.Array]:
    """Per-output-channel (last dim) ternarization with STE.

    Returns (w_t, scale) where w_t in {-1,0,1} and scale has shape (1, N).
    Gradients flow straight-through to the latent fp weight.
    """
    t, scale = tern.ternarize(w, axis=tuple(range(w.ndim - 1)), factor=factor)
    # STE: forward EXACTLY t (w + sg(t - w) is not value-exact in bf16 —
    # the rounding perturbs the CiM event counts), backward identity.
    w_t = t + (w - jax.lax.stop_gradient(w))
    return w_t, jax.lax.stop_gradient(scale)


def _ternarize_act(
    x: jax.Array,
    factor: float = tern.TWN_THRESHOLD_FACTOR,
    per_row: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    """Activation ternarization with STE; returns (x_t, scale).

    ``per_row=False``: one scale for the whole tensor (scalar).
    ``per_row=True``: threshold and scale per (..., K) row — shape
    (..., 1) — so each batched row quantizes independently of its
    batchmates (row-independent numerics; DESIGN.md §9).
    """
    axis = (x.ndim - 1,) if per_row else None
    t, scale = tern.ternarize(x, axis=axis, factor=factor)
    x_t = t + (x - jax.lax.stop_gradient(x))  # value-exact STE
    return x_t, jax.lax.stop_gradient(scale)


def dense(
    x: jax.Array,
    w: jax.Array,
    qc: QuantConfig,
    bias: Optional[jax.Array] = None,
    key: Optional[jax.Array] = None,
    tp: str = "none",
) -> jax.Array:
    """The mode-switched linear layer. x: (..., K), w: (K, N).

    ``key`` feeds the stochastic sensing-error channel and is required
    when the resolved spec has ``error_prob > 0`` (the model-assembly
    code does not thread per-layer RNG, so noisy specs are for direct
    dense()/api.execute callers — see serve.engine.apply_exec_spec).

    ``tp`` marks how this layer parallelizes under a "model"-axis mesh
    (DESIGN.md §8): "row" = the contraction dim K is the sharded one
    (wo / w_down / w_out — the layers whose partial sums need a TP
    all-reduce every step). With ``qc.tp_reduce="int8"`` and a TP mesh
    installed (dist.sharding.set_tp_mesh), row-parallel quantized MACs
    route through the explicit ``execution.execute_tp`` shard_map path
    so that all-reduce moves an int8 payload; everything else keeps the
    implicit GSPMD collectives (exact).
    """
    if qc.mode == "off":
        out = x @ w.astype(x.dtype)
    else:
        if qc.pre_quantized:
            # weights were ternarized offline with the per-channel scale
            # folded in (values in {-s_n, 0, +s_n}); recover (t, s) with a
            # single max-reduce — the CiM event counts need pure {-1,0,1}
            # operands, and this is one pass over w instead of the ~4 the
            # STE threshold quantizer costs.
            sw = jnp.max(jnp.abs(w), axis=tuple(range(w.ndim - 1)), keepdims=True)
            w_t = w / jnp.maximum(sw, jnp.asarray(1e-12, w.dtype))
            sw = jax.lax.stop_gradient(sw)
        else:
            w_t, sw = _ternarize_weight(w, qc.threshold_factor)
        if qc.quantize_activations:
            x_t, sx = _ternarize_act(x, qc.threshold_factor,
                                     per_row=qc.act_scale == "per_row")
        else:
            x_t, sx = x, jnp.ones((), x.dtype)
        # One dispatch point for every ternary MAC: the spec (derived from
        # the mode, or an explicit qc.exec_spec) picks the registered
        # kernel; the shim owns padding, dtype policy, and the STE VJP.
        #   ternary    -> exact/jnp: operand-dtype dot (the TP partial-sum
        #                 all-reduce then moves bf16, not f32 — §Perf A4)
        #   cim        -> blocked/auto: faithful per-16-block ADC clamp
        #                 (Pallas kernel on TPU, jnp formulation on CPU)
        #   cim_fused  -> fused/jnp: the kernel's HLO cost structure for
        #                 dry-run/roofline work (numerically exact; on TPU
        #                 the clamp happens inside the kernel's VMEM
        #                 tiles, so no block intermediates reach HBM)
        spec = qc.resolved_spec()
        mac = exec_mac
        if qc.tp_reduce == "int8" and tp == "row":
            from repro.core.execution import execute_tp
            from repro.dist.sharding import tp_mesh

            mesh = tp_mesh()
            if mesh is not None and "model" in mesh.axis_names \
                    and spec.resolve().packing == "none":
                # explicit row-parallel shard_map MAC: the per-layer TP
                # partial-sum all-reduce moves int8 (inference-only);
                # the caller's key (if any) seeds the rounding stream
                def mac(spec, x_q, w_q, key=None):
                    return execute_tp(spec, x_q, w_q, mesh,
                                      compressed=True, key=key)

        if spec.clamps:
            out = mac(spec, x_t.astype(jnp.float32), w_t.astype(jnp.float32),
                      key=key)
        else:
            out = mac(spec, x_t.astype(x.dtype), w_t.astype(x.dtype),
                      key=key)
        # fold scales in the output dtype: an f32 round-trip here makes
        # every backward cotangent (and its all-reduce) f32 (§Perf A5)
        out = out.astype(x.dtype) * (sx * sw).astype(x.dtype)
    if bias is not None:
        out = out + bias.astype(out.dtype)
    return out


# ---------------------------------------------------------------------------
# Norms / activations / embeddings
# ---------------------------------------------------------------------------

def rms_norm(x: jax.Array, gamma: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)).astype(x.dtype) * gamma.astype(x.dtype)


def layer_norm(x: jax.Array, gamma: jax.Array, beta: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return y.astype(x.dtype) * gamma.astype(x.dtype) + beta.astype(x.dtype)


def swiglu(x_gate: jax.Array, x_up: jax.Array) -> jax.Array:
    return jax.nn.silu(x_gate) * x_up


def embed(tokens: jax.Array, table: jax.Array) -> jax.Array:
    return jnp.take(table, tokens, axis=0)


def unembed(x: jax.Array, table: jax.Array, qc: QuantConfig) -> jax.Array:
    # The unembedding is a dense layer too; ternary mode applies when the
    # config enables it (logit layers are usually kept high precision —
    # controlled by the arch config's `quantize_unembed`).
    return dense(x, table.T, qc)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float = 10000.0) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float = 10000.0) -> jax.Array:
    """x: (B, S, H, Dh), positions: (B, S) int32."""
    head_dim = x.shape[-1]
    freqs = rope_frequencies(head_dim, theta)           # (Dh/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (B, S, Dh/2)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP blocks
# ---------------------------------------------------------------------------

def init_mlp(key, d_model: int, d_ff: int, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    s_in = d_model ** -0.5
    s_ff = d_ff ** -0.5
    return {
        "w_gate": (jax.random.normal(k1, (d_model, d_ff)) * s_in).astype(dtype),
        "w_up": (jax.random.normal(k2, (d_model, d_ff)) * s_in).astype(dtype),
        "w_down": (jax.random.normal(k3, (d_ff, d_model)) * s_ff).astype(dtype),
    }


def mlp(params, x: jax.Array, qc: QuantConfig) -> jax.Array:
    g = dense(x, params["w_gate"], qc)
    u = dense(x, params["w_up"], qc)
    return dense(swiglu(g, u), params["w_down"], qc, tp="row")


def init_dense_weight(key, shape, fan_in: Optional[int] = None, dtype=jnp.float32):
    fan_in = shape[0] if fan_in is None else fan_in
    return (jax.random.normal(key, shape) * fan_in ** -0.5).astype(dtype)
