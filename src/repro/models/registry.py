"""Architecture registry: --arch <id> -> config, shape cells, input specs."""
from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig

ARCH_IDS = (
    "smollm-135m",
    "starcoder2-7b",
    "starcoder2-15b",
    "yi-34b",
    "mamba2-780m",
    "zamba2-2.7b",
    "deepseek-v2-236b",
    "grok-1-314b",
    "whisper-large-v3",
    "llava-next-34b",
)

_MODULES = {a: a.replace("-", "_").replace(".", "_") for a in ARCH_IDS}


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    kind: str       # train | prefill | decode
    seq: int
    batch: int


SHAPES = {
    "train_4k": ShapeCell("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeCell("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeCell("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeCell("long_500k", "decode", 524288, 1),
}


def get_config(arch: str, smoke: bool = False) -> ArchConfig:
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch]}")
    return mod.SMOKE_CONFIG if smoke else mod.CONFIG


def cell_supported(cfg: ArchConfig, shape: ShapeCell) -> Optional[str]:
    """None if the (arch, shape) cell runs; otherwise the skip reason."""
    if shape.name == "long_500k" and not cfg.subquadratic:
        return (
            "long_500k requires sub-quadratic attention; "
            f"{cfg.name} is pure full-attention (DESIGN.md §5)"
        )
    return None


def all_cells(smoke: bool = False):
    """Yield (arch, shape_cell, skip_reason)."""
    for arch in ARCH_IDS:
        cfg = get_config(arch, smoke=smoke)
        for shape in SHAPES.values():
            yield arch, shape, cell_supported(cfg, shape)


# ---------------------------------------------------------------------------
# Input specs (ShapeDtypeStructs — no allocation; dry-run currency)
# ---------------------------------------------------------------------------

def input_specs(cfg: ArchConfig, shape: ShapeCell) -> Dict[str, jax.ShapeDtypeStruct]:
    """Model inputs for a cell, as ShapeDtypeStructs.

    train:   full (B, S) token/label batch.
    prefill: (B, S) tokens, logits out.
    decode:  (B, 1) new token; KV caches are supplied separately
             (see repro.launch.dryrun.decode_cache_specs).
    """
    i32 = jnp.int32
    b, s = shape.batch, shape.seq
    if shape.kind in ("train", "prefill"):
        n_img = cfg.n_image_tokens if cfg.family == "vlm" else 0
        specs = {
            "tokens": jax.ShapeDtypeStruct((b, s - n_img), i32),
        }
        if shape.kind == "train":
            specs["labels"] = jax.ShapeDtypeStruct((b, s - n_img), i32)
        if cfg.family == "encdec":
            specs["frames"] = jax.ShapeDtypeStruct(
                (b, cfg.encoder_seq, cfg.d_model), jnp.bfloat16
            )
        if cfg.family == "vlm":
            specs["patches"] = jax.ShapeDtypeStruct((b, n_img, cfg.d_vision), jnp.bfloat16)
        return specs
    # decode: one new token against a seq_len-deep cache
    specs = {"tokens": jax.ShapeDtypeStruct((b, 1), i32)}
    if cfg.family == "encdec":
        specs["enc"] = jax.ShapeDtypeStruct((b, cfg.encoder_seq, cfg.d_model), jnp.bfloat16)
    return specs
