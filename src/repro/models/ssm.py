"""Mamba2 — SSD (state-space duality) layer, chunked scan + O(1) decode.

Implements the minimal SSD form of Mamba-2 (Dao & Gu, arXiv:2405.21060):

  h_t = exp(dt_t * A) * h_{t-1} + dt_t * B_t x_t^T        (per head)
  y_t = C_t h_t + D x_t

computed with the chunked algorithm: within-chunk quadratic attention-like
term + inter-chunk state recurrence (a lax.scan over chunks, O(L) total).
Decode keeps (conv_state, ssm_state) caches for O(1) per-token steps —
this is why mamba2/zamba2 are the archs assigned the ``long_500k`` cell.

Projections route through ``layers.dense`` (ternary/CiM modes apply); the
state recurrence itself is activation math and stays bf16 (DESIGN.md §5).
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import layers as L


class SSMCache(NamedTuple):
    conv: jax.Array   # (B, W-1, conv_channels) rolling conv window
    state: jax.Array  # (B, H, P, N) ssm state

    @staticmethod
    def zeros(batch: int, cfg: ArchConfig, dtype=jnp.float32):
        di = cfg.ssm_d_inner
        conv_ch = di + 2 * cfg.ssm_n_groups * cfg.ssm_state
        h = cfg.ssm_n_heads
        p = cfg.ssm_head_dim
        return SSMCache(
            jnp.zeros((batch, cfg.ssm_conv_width - 1, conv_ch), dtype),
            jnp.zeros((batch, h, p, cfg.ssm_state), dtype),
        )


def init_mamba2(key, cfg: ArchConfig, dtype=jnp.float32):
    d = cfg.d_model
    di = cfg.ssm_d_inner
    g, n = cfg.ssm_n_groups, cfg.ssm_state
    h = cfg.ssm_n_heads
    conv_ch = di + 2 * g * n
    ks = jax.random.split(key, 5)
    return {
        # in_proj -> [z (di), x (di), B (g*n), C (g*n), dt (h)]
        "w_in": L.init_dense_weight(ks[0], (d, 2 * di + 2 * g * n + h), dtype=dtype),
        "conv_w": (jax.random.normal(ks[1], (cfg.ssm_conv_width, conv_ch)) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, h)).astype(jnp.float32),
        "D": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "norm": jnp.ones((di,), dtype),
        "w_out": L.init_dense_weight(ks[4], (di, d), dtype=dtype),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv. x: (B, S, C), w: (W, C)."""
    width = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(width):
        out = out + xp[:, i : i + x.shape[1], :] * w[i][None, None, :]
    return out + b[None, None, :]


def _ssd_chunked(x, dt, A, B, C, D, chunk: int):
    """Chunked SSD. Shapes:
      x: (b, l, h, p), dt: (b, l, h), A: (h,) negative decay rates,
      B, C: (b, l, g, n). Returns y: (b, l, h, p), final_state (b, h, p, n).
    """
    b, l, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    assert l % chunk == 0, (l, chunk)
    nc = l // chunk
    heads_per_group = h // g

    # broadcast B, C to heads
    Bh = jnp.repeat(B, heads_per_group, axis=2)  # (b, l, h, n)
    Ch = jnp.repeat(C, heads_per_group, axis=2)

    # reshape to chunks
    xc = x.reshape(b, nc, chunk, h, p)
    dtc = dt.reshape(b, nc, chunk, h)
    Bc = Bh.reshape(b, nc, chunk, h, n)
    Cc = Ch.reshape(b, nc, chunk, h, n)

    dA = dtc * A[None, None, None, :]                # (b, nc, c, h) negative
    cum = jnp.cumsum(dA, axis=2)                     # within-chunk cumulative

    # --- within-chunk (quadratic in chunk) ---
    # L[i, j] = exp(cum_i - cum_j) for j <= i. Mask the *argument* before
    # exp: masked (j > i) entries have positive arguments whose exp
    # overflows, and where(mask, inf, 0) produces NaN gradients.
    li = cum[:, :, :, None, :]                       # (b, nc, c, 1, h)
    lj = cum[:, :, None, :, :]                       # (b, nc, 1, c, h)
    mask = jnp.tril(jnp.ones((chunk, chunk), bool))
    delta = jnp.where(mask[None, None, :, :, None], li - lj, -1e30)
    decay = jnp.exp(delta)
    cb = jnp.einsum("bzihn,bzjhn->bzijh", Cc, Bc)    # (b, nc, c, c, h)
    att = cb * decay
    y_diag = jnp.einsum("bzijh,bzjh,bzjhp->bzihp", att, dtc, xc)

    # --- chunk states ---
    chunk_sum = cum[:, :, -1, :]                     # (b, nc, h) total decay
    # state contribution of each position: decay to end of chunk
    state_w = jnp.exp(chunk_sum[:, :, None, :] - cum)  # (b, nc, c, h)
    states = jnp.einsum("bzch,bzch,bzchn,bzchp->bzhpn", state_w, dtc, Bc, xc)

    # --- inter-chunk recurrence (scan over chunks) ---
    def step(h_prev, inp):
        st, dsum = inp                               # (b,h,p,n), (b,h)
        h_new = h_prev * jnp.exp(dsum)[:, :, None, None] + st
        return h_new, h_prev

    h0 = jnp.zeros((b, h, p, n), x.dtype)
    states_t = jnp.moveaxis(states, 1, 0)            # (nc, b, h, p, n)
    dsum_t = jnp.moveaxis(chunk_sum, 1, 0)           # (nc, b, h)
    h_final, h_prevs = jax.lax.scan(step, h0, (states_t, dsum_t))
    h_prevs = jnp.moveaxis(h_prevs, 0, 1)            # (b, nc, h, p, n) state entering chunk

    # --- contribution of carried-in state to each position ---
    pos_decay = jnp.exp(cum)                         # (b, nc, c, h)
    y_carry = jnp.einsum("bzchn,bzhpn,bzch->bzchp", Cc, h_prevs, pos_decay)

    y = (y_diag + y_carry).reshape(b, l, h, p)
    y = y + x * D[None, None, :, None]
    return y, h_final


def mamba2_block(
    params,
    x: jax.Array,
    cfg: ArchConfig,
    cache: Optional[SSMCache] = None,
    valid: Optional[jax.Array] = None,
) -> Tuple[jax.Array, Optional[SSMCache]]:
    """x: (B, S, D). Without cache: chunked parallel form (training /
    prefill). With cache: any S ≥ 1 — S = 1 is the O(1) decode step,
    S > 1 is cached prefill (conv window seeded from the cache, state
    recurrence continued from ``cache.state``).

    ``valid`` (B, S) marks real columns in a left-padded batched prefill:
    pad columns contribute nothing — their raw conv inputs are zeroed
    (matching the zero-initialized conv window of an unpadded run) and
    their dt is zeroed, which freezes the state (exp(0·A) = 1, no B·x
    injection)."""
    b, s, d = x.shape
    di = cfg.ssm_d_inner
    g, n = cfg.ssm_n_groups, cfg.ssm_state
    h, p = cfg.ssm_n_heads, cfg.ssm_head_dim
    qc = cfg.quant

    zxbcdt = L.dense(x, params["w_in"], qc)
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di : di + di + 2 * g * n]
    dt_raw = zxbcdt[..., -h:]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"][None, None, :])
    A = -jnp.exp(params["A_log"])

    if cache is None:
        xbc = _causal_conv(xbc, params["conv_w"], params["conv_b"])
        xbc = jax.nn.silu(xbc)
        xs = xbc[..., :di].reshape(b, s, h, p).astype(jnp.float32)
        B_ = xbc[..., di : di + g * n].reshape(b, s, g, n).astype(jnp.float32)
        C_ = xbc[..., di + g * n :].reshape(b, s, g, n).astype(jnp.float32)
        chunk = min(cfg.ssm_chunk, s)
        pad = (-s) % chunk
        if pad:
            xs = jnp.pad(xs, ((0, 0), (0, pad), (0, 0), (0, 0)))
            B_ = jnp.pad(B_, ((0, 0), (0, pad), (0, 0), (0, 0)))
            C_ = jnp.pad(C_, ((0, 0), (0, pad), (0, 0), (0, 0)))
            dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        y, h_final = _ssd_chunked(xs, dt, A, B_, C_, params["D"], chunk)
        y = y[:, :s]
        new_cache = None
    else:
        # decode / cached prefill: the last W-1 *raw* conv inputs ride in
        # cache.conv; run the depthwise causal conv over the extended
        # window and continue the state recurrence from cache.state with
        # a sequential scan over the S new tokens (S = 1: one recurrent
        # update, the O(1) decode step).
        if valid is not None:
            keep = valid[:, :, None]
            xbc = jnp.where(keep, xbc, jnp.zeros((), xbc.dtype))
            dt = jnp.where(valid[:, :, None], dt, 0.0)
        conv_in = jnp.concatenate([cache.conv, xbc], axis=1)  # (B, W-1+S, C)
        w = params["conv_w"]
        width = w.shape[0]
        conv_out = sum(
            conv_in[:, i : i + s, :] * w[i][None, None, :] for i in range(width)
        )
        xbc_f = jax.nn.silu(conv_out + params["conv_b"][None, None, :])
        xs = xbc_f[..., :di].reshape(b, s, h, p).astype(jnp.float32)
        B_ = xbc_f[..., di : di + g * n].reshape(b, s, g, n).astype(jnp.float32)
        C_ = xbc_f[..., di + g * n :].reshape(b, s, g, n).astype(jnp.float32)
        hp = h // g
        Bh = jnp.repeat(B_, hp, axis=2)                       # (b, s, h, n)
        Ch = jnp.repeat(C_, hp, axis=2)
        dA = jnp.exp(dt * A[None, None, :])                   # (b, s, h)

        def step(state, inp):
            x_t, B_t, C_t, dt_t, dA_t = inp
            state = state * dA_t[:, :, None, None] + jnp.einsum(
                "bh,bhn,bhp->bhpn", dt_t, B_t, x_t)
            y_t = jnp.einsum("bhn,bhpn->bhp", C_t, state)
            return state, y_t

        to_time = lambda a: jnp.moveaxis(a, 1, 0)
        state, ys = jax.lax.scan(
            step, cache.state,
            (to_time(xs), to_time(Bh), to_time(Ch), to_time(dt), to_time(dA)),
        )
        y = jnp.moveaxis(ys, 0, 1)                            # (b, s, h, p)
        y = y + xs * params["D"][None, None, :, None]
        new_cache = SSMCache(conv=conv_in[:, s:], state=state)

    y = y.reshape(b, s, di).astype(x.dtype)
    y = L.rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype), params["norm"])
    return L.dense(y, params["w_out"], qc, tp="row"), new_cache
