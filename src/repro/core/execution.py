"""Declarative CiM execution API — the single dispatch point for every
signed-ternary MAC in the repo (re-exported as ``repro.api``).

Motivation (DESIGN.md §3): the SiTe CiM dot product used to be reachable
through seven parallel entry points (``site_cim_matmul``,
``site_cim_matmul_corrected``, ``site_cim_matmul_bitplane``,
``nm_ternary_matmul``, ``kernels.ops.cim_matmul``,
``exact_ternary_matmul``, ``packed_cim_matmul``), each with its own
padding/dtype/VJP/backend-selection logic. TiM-DNN and STeP-CiM show the
same functional MAC semantics recur across array technologies — so the
dispatch is now data, not code:

    spec = CiMExecSpec(formulation="blocked", backend="auto")
    out  = execute(spec, x_t, w_t)

``CiMExecSpec`` names *what* to compute (formulation, ADC clamp, flavor,
sensing-error channel) and *how* (backend kernel, weight packing). A
registry maps resolved ``(formulation, backend, packing)`` keys to kernel
functions; new formulations/kernels plug in with ``register_backend``
without touching any call site. One shared shim owns:

  * leading-batch-dim flattening (kernels see (M, K) x (K, N)),
  * contraction-dim padding to the block granularity (zero rows are
    inert under the a/b event-count semantics),
  * the straight-through-estimator ``custom_vjp`` (backward = exact
    matmul; the ADC clamp is piecewise linear with slope 1 almost
    everywhere — DESIGN.md §4),
  * the stochastic sensing-error channel (±1 ADC-level flips per block
    partial, paper rate 3.1e-3),
  * output dtype restoration (results return in the input dtype).

Built-in formulations:

  exact     — near-memory baseline, no clamp (paper's NM design).
  blocked   — faithful per-16-row a/b event counts + 3-bit ADC clamp.
  corrected — clip-as-correction: exact full-depth dot + rare clamp
              correction term (numerically == blocked, DESIGN.md §2).
  bitplane  — event counting over the (M1, M2) bitplanes; mirrors the
              circuit directly and serves as the structural test oracle.
  fused     — two full-depth dots (signed + magnitude) + elementwise
              combine; the Pallas kernel's HLO cost structure for
              dry-run/roofline work (numerically == exact).

Backends: ``jnp`` lowers everywhere (CPU, autodiff tracing, pjit);
``pallas`` uses the TPU kernels in repro.kernels (interpret mode off
TPU); ``pallas_stream`` is the double-buffered streaming decode variant
(plane DMA overlapped with the MAC — DESIGN.md §14); ``auto`` resolves
to pallas on TPU else jnp. Packing ``bitplane_u8`` stores weights as two
packed uint8 bitplanes, 2 bits per ternary weight (the memory-macro
layout; 8x less HBM weight traffic than int8).

Shape-aware dispatch (DESIGN.md §9): pallas registry entries carry a
*tile table* — ``(bm, bk, bn)`` as a function of (M, K, N) — with a
**decode class** (M <= :data:`DECODE_M_MAX`) that selects small-M tiles
instead of padding every activation to the 128-row MXU tile (a 3-slot
decode step would waste >97% of the MXU rows). ``tiles_for`` resolves
the tiles for a call (autotuned winners first, then the entry's table)
*outside* the jit boundary, so the choice participates in the trace
cache key; :func:`autotune` benchmarks the registered candidates per
(spec, shape-class) and caches winners for every later ``execute``.
"""
from __future__ import annotations

import dataclasses
import functools
import math
import threading
from typing import Callable, Dict, Iterator, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import ternary as tern
from repro.kernels import ref
from repro.kernels.packed_mac import (
    packed_cim_matmul,
    packed_cim_matmul_decode,
    packed_cim_matmul_decode_stream,
)
from repro.kernels.ternary_mac import ternary_cim_matmul, ternary_exact_matmul

FORMULATIONS = ("exact", "blocked", "corrected", "bitplane", "fused")
BACKENDS = ("auto", "pallas", "jnp")
PACKINGS = ("none", "bitplane_u8")
FLAVORS = ("I", "II")


@dataclasses.dataclass(frozen=True)
class CiMExecSpec:
    """Declarative description of one ternary-MAC execution.

    formulation: exact | blocked | corrected | bitplane | fused (or any
      name later added via :func:`register_backend`).
    backend:     auto | pallas | jnp ("auto" = pallas on TPU, else jnp).
    packing:     none | bitplane_u8 (2-bit differential weight storage).
    flavor:      "I" | "II" — identical MAC math; the flavors differ in
      circuits/latency/energy (core/cost_model.py; see
      :func:`spec_design` for the cost-model mapping).
    block:       rows asserted per array cycle (paper N_A = 16).
    adc_max:     ADC clamp bound for the a/b event counts (3-bit + extra
      sense amp = 8). Only clamping formulations consume it.
    error_prob:  per-block sensing-error probability (paper: 3.1e-3);
      requires a PRNG key at :func:`execute` time when > 0.
    """

    formulation: str = "blocked"
    backend: str = "auto"
    packing: str = "none"
    flavor: str = "I"
    block: int = 16
    adc_max: int = 8
    error_prob: float = 0.0

    def __post_init__(self):
        # formulation/backend/packing are open sets: anything a plugin
        # has put in the registry is valid, so validation accepts the
        # built-ins plus every registered key dimension (typos still die
        # early; genuinely new names registered via register_backend
        # pass). "auto" stays backend-only.
        if not self.formulation or not isinstance(self.formulation, str):
            raise ValueError(f"bad formulation {self.formulation!r}")
        formulations = set(FORMULATIONS) | {k[0] for k in _REGISTRY}
        if self.formulation not in formulations:
            raise ValueError(
                f"unknown formulation {self.formulation!r} "
                f"(use one of {sorted(formulations)})"
            )
        backends = set(BACKENDS) | {k[1] for k in _REGISTRY}
        if self.backend not in backends:
            raise ValueError(
                f"unknown backend {self.backend!r} (use one of {sorted(backends)})"
            )
        packings = set(PACKINGS) | {k[2] for k in _REGISTRY}
        if self.packing not in packings:
            raise ValueError(
                f"unknown packing {self.packing!r} (use one of {sorted(packings)})"
            )
        if self.flavor not in FLAVORS:
            raise ValueError(f"unknown SiTe CiM flavor {self.flavor!r}")
        if self.block <= 0:
            raise ValueError(f"block must be positive, got {self.block}")
        if self.adc_max <= 0:
            raise ValueError(f"adc_max must be positive, got {self.adc_max}")

    def resolve(self) -> "CiMExecSpec":
        """Fix "auto" to a concrete backend for the current platform."""
        if self.backend != "auto":
            return self
        backend = "pallas" if jax.default_backend() == "tpu" else "jnp"
        return dataclasses.replace(self, backend=backend)

    @property
    def clamps(self) -> bool:
        entry = _REGISTRY.get(self.resolve().registry_key)
        if entry is not None:
            return entry.clamps
        return self.formulation in ("blocked", "corrected", "bitplane")

    @property
    def registry_key(self) -> Tuple[str, str, str]:
        return (self.formulation, self.backend, self.packing)

    @property
    def name(self) -> str:
        return "/".join(self.registry_key)


# ---------------------------------------------------------------------------
# Backend registry
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class BackendEntry:
    """One registered MAC kernel: the callable plus the registry's
    static metadata about it (whether the formulation clamps, and the
    tile table for tiled backends) — see :func:`register_backend`."""

    fn: Callable  # fn(x2d, w, spec[, tiles]) -> (M, N); K padded to block
    clamps: bool  # whether the formulation applies the ADC clamp
    # (m, k, n) -> (bm, bk, bn) tile table; None = kernel has no tiling
    # dimension (jnp formulations). When set, ``fn`` takes a 4th ``tiles``
    # argument and the shim resolves it via tiles_for outside the jit.
    # Streaming entries return 4-tuples (bm, bk, bn, nbuf) — nbuf is the
    # VMEM buffer depth of the DMA double buffer.
    tiles: Optional[Callable[[int, int, int], Tuple[int, ...]]] = None
    # per-shape-class autotune candidates overriding the global
    # _TILE_CANDIDATES (entries whose tile tuples carry extra dimensions
    # — e.g. the stream backend's buffer depth — sweep their own grid)
    tile_candidates: Optional[Dict[str, Tuple[Tuple[int, ...], ...]]] = None


_REGISTRY: Dict[Tuple[str, str, str], BackendEntry] = {}


def _parse_key(name) -> Tuple[str, str, str]:
    if isinstance(name, tuple):
        key = name
    else:
        key = tuple(str(name).split("/"))
    if len(key) != 3:
        raise ValueError(
            f"backend key must be 'formulation/backend/packing', got {name!r}"
        )
    return key  # type: ignore[return-value]


def register_backend(name, fn: Callable, *, clamps: bool = True,
                     tiles: Optional[Callable] = None,
                     tile_candidates: Optional[Dict] = None) -> None:
    """Register a MAC kernel under a ``"formulation/backend/packing"``
    key (or an equivalent 3-tuple). ``fn(x2d, w_t, spec)`` receives the
    flattened (M, K) inputs with K padded to the block/packing
    granularity and must return the (M, N) product. ``clamps`` records
    whether the formulation applies the per-block ADC clamp (tests use it
    to pick the right oracle configuration).

    ``tiles``: optional ``(m, k, n) -> (bm, bk, bn)`` tile table for
    tiled (pallas) kernels. When given, ``fn`` is called as
    ``fn(x2d, w_t, spec, tiles)`` with the resolved tile triple (an
    autotuned winner when one is cached, else the table's answer for the
    call's shape class — see :func:`tiles_for`).

    ``tile_candidates``: optional per-shape-class candidate grid for
    :func:`autotune` (entries with non-standard tile tuples — the stream
    backend's ``(bm, bk, bn, nbuf)`` — own their sweep)."""
    key = _parse_key(name)
    if key[1] == "auto":
        raise ValueError("register concrete backends, not 'auto'")
    _REGISTRY[key] = BackendEntry(fn, bool(clamps), tiles, tile_candidates)


def get_backend(spec: CiMExecSpec) -> BackendEntry:
    """The :class:`BackendEntry` registered for ``spec`` (after
    ``resolve()``); raises KeyError listing the known keys."""
    key = spec.resolve().registry_key
    entry = _REGISTRY.get(key)
    if entry is None:
        known = ", ".join("/".join(k) for k in sorted(_REGISTRY))
        raise KeyError(f"no backend registered for {'/'.join(key)} (known: {known})")
    return entry


def registered_specs() -> Iterator[CiMExecSpec]:
    """One CiMExecSpec per registered (formulation, backend, packing)."""
    for f, b, p in sorted(_REGISTRY):
        yield CiMExecSpec(formulation=f, backend=b, packing=p)


# ---------------------------------------------------------------------------
# Shape classes, tile tables, autotune (DESIGN.md §9)
# ---------------------------------------------------------------------------

# decode regime boundary: at M <= 8 the MAC is weight-streaming-bound and
# padding M to the 128-row MXU tile wastes >93% of the rows
DECODE_M_MAX = 8

SHAPE_CLASSES = ("decode", "prefill")

# autotuned winners: {(registry_key, block, shape_class): (bm, bk, bn)}
# — block is part of the key because it sets the bk validity granularity
# (a winner tuned at block=16 may not tile a block=64 spec)
_TILE_CACHE: Dict[Tuple, Tuple[int, int, int]] = {}

# benchmark/test lever: force every call into one shape class (None = off)
_CLASS_OVERRIDE: Optional[str] = None

# Guards _TILE_CACHE and _CLASS_OVERRIDE: the front door's ReplicaRouter
# drives N ContinuousBatchers from N single-thread executors, so
# tiles_for races autotune/override writes without it. Dict reads of
# CPython builtins are atomic, but the override read-compose-lookup in
# tiles_for is not — and the override context manager below must
# restore the *pre-entry* value even under interleaving.
_DISPATCH_LOCK = threading.Lock()


def shape_class(m: int) -> str:
    """The dispatch class of an (M, K) x (K, N) MAC: "decode" for
    M <= DECODE_M_MAX (ragged decode steps, M = occupied slots), else
    "prefill" (prompt/training shapes that fill MXU tiles)."""
    return "decode" if m <= DECODE_M_MAX else "prefill"


class _ShapeClassOverride:
    """Handle returned by :func:`set_shape_class_override`. The override
    is already installed at construction; using the handle as a context
    manager restores the previous value on exit, so

        with set_shape_class_override("prefill"):
            ...

    is exception-safe, while the historical imperative call (ignore the
    return value, later call ``set_shape_class_override(None)``) keeps
    working unchanged."""

    def __init__(self, prev: Optional[str]):
        self._prev = prev

    def __enter__(self) -> "_ShapeClassOverride":
        return self

    def __exit__(self, *exc) -> bool:
        set_shape_class_override(self._prev)
        return False


def set_shape_class_override(cls: Optional[str]) -> _ShapeClassOverride:
    """Force tile resolution into one shape class regardless of M (the
    pre-PR behaviour is ``"prefill"`` — decode shapes padded to the
    128-row tile). Benchmarks use it to measure old-vs-new on the same
    shape; None restores shape-derived dispatch. Affects new traces only
    (tiles are resolved per call, outside jit). Returns a context
    manager restoring the previous override on exit (optional — plain
    imperative use stays valid). Thread-safe."""
    global _CLASS_OVERRIDE
    if cls is not None and cls not in SHAPE_CLASSES:
        raise ValueError(f"unknown shape class {cls!r} (use {SHAPE_CLASSES})")
    with _DISPATCH_LOCK:
        prev = _CLASS_OVERRIDE
        _CLASS_OVERRIDE = cls
    return _ShapeClassOverride(prev)


def clear_tile_cache() -> None:
    """Drop every autotuned winner (tests / re-tuning). Thread-safe."""
    with _DISPATCH_LOCK:
        _TILE_CACHE.clear()


def tiles_for(
    spec: CiMExecSpec, m: int, k: int, n: int
) -> Optional[Tuple[int, int, int]]:
    """Resolve the (bm, bk, bn) tiles an ``execute`` call will use: an
    autotuned winner for (spec, shape-class) when cached, else the
    registry entry's tile table. None for untiled (jnp) backends.

    Resolved *outside* the jitted forward so the choice is part of the
    trace cache key — flipping the override or re-autotuning retraces
    instead of silently reusing a stale executable."""
    spec = spec.resolve()
    entry = _REGISTRY.get(spec.registry_key)
    if entry is None or entry.tiles is None:
        return None
    with _DISPATCH_LOCK:
        cls = _CLASS_OVERRIDE or shape_class(m)
        cached = _TILE_CACHE.get((spec.registry_key, spec.block, cls))
    if cached is not None:
        return cached
    # an override crossing the natural class substitutes a representative
    # M so the entry table answers for the *forced* class
    if cls != shape_class(m):
        m = DECODE_M_MAX if cls == "decode" else 128
    return entry.tiles(m, k, n)


# tile candidates swept by autotune(), per shape class
_TILE_CANDIDATES: Dict[str, Tuple[Tuple[int, int, int], ...]] = {
    "decode": ((8, 128, 128), (8, 256, 128), (8, 512, 128), (8, 256, 256)),
    "prefill": ((128, 128, 128), (128, 256, 128), (128, 512, 128),
                (256, 256, 128), (128, 256, 256)),
}

# the stream backend's own grid: the 4th element is the VMEM buffer
# depth nbuf ∈ {2, 3} of the DMA double/triple buffer (prefill rows
# delegate to the non-stream prefill kernel, so only tiles matter there)
_STREAM_TILE_CANDIDATES: Dict[str, Tuple[Tuple[int, ...], ...]] = {
    "decode": ((8, 128, 128, 2), (8, 256, 128, 2), (8, 256, 128, 3),
               (8, 512, 128, 2), (8, 512, 128, 3), (8, 256, 256, 2)),
    "prefill": ((128, 256, 128, 2), (128, 512, 128, 2), (128, 256, 256, 2)),
}


def _tiles_valid(spec: CiMExecSpec, tiles: Tuple[int, ...]) -> bool:
    if len(tiles) not in (3, 4):
        return False
    bm, bk, bn = tiles[:3]
    if len(tiles) == 4 and tiles[3] not in (2, 3):
        return False  # stream buffer depth: double or triple buffering
    if spec.packing == "bitplane_u8":
        return bk % (8 * spec.block) == 0  # whole packed bytes, whole blocks
    return bk % spec.block == 0  # the ADC clamp never straddles a K tile


def autotune(
    spec: CiMExecSpec,
    shapes: Tuple[Tuple[int, int, int], ...] = ((4, 1024, 512), (256, 1024, 512)),
    *,
    candidates: Optional[Dict[str, Tuple[Tuple[int, int, int], ...]]] = None,
    repeats: int = 3,
    calibration=None,
) -> Dict[str, Dict]:
    """Benchmark the registered tile candidates for ``spec`` on one
    representative (M, K, N) per shape class and cache the winners —
    every later :func:`execute`/:func:`execute_packed` at that
    (spec, shape-class) picks them up (new traces; run before serving).

    With ``calibration=`` (a ``repro.profile.CalibrationTable`` or any
    object with a ``tile_winners`` mapping), no timing runs: the table's
    recorded winners for ``spec`` are validated and installed directly —
    replaying a past autotune instead of re-measuring on a possibly
    noisy host.

    Entries with their own candidate grids (``tile_candidates`` on the
    registry entry) sweep those instead of the global table — the
    ``pallas_stream`` backend's grid includes the DMA buffer depth
    ``nbuf`` ∈ {2, 3} as a 4th tile element.

    Returns ``{shape_class: {"tiles": winner, "us": best_us,
    "candidates": {"bmxbkxbn": us}}}``. Raises for untiled backends —
    jnp formulations have no tile dimension to tune."""
    import time

    import numpy as np

    spec = spec.resolve()
    entry = get_backend(spec)
    if entry.tiles is None:
        raise ValueError(
            f"{spec.name} has no tile table to autotune (jnp backends "
            f"lower through XLA; only tiled pallas entries tune)"
        )
    if calibration is not None:
        winners = dict(getattr(calibration, "tile_winners", {}) or {})
        per_spec = winners.get(spec.name)
        if not per_spec:
            raise ValueError(
                f"calibration table has no tile winners for {spec.name} "
                f"(known: {sorted(winners)})"
            )
        report = {}
        for cls, tiles in sorted(per_spec.items()):
            if cls not in SHAPE_CLASSES:
                raise ValueError(f"unknown shape class {cls!r} in calibration")
            tiles = tuple(int(t) for t in tiles)
            if not _tiles_valid(spec, tiles):
                raise ValueError(
                    f"calibrated tiles {tiles} invalid for {spec.name} "
                    f"(block={spec.block})"
                )
            with _DISPATCH_LOCK:
                _TILE_CACHE[(spec.registry_key, spec.block, cls)] = tiles
            report[cls] = {"tiles": tiles, "us": None, "candidates": {},
                           "source": "calibration"}
        return report
    key = jax.random.PRNGKey(0)
    report: Dict[str, Dict] = {}
    for m, k, n in shapes:
        cls = shape_class(m)
        kx, kw = jax.random.split(jax.random.fold_in(key, m))
        x = jnp.sign(jax.random.normal(kx, (m, k))).astype(jnp.float32)
        w = jnp.sign(jax.random.normal(kw, (k, n))).astype(jnp.float32)
        if spec.packing == "bitplane_u8":
            from repro.core import ternary as _tern

            p1, p2 = _tern.pack_ternary(w.astype(jnp.int8), axis=0)

            def run(tiles):
                return _packed_forward(spec, tiles, x, p1, p2, n)
        else:

            def run(tiles):
                return _jit_execute(spec, tiles, x, w)

        cands = (candidates or entry.tile_candidates or _TILE_CANDIDATES)[cls]
        timings: Dict[str, float] = {}
        best: Optional[Tuple[int, int, int]] = None
        for tiles in cands:
            if not _tiles_valid(spec, tiles):
                continue
            # analysis: host-sync ok — autotune timing must block the host
            run(tiles).block_until_ready()  # compile outside the clock
            times = []
            for _ in range(max(1, repeats)):
                t0 = time.perf_counter()
                # analysis: host-sync ok — autotune timing must block the host
                run(tiles).block_until_ready()
                times.append(time.perf_counter() - t0)
            us = float(np.min(times) * 1e6)
            timings["x".join(map(str, tiles))] = round(us, 2)
            if best is None or us < timings["x".join(map(str, best))]:
                best = tiles
        if best is None:
            raise ValueError(f"no valid tile candidate for {spec.name}/{cls}")
        with _DISPATCH_LOCK:
            _TILE_CACHE[(spec.registry_key, spec.block, cls)] = best
        report[cls] = {
            "tiles": best,
            "us": timings["x".join(map(str, best))],
            "candidates": timings,
        }
    return report


def canonical_plane_layout(spec: CiMExecSpec) -> Tuple[int, int]:
    """(K multiple, N multiple) of the **canonical stored-plane layout**
    for ``spec``: the granularity ``quant.prepare.prepare_for_spec`` pads
    packed bitplanes to at prepare time, chosen so the *default* tile
    tables of both shape classes divide it — ``execute_packed`` then
    consumes the stored planes with zero per-step padding/relayout
    (autotuned non-default winners may still re-pad per call, which is
    correct, merely slower). jnp packed backends tile nothing; their
    canonical granularity is the block/byte lcm."""
    spec = spec.resolve()
    entry = _REGISTRY.get(spec.registry_key)
    base = math.lcm(spec.block, 8)
    if entry is None or entry.tiles is None:
        return base, 1
    k_mult, n_mult = base, 1
    # query the table at a representative large (K, N): the canonical
    # layout is one granularity for the whole weight tree, so tables
    # that scale tiles with the shape answer for the unclamped regime
    big = 1 << 20
    for m in (1, 128):
        t = entry.tiles(m, big, big)  # (bm, bk, bn[, nbuf])
        k_mult = math.lcm(k_mult, max(int(t[1]), 1))
        n_mult = math.lcm(n_mult, max(int(t[2]), 1))
    return k_mult, n_mult


# ---------------------------------------------------------------------------
# The shared execution shim
# ---------------------------------------------------------------------------


def _pad_axis(x: jax.Array, mult: int, axis: int) -> jax.Array:
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    cfg = [(0, 0)] * x.ndim
    cfg[axis] = (0, pad)
    return jnp.pad(x, cfg)


def _forward(
    spec: CiMExecSpec, x: jax.Array, w: jax.Array, tiles=None
) -> jax.Array:
    entry = get_backend(spec)
    lead, k, n = x.shape[:-1], x.shape[-1], w.shape[-1]
    x2 = x.reshape((-1, k))
    mult = spec.block if spec.packing == "none" else math.lcm(spec.block, 8)
    xp, wp = _pad_axis(x2, mult, 1), _pad_axis(w, mult, 0)
    if entry.tiles is None:
        out = entry.fn(xp, wp, spec)
    else:
        out = entry.fn(xp, wp, spec, tiles or tiles_for(spec, x2.shape[0], k, n))
    return out.reshape(lead + (n,)).astype(x.dtype)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1))
def _ste_execute(
    spec: CiMExecSpec, tiles, x: jax.Array, w: jax.Array
) -> jax.Array:
    return _forward(spec, x, w, tiles)


def _ste_fwd(spec, tiles, x, w):
    return _ste_execute(spec, tiles, x, w), (x, w)


def _ste_bwd(spec, tiles, res, g):
    # Straight-through past the clamp: exact-matmul gradients (for the
    # exact/fused formulations this IS the true gradient). Clamping
    # formulations accumulate the STE backward in f32; exact/fused keep
    # the operand dtype so backward TP partial-sum all-reduces stay at
    # the activation width (bf16 in training — §Perf A4).
    x, w = res
    acc = jnp.float32 if spec.clamps else x.dtype
    gf = g.astype(acc)
    dx = jnp.einsum("...n,kn->...k", gf, w.astype(acc)).astype(x.dtype)
    dw = jnp.einsum("...k,...n->kn", x.astype(acc), gf).astype(w.dtype)
    return dx, dw


_ste_execute.defvjp(_ste_fwd, _ste_bwd)

_jit_execute = jax.jit(_ste_execute, static_argnums=(0, 1))


# ---------------------------------------------------------------------------
# Profiler sink (repro.profile.trace — DESIGN.md §11)
# ---------------------------------------------------------------------------

#: installed by repro.profile.trace.set_profiler; None = profiling off.
#: The disabled cost is one None comparison per entry-point call.
_PROFILE_SINK: Optional[Callable] = None


def set_profile_sink(sink: Optional[Callable]) -> None:
    """Install (or, with None, remove) the kernel-event sink the eager
    ``execute``/``execute_packed`` entry points report wall times to.
    Wired by :func:`repro.profile.trace.set_profiler` — use that, not
    this, unless you are building a custom trace consumer."""
    global _PROFILE_SINK
    _PROFILE_SINK = sink


def _profiled_call(entry, spec, probe, m, k, n, weight_bytes, thunk):
    """Run ``thunk()``; when a profiler sink is installed AND the call
    is eager (``probe`` is not a tracer — timing under a jit trace is
    meaningless and would force a callback into the jaxpr), time it and
    emit one kernel-level trace event."""
    sink = _PROFILE_SINK
    if sink is None or isinstance(probe, jax.core.Tracer):
        return thunk()
    import time

    t0 = time.perf_counter()
    out = thunk()
    t1 = time.perf_counter()
    # analysis: host-sync ok — profiler wall-time capture; opt-in (sink
    # installed) and never under a jit trace (tracer-probed above)
    jax.block_until_ready(out)
    t2 = time.perf_counter()
    sink(
        entry_point=entry,
        exec_spec=spec.name,
        shape_class=_CLASS_OVERRIDE or shape_class(m),
        mesh=None,
        wall_us=(t2 - t0) * 1e6,
        dispatch_us=(t1 - t0) * 1e6,
        meta={"m": int(m), "k": int(k), "n": int(n),
              "macs": int(m) * int(k) * int(n),
              "weight_bytes": int(weight_bytes)},
    )
    return out


def _apply_sense_channel(spec, out, k_dim, key):
    """Shared post-MAC sensing-error application (validation + noise)."""
    if spec.error_prob <= 0.0:
        return out
    if not spec.clamps:
        raise ValueError(
            f"the sensing-error channel models the ADC readout; the "
            f"{spec.formulation!r} formulation has no ADC (use a "
            f"clamping formulation or error_prob=0)"
        )
    if key is None:
        raise ValueError("spec.error_prob > 0 requires a PRNG key")
    kb = -(-k_dim // spec.block)
    noise = _sense_noise(key, out.shape, kb, spec.error_prob, out.dtype)
    return out + jax.lax.stop_gradient(noise)


def _sense_noise(key, shape, kb: int, prob: float, dtype) -> jax.Array:
    """Additive equivalent of the per-block ±1 ADC-level error channel:
    each of the ``kb`` block partials behind an output flips one level
    with probability ``prob``; the PCU sums them."""
    ku, ks = jax.random.split(key)
    flip = jax.random.bernoulli(ku, prob, shape + (kb,))
    base = dtype if jnp.issubdtype(dtype, jnp.floating) else jnp.int32
    sign = jax.random.rademacher(ks, shape + (kb,), dtype=base)
    return jnp.sum(flip.astype(base) * sign, axis=-1).astype(dtype)


def execute(
    spec: CiMExecSpec,
    x_t: jax.Array,
    w_t: jax.Array,
    *,
    key: Optional[jax.Array] = None,
) -> jax.Array:
    """Run one ternary MAC under ``spec``.

    x_t: (..., K) ternary values in {-1, 0, +1} (any numeric dtype).
    w_t: (K, N) ternary values.
    key: PRNG key for the sensing-error channel (required iff
      ``spec.error_prob > 0``).

    Returns (..., N) in the dtype of ``x_t``, with gradients defined
    straight-through (exact-matmul backward).

    NOTE: with ``packing="bitplane_u8"`` this functional entry point
    packs ``w_t`` on the fly inside the forward — correct, and what the
    equivalence tests pin, but it realizes none of the packed format's
    weight-traffic savings. Serving should pack offline
    (``quant.prepare.prepare_for_spec``) and call
    :func:`execute_packed` with the stored planes.
    """
    spec = spec.resolve()
    clean = dataclasses.replace(spec, error_prob=0.0)
    m = math.prod(x_t.shape[:-1])
    k_dim, n_dim = x_t.shape[-1], w_t.shape[-1]
    tiles = tiles_for(clean, m, k_dim, n_dim)
    out = _profiled_call(
        "execution.execute", clean, x_t, m, k_dim, n_dim,
        k_dim * n_dim * jnp.dtype(w_t.dtype).itemsize,
        lambda: _jit_execute(clean, tiles, x_t, w_t),
    )
    return _apply_sense_channel(spec, out, x_t.shape[-1], key)


@functools.partial(jax.jit, static_argnums=(0, 1, 5))
def _packed_forward(spec, tiles, x, w_pos, w_neg, n_out):
    lead, k = x.shape[:-1], x.shape[-1]
    x2 = x.reshape((-1, k))
    # lift x to the stored planes' K depth (canonical planes carry K
    # already padded — zero activation rows are inert); legacy same-K
    # planes pad both sides to the block/byte granularity as before
    mult = math.lcm(spec.block, 8)
    k_target = max(w_pos.shape[-2] * 8, -(-k // mult) * mult)
    out = _packed_stored(
        _pad_axis(x2, k_target, 1),
        _pad_axis(w_pos, k_target // 8, 0),
        _pad_axis(w_neg, k_target // 8, 0),
        spec,
        tiles,
    )
    return out[:, :n_out].reshape(lead + (n_out,)).astype(x.dtype)


@functools.partial(jax.jit, static_argnums=(0, 1, 4))
def _packed_stream_forward(spec, tiles, x, w_int, n_out):
    """Stream-backend twin of :func:`_packed_forward`: the weight side is
    ONE (K/4, N) plane-interleaved array (layout version 1 — see
    ``repro.core.ternary.interleave_planes``), DMA'd tile-by-tile by the
    streaming decode kernel. Canonical version-1 planes enter with zero
    per-step padding/relayout, exactly like the legacy path."""
    lead, k = x.shape[:-1], x.shape[-1]
    x2 = x.reshape((-1, k))
    mult = math.lcm(spec.block, 8)
    k_target = max(w_int.shape[-2] * 4, -(-k // mult) * mult)
    out = _packed_stream_mac(
        _pad_axis(x2, k_target, 1),
        _pad_axis(w_int, k_target // 4, 0),
        spec,
        tiles,
        spec.clamps,
    )
    return out[:, :n_out].reshape(lead + (n_out,)).astype(x.dtype)


def execute_packed(
    spec: CiMExecSpec,
    x_t: jax.Array,
    w_pos,
    w_neg: Optional[jax.Array] = None,
    *,
    key: Optional[jax.Array] = None,
) -> jax.Array:
    """Packed-weight fast path: run a ternary MAC from **pre-packed**
    (M1, M2) bitplanes — the 2-bit storage format ``quant.prepare``
    emits — without ever materializing the dense weight or re-packing
    per call (this is where the 8x-vs-int8 weight-traffic saving of
    ``bitplane_u8`` is actually realized; :func:`execute` with
    ``packing="bitplane_u8"`` packs on the fly and is for functional
    work only).

    x_t: (..., K) ternary values. The weight side is either

      * ``w_pos``/``w_neg``: (K/8, N) uint8 planes
        (``repro.core.ternary.pack_ternary`` layout along K), or
      * one :class:`repro.core.ternary.PackedPlanes` — the canonical
        pre-padded layout ``quant.prepare.prepare_for_spec`` stores
        (pass it as ``w_pos``, leave ``w_neg`` unset). Its planes enter
        the kernel with **zero** per-step padding/relayout and the
        result slices back to the recorded logical N; decode-class M
        (<= DECODE_M_MAX) pads M only to the small decode tile, never
        to 128 (both pinned by jaxpr tests).

    The spec's formulation selects clamped ("blocked") or exact MAC
    semantics. Inference path — no custom VJP over the packed planes.

    ``x_t`` must hold exact ternary values: the decode-class pallas path
    computes in int8/int32 (DESIGN.md §9), so fractional activations —
    already outside this function's contract — would *truncate* there
    while the bf16 prefill path would not.
    """
    from repro.core.ternary import PackedPlanes

    spec = spec.resolve()
    if spec.packing != "bitplane_u8":
        raise ValueError("execute_packed requires packing='bitplane_u8'")
    if spec.formulation not in ("exact", "blocked"):
        raise ValueError(
            f"packed kernels implement exact|blocked, not {spec.formulation!r}"
        )
    stream = spec.backend == "pallas_stream"
    if isinstance(w_pos, PackedPlanes):
        planes = w_pos
        if w_neg is not None:
            raise ValueError("pass PackedPlanes alone (it carries both planes)")
        if planes.pos.ndim != 2:
            raise ValueError(
                f"stacked planes {planes.pos.shape}: slice one layer first "
                f"(PackedPlanes.layer(i))"
            )
        if x_t.shape[-1] != planes.k:
            raise ValueError(
                f"plane/input shape mismatch: x K={x_t.shape[-1]}, "
                f"logical plane K={planes.k}"
            )
        n_out = planes.n
        if stream:
            # free on canonical version-1 planes; an (eager) interleave
            # on legacy-layout planes — old stored planes still load
            w_int = planes.interleaved()
        else:
            # free on legacy planes; de-interleaves version-1 storage
            w_pos, w_neg = planes.planes()
    else:
        if w_neg is None:
            raise ValueError("raw planes need both w_pos and w_neg")
        if x_t.shape[-1] != w_pos.shape[0] * 8 or w_pos.shape != w_neg.shape:
            raise ValueError(
                f"plane/input shape mismatch: x K={x_t.shape[-1]}, "
                f"planes {w_pos.shape} / {w_neg.shape}"
            )
        n_out = w_pos.shape[-1]
        if stream:
            w_int = tern.interleave_planes(w_pos, w_neg)
    clean = dataclasses.replace(spec, error_prob=0.0)
    m = math.prod(x_t.shape[:-1])
    if stream:
        k_dim, n_cols = w_int.shape[-2] * 4, w_int.shape[-1]
        tiles = tiles_for(clean, m, k_dim, n_cols)
        out = _profiled_call(
            "execution.execute_packed", clean, x_t, m, k_dim, n_out,
            int(w_int.size),
            lambda: _packed_stream_forward(clean, tiles, x_t, w_int, n_out),
        )
    else:
        k_dim = w_pos.shape[0] * 8
        tiles = tiles_for(clean, m, k_dim, w_pos.shape[-1])
        out = _profiled_call(
            "execution.execute_packed", clean, x_t, m, k_dim, n_out,
            int(w_pos.size) + int(w_neg.size),
            lambda: _packed_forward(clean, tiles, x_t, w_pos, w_neg, n_out),
        )
    return _apply_sense_channel(spec, out, x_t.shape[-1], key)


# ---------------------------------------------------------------------------
# Tensor-parallel execution (explicit shard_map path)
# ---------------------------------------------------------------------------

def execute_tp(
    spec: CiMExecSpec,
    x_t: jax.Array,
    w_t: jax.Array,
    mesh,
    *,
    axis_name: str = "model",
    compressed: bool = False,
    key: Optional[jax.Array] = None,
) -> jax.Array:
    """Row-parallel ternary MAC over a mesh axis (explicit manual SPMD).

    The contraction dim K is split over ``axis_name``: each device runs
    the registered kernel on its K-shard and the partial sums all-reduce
    through :func:`repro.dist.collectives.tp_allreduce`. K is padded so
    every shard holds *whole* ``spec.block`` blocks — the per-block ADC
    clamp then never straddles a device boundary, the per-shard partials
    are integer event counts, and the f32 psum is exact: TP execution is
    **bit-identical** to :func:`execute` for every built-in formulation
    (pinned in tests/test_tp_serve.py).

    ``compressed=True`` narrows the all-reduce wire to int8 (stochastic
    rounding; ``key`` seeds the per-shard rounding streams). Without a
    ``key`` the stream is **deterministic and idempotent** — a pure
    function of the operand shape — so identical calls return identical
    results and serving stays reproducible across retraces. The flip
    side: same-shaped call sites, scan-stacked layers, and repeated
    decode steps all reuse the same noise, making the rounding error a
    fixed perturbation rather than zero-mean noise that averages out.
    The *unbiasedness* property (tests/test_collectives.py) applies
    across fresh keys — thread ``key`` per call to get it. This is the
    opt-in trade: 4x less collective traffic for quantization-level
    error — the exact path is the default.

    This is the *explicit* TP entry point (shard_map — the collective is
    named in the program). Serving under plain sharded params/caches uses
    the implicit GSPMD path instead and never needs this function; the
    engine routes through it only for ``compress_tp=True`` (the
    partitioner cannot be told to compress its own all-reduces).
    Inference-only: no custom VJP is defined over the shard_map.
    """
    from repro.dist.collectives import shard_map, tp_allreduce

    spec = spec.resolve()
    if spec.packing != "none":
        raise ValueError(
            "execute_tp splits the contraction dim; packed (K-major 2-bit) "
            "planes shard over N instead — use execute_packed with "
            "N-sharded planes (dist.sharding.packed_specs) or the "
            "explicit column-parallel execute_packed_tp"
        )
    if spec.error_prob > 0.0:
        raise ValueError(
            "execute_tp is the serving TP path; drive the sensing-error "
            "channel through execute/execute_packed (error_prob=0 here)"
        )
    entry = get_backend(spec)
    tp = int(mesh.shape[axis_name])
    lead, k, n = x_t.shape[:-1], x_t.shape[-1], w_t.shape[-1]
    x2 = x_t.reshape((-1, k))
    # whole blocks per shard: pad K to (block granularity) * tp
    mult = spec.block * tp
    x2 = _pad_axis(x2, mult, 1)
    wp = _pad_axis(w_t, mult, 0)
    if key is None:
        # idempotent default stream — a pure function of the operand
        # shape (trace-time constants), so identical calls round
        # identically; see the docstring for what stays correlated
        salt = (k * 1000003 + n * 8191) % (1 << 30)
        key = jax.random.fold_in(jax.random.PRNGKey(0), salt)
    keys = jax.random.split(key, tp)
    # per-shard tiles for tiled (pallas) entries, resolved on the shard's
    # local K extent (the shape the kernel actually sees)
    tiles = tiles_for(spec, x2.shape[0], x2.shape[1] // tp, n)

    def local(xs, ws, ks):
        if entry.tiles is None:
            part = entry.fn(xs, ws, spec)
        else:
            part = entry.fn(xs, ws, spec, tiles)
        return tp_allreduce(part, axis_name, key=ks[0], compressed=compressed)

    from jax.sharding import PartitionSpec as _P

    f = shard_map(
        local, mesh=mesh,
        in_specs=(_P(None, axis_name), _P(axis_name, None), _P(axis_name)),
        out_specs=_P(),
    )
    return f(x2, wp, keys).reshape(lead + (n,)).astype(x_t.dtype)


def execute_packed_tp(
    spec: CiMExecSpec,
    x_t: jax.Array,
    planes,
    mesh,
    *,
    axis_name: str = "model",
) -> jax.Array:
    """Column-parallel packed MAC over N-sharded stored planes (explicit
    shard_map) — the TP twin of :func:`execute_packed`.

    The packed (K-major 2-bit) planes shard over their *output* dim N
    (``dist.sharding.packed_specs`` layout): each device runs the packed
    kernel on its local (rows, N/tp) plane shard and the shards
    concatenate. Column sharding never splits the contraction, so no
    collective runs and TP is trivially **bit-identical** to the
    single-device :func:`execute_packed` (pinned in
    tests/test_stream_decode.py).

    Decode-class shapes under a ``pallas_stream`` spec route through the
    double-buffered streaming kernel per shard — each device overlaps
    its own plane DMA with its MAC, which is exactly the regime the
    N-sharded serving weights are in. ``planes`` must be a 2-D
    :class:`repro.core.ternary.PackedPlanes`; its *padded* N must divide
    the mesh axis.
    """
    from repro.dist.collectives import shard_map
    from jax.sharding import PartitionSpec as _P

    spec = spec.resolve()
    if spec.packing != "bitplane_u8":
        raise ValueError("execute_packed_tp requires packing='bitplane_u8'")
    if spec.error_prob > 0.0:
        raise ValueError(
            "execute_packed_tp is the serving TP path; drive the sensing-"
            "error channel through execute_packed (error_prob=0 here)"
        )
    if not isinstance(planes, tern.PackedPlanes):
        raise ValueError("execute_packed_tp consumes stored PackedPlanes")
    if planes.pos.ndim != 2:
        raise ValueError(
            f"stacked planes {planes.pos.shape}: slice one layer first "
            f"(PackedPlanes.layer(i))"
        )
    if x_t.shape[-1] != planes.k:
        raise ValueError(
            f"plane/input shape mismatch: x K={x_t.shape[-1]}, "
            f"logical plane K={planes.k}"
        )
    tp = int(mesh.shape[axis_name])
    n_pad = int(planes.pos.shape[-1])
    if n_pad % tp != 0:
        raise ValueError(
            f"padded plane N={n_pad} does not divide the {axis_name!r} "
            f"axis ({tp} devices) — re-prepare with the mesh "
            f"(quant.prepare.prepare_for_spec(mesh=...))"
        )
    stream = spec.backend == "pallas_stream"
    lead, k = x_t.shape[:-1], x_t.shape[-1]
    x2 = x_t.reshape((-1, k))
    m = x2.shape[0]
    if stream:
        w_int = planes.interleaved()
        k_dim = w_int.shape[-2] * 4
        tiles = tiles_for(spec, m, k_dim, n_pad // tp)

        def local(xs, wl):
            return _packed_stream_forward(spec, tiles, xs, wl, wl.shape[-1])

        f = shard_map(
            local, mesh=mesh,
            in_specs=(_P(), _P(None, axis_name)),
            out_specs=_P(None, axis_name),
            check_rep=False,  # pallas_call has no replication rule
        )
        out = f(x2, w_int)
    else:
        w_pos, w_neg = planes.planes()
        k_dim = w_pos.shape[-2] * 8
        tiles = tiles_for(spec, m, k_dim, n_pad // tp)

        def local(xs, wp, wn):
            return _packed_forward(spec, tiles, xs, wp, wn, wp.shape[-1])

        f = shard_map(
            local, mesh=mesh,
            in_specs=(_P(), _P(None, axis_name), _P(None, axis_name)),
            out_specs=_P(None, axis_name),
            check_rep=False,  # pallas_call has no replication rule
        )
        out = f(x2, w_pos, w_neg)
    return out[:, :planes.n].reshape(lead + (planes.n,)).astype(x_t.dtype)


# ---------------------------------------------------------------------------
# Built-in backends
# ---------------------------------------------------------------------------


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


# ---- jnp ------------------------------------------------------------------


def _exact_jnp(x2, w, spec):
    # operand-dtype dot: keeps TP partial-sum all-reduces at the
    # activation width (bf16 in training — §Perf A4)
    return jnp.einsum("mk,kn->mn", x2, w.astype(x2.dtype))


def _blocked_jnp(x2, w, spec):
    return ref.ref_cim_matmul(
        x2.astype(jnp.float32), w.astype(jnp.float32),
        block=spec.block, adc_max=spec.adc_max,
    )


def _corrected_jnp(x2, w, spec):
    """exact + sum_blk(relu(b-adc) - relu(a-adc)): the bulk contraction
    is one full-depth MXU matmul; only the rare saturation correction
    needs blocked arithmetic (DESIGN.md §2)."""
    xf = x2.astype(jnp.float32)
    wf = w.astype(jnp.float32)
    exact = xf @ wf
    kb = xf.shape[1] // spec.block
    xb = xf.reshape(xf.shape[0], kb, spec.block)
    wb = wf.reshape(kb, spec.block, wf.shape[1])
    p = jnp.einsum("mki,kin->mkn", xb, wb)
    m = jnp.einsum("mki,kin->mkn", jnp.abs(xb), jnp.abs(wb))
    a = (m + p) * 0.5
    b = (m - p) * 0.5
    adc = float(spec.adc_max)
    corr = jnp.maximum(b - adc, 0.0) - jnp.maximum(a - adc, 0.0)
    return exact + jnp.sum(corr, axis=1)


def _bitplane_jnp(x2, w, spec):
    """Event counting over (M1, M2) bitplanes — mirrors the circuit:
    a = #(RWL1&M1) + #(RWL2&M2), b = #(RWL1&M2) + #(RWL2&M1)."""
    m1 = (w > 0).astype(jnp.int32)
    m2 = (w < 0).astype(jnp.int32)
    r1 = (x2 > 0).astype(jnp.int32)
    r2 = (x2 < 0).astype(jnp.int32)
    kb = x2.shape[1] // spec.block
    r1b = r1.reshape(r1.shape[0], kb, spec.block)
    r2b = r2.reshape(r2.shape[0], kb, spec.block)
    m1b = m1.reshape(kb, spec.block, m1.shape[1])
    m2b = m2.reshape(kb, spec.block, m2.shape[1])
    a = jnp.einsum("mki,kin->mkn", r1b, m1b) + jnp.einsum("mki,kin->mkn", r2b, m2b)
    b = jnp.einsum("mki,kin->mkn", r1b, m2b) + jnp.einsum("mki,kin->mkn", r2b, m1b)
    part = jnp.minimum(a, spec.adc_max) - jnp.minimum(b, spec.adc_max)
    return jnp.sum(part, axis=1)


def _fused_jnp(x2, w, spec):
    """Pallas-kernel cost structure: signed + magnitude full-depth dots,
    elementwise combine (== exact; per-block clamping happens inside the
    kernel's VMEM tiles on TPU). The large `minimum` bound keeps XLA from
    folding the magnitude dot away."""
    wd = w.astype(x2.dtype)
    p = jnp.einsum("mk,kn->mn", x2, wd)
    m = jnp.einsum("mk,kn->mn", jnp.abs(x2), jnp.abs(wd))
    big = jnp.asarray(2.0**14, jnp.float32)
    pf, mf = p.astype(jnp.float32), m.astype(jnp.float32)
    return jnp.minimum((mf + pf) * 0.5, big) - jnp.minimum((mf - pf) * 0.5, big)


# ---- pallas ---------------------------------------------------------------

# built-in tile tables: decode class (M <= DECODE_M_MAX) takes the small
# 8-row M tile — the kernels then pad M to 8, not 128 — prefill keeps the
# pre-§9 MXU-filling tiles


def _blocked_tiles(m, k, n):
    return (8, 128, 128) if m <= DECODE_M_MAX else (128, 128, 128)


def _exact_tiles(m, k, n):
    return (8, 512, 128) if m <= DECODE_M_MAX else (128, 512, 128)


def _packed_tiles(m, k, n):
    return (8, 256, 128) if m <= DECODE_M_MAX else (128, 256, 128)


def _packed_stream_tiles(m, k, n):
    # 4th element = DMA buffer depth (nbuf); prefill rows delegate to
    # the non-stream prefill kernel, which ignores it
    return (8, 256, 128, 2) if m <= DECODE_M_MAX else (128, 256, 128, 2)


def _blocked_pallas(x2, w, spec, tiles):
    m, n = x2.shape[0], w.shape[1]
    bm, bk, bn = tiles
    xp = _pad_axis(_pad_axis(x2, bm, 0), bk, 1)
    wp = _pad_axis(_pad_axis(w, bk, 0), bn, 1)
    out = ternary_cim_matmul(
        xp.astype(jnp.bfloat16), wp.astype(jnp.bfloat16),
        block=spec.block, adc_max=spec.adc_max,
        bm=bm, bk=bk, bn=bn,
        interpret=not _on_tpu(),
    )
    return out[:m, :n]


def _exact_pallas(x2, w, spec, tiles):
    m, n = x2.shape[0], w.shape[1]
    bm, bk, bn = tiles
    xp = _pad_axis(_pad_axis(x2, bm, 0), bk, 1)
    wp = _pad_axis(_pad_axis(w, bk, 0), bn, 1)
    out = ternary_exact_matmul(
        xp.astype(jnp.bfloat16), wp.astype(jnp.bfloat16),
        bm=bm, bk=bk, bn=bn,
        interpret=not _on_tpu(),
    )
    return out[:m, :n]


def _pad_planes(w_pos, w_neg, rows: int, cols: int):
    """Pad stored (K/8, N) planes to a kernel tile granularity — a no-op
    (nothing enters the jaxpr) when the planes are already canonical
    (quant.prepare.prepare_for_spec emits them pre-padded)."""
    return (
        _pad_axis(_pad_axis(w_pos, rows, 0), cols, 1),
        _pad_axis(_pad_axis(w_neg, rows, 0), cols, 1),
    )


def _packed_planes_mac(x2, w_pos, w_neg, spec, tiles, cim: bool, pallas: bool):
    """The shared packed-plane MAC behind both the functional `_packed`
    path and the stored-plane `_packed_stored` fast path: pad planes to
    the tile granularity (shared helper; no-op on canonical layouts) and
    dispatch the decode- or prefill-shaped kernel by the M tile."""
    m, n = x2.shape[0], w_pos.shape[1]
    if not pallas:
        return ref.ref_packed_matmul(
            x2.astype(jnp.float32), w_pos, w_neg,
            block=spec.block, adc_max=spec.adc_max, cim=cim,
        )
    bm, bk, bn = tiles or _packed_tiles(m, x2.shape[1], n)
    xp = _pad_axis(x2, bk, 1)
    pp, pn = _pad_planes(w_pos, w_neg, bk // 8, bn)
    if bm <= DECODE_M_MAX:
        # decode class: whole-M grid steps, int8 operands, int32 a/b
        # accumulation — M pads to the 8-row decode tile, never to 128
        out = packed_cim_matmul_decode(
            _pad_axis(xp, bm, 0).astype(jnp.int8), pp, pn,
            block=spec.block, adc_max=spec.adc_max, cim=cim,
            bk=bk, bn=bn, interpret=not _on_tpu(),
        ).astype(jnp.float32)
    else:
        out = packed_cim_matmul(
            _pad_axis(xp, bm, 0).astype(jnp.bfloat16), pp, pn,
            block=spec.block, adc_max=spec.adc_max, cim=cim,
            bm=bm, bk=bk, bn=bn, interpret=not _on_tpu(),
        )
    return out[:m, :n]


def _packed_stream_mac(x2, w_int, spec, tiles, cim: bool):
    """Streaming MAC from ONE plane-interleaved (K/4, N) uint8 array
    (layout version 1). Decode-class M takes the double-buffered
    streaming kernel — the (k, j) tile DMA rides ``nbuf`` VMEM slots
    ahead of the int32 MAC; prefill-class M de-interleaves (a reshape,
    never a pad) and delegates to the prefill kernel, which already
    pipelines its grid."""
    m, n = x2.shape[0], w_int.shape[1]
    tl = tiles or _packed_stream_tiles(m, x2.shape[1], n)
    bm, bk, bn = tl[0], tl[1], tl[2]
    nbuf = tl[3] if len(tl) > 3 else 2
    if bm <= DECODE_M_MAX:
        xp = _pad_axis(x2, bk, 1)
        wi = _pad_axis(_pad_axis(w_int, bk // 4, 0), bn, 1)
        out = packed_cim_matmul_decode_stream(
            _pad_axis(xp, bm, 0).astype(jnp.int8), wi,
            block=spec.block, adc_max=spec.adc_max, cim=cim,
            bk=bk, bn=bn, nbuf=nbuf, interpret=not _on_tpu(),
        ).astype(jnp.float32)
        return out[:m, :n]
    w_pos, w_neg = tern.deinterleave_planes(w_int)
    return _packed_planes_mac(
        x2, w_pos, w_neg, spec, (bm, bk, bn), cim, pallas=True
    )


def _packed(x2, w, spec, tiles=None, *, cim: bool, pallas: bool):
    """Functional packed path (dense ternary w in hand): pack **once**
    at the logical K extent, then pad the 2-bit planes — not the dense
    weight — to the tile granularity (the pre-§9 version padded w to the
    full K tile first and packed the padded array every call)."""
    w_pos, w_neg = tern.pack_ternary(w.astype(jnp.int8), axis=0)
    return _packed_planes_mac(x2, w_pos, w_neg, spec, tiles, cim, pallas)


def _packed_stream(x2, w, spec, tiles=None, *, cim: bool):
    """Functional stream path: pack once, interleave the planes (layout
    version 1), stream."""
    w_pos, w_neg = tern.pack_ternary(w.astype(jnp.int8), axis=0)
    return _packed_stream_mac(
        x2, tern.interleave_planes(w_pos, w_neg), spec, tiles, cim
    )


def _packed_stored(x2, w_pos, w_neg, spec, tiles=None):
    """Packed MAC from stored planes (no per-call pack) — the
    execute_packed fast path."""
    if spec.backend == "pallas_stream":
        return _packed_stream_mac(
            x2, tern.interleave_planes(w_pos, w_neg), spec, tiles,
            spec.clamps,
        )
    return _packed_planes_mac(
        x2, w_pos, w_neg, spec, tiles, spec.clamps,
        pallas=spec.backend == "pallas",
    )


register_backend("exact/jnp/none", _exact_jnp, clamps=False)
register_backend("exact/pallas/none", _exact_pallas, clamps=False,
                 tiles=_exact_tiles)
register_backend(
    "exact/jnp/bitplane_u8",
    functools.partial(_packed, cim=False, pallas=False), clamps=False,
)
register_backend(
    "exact/pallas/bitplane_u8",
    functools.partial(_packed, cim=False, pallas=True), clamps=False,
    tiles=_packed_tiles,
)
register_backend("blocked/jnp/none", _blocked_jnp, clamps=True)
register_backend("blocked/pallas/none", _blocked_pallas, clamps=True,
                 tiles=_blocked_tiles)
register_backend(
    "blocked/jnp/bitplane_u8",
    functools.partial(_packed, cim=True, pallas=False), clamps=True,
)
register_backend(
    "blocked/pallas/bitplane_u8",
    functools.partial(_packed, cim=True, pallas=True), clamps=True,
    tiles=_packed_tiles,
)
register_backend(
    "exact/pallas_stream/bitplane_u8",
    functools.partial(_packed_stream, cim=False), clamps=False,
    tiles=_packed_stream_tiles, tile_candidates=_STREAM_TILE_CANDIDATES,
)
register_backend(
    "blocked/pallas_stream/bitplane_u8",
    functools.partial(_packed_stream, cim=True), clamps=True,
    tiles=_packed_stream_tiles, tile_candidates=_STREAM_TILE_CANDIDATES,
)
register_backend("corrected/jnp/none", _corrected_jnp, clamps=True)
register_backend("bitplane/jnp/none", _bitplane_jnp, clamps=True)
register_backend("fused/jnp/none", _fused_jnp, clamps=False)


# ---------------------------------------------------------------------------
# Spec -> hardware-model mapping (paper Section V / repro.hw)
# ---------------------------------------------------------------------------


def spec_design(spec: CiMExecSpec) -> str:
    """Map an execution spec onto the registered array designs. "exact"
    is the near-memory baseline; every CiM formulation — including
    "fused", the Pallas kernel's cost stand-in — executes on a SiTe
    array, flavor choosing the design through the ``repro.hw`` design
    registry. Unknown (plugged-in) formulations fall back on whether
    they clamp."""
    if spec.formulation == "exact":
        return "NM"
    if spec.formulation in FORMULATIONS or spec.clamps:
        from repro.hw import design_for_flavor

        return design_for_flavor(spec.flavor)
    return "NM"


def _bind_array(spec: CiMExecSpec, tech, array):
    """Bind an execution spec to a concrete ArraySpec: the ArraySpec
    supplies technology and geometry, the *execution* spec decides the
    design (an "exact" spec costs as the NM baseline of that array no
    matter how the ArraySpec was labelled). Without an array, a
    default-geometry array on ``tech`` (default 8T-SRAM). ``tech`` and
    ``array`` are mutually exclusive — the ArraySpec already names its
    technology, so accepting both would silently ignore one."""
    from repro import hw

    design = spec_design(spec)
    if array is None:
        return hw.ArraySpec(technology=tech or "8T-SRAM", design=design)
    if tech is not None:
        raise ValueError(
            f"pass either tech= or array=, not both (array already "
            f"names technology {array.technology!r}, got tech={tech!r})"
        )
    return array.with_design(design)


def spec_array_cost(spec: CiMExecSpec, tech=None, array=None):
    """Absolute array-level cost (latency/energy/area) of executing this
    spec — the dry-run/roofline's bridge from the execution API to the
    hardware model (``repro.hw``). See :func:`_bind_array` for how the
    optional ``tech`` (technology name, default 8T-SRAM) / ``array``
    (an :class:`repro.hw.ArraySpec`) binding works."""
    from repro import hw

    return hw.array_cost(_bind_array(spec, tech, array))


def spec_cost_summary(
    spec: CiMExecSpec, tech=None, array=None
) -> Dict[str, float]:
    """JSON-ready per-MAC-pass cost summary of ``spec`` on the bound
    array (same binding rules as :func:`spec_array_cost`): technology /
    design names plus the pass latency, energy, and relative area."""
    from repro import hw

    bound = _bind_array(spec, tech, array)
    cost = hw.array_cost(bound)
    return {
        "tech": cost.tech,
        "design": cost.design,
        "array": bound.name,
        "mac_pass_ns": cost.mac_pass_ns,
        "mac_pass_pj": cost.mac_pass_pj,
        "macro_area_vs_nm": cost.macro_area,
    }


# ---------------------------------------------------------------------------
# Tracing contracts (repro.analysis — DESIGN.md §10)
#
# The execution-shim invariants, declared where the shim lives. These
# drive the jaxpr auditor, the migrated jaxpr-pin tests, and the
# `python -m repro.analysis` CI ratchet from one table.
# ---------------------------------------------------------------------------

from repro.analysis.contracts import (  # noqa: E402
    PrimRule,
    SkipTrace,
    TraceContract,
    forbid_convert,
    register_trace_contract,
)


def _audit_planes(spec: CiMExecSpec, k: int = 512, n: int = 256):
    """Deterministic canonical PackedPlanes for audit traces — the
    prepare-time layout without initializing a model. K/N are chosen so
    no plane dim collides with the 128-row M tile (the decode-M rule
    below keys on a literal 128 leading dim)."""
    kw = jax.random.PRNGKey(7)
    w = jax.random.choice(kw, jnp.asarray([-1, 0, 1], jnp.int8), (k, n))
    p1, p2 = tern.pack_ternary(w, axis=0)
    k_mult, n_mult = canonical_plane_layout(spec)
    p1 = _pad_axis(_pad_axis(p1, k_mult // 8, 0), n_mult, 1)
    p2 = _pad_axis(_pad_axis(p2, k_mult // 8, 0), n_mult, 1)
    if spec.resolve().backend == "pallas_stream":
        # the canonical layout prepare_for_spec emits for stream specs:
        # plane-interleaved version 1 (DESIGN.md §14)
        wi = tern.interleave_planes(p1, p2)
        return tern.PackedPlanes(
            pos=wi, neg=wi[:0], scale=jnp.ones((n,), jnp.float32), k=k, n=n,
            layout_version=tern.PLANE_LAYOUT_STREAM,
        )
    return tern.PackedPlanes(
        pos=p1, neg=p2, scale=jnp.ones((n,), jnp.float32), k=k, n=n
    )


def no_decode_m128_rule() -> PrimRule:
    """No Pallas kernel on a decode-class trace may consume a 2-D
    operand padded to the 128-row MXU tile — the decode fast path pads
    M only to the 8-row decode tile (DESIGN.md §9)."""

    def _m128(eqn) -> bool:
        # uint8 operands are the stored 2-bit planes — their leading dim
        # is K/8 (or K/4 interleaved), not M, and may legitimately be 128
        return any(
            getattr(v.aval, "ndim", 0) == 2 and v.aval.shape[0] == 128
            and str(getattr(v.aval, "dtype", "")) != "uint8"
            for v in eqn.invars
        )

    return PrimRule(
        rule="decode-m-pad-128", prim="pallas_call", when=_m128,
        reason="decode shapes pad M to the 8-row decode tile, never 128",
    )


def _packed_decode_point(backend: str):
    """execute_packed over canonical stored planes at a decode shape
    (M=3) — the serving weight path."""

    def build():
        spec = CiMExecSpec(formulation="blocked", backend=backend,
                           packing="bitplane_u8")
        planes = _audit_planes(spec)
        kx = jax.random.PRNGKey(3)
        x = jax.random.choice(
            kx, jnp.asarray([-1, 0, 1], jnp.float32), (3, planes.k))

        def f(xv, pos, neg):
            lay = tern.PackedPlanes(pos=pos, neg=neg, scale=planes.scale,
                                    k=planes.k, n=planes.n,
                                    layout_version=planes.layout_version)
            return execute_packed(spec, xv, lay)

        return f, (x, planes.pos, planes.neg)

    return build


_PACKED_DECODE_RULES = dict(
    max_host_callbacks=0,
    no_pad_on_dtypes=("uint8",),
)

register_trace_contract(
    "execution.execute_packed.decode.jnp",
    _packed_decode_point("jnp"),
    TraceContract(**_PACKED_DECODE_RULES),
)

register_trace_contract(
    "execution.execute_packed.decode.pallas",
    _packed_decode_point("pallas"),
    TraceContract(
        **_PACKED_DECODE_RULES,
        accum_dtype="int32",
        forbid_prims=(
            no_decode_m128_rule(),
            forbid_convert(
                from_kinds=("int",), to=("float32", "float64", "bfloat16"),
                within="pallas_call",
                reason="decode-class event counts stay integer end-to-end",
            ),
        ),
    ),
)

# The streaming decode path inherits every pallas decode rule (int32
# accumulation, no uint8 pad — canonical version-1 planes enter the
# kernel untouched — no int→float convert, M never padded to 128) and
# adds the DMA-eqn pin: exactly nbuf (= 2 at the default tiles) async
# copy *starts* — the unrolled warm-up plus the in-loop prefetch — and
# ONE wait per trace. The pin is what makes the overlap auditable: a
# kernel that silently stops prefetching, or blocks on every tile,
# changes these counts before any benchmark notices (DESIGN.md §14).
register_trace_contract(
    "execution.execute_packed.decode.stream",
    _packed_decode_point("pallas_stream"),
    TraceContract(
        **_PACKED_DECODE_RULES,
        accum_dtype="int32",
        pin_prims=(("dma_start", 2), ("dma_wait", 1)),
        forbid_prims=(
            no_decode_m128_rule(),
            forbid_convert(
                from_kinds=("int",), to=("float32", "float64", "bfloat16"),
                within="pallas_call",
                reason="the streaming decode path keeps the int8/int32 "
                       "event-count datapath",
            ),
        ),
    ),
)


def _ste_backward_point(formulation: str = "exact"):
    """grad of ``formulation`` on bf16 operands — §Perf A4: the exact
    STE backward dots keep the operand dtype so TP all-reduce payloads
    stay at activation width (no f32[4,32] dx anywhere in the trace).
    The blocked formulation accumulates its STE backward in f32 by
    design — the tests use it as the rule's positive control."""

    def build():
        spec = CiMExecSpec(formulation=formulation, backend="jnp")
        x = jnp.ones((4, 32), jnp.bfloat16)
        w = jnp.ones((32, 3), jnp.bfloat16)
        f = jax.grad(
            lambda a, b: execute(spec, a, b).astype(jnp.float32).sum(),
            argnums=(0, 1),
        )
        return f, (x, w)

    return build


register_trace_contract(
    "execution.ste_backward.exact",
    _ste_backward_point(),
    TraceContract(forbid_dtype_shapes=(("float32", (4, 32)),)),
)


def _execute_tp_point():
    """The explicit shard_map TP route with the compressed int8
    collective: one primitive per all-reduce regardless of mesh size —
    the traced program must not grow with tp."""

    def build(tp: int = 2):
        if jax.device_count() < tp:
            raise SkipTrace(
                f"needs {tp} devices, have {jax.device_count()} "
                f"(XLA_FLAGS=--xla_force_host_platform_device_count=8)"
            )
        from repro.launch.mesh import make_tp_mesh

        mesh = make_tp_mesh(tp)
        spec = CiMExecSpec(formulation="blocked", backend="jnp")
        x = jnp.ones((4, 64), jnp.float32)
        w = jnp.ones((64, 32), jnp.float32)

        def f(a, b):
            return execute_tp(spec, a, b, mesh, compressed=True)

        return f, (x, w)

    return build


register_trace_contract(
    "execution.execute_tp.compressed",
    _execute_tp_point(),
    TraceContract(max_host_callbacks=0),
    axes={"tp": (2, 4)},
)
