"""Core contribution of the paper: signed-ternary CiM in JAX.

Public surface:
  * declarative execution API (``repro.core.execution`` / ``repro.api``)
  * ternary quantization / encodings (``repro.core.ternary``)
  * SiTe CiM array functional model (``repro.core.site_cim`` — aliases
    forwarding into the execution registry)
  * declarative hardware model — ArraySpec + technology/design
    registries, array cost, system model, workload projection
    (``repro.hw``; ``repro.core.cost_model`` and
    ``repro.core.accelerator`` are deprecated shims over it)
"""
from repro.core.execution import (  # noqa: F401
    CiMExecSpec,
    execute,
    register_backend,
    registered_specs,
)
from repro.core.site_cim import (  # noqa: F401
    ADC_MAX,
    N_ACTIVE,
    PAPER_CIM_I,
    PAPER_CIM_II,
    SENSE_ERROR_PROB,
    SiTeCiMConfig,
    nm_ternary_matmul,
    scalar_product,
    site_cim_matmul,
    site_cim_matmul_bitplane,
    site_cim_matmul_corrected,
)
from repro.core.ternary import (  # noqa: F401
    from_bitplanes,
    pack_ternary,
    ste_ternarize,
    ste_unit_ternarize,
    ternarize,
    ternary_sparsity,
    to_bitplanes,
    unpack_ternary,
)
