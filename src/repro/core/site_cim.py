"""SiTe CiM functional model — the paper's core contribution, in JAX.

Implements the *architectural semantics* of the signed-ternary
compute-in-memory array (Sections III and IV of the paper):

  * differential ternary encoding of weights (M1/M2 bit-cells) and inputs
    (RWL1/RWL2 wordlines),
  * scalar product truth table (Fig. 3(d) / Fig. 5(e)),
  * multi-row MAC: N_A = 16 rows asserted per cycle; RBL1 accumulates the
    count ``a`` of (+1) products and RBL2 the count ``b`` of (-1) products,
  * 3-bit flash ADC + extra sense-amp: each of a, b is digitized to 0..8,
    with the paper's approximation that all values 9..16 read as 8,
  * block partial sum = clip8(a) - clip8(b); partial sums accumulated
    digitally in the PCU across the K/16 blocks of a column,
  * optional stochastic sensing-error channel (total error probability
    3.1e-3 per the paper's SM + sparsity analysis [21]), modelled as a
    +/-1 perturbation of a block partial (adjacent-ADC-level error),
  * flavor I vs II: functionally identical MAC results (the flavors differ
    in circuits/cost, captured in core/cost_model.py); flavor II is
    restricted to one row per block per cycle, which only affects the
    cost/latency model, not the math.

TPU adaptation (see DESIGN.md §2): instead of emulating bitline event
counting, we use the exact identity

    p_blk = sum_i x_i w_i          (signed dot, 16-deep)
    m_blk = sum_i |x_i| |w_i|      (magnitude dot, 16-deep)
    a = (m_blk + p_blk) / 2,   b = (m_blk - p_blk) / 2

so the array semantics become two (blocked) matmuls + elementwise clamp —
an MXU-native formulation.

NOTE: the public matmul entry points in this module are **deprecated
aliases**. The implementation (and every other ternary-MAC kernel) lives
behind the declarative execution API in ``repro.core.execution``
(re-exported as ``repro.api``); each alias below simply builds a
``CiMExecSpec`` from its ``SiTeCiMConfig`` and forwards to
``execute(spec, x_t, w_t)``. New call sites should use ``repro.api``
directly.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Optional

import jax
import jax.numpy as jnp

# Paper constants (Sections III.2, IV.3)
N_ROWS = 256            # rows per array
N_COLS = 256            # columns per array
N_ACTIVE = 16           # rows asserted per cycle (N_A)
ADC_BITS = 3
ADC_MAX = 8             # 3-bit ADC + extra sense amp for the value 8
SENSE_ERROR_PROB = 3.1e-3  # total probability of a sensing error [21]


@dataclasses.dataclass(frozen=True)
class SiTeCiMConfig:
    """Architectural knobs of a SiTe CiM array (paper defaults)."""
    flavor: str = "I"            # "I" (per-cell coupling) or "II" (sub-column)
    block: int = N_ACTIVE        # rows asserted per cycle
    adc_max: int = ADC_MAX       # clamp bound for a and b
    error_prob: float = 0.0      # sensing-error probability (0 = ideal)
    n_rows: int = N_ROWS
    n_cols: int = N_COLS

    def __post_init__(self):
        if self.flavor not in ("I", "II"):
            raise ValueError(f"unknown SiTe CiM flavor {self.flavor!r}")
        if self.n_rows % self.block != 0:
            raise ValueError("n_rows must be divisible by the block size")


PAPER_CIM_I = SiTeCiMConfig(flavor="I")
PAPER_CIM_II = SiTeCiMConfig(flavor="II")


# ---------------------------------------------------------------------------
# Scalar product (single cell) — Fig. 3(c-f) truth table
# ---------------------------------------------------------------------------

def scalar_product(i: jax.Array, w: jax.Array) -> jax.Array:
    """Ternary scalar product through the cell model.

    The cell produces discharge events on (RBL1, RBL2); we model them and
    decode, rather than shortcutting to ``i * w``, so tests can check the
    truth table the same way the paper's Fig. 3 does.
    """
    m1 = (w > 0)
    m2 = (w < 0)
    rwl1 = (i > 0)
    rwl2 = (i < 0)
    # RBL1 discharges when AX1 path (RWL1 & M1) or cross-coupled AX4 path
    # (RWL2 & M2) conducts; symmetrically for RBL2 (Fig. 2 / Fig. 3(c)).
    rbl1 = (rwl1 & m1) | (rwl2 & m2)   # "+1" event
    rbl2 = (rwl1 & m2) | (rwl2 & m1)   # "-1" event
    return rbl1.astype(jnp.int32) - rbl2.astype(jnp.int32)


# ---------------------------------------------------------------------------
# Deprecated aliases over the execution registry
# ---------------------------------------------------------------------------

def _warn_ignored_precision(precision) -> None:
    if precision is not None:
        warnings.warn(
            "the `precision` argument of the deprecated site_cim aliases is "
            "ignored: the execution shim (repro.core.execution) owns the "
            "dtype/precision policy",
            DeprecationWarning,
            stacklevel=3,
        )


def _spec_from_config(config: SiTeCiMConfig, formulation: str):
    from repro.core import execution as xapi

    return xapi.CiMExecSpec(
        formulation=formulation,
        backend="jnp",
        flavor=config.flavor,
        block=config.block,
        adc_max=config.adc_max,
        error_prob=config.error_prob,
    )


def site_cim_matmul(
    x_t: jax.Array,
    w_t: jax.Array,
    config: SiTeCiMConfig = PAPER_CIM_I,
    key: Optional[jax.Array] = None,
    precision=None,
) -> jax.Array:
    """Deprecated alias — forwards to ``repro.api.execute`` with the
    "blocked" formulation (per-16-row a/b event counts + ADC clamp).

    Args:
      x_t: (..., K) ternary inputs in {-1, 0, 1} (any numeric dtype).
      w_t: (K, N) ternary weights in {-1, 0, 1}.
      config: array config; ``config.adc_max`` clamps per-block event counts.
      key: PRNG key for the sensing-error channel (required if
        ``config.error_prob > 0``).

    Returns:
      (..., N) integer-valued dot products with per-16-row-block 3-bit ADC
      saturation: ``sum_blk clip8(a_blk) - clip8(b_blk)``.

    Gradient-semantics change vs. the pre-API implementation: the shim
    defines a straight-through VJP (exact-matmul backward everywhere),
    where the old jnp body autodiffed through the clamp (zero gradient
    in saturated blocks). STE is the trained-model semantic the layer
    stack always used (kernels.ops.cim_matmul); clamp-sensitivity work
    should differentiate the "bitplane"/"blocked" registry fns directly.
    """
    _warn_ignored_precision(precision)
    from repro.core import execution as xapi

    return xapi.execute(_spec_from_config(config, "blocked"), x_t, w_t, key=key)


def nm_ternary_matmul(x_t: jax.Array, w_t: jax.Array, precision=None) -> jax.Array:
    """Deprecated alias — forwards to ``repro.api.execute`` with the
    "exact" formulation (near-memory baseline: row-by-row digital MAC,
    no ADC clamp; the NM/CiM difference is cost, core/cost_model.py)."""
    _warn_ignored_precision(precision)
    from repro.core import execution as xapi

    spec = xapi.CiMExecSpec(formulation="exact", backend="jnp")
    return xapi.execute(spec, x_t, w_t)


def site_cim_matmul_corrected(
    x_t: jax.Array,
    w_t: jax.Array,
    config: SiTeCiMConfig = PAPER_CIM_I,
    precision=None,
) -> jax.Array:
    """Deprecated alias — forwards to ``repro.api.execute`` with the
    "corrected" (clip-as-correction) formulation: exact_dot +
    sum_blk (relu(b_blk - 8) - relu(a_blk - 8)), numerically identical to
    :func:`site_cim_matmul` with error_prob=0 but with the bulk
    contraction as one full-depth MXU matmul (DESIGN.md §2).

    Gradients are straight-through (see :func:`site_cim_matmul`)."""
    _warn_ignored_precision(precision)
    from repro.core import execution as xapi

    return xapi.execute(_spec_from_config(config, "corrected"), x_t, w_t)


def site_cim_matmul_bitplane(
    x_t: jax.Array, w_t: jax.Array, config: SiTeCiMConfig = PAPER_CIM_I
) -> jax.Array:
    """Deprecated alias — forwards to ``repro.api.execute`` with the
    "bitplane" (event-counting) formulation:

        a = #(RWL1 & M1) + #(RWL2 & M2)   (RBL1 discharge events)
        b = #(RWL1 & M2) + #(RWL2 & M1)   (RBL2 discharge events)

    Slower on TPU than the matmul form; the structural oracle the test
    suite pins every other registered backend against.
    """
    from repro.core import execution as xapi

    return xapi.execute(_spec_from_config(config, "bitplane"), x_t, w_t)
