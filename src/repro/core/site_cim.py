"""SiTe CiM functional model — the paper's core contribution, in JAX.

Implements the *architectural semantics* of the signed-ternary
compute-in-memory array (Sections III and IV of the paper):

  * differential ternary encoding of weights (M1/M2 bit-cells) and inputs
    (RWL1/RWL2 wordlines),
  * scalar product truth table (Fig. 3(d) / Fig. 5(e)),
  * multi-row MAC: N_A = 16 rows asserted per cycle; RBL1 accumulates the
    count ``a`` of (+1) products and RBL2 the count ``b`` of (-1) products,
  * 3-bit flash ADC + extra sense-amp: each of a, b is digitized to 0..8,
    with the paper's approximation that all values 9..16 read as 8,
  * block partial sum = clip8(a) - clip8(b); partial sums accumulated
    digitally in the PCU across the K/16 blocks of a column,
  * optional stochastic sensing-error channel (total error probability
    3.1e-3 per the paper's SM + sparsity analysis [21]), modelled as a
    +/-1 perturbation of a block partial (adjacent-ADC-level error),
  * flavor I vs II: functionally identical MAC results (the flavors differ
    in circuits/cost, captured in core/cost_model.py); flavor II is
    restricted to one row per block per cycle, which only affects the
    cost/latency model, not the math.

TPU adaptation (see DESIGN.md §2): instead of emulating bitline event
counting, we use the exact identity

    p_blk = sum_i x_i w_i          (signed dot, 16-deep)
    m_blk = sum_i |x_i| |w_i|      (magnitude dot, 16-deep)
    a = (m_blk + p_blk) / 2,   b = (m_blk - p_blk) / 2

so the array semantics become two (blocked) matmuls + elementwise clamp —
an MXU-native formulation. ``site_cim_matmul`` below is the reference
implementation; ``repro.kernels`` holds the Pallas kernels.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp

# Paper constants (Sections III.2, IV.3)
N_ROWS = 256            # rows per array
N_COLS = 256            # columns per array
N_ACTIVE = 16           # rows asserted per cycle (N_A)
ADC_BITS = 3
ADC_MAX = 8             # 3-bit ADC + extra sense amp for the value 8
SENSE_ERROR_PROB = 3.1e-3  # total probability of a sensing error [21]


@dataclasses.dataclass(frozen=True)
class SiTeCiMConfig:
    """Architectural knobs of a SiTe CiM array (paper defaults)."""
    flavor: str = "I"            # "I" (per-cell coupling) or "II" (sub-column)
    block: int = N_ACTIVE        # rows asserted per cycle
    adc_max: int = ADC_MAX       # clamp bound for a and b
    error_prob: float = 0.0      # sensing-error probability (0 = ideal)
    n_rows: int = N_ROWS
    n_cols: int = N_COLS

    def __post_init__(self):
        if self.flavor not in ("I", "II"):
            raise ValueError(f"unknown SiTe CiM flavor {self.flavor!r}")
        if self.n_rows % self.block != 0:
            raise ValueError("n_rows must be divisible by the block size")


PAPER_CIM_I = SiTeCiMConfig(flavor="I")
PAPER_CIM_II = SiTeCiMConfig(flavor="II")


# ---------------------------------------------------------------------------
# Scalar product (single cell) — Fig. 3(c-f) truth table
# ---------------------------------------------------------------------------

def scalar_product(i: jax.Array, w: jax.Array) -> jax.Array:
    """Ternary scalar product through the cell model.

    The cell produces discharge events on (RBL1, RBL2); we model them and
    decode, rather than shortcutting to ``i * w``, so tests can check the
    truth table the same way the paper's Fig. 3 does.
    """
    m1 = (w > 0)
    m2 = (w < 0)
    rwl1 = (i > 0)
    rwl2 = (i < 0)
    # RBL1 discharges when AX1 path (RWL1 & M1) or cross-coupled AX4 path
    # (RWL2 & M2) conducts; symmetrically for RBL2 (Fig. 2 / Fig. 3(c)).
    rbl1 = (rwl1 & m1) | (rwl2 & m2)   # "+1" event
    rbl2 = (rwl1 & m2) | (rwl2 & m1)   # "-1" event
    return rbl1.astype(jnp.int32) - rbl2.astype(jnp.int32)


# ---------------------------------------------------------------------------
# Block MAC: a/b decomposition + ADC clamp
# ---------------------------------------------------------------------------

def _block_ab(xb: jax.Array, wb: jax.Array, precision=None):
    """Per-block event counts.

    xb: (..., KB, B) ternary inputs, wb: (KB, B, N) ternary weights.
    Returns a, b with shape (..., KB, N): the number of +1 / -1 scalar
    products per 16-row block per output column (RBL1/RBL2 counts).
    """
    p = jnp.einsum("...ki,kin->...kn", xb, wb, precision=precision)
    m = jnp.einsum("...ki,kin->...kn", jnp.abs(xb), jnp.abs(wb), precision=precision)
    a = (m + p) * 0.5 if jnp.issubdtype(p.dtype, jnp.floating) else (m + p) // 2
    b = (m - p) * 0.5 if jnp.issubdtype(p.dtype, jnp.floating) else (m - p) // 2
    return a, b


def _apply_sense_error(partial: jax.Array, key: jax.Array, prob: float) -> jax.Array:
    """Stochastic sensing-error channel: with probability ``prob`` a block
    partial reads one ADC level off (+/-1), the adjacent-level error mode
    that the SM analysis bounds."""
    ku, ks = jax.random.split(key)
    flip = jax.random.bernoulli(ku, prob, partial.shape)
    sign = jax.random.rademacher(ks, partial.shape, dtype=partial.dtype)
    return partial + flip.astype(partial.dtype) * sign


@functools.partial(jax.jit, static_argnames=("config", "precision"))
def site_cim_matmul(
    x_t: jax.Array,
    w_t: jax.Array,
    config: SiTeCiMConfig = PAPER_CIM_I,
    key: Optional[jax.Array] = None,
    precision=None,
) -> jax.Array:
    """Signed-ternary MAC with SiTe CiM array semantics.

    Args:
      x_t: (..., K) ternary inputs in {-1, 0, 1} (any numeric dtype).
      w_t: (K, N) ternary weights in {-1, 0, 1}.
      config: array config; ``config.adc_max`` clamps per-block event counts.
      key: PRNG key for the sensing-error channel (required if
        ``config.error_prob > 0``).

    Returns:
      (..., N) integer-valued dot products with per-16-row-block 3-bit ADC
      saturation: ``sum_blk clip8(a_blk) - clip8(b_blk)``.
    """
    k = x_t.shape[-1]
    block = config.block
    pad = (-k) % block
    if pad:
        x_t = jnp.pad(x_t, [(0, 0)] * (x_t.ndim - 1) + [(0, pad)])
        w_t = jnp.pad(w_t, [(0, pad), (0, 0)])
        k += pad
    kb = k // block
    xb = x_t.reshape(x_t.shape[:-1] + (kb, block))
    wb = w_t.reshape((kb, block) + w_t.shape[1:])
    a, b = _block_ab(xb, wb, precision=precision)
    adc_max = jnp.asarray(config.adc_max, a.dtype)
    partial = jnp.minimum(a, adc_max) - jnp.minimum(b, adc_max)
    if config.error_prob > 0.0:
        if key is None:
            raise ValueError("error_prob > 0 requires a PRNG key")
        partial = _apply_sense_error(partial, key, config.error_prob)
    # PCU digital accumulation across blocks.
    return jnp.sum(partial, axis=-2)


@functools.partial(jax.jit, static_argnames=("precision",))
def nm_ternary_matmul(x_t: jax.Array, w_t: jax.Array, precision=None) -> jax.Array:
    """Near-memory baseline: exact ternary dot product (row-by-row digital
    MAC — no ADC clamp). Functionally this is a plain matmul; the paper's
    NM/CiM difference is in latency/energy (core/cost_model.py)."""
    return jnp.einsum("...k,kn->...n", x_t, w_t, precision=precision)


@functools.partial(jax.jit, static_argnames=("config", "precision"))
def site_cim_matmul_corrected(
    x_t: jax.Array,
    w_t: jax.Array,
    config: SiTeCiMConfig = PAPER_CIM_I,
    precision=None,
) -> jax.Array:
    """Clip-as-correction formulation (DESIGN.md §2, beyond-paper opt).

    exact_dot + sum_blk (relu(b_blk - 8) - relu(a_blk - 8)) — numerically
    identical to :func:`site_cim_matmul` with error_prob=0, but the bulk
    contraction is a full-depth MXU matmul; only the (rare) saturation
    correction needs blocked arithmetic.
    """
    k = x_t.shape[-1]
    block = config.block
    pad = (-k) % block
    if pad:
        x_t = jnp.pad(x_t, [(0, 0)] * (x_t.ndim - 1) + [(0, pad)])
        w_t = jnp.pad(w_t, [(0, pad), (0, 0)])
        k += pad
    exact = jnp.einsum("...k,kn->...n", x_t, w_t, precision=precision)
    kb = k // block
    xb = x_t.reshape(x_t.shape[:-1] + (kb, block))
    wb = w_t.reshape((kb, block) + w_t.shape[1:])
    a, b = _block_ab(xb, wb, precision=precision)
    adc_max = jnp.asarray(config.adc_max, a.dtype)
    corr = jnp.maximum(b - adc_max, 0) - jnp.maximum(a - adc_max, 0)
    return exact + jnp.sum(corr, axis=-2)


# ---------------------------------------------------------------------------
# Bitplane (event-counting) reference — mirrors the hardware directly
# ---------------------------------------------------------------------------

def site_cim_matmul_bitplane(
    x_t: jax.Array, w_t: jax.Array, config: SiTeCiMConfig = PAPER_CIM_I
) -> jax.Array:
    """Event-counting formulation over (M1, M2) bitplanes:

        a = #(RWL1 & M1) + #(RWL2 & M2)   (RBL1 discharge events)
        b = #(RWL1 & M2) + #(RWL2 & M1)   (RBL2 discharge events)

    Slower on TPU than the matmul form; used as a structural oracle in
    tests to pin the functional model to the circuit description.
    """
    m1 = (w_t > 0).astype(jnp.int32)
    m2 = (w_t < 0).astype(jnp.int32)
    r1 = (x_t > 0).astype(jnp.int32)
    r2 = (x_t < 0).astype(jnp.int32)
    k = x_t.shape[-1]
    block = config.block
    pad = (-k) % block
    if pad:
        r1 = jnp.pad(r1, [(0, 0)] * (r1.ndim - 1) + [(0, pad)])
        r2 = jnp.pad(r2, [(0, 0)] * (r2.ndim - 1) + [(0, pad)])
        m1 = jnp.pad(m1, [(0, pad), (0, 0)])
        m2 = jnp.pad(m2, [(0, pad), (0, 0)])
        k += pad
    kb = k // block

    def blk(v, lead):
        if lead:
            return v.reshape(v.shape[:-1] + (kb, block))
        return v.reshape((kb, block) + v.shape[1:])

    r1b, r2b = blk(r1, True), blk(r2, True)
    m1b, m2b = blk(m1, False), blk(m2, False)
    a = jnp.einsum("...ki,kin->...kn", r1b, m1b) + jnp.einsum("...ki,kin->...kn", r2b, m2b)
    b = jnp.einsum("...ki,kin->...kn", r1b, m2b) + jnp.einsum("...ki,kin->...kn", r2b, m1b)
    partial = jnp.minimum(a, config.adc_max) - jnp.minimum(b, config.adc_max)
    return jnp.sum(partial, axis=-2)
