"""DEPRECATED compatibility shim — the array-level cost model now lives
in the declarative hardware API, ``repro.hw`` (DESIGN.md §7).

Every legacy name forwards to its ``repro.hw`` equivalent and emits a
``DeprecationWarning`` on first touch:

  * ``TECHNOLOGIES`` / ``DESIGNS``          -> ``hw.PAPER_TECHNOLOGIES`` /
    ``hw.PAPER_DESIGNS`` (the *registered* sets are ``hw.technologies()``
    / ``hw.designs()`` — new technologies land there, never here),
  * ``ArrayMetrics`` / ``ARRAY_METRICS``    -> ``hw.DesignMetrics`` /
    ``hw.design_metrics(tech, design)``,
  * ``TechBase`` / ``TECH_BASE``            -> ``hw.TechnologySpec`` /
    ``hw.get_technology(name)``,
  * ``array_cost(tech, design)``            -> ``hw.array_cost(ArraySpec)``,
  * ``paper_validation_table`` / ``flavor_comparison`` — unchanged
    output, now derived through the registries.

Geometry constants (N_ROWS, N_COLS, N_ACTIVE, CYCLES_PER_MAC_*) forward
to the ``ArraySpec`` defaults.
"""
from __future__ import annotations

import warnings
from typing import Dict

from repro.hw import array as _arr
from repro.hw import registry as _reg

# re-exported types (no warning: harmless to name in annotations)
ArrayMetrics = _reg.DesignMetrics
TechBase = _reg.TechnologySpec
ArrayCost = _arr.ArrayCost


def _warn(name: str, repl: str) -> None:
    warnings.warn(
        f"repro.core.cost_model.{name} is deprecated; use {repl}",
        DeprecationWarning,
        stacklevel=3,
    )


def array_cost(tech: str, design: str) -> ArrayCost:
    """Forward to ``hw.array_cost`` on a default-geometry ArraySpec."""
    return _arr.array_cost(_arr.ArraySpec(technology=tech, design=design))


def paper_validation_table() -> Dict[str, Dict[str, Dict[str, float]]]:
    return _arr.paper_validation_table()


def flavor_comparison() -> Dict[str, Dict[str, float]]:
    return _arr.flavor_comparison()


def _legacy_array_metrics() -> Dict[str, Dict[str, ArrayMetrics]]:
    return {
        tech: {d: _reg.design_metrics(tech, d) for d in _reg.PAPER_DESIGNS}
        for tech in _reg.PAPER_TECHNOLOGIES
    }


_FORWARDS = {
    "TECHNOLOGIES": (lambda: _reg.PAPER_TECHNOLOGIES,
                     "repro.hw.technologies() (registered set) or "
                     "hw.PAPER_TECHNOLOGIES (paper set)"),
    "DESIGNS": (lambda: _reg.PAPER_DESIGNS, "repro.hw.designs()"),
    "N_ROWS": (lambda: _arr.DEFAULT_ROWS, "ArraySpec.rows"),
    "N_COLS": (lambda: _arr.DEFAULT_COLS, "ArraySpec.cols"),
    "N_ACTIVE": (lambda: _arr.DEFAULT_N_ACTIVE, "ArraySpec.n_active"),
    "CYCLES_PER_MAC_CIM": (
        lambda: _arr.DEFAULT_ROWS // _arr.DEFAULT_N_ACTIVE,
        "ArraySpec.cycles_per_pass"),
    "CYCLES_PER_MAC_NM": (lambda: _arr.DEFAULT_ROWS,
                          "ArraySpec.cycles_per_pass"),
    "ARRAY_METRICS": (_legacy_array_metrics,
                      "repro.hw.design_metrics(tech, design)"),
    "TECH_BASE": (
        lambda: {t: _reg.get_technology(t) for t in _reg.PAPER_TECHNOLOGIES},
        "repro.hw.get_technology(name)"),
}


def __getattr__(name: str):
    if name in _FORWARDS:
        thunk, repl = _FORWARDS[name]
        _warn(name, repl)
        return thunk()
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return sorted(list(globals()) + list(_FORWARDS))
