"""Array-level latency / energy / area model for SiTe CiM I/II vs NM.

The paper's Section V reports *normalized* array-level metrics (Figs 9 and
11) for the three technologies (8T-SRAM, 3T-eDRAM, 3T-FEMFET) and two CiM
flavors, against near-memory (NM) baselines built from standard 512x256
binary arrays (= 256x256 ternary words). Those normalized numbers are the
primary data we reproduce; this module encodes them together with an
absolute timing/energy scale for the NM baselines (the paper reports only
normalized values; the absolute scale is an assumption, documented, and
only affects absolute — never relative — system results).

Conventions:
  * "cim" metrics are per MAC pass of a full 256-row column set:
    NM = 256 sequential row reads + digital MAC; CiM I/II = 16 array
    cycles (16 rows per cycle for I; one row per each of the 16 blocks per
    cycle for II).
  * all ``*_vs_nm`` numbers are ratios normalized to the same-technology NM
    baseline (1.0), straight from the paper's Figs 9/11 and Section V text.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

TECHNOLOGIES = ("8T-SRAM", "3T-eDRAM", "3T-FEMFET")
DESIGNS = ("NM", "CiM-I", "CiM-II")

N_ROWS = 256
N_COLS = 256
N_ACTIVE = 16
CYCLES_PER_MAC_CIM = N_ROWS // N_ACTIVE  # 16 cycles, both flavors
CYCLES_PER_MAC_NM = N_ROWS               # row-by-row readout


@dataclasses.dataclass(frozen=True)
class ArrayMetrics:
    """Normalized-to-NM array metrics for one (technology, design)."""
    cim_latency_vs_nm: float      # full MAC pass latency ratio
    cim_energy_vs_nm: float       # full MAC pass energy ratio
    read_latency_vs_nm: float
    read_energy_vs_nm: float
    write_latency_vs_nm: float
    write_energy_vs_nm: float
    cell_area_vs_nm: float        # ternary cell area ratio
    macro_area_vs_nm: float       # incl. peripherals (ADCs vs NM MAC unit)


# --- Paper Fig. 9 (SiTe CiM I) -------------------------------------------
# "~88% lower latency" for all three technologies; energy savings 74 / 78 /
# 78%; read energy +22/24/17%, read latency +7/7/19%; write latency
# +4/4/10%, write energy comparable; cell area +18/34/34%; macro area
# 1.3x-1.53x (SRAM at the low end — its baseline cell is largest, so the
# relative ADC overhead is smallest... the paper gives the range; the
# per-tech split below is our documented assumption within that range).
_CIM_I: Dict[str, ArrayMetrics] = {
    "8T-SRAM": ArrayMetrics(0.12, 0.26, 1.07, 1.22, 1.04, 1.00, 1.18, 1.30),
    "3T-eDRAM": ArrayMetrics(0.12, 0.22, 1.07, 1.24, 1.04, 1.00, 1.34, 1.53),
    "3T-FEMFET": ArrayMetrics(0.12, 0.22, 1.19, 1.17, 1.10, 1.00, 1.34, 1.53),
}

# --- Paper Fig. 11 (SiTe CiM II) -------------------------------------------
# MAC delay improvements 80 / 78 / 84%; energy 61 / 63 / 62%; read speed
# 2.4X / 2.6X / 1.8X lower; read energy +74/44/79%; write latency
# +8/10/3%; cell area +6% for all; macro area 1.21x-1.33x.
_CIM_II: Dict[str, ArrayMetrics] = {
    "8T-SRAM": ArrayMetrics(0.20, 0.39, 2.40, 1.74, 1.08, 1.00, 1.06, 1.21),
    "3T-eDRAM": ArrayMetrics(0.22, 0.37, 2.60, 1.44, 1.10, 1.00, 1.06, 1.33),
    "3T-FEMFET": ArrayMetrics(0.16, 0.38, 1.80, 1.79, 1.03, 1.00, 1.06, 1.33),
}

_NM = ArrayMetrics(1.0, 1.0, 1.0, 1.0, 1.0, 1.0, 1.0, 1.0)

ARRAY_METRICS: Dict[str, Dict[str, ArrayMetrics]] = {
    tech: {"NM": _NM, "CiM-I": _CIM_I[tech], "CiM-II": _CIM_II[tech]}
    for tech in TECHNOLOGIES
}


@dataclasses.dataclass(frozen=True)
class TechBase:
    """Absolute NM-baseline scale per technology (assumed, documented).

    t_read_ns: one row read (256 bit-cell pairs sensed in parallel).
    e_read_pj: energy of that row read.
    t_write_ns / e_write_pj: one row write.
    t_nm_mac_ns / e_nm_mac_pj: digital near-memory MAC of one 256-wide row
      against the input element (pipelined with the next read in the NM
      design; we keep it explicit for energy).
    """
    t_read_ns: float
    e_read_pj: float
    t_write_ns: float
    e_write_pj: float
    t_nm_mac_ns: float
    e_nm_mac_pj: float
    leakage_mw: float  # array standby power (0 for NVM)


TECH_BASE: Dict[str, TechBase] = {
    # 45nm PTM class numbers; SRAM fastest read, FEMFET slow high-voltage
    # write (-5V reset / +4.8V set), eDRAM in between. NVM has no standby
    # leakage (paper Section II.C).
    "8T-SRAM": TechBase(1.0, 12.0, 1.0, 14.0, 1.2, 22.0, 1.5),
    "3T-eDRAM": TechBase(1.3, 10.0, 1.1, 11.0, 1.2, 22.0, 0.8),
    "3T-FEMFET": TechBase(1.5, 10.0, 8.0, 30.0, 1.2, 22.0, 0.0),
}


@dataclasses.dataclass(frozen=True)
class ArrayCost:
    """Absolute per-operation array costs, derived from TECH_BASE x ratios."""
    tech: str
    design: str
    mac_pass_ns: float     # one full 256-row x 256-col ternary MAC pass
    mac_pass_pj: float
    row_read_ns: float
    row_read_pj: float
    row_write_ns: float
    row_write_pj: float
    cell_area: float       # relative units (NM ternary cell of tech = 1.0)
    macro_area: float

    @property
    def macs_per_pass(self) -> int:
        return N_ROWS * N_COLS


def array_cost(tech: str, design: str) -> ArrayCost:
    base = TECH_BASE[tech]
    m = ARRAY_METRICS[tech][design]
    # NM MAC pass: 256 row reads + digital MACs (read/compute pipelined, so
    # latency is dominated by reads; energy adds both).
    nm_mac_ns = CYCLES_PER_MAC_NM * max(base.t_read_ns, base.t_nm_mac_ns)
    nm_mac_pj = CYCLES_PER_MAC_NM * (base.e_read_pj + base.e_nm_mac_pj)
    return ArrayCost(
        tech=tech,
        design=design,
        mac_pass_ns=nm_mac_ns * m.cim_latency_vs_nm,
        mac_pass_pj=nm_mac_pj * m.cim_energy_vs_nm,
        row_read_ns=base.t_read_ns * m.read_latency_vs_nm,
        row_read_pj=base.e_read_pj * m.read_energy_vs_nm,
        row_write_ns=base.t_write_ns * m.write_latency_vs_nm,
        row_write_pj=base.e_write_pj * m.write_energy_vs_nm,
        cell_area=m.cell_area_vs_nm,
        macro_area=m.macro_area_vs_nm,
    )


def paper_validation_table() -> Dict[str, Dict[str, Dict[str, float]]]:
    """The claims of Figs 9/11 as derived from this model — what tests and
    EXPERIMENTS.md compare against the paper's text."""
    out: Dict[str, Dict[str, Dict[str, float]]] = {}
    for tech in TECHNOLOGIES:
        out[tech] = {}
        for design in ("CiM-I", "CiM-II"):
            nm = array_cost(tech, "NM")
            c = array_cost(tech, design)
            out[tech][design] = {
                "cim_latency_reduction_pct": 100.0 * (1 - c.mac_pass_ns / nm.mac_pass_ns),
                "cim_energy_reduction_pct": 100.0 * (1 - c.mac_pass_pj / nm.mac_pass_pj),
                "read_energy_overhead_pct": 100.0 * (c.row_read_pj / nm.row_read_pj - 1),
                "read_latency_overhead_pct": 100.0 * (c.row_read_ns / nm.row_read_ns - 1),
                "write_latency_overhead_pct": 100.0 * (c.row_write_ns / nm.row_write_ns - 1),
                "cell_area_overhead_pct": 100.0 * (c.cell_area - 1),
                "macro_area_ratio": c.macro_area,
            }
    return out


def flavor_comparison() -> Dict[str, Dict[str, float]]:
    """Section V.3: CiM II vs CiM I energy/latency/area ratios."""
    out = {}
    for tech in TECHNOLOGIES:
        c1 = array_cost(tech, "CiM-I")
        c2 = array_cost(tech, "CiM-II")
        out[tech] = {
            "energy_II_over_I": c2.mac_pass_pj / c1.mac_pass_pj,
            "latency_II_over_I": c2.mac_pass_ns / c1.mac_pass_ns,
            "cell_area_II_over_I": c2.cell_area / c1.cell_area,
        }
    return out
