"""DEPRECATED compatibility shim — the TiM-DNN-style system model now
lives in ``repro.hw.macro`` (+ the paper's DNN suite in
``repro.hw.dnn_suite``), generalized over ``ArraySpec``/``MacroSpec``
(DESIGN.md §7).

Functions forward directly (same signatures, same outputs); legacy
module constants forward with a ``DeprecationWarning`` — new code
should size macros through ``hw.MacroSpec`` fields instead.
"""
from __future__ import annotations

import warnings

from repro.hw import array as _array
from repro.hw import dnn_suite as _suite
from repro.hw import macro as _macro

# types + paper pins, re-exported unchanged
GemmLayer = _macro.GemmLayer
SystemResult = _macro.SystemResult
conv = _macro.conv
PAPER_SYSTEM_SPEEDUP = _macro.PAPER_SYSTEM_SPEEDUP
PAPER_SYSTEM_ENERGY = _macro.PAPER_SYSTEM_ENERGY

# the paper's Section VI workloads
alexnet = _suite.alexnet
resnet34 = _suite.resnet34
inception = _suite.inception
lstm = _suite.lstm
gru = _suite.gru
get_benchmarks = _suite.get_benchmarks

# the system model itself
run_system = _macro.run_system
speedup_and_energy = _macro.speedup_and_energy
average_speedup = _macro.average_speedup
average_energy_reduction = _macro.average_energy_reduction


_DEFAULT = _macro.PAPER_MACRO
_FORWARDS = {
    "N_ARRAYS": (lambda: _DEFAULT.n_arrays, "MacroSpec.n_arrays"),
    "N_PCUS": (lambda: _array.DEFAULT_PCUS, "ArraySpec.pcus"),
    "POST_NS_PER_OUT": (lambda: _DEFAULT.post_ns_per_out,
                        "MacroSpec.post_ns_per_out"),
    "POST_PJ_PER_OUT": (lambda: _DEFAULT.post_pj_per_out,
                        "MacroSpec.post_pj_per_out"),
    "WRITE_AMORTIZATION": (lambda: _DEFAULT.write_amortization,
                           "MacroSpec.write_amortization"),
    "ISO_AREA_NM_ARRAYS": (lambda: _macro.PAPER_ISO_AREA_NM_ARRAYS,
                           "repro.hw.iso_area_nm_arrays(array, macro)"),
    "BENCHMARKS": (lambda: _suite.BENCHMARKS,
                   "repro.hw.dnn_suite.get_benchmarks()"),
}


def __getattr__(name: str):
    if name in _FORWARDS:
        thunk, repl = _FORWARDS[name]
        warnings.warn(
            f"repro.core.accelerator.{name} is deprecated; use {repl}",
            DeprecationWarning,
            stacklevel=2,
        )
        return thunk()
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return sorted(list(globals()) + list(_FORWARDS))
