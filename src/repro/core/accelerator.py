"""System-level TiM-DNN-style accelerator model (paper Section VI).

Maps DNN benchmark workloads (AlexNet, ResNet34, Inception, LSTM, GRU —
the paper's suite) onto a macro of SiTe CiM (or NM) arrays and derives
execution time and energy, reproducing Figs 12/13:

  * 32 arrays of 256x256 ternary cells (2M ternary words / 512 kB),
  * N_A = 16 rows asserted per cycle -> 16 cycles per full-column MAC pass,
  * 32 PCUs per array (< N_C = 256): column partials are drained 32 at a
    time, so a MAC pass takes ceil(256/32) = 8 PCU drain slots overlapped
    with compute; we model the drain as part of the pass constants,
  * NM baselines: iso-capacity (32 arrays) and iso-area (more arrays —
    41/48/47 for CiM I comparisons and 38/42/41 for CiM II, per tech),
  * weight reloading: layers larger than macro capacity are processed in
    weight tiles; writing a tile costs row writes,
  * a fixed per-output post-processing cost (quantization + activation in
    the digital periphery) identical across designs — this is the Amdahl
    term that brings the raw ~8.3x array-level CiM I advantage down to the
    ~6.6-7.1x system-level speedups the paper reports.

The post-processing rate is the single calibration constant; it was fitted
once so the 8T-SRAM CiM I iso-capacity average lands near the paper's
6.74x, and then *everything else* (other technologies, flavors, iso-area
baselines, energy ratios) is a prediction of the model that EXPERIMENTS.md
compares against the paper's numbers.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Sequence, Tuple

from repro.core import cost_model as cm

N_ARRAYS = 32
N_PCUS = 32

# Iso-area NM baseline array counts (paper Section VI.A).
ISO_AREA_NM_ARRAYS = {
    "CiM-I": {"8T-SRAM": 41, "3T-eDRAM": 48, "3T-FEMFET": 47},
    "CiM-II": {"8T-SRAM": 38, "3T-eDRAM": 42, "3T-FEMFET": 41},
}

# Calibrated digital post-processing (partial-sum reduce + quantize +
# activation) throughput, ns and pJ per output element, identical for CiM
# and NM designs (see module docstring for the calibration procedure).
POST_NS_PER_OUT = 0.4486
POST_PJ_PER_OUT = 31.5

# Weight tiles are loaded once and reused across a batch of inferences
# (weight-stationary steady state, as in the TiM-DNN evaluation); write
# cost is amortized over this batch. FEMFET is non-volatile, so resident
# tiles would persist across power cycles as well.
WRITE_AMORTIZATION = 16


@dataclasses.dataclass(frozen=True)
class GemmLayer:
    """One DNN layer as a GEMM: out[M, N] = in[M, K] @ w[K, N].

    Convs are im2col-lowered (K = C_in * kh * kw, M = H_out * W_out).
    RNN steps: K = input + hidden, N = gates * hidden, M = timesteps.
    """
    name: str
    m: int
    k: int
    n: int

    @property
    def macs(self) -> int:
        return self.m * self.k * self.n


def conv(name: str, h_out: int, c_in: int, kh: int, c_out: int, kw: int | None = None) -> GemmLayer:
    kw = kh if kw is None else kw
    return GemmLayer(name, h_out * h_out, c_in * kh * kw, c_out)


# ---------------------------------------------------------------------------
# Benchmark workloads (paper Section VI: AlexNet, ResNet34, Inception,
# LSTM, GRU). Dimensions follow the standard published architectures.
# ---------------------------------------------------------------------------

def alexnet() -> List[GemmLayer]:
    return [
        conv("conv1", 55, 3, 11, 96),
        conv("conv2", 27, 96, 5, 256),
        conv("conv3", 13, 256, 3, 384),
        conv("conv4", 13, 384, 3, 384),
        conv("conv5", 13, 384, 3, 256),
        GemmLayer("fc6", 1, 9216, 4096),
        GemmLayer("fc7", 1, 4096, 4096),
        GemmLayer("fc8", 1, 4096, 1000),
    ]


def resnet34() -> List[GemmLayer]:
    layers = [conv("conv1", 112, 3, 7, 64)]
    stages = [(64, 3, 56), (128, 4, 28), (256, 6, 14), (512, 3, 7)]
    prev_c = 64
    for si, (c, blocks, hw) in enumerate(stages):
        for b in range(blocks):
            cin = prev_c if b == 0 else c
            layers.append(conv(f"s{si}b{b}c1", hw, cin, 3, c))
            layers.append(conv(f"s{si}b{b}c2", hw, c, 3, c))
            if b == 0 and cin != c:
                layers.append(conv(f"s{si}b{b}ds", hw, cin, 1, c))
        prev_c = c
    layers.append(GemmLayer("fc", 1, 512, 1000))
    return layers


def inception() -> List[GemmLayer]:
    """GoogLeNet(Inception-v1)-style workload: stem + 9 inception modules."""
    layers = [
        conv("stem1", 112, 3, 7, 64),
        conv("stem2", 56, 64, 3, 192),
    ]
    # (hw, c_in, [#1x1, #3x3red, #3x3, #5x5red, #5x5, pool_proj])
    modules = [
        (28, 192, (64, 96, 128, 16, 32, 32)),
        (28, 256, (128, 128, 192, 32, 96, 64)),
        (14, 480, (192, 96, 208, 16, 48, 64)),
        (14, 512, (160, 112, 224, 24, 64, 64)),
        (14, 512, (128, 128, 256, 24, 64, 64)),
        (14, 512, (112, 144, 288, 32, 64, 64)),
        (14, 528, (256, 160, 320, 32, 128, 128)),
        (7, 832, (256, 160, 320, 32, 128, 128)),
        (7, 832, (384, 192, 384, 48, 128, 128)),
    ]
    for i, (hw, cin, (c1, r3, c3, r5, c5, pp)) in enumerate(modules):
        layers += [
            conv(f"inc{i}_1x1", hw, cin, 1, c1),
            conv(f"inc{i}_3x3r", hw, cin, 1, r3),
            conv(f"inc{i}_3x3", hw, r3, 3, c3),
            conv(f"inc{i}_5x5r", hw, cin, 1, r5),
            conv(f"inc{i}_5x5", hw, r5, 5, c5),
            conv(f"inc{i}_pool", hw, cin, 1, pp),
        ]
    layers.append(GemmLayer("fc", 1, 1024, 1000))
    return layers


def lstm(hidden: int = 512, inp: int = 512, steps: int = 100) -> List[GemmLayer]:
    # 4 gates; input and recurrent GEMMs per step, batched over timesteps.
    return [
        GemmLayer("lstm_x", steps, inp, 4 * hidden),
        GemmLayer("lstm_h", steps, hidden, 4 * hidden),
        GemmLayer("proj", steps, hidden, inp),
    ]


def gru(hidden: int = 512, inp: int = 512, steps: int = 100) -> List[GemmLayer]:
    return [
        GemmLayer("gru_x", steps, inp, 3 * hidden),
        GemmLayer("gru_h", steps, hidden, 3 * hidden),
        GemmLayer("proj", steps, hidden, inp),
    ]


BENCHMARKS: Dict[str, List[GemmLayer]] = {}


def get_benchmarks() -> Dict[str, List[GemmLayer]]:
    if not BENCHMARKS:
        BENCHMARKS.update(
            AlexNet=alexnet(),
            ResNet34=resnet34(),
            Inception=inception(),
            LSTM=lstm(),
            GRU=gru(),
        )
    return BENCHMARKS


# ---------------------------------------------------------------------------
# Execution model
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SystemResult:
    benchmark: str
    tech: str
    design: str
    n_arrays: int
    time_ns: float
    energy_pj: float
    macs: int


def _layer_cost(layer: GemmLayer, cost: cm.ArrayCost, n_arrays: int) -> Tuple[float, float]:
    """(time_ns, energy_pj) for one GEMM layer on ``n_arrays`` arrays."""
    row_tiles = math.ceil(layer.k / cm.N_ROWS)     # weight tiles along K
    col_tiles = math.ceil(layer.n / cm.N_COLS)     # weight tiles along N
    tiles = row_tiles * col_tiles

    if cost.design == "NM":
        # NM: per input vector, each tile streams its rows through the MAC
        # unit — a full MAC pass per (vector, tile).
        nm_base = cm.TECH_BASE[cost.tech]
        pass_ns = cm.CYCLES_PER_MAC_NM * max(nm_base.t_read_ns, nm_base.t_nm_mac_ns)
        pass_pj = cm.CYCLES_PER_MAC_NM * (nm_base.e_read_pj + nm_base.e_nm_mac_pj)
    else:
        pass_ns = cost.mac_pass_ns
        pass_pj = cost.mac_pass_pj

    total_passes = layer.m * tiles
    # Weight loading: each tile written once (weight-stationary reuse over
    # all M vectors and a batch of WRITE_AMORTIZATION inferences); 512
    # binary rows per 256-row ternary tile.
    write_rows = tiles * cm.N_ROWS * 2 / WRITE_AMORTIZATION
    # Arrays work in parallel across tiles and across input vectors.
    parallel_time = math.ceil(total_passes / n_arrays) * pass_ns
    write_time = write_rows / n_arrays * cost.row_write_ns
    post = layer.m * layer.n
    post_time = post * POST_NS_PER_OUT / (n_arrays * N_PCUS / 8.0)

    time_ns = parallel_time + write_time + post_time
    energy_pj = (
        total_passes * pass_pj
        + write_rows * cost.row_write_pj
        + post * POST_PJ_PER_OUT
    )
    return time_ns, energy_pj


def run_system(benchmark: str, tech: str, design: str, n_arrays: int = N_ARRAYS) -> SystemResult:
    layers = get_benchmarks()[benchmark]
    cost = cm.array_cost(tech, design)
    t = e = 0.0
    macs = 0
    for layer in layers:
        lt, le = _layer_cost(layer, cost, n_arrays)
        t += lt
        e += le
        macs += layer.macs
    return SystemResult(benchmark, tech, design, n_arrays, t, e, macs)


def speedup_and_energy(tech: str, design: str, baseline: str = "iso-capacity") -> Dict[str, Dict[str, float]]:
    """Per-benchmark speedup and energy-reduction of ``design`` vs the NM
    baseline variant (Figs 12/13)."""
    assert design in ("CiM-I", "CiM-II")
    if baseline == "iso-capacity":
        nm_arrays = N_ARRAYS
    elif baseline == "iso-area":
        nm_arrays = ISO_AREA_NM_ARRAYS[design][tech]
    else:
        raise ValueError(baseline)
    out: Dict[str, Dict[str, float]] = {}
    for bench in get_benchmarks():
        cim = run_system(bench, tech, design, N_ARRAYS)
        nm = run_system(bench, tech, "NM", nm_arrays)
        out[bench] = {
            "speedup": nm.time_ns / cim.time_ns,
            "energy_reduction": nm.energy_pj / cim.energy_pj,
        }
    return out


def average_speedup(tech: str, design: str, baseline: str) -> float:
    res = speedup_and_energy(tech, design, baseline)
    vals = [v["speedup"] for v in res.values()]
    return float(sum(vals) / len(vals))


def average_energy_reduction(tech: str, design: str, baseline: str = "iso-capacity") -> float:
    res = speedup_and_energy(tech, design, baseline)
    vals = [v["energy_reduction"] for v in res.values()]
    return float(sum(vals) / len(vals))


# Paper-reported system-level averages (Figs 12/13 text) for validation.
PAPER_SYSTEM_SPEEDUP = {
    ("CiM-I", "iso-capacity"): {"8T-SRAM": 6.74, "3T-eDRAM": 6.59, "3T-FEMFET": 7.12},
    ("CiM-I", "iso-area"): {"8T-SRAM": 5.41, "3T-eDRAM": 4.63, "3T-FEMFET": 5.00},
    ("CiM-II", "iso-capacity"): {"8T-SRAM": 4.90, "3T-eDRAM": 4.78, "3T-FEMFET": 5.06},
    ("CiM-II", "iso-area"): {"8T-SRAM": 4.21, "3T-eDRAM": 3.85, "3T-FEMFET": 3.99},
}
PAPER_SYSTEM_ENERGY = {
    "CiM-I": {"8T-SRAM": 2.46, "3T-eDRAM": 2.52, "3T-FEMFET": 2.54},
    "CiM-II": {"8T-SRAM": 2.12, "3T-eDRAM": 2.14, "3T-FEMFET": 2.14},
}
