"""Ternary quantization primitives.

The paper computes dot products of *signed ternary* inputs and weights,
both in {-1, 0, +1}. This module provides:

  * threshold ternarization (TWN-style, Li et al. [8] in the paper) with a
    per-tensor or per-channel scale,
  * a straight-through estimator (STE) wrapper so ternary layers are
    trainable (quantization-aware training),
  * the differential (M1, M2) bitplane encoding used by the SiTe CiM cell
    (W=+1 -> M1=1,M2=0; W=-1 -> M1=0,M2=1; W=0 -> M1=M2=0), plus 8-way
    bit packing of each plane into uint8 words (the storage layout of the
    memory macro: two binary bit-cells per ternary weight).

All functions are pure and jit-safe.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Tuple

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Threshold ternarization
# ---------------------------------------------------------------------------

# TWN threshold factor: delta = 0.7 * E[|w|] (Li et al., "Ternary Weight
# Networks", Eq. 6). The paper builds on ternary DNNs trained this way.
# Configurable per layer stack via QuantConfig.threshold_factor.
TWN_THRESHOLD_FACTOR = 0.7


def ternary_threshold(x: jax.Array, axis=None, factor: float = TWN_THRESHOLD_FACTOR) -> jax.Array:
    """delta = factor * mean(|x|) (optionally per-channel along ``axis``)."""
    absx = jnp.abs(x)
    if axis is None:
        return factor * jnp.mean(absx)
    return factor * jnp.mean(absx, axis=axis, keepdims=True)


def ternarize(x: jax.Array, axis=None, factor: float = TWN_THRESHOLD_FACTOR) -> Tuple[jax.Array, jax.Array]:
    """Quantize ``x`` to {-1, 0, +1} * scale.

    Returns ``(t, scale)`` with ``t`` in {-1, 0, 1} (same dtype as x) and
    ``scale`` the optimal per-tensor/per-channel scale
    ``E[|x| : |x| > delta]`` (TWN closed form).
    """
    delta = ternary_threshold(x, axis=axis, factor=factor)
    mask = (jnp.abs(x) > delta).astype(x.dtype)
    t = jnp.sign(x) * mask
    num = jnp.sum(jnp.abs(x) * mask, axis=axis, keepdims=axis is not None)
    den = jnp.maximum(jnp.sum(mask, axis=axis, keepdims=axis is not None), 1.0)
    scale = (num / den).astype(x.dtype)
    return t, scale


def ternarize_fixed(x: jax.Array, delta) -> jax.Array:
    """Quantize with an externally supplied threshold (calibration path)."""
    return jnp.sign(x) * (jnp.abs(x) > delta).astype(x.dtype)


# ---------------------------------------------------------------------------
# Straight-through estimator
# ---------------------------------------------------------------------------

@jax.custom_vjp
def ste_ternarize(x: jax.Array) -> jax.Array:
    """Per-tensor scaled ternarization with identity (clipped) gradient."""
    t, scale = ternarize(x)
    return t * scale


def _ste_fwd(x):
    t, scale = ternarize(x)
    return t * scale, (x,)


def _ste_bwd(res, g):
    (x,) = res
    # Clipped STE: pass gradient where |x| <= 1 (standard BNN/TWN practice).
    return (g * (jnp.abs(x) <= 1.0).astype(g.dtype),)


ste_ternarize.defvjp(_ste_fwd, _ste_bwd)


@jax.custom_vjp
def ste_unit_ternarize(x: jax.Array) -> jax.Array:
    """Unscaled ternarization (outputs exactly {-1,0,1}) with STE gradient.

    Used for *activations* feeding a SiTe CiM array: the array consumes raw
    ternary symbols; the activation scale is folded into the layer output.
    """
    t, _ = ternarize(x)
    return t


def _steu_fwd(x):
    t, _ = ternarize(x)
    return t, (x,)


def _steu_bwd(res, g):
    (x,) = res
    return (g * (jnp.abs(x) <= 1.0).astype(g.dtype),)


ste_unit_ternarize.defvjp(_steu_fwd, _steu_bwd)


# ---------------------------------------------------------------------------
# Differential (M1, M2) encoding — the SiTe cell storage format
# ---------------------------------------------------------------------------

def to_bitplanes(t: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Ternary {-1,0,1} -> (M1, M2) uint8 bitplanes (Fig. 3(a) encoding)."""
    m1 = (t > 0).astype(jnp.uint8)
    m2 = (t < 0).astype(jnp.uint8)
    return m1, m2


def from_bitplanes(m1: jax.Array, m2: jax.Array, dtype=jnp.int8) -> jax.Array:
    """(M1, M2) -> ternary. (1,1) is an illegal cell state; decoded as 0
    the way a differential sense would cancel, but ``validate_bitplanes``
    exists for checking."""
    return (m1.astype(jnp.int32) - m2.astype(jnp.int32)).astype(dtype)


def validate_bitplanes(m1: jax.Array, m2: jax.Array) -> jax.Array:
    """True iff no cell stores the illegal (1,1) combination."""
    return jnp.logical_not(jnp.any((m1 == 1) & (m2 == 1)))


# ---------------------------------------------------------------------------
# 2-bit packed storage (8 ternary weights per (uint8, uint8) pair)
# ---------------------------------------------------------------------------

def pack_ternary(t: jax.Array, axis: int = 0) -> Tuple[jax.Array, jax.Array]:
    """Pack ternary values along ``axis`` (length divisible by 8) into two
    uint8 bitplane arrays of 1/8 the length: the memory-macro layout.
    """
    k = t.shape[axis]
    if k % 8 != 0:
        raise ValueError(f"pack axis length {k} not divisible by 8")
    m1, m2 = to_bitplanes(t)
    shifts = jnp.arange(8, dtype=jnp.uint8)

    def _pack(plane):
        moved = jnp.moveaxis(plane, axis, 0)
        grouped = moved.reshape((k // 8, 8) + moved.shape[1:])
        shift = shifts.reshape((1, 8) + (1,) * (grouped.ndim - 2))
        packed = jnp.sum(
            grouped.astype(jnp.uint32) << shift.astype(jnp.uint32), axis=1
        ).astype(jnp.uint8)
        return jnp.moveaxis(packed, 0, axis)

    return _pack(m1), _pack(m2)


# Canonical plane storage layouts (PackedPlanes.layout_version):
#   0 — legacy: pos/neg are two separate (..., K/8, N) byte planes.
#   1 — stream-friendly K-major plane-interleaved: ``pos`` holds one
#       (..., K/4, N) array whose byte-rows alternate pos/neg (row 2r is
#       the M1 byte-row r, row 2r+1 the M2 byte-row r) so one contiguous
#       DMA fetches both planes of a (k, j) tile; ``neg`` is an empty
#       (..., 0, N) placeholder keeping the pytree structure fixed.
PLANE_LAYOUT_LEGACY = 0
PLANE_LAYOUT_STREAM = 1


def interleave_planes(pos: jax.Array, neg: jax.Array) -> jax.Array:
    """(..., K/8, N) pos/neg byte planes -> one (..., K/4, N) array with
    alternating pos/neg byte-rows (layout version 1). Pure reshape —
    never a pad, so it is safe inside the no-uint8-pad traced contract."""
    if pos.shape != neg.shape:
        raise ValueError(f"plane shape mismatch: {pos.shape} vs {neg.shape}")
    stacked = jnp.stack([pos, neg], axis=-2)  # (..., K/8, 2, N)
    return stacked.reshape(pos.shape[:-2] + (2 * pos.shape[-2], pos.shape[-1]))


def deinterleave_planes(w_int: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Inverse of :func:`interleave_planes`: (..., K/4, N) -> two
    (..., K/8, N) byte planes."""
    rows = w_int.shape[-2]
    if rows % 2 != 0:
        raise ValueError(f"interleaved plane rows {rows} not even")
    split = w_int.reshape(w_int.shape[:-2] + (rows // 2, 2, w_int.shape[-1]))
    return split[..., 0, :], split[..., 1, :]


@functools.partial(
    jax.tree_util.register_dataclass,
    data_fields=("pos", "neg", "scale"),
    meta_fields=("k", "n", "layout_version"),
)
@dataclasses.dataclass(frozen=True)
class PackedPlanes:
    """Stored 2-bit bitplanes in the *canonical kernel layout*.

    ``pos``/``neg`` are the packed (M1, M2) uint8 planes, already padded
    along their last two dims to the packed-kernel tile granularity
    (``repro.core.execution.canonical_plane_layout``) so the serving
    jaxpr never re-pads or re-lays-out the weight side per step;
    ``scale`` is the per-output-channel weight scale over the *logical*
    channels. ``k``/``n`` record the logical contraction/output dims so
    results slice back exactly (pad plane cells are (0, 0) cells — inert
    under the a/b event-count semantics).

    ``layout_version`` selects the physical storage ordering (see
    ``PLANE_LAYOUT_*`` above). It defaults to the legacy two-plane
    layout, so planes stored before the field existed round-trip
    unchanged; :meth:`planes` and :meth:`interleaved` convert between
    views regardless of the stored version.

    Registered as a jax pytree (``k``/``n``/``layout_version`` are
    static metadata), so a tree of PackedPlanes flows through
    ``jax.device_put`` / ``dist.sharding.packed_specs`` unchanged.
    Iterating yields ``(pos, neg, scale)`` — the legacy ``pack_params``
    tuple shape, de-interleaved on demand for version-1 planes.

    Stacked-layer weights keep their leading layer dim on the planes;
    :meth:`layer` slices out one layer's planes for
    ``repro.api.execute_packed``.
    """

    pos: jax.Array
    neg: jax.Array
    scale: jax.Array
    k: int
    n: int
    layout_version: int = PLANE_LAYOUT_LEGACY

    def __iter__(self):
        return iter(self.planes() + (self.scale,))

    def planes(self) -> Tuple[jax.Array, jax.Array]:
        """The two separate (..., K/8, N) byte planes (legacy view) —
        a de-interleaving reshape when stored in layout version 1."""
        if self.layout_version == PLANE_LAYOUT_STREAM:
            return deinterleave_planes(self.pos)
        return self.pos, self.neg

    def interleaved(self) -> jax.Array:
        """The (..., K/4, N) plane-interleaved array the streaming decode
        kernel DMAs from — free for version-1 planes, an interleaving
        reshape for legacy ones."""
        if self.layout_version == PLANE_LAYOUT_STREAM:
            return self.pos
        return interleave_planes(self.pos, self.neg)

    def layer(self, i: int) -> "PackedPlanes":
        """One layer's (K/8, N) planes from a stacked (L, K/8, N) entry."""
        if self.pos.ndim < 3:
            raise ValueError(
                f"layer() needs stacked (L, K/8, N) planes, got {self.pos.shape}"
            )
        return PackedPlanes(
            pos=self.pos[i], neg=self.neg[i], scale=self.scale[i],
            k=self.k, n=self.n, layout_version=self.layout_version,
        )


def unpack_ternary(p1: jax.Array, p2: jax.Array, axis: int = 0, dtype=jnp.int8) -> jax.Array:
    """Inverse of :func:`pack_ternary`."""
    shifts = jnp.arange(8, dtype=jnp.uint8)

    def _unpack(packed):
        moved = jnp.moveaxis(packed, axis, 0)
        shift = shifts.reshape((1, 8) + (1,) * (moved.ndim - 1))
        bits = (moved[:, None].astype(jnp.uint32) >> shift.astype(jnp.uint32)) & 1
        flat = bits.reshape((moved.shape[0] * 8,) + moved.shape[1:])
        return jnp.moveaxis(flat, 0, axis)

    m1 = _unpack(p1)
    m2 = _unpack(p2)
    return from_bitplanes(m1, m2, dtype=dtype)


# ---------------------------------------------------------------------------
# Sparsity statistics (the paper leans on DNN sparsity for sense margin)
# ---------------------------------------------------------------------------

def ternary_sparsity(t: jax.Array) -> jax.Array:
    """Fraction of zeros — the quantity the paper's SM analysis relies on."""
    return jnp.mean((t == 0).astype(jnp.float32))


@functools.partial(jax.jit, static_argnames=("block",))
def block_overflow_rate(x_t: jax.Array, w_t: jax.Array, block: int = 16) -> jax.Array:
    """Fraction of (16-row block, output column) partial MACs whose event
    count a or b exceeds 8 — i.e. how often the 3-bit ADC clamp binds
    (paper: rare, due to sparsity; total error prob 3.1e-3)."""
    k = x_t.shape[-1]
    kb = k // block
    xb = x_t.reshape(x_t.shape[:-1] + (kb, block))
    wb = w_t.reshape((kb, block) + w_t.shape[1:])
    p = jnp.einsum("...ki,kin->...kn", xb, wb)
    m = jnp.einsum("...ki,kin->...kn", jnp.abs(xb), jnp.abs(wb))
    a = (m + p) / 2
    b = (m - p) / 2
    return jnp.mean(((a > 8) | (b > 8)).astype(jnp.float32))
