"""``repro.profile`` — measured-time observability for the hardware
model: profile → calibrate → replay (DESIGN.md §11).

  * :mod:`repro.profile.trace` — opt-in per-op trace capture around the
    jitted segments of the serving engine and the execution shim
    (``ContinuousBatcher(profile=...)`` / ``launch/serve --profile`` /
    :func:`set_profiler`), JSON-lines events;
  * :mod:`repro.profile.calibrate` — least-squares fit of the cost
    parameters (per-MAC latency scale, weight-DMA bandwidth, per-step
    fixed overhead) against measured kernel times, emitting a versioned
    :class:`CalibrationTable` that ``hw.project(calibration=...)`` and
    ``execution.autotune(calibration=...)`` consume;
  * :mod:`repro.profile.replay` — dependency-graph replay of a serving
    workload under predicted segment times: serve tok/s and p50/p99
    step latency for arbitrary (arch × ArraySpec × mesh × occupancy)
    points, validated by a predicted-vs-measured error bound
    (benchmarks/bench_calibrate.py → BENCH_calib.json).
"""
from repro.profile.calibrate import (  # noqa: F401
    CALIBRATION_VERSION,
    CalibrationTable,
    EngineFit,
    KernelFit,
    calibrate,
    fit_engines,
    fit_kernel,
    fit_kernels,
)
from repro.profile.replay import (  # noqa: F401
    Node,
    ReplayRequest,
    compare_to_measured,
    make_array_kernel_model,
    make_kernel_model,
    poisson_requests,
    predict_decode_step_us,
    replay_traffic_bench,
    requests_from_trace,
    requests_like_bench,
    simulate,
    table_from_traffic_row,
)
from repro.profile.trace import (  # noqa: F401
    TRACE_SCHEMA_VERSION,
    Profiler,
    TraceEvent,
    backend_block,
    current_profiler,
    event_from_json,
    read_trace,
    set_profiler,
    validate_event,
    wrap_step,
)
