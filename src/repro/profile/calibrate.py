"""Least-squares calibration of the hardware model against measured
kernel times — the "calibrate" leg of profile → calibrate → replay
(DESIGN.md §11).

``repro.hw`` costs the paper's arrays *analytically* (registered ns/pJ
parameters — Figs 9–13). This module fits the same cost structure to
what the execution shim actually measured on this host:

    wall_us ≈ fixed_us + us_per_mmac · (M·K·N / 1e6)
                       + us_per_mb   · (weight_bytes / 1e6)

per ``(exec_spec, shape_class)`` — ``fixed_us`` is the per-call fixed
overhead (dispatch + kernel launch), ``us_per_mmac`` the measured
per-MAC latency scale (the fitted analog of the array's
``t_cim_mac_ns``), and ``us_per_mb`` the measured plane/weight-DMA
bandwidth term (the fitted analog of the macro's weight-traffic model).
The fit is plain non-negative least squares over trace events
(:mod:`repro.profile.trace`); residuals ship with the table so a bad
fit is visible, never silent.

The result is a **versioned** :class:`CalibrationTable` that downstream
consumers accept in place of the analytic constants:

  * ``hw.project(..., calibration=table)`` adds a ``"calibrated"``
    block — the workload's GEMMs costed from the fitted parameters —
    beside the analytic projection;
  * ``execution.autotune(spec, calibration=table)`` installs the
    table's recorded tile winners instead of re-benchmarking;
  * ``profile.replay`` predicts serve tok/s and step latency from it.

Engine-level fits (:func:`fit_engines`) capture what the kernel model
cannot: the per-decode-step fixed overhead of the serving loop (host
bookkeeping + sampling + cache plumbing) per (arch, mesh), fitted
against the ``serve.decode_step`` events with the kernel model's
occupancy-dependent share subtracted.
"""
from __future__ import annotations

import dataclasses
import json
from pathlib import Path
from typing import Any, Callable, Dict, List, Mapping, Optional, Sequence, Tuple, Union

import numpy as np

from repro.profile.trace import TraceEvent

#: bump when the table layout changes; loaders reject unknown versions
CALIBRATION_VERSION = 1

#: decode/prefill boundary, mirrored from the execution API's dispatch
#: (kept in sync by tests/test_profile.py against execution.DECODE_M_MAX)
DECODE_M_MAX = 8


def kernel_key(exec_spec: str, shape_class: str) -> str:
    """The table key of one fitted kernel model."""
    return f"{exec_spec}|{shape_class}"


def engine_key(arch: str, mesh: str) -> str:
    """The table key of one fitted serving-step model."""
    return f"{arch}|{mesh}"


def mesh_tag(mesh: Optional[Mapping[str, int]]) -> str:
    """Canonical mesh description for table keys: ``"tp1"`` unsharded,
    else ``"tpN"`` from the 'model' axis."""
    if not mesh:
        return "tp1"
    return f"tp{int(mesh.get('model', 1))}"


@dataclasses.dataclass(frozen=True)
class KernelFit:
    """One fitted kernel cost model (see the module docstring for the
    functional form). ``bytes_per_weight`` records the storage format
    the events measured (2.0 for unpacked bf16/f32 operands, 0.25 for
    2-bit packed planes) so predictions can reconstruct weight bytes
    from (K, N). ``residual_pct`` is the median relative error of the
    fit over its own events — the honesty metric BENCH_calib.json
    surfaces."""

    fixed_us: float
    us_per_mmac: float
    us_per_mb: float
    bytes_per_weight: float
    n_events: int
    residual_pct: float

    def predict_us(self, m: int, k: int, n: int) -> float:
        """Predicted wall time of one (M, K) x (K, N) MAC."""
        macs = float(m) * k * n
        weight_bytes = float(k) * n * self.bytes_per_weight
        return (self.fixed_us + self.us_per_mmac * macs * 1e-6
                + self.us_per_mb * weight_bytes * 1e-6)


@dataclasses.dataclass(frozen=True)
class EngineFit:
    """Per-(arch, mesh) serving-step overheads fitted from engine
    events: ``decode_fixed_us`` is the measured fused-step cost with the
    kernel model's occupancy share removed; ``prefill_us`` the median
    batched-prefill wall."""

    arch: str
    mesh: str
    exec_spec: str
    decode_fixed_us: float
    prefill_us: float
    n_decode: int
    n_prefill: int
    residual_pct: float


@dataclasses.dataclass(frozen=True)
class CalibrationTable:
    """The versioned fit artifact (see module docstring). ``backend``
    records where the measurements ran (``"cpu"`` interpret-mode CI vs a
    real TPU) — fitted numbers are only meaningful on the backend that
    produced them, which is exactly the analytic/fitted split
    docs/calibration.md documents."""

    version: int
    backend: str
    default_spec: str
    kernels: Mapping[str, KernelFit]
    engines: Mapping[str, EngineFit] = dataclasses.field(default_factory=dict)
    tile_winners: Mapping[str, Mapping[str, Tuple[int, int, int]]] = (
        dataclasses.field(default_factory=dict))

    def predict_gemm_us(self, m: int, k: int, n: int,
                        spec: Optional[str] = None) -> float:
        """Predicted wall time of one GEMM under the fitted model for
        ``spec`` (default: the table's ``default_spec``), dispatched by
        shape class like the execution API."""
        spec = spec or self.default_spec
        cls = "decode" if m <= DECODE_M_MAX else "prefill"
        fit = self.kernels.get(kernel_key(spec, cls))
        if fit is None:
            # one-class sweeps still answer for the other class —
            # extrapolation, but a prediction with a residual story
            # beats a KeyError in a projection pipeline
            other = "prefill" if cls == "decode" else "decode"
            fit = self.kernels.get(kernel_key(spec, other))
        if fit is None:
            known = ", ".join(sorted(self.kernels))
            raise KeyError(f"no kernel fit for spec {spec!r} (known: {known})")
        return fit.predict_us(m, k, n)

    def engine_fit(self, arch: str, mesh: str = "tp1") -> EngineFit:
        fit = self.engines.get(engine_key(arch, mesh))
        if fit is None:
            known = ", ".join(sorted(self.engines))
            raise KeyError(
                f"no engine fit for {arch!r} on {mesh!r} (known: {known})")
        return fit

    # -- serialization ------------------------------------------------------

    def to_json(self) -> Dict[str, Any]:
        return {
            "version": self.version,
            "backend": self.backend,
            "default_spec": self.default_spec,
            "kernels": {k: dataclasses.asdict(v)
                        for k, v in sorted(self.kernels.items())},
            "engines": {k: dataclasses.asdict(v)
                        for k, v in sorted(self.engines.items())},
            "tile_winners": {
                s: {c: list(t) for c, t in sorted(classes.items())}
                for s, classes in sorted(self.tile_winners.items())
            },
        }

    @classmethod
    def from_json(cls, d: Mapping[str, Any]) -> "CalibrationTable":
        v = d.get("version")
        if v != CALIBRATION_VERSION:
            raise ValueError(
                f"calibration table version {v!r} != {CALIBRATION_VERSION} "
                f"(re-fit with this tree)")
        return cls(
            version=CALIBRATION_VERSION,
            backend=str(d["backend"]),
            default_spec=str(d["default_spec"]),
            kernels={k: KernelFit(**f) for k, f in d["kernels"].items()},
            engines={k: EngineFit(**f) for k, f in d.get("engines", {}).items()},
            tile_winners={
                s: {c: tuple(int(x) for x in t) for c, t in classes.items()}
                for s, classes in d.get("tile_winners", {}).items()
            },
        )

    def save(self, path: Union[str, Path]) -> None:
        Path(path).write_text(json.dumps(self.to_json(), indent=2,
                                         sort_keys=True) + "\n")

    @classmethod
    def load(cls, path: Union[str, Path]) -> "CalibrationTable":
        return cls.from_json(json.loads(Path(path).read_text()))


# ---------------------------------------------------------------------------
# Fitting
# ---------------------------------------------------------------------------


def _nnls(rows: Sequence[Sequence[float]], y: Sequence[float]) -> List[float]:
    """Tiny non-negative least squares: solve, clamp negative
    coefficients to zero, refit the surviving columns (repeat until
    stable). Good enough for a 3-parameter cost model; keeps fitted
    rates physical (a negative per-MAC latency is a fit artifact, not a
    speedup)."""
    ncol = len(rows[0])
    active = list(range(ncol))
    coef = [0.0] * ncol
    for _ in range(ncol + 1):
        a = [[row[j] for j in active] for row in rows]
        sol, *_ = np.linalg.lstsq(a, list(y), rcond=None)
        neg = [j for j, v in zip(active, sol) if v < 0]
        for j, v in zip(active, sol):
            coef[j] = float(v)
        if not neg:
            break
        for j in neg:
            coef[j] = 0.0
        active = [j for j in active if j not in neg]
        if not active:
            break
    return coef


def _event_features(e: TraceEvent) -> Optional[Tuple[float, float, float]]:
    """(macs, weight_bytes, wall_us) of one kernel event, or None when
    the event lacks the kernel meta."""
    meta = e.meta
    if "m" not in meta or "k" not in meta or "n" not in meta:
        return None
    macs = float(meta["m"]) * meta["k"] * meta["n"]
    wb = float(meta.get("weight_bytes", 2.0 * meta["k"] * meta["n"]))
    return macs, wb, float(e.wall_us)


def fit_kernel(events: Sequence[TraceEvent]) -> KernelFit:
    """Fit one kernel cost model to a homogeneous event group (same
    exec_spec and shape class)."""
    feats = [f for f in (_event_features(e) for e in events) if f is not None]
    if not feats:
        raise ValueError("no kernel events with m/k/n meta to fit")
    rows = [[1.0, macs * 1e-6, wb * 1e-6] for macs, wb, _ in feats]
    y = [wall for _, _, wall in feats]
    fixed, per_mmac, per_mb = _nnls(rows, y)
    fixed = max(fixed, 0.0)
    preds = [fixed + per_mmac * r[1] + per_mb * r[2] for r in rows]
    resid = [abs(p - w) / max(w, 1e-9) for p, w in zip(preds, y)]
    # bytes-per-weight is a property of the storage format: recover it
    # from the first event's (weight_bytes, k*n)
    first = next(e.meta for e in events
                 if _event_features(e) is not None)
    bpw = float(first.get("weight_bytes", 2.0 * first["k"] * first["n"]))
    bpw /= float(first["k"]) * first["n"]
    return KernelFit(
        fixed_us=round(fixed, 4),
        us_per_mmac=round(per_mmac, 6),
        us_per_mb=round(per_mb, 6),
        bytes_per_weight=bpw,
        n_events=len(feats),
        residual_pct=round(100.0 * float(np.median(resid)), 2),
    )


def fit_kernels(events: Sequence[TraceEvent]) -> Dict[str, KernelFit]:
    """Group kernel-level events (``execution.*`` entry points) by
    (exec_spec, shape_class) and fit each group."""
    groups: Dict[str, List[TraceEvent]] = {}
    for e in events:
        if not e.entry_point.startswith("execution."):
            continue
        groups.setdefault(kernel_key(e.exec_spec, e.shape_class), []).append(e)
    return {k: fit_kernel(v) for k, v in sorted(groups.items())}


def fit_engines(
    events: Sequence[TraceEvent],
    kernel_model: Optional[Callable[[str, int], float]] = None,
) -> Dict[str, EngineFit]:
    """Fit per-(arch, mesh) serving-step overheads from engine events.

    ``kernel_model(arch, occupancy) -> us`` supplies the model-side MAC
    share of one fused decode step (see
    :func:`repro.profile.replay.make_kernel_model`); the fitted
    ``decode_fixed_us`` is the median residual after subtracting it.
    Without a kernel model the whole measured step is fixed overhead —
    still a valid (occupancy-insensitive) replay basis.
    """
    decode: Dict[str, List[TraceEvent]] = {}
    prefill: Dict[str, List[TraceEvent]] = {}
    for e in events:
        arch = str(e.meta.get("arch", "?"))
        key = engine_key(arch, mesh_tag(e.mesh))
        if e.entry_point == "serve.decode_step":
            decode.setdefault(key, []).append(e)
        elif e.entry_point == "serve.prefill":
            prefill.setdefault(key, []).append(e)
    out: Dict[str, EngineFit] = {}
    for key in sorted(set(decode) | set(prefill)):
        dev = decode.get(key, [])
        pev = prefill.get(key, [])
        arch, mesh = key.rsplit("|", 1)
        spec = dev[0].exec_spec if dev else (pev[0].exec_spec if pev else "?")
        fixed = 0.0
        resid_pct = 0.0
        if dev:
            kern = [
                kernel_model(arch, int(e.meta.get("occupancy", 1)))
                if kernel_model is not None else 0.0
                for e in dev
            ]
            fixed = max(0.0, float(np.median(
                [e.wall_us - k for e, k in zip(dev, kern)])))
            preds = [fixed + k for k in kern]
            resid = [abs(p - e.wall_us) / max(e.wall_us, 1e-9)
                     for p, e in zip(preds, dev)]
            resid_pct = round(100.0 * float(np.median(resid)), 2)
        pre = float(np.median([e.wall_us for e in pev])) if pev else 0.0
        out[key] = EngineFit(
            arch=arch, mesh=mesh, exec_spec=spec,
            decode_fixed_us=round(fixed, 2),
            prefill_us=round(pre, 2),
            n_decode=len(dev), n_prefill=len(pev),
            residual_pct=resid_pct,
        )
    return out


def calibrate(
    events: Sequence[TraceEvent],
    *,
    backend: str = "cpu",
    default_spec: Optional[str] = None,
    kernel_model: Optional[Callable[[str, int], float]] = None,
    tile_winners: Optional[Mapping[str, Mapping[str, Tuple[int, int, int]]]] = None,
) -> CalibrationTable:
    """Build a :class:`CalibrationTable` from a trace: kernel fits from
    the ``execution.*`` events, engine fits from the ``serve.*`` events.
    ``default_spec`` defaults to the first fitted spec name."""
    kernels = fit_kernels(events)
    if default_spec is None:
        specs = sorted({k.rsplit("|", 1)[0] for k in kernels})
        default_spec = specs[0] if specs else "exact/jnp/none"
    engines = fit_engines(events, kernel_model)
    return CalibrationTable(
        version=CALIBRATION_VERSION,
        backend=backend,
        default_spec=default_spec,
        kernels=kernels,
        engines=engines,
        tile_winners=dict(tile_winners or {}),
    )
