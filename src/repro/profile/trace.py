"""Per-op trace capture — the "profile" leg of profile → calibrate →
replay (DESIGN.md §11).

The serving engine and the execution shim are instrumented with opt-in
timing hooks that record one :class:`TraceEvent` per jitted segment —
the fused decode step, the batched prefill, the offline weight prepare
(``ContinuousBatcher(profile=...)`` / ``launch/serve --profile``), and
every *eager* ``execute``/``execute_packed`` call while a profiler is
installed (:func:`set_profiler`). Events go to an in-memory list and,
when the profiler is path-backed, to a JSON-lines trace file
(:func:`read_trace` round-trips it).

Measuring device wall time requires blocking the host — exactly the
host-sync class the analysis lint polices (DESIGN.md §10). The
discipline here:

  * profiling is **opt-in**: with no profiler, :func:`wrap_step`
    returns the step function **unchanged** (the same object — bit- and
    jaxpr-identical by construction; the
    ``profile.step_instrumentation.disabled`` contract below pins it),
    and the execution shim's sink check is one ``None`` comparison;
  * the profiler's syncs happen **outside** the jit boundary and are
    never counted in the engine's ``host_syncs`` discipline stat;
  * every deliberate sync carries the standard justification marker.

Event schema (JSON-lines; ``v`` is :data:`TRACE_SCHEMA_VERSION`)::

    {"v": 1, "entry_point": "serve.decode_step", "exec_spec": "mode:off",
     "shape_class": "decode", "mesh": null, "wall_us": 812.4,
     "dispatch_us": 101.2, "meta": {"arch": "smollm-135m", "step": 3,
     "occupancy": 2, ...}}

``wall_us`` is host call → device completion (includes dispatch);
``dispatch_us`` is the host time to *enqueue* the work — their
difference isolates what the profiler's own sync added to the step, so
fused-step analyses can subtract it.
"""
from __future__ import annotations

import dataclasses
import json
import time
from pathlib import Path
from typing import Any, Callable, Dict, List, Mapping, Optional, Union

import jax

#: bump when the event schema changes; readers reject unknown versions
TRACE_SCHEMA_VERSION = 1

#: the fields every event must carry (the ISSUE-level contract)
REQUIRED_FIELDS = ("entry_point", "exec_spec", "shape_class", "mesh", "wall_us")


@dataclasses.dataclass(frozen=True)
class TraceEvent:
    """One timed jitted segment.

    entry_point: dotted hook name — ``serve.decode_step``,
      ``serve.prefill``, ``serve.prepare``, ``execution.execute``,
      ``execution.execute_packed``.
    exec_spec:   the CiM execution spec name (``"blocked/jnp/none"``) or
      a quant-mode tag (``"mode:off"``) when the engine serves without
      an explicit spec.
    shape_class: the dispatch class the segment ran in (``"decode"`` /
      ``"prefill"`` — DESIGN.md §9) or a hook-specific tag
      (``"prepare"``).
    mesh:        ``{axis: size}`` for TP serving, ``None`` unsharded.
    wall_us:     host call to device completion (includes dispatch and
      the profiler's own sync).
    dispatch_us: host time to enqueue (call returned, device still
      running) — ``wall_us - dispatch_us`` is pure device+sync time.
    meta:        hook-specific payload (m/k/n/macs/weight_bytes for
      kernel events; arch/step/occupancy for engine events).
    """

    entry_point: str
    exec_spec: str
    shape_class: str
    mesh: Optional[Mapping[str, int]]
    wall_us: float
    dispatch_us: float = 0.0
    meta: Mapping[str, Any] = dataclasses.field(default_factory=dict)

    def to_json(self) -> Dict[str, Any]:
        return {
            "v": TRACE_SCHEMA_VERSION,
            "entry_point": self.entry_point,
            "exec_spec": self.exec_spec,
            "shape_class": self.shape_class,
            "mesh": dict(self.mesh) if self.mesh is not None else None,
            "wall_us": self.wall_us,
            "dispatch_us": self.dispatch_us,
            "meta": dict(self.meta),
        }


def validate_event(d: Mapping[str, Any]) -> None:
    """Raise ``ValueError`` unless ``d`` is a well-formed serialized
    event of the current schema version."""
    if not isinstance(d, Mapping):
        raise ValueError(f"trace event must be an object, got {type(d).__name__}")
    v = d.get("v")
    if v != TRACE_SCHEMA_VERSION:
        raise ValueError(
            f"trace schema version {v!r} != {TRACE_SCHEMA_VERSION} "
            f"(re-capture the trace with this tree)"
        )
    for field in REQUIRED_FIELDS:
        if field not in d:
            raise ValueError(f"trace event missing required field {field!r}: {d}")
    for field in ("entry_point", "exec_spec", "shape_class"):
        if not d[field] or not isinstance(d[field], str):
            raise ValueError(f"trace event field {field!r} must be a "
                             f"non-empty string, got {d[field]!r}")
    if d["mesh"] is not None and not isinstance(d["mesh"], Mapping):
        raise ValueError(f"trace event mesh must be null or an object: {d['mesh']!r}")
    wall = d["wall_us"]
    if not isinstance(wall, (int, float)) or wall < 0:
        raise ValueError(f"trace event wall_us must be >= 0, got {wall!r}")


def event_from_json(d: Mapping[str, Any]) -> TraceEvent:
    validate_event(d)
    return TraceEvent(
        entry_point=d["entry_point"],
        exec_spec=d["exec_spec"],
        shape_class=d["shape_class"],
        mesh=dict(d["mesh"]) if d["mesh"] is not None else None,
        wall_us=float(d["wall_us"]),
        dispatch_us=float(d.get("dispatch_us", 0.0)),
        meta=dict(d.get("meta", {})),
    )


class Profiler:
    """Collects :class:`TraceEvent`\\ s; optionally streams them as
    JSON-lines to ``path`` (append mode, flushed per event so a crashed
    run keeps its trace). Use as a context manager, or call
    :meth:`close` when done with a path-backed profiler."""

    def __init__(self, path: Optional[Union[str, Path]] = None):
        self.path = Path(path) if path is not None else None
        self.events: List[TraceEvent] = []
        self._fh = None

    def record(self, event: Optional[TraceEvent] = None, **kw) -> TraceEvent:
        """Append one event (an explicit :class:`TraceEvent`, or the
        constructor kwargs)."""
        if event is None:
            event = TraceEvent(**kw)
        elif kw:
            raise ValueError("pass an event or kwargs, not both")
        self.events.append(event)
        if self.path is not None:
            if self._fh is None:
                self._fh = open(self.path, "a")
            self._fh.write(json.dumps(event.to_json(), sort_keys=True) + "\n")
            self._fh.flush()
        return event

    def close(self) -> None:
        if self._fh is not None:
            self._fh.close()
            self._fh = None

    def __enter__(self) -> "Profiler":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def read_trace(path: Union[str, Path]) -> List[TraceEvent]:
    """Load and validate a JSON-lines trace file."""
    events: List[TraceEvent] = []
    for i, line in enumerate(Path(path).read_text().splitlines(), 1):
        line = line.strip()
        if not line:
            continue
        try:
            d = json.loads(line)
        except json.JSONDecodeError as e:
            raise ValueError(f"{path}:{i}: not JSON: {e}") from None
        events.append(event_from_json(d))
    return events


# ---------------------------------------------------------------------------
# The global profiler hook (eager execution-shim calls)
# ---------------------------------------------------------------------------

_ACTIVE: Optional[Profiler] = None


def set_profiler(p: Optional[Profiler]) -> Optional[Profiler]:
    """Install ``p`` as the process-wide profiler (``None`` uninstalls)
    and wire the execution shim's sink to it: every *eager*
    ``execute``/``execute_packed`` call is timed while installed (calls
    under a jit trace are never timed — timing a tracer is meaningless
    and would poison the jaxpr). Returns the previous profiler so
    callers can restore it."""
    global _ACTIVE
    prev = _ACTIVE
    _ACTIVE = p
    from repro.core import execution

    execution.set_profile_sink(p.record if p is not None else None)
    return prev


def current_profiler() -> Optional[Profiler]:
    """The installed process-wide profiler, or None."""
    return _ACTIVE


def backend_block() -> Dict[str, Any]:
    """Measurement provenance: the ``"backend"`` block every
    BENCH_*.json embeds so validators know *where* numbers came from.
    ``interpret`` is the load-bearing bit — off-TPU the Pallas kernels
    run through the interpreter (the repo's ``interpret=not _on_tpu()``
    convention), where timings prove bit-exactness and plumbing but
    never compiled speed, so validators must refuse any compiled-
    speedup claim made under it."""
    dev = jax.devices()[0]
    return {
        "platform": jax.default_backend(),
        "device_kind": getattr(dev, "device_kind", "unknown"),
        "device_count": jax.device_count(),
        "interpret": jax.default_backend() != "tpu",
    }


# ---------------------------------------------------------------------------
# Step instrumentation (the serving engine's hook)
# ---------------------------------------------------------------------------


def wrap_step(
    fn: Callable,
    profiler: Optional[Profiler],
    entry_point: str,
    *,
    exec_spec: str = "mode:off",
    shape_class: str = "decode",
    mesh: Optional[Mapping[str, int]] = None,
    meta_fn: Optional[Callable[..., Mapping[str, Any]]] = None,
) -> Callable:
    """Wrap a jitted step function with wall-time capture.

    With ``profiler=None`` this returns ``fn`` **unchanged** — the same
    object, so the disabled path is bit- and jaxpr-identical to an
    uninstrumented engine (pinned by the
    ``profile.step_instrumentation.disabled`` contract and
    tests/test_profile.py). With a profiler, the wrapper times the call,
    blocks on the outputs (outside the jit boundary — the jaxpr is
    untouched), and records one event; ``meta_fn(*args)`` supplies the
    hook-specific payload at record time.
    """
    if profiler is None:
        return fn

    def timed(*args):
        t0 = time.perf_counter()
        out = fn(*args)
        t1 = time.perf_counter()
        # analysis: host-sync ok — profiler wall-time capture, opt-in and
        # outside the jitted step (never on the disabled path)
        jax.block_until_ready(out)
        t2 = time.perf_counter()
        profiler.record(TraceEvent(
            entry_point=entry_point,
            exec_spec=exec_spec,
            shape_class=shape_class,
            mesh=mesh,
            wall_us=(t2 - t0) * 1e6,
            dispatch_us=(t1 - t0) * 1e6,
            meta=dict(meta_fn(*args)) if meta_fn is not None else {},
        ))
        return out

    return timed


# ---------------------------------------------------------------------------
# Tracing contract (repro.analysis — DESIGN.md §10/§11)
#
# Instrumentation must be free when disabled: wrap_step(fn, None) IS fn,
# so the fused decode step traced through the profile layer has the same
# equation count as the raw step (invariance over the `wrapped` axis)
# and still zero host callbacks. A future wrapper that traced timing
# logic into the step would break both.
# ---------------------------------------------------------------------------

from repro.analysis.contracts import (  # noqa: E402
    TraceContract,
    register_trace_contract,
)


def _instrumented_step_point():
    """The production fused decode step, traced raw (``wrapped=0``) and
    through the disabled profile wrapper (``wrapped=1``) — the auditor
    requires one equation count across both."""

    def build(wrapped: int = 0):
        import jax.numpy as jnp

        from repro.models import transformer as T
        from repro.models.layers import QuantConfig
        from repro.models.registry import get_config
        from repro.serve.engine import fused_decode_fn

        n_slots = 3
        cfg = get_config("smollm-135m", smoke=True).replace(
            quant=QuantConfig(mode="off"))
        params = T.init_params(jax.random.PRNGKey(0), cfg)
        caches = T.init_caches(cfg, n_slots, 32)
        step = fused_decode_fn(cfg)
        if wrapped:
            step = wrap_step(step, None, "serve.decode_step")
        args = (params, jnp.zeros((n_slots, 1), jnp.int32), caches,
                jnp.zeros((n_slots,), jnp.int32),
                jnp.zeros((n_slots,), jnp.int32), jax.random.PRNGKey(1))
        return step, args

    return build


register_trace_contract(
    "profile.step_instrumentation.disabled",
    _instrumented_step_point(),
    TraceContract(max_host_callbacks=0),
    axes={"wrapped": (0, 1)},
)
