"""Trace replay — the "replay" leg of profile → calibrate → replay
(DESIGN.md §11).

A discrete-event simulator that mirrors the ``ContinuousBatcher``'s
slot discipline (fill slots → batched left-padded prefill → fused
decode step over all slots, until the queue drains) and advances a
simulated clock by **predicted** segment times from a
:class:`~repro.profile.calibrate.CalibrationTable` — so serve tok/s and
p50/p99 step latency can be projected for arbitrary
(arch × ArraySpec × mesh × slot-occupancy) points without running the
model.

The replay builds an explicit dependency graph (:class:`Node`): every
prefill/decode node depends on the nodes whose cache state it consumes.
In the current single-stream engine the graph is a chain — kept
explicit because the node set is what a multi-stream scheduler would
re-order, and because the graph is the honest record of *why* the
predicted wall is the sum it is.

Step-time model::

    decode_step_us(occupancy) = engines[arch|mesh].decode_fixed_us
                              + Σ_gemms kernel_fit.predict_us(occupancy, k, n)
    prefill_us                = engines[arch|mesh].prefill_us

With ``array=`` (an :class:`repro.hw.ArraySpec`), the kernel share is
costed by the **analytic** hardware model instead
(:func:`repro.hw.macro.layer_cost` on the paper's macro) while the
fitted per-step fixed overhead is kept — projecting what this host's
serving loop would sustain if the MACs ran inside CiM arrays. That is
the bridge between the measured engine and the paper's Figs 12/13
claims.

Validated (tests/test_profile.py + benchmarks/bench_calibrate.py) by a
predicted-vs-measured error bound on the decode-step p50 of a holdout
profiled run.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

from repro.profile.calibrate import CalibrationTable, EngineFit, mesh_tag
from repro.profile.trace import TraceEvent


@dataclasses.dataclass(frozen=True)
class ReplayRequest:
    """One simulated request: the lengths drive the work, and
    ``arrival_us`` (0 = offered up front, the offline-replay default)
    drives *when* the simulated engine may admit it — the traffic-model
    axis shared with benchmarks/bench_traffic.py."""

    rid: int
    prompt_len: int
    max_new: int
    arrival_us: float = 0.0


@dataclasses.dataclass(frozen=True)
class Node:
    """One node of the replay dependency graph."""

    nid: int
    kind: str                  # "prefill" | "decode"
    deps: Tuple[int, ...]      # node ids whose outputs this node consumes
    us: float                  # predicted duration
    start_us: float            # max(end of deps)
    occupancy: int             # active slots (decode) / filled slots (prefill)

    @property
    def end_us(self) -> float:
        return self.start_us + self.us


def requests_like_bench(vocab: int, n_requests: int, max_new: int
                        ) -> List[ReplayRequest]:
    """The deterministic ragged mix benchmarks/bench_serve.py submits,
    reduced to its lengths (prompt 1–4 tokens, ragged max_new)."""
    return [ReplayRequest(i, 1 + i % 4, 2 + i % max_new)
            for i in range(n_requests)]


def requests_from_trace(events: Sequence[TraceEvent]) -> List[ReplayRequest]:
    """Reconstruct the request mix a profiled serve run processed, from
    its prefill events' ``prompts`` meta (recorded by the engine hook)."""
    out: List[ReplayRequest] = []
    for e in events:
        if e.entry_point != "serve.prefill":
            continue
        for rid, p_len, max_new in e.meta.get("prompts", []):
            out.append(ReplayRequest(int(rid), int(p_len), int(max_new)))
    return sorted(out, key=lambda r: r.rid)


def poisson_requests(
    rate_rps: float,
    seed: int = 0,
    n_requests: int = 16,
    prompt_len_max: int = 4,
    max_new: int = 8,
) -> List[ReplayRequest]:
    """Synthetic Poisson traffic: ``n_requests`` arrivals with
    exponential inter-arrival gaps at ``rate_rps`` requests/second,
    prompt lengths uniform in [1, prompt_len_max] and ``max_new``
    uniform in [2, max_new] — the same ragged family as
    :func:`requests_like_bench`, but with a real arrival process.

    Deterministic in ``seed`` (one ``numpy`` Generator drives gaps and
    lengths), so the *same* workload can be replayed through
    :func:`simulate` for capacity planning and driven through the real
    front door by ``benchmarks/bench_traffic.py`` — closing the loop
    between predicted and measured load points (DESIGN.md §12)."""
    if rate_rps <= 0:
        raise ValueError(f"rate_rps must be > 0, got {rate_rps}")
    if max_new < 2:
        raise ValueError(f"max_new must be >= 2, got {max_new}")
    rng = np.random.default_rng(seed)
    gaps_us = rng.exponential(1e6 / rate_rps, size=n_requests)
    arrivals = np.cumsum(gaps_us)
    return [
        ReplayRequest(
            rid=i,
            prompt_len=int(rng.integers(1, prompt_len_max + 1)),
            max_new=int(rng.integers(2, max_new + 1)),
            arrival_us=float(arrivals[i]),
        )
        for i in range(n_requests)
    ]


def _next_pow2(n: int, lo: int = 4) -> int:
    v = lo
    while v < n:
        v *= 2
    return v


def make_kernel_model(
    table: CalibrationTable,
    cfgs: Mapping[str, object],
    spec: Optional[str] = None,
) -> Callable[[str, int], float]:
    """``(arch, occupancy) -> us``: the fitted kernel model summed over
    the arch's weight-bearing decode GEMMs at M = occupancy
    (``repro.hw.workload`` owns the GEMM enumeration). Unknown archs
    cost 0 — the engine fit then absorbs everything into the fixed
    term."""
    from repro.hw.workload import workload_layers
    from repro.models.registry import ShapeCell

    cache: Dict[Tuple[str, int], float] = {}

    def kernel_us(arch: str, occupancy: int) -> float:
        key = (arch, occupancy)
        if key not in cache:
            cfg = cfgs.get(arch)
            if cfg is None:
                cache[key] = 0.0
            else:
                shape = ShapeCell("replay_decode", "decode", 1,
                                  max(1, occupancy))
                cache[key] = sum(
                    table.predict_gemm_us(layer.m, layer.k, layer.n, spec)
                    * count
                    for layer, count in workload_layers(cfg, shape)
                )
        return cache[key]

    return kernel_us


def make_array_kernel_model(
    cfgs: Mapping[str, object],
    array,
    macro=None,
) -> Callable[[str, int], float]:
    """Analytic variant of :func:`make_kernel_model`: cost the decode
    GEMMs on a CiM ``array`` through the paper's macro model instead of
    the fitted host kernels (the ArraySpec axis of the replay space)."""
    from repro.hw.array import array_cost
    from repro.hw.macro import PAPER_MACRO, layer_cost
    from repro.hw.workload import workload_layers
    from repro.models.registry import ShapeCell

    macro = macro or PAPER_MACRO
    cost = array_cost(array)
    cache: Dict[Tuple[str, int], float] = {}

    def kernel_us(arch: str, occupancy: int) -> float:
        key = (arch, occupancy)
        if key not in cache:
            cfg = cfgs.get(arch)
            if cfg is None:
                cache[key] = 0.0
            else:
                shape = ShapeCell("replay_decode", "decode", 1,
                                  max(1, occupancy))
                t_ns = sum(
                    layer_cost(layer, array, macro.n_arrays, macro,
                               cost=cost)[0] * count
                    for layer, count in workload_layers(cfg, shape)
                )
                cache[key] = t_ns * 1e-3
        return cache[key]

    return kernel_us


def predict_decode_step_us(
    table: CalibrationTable,
    arch: str,
    occupancy: int,
    *,
    mesh: str = "tp1",
    kernel_model: Optional[Callable[[str, int], float]] = None,
) -> float:
    """Predicted wall time of one fused decode step at ``occupancy``
    active slots: the fitted per-step fixed overhead plus the kernel
    model's share (0 when no kernel model is supplied — the fixed term
    then already contains the median MAC cost it was fitted with)."""
    fit = table.engine_fit(arch, mesh)
    kern = kernel_model(arch, occupancy) if kernel_model is not None else 0.0
    return fit.decode_fixed_us + kern


def simulate(
    table: CalibrationTable,
    arch: str,
    requests: Sequence[ReplayRequest],
    *,
    n_slots: int = 4,
    s_max: int = 64,
    mesh: str = "tp1",
    kernel_model: Optional[Callable[[str, int], float]] = None,
) -> Dict[str, object]:
    """Replay one continuous-batching workload through the predicted
    clock. Mirrors ``ContinuousBatcher``'s host discipline exactly
    (batched pow-2-bucketed prefill, fused step over active slots,
    immediate refill, the s_max - 1 capacity cutoff) so predicted step
    *counts* match the engine's and only the *durations* come from the
    calibration.

    Requests with a nonzero ``arrival_us`` (e.g. from
    :func:`poisson_requests`) are admitted only once the simulated
    clock reaches them — an idle engine fast-forwards to the next
    arrival — so the replay covers *traffic-shaped* load points, not
    just offered-up-front batches. With all arrivals at 0 (the
    default) the behavior is the original offline replay, unchanged.

    Returns predicted ``tok_s``, ``p50_step_us`` / ``p99_step_us`` over
    the decode steps, totals, and the dependency ``graph`` (the Node
    list, JSON-ready)."""
    fit = table.engine_fit(arch, mesh)
    # stable sort: equal arrivals (the offline all-zero case) keep
    # submission order, so pre-arrival replays are byte-identical
    queue = sorted(requests, key=lambda r: r.arrival_us)
    slots: List[Optional[ReplayRequest]] = [None] * n_slots
    produced: List[int] = [0] * n_slots
    pos: List[int] = [0] * n_slots

    nodes: List[Node] = []
    last_nid: Optional[int] = None  # chain dep: the node owning cache state
    step_durs: List[float] = []
    ttfts: List[float] = []         # per request: arrival -> first token
    tokens = 0
    clock = 0.0

    def _finish(s: int) -> None:
        slots[s] = None

    while queue or any(r is not None for r in slots):
        # -- fill slots + batched prefill (engine: _fill_slots_fused) --
        # only *arrived* requests are admissible at the current clock
        newly = []
        for s in range(n_slots):
            if slots[s] is None and queue and queue[0].arrival_us <= clock:
                slots[s] = queue.pop(0)
                newly.append(s)
        if newly:
            max_len = max(slots[s].prompt_len for s in newly)
            s_pad = _next_pow2(max_len)
            if s_pad >= s_max:
                s_pad = max_len
            deps = (last_nid,) if last_nid is not None else ()
            start = max((nodes[d].end_us for d in deps), default=clock)
            start = max(start, clock)
            node = Node(len(nodes), "prefill", deps, fit.prefill_us,
                        start, len(newly))
            nodes.append(node)
            last_nid = node.nid
            clock = node.end_us
            for s in newly:
                produced[s] = 1           # prefill samples the first token
                tokens += 1
                pos[s] = s_pad
                ttfts.append(node.end_us - slots[s].arrival_us)
                if produced[s] >= slots[s].max_new:
                    _finish(s)
        active = [s for s in range(n_slots) if slots[s] is not None]
        if not active:
            if queue:
                # idle engine waiting on traffic: fast-forward to the
                # next arrival (never backwards)
                clock = max(clock, queue[0].arrival_us)
                continue
            break
        # -- one fused decode step (engine: _step_fused) ---------------
        occ = len(active)
        us = predict_decode_step_us(table, arch, occ, mesh=mesh,
                                    kernel_model=kernel_model)
        deps = (last_nid,) if last_nid is not None else ()
        start = max((nodes[d].end_us for d in deps), default=clock)
        node = Node(len(nodes), "decode", deps, us, start, occ)
        nodes.append(node)
        last_nid = node.nid
        clock = node.end_us
        step_durs.append(us)
        for s in active:
            produced[s] += 1
            tokens += 1
            pos[s] += 1
            if produced[s] >= slots[s].max_new or pos[s] >= s_max - 1:
                _finish(s)

    total_us = max((n.end_us for n in nodes), default=0.0)
    return {
        "arch": arch,
        "mesh": mesh,
        "n_slots": n_slots,
        "s_max": s_max,
        "tokens": tokens,
        "decode_steps": len(step_durs),
        "prefill_batches": sum(1 for n in nodes if n.kind == "prefill"),
        "total_us": round(total_us, 2),
        "tok_s": round(tokens / max(total_us * 1e-6, 1e-12), 2),
        "p50_step_us": round(float(np.percentile(step_durs, 50)), 2)
        if step_durs else 0.0,
        "p99_step_us": round(float(np.percentile(step_durs, 99)), 2)
        if step_durs else 0.0,
        "ttft_p50_us": round(float(np.percentile(ttfts, 50)), 2)
        if ttfts else 0.0,
        "ttft_p99_us": round(float(np.percentile(ttfts, 99)), 2)
        if ttfts else 0.0,
        "graph": [dataclasses.asdict(n) for n in nodes],
    }


def compare_to_measured(
    predicted: Mapping[str, object],
    events,
) -> Dict[str, float]:
    """Predicted-vs-measured validation.

    ``events`` is either a profiled run's trace events (the original
    path: relative error of the p50 decode-step time — the bound
    BENCH_calib.json gates on — plus tok/s on the same event-time
    basis) or **one committed BENCH_traffic.json row** (a mapping with
    ``goodput_tok_s``): then the comparison is goodput and TTFT-p50 of
    the replayed Poisson workload against what the live front door
    measured — the loop :func:`replay_traffic_bench` closes and
    ``benchmarks/bench_traffic.py`` gates under its stated error bound.
    """
    if isinstance(events, Mapping) and "goodput_tok_s" in events:
        row = events
        meas_good = float(row["goodput_tok_s"])
        meas_ttft = float(row["ttft_us"]["p50"])
        pred_good = float(predicted["tok_s"])
        pred_ttft = float(predicted.get("ttft_p50_us", 0.0))
        return {
            "measured_goodput_tok_s": round(meas_good, 2),
            "predicted_goodput_tok_s": round(pred_good, 2),
            "goodput_error_pct": round(
                100.0 * abs(pred_good - meas_good) / max(meas_good, 1e-9), 2),
            "measured_ttft_p50_us": round(meas_ttft, 2),
            "predicted_ttft_p50_us": round(pred_ttft, 2),
            "ttft_error_pct": round(
                100.0 * abs(pred_ttft - meas_ttft) / max(meas_ttft, 1e-9), 2),
            "measured_tokens": int(row["tokens_out"]),
            "predicted_tokens": int(predicted["tokens"]),
        }
    walls = [e.wall_us for e in events if e.entry_point == "serve.decode_step"]
    pre = [e.wall_us for e in events if e.entry_point == "serve.prefill"]
    if not walls:
        raise ValueError("no measured serve.decode_step events to compare")
    meas_p50 = float(np.percentile(walls, 50))
    meas_p99 = float(np.percentile(walls, 99))
    meas_total_us = float(sum(walls) + sum(pre))
    tokens = int(predicted["tokens"])
    pred_p50 = float(predicted["p50_step_us"])
    return {
        "measured_steps": len(walls),
        "measured_p50_us": round(meas_p50, 2),
        "measured_p99_us": round(meas_p99, 2),
        "predicted_p50_us": round(pred_p50, 2),
        "predicted_p99_us": float(predicted["p99_step_us"]),
        "measured_tok_s": round(tokens / max(meas_total_us * 1e-6, 1e-12), 2),
        "predicted_tok_s": float(predicted["tok_s"]),
        "p50_error_pct": round(
            100.0 * abs(pred_p50 - meas_p50) / max(meas_p50, 1e-9), 2),
    }


def table_from_traffic_row(row: Mapping[str, object], arch: str,
                           *, backend: str = "cpu") -> CalibrationTable:
    """Fit a minimal engine-only table from one measured
    BENCH_traffic.json row: the fused decode-step time is the measured
    inter-token cadence (``tok_latency_us.p50`` — host step plus the
    modeled device pace), the prefill time the first-token latency with
    queueing removed (``ttft_us.p50 - queue_wait_us.p50``). Nothing is
    re-measured: the table is exactly what the committed artifact
    already states, in replayable form."""
    fit = EngineFit(
        arch=arch, mesh="tp1", exec_spec="measured/traffic",
        decode_fixed_us=float(row["tok_latency_us"]["p50"]),
        prefill_us=max(0.0, float(row["ttft_us"]["p50"])
                       - float(row["queue_wait_us"]["p50"])),
        n_decode=int(row["decode_steps"]),
        n_prefill=int(row["prefill_batches"]),
        residual_pct=0.0,
    )
    from repro.profile.calibrate import (
        CALIBRATION_VERSION, engine_key)

    return CalibrationTable(
        version=CALIBRATION_VERSION, backend=backend,
        default_spec=fit.exec_spec, kernels={},
        engines={engine_key(arch, "tp1"): fit})


def replay_traffic_bench(
    bench: Mapping[str, object], row_key: str = "1",
) -> Tuple[Dict[str, object], Dict[str, float]]:
    """Close the predicted-vs-measured loop on a committed
    BENCH_traffic.json: rebuild the exact Poisson workload the bench
    drove (same rate/seed/lengths — :func:`poisson_requests` is
    deterministic), replay it through :func:`simulate` with the
    row's own measured segment times (:func:`table_from_traffic_row`,
    with the prefill time sharpened from the row's wall-clock residual
    when the TTFT split is queueing-dominated), and return
    ``(predicted, comparison)`` where ``comparison`` is
    :func:`compare_to_measured` of the replay against the row's
    goodput/TTFT. ``benchmarks/bench_traffic.py`` records this under
    ``"replay_check"`` and its validator gates the errors under the
    stated bound."""
    row = bench["rows"][row_key]
    if int(row["replicas"]) != 1:
        raise ValueError(
            f"replay_traffic_bench replays the single-engine row; "
            f"rows[{row_key!r}] has replicas={row['replicas']}")
    arch = str(bench["arch"])
    backend = bench.get("backend", "cpu")
    if isinstance(backend, Mapping):  # provenance block (profile.backend_block)
        backend = str(backend.get("platform", "cpu"))
    table = table_from_traffic_row(row, arch, backend=str(backend))
    fit = next(iter(table.engines.values()))
    if fit.prefill_us <= 0.0 and fit.n_prefill > 0:
        # the tracker's TTFT starts at arrival, so under saturation
        # ttft == queue_wait at p50 and the split carries no prefill
        # signal; recover it from the row's wall-clock residual after
        # the decode cadence is accounted for
        residual = (float(row["wall_s"]) * 1e6
                    - fit.n_decode * fit.decode_fixed_us)
        fit = dataclasses.replace(
            fit, prefill_us=max(0.0, residual / fit.n_prefill))
        table = dataclasses.replace(
            table, engines={k: fit for k in table.engines})
    reqs = poisson_requests(
        float(row["rate_rps"]), seed=int(bench["seed"]),
        n_requests=int(row["n_requests"]), prompt_len_max=4,
        max_new=int(bench.get("max_new", 8)))
    predicted = simulate(table, arch, reqs,
                         n_slots=int(bench["n_slots"]),
                         s_max=int(bench["s_max"]))
    return predicted, compare_to_measured(predicted, row)
