"""Grok-1-314B [hf:xai-org/grok-1] — 8-expert top-2 MoE."""
from repro.configs.base import ArchConfig
from repro.models.layers import QuantConfig

CONFIG = ArchConfig(
    name="grok-1-314b",
    family="moe",
    n_layers=64,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=32768,
    vocab=131072,
    head_dim=128,
    n_experts=8,
    n_shared_experts=0,
    top_k=2,
    expert_d_ff=32768,
    quant=QuantConfig(mode="cim"),
)

SMOKE_CONFIG = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    n_experts=4, top_k=2, expert_d_ff=96, d_ff=96, vocab=256, remat=False,
)
