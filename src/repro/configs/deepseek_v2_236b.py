"""DeepSeek-V2-236B [arXiv:2405.04434] — MLA (kv_lora=512) + 160-expert
top-6 MoE with 2 shared experts."""
from repro.configs.base import ArchConfig
from repro.models.layers import QuantConfig

CONFIG = ArchConfig(
    name="deepseek-v2-236b",
    family="moe",
    n_layers=60,
    d_model=5120,
    n_heads=128,
    n_kv_heads=128,     # MLA: per-head keys from the shared latent
    d_ff=1536,
    vocab=102400,
    mla=True,
    kv_lora_rank=512,
    qk_rope_head_dim=64,
    qk_nope_head_dim=128,
    v_head_dim=128,
    n_experts=160,
    n_shared_experts=2,
    top_k=6,
    expert_d_ff=1536,
    quant=QuantConfig(mode="cim"),
)

SMOKE_CONFIG = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    kv_lora_rank=32, qk_rope_head_dim=8, qk_nope_head_dim=16, v_head_dim=16,
    n_experts=8, n_shared_experts=1, top_k=2, expert_d_ff=64, d_ff=64,
    vocab=256, remat=False,
)
