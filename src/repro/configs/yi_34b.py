"""Yi-34B [arXiv:2403.04652] — llama-arch GQA dense LM."""
from repro.configs.base import ArchConfig
from repro.models.layers import QuantConfig

CONFIG = ArchConfig(
    name="yi-34b",
    family="dense",
    n_layers=60,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=20480,
    vocab=64000,
    head_dim=128,
    rope_theta=5e6,
    quant=QuantConfig(mode="cim"),
)

SMOKE_CONFIG = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=8, n_kv_heads=2, head_dim=8,
    d_ff=176, vocab=256, remat=False,
)
