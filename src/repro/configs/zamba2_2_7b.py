"""Zamba2-2.7B [arXiv:2411.15242] — Mamba2 backbone + shared attention."""
from repro.configs.base import ArchConfig
from repro.models.layers import QuantConfig

CONFIG = ArchConfig(
    name="zamba2-2.7b",
    family="hybrid",
    n_layers=54,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    d_ff=10240,
    vocab=32000,
    head_dim=80,
    ssm_state=64,
    ssm_head_dim=64,
    ssm_n_groups=1,
    ssm_chunk=256,
    hybrid_attn_every=6,
    subquadratic=True,
    quant=QuantConfig(mode="cim"),
)

SMOKE_CONFIG = CONFIG.replace(
    n_layers=4, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=128, vocab=256, ssm_state=16, ssm_head_dim=16, ssm_chunk=8,
    hybrid_attn_every=2, remat=False,
)
