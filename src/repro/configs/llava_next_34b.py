"""LLaVA-NeXT-34B [hf:llava-hf/llava-v1.6] — Yi-34B backbone VLM.

The vision tower + anyres tiling is a STUB per the assignment:
input_specs provides precomputed patch embeddings (B, n_img, d_vision);
the trainable projector maps them into the LM stream."""
from repro.configs.base import ArchConfig
from repro.models.layers import QuantConfig

CONFIG = ArchConfig(
    name="llava-next-34b",
    family="vlm",
    n_layers=60,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=20480,
    vocab=64000,
    head_dim=128,
    rope_theta=5e6,
    n_image_tokens=2880,   # anyres: base 576 + 4 tiles x 576
    d_vision=1024,
    quant=QuantConfig(mode="cim"),
)

SMOKE_CONFIG = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=8, n_kv_heads=2, head_dim=8,
    d_ff=176, vocab=256, n_image_tokens=8, d_vision=32, remat=False,
)
