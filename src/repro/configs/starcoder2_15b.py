"""StarCoder2-15B [arXiv:2402.19173] — GQA + RoPE dense code LM."""
from repro.configs.base import ArchConfig
from repro.models.layers import QuantConfig

CONFIG = ArchConfig(
    name="starcoder2-15b",
    family="dense",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=4,
    d_ff=24576,
    vocab=49152,
    head_dim=128,
    rope_theta=1e6,
    quant=QuantConfig(mode="cim"),
)

SMOKE_CONFIG = CONFIG.replace(
    n_layers=3, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=192, vocab=256, remat=False,
)
