"""Per-architecture configs. Each module exports CONFIG (full size, used
by the dry-run only) and SMOKE_CONFIG (reduced same-family config that
runs a real step on CPU)."""
