"""StarCoder2-7B [arXiv:2402.19173] — GQA + RoPE dense code LM."""
from repro.configs.base import ArchConfig
from repro.models.layers import QuantConfig

CONFIG = ArchConfig(
    name="starcoder2-7b",
    family="dense",
    n_layers=32,
    d_model=4608,
    n_heads=36,
    n_kv_heads=4,
    d_ff=18432,
    vocab=49152,
    head_dim=128,
    rope_theta=1e6,
    quant=QuantConfig(mode="cim"),
)

SMOKE_CONFIG = CONFIG.replace(
    n_layers=2, d_model=72, n_heads=6, n_kv_heads=2, head_dim=12,
    d_ff=160, vocab=256, remat=False,
)
