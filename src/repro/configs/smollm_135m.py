"""SmolLM-135M [hf:HuggingFaceTB/SmolLM-135M] — llama-arch small dense LM."""
from repro.configs.base import ArchConfig
from repro.models.layers import QuantConfig

CONFIG = ArchConfig(
    name="smollm-135m",
    family="dense",
    n_layers=30,
    d_model=576,
    n_heads=9,
    n_kv_heads=3,
    d_ff=1536,
    vocab=49152,
    head_dim=64,
    tie_embeddings=True,
    quant=QuantConfig(mode="cim"),
)

SMOKE_CONFIG = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab=256, remat=False,
)
