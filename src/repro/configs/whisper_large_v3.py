"""Whisper-large-v3 [arXiv:2212.04356] — enc-dec transformer backbone.

The conv/mel frontend is a STUB per the assignment: input_specs provides
precomputed frame embeddings (B, 1500, 1280)."""
from repro.configs.base import ArchConfig
from repro.models.layers import QuantConfig

CONFIG = ArchConfig(
    name="whisper-large-v3",
    family="encdec",
    n_layers=32,           # decoder layers
    n_encoder_layers=32,
    encoder_seq=1500,
    d_model=1280,
    n_heads=20,
    n_kv_heads=20,
    d_ff=5120,
    vocab=51866,
    head_dim=64,
    quant=QuantConfig(mode="cim"),
)

SMOKE_CONFIG = CONFIG.replace(
    n_layers=2, n_encoder_layers=2, encoder_seq=32, d_model=64,
    n_heads=4, n_kv_heads=4, head_dim=16, d_ff=128, vocab=256, remat=False,
)
