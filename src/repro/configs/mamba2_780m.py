"""Mamba2-780M [arXiv:2405.21060] — attention-free SSD state-space LM."""
from repro.configs.base import ArchConfig
from repro.models.layers import QuantConfig

CONFIG = ArchConfig(
    name="mamba2-780m",
    family="ssm",
    n_layers=48,
    d_model=1536,
    n_heads=1,           # unused (attention-free)
    n_kv_heads=1,
    d_ff=0,
    vocab=50280,
    ssm_state=128,
    ssm_head_dim=64,
    ssm_n_groups=1,
    ssm_chunk=256,
    subquadratic=True,
    quant=QuantConfig(mode="cim"),
)

SMOKE_CONFIG = CONFIG.replace(
    n_layers=2, d_model=64, vocab=256, ssm_state=16, ssm_head_dim=16,
    ssm_chunk=8, remat=False,
)
