"""Architecture configuration schema.

One dataclass covers every assigned architecture family (dense / ssm /
hybrid / moe / encdec-audio / vlm). Each assigned arch gets a module in
this package exporting ``CONFIG`` (full-size, dry-run only) and
``SMOKE_CONFIG`` (reduced, runs a real step on CPU).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

from repro.models.layers import QuantConfig


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                  # dense | ssm | hybrid | moe | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None        # defaults to d_model // n_heads
    rope_theta: float = 10000.0
    norm: str = "rmsnorm"                 # rmsnorm | layernorm
    tie_embeddings: bool = False

    # --- MoE ---
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    expert_d_ff: int = 0                  # per-expert FFN width (moe)
    moe_capacity_factor: float = 1.25

    # --- MLA (deepseek-v2) ---
    mla: bool = False
    kv_lora_rank: int = 0
    q_lora_rank: int = 0
    qk_rope_head_dim: int = 64
    qk_nope_head_dim: int = 128
    v_head_dim: int = 128

    # --- SSM (mamba2 SSD) ---
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_n_groups: int = 1
    ssm_chunk: int = 256
    ssm_conv_width: int = 4
    ssm_expand: int = 2

    # --- hybrid (zamba2): shared attention block every k mamba layers ---
    hybrid_attn_every: int = 6

    # --- encdec (whisper) ---
    n_encoder_layers: int = 0
    encoder_seq: int = 1500               # precomputed frame embeddings

    # --- vlm (llava-next) ---
    n_image_tokens: int = 0
    d_vision: int = 1024                  # patch-embedding width (stub)

    # --- paper technique ---
    quant: QuantConfig = QuantConfig(mode="off")
    quantize_unembed: bool = False

    # --- attention execution ---
    # 0 = full (materialized scores); >0 = flash-style chunked attention
    # over KV blocks of this size for training/prefill (§Perf hillclimb)
    attn_chunk: int = 0

    # --- training/runtime ---
    dtype: str = "bfloat16"
    remat: bool = True
    scan_layers: bool = True
    # long-context support marker: archs with sub-quadratic decode
    subquadratic: bool = False

    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.n_heads

    @property
    def ssm_d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_n_heads(self) -> int:
        return self.ssm_d_inner // self.ssm_head_dim

    def param_count(self) -> int:
        """Approximate parameter count (used for 6ND model-FLOPs)."""
        d, f, v, L = self.d_model, self.d_ff, self.vocab, self.n_layers
        hd = self.resolved_head_dim
        emb = v * d * (1 if self.tie_embeddings else 2)
        per_layer = 0
        if self.family in ("dense", "moe", "encdec", "vlm", "hybrid"):
            if self.mla:
                attn = (
                    d * (self.q_lora_rank or d)  # wq (or via q lora)
                    + d * (self.kv_lora_rank + self.qk_rope_head_dim)
                    + self.kv_lora_rank * self.n_heads * (self.qk_nope_head_dim + self.v_head_dim)
                    + self.n_heads * self.v_head_dim * d
                )
                if self.q_lora_rank:
                    attn += self.q_lora_rank * self.n_heads * (self.qk_nope_head_dim + self.qk_rope_head_dim)
                else:
                    attn = (
                        d * self.n_heads * (self.qk_nope_head_dim + self.qk_rope_head_dim)
                        + d * (self.kv_lora_rank + self.qk_rope_head_dim)
                        + self.kv_lora_rank * self.n_heads * (self.qk_nope_head_dim + self.v_head_dim)
                        + self.n_heads * self.v_head_dim * d
                    )
            else:
                attn = d * hd * (self.n_heads + 2 * self.n_kv_heads) + self.n_heads * hd * d
            if self.n_experts:
                ffn = 3 * d * self.expert_d_ff * (self.n_experts + self.n_shared_experts) + d * self.n_experts
            else:
                ffn = 3 * d * f
            per_layer = attn + ffn
        if self.family == "ssm":
            di, ns = self.ssm_d_inner, self.ssm_state
            per_layer = d * (2 * di + 2 * self.ssm_n_groups * ns + self.ssm_n_heads) + di * d
        if self.family == "hybrid":
            # mamba layers + shared attention block (counted once: shared)
            di, ns = self.ssm_d_inner, self.ssm_state
            mamba = d * (2 * di + 2 * self.ssm_n_groups * ns + self.ssm_n_heads) + di * d
            per_layer = mamba  # attention block shared; add below
        total = emb + L * per_layer
        if self.family == "hybrid":
            total += self.d_model * self.resolved_head_dim * (self.n_heads + 2 * self.n_kv_heads) \
                + self.n_heads * self.resolved_head_dim * self.d_model + 3 * d * f
        if self.family == "encdec":
            total += self.n_encoder_layers * (4 * d * d + 3 * d * f)  # encoder
            total += L * (4 * d * d)  # cross attention
        if self.family == "vlm":
            total += self.d_vision * d  # projector
        return int(total)

    def active_param_count(self) -> int:
        """Active parameters per token (MoE: only routed top-k + shared)."""
        if not self.n_experts:
            return self.param_count()
        d = self.d_model
        full = self.param_count()
        all_experts = 3 * d * self.expert_d_ff * self.n_experts * self.n_layers
        active = 3 * d * self.expert_d_ff * self.top_k * self.n_layers
        return int(full - all_experts + active)
