"""Deterministic, shardable token pipeline.

Synthetic LM data generator with the properties the trainer needs at
scale:

  * **deterministic & seekable**: batch ``i`` is a pure function of
    (seed, i) — restart/elastic-rescale replays exactly-once without
    storing stream state beyond the step counter,
  * **host-shardable**: each data-parallel host slices its rows of the
    global batch from the same logical stream (``host_slice``),
  * **structured**: token streams have Zipfian unigram structure plus
    copy/induction motifs so a ~100M model actually learns something
    measurable in a few hundred steps (examples/train driver),
  * **file-backed mode**: if a ``.npy`` corpus is supplied, batches are
    gathered from it with the same deterministic indexing.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    corpus_path: Optional[str] = None
    zipf_alpha: float = 1.1
    motif_len: int = 16


class TokenPipeline:
    """Stateless-per-batch pipeline: ``batch(step)`` is pure."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        self._corpus = None
        if cfg.corpus_path:
            self._corpus = np.load(cfg.corpus_path, mmap_mode="r")
        # Zipf unigram distribution (stable across processes)
        ranks = np.arange(1, cfg.vocab + 1, dtype=np.float64)
        probs = ranks ** (-cfg.zipf_alpha)
        self._probs = probs / probs.sum()

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        """Global batch for ``step``: tokens + next-token labels."""
        cfg = self.cfg
        if self._corpus is not None:
            rng = np.random.default_rng((cfg.seed, step))
            starts = rng.integers(0, len(self._corpus) - cfg.seq_len - 1, cfg.global_batch)
            rows = np.stack(
                [self._corpus[s : s + cfg.seq_len + 1] for s in starts]
            ).astype(np.int32)
        else:
            rng = np.random.default_rng((cfg.seed, step))
            rows = rng.choice(
                cfg.vocab, size=(cfg.global_batch, cfg.seq_len + 1), p=self._probs
            ).astype(np.int32)
            # induction motifs: repeat a short random span later in the row
            m = cfg.motif_len
            if cfg.seq_len >= 4 * m:
                src = rng.integers(0, cfg.seq_len // 2 - m, cfg.global_batch)
                dst = rng.integers(cfg.seq_len // 2, cfg.seq_len - m, cfg.global_batch)
                for i in range(cfg.global_batch):
                    rows[i, dst[i] : dst[i] + m] = rows[i, src[i] : src[i] + m]
        return {"tokens": rows[:, :-1], "labels": rows[:, 1:]}

    def host_slice(self, step: int, host_id: int, n_hosts: int) -> Dict[str, np.ndarray]:
        """This host's rows of the global batch (contiguous row blocks)."""
        g = self.batch(step)
        per = self.cfg.global_batch // n_hosts
        sl = slice(host_id * per, (host_id + 1) * per)
        return {k: v[sl] for k, v in g.items()}

    def iterator(self, start_step: int = 0) -> Iterator[Dict[str, np.ndarray]]:
        step = start_step
        while True:
            yield self.batch(step)
            step += 1
