"""Distribution utilities: sharding rules and explicit collectives."""
from repro.dist import collectives, sharding  # noqa: F401
from repro.dist.sharding import (  # noqa: F401
    batch_axes,
    cache_specs,
    disable_activation_sharding,
    enable_activation_sharding,
    model_axis_size,
    param_specs,
    shard_act,
    tree_paths,
    use_mesh,
)
