"""Distribution utilities: sharding rules and explicit collectives."""
from repro.dist import collectives, sharding  # noqa: F401
from repro.dist.collectives import (  # noqa: F401
    compressed_psum_int8,
    mean_grads_int8,
    tp_allreduce,
)
from repro.dist.sharding import (  # noqa: F401
    batch_axes,
    cache_specs,
    disable_activation_sharding,
    enable_activation_sharding,
    mesh_axis_sizes,
    model_axis_size,
    named_shardings,
    packed_specs,
    param_specs,
    set_tp_mesh,
    shard_act,
    tp_mesh,
    tree_paths,
    use_mesh,
)
