"""Explicit compressed collectives (shard_map manual SPMD).

optim/compress.py models the *numerics* of a compressed gradient
reduction under pjit autodiff (encode/decode round trip). These
primitives actually narrow the wire format: each shard quantizes its
local payload to int8 (stochastic rounding, globally shared scale) and
the all-reduce moves the int8 payload; the f32 decode happens after the
sum. Tested on a forced multi-device host mesh in
tests/test_collectives.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

try:  # jax >= 0.6 promotes shard_map out of experimental
    from jax import shard_map  # type: ignore[attr-defined]
except ImportError:
    from jax.experimental.shard_map import shard_map


def compressed_psum_int8(x: jax.Array, key: jax.Array, axis_name: str) -> jax.Array:
    """Int8-compressed psum over ``axis_name`` (call inside shard_map).

    All shards agree on one scale (pmax of the local amax), quantize with
    unbiased stochastic rounding, and all-reduce the payload in an int32
    accumulator (sums of int8 across any realistic axis size fit).
    Returns the decoded f32 sum.
    """
    amax = jax.lax.pmax(jnp.max(jnp.abs(x)).astype(jnp.float32), axis_name)
    scale = jnp.maximum(amax, 1e-12) / 127.0
    noise = jax.random.uniform(key, x.shape, jnp.float32, -0.5, 0.5)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale + noise), -127, 127)
    total = jax.lax.psum(q.astype(jnp.int32), axis_name)
    return total.astype(jnp.float32) * scale


def tp_allreduce(
    x: jax.Array,
    axis_name: str,
    *,
    key: jax.Array | None = None,
    compressed: bool = False,
) -> jax.Array:
    """Tensor-parallel partial-sum all-reduce (call inside shard_map).

    The serving TP entry point: row-parallel (contraction-dim-sharded)
    ternary GEMMs produce per-device partial sums that must be summed
    over the "model" axis every layer. ``compressed=False`` is the exact
    ``psum`` — for CiM formulations the partials are integer ADC event
    counts, so the f32 sum is exact and TP serving stays bit-identical.
    ``compressed=True`` narrows the wire to int8
    (:func:`compressed_psum_int8`, needs ``key`` for the stochastic
    rounding) — the 4x-narrower collective the SiTe bitplane format pairs
    with, at quantization-level error (bounded in tests/test_collectives).
    """
    if not compressed:
        return jax.lax.psum(x, axis_name)
    if key is None:
        raise ValueError("compressed tp_allreduce needs a PRNG key "
                         "(stochastic-rounding stream)")
    return compressed_psum_int8(x, key, axis_name)


def mean_grads_int8(
    mesh, grads: jax.Array, keys: jax.Array, axis_name: str = "data"
) -> jax.Array:
    """Mean-reduce per-shard gradients over ``axis_name`` with an int8
    wire format.

    grads: (n_shards, ...) — one local gradient per shard along dim 0.
    keys:  (n_shards, 2) uint32 PRNG keys (one rounding stream per shard).
    Returns the replicated f32 mean with shape ``grads.shape[1:]``.
    """
    n = int(mesh.shape[axis_name])

    def local(g, k):
        g = g.reshape(g.shape[1:])        # drop the size-1 sharded dim
        s = compressed_psum_int8(g, k[0], axis_name)
        return s / n

    f = shard_map(
        local, mesh=mesh, in_specs=(P(axis_name), P(axis_name)), out_specs=P()
    )
    return f(grads, keys)
