"""Sharding rules for parameters, decode caches, and activations.

Three layers of policy, all mesh-axis-name based ("pod", "data", "model"):

  * ``param_specs``  — PartitionSpec tree for a parameter pytree. Tensor
    parallelism on the weight names the ternary/CiM dense path quantizes
    (attention projections, MLP/expert FFN weights), expert-dim sharding
    for MoE, replication for norms/small leaves, optional FSDP ("data"
    axis added to large weights whose dims divide).
  * ``cache_specs``  — decode caches: batch over the data-like axes, the
    sequence/state dim over "model".
  * ``shard_act``    — activation sharding constraints by *logical* axes
    name ("btd", "logits", "gecd", ...). Module-global switch: the
    dry-run (and tests) call ``enable_activation_sharding`` around the
    lowering; everything is an identity no-op when disabled, so CPU
    smoke tests never pay a constraint.

``tree_paths`` flattens a pytree into ("a/b/c", leaf) pairs — the path
currency used by quant/prepare.py's weight-name regexes and the spec
rules here.
"""
from __future__ import annotations

import math
from typing import Any, Dict, List, Optional, Tuple

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

PyTree = Any


def mesh_axis_sizes(mesh) -> Dict[str, int]:
    """{axis_name: size} for a mesh — the ``axis_sizes`` currency the
    spec rules below take (so specs only name axes the shapes divide)."""
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def named_shardings(mesh, spec_tree: PyTree) -> PyTree:
    """Bind a PartitionSpec tree to a mesh as NamedShardings (the form
    ``jax.device_put`` / ``jit`` shardings consume)."""
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda s: isinstance(s, P),
    )

# ---------------------------------------------------------------------------
# Pytree path flattening
# ---------------------------------------------------------------------------


def _key_str(k) -> str:
    if isinstance(k, jax.tree_util.DictKey):
        return str(k.key)
    if isinstance(k, jax.tree_util.SequenceKey):
        return str(k.idx)
    if isinstance(k, jax.tree_util.GetAttrKey):
        return str(k.name)
    if isinstance(k, jax.tree_util.FlattenedIndexKey):
        return str(k.key)
    return str(k)


def tree_paths(tree: PyTree) -> List[Tuple[str, Any]]:
    """Flatten ``tree`` to a list of ("path/like/this", leaf) pairs, in
    ``jax.tree_util.tree_flatten`` leaf order."""
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return [("/".join(_key_str(k) for k in path), leaf) for path, leaf in flat]


# ---------------------------------------------------------------------------
# Mesh-context compatibility
# ---------------------------------------------------------------------------


def use_mesh(mesh):
    """Version-portable mesh context: ``jax.set_mesh`` on new jax, the
    ``Mesh`` object's own context manager on older releases. Usage:

        with use_mesh(mesh):
            jax.jit(f, in_shardings=...).lower(...)
    """
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh


# ---------------------------------------------------------------------------
# Parameter specs
# ---------------------------------------------------------------------------

# column-parallel (shard the output-channel / last dim over "model")
_COL_TP = {
    "wq", "wk", "wv", "w_uk", "w_uv", "w_dkv", "w_in",
    "w_gate", "w_up", "unembed", "projector",
}
# row-parallel (shard the contraction dim over "model")
_ROW_TP = {"wo", "w_out", "w_down"}
# MoE expert weights: shard the expert dim over "model"
_EXPERT_TP = {"w_gate", "w_up", "w_down"}

# leaves below this size are never FSDP-sharded (gather overhead > savings)
FSDP_MIN_SIZE = 1 << 20


def _axis_size(axis, axis_sizes: Optional[Dict[str, int]]) -> int:
    if axis_sizes is None:
        return 1
    size = 1
    for a in axis if isinstance(axis, tuple) else (axis,):
        size *= int(axis_sizes.get(a, 1))
    return size


def _divides(dim: int, axis, axis_sizes: Optional[Dict[str, int]]) -> bool:
    """True when sharding ``dim`` over ``axis`` is legal. With no
    ``axis_sizes`` the mesh is unknown — emit the logical axis and let the
    partitioner decide (the unit tests exercise this mode)."""
    if axis_sizes is None:
        return True
    size = _axis_size(axis, axis_sizes)
    return size >= 1 and dim % size == 0


def _is_stacked(segs: List[str]) -> bool:
    """Stacked-layer leaves carry the layer dim first (scan-over-layers);
    unrolled lists ("blocks/0/...") see per-layer leaves."""
    return segs[0] in ("blocks", "enc_blocks") and not (
        len(segs) > 1 and segs[1].isdigit()
    )


def _leaf_spec(path: str, leaf, axis_sizes: Optional[Dict[str, int]]) -> List:
    segs = path.split("/")
    name = segs[-1]
    parent = segs[-2] if len(segs) > 1 else ""
    ndim = len(leaf.shape)
    spec: List = [None] * ndim

    # norms / biases / vectors: replicated
    if ndim < 2 or name.startswith("ln") or name in (
        "final_norm", "enc_norm", "router", "conv_w", "conv_b", "dt_bias",
        "enc_pos",
    ):
        return spec

    if parent == "moe" and name in _EXPERT_TP and ndim >= 3:
        e_dim = ndim - 3
        if _divides(leaf.shape[e_dim], "model", axis_sizes):
            spec[e_dim] = "model"
        return spec

    if name == "embed":
        # shard the vocab dim (embedding lookups all-gather cheaply)
        if _divides(leaf.shape[0], "model", axis_sizes):
            spec[0] = "model"
        return spec

    if name in _COL_TP:
        if _divides(leaf.shape[-1], "model", axis_sizes):
            spec[-1] = "model"
        return spec

    if name in _ROW_TP:
        if _divides(leaf.shape[-2], "model", axis_sizes):
            spec[-2] = "model"
        return spec

    return spec


def param_specs(
    params: PyTree,
    fsdp: bool = False,
    axis_sizes: Optional[Dict[str, int]] = None,
) -> PyTree:
    """PartitionSpec tree matching ``params`` (rank always equals leaf
    rank). ``fsdp=True`` additionally spreads large weights over the
    "data" axis wherever a free dim divides."""

    def f(path_keys, leaf):
        path = "/".join(_key_str(k) for k in path_keys)
        spec = _leaf_spec(path, leaf, axis_sizes)
        if fsdp and axis_sizes and math.prod(leaf.shape) >= FSDP_MIN_SIZE:
            if "data" not in spec:
                start = 1 if _is_stacked(path.split("/")) else 0
                for i in range(start, len(spec)):
                    if spec[i] is None and _divides(leaf.shape[i], "data", axis_sizes):
                        spec[i] = "data"
                        break
        return P(*spec)

    return jax.tree_util.tree_map_with_path(f, params)


# ---------------------------------------------------------------------------
# Cache specs
# ---------------------------------------------------------------------------


def cache_specs(caches: PyTree, mesh, batch: int) -> PyTree:
    """Decode-cache PartitionSpecs. Stacked cache leaves are
    (L, B, S/state...): batch over the data-like axes, the first trailing
    dim that divides over "model" (KV caches: the sequence dim).

    Quantized caches (DESIGN.md §13) need no special casing: int8/uint8
    code leaves keep the (L, B, S, ...) layout and their per-(row,
    position) scale leaves are (L, B, S) — both split on the sequence
    dim under the same rule, so each device stores its sequence shard
    of the codes together with the matching shard of the scales."""
    axis_sizes = mesh_axis_sizes(mesh)
    daxes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    dsize = _axis_size(daxes, axis_sizes)
    msize = int(axis_sizes.get("model", 1))

    def f(leaf):
        shape = leaf.shape
        spec: List = [None] * len(shape)
        if len(shape) >= 2 and daxes and batch % max(dsize, 1) == 0 and shape[1] == batch:
            spec[1] = daxes if len(daxes) > 1 else daxes[0]
        for i in range(2, len(shape)):
            if msize >= 1 and shape[i] % max(msize, 1) == 0:
                spec[i] = "model"
                break
        return P(*spec)

    return jax.tree.map(f, caches)


# ---------------------------------------------------------------------------
# Packed-bitplane specs (quant/prepare.pack_params output)
# ---------------------------------------------------------------------------


def packed_specs(
    packed: Dict[str, Any], axis_sizes: Optional[Dict[str, int]] = None
) -> Dict[str, Any]:
    """PartitionSpecs for a ``quant.prepare`` packed dict — either the
    legacy ``{path: (pos_plane, neg_plane, scale)}`` tuples or the
    canonical ``{path: PackedPlanes}`` layout ``prepare_for_spec`` emits
    (a registered pytree, so one structure-preserving tree map covers
    both; the canonical layout is consumed unchanged — no re-layout
    between prepare and placement). Planes are (..., K/8, N), scales
    (..., 1, N).

    Every entry shards the output-channel dim N over "model" — the planes
    are packed 2-bit *along K*, so splitting K would tear u8 bytes apart,
    while an N split keeps each device streaming only the plane columns
    its TP shard consumes (the "each device streams only its 2-bit weight
    shard" contract). Leaves whose N doesn't divide stay replicated (the
    canonical padded N is a 128 multiple, so typical TP degrees divide)."""

    def leaf_spec(leaf):
        spec: List = [None] * leaf.ndim
        if leaf.ndim >= 2 and _divides(leaf.shape[-1], "model", axis_sizes):
            spec[-1] = "model"
        return P(*spec)

    return jax.tree.map(leaf_spec, packed)


# ---------------------------------------------------------------------------
# Serving tensor-parallel mesh (module-global switch, mirrors the
# activation-sharding pattern: consumers read it at trace time)
# ---------------------------------------------------------------------------

_TP_MESH = None


def set_tp_mesh(mesh) -> None:
    """Install the mesh the explicit TP collectives (shard_map entry
    points — ``execution.execute_tp``) run over. ``None`` disables the
    explicit path; the implicit GSPMD path (params/caches device_put with
    NamedShardings, partitioner inserts collectives) needs no global."""
    global _TP_MESH
    _TP_MESH = mesh


def tp_mesh():
    return _TP_MESH


def replica_device_groups(replicas: int, tp: int) -> List[List[Any]]:
    """Partition the visible devices into ``replicas`` disjoint groups
    of ``tp`` devices — the device plan behind the serving front door's
    multi-replica router (DESIGN.md §12): the groups are the rows of a
    ``(replicas, tp)`` grid, i.e. replication lives on the ``"data"``
    axis of the device plane while each replica's internal TP sharding
    keeps the ``"model"`` axis. Groups are disjoint, so replica engines
    never contend for a device and their collectives never cross."""
    if replicas < 1 or tp < 1:
        raise ValueError(f"need replicas >= 1 and tp >= 1, got "
                         f"replicas={replicas} tp={tp}")
    devs = jax.devices()
    need = replicas * tp
    if len(devs) < need:
        raise ValueError(
            f"{replicas} replicas x tp={tp} needs {need} devices but only "
            f"{len(devs)} are visible (on CPU set XLA_FLAGS="
            f"--xla_force_host_platform_device_count={need} before the "
            "first jax import)"
        )
    return [list(devs[r * tp:(r + 1) * tp]) for r in range(replicas)]


# ---------------------------------------------------------------------------
# Activation sharding (logical axes, module-global switch)
# ---------------------------------------------------------------------------

# None = disabled (identity). When enabled: {"multi_pod", "divisor",
# "model_size", "data"} — consumers (models/moe.py) read "divisor" to pick
# the routing group count.
_ACT_AXES: Optional[Dict[str, Any]] = None


def enable_activation_sharding(
    *, multi_pod: bool = False, batch_divisor: int = 1, model_size: int = 1
) -> None:
    global _ACT_AXES
    _ACT_AXES = {
        "multi_pod": bool(multi_pod),
        "divisor": int(batch_divisor),
        "model_size": int(model_size),
        "data": ("pod", "data") if multi_pod else ("data",),
    }


def disable_activation_sharding() -> None:
    global _ACT_AXES
    _ACT_AXES = None


def model_axis_size() -> int:
    """Size of the "model" mesh axis when activation sharding is on; 1
    otherwise (callers use it to guard divisibility)."""
    return int(_ACT_AXES.get("model_size", 1)) if _ACT_AXES else 1


def batch_axes() -> Tuple[str, ...]:
    """The data-like mesh axes batch dims shard over (() when off)."""
    return _ACT_AXES["data"] if _ACT_AXES else ()


def _act_spec(x, name: str) -> P:
    cfg = _ACT_AXES
    data = cfg["data"]
    d = data if len(data) > 1 else data[0]
    div = cfg["divisor"]
    msize = cfg.get("model_size", 1)
    batch_ok = div > 1 and x.shape[0] % div == 0
    b = d if batch_ok else None
    if name == "btd":
        return P(b, None, None)
    if name == "logits":
        v = "model" if msize >= 1 and x.shape[-1] % max(msize, 1) == 0 else None
        return P(b, None, v)
    if name == "gecd":          # (groups, experts, capacity, d): expert-sharded
        return P(b, "model", None, None)
    if name == "gecd_cap":      # expert count doesn't divide: shard capacity
        return P(b, None, "model", None)
    if name == "bqhgd_sp":      # context parallelism: query rows over "model"
        return P(None, "model", None, None, None)
    return P(*([None] * x.ndim))


def shard_act(x: jax.Array, name: str) -> jax.Array:
    """Apply a named activation sharding constraint; identity when
    activation sharding is disabled or no mesh context is active."""
    if _ACT_AXES is None:
        return x
    try:
        return jax.lax.with_sharding_constraint(x, _act_spec(x, name))
    except (RuntimeError, ValueError):
        # no mesh context (eager smoke path) — constraints are advisory
        return x
