"""Production training launcher.

On a real TPU cluster each host runs this under its own process (with
jax.distributed.initialize); here it drives the same code single-process.
For the 512-placeholder-device mesh use launch/dryrun.py — this launcher
executes real steps and therefore uses the actual local devices.

    PYTHONPATH=src python -m repro.launch.train --arch smollm-135m \
        --smoke --steps 50 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse

import jax

from repro.data.pipeline import DataConfig, TokenPipeline
from repro.dist import sharding as shd
from repro.launch.mesh import make_smoke_mesh
from repro.models.registry import get_config
from repro.optim.adamw import AdamWConfig
from repro.optim.schedules import warmup_cosine
from repro.train.trainer import TrainConfig, Trainer


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--grad-compression", default=None, choices=[None, "bf16", "int8"])
    ap.add_argument("--quant", default=None,
                    choices=[None, "off", "ternary", "cim", "cim_fused"])
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    if args.quant:
        import dataclasses

        cfg = cfg.replace(quant=dataclasses.replace(cfg.quant, mode=args.quant))
    print(f"[train] {cfg.name}: {cfg.param_count():,} params, "
          f"quant={cfg.quant.mode}, devices={len(jax.devices())}")

    pipe = TokenPipeline(DataConfig(
        vocab=cfg.vocab, seq_len=args.seq, global_batch=args.batch))
    opt = AdamWConfig(lr=args.lr, schedule=warmup_cosine(20, args.steps))
    tcfg = TrainConfig(
        num_steps=args.steps, ckpt_dir=args.ckpt_dir,
        ckpt_every=args.ckpt_every, log_every=10,
        grad_compression=args.grad_compression,
    )
    trainer = Trainer(cfg, opt, tcfg, pipe)
    log = trainer.run()
    print(f"[train] done: loss {log[0]['loss']:.4f} -> {log[-1]['loss']:.4f}; "
          f"restarts={trainer.restarts} stragglers={len(trainer.straggler_steps)}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
