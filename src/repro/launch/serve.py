"""Production serving launcher: continuous batching over a ternary model.

    PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m --smoke \
        --requests 8 --slots 4
"""
from __future__ import annotations

import argparse
import time

import jax

from repro.models import transformer as T
from repro.models.registry import get_config
from repro.quant.prepare import ternarize_params
from repro.serve.engine import ContinuousBatcher, Request


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--s-max", type=int, default=64)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--pre-quantize", action="store_true",
                    help="fold ternarization into weights offline")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    if args.pre_quantize:
        import dataclasses

        params = ternarize_params(params)
        cfg = cfg.replace(quant=dataclasses.replace(cfg.quant, pre_quantized=True))
    batcher = ContinuousBatcher(params, cfg, n_slots=args.slots, s_max=args.s_max)
    reqs = [
        Request(i, [1 + (i * 7 + j) % (cfg.vocab - 1) for j in range(1 + i % 4)],
                max_new=2 + i % args.max_new)
        for i in range(args.requests)
    ]
    for r in reqs:
        batcher.submit(r)
    t0 = time.perf_counter()
    batcher.run()
    dt = time.perf_counter() - t0
    toks = sum(len(r.generated) for r in reqs)
    print(f"[serve] {len(reqs)} requests, {toks} tokens, {dt:.2f}s "
          f"({toks / max(dt, 1e-9):.1f} tok/s functional-CPU)")
    assert all(r.done for r in reqs)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
