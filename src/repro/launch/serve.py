"""Production serving launcher: continuous batching over a ternary model.

    PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m --smoke \
        --requests 8 --slots 4

``--serve-http`` starts the async front door instead of the one-shot
batch run (DESIGN.md §12): an HTTP + WebSocket server (stdlib asyncio)
streaming tokens per request, with ``--replicas N`` engine replicas
behind a least-loaded router and bounded admission (``--queue-limit``,
429 on overflow). ``--selftest`` runs the front door against itself —
stream one request, cancel a second mid-stream, verify /stats, clean
shutdown — and exits; CI uses it as the front-door smoke:

    PYTHONPATH=src python -m repro.launch.serve --smoke --serve-http \
        --replicas 2 --selftest

The serving CiM execution spec is selected with ``--exec-spec`` as
``formulation[/backend[/packing[/flavor]]]``, e.g. ``exact/jnp`` (the
near-memory exact baseline), ``blocked`` (faithful per-16-block ADC
clamp), or ``bitplane/jnp/bitplane_u8/II`` (2-bit packed planes, flavor
II); combined with ``--prepare-weights`` the quantization is folded
offline once (quant.prepare.prepare_for_spec) and packed planes are
prepared up front instead of per step.

``--tp N`` serves tensor-parallel over an N-device ("data", "model")
mesh (DESIGN.md §8): params/caches/planes sharded, same token streams,
same host-sync discipline. On CPU the devices are virtualized — the
bootstrap below forces enough host devices, and it MUST run before the
first jax import (jax locks the device count at first init, same
contract as launch/dryrun.py). ``--compress-tp`` opts the quantized
layers' TP all-reduces into the int8-compressed collective.
"""
from __future__ import annotations

import sys

from repro.launch._boot import force_host_devices_for_tp

force_host_devices_for_tp(sys.argv)  # before the jax import below

import argparse
import time

import jax

from repro.core.execution import CiMExecSpec
from repro.models import transformer as T
from repro.models.registry import get_config
from repro.quant.prepare import ternarize_params
from repro.serve.engine import ContinuousBatcher, Request


def parse_exec_spec(text: str) -> CiMExecSpec:
    """``formulation[/backend[/packing[/flavor]]]`` -> CiMExecSpec."""
    parts = text.split("/")
    if len(parts) > 4:
        raise ValueError(f"bad exec spec {text!r} (at most 4 '/'-fields)")
    fields = ("formulation", "backend", "packing", "flavor")
    return CiMExecSpec(**dict(zip(fields, parts)))


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--s-max", type=int, default=64)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--exec-spec", default=None, metavar="FORM[/BACKEND[/PACKING[/FLAVOR]]]",
                    help="serve under an explicit CiM execution spec, e.g. "
                         "'exact/jnp', 'blocked', 'bitplane/jnp/bitplane_u8/II'")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="sampling temperature (0 = greedy), applied on device")
    ap.add_argument("--seed", type=int, default=0, help="sampling PRNG seed")
    ap.add_argument("--loop-decode", action="store_true",
                    help="use the legacy per-slot-loop decode baseline "
                         "instead of the fused ragged-position step")
    ap.add_argument("--tp", type=int, default=1, metavar="N",
                    help="tensor-parallel degree: serve over an N-device "
                         "('data', 'model') mesh (params/caches/planes "
                         "sharded; CPU forces virtual host devices)")
    ap.add_argument("--compress-tp", action="store_true",
                    help="route the quantized layers' TP all-reduces "
                         "through the int8-compressed collective "
                         "(requires --tp > 1 and a quantized mode)")
    ap.add_argument("--prepare-weights", action="store_true",
                    help="run quant.prepare.prepare_for_spec once at startup "
                         "(requires --exec-spec): folded ternary weights, and "
                         "pre-packed planes for bitplane_u8 packing")
    ap.add_argument("--pre-quantize", action="store_true",
                    help="fold ternarization into weights offline")
    ap.add_argument("--profile", default=None, metavar="TRACE.jsonl",
                    help="record per-step timing events (serve.prefill / "
                         "serve.decode_step / serve.prepare) to a JSON-lines "
                         "trace file — repro.profile reads it back for "
                         "calibration and replay")
    ap.add_argument("--serve-http", action="store_true",
                    help="start the async HTTP/WebSocket front door "
                         "(repro.serve.frontdoor) instead of the one-shot "
                         "batch run; serves until interrupted")
    ap.add_argument("--replicas", type=int, default=1, metavar="N",
                    help="engine replicas behind the front-door router "
                         "(each a full ContinuousBatcher; with --tp > 1 "
                         "each replica gets its own disjoint (1, tp) mesh)")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=8471,
                    help="front-door TCP port (0 = ephemeral)")
    ap.add_argument("--queue-limit", type=int, default=64,
                    help="admission cap: total in-flight requests across "
                         "replicas; over it, new requests get 429")
    ap.add_argument("--pace-us", type=float, default=0.0, dest="pace_us",
                    help="modeled per-step device latency in microseconds, "
                         "slept off-GIL in each replica's worker thread "
                         "(benchmarks/bench_traffic.py uses this to make "
                         "replica scaling measurable on CPU hosts; 0 = off)")
    ap.add_argument("--selftest", action="store_true",
                    help="front-door smoke: start --serve-http on an "
                         "ephemeral port, stream one request, cancel a "
                         "second mid-stream, check /stats, shut down "
                         "cleanly, exit 0")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    if args.pre_quantize:
        import dataclasses

        params = ternarize_params(params)
        cfg = cfg.replace(quant=dataclasses.replace(cfg.quant, pre_quantized=True))
    exec_spec = parse_exec_spec(args.exec_spec) if args.exec_spec else None
    if args.prepare_weights and exec_spec is None:
        ap.error("--prepare-weights requires --exec-spec")
    if args.compress_tp and args.tp <= 1:
        ap.error("--compress-tp requires --tp > 1")
    if args.replicas < 1:
        ap.error("--replicas must be >= 1")
    if args.selftest:
        args.serve_http = True
        args.port = 0  # ephemeral: the selftest races no other listener
    if args.serve_http:
        return _serve_http_main(args, cfg, params, exec_spec)
    mesh = None
    if args.tp > 1:
        from repro.launch.mesh import make_tp_mesh

        mesh = make_tp_mesh(args.tp)
    batcher = ContinuousBatcher(
        params, cfg, n_slots=args.slots, s_max=args.s_max,
        exec_spec=exec_spec, temperature=args.temperature, seed=args.seed,
        fused=not args.loop_decode, prepare_weights=args.prepare_weights,
        mesh=mesh, compress_tp=args.compress_tp, profile=args.profile,
    )
    reqs = [
        Request(i, [1 + (i * 7 + j) % (cfg.vocab - 1) for j in range(1 + i % 4)],
                max_new=2 + i % args.max_new)
        for i in range(args.requests)
    ]
    for r in reqs:
        batcher.submit(r)
    t0 = time.perf_counter()
    batcher.run()
    dt = time.perf_counter() - t0
    toks = sum(len(r.generated) for r in reqs)
    stats = batcher.stats()
    print(f"[serve] {len(reqs)} requests, {toks} tokens, {dt:.2f}s "
          f"({toks / max(dt, 1e-9):.1f} tok/s functional-CPU), "
          f"{stats['decode_steps']} decode steps, "
          f"{stats['host_syncs']} host syncs "
          f"({'looped' if args.loop_decode else 'fused'} decode"
          + (f", tp={args.tp}" + (" int8-compressed" if args.compress_tp else "")
             if args.tp > 1 else "") + ")")
    if args.profile:
        n_ev = len(batcher.profiler.events)
        print(f"[serve] profile: {n_ev} trace events -> {args.profile}")
    assert all(r.done for r in reqs)
    return 0


# ---------------------------------------------------------------------------
# --serve-http: the async front door (repro.serve.frontdoor)
# ---------------------------------------------------------------------------


def build_frontdoor(args, cfg, params, exec_spec):
    """(FrontDoor, profiler) for the parsed args: N replica batchers
    (disjoint (1, tp) meshes when --tp > 1), one router, one tracker.
    Shared with benchmarks/bench_traffic.py so the bench serves through
    the identical stack."""
    from repro.serve.frontdoor import (
        EngineWorker,
        FrontDoor,
        ReplicaRouter,
        SLOTracker,
    )

    meshes = [None] * args.replicas
    if args.tp > 1:
        from repro.launch.mesh import make_replica_meshes

        meshes = make_replica_meshes(args.replicas, args.tp)
    profiler = None
    if args.profile:
        from repro.profile.trace import Profiler

        # one trace file for every replica AND the frontdoor.request
        # events — the profiler appends per event, so streams interleave
        profiler = Profiler(args.profile)
    batchers = [
        ContinuousBatcher(
            params, cfg, n_slots=args.slots, s_max=args.s_max,
            exec_spec=exec_spec, temperature=args.temperature,
            seed=args.seed, fused=not args.loop_decode,
            prepare_weights=args.prepare_weights, mesh=meshes[i],
            compress_tp=args.compress_tp, profile=profiler,
        )
        for i in range(args.replicas)
    ]
    tracker = SLOTracker(
        profiler=profiler,
        exec_spec=args.exec_spec or "mode:off",
        mesh={"data": args.replicas, "model": args.tp} if args.tp > 1 else None,
    )
    workers = [EngineWorker(f"r{i}", b, tracker,
                            pace_us=getattr(args, "pace_us", 0.0))
               for i, b in enumerate(batchers)]
    router = ReplicaRouter(workers, queue_limit=args.queue_limit)
    return FrontDoor(router, tracker, host=args.host, port=args.port), profiler


async def _selftest_session(door) -> None:
    """The CI front-door smoke: one full streamed request, one
    cancelled mid-stream, /stats agrees, nothing left in flight."""
    from repro.serve.frontdoor.client import WSClient, http_json

    host, port = door.host, door.port
    ws = await WSClient.connect(host, port)
    full = await ws.generate([1, 2, 3], max_new=6)
    assert len(full["tokens"]) == 6, full
    assert full["done"]["cancelled"] is False, full
    part = await ws.generate([4, 5], max_new=32, cancel_after=2)
    assert part["done"]["cancelled"] is True, part
    assert 2 <= len(part["tokens"]) < 32, part
    await ws.close()
    status, stats = await http_json(host, port, "GET", "/stats")
    assert status == 200, (status, stats)
    reqs = stats["slo"]["requests"]
    assert reqs["completed"] == 1 and reqs["cancelled"] == 1, reqs
    assert stats["router"]["in_flight"] == 0, stats["router"]
    print(f"[serve] selftest: streamed {len(full['tokens'])} tokens, "
          f"cancelled after {len(part['tokens'])}, /stats consistent")


async def _serve_http_async(args, cfg, params, exec_spec) -> int:
    import asyncio
    import signal

    door, profiler = build_frontdoor(args, cfg, params, exec_spec)
    host, port = await door.start()
    n_rep, n_tp = args.replicas, args.tp
    print(f"[serve] front door on http://{host}:{port} "
          f"({n_rep} replica{'s' if n_rep != 1 else ''}"
          + (f", tp={n_tp}" if n_tp > 1 else "")
          + f", queue-limit {args.queue_limit}) — "
          "routes: /healthz /stats /v1/generate /v1/stream")
    try:
        if args.selftest:
            await _selftest_session(door)
        else:
            stop = asyncio.Event()
            loop = asyncio.get_running_loop()
            for sig in (signal.SIGINT, signal.SIGTERM):
                try:
                    loop.add_signal_handler(sig, stop.set)
                except NotImplementedError:
                    pass  # non-unix event loops: rely on KeyboardInterrupt
            await stop.wait()
            print("[serve] draining...")
    finally:
        await door.stop()
        if profiler is not None:
            profiler.close()
    for w in door.router.workers:
        assert not w.load, f"replica {w.name} still has load after stop"
    print("[serve] clean shutdown"
          + (" — selftest ok" if args.selftest else ""))
    return 0


def _serve_http_main(args, cfg, params, exec_spec) -> int:
    import asyncio

    try:
        return asyncio.run(_serve_http_async(args, cfg, params, exec_spec))
    except KeyboardInterrupt:
        print("[serve] interrupted")
        return 130


if __name__ == "__main__":
    raise SystemExit(main())
