"""Production serving launcher: continuous batching over a ternary model.

    PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m --smoke \
        --requests 8 --slots 4

The serving CiM execution spec is selected with ``--exec-spec`` as
``formulation[/backend[/packing[/flavor]]]``, e.g. ``exact/jnp`` (the
near-memory exact baseline), ``blocked`` (faithful per-16-block ADC
clamp), or ``bitplane/jnp/bitplane_u8/II`` (2-bit packed planes, flavor
II); combined with ``--prepare-weights`` the quantization is folded
offline once (quant.prepare.prepare_for_spec) and packed planes are
prepared up front instead of per step.

``--tp N`` serves tensor-parallel over an N-device ("data", "model")
mesh (DESIGN.md §8): params/caches/planes sharded, same token streams,
same host-sync discipline. On CPU the devices are virtualized — the
bootstrap below forces enough host devices, and it MUST run before the
first jax import (jax locks the device count at first init, same
contract as launch/dryrun.py). ``--compress-tp`` opts the quantized
layers' TP all-reduces into the int8-compressed collective.
"""
from __future__ import annotations

import sys

from repro.launch._boot import force_host_devices_for_tp

force_host_devices_for_tp(sys.argv)  # before the jax import below

import argparse
import time

import jax

from repro.core.execution import CiMExecSpec
from repro.models import transformer as T
from repro.models.registry import get_config
from repro.quant.prepare import ternarize_params
from repro.serve.engine import ContinuousBatcher, Request


def parse_exec_spec(text: str) -> CiMExecSpec:
    """``formulation[/backend[/packing[/flavor]]]`` -> CiMExecSpec."""
    parts = text.split("/")
    if len(parts) > 4:
        raise ValueError(f"bad exec spec {text!r} (at most 4 '/'-fields)")
    fields = ("formulation", "backend", "packing", "flavor")
    return CiMExecSpec(**dict(zip(fields, parts)))


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--s-max", type=int, default=64)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--exec-spec", default=None, metavar="FORM[/BACKEND[/PACKING[/FLAVOR]]]",
                    help="serve under an explicit CiM execution spec, e.g. "
                         "'exact/jnp', 'blocked', 'bitplane/jnp/bitplane_u8/II'")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="sampling temperature (0 = greedy), applied on device")
    ap.add_argument("--seed", type=int, default=0, help="sampling PRNG seed")
    ap.add_argument("--loop-decode", action="store_true",
                    help="use the legacy per-slot-loop decode baseline "
                         "instead of the fused ragged-position step")
    ap.add_argument("--tp", type=int, default=1, metavar="N",
                    help="tensor-parallel degree: serve over an N-device "
                         "('data', 'model') mesh (params/caches/planes "
                         "sharded; CPU forces virtual host devices)")
    ap.add_argument("--compress-tp", action="store_true",
                    help="route the quantized layers' TP all-reduces "
                         "through the int8-compressed collective "
                         "(requires --tp > 1 and a quantized mode)")
    ap.add_argument("--prepare-weights", action="store_true",
                    help="run quant.prepare.prepare_for_spec once at startup "
                         "(requires --exec-spec): folded ternary weights, and "
                         "pre-packed planes for bitplane_u8 packing")
    ap.add_argument("--pre-quantize", action="store_true",
                    help="fold ternarization into weights offline")
    ap.add_argument("--profile", default=None, metavar="TRACE.jsonl",
                    help="record per-step timing events (serve.prefill / "
                         "serve.decode_step / serve.prepare) to a JSON-lines "
                         "trace file — repro.profile reads it back for "
                         "calibration and replay")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    if args.pre_quantize:
        import dataclasses

        params = ternarize_params(params)
        cfg = cfg.replace(quant=dataclasses.replace(cfg.quant, pre_quantized=True))
    exec_spec = parse_exec_spec(args.exec_spec) if args.exec_spec else None
    if args.prepare_weights and exec_spec is None:
        ap.error("--prepare-weights requires --exec-spec")
    if args.compress_tp and args.tp <= 1:
        ap.error("--compress-tp requires --tp > 1")
    mesh = None
    if args.tp > 1:
        from repro.launch.mesh import make_tp_mesh

        mesh = make_tp_mesh(args.tp)
    batcher = ContinuousBatcher(
        params, cfg, n_slots=args.slots, s_max=args.s_max,
        exec_spec=exec_spec, temperature=args.temperature, seed=args.seed,
        fused=not args.loop_decode, prepare_weights=args.prepare_weights,
        mesh=mesh, compress_tp=args.compress_tp, profile=args.profile,
    )
    reqs = [
        Request(i, [1 + (i * 7 + j) % (cfg.vocab - 1) for j in range(1 + i % 4)],
                max_new=2 + i % args.max_new)
        for i in range(args.requests)
    ]
    for r in reqs:
        batcher.submit(r)
    t0 = time.perf_counter()
    batcher.run()
    dt = time.perf_counter() - t0
    toks = sum(len(r.generated) for r in reqs)
    stats = batcher.stats()
    print(f"[serve] {len(reqs)} requests, {toks} tokens, {dt:.2f}s "
          f"({toks / max(dt, 1e-9):.1f} tok/s functional-CPU), "
          f"{stats['decode_steps']} decode steps, "
          f"{stats['host_syncs']} host syncs "
          f"({'looped' if args.loop_decode else 'fused'} decode"
          + (f", tp={args.tp}" + (" int8-compressed" if args.compress_tp else "")
             if args.tp > 1 else "") + ")")
    if args.profile:
        n_ev = len(batcher.profiler.events)
        print(f"[serve] profile: {n_ev} trace events -> {args.profile}")
    assert all(r.done for r in reqs)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
