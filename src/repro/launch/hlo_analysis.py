"""Whole-program HLO accounting: FLOPs / HBM bytes / collective bytes.

Why this exists: XLA:CPU's ``compiled.cost_analysis()`` counts a while
body exactly once (verified — a 10-iteration scan reports 1/10 of the
true FLOPs), which makes it useless for scan-over-layers models. This
module parses the *optimized* HLO text (``compiled.as_text()``), builds
the computation graph, and walks it with while-loop trip-count
multipliers (``backend_config={"known_trip_count":...}``) to produce:

  * ``flops``      — 2*M*N*K for every dot (incl. inside fusions/loops),
  * ``hbm_bytes``  — per top-level op: operand + result bytes (the fused-
                     kernel HBM traffic model); dynamic-update-slice
                     counts only the updated slice (XLA performs it in
                     place); bookkeeping ops (tuple/gte/bitcast/parameter)
                     are free,
  * ``coll_bytes`` — ring-model bytes per collective op type x trip count.

All numbers are per-partition (the SPMD module is per-device), which is
exactly what the per-chip roofline terms need.
"""
from __future__ import annotations

import collections
import dataclasses
import json
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "s4": 1, "u4": 1,
    "token": 0, "s2": 1, "u2": 1,
}

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\(?[^=]*?\)?)\s+([\w\-]+)\((.*)$"
)
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s+->\s+.*\{")
_TRIP_RE = re.compile(r'known_trip_count[\\\"={:]+n[\\\"]*:[\\\"]*(\d+)')
_GROUPS_RE = re.compile(r"replica_groups=\{(\{[^=]*?\})\}")
_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")

COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)
_FREE_OPS = {
    "tuple", "get-tuple-element", "bitcast", "parameter", "constant",
    "after-all", "partition-id", "replica-id", "iota", "broadcast",
    "reshape",
}


def _shape_dims(text: str) -> List[Tuple[str, List[int]]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        out.append((dt, [int(d) for d in dims.split(",") if d] if dims else []))
    return out


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _shape_dims(text):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class Instr:
    name: str
    result_type: str
    op: str
    args_text: str      # everything after the '(' of the op
    line: str
    is_root: bool = False

    @property
    def result_bytes(self) -> int:
        return _shape_bytes(self.result_type)

    def operand_names(self) -> List[str]:
        # operands are %refs before the closing paren at depth 0
        depth = 0
        out = []
        for m in re.finditer(r"%([\w.\-]+)|[()]", self.args_text):
            t = m.group(0)
            if t == "(":
                depth += 1
            elif t == ")":
                if depth == 0:
                    break
                depth -= 1
            else:
                out.append(m.group(1))
        return out

    def called_computations(self) -> List[str]:
        out = []
        for key in ("calls=", "body=", "condition=", "to_apply=",
                    "branch_computations={"):
            idx = self.line.find(key)
            if idx < 0:
                continue
            rest = self.line[idx + len(key):]
            for m in re.finditer(r"%([\w.\-]+)", rest[: rest.find("}") + 1 or None]):
                out.append(m.group(1))
                if key not in ("branch_computations={",):
                    break
        return out


@dataclasses.dataclass
class Computation:
    name: str
    instrs: List[Instr]
    shapes: Dict[str, str]  # instr name -> result type text


def parse_hlo(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    entry_name = None
    comment_re = re.compile(r"/\*.*?\*/")
    for raw in text.splitlines():
        line = comment_re.sub("", raw.rstrip())
        stripped = line.strip()
        if not stripped:
            continue
        m = _COMP_RE.match(stripped)
        # Header lines end with '{' and are not instruction assignments.
        # (Tuple parameter lists may contain '/*index=N*/' comments, so a
        # bare '=' test is not sufficient — look for ' = ' assignment.)
        if m and stripped.endswith("{") and " = " not in stripped.split(" -> ")[0]:
            cur = Computation(m.group(1), [], {})
            comps[cur.name] = cur
            if stripped.startswith("ENTRY"):
                entry_name = cur.name
            continue
        if stripped == "}":
            cur = None
            continue
        if cur is None:
            continue
        im = _INSTR_RE.match(stripped)
        if im:
            name, rtype, op, args = im.groups()
            inst = Instr(
                name, rtype.strip(), op, args, stripped,
                is_root=stripped.startswith("ROOT"),
            )
            cur.instrs.append(inst)
            cur.shapes[name] = rtype.strip()
    if entry_name:
        comps["__entry__"] = comps[entry_name]
    return comps


def _dot_flops(inst: Instr, shapes: Dict[str, str]) -> float:
    ops = inst.operand_names()
    if len(ops) < 2:
        return 0.0
    lhs_t = shapes.get(ops[0], "")
    dims = _shape_dims(lhs_t)
    if not dims:
        return 0.0
    lhs_dims = dims[0][1]
    cm = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", inst.line)
    contract = 1
    if cm and cm.group(1):
        for d in cm.group(1).split(","):
            if int(d) < len(lhs_dims):
                contract *= lhs_dims[int(d)]
    res = _shape_dims(inst.result_type)
    numel = 1
    for d in (res[0][1] if res else []):
        numel *= d
    return 2.0 * numel * contract


def _conv_flops(inst: Instr, shapes: Dict[str, str]) -> float:
    ops = inst.operand_names()
    if len(ops) < 2:
        return 0.0
    k = _shape_dims(shapes.get(ops[1], ""))
    res = _shape_dims(inst.result_type)
    if not k or not res:
        return 0.0
    kn = 1
    for d in k[0][1]:
        kn *= d
    rn = 1
    for d in res[0][1]:
        rn *= d
    # flops ~= 2 * out_numel * kernel_numel / out_channels (approximation)
    out_ch = res[0][1][-1] if res[0][1] else 1
    return 2.0 * rn * kn / max(out_ch, 1)


def _group_size(line: str, default: int) -> int:
    m = _IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_RE.search(line)
    if m:
        first = m.group(1)
        return len([x for x in first.strip("{}").split(",") if x.strip()])
    return default


def _collective_moved(inst: Instr, n_devices: int) -> Tuple[str, float]:
    op = inst.op.replace("-start", "")
    nbytes = inst.result_bytes
    # start ops return tuple (in, out buffers) — halve to the payload
    if inst.op.endswith("-start") and inst.result_type.startswith("("):
        nbytes = nbytes / 2
    n = _group_size(inst.line, n_devices)
    if n <= 1:
        return op, 0.0
    if op == "all-gather":
        return op, nbytes * (n - 1) / n
    if op == "reduce-scatter":
        return op, nbytes * (n - 1)
    if op == "all-reduce":
        return op, 2 * nbytes * (n - 1) / n
    if op == "all-to-all":
        return op, nbytes * (n - 1) / n
    return op, nbytes  # collective-permute


@dataclasses.dataclass
class HloCost:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    coll: Dict[str, float] = dataclasses.field(
        default_factory=lambda: collections.defaultdict(float)
    )
    dot_flops_by_meta: Dict[str, float] = dataclasses.field(
        default_factory=lambda: collections.defaultdict(float)
    )

    @property
    def coll_bytes(self) -> float:
        return float(sum(self.coll.values()))


def _trip_count(inst: Instr) -> int:
    m = _TRIP_RE.search(inst.line)
    return int(m.group(1)) if m else 1


def analyze(text: str, n_devices: int) -> HloCost:
    comps = parse_hlo(text)
    cost = HloCost()
    if "__entry__" not in comps:
        return cost

    memo_flops: Dict[str, float] = {}

    def comp_flops(cname: str) -> float:
        """FLOPs of one execution of computation ``cname`` (recursive)."""
        if cname in memo_flops:
            return memo_flops[cname]
        comp = comps.get(cname)
        if comp is None:
            return 0.0
        memo_flops[cname] = 0.0  # cycle guard
        total = 0.0
        for inst in comp.instrs:
            if inst.op == "dot":
                total += _dot_flops(inst, comp.shapes)
            elif inst.op == "convolution":
                total += _conv_flops(inst, comp.shapes)
            elif inst.op == "while":
                called = inst.called_computations()
                trip = _trip_count(inst)
                for c in called:
                    total += comp_flops(c) * trip
            elif inst.op in ("fusion", "call", "custom-call", "conditional",
                             "async-start"):
                for c in inst.called_computations():
                    total += comp_flops(c)
        memo_flops[cname] = total
        return total

    _SLICERS = ("dynamic-slice", "gather", "dynamic-update-slice")

    def _dus_update_bytes(inst: Instr, shapes) -> int:
        ops = inst.operand_names()
        upd = shapes.get(ops[1], "") if len(ops) > 1 else ""
        return 2 * _shape_bytes(upd) if upd else inst.result_bytes

    def _fusion_bytes(inst: Instr, shapes) -> float:
        """HBM traffic of a fusion: inputs whose only uses are
        slice/gather ops stream just the touched slices; the output is the
        result (or the update slice for a DUS root — in-place)."""
        called = inst.called_computations()
        comp = comps.get(called[0]) if called else None
        if comp is None:
            operand_bytes = sum(_shape_bytes(shapes.get(o, "")) for o in inst.operand_names())
            return inst.result_bytes + operand_bytes
        uses: Dict[str, List[Instr]] = {}
        for ii in comp.instrs:
            for o in ii.operand_names():
                uses.setdefault(o, []).append(ii)
        total = 0.0
        root = next((ii for ii in comp.instrs if ii.is_root), comp.instrs[-1])
        for ii in comp.instrs:
            if ii.op != "parameter":
                continue
            us = uses.get(ii.name, [])
            if us and all(u.op in _SLICERS for u in us):
                for u in us:
                    if u.op == "dynamic-update-slice":
                        total += _dus_update_bytes(u, comp.shapes) / 2  # read side
                    else:
                        total += u.result_bytes
            else:
                total += ii.result_bytes
        if root.op == "dynamic-update-slice":
            total += _dus_update_bytes(root, comp.shapes) / 2  # write side
        else:
            total += root.result_bytes
        return total

    def walk_bytes(cname: str, mult: float):
        comp = comps.get(cname)
        if comp is None:
            return
        for inst in comp.instrs:
            opname = inst.op.replace("-start", "")
            if opname in COLLECTIVES:
                op, moved = _collective_moved(inst, n_devices)
                cost.coll[op] += moved * mult
                continue
            if inst.op == "while":
                trip = _trip_count(inst)
                for c in inst.called_computations():
                    walk_bytes(c, mult * trip)
                continue
            if inst.op in ("call", "conditional", "async-start"):
                for c in inst.called_computations():
                    walk_bytes(c, mult)
                continue
            if inst.op in _FREE_OPS or inst.op.endswith("-done"):
                continue
            if inst.op == "fusion":
                cost.hbm_bytes += _fusion_bytes(inst, comp.shapes) * mult
                continue
            if inst.op == "dynamic-update-slice":
                cost.hbm_bytes += _dus_update_bytes(inst, comp.shapes) * mult
                continue
            if inst.op in ("dynamic-slice", "gather"):
                cost.hbm_bytes += 2 * inst.result_bytes * mult
                continue
            operand_bytes = 0
            for o in inst.operand_names():
                operand_bytes += _shape_bytes(comp.shapes.get(o, ""))
            cost.hbm_bytes += (inst.result_bytes + operand_bytes) * mult

    cost.flops = comp_flops("__entry__")
    walk_bytes("__entry__", 1.0)
    return cost
