"""Roofline term extraction from compiled dry-run artifacts.

Hardware constants are the assignment's TPU v5e-class numbers. The three
terms per (arch x shape x mesh):

    T_compute = HLO_FLOPs   / (chips * PEAK_FLOPS)
    T_memory  = HLO_bytes   / (chips * HBM_BW)
    T_coll    = coll_bytes  / (chips * ICI_BW)   [per-device link-serialized]

HLO_FLOPs / bytes come from ``compiled.cost_analysis()`` (whole-program,
all partitions; we divide by chip count). Collective bytes are parsed
from the optimized HLO text with ring-algorithm accounting per op type
(XLA does not expose them via cost_analysis).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

PEAK_FLOPS = 197e12      # bf16 per chip
HBM_BW = 819e9           # bytes/s per chip
ICI_BW = 50e9            # bytes/s per link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{?\{([^}]*)\}")
_IOTA_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")

COLLECTIVE_OPS = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str, default: int) -> int:
    m = _IOTA_GROUPS_RE.search(line)
    if m:
        return int(m.group(2))  # [ngroups, group_size]
    m = _GROUPS_RE.search(line)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip() != ""])
    return default


def collective_bytes(hlo_text: str, n_devices: int) -> Dict[str, float]:
    """Per-device bytes moved over ICI, by collective type (ring model)."""
    out: Dict[str, float] = {op: 0.0 for op in COLLECTIVE_OPS}
    for line in hlo_text.splitlines():
        s = line.strip()
        if "=" not in s:
            continue
        rhs = s.split("=", 1)[1]
        opm = re.search(r"\b(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)(-start)?\(", rhs)
        if not opm:
            continue
        op = opm.group(1)
        if f"{op}-done" in rhs:
            continue
        # result shape(s) are on the lhs of the op name in `rhs` prefix
        result_part = rhs.split(opm.group(0))[0]
        nbytes = _shape_bytes(result_part)
        if nbytes == 0:
            continue
        n = _group_size(s, n_devices)
        if n <= 1:
            continue
        if op == "all-gather":
            moved = nbytes * (n - 1) / n
        elif op == "reduce-scatter":
            moved = nbytes * (n - 1)            # input = result * n
        elif op == "all-reduce":
            moved = 2 * nbytes * (n - 1) / n
        elif op == "all-to-all":
            moved = nbytes * (n - 1) / n
        else:  # collective-permute
            moved = nbytes
        out[op] += moved
    out["total"] = sum(out[o] for o in COLLECTIVE_OPS)
    return out


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops: float
    bytes_accessed: float
    coll_bytes: float           # per-device
    coll_breakdown: Dict[str, float]
    model_flops: float          # 6*N*D (or 6*N_active*D) useful flops
    bytes_per_device: Optional[float] = None
    # execution-spec -> array-design cost mapping (repro.hw via
    # repro.core.execution.spec_cost_summary); None for fp cells
    cim_array: Optional[Dict[str, float]] = None
    # canonical name of the ArraySpec the cell was costed on (None when
    # no --array-spec binding was given — default-geometry 8T-SRAM)
    array_spec: Optional[str] = None

    @property
    def t_compute(self) -> float:
        return self.flops / (self.chips * PEAK_FLOPS)

    @property
    def t_memory(self) -> float:
        return self.bytes_accessed / (self.chips * HBM_BW)

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {
            "compute": self.t_compute,
            "memory": self.t_memory,
            "collective": self.t_collective,
        }
        return max(terms, key=terms.get)

    @property
    def roofline_fraction(self) -> float:
        """How close the step is to the compute roofline: T_comp / max(T)."""
        peak = max(self.t_compute, self.t_memory, self.t_collective, 1e-30)
        return self.t_compute / peak

    @property
    def useful_flops_ratio(self) -> float:
        return self.model_flops / max(self.flops, 1.0)

    def to_dict(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "hlo_flops": self.flops,
            "hlo_bytes": self.bytes_accessed,
            "coll_bytes_per_device": self.coll_bytes,
            "coll_breakdown": self.coll_breakdown,
            "model_flops": self.model_flops,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "roofline_fraction": self.roofline_fraction,
            "useful_flops_ratio": self.useful_flops_ratio,
            "bytes_per_device": self.bytes_per_device,
            "cim_array": self.cim_array,
            "array_spec": self.array_spec,
        }


def model_flops_estimate(cfg, shape, kind: str) -> float:
    """MODEL_FLOPS = 6*N*D for training, 2*N*D for inference tokens
    (N = active params)."""
    n_active = cfg.active_param_count()
    if kind == "train":
        tokens = shape.batch * shape.seq
        return 6.0 * n_active * tokens
    if kind == "prefill":
        tokens = shape.batch * shape.seq
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * shape.batch


def fmt_table(rows) -> str:
    hdr = (
        f"{'arch':<18} {'shape':<12} {'mesh':<10} {'Tcomp(s)':>10} {'Tmem(s)':>10} "
        f"{'Tcoll(s)':>10} {'bneck':>10} {'roofl%':>7} {'useful%':>8}"
    )
    lines = [hdr, "-" * len(hdr)]
    for r in rows:
        lines.append(
            f"{r.arch:<18} {r.shape:<12} {r.mesh:<10} {r.t_compute:>10.3e} "
            f"{r.t_memory:>10.3e} {r.t_collective:>10.3e} {r.bottleneck:>10} "
            f"{100*r.roofline_fraction:>6.1f} {100*r.useful_flops_ratio:>7.1f}"
        )
    return "\n".join(lines)
