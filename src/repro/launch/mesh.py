"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (importing this module never
touches jax device state). The dry-run launcher sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 *before* any jax
import so 512 placeholder CPU devices exist for the 16x16 / 2x16x16
meshes.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_smoke_mesh():
    """1-device mesh with the production axis names (CPU tests)."""
    n = len(jax.devices())
    if n >= 4:
        return jax.make_mesh((n // 2, 2), ("data", "model"))
    return jax.make_mesh((1, 1), ("data", "model"))


def mesh_batch_divisor(mesh) -> int:
    """Product of the data-like axis sizes (batch must divide this to be
    batch-sharded; shard_act falls back to replicated otherwise)."""
    d = 1
    for ax in ("pod", "data"):
        if ax in mesh.axis_names:
            d *= mesh.shape[ax]
    return d
