"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (importing this module never
touches jax device state). The dry-run launcher sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 *before* any jax
import so 512 placeholder CPU devices exist for the 16x16 / 2x16x16
meshes.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_tp_mesh(tp: int):
    """(1, tp) mesh with the production axis names ("data", "model") over
    the first ``tp`` local devices — the serving tensor-parallel mesh
    (``launch.serve --tp N`` / ``ContinuousBatcher(mesh=...)``).

    Uses an explicit device subset (``jax.make_mesh`` insists on
    consuming every device): TP tests carve 2- and 4-way meshes out of
    the 8 forced host devices, and a real deployment may reserve devices
    for other model replicas.
    """
    import numpy as np

    devs = jax.devices()
    if tp < 1:
        raise ValueError(f"tp must be >= 1, got {tp}")
    if len(devs) < tp:
        raise ValueError(
            f"tp={tp} needs {tp} devices but only {len(devs)} are visible "
            "(on CPU set XLA_FLAGS=--xla_force_host_platform_device_count="
            f"{tp} before the first jax import)"
        )
    return jax.sharding.Mesh(
        np.asarray(devs[:tp]).reshape(1, tp), ("data", "model")
    )


def make_replica_meshes(replicas: int, tp: int):
    """One ``(1, tp)`` serving mesh per router replica, carved from
    disjoint rows of the ``(replicas, tp)`` device grid
    (:func:`repro.dist.sharding.replica_device_groups`) — the front
    door's replication axis is the grid's ``"data"`` row dimension,
    while every per-replica mesh keeps the production axis names
    ``("data", "model")`` so the engine's TP sharding specs apply
    unchanged inside each replica."""
    import numpy as np

    from repro.dist.sharding import replica_device_groups

    groups = replica_device_groups(replicas, tp)
    return [
        jax.sharding.Mesh(np.asarray(g).reshape(1, tp), ("data", "model"))
        for g in groups
    ]


def make_smoke_mesh():
    """1-device mesh with the production axis names (CPU tests)."""
    n = len(jax.devices())
    if n >= 4:
        return jax.make_mesh((n // 2, 2), ("data", "model"))
    return jax.make_mesh((1, 1), ("data", "model"))


def mesh_batch_divisor(mesh) -> int:
    """Product of the data-like axis sizes (batch must divide this to be
    batch-sharded; shard_act falls back to replicated otherwise)."""
    d = 1
    for ax in ("pod", "data"):
        if ax in mesh.axis_names:
            d *= mesh.shape[ax]
    return d
