import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("REPRO_EXTRA_XLA_FLAGS", "")
)

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST stay first — jax locks the device count at first
init. Usage:

    PYTHONPATH=src python -m repro.launch.dryrun --arch yi-34b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod both \
        --out results/dryrun

Per cell this:
  1. builds the production mesh (16x16, and 2x16x16 with --multi-pod),
  2. constructs ShapeDtypeStruct stand-ins for every input (weights via
     jax.eval_shape over init — no allocation anywhere),
  3. jit(train_step/serve_step, in_shardings, out_shardings)
       .lower(...).compile(),
  4. prints memory_analysis + cost_analysis and writes the roofline JSON.
"""
import argparse
import dataclasses
import functools
import json
import sys
import time
import traceback
from typing import Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.dist import sharding as shd
from repro.launch import hlo_analysis
from repro.launch import roofline as rl
from repro.launch.mesh import make_production_mesh, mesh_batch_divisor
from repro.models import transformer as T
from repro.models.registry import SHAPES, ShapeCell, cell_supported, get_config, input_specs
from repro.optim.adamw import AdamWConfig
import importlib
ts = importlib.import_module('repro.train.train_step')


def _ns(mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda s: isinstance(s, P),
    )


def state_specs(cfg: ArchConfig):
    """ShapeDtypeStruct tree of the TrainState — zero allocation."""
    return jax.eval_shape(
        functools.partial(ts.init_train_state, cfg=cfg), jax.random.PRNGKey(0)
    )


def train_shardings(cfg: ArchConfig, mesh, state_shapes):
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    pspec = shd.param_specs(state_shapes.params, axis_sizes=axis_sizes)
    opt_spec = ts.TrainState(
        params=pspec,
        opt=type(state_shapes.opt)(
            step=P(), mu=pspec, nu=pspec
        ),
        rng=P(),
        residual=None if state_shapes.residual is None else shd.param_specs(
            state_shapes.residual, axis_sizes=axis_sizes),
    )
    return _ns(mesh, opt_spec)


def batch_shardings(cfg: ArchConfig, mesh, specs: Dict, batch: int):
    daxes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    dprod = 1
    for a in daxes:
        dprod *= mesh.shape[a]
    b = daxes if batch % dprod == 0 and dprod > 1 else None
    out = {}
    for k, v in specs.items():
        out[k] = NamedSharding(mesh, P(*((b,) + (None,) * (len(v.shape) - 1))))
    return out


@dataclasses.dataclass
class CellResult:
    arch: str
    shape: str
    mesh_name: str
    ok: bool
    seconds: float
    error: Optional[str] = None
    roofline: Optional[dict] = None
    memory_analysis: Optional[str] = None
    #: measured-cost score (launch.hillclimb.score_cell) when the cell
    #: was driven with --calibration; None for analytic-only runs
    calibrated: Optional[dict] = None


def lower_cell(
    arch: str,
    shape_name: str,
    multi_pod: bool = False,
    quant_mode: Optional[str] = None,
    remat: Optional[bool] = None,
    verbose: bool = True,
    extra_tag: str = "",
    cfg_overrides: Optional[dict] = None,
    quant_overrides: Optional[dict] = None,
    fsdp: bool = False,
    array_spec=None,
) -> CellResult:
    # resolve the hardware binding first: a typo'd --array-spec dies with
    # the registered sets listed, before any compile work
    from repro import hw

    if isinstance(array_spec, str):
        array_spec = hw.parse_array_spec(array_spec)
    cfg = get_config(arch)
    if quant_mode is not None:
        cfg = cfg.replace(quant=dataclasses.replace(cfg.quant, mode=quant_mode))
    if quant_overrides:
        cfg = cfg.replace(quant=dataclasses.replace(cfg.quant, **quant_overrides))
    if cfg_overrides:
        cfg = cfg.replace(**cfg_overrides)
    if remat is not None:
        cfg = cfg.replace(remat=remat)
    shape = SHAPES[shape_name]
    mesh_name = ("2x16x16" if multi_pod else "16x16") + extra_tag
    skip = cell_supported(cfg, shape)
    if skip:
        return CellResult(arch, shape_name, mesh_name, ok=True, seconds=0.0,
                          error=f"SKIP: {skip}")
    t0 = time.time()
    from repro.models import layers as _L
    _L.set_native_accum(True)  # TPU-target HLO: bf16 operands, f32 accum
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.size
    shd.enable_activation_sharding(
        multi_pod=multi_pod, batch_divisor=mesh_batch_divisor(mesh),
        model_size=mesh.shape["model"],
    )
    try:
        specs = input_specs(cfg, shape)
        if shape.kind == "train":
            state_shapes = state_specs(cfg)
            state_sh = train_shardings(cfg, mesh, state_shapes)
            batch_sh = batch_shardings(cfg, mesh, specs, shape.batch)
            opt_cfg = AdamWConfig()

            def step(state, batch):
                return ts.train_step(state, batch, cfg, opt_cfg)

            with shd.use_mesh(mesh):
                lowered = jax.jit(
                    step,
                    in_shardings=(state_sh, batch_sh),
                    out_shardings=(state_sh, None),
                    donate_argnums=(0,),
                ).lower(state_shapes, specs)
        elif shape.kind == "prefill":
            params_shapes = jax.eval_shape(
                functools.partial(T.init_params, cfg=cfg), jax.random.PRNGKey(0)
            )
            params_sh = _ns(mesh, shd.param_specs(
                params_shapes, axis_sizes=dict(zip(mesh.axis_names, mesh.devices.shape))))
            batch_sh = batch_shardings(cfg, mesh, specs, shape.batch)

            def step(params, batch):
                return T.forward(params, batch, cfg)

            with shd.use_mesh(mesh):
                lowered = jax.jit(
                    step, in_shardings=(params_sh, batch_sh)
                ).lower(params_shapes, specs)
        else:  # decode
            params_shapes = jax.eval_shape(
                functools.partial(T.init_params, cfg=cfg), jax.random.PRNGKey(0)
            )
            params_sh = _ns(mesh, shd.param_specs(
                params_shapes, fsdp=fsdp,
                axis_sizes=dict(zip(mesh.axis_names, mesh.devices.shape))))
            cache_shapes = jax.eval_shape(
                functools.partial(T.init_caches, cfg, shape.batch, shape.seq)
            )
            cache_sh = _ns(mesh, shd.cache_specs(cache_shapes, mesh, shape.batch))
            batch_sh = batch_shardings(cfg, mesh, specs, shape.batch)
            enc_in_specs = "enc" in specs
            tok_spec = specs["tokens"]

            def step(params, tokens, caches, index, enc=None):
                from repro.serve.engine import serve_step

                return serve_step(params, tokens, caches, index, cfg, enc)

            args = [params_shapes, tok_spec, cache_shapes,
                    jax.ShapeDtypeStruct((), jnp.int32)]
            in_sh = [params_sh, batch_sh["tokens"], cache_sh, None]
            if enc_in_specs:
                args.append(specs["enc"])
                in_sh.append(batch_sh["enc"])
            with shd.use_mesh(mesh):
                lowered = jax.jit(
                    step,
                    in_shardings=tuple(in_sh),
                    out_shardings=(None, cache_sh),
                    donate_argnums=(2,),
                ).lower(*args)

        compiled = lowered.compile()
        mem = None
        try:
            ma = compiled.memory_analysis()
            mem = str(ma)
        except Exception:
            pass
        hlo = compiled.as_text()
        # Whole-program accounting with while-loop trip counts; the SPMD
        # module is per-device, so flops/bytes are per-chip already (see
        # launch/hlo_analysis.py for why compiled.cost_analysis() cannot
        # be used on this backend).
        hc = hlo_analysis.analyze(hlo, chips)
        # execution-spec -> hardware mapping: which array design (NM /
        # CiM-I / CiM-II) this cell's MACs would execute on — bound to
        # the --array-spec hardware when given — with the Figs
        # 9/11-calibrated per-MAC-pass cost attached.
        cim_array = None
        if cfg.quant.mode != "off":
            from repro.core import execution as xapi

            cim_array = xapi.spec_cost_summary(
                cfg.quant.resolved_spec(), array=array_spec)
        roof = rl.Roofline(
            arch=arch, shape=shape_name, mesh=mesh_name, chips=chips,
            flops=hc.flops * chips,            # whole-job FLOPs
            bytes_accessed=hc.hbm_bytes * chips,
            coll_bytes=hc.coll_bytes,          # per-device
            coll_breakdown=dict(hc.coll),
            model_flops=rl.model_flops_estimate(cfg, shape, shape.kind),
            cim_array=cim_array,
            array_spec=None if array_spec is None else array_spec.name,
        )
        res = CellResult(
            arch, shape_name, mesh_name, ok=True, seconds=time.time() - t0,
            roofline=roof.to_dict(), memory_analysis=mem,
        )
        if verbose:
            print(f"[dryrun] {arch} {shape_name} {mesh_name}: OK "
                  f"({res.seconds:.1f}s) bottleneck={roof.bottleneck} "
                  f"Tc={roof.t_compute:.3e} Tm={roof.t_memory:.3e} "
                  f"Tx={roof.t_collective:.3e}")
            if mem:
                print(f"  memory: {mem}")
        return res
    except Exception as e:
        if verbose:
            traceback.print_exc()
        return CellResult(arch, shape_name, mesh_name, ok=False,
                          seconds=time.time() - t0, error=f"{type(e).__name__}: {e}")
    finally:
        shd.disable_activation_sharding()


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", choices=["off", "on", "both"], default="off")
    ap.add_argument("--quant", default=None,
                    choices=[None, "off", "ternary", "cim", "cim_fused"])
    ap.add_argument("--array-spec", default=None,
                    help="hardware binding for cost cells: "
                         "TECH[/DESIGN][/RxC][/aN][/pP], e.g. 3T-FEMFET/CiM-I "
                         "(see repro.hw; design is overridden by the "
                         "cell's execution spec)")
    ap.add_argument("--out", default=None, help="directory for per-cell JSON")
    args = ap.parse_args(argv)

    if args.array_spec is not None:
        from repro import hw

        try:
            hw.parse_array_spec(args.array_spec)
        except ValueError as e:
            ap.error(f"bad --array-spec: {e}")

    from repro.models.registry import ARCH_IDS

    cells = []
    archs = ARCH_IDS if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    pods = {"off": [False], "on": [True], "both": [False, True]}[args.multi_pod]
    failures = 0
    for arch in archs:
        for shape in shapes:
            for mp in pods:
                res = lower_cell(arch, shape, multi_pod=mp, quant_mode=args.quant,
                                 array_spec=args.array_spec)
                cells.append(res)
                failures += 0 if res.ok else 1
                if args.out:
                    os.makedirs(args.out, exist_ok=True)
                    tag = f"{arch}__{shape}__{res.mesh_name}"
                    if args.quant:
                        tag += f"__{args.quant}"
                    with open(os.path.join(args.out, tag + ".json"), "w") as f:
                        json.dump(dataclasses.asdict(res), f, indent=1)
    print(f"\n[dryrun] {len(cells)} cells, {failures} failures")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
