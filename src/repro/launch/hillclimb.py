"""Perf-iteration driver (§Perf in EXPERIMENTS.md).

Runs one hillclimb cell — a (arch, shape) pair with config overrides —
through the dry-run lowering and records the roofline JSON:

    PYTHONPATH=src python -m repro.launch.hillclimb \
        --arch starcoder2-7b --shape train_4k --name A1 \
        --quant cim_fused --cfg '{"attn_chunk": 2048}' \
        --qc '{"pre_quantized": true}' --out results/perf

The methodology (hypothesis -> change -> re-lower -> record) and the full
iteration log live in EXPERIMENTS.md §Perf.
"""
import os

os.environ.setdefault(
    "XLA_FLAGS", "--xla_force_host_platform_device_count=512"
)

import argparse
import dataclasses
import json


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--name", required=True)
    ap.add_argument("--quant", default=None)
    ap.add_argument("--cfg", default=None, help="JSON ArchConfig overrides")
    ap.add_argument("--qc", default=None, help="JSON QuantConfig overrides")
    ap.add_argument("--array-spec", default=None,
                    help="hardware binding: TECH[/DESIGN][/RxC][/aN][/pP] "
                         "(e.g. 3T-FEMFET/CiM-I); recorded in the "
                         "roofline JSON so perf cells say what hardware "
                         "they were costed on")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--fsdp", action="store_true")
    ap.add_argument("--out", default="results/perf")
    args = ap.parse_args(argv)

    # Validate registry-facing arguments up front with the valid sets in
    # the message — an unknown arch used to die as a bare KeyError deep
    # inside importlib, an unknown shape as a KeyError in SHAPES.
    from repro.models.registry import ARCH_IDS, SHAPES

    if args.arch not in ARCH_IDS:
        ap.error(f"unknown --arch {args.arch!r}; registered archs: "
                 f"{', '.join(ARCH_IDS)}")
    if args.shape not in SHAPES:
        ap.error(f"unknown --shape {args.shape!r}; registered shapes: "
                 f"{', '.join(SHAPES)}")
    if args.array_spec is not None:
        from repro import hw

        try:
            hw.parse_array_spec(args.array_spec)
        except ValueError as e:
            ap.error(f"bad --array-spec: {e}")

    from repro.launch.dryrun import lower_cell

    res = lower_cell(
        args.arch,
        args.shape,
        multi_pod=args.multi_pod,
        quant_mode=args.quant,
        cfg_overrides=json.loads(args.cfg) if args.cfg else None,
        quant_overrides=json.loads(args.qc) if args.qc else None,
        fsdp=args.fsdp,
        array_spec=args.array_spec,
    )
    os.makedirs(args.out, exist_ok=True)
    path = os.path.join(args.out, f"{args.arch}__{args.shape}__{args.name}.json")
    with open(path, "w") as f:
        json.dump(dataclasses.asdict(res), f, indent=1)
    print("saved", path)
    if res.roofline:
        r = res.roofline
        print(
            f"Tc={r['t_compute_s']:.3e} Tm={r['t_memory_s']:.3e} "
            f"Tx={r['t_collective_s']:.3e} bottleneck={r['bottleneck']}"
        )
        return 0
    print("ERROR:", res.error)
    return 1


if __name__ == "__main__":
    raise SystemExit(main())
