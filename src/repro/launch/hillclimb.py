"""Perf-iteration driver (§Perf in EXPERIMENTS.md).

Runs one hillclimb cell — a (arch, shape) pair with config overrides —
through the dry-run lowering and records the roofline JSON:

    PYTHONPATH=src python -m repro.launch.hillclimb \
        --arch starcoder2-7b --shape train_4k --name A1 \
        --quant cim_fused --cfg '{"attn_chunk": 2048}' \
        --qc '{"pre_quantized": true}' --out results/perf

With ``--calibration PATH`` (a saved
:class:`repro.profile.calibrate.CalibrationTable`) the cell is
additionally *scored* with the fitted per-(exec-spec, shape-class)
kernel costs — the measured analog of the analytic roofline: the cell's
weight-bearing GEMM workload (``hw.workload.workload_layers``) is costed
through ``predict_gemm_us`` and the score lands in the cell JSON under
``"calibrated"``. Scores whose consulted fits carry a residual above
``RESIDUAL_GATE_PCT`` are marked untrusted (``"trusted": false``) —
:func:`rank_candidates` sorts them last so a noisy fit never silently
reorders a perf iteration.

The methodology (hypothesis -> change -> re-lower -> record) and the full
iteration log live in EXPERIMENTS.md §Perf.
"""
import os

os.environ.setdefault(
    "XLA_FLAGS", "--xla_force_host_platform_device_count=512"
)

import argparse
import dataclasses
import json

#: fits with a median relative error above this are scoring-ineligible:
#: the score is still reported, but flagged untrusted and ranked last
RESIDUAL_GATE_PCT = 25.0


def score_cell(arch, shape, table, spec=None,
               residual_gate_pct: float = RESIDUAL_GATE_PCT) -> dict:
    """Score one (arch, shape) cell with a fitted calibration table.

    Costs every weight-bearing GEMM of one forward
    (``hw.workload.workload_layers`` — the same workload the analytic
    system projection uses) through ``table.predict_gemm_us`` under
    ``spec`` (default: the table's ``default_spec``), dispatched per
    layer by shape class exactly like the execution API. Returns::

        {"spec", "predicted_us", "layers", "classes",
         "worst_residual_pct", "trusted"}

    ``trusted`` is False when any consulted fit's ``residual_pct``
    exceeds ``residual_gate_pct`` (or a shape class had to borrow the
    other class's fit) — the fit may rank candidates wrong, so
    :func:`rank_candidates` pushes such scores below every trusted one.
    """
    from repro.hw.workload import _resolve, workload_layers
    from repro.profile.calibrate import DECODE_M_MAX, kernel_key

    cfg, shape_cell = _resolve(arch, shape)
    layers = workload_layers(cfg, shape_cell)
    spec = spec or table.default_spec
    total = 0.0
    classes = set()
    worst = 0.0
    trusted = True
    for layer, count in layers:
        cls = "decode" if layer.m <= DECODE_M_MAX else "prefill"
        classes.add(cls)
        fit = table.kernels.get(kernel_key(spec, cls))
        if fit is None:
            # predict_gemm_us borrows the other class's fit — usable,
            # but extrapolated: never trust a ranking built on it
            trusted = False
            other = "prefill" if cls == "decode" else "decode"
            fit = table.kernels.get(kernel_key(spec, other))
        if fit is None:
            known = ", ".join(sorted(table.kernels))
            raise KeyError(f"no kernel fit for spec {spec!r} (known: {known})")
        worst = max(worst, float(fit.residual_pct))
        total += fit.predict_us(layer.m, layer.k, layer.n) * count
    if worst > residual_gate_pct:
        trusted = False
    return {
        "spec": spec,
        "predicted_us": round(total, 3),
        "layers": len(layers),
        "classes": sorted(classes),
        "worst_residual_pct": worst,
        "trusted": trusted,
    }


def rank_candidates(candidates, table,
                    residual_gate_pct: float = RESIDUAL_GATE_PCT) -> list:
    """Rank perf-iteration candidates by fitted cost, fastest first.

    ``candidates``: iterable of ``(name, arch, shape)`` or
    ``(name, arch, shape, spec)`` tuples. Returns
    ``[(name, score_dict), ...]`` sorted by ``predicted_us`` ascending
    with every untrusted score (high-residual or borrowed-class fit)
    after every trusted one, so calibration noise cannot promote a
    candidate."""
    scored = []
    for cand in candidates:
        name, arch, shape = cand[0], cand[1], cand[2]
        spec = cand[3] if len(cand) > 3 else None
        scored.append((name, score_cell(
            arch, shape, table, spec=spec,
            residual_gate_pct=residual_gate_pct)))
    return sorted(scored,
                  key=lambda ns: (not ns[1]["trusted"], ns[1]["predicted_us"]))


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--name", required=True)
    ap.add_argument("--quant", default=None)
    ap.add_argument("--cfg", default=None, help="JSON ArchConfig overrides")
    ap.add_argument("--qc", default=None, help="JSON QuantConfig overrides")
    ap.add_argument("--array-spec", default=None,
                    help="hardware binding: TECH[/DESIGN][/RxC][/aN][/pP] "
                         "(e.g. 3T-FEMFET/CiM-I); recorded in the "
                         "roofline JSON so perf cells say what hardware "
                         "they were costed on")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--fsdp", action="store_true")
    ap.add_argument("--calibration", default=None, metavar="PATH",
                    help="saved CalibrationTable JSON (profile.calibrate); "
                         "scores the cell's GEMM workload with the fitted "
                         "per-(spec, shape-class) costs next to the "
                         "analytic roofline")
    ap.add_argument("--out", default="results/perf")
    args = ap.parse_args(argv)

    table = None
    if args.calibration is not None:
        from repro.profile.calibrate import CalibrationTable

        try:
            table = CalibrationTable.load(args.calibration)
        except (OSError, ValueError, KeyError) as e:
            ap.error(f"bad --calibration {args.calibration!r}: {e}")

    # Validate registry-facing arguments up front with the valid sets in
    # the message — an unknown arch used to die as a bare KeyError deep
    # inside importlib, an unknown shape as a KeyError in SHAPES.
    from repro.models.registry import ARCH_IDS, SHAPES

    if args.arch not in ARCH_IDS:
        ap.error(f"unknown --arch {args.arch!r}; registered archs: "
                 f"{', '.join(ARCH_IDS)}")
    if args.shape not in SHAPES:
        ap.error(f"unknown --shape {args.shape!r}; registered shapes: "
                 f"{', '.join(SHAPES)}")
    if args.array_spec is not None:
        from repro import hw

        try:
            hw.parse_array_spec(args.array_spec)
        except ValueError as e:
            ap.error(f"bad --array-spec: {e}")

    from repro.launch.dryrun import lower_cell

    res = lower_cell(
        args.arch,
        args.shape,
        multi_pod=args.multi_pod,
        quant_mode=args.quant,
        cfg_overrides=json.loads(args.cfg) if args.cfg else None,
        quant_overrides=json.loads(args.qc) if args.qc else None,
        fsdp=args.fsdp,
        array_spec=args.array_spec,
    )
    if table is not None and res.ok and not (res.error or "").startswith("SKIP"):
        try:
            res.calibrated = score_cell(args.arch, args.shape, table)
        except KeyError as e:
            res.calibrated = {"error": str(e)}
    os.makedirs(args.out, exist_ok=True)
    path = os.path.join(args.out, f"{args.arch}__{args.shape}__{args.name}.json")
    with open(path, "w") as f:
        json.dump(dataclasses.asdict(res), f, indent=1)
    print("saved", path)
    if res.roofline:
        r = res.roofline
        print(
            f"Tc={r['t_compute_s']:.3e} Tm={r['t_memory_s']:.3e} "
            f"Tx={r['t_collective_s']:.3e} bottleneck={r['bottleneck']}"
        )
        if res.calibrated and "predicted_us" in res.calibrated:
            c = res.calibrated
            print(f"calibrated[{c['spec']}]: {c['predicted_us']:.1f}us "
                  f"(worst residual {c['worst_residual_pct']}%, "
                  f"{'trusted' if c['trusted'] else 'UNTRUSTED'})")
        return 0
    print("ERROR:", res.error)
    return 1


if __name__ == "__main__":
    raise SystemExit(main())
