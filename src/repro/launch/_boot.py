"""Pre-jax-import bootstrap helpers (MUST stay jax-free).

jax locks the host device count at first init, so anything that wants
virtual CPU devices (``--tp`` serving/benchmarks, the dry-run's 512-way
meshes) has to mutate ``XLA_FLAGS`` before the first ``import jax`` in
the process. The ``--tp`` consumers (``repro.launch.serve``,
``benchmarks.bench_serve``) share this scanner instead of carrying
their own copies.
"""
from __future__ import annotations

import os


def int_flag_from_argv(argv, flag: str) -> int:
    """Best-effort ``--flag N`` / ``--flag=N`` scan of raw argv
    (argparse hasn't run yet at bootstrap time). Unparseable values
    return 0 — argparse will reject them properly later."""
    for i, a in enumerate(argv):
        val = None
        if a == flag and i + 1 < len(argv):
            val = argv[i + 1]
        elif a.startswith(flag + "="):
            val = a.split("=", 1)[1]
        if val is not None:
            try:
                return int(val)
            except ValueError:
                return 0
    return 0


def tp_from_argv(argv) -> int:
    return int_flag_from_argv(argv, "--tp")


def force_host_devices_for_tp(argv) -> int:
    """If argv requests ``--tp N > 1`` and the device-count flag isn't
    already set, force enough virtual host devices — ``N`` per serving
    replica when ``--replicas R`` is also present (the front door's
    router places each replica on a disjoint (1, tp) mesh), at least 8
    so the TP contract axes can still trace. Call before the first jax
    import. Returns the scanned tp (0/1 = untouched)."""
    tp = tp_from_argv(argv)
    replicas = max(int_flag_from_argv(argv, "--replicas"), 1)
    if tp > 1 and "xla_force_host_platform_device_count" not in os.environ.get(
        "XLA_FLAGS", ""
    ):
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + f" --xla_force_host_platform_device_count={max(tp * replicas, 8)}"
        ).strip()
    return tp
