"""``repro.api`` — the public API: declarative execution (what a ternary
MAC computes, and how) plus declarative hardware (what it runs on).

    from repro import api

    spec = api.CiMExecSpec(formulation="blocked", backend="auto")
    out = api.execute(spec, x_t, w_t)

    arr = api.ArraySpec(technology="3T-FEMFET", design="CiM-I")
    api.spec_cost_summary(spec, array=arr)          # cost on that array
    api.project("yi-34b", "decode_32k", arr)        # system projection

New kernels land via ``register_backend``; new memory technologies /
array designs via ``register_technology`` / ``register_design`` — both
without touching any call site. See repro.core.execution and repro.hw
for the full documentation, DESIGN.md §3/§7 for the architecture.
"""
from repro.core.execution import (  # noqa: F401
    BACKENDS,
    DECODE_M_MAX,
    FLAVORS,
    FORMULATIONS,
    PACKINGS,
    SHAPE_CLASSES,
    BackendEntry,
    CiMExecSpec,
    autotune,
    canonical_plane_layout,
    clear_tile_cache,
    execute,
    execute_packed,
    execute_packed_tp,
    execute_tp,
    get_backend,
    register_backend,
    registered_specs,
    shape_class,
    spec_array_cost,
    spec_cost_summary,
    spec_design,
    tiles_for,
)
from repro.core.ternary import PackedPlanes  # noqa: F401
from repro.hw import (  # noqa: F401
    ArrayCost,
    ArraySpec,
    DesignMetrics,
    DesignSpec,
    MacroSpec,
    TechnologySpec,
    array_cost,
    design_claims,
    designs,
    parse_array_spec,
    project,
    register_design,
    register_technology,
    technologies,
)
