"""``repro.api`` — the public execution API for signed-ternary CiM MACs.

    from repro import api

    spec = api.CiMExecSpec(formulation="blocked", backend="auto")
    out = api.execute(spec, x_t, w_t)

See repro.core.execution for the full documentation and DESIGN.md for
the architecture.
"""
from repro.core.execution import (  # noqa: F401
    BACKENDS,
    FLAVORS,
    FORMULATIONS,
    PACKINGS,
    BackendEntry,
    CiMExecSpec,
    execute,
    execute_packed,
    get_backend,
    register_backend,
    registered_specs,
    spec_array_cost,
    spec_cost_summary,
    spec_design,
)
