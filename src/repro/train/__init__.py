"""Training loop, checkpointing, fault tolerance."""
from repro.train.train_step import (  # noqa: F401
    TrainState, cross_entropy, init_train_state, loss_fn, make_jit_train_step,
    train_step,
)
from repro.train.trainer import FailureInjector, TrainConfig, Trainer  # noqa: F401
from repro.train import checkpoint  # noqa: F401
