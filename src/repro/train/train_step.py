"""Loss + train step (pure functions; jit/pjit-ready)."""
from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import transformer as T
from repro.optim import adamw
from repro.optim import compress as gcomp

PyTree = Any


class TrainState(NamedTuple):
    params: PyTree
    opt: adamw.AdamWState
    rng: jax.Array
    residual: Optional[PyTree] = None   # error-feedback for grad compression


def init_train_state(key, cfg: ArchConfig, grad_compression: Optional[str] = None) -> TrainState:
    kp, kr = jax.random.split(key)
    params = T.init_params(kp, cfg)
    residual = gcomp.init_residual(params) if grad_compression == "int8" else None
    return TrainState(params, adamw.init(params), kr, residual)


def cross_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return (logz - gold).mean()


def loss_fn(params, batch: Dict[str, jax.Array], cfg: ArchConfig) -> Tuple[jax.Array, Dict]:
    logits = T.forward(params, batch, cfg)
    if cfg.family == "vlm":
        logits = logits[:, cfg.n_image_tokens :, :]
    loss = cross_entropy(logits, batch["labels"])
    acc = (jnp.argmax(logits, -1) == batch["labels"]).mean()
    return loss, {"loss": loss, "accuracy": acc}


def train_step(
    state: TrainState,
    batch: Dict[str, jax.Array],
    cfg: ArchConfig,
    opt_cfg: adamw.AdamWConfig,
    grad_compression: Optional[str] = None,
) -> Tuple[TrainState, Dict[str, jax.Array]]:
    """One optimizer step. Under pjit, XLA inserts the gradient
    reduce-scatter/all-reduce implied by the shardings; when
    ``grad_compression`` is set the collective payload is the compressed
    dtype (encode/decode straddles the reduction)."""
    rng, rng_next = jax.random.split(state.rng)
    (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
        state.params, batch, cfg
    )
    residual = state.residual
    if grad_compression:
        grads, residual = gcomp.compress_grads(grads, grad_compression, rng, residual)
    params, opt, gnorm = adamw.update(opt_cfg, grads, state.opt, state.params)
    metrics = dict(metrics, grad_norm=gnorm)
    return TrainState(params, opt, rng_next, residual), metrics


def make_jit_train_step(cfg: ArchConfig, opt_cfg: adamw.AdamWConfig,
                        grad_compression: Optional[str] = None,
                        donate: bool = True):
    f = functools.partial(
        train_step, cfg=cfg, opt_cfg=opt_cfg, grad_compression=grad_compression
    )
    return jax.jit(f, donate_argnums=(0,) if donate else ())


# Convenience single-arg forms used by the dry-run (shardings applied there)
def bare_train_step(state: TrainState, batch, cfg: ArchConfig, opt_cfg: adamw.AdamWConfig):
    return train_step(state, batch, cfg, opt_cfg)
