"""Sharded checkpointing with atomic commit, async save, elastic restore.

Layout (one directory per step):

    <dir>/step_000123/
        manifest.json       # step, leaf index, shapes/dtypes, mesh note
        arrays.npz          # one entry per pytree leaf ("0", "1", ...)
    <dir>/LATEST            # text file: committed step number

Fault-tolerance properties:
  * two-phase commit — writes go to ``step_X.tmp`` and are renamed only
    when complete, then LATEST is updated (a crash mid-save never
    corrupts the restore point),
  * restore is **resharding-agnostic** (elastic): leaves are saved as
    full host arrays, restore device_puts them under whatever shardings
    the *current* mesh prescribes — a job restarted on a different pod
    count resumes from the same step,
  * async mode hands the host arrays to a worker thread so the train
    loop only blocks on d2h, not on disk,
  * keep_last_n garbage collection.

(On a real multi-host cluster each host would write only its addressable
shards; the manifest/commit protocol is the same. Single-process here, so
leaves are gathered — noted in DESIGN.md.)
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Any, Optional, Tuple

import jax
import numpy as np

try:
    import ml_dtypes

    _BF16 = np.dtype(ml_dtypes.bfloat16)
except ImportError:  # pragma: no cover
    _BF16 = None

PyTree = Any

_EXECUTOR = ThreadPoolExecutor(max_workers=1, thread_name_prefix="ckpt")


def _leaves_with_treedef(tree: PyTree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def save(
    directory: str,
    step: int,
    tree: PyTree,
    extra: Optional[dict] = None,
    async_: bool = False,
) -> Optional[Future]:
    """Checkpoint ``tree`` at ``step``. Returns a Future in async mode."""
    leaves, treedef = _leaves_with_treedef(tree)
    # analysis: host-sync ok — checkpoint d2h copy is the whole point
    host_leaves = [np.asarray(x) for x in leaves]
    # npz cannot represent ml_dtypes (bf16 etc.) — store a raw byte view
    # and reconstruct from the manifest dtype on restore.
    stored = [
        a.view(np.uint16) if a.dtype == _BF16 else a for a in host_leaves
    ]
    manifest = {
        "step": int(step),
        "treedef": str(treedef),
        "n_leaves": len(host_leaves),
        "shapes": [list(x.shape) for x in host_leaves],
        "dtypes": [str(x.dtype) for x in host_leaves],
        "extra": extra or {},
    }

    def _commit():
        os.makedirs(directory, exist_ok=True)
        final = os.path.join(directory, f"step_{step:08d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        np.savez(os.path.join(tmp, "arrays.npz"), **{str(i): a for i, a in enumerate(stored)})
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        for attempt in range(3):                    # atomic commit (retry a
            try:                                    # concurrent-recreate race)
                if os.path.exists(final):
                    shutil.rmtree(final)
                os.rename(tmp, final)
                break
            except OSError:
                if attempt == 2:
                    raise
        latest_tmp = os.path.join(directory, "LATEST.tmp")
        with open(latest_tmp, "w") as f:
            f.write(str(step))
        os.replace(latest_tmp, os.path.join(directory, "LATEST"))
        return final

    if async_:
        return _EXECUTOR.submit(_commit)
    _commit()
    return None


def latest_step(directory: str) -> Optional[int]:
    p = os.path.join(directory, "LATEST")
    if not os.path.exists(p):
        return None
    with open(p) as f:
        return int(f.read().strip())


def restore(
    directory: str,
    like: PyTree,
    step: Optional[int] = None,
    shardings: Optional[PyTree] = None,
) -> Tuple[PyTree, int]:
    """Restore into the structure of ``like``. ``shardings`` (a matching
    pytree of jax.sharding.Sharding, e.g. dist.named_sharding_tree for the
    *current* mesh) enables elastic restore onto any topology."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint in {directory}")
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "arrays.npz"))
    leaves = []
    for i in range(manifest["n_leaves"]):
        a = data[str(i)]
        if manifest["dtypes"][i] == "bfloat16":
            a = a.view(_BF16)
        leaves.append(a)
    _, treedef = _leaves_with_treedef(like)
    like_leaves = jax.tree_util.tree_leaves(like)
    assert len(leaves) == len(like_leaves), "checkpoint/model structure mismatch"
    if shardings is not None:
        shard_leaves = jax.tree_util.tree_leaves(
            shardings, is_leaf=lambda x: isinstance(x, jax.sharding.Sharding)
        )
        out = [
            jax.device_put(a.astype(l.dtype), s)
            for a, l, s in zip(leaves, like_leaves, shard_leaves)
        ]
    else:
        out = [jax.numpy.asarray(a.astype(l.dtype)) for a, l in zip(leaves, like_leaves)]
    return jax.tree_util.tree_unflatten(treedef, out), step


def gc_old(directory: str, keep_last_n: int = 3) -> None:
    if not os.path.isdir(directory):
        return
    steps = sorted(
        int(d.split("_")[1])
        for d in os.listdir(directory)
        if d.startswith("step_") and not d.endswith(".tmp")
    )
    for s in steps[:-keep_last_n]:
        shutil.rmtree(os.path.join(directory, f"step_{s:08d}"), ignore_errors=True)
