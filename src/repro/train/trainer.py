"""Fault-tolerant training loop.

Responsibilities beyond calling the jitted step:
  * periodic async checkpointing (two-phase commit via train.checkpoint),
  * crash recovery: on any step failure, restore the last committed
    checkpoint and replay from there (the data pipeline is seekable, so
    samples are exactly-once); a ``FailureInjector`` hook lets tests and
    the chaos example exercise this path deterministically,
  * straggler mitigation: per-step deadline tracking — steps slower than
    ``straggler_factor`` x the trailing-median are logged and counted;
    on a real cluster the same hook triggers preemption/re-slicing
    (here it feeds the metrics so the policy is testable),
  * elastic restart: ``Trainer.restore`` accepts the *current* mesh's
    shardings, so a checkpoint written on one topology resumes on another.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np

from repro.configs.base import ArchConfig
from repro.data.pipeline import TokenPipeline
from repro.optim.adamw import AdamWConfig
from repro.train import checkpoint as ckpt
from repro.train.train_step import TrainState, init_train_state, make_jit_train_step

PyTree = Any


@dataclasses.dataclass
class TrainConfig:
    num_steps: int = 100
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 50
    keep_last_n: int = 3
    async_ckpt: bool = True
    log_every: int = 10
    straggler_factor: float = 3.0
    grad_compression: Optional[str] = None   # none | bf16 | int8
    max_restarts: int = 3


class FailureInjector:
    """Deterministic failure hook for fault-tolerance tests."""

    def __init__(self, fail_at_steps: Optional[List[int]] = None):
        self.fail_at = set(fail_at_steps or [])
        self.fired = set()

    def maybe_fail(self, step: int):
        if step in self.fail_at and step not in self.fired:
            self.fired.add(step)
            raise RuntimeError(f"injected node failure at step {step}")


class Trainer:
    def __init__(
        self,
        cfg: ArchConfig,
        opt_cfg: AdamWConfig,
        train_cfg: TrainConfig,
        pipeline: TokenPipeline,
        seed: int = 0,
        failure_injector: Optional[FailureInjector] = None,
        batch_transform: Optional[Callable[[Dict], Dict]] = None,
    ):
        self.cfg = cfg
        self.opt_cfg = opt_cfg
        self.train_cfg = train_cfg
        self.pipeline = pipeline
        self.failure_injector = failure_injector
        self.batch_transform = batch_transform
        self.step_fn = make_jit_train_step(
            cfg, opt_cfg, grad_compression=train_cfg.grad_compression
        )
        self.state: TrainState = init_train_state(
            jax.random.PRNGKey(seed), cfg, train_cfg.grad_compression
        )
        self.start_step = 0
        self.metrics_log: List[Dict[str, float]] = []
        self.straggler_steps: List[int] = []
        self.restarts = 0
        self._pending_ckpt = None
        if train_cfg.ckpt_dir and ckpt.latest_step(train_cfg.ckpt_dir) is not None:
            self.restore()

    # -- checkpoint/restore -------------------------------------------------

    def save(self, step: int):
        tc = self.train_cfg
        if not tc.ckpt_dir:
            return
        if self._pending_ckpt is not None:
            self._pending_ckpt.result()  # don't overlap two saves
        fut = ckpt.save(
            tc.ckpt_dir,
            step,
            self.state,
            extra={"arch": self.cfg.name, "data_step": step},
            async_=tc.async_ckpt,
        )
        self._pending_ckpt = fut
        ckpt.gc_old(tc.ckpt_dir, tc.keep_last_n)

    def restore(self, shardings: Optional[PyTree] = None):
        if self._pending_ckpt is not None:
            self._pending_ckpt.result()  # never read a mid-commit checkpoint
            self._pending_ckpt = None
        state, step = ckpt.restore(
            self.train_cfg.ckpt_dir, self.state, shardings=shardings
        )
        self.state = state
        self.start_step = step
        return step

    # -- main loop ----------------------------------------------------------

    def _one_step(self, step: int) -> Dict[str, float]:
        batch = self.pipeline.batch(step)
        if self.batch_transform:
            batch = self.batch_transform(batch)
        batch = {k: jax.numpy.asarray(v) for k, v in batch.items()}
        if self.failure_injector:
            self.failure_injector.maybe_fail(step)
        self.state, metrics = self.step_fn(self.state, batch)
        return {k: float(v) for k, v in metrics.items()}

    def run(self) -> List[Dict[str, float]]:
        tc = self.train_cfg
        step = self.start_step
        durations: List[float] = []
        while step < tc.num_steps:
            t0 = time.perf_counter()
            try:
                metrics = self._one_step(step)
            except Exception as e:  # node failure path
                self.restarts += 1
                if self.restarts > tc.max_restarts or not tc.ckpt_dir:
                    # drain in-flight checkpoint IO before propagating so
                    # callers can tear down the directory safely
                    if self._pending_ckpt is not None:
                        self._pending_ckpt.result()
                        self._pending_ckpt = None
                    raise
                if ckpt.latest_step(tc.ckpt_dir) is not None:
                    step = self.restore()
                else:  # failure before first checkpoint: restart from 0
                    self.state = init_train_state(
                        jax.random.PRNGKey(0), self.cfg, tc.grad_compression
                    )
                    step = 0
                print(f"[trainer] recovered from failure ({e}); resuming at step {step}")
                continue
            dt = time.perf_counter() - t0
            durations.append(dt)
            med = float(np.median(durations[-20:]))
            if len(durations) > 5 and dt > tc.straggler_factor * med:
                self.straggler_steps.append(step)
                print(f"[trainer] straggler step {step}: {dt:.3f}s vs median {med:.3f}s")
            metrics["step"] = step
            metrics["sec"] = dt
            self.metrics_log.append(metrics)
            if tc.log_every and step % tc.log_every == 0:
                print(
                    f"[trainer] step {step:5d} loss {metrics['loss']:.4f} "
                    f"acc {metrics['accuracy']:.3f} ({dt:.2f}s)"
                )
            step += 1
            if tc.ckpt_dir and step % tc.ckpt_every == 0:
                self.save(step)
        if tc.ckpt_dir:
            self.save(step)
            if self._pending_ckpt is not None:
                self._pending_ckpt.result()
        return self.metrics_log
