"""Project the repo's own registry architectures through the CiM system
model — the workload the paper never ran.

``arch_gemms`` maps any :class:`repro.configs.base.ArchConfig` to the
per-forward weight-bearing GEMMs that would execute inside CiM arrays
(DESIGN.md §5: attention QKV/O, MLP and expert FFN weights, MLA
low-rank factors, SSM in/out projections; routers, norms, embeddings
and activation-activation contractions stay digital). ``project`` runs
one (arch, shape) cell through the macro model on a chosen
:class:`~repro.hw.array.ArraySpec` and reports projected throughput and
energy against the iso-capacity and iso-area NM baselines — the same
comparison the paper makes for AlexNet/LSTM (Figs 12/13), now for the
actual transformer / SSM / hybrid / MoE / encdec / VLM configs.

Token accounting per shape kind: ``prefill``/``train`` process
``batch x seq`` tokens per forward (train is costed as its forward pass
— the CiM macro is a weight-stationary inference engine; backward stays
on the digital side), ``decode`` processes ``batch`` tokens per step.
Encoder frames (whisper) and image patches (llava) are separate token
bases that only flow at prefill; at decode their projections are cached.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

from repro.hw.array import ArraySpec, array_cost
from repro.hw.macro import (
    GemmLayer,
    MacroSpec,
    PAPER_MACRO,
    iso_area_nm_arrays,
    layer_cost,
)


@dataclasses.dataclass(frozen=True)
class WeightGemm:
    """One weight matrix of an architecture, with its execution count
    per forward pass and the token basis its M dimension scales with."""
    name: str
    k: int
    n: int
    count: int = 1          # executions per forward (usually n_layers)
    basis: str = "tokens"   # tokens | encoder | image


def _attn_gemms(cfg, prefix: str = "attn.") -> List[Tuple[str, int, int]]:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    if cfg.mla:
        qk_all = cfg.qk_nope_head_dim + cfg.qk_rope_head_dim
        out = []
        if cfg.q_lora_rank:
            out += [(prefix + "wq_a", d, cfg.q_lora_rank),
                    (prefix + "wq_b", cfg.q_lora_rank, cfg.n_heads * qk_all)]
        else:
            out += [(prefix + "wq", d, cfg.n_heads * qk_all)]
        out += [
            (prefix + "wkv_a", d, cfg.kv_lora_rank + cfg.qk_rope_head_dim),
            (prefix + "wkv_b", cfg.kv_lora_rank,
             cfg.n_heads * (cfg.qk_nope_head_dim + cfg.v_head_dim)),
            (prefix + "wo", cfg.n_heads * cfg.v_head_dim, d),
        ]
        return out
    return [
        (prefix + "wq", d, cfg.n_heads * hd),
        (prefix + "wk", d, cfg.n_kv_heads * hd),
        (prefix + "wv", d, cfg.n_kv_heads * hd),
        (prefix + "wo", cfg.n_heads * hd, d),
    ]


def _ffn_gemms(cfg, prefix: str = "ffn.") -> List[Tuple[str, int, int]]:
    d, f = cfg.d_model, cfg.d_ff
    return [(prefix + "gate", d, f), (prefix + "up", d, f),
            (prefix + "down", f, d)]


def _expert_gemms(cfg) -> List[Tuple[str, int, int]]:
    d, f = cfg.d_model, cfg.expert_d_ff
    return [("expert.gate", d, f), ("expert.up", d, f), ("expert.down", f, d)]


def _ssm_gemms(cfg, prefix: str = "ssm.") -> List[Tuple[str, int, int]]:
    d, di = cfg.d_model, cfg.ssm_d_inner
    in_width = 2 * di + 2 * cfg.ssm_n_groups * cfg.ssm_state + cfg.ssm_n_heads
    return [(prefix + "in_proj", d, in_width), (prefix + "out_proj", di, d)]


def arch_gemms(cfg) -> List[WeightGemm]:
    """The weight-bearing GEMMs of one forward pass of ``cfg``."""
    L = cfg.n_layers
    out: List[WeightGemm] = []
    if cfg.family in ("dense", "moe", "encdec", "vlm"):
        out += [WeightGemm(n, k, w, L) for n, k, w in _attn_gemms(cfg)]
        if cfg.n_experts:
            # router stays digital; each token activates top_k routed +
            # the shared experts (MoE capacity dropping ignored: the
            # projection costs the steady-state routed load)
            active = cfg.top_k + cfg.n_shared_experts
            out += [WeightGemm(n, k, w, L * active)
                    for n, k, w in _expert_gemms(cfg)]
        else:
            out += [WeightGemm(n, k, w, L) for n, k, w in _ffn_gemms(cfg)]
    elif cfg.family == "ssm":
        out += [WeightGemm(n, k, w, L) for n, k, w in _ssm_gemms(cfg)]
    elif cfg.family == "hybrid":
        out += [WeightGemm(n, k, w, L) for n, k, w in _ssm_gemms(cfg)]
        shared = max(1, L // cfg.hybrid_attn_every)
        out += [WeightGemm(n, k, w, shared)
                for n, k, w in _attn_gemms(cfg, "shared_attn.")]
        out += [WeightGemm(n, k, w, shared)
                for n, k, w in _ffn_gemms(cfg, "shared_ffn.")]
    else:
        raise ValueError(f"unknown family {cfg.family!r} for {cfg.name}")
    if cfg.family == "encdec":
        d, hd = cfg.d_model, cfg.resolved_head_dim
        # cross attention: q/o per decoded token; k/v once per encoder
        # frame (cached across decode steps)
        out += [
            WeightGemm("cross.wq", d, cfg.n_heads * hd, L),
            WeightGemm("cross.wo", cfg.n_heads * hd, d, L),
            WeightGemm("cross.wk", d, cfg.n_heads * hd, L, basis="encoder"),
            WeightGemm("cross.wv", d, cfg.n_heads * hd, L, basis="encoder"),
        ]
        E = cfg.n_encoder_layers
        out += [WeightGemm(n, k, w, E, basis="encoder")
                for n, k, w in _attn_gemms(cfg, "enc.attn.")]
        out += [WeightGemm(n, k, w, E, basis="encoder")
                for n, k, w in _ffn_gemms(cfg, "enc.ffn.")]
    if cfg.family == "vlm":
        out.append(WeightGemm("projector", cfg.d_vision, cfg.d_model, 1,
                              basis="image"))
    if cfg.quantize_unembed:
        out.append(WeightGemm("unembed", cfg.d_model, cfg.vocab, 1))
    return out


def _token_bases(cfg, shape) -> Dict[str, int]:
    decode = shape.kind == "decode"
    n_img = cfg.n_image_tokens if cfg.family == "vlm" else 0
    return {
        # the decoder stream sees the full sequence (incl. image tokens)
        "tokens": shape.batch * (1 if decode else shape.seq),
        "encoder": 0 if decode else shape.batch * getattr(cfg, "encoder_seq", 0),
        "image": 0 if decode else shape.batch * n_img,
    }


def workload_layers(cfg, shape) -> List[Tuple[GemmLayer, int]]:
    """(GemmLayer with resolved M, execution count) for one forward of
    (cfg, shape); zero-M bases (e.g. the encoder at decode) drop out."""
    bases = _token_bases(cfg, shape)
    out = []
    for g in arch_gemms(cfg):
        m = bases[g.basis]
        if m > 0:
            out.append((GemmLayer(g.name, m, g.k, g.n), g.count))
    return out


def _resolve(arch, shape):
    # registry import is lazy: repro.hw stays importable without jax
    from repro.models.registry import SHAPES, get_config

    cfg = get_config(arch) if isinstance(arch, str) else arch
    if isinstance(shape, str):
        try:
            shape = SHAPES[shape]
        except KeyError:
            raise KeyError(
                f"unknown shape {shape!r} (known: {list(SHAPES)})") from None
    return cfg, shape


def project(arch, shape, array: ArraySpec,
            macro: MacroSpec = PAPER_MACRO,
            calibration=None) -> Dict[str, object]:
    """Run one (arch, shape) cell through the system model on ``array``.

    arch: registry id ("yi-34b") or an ArchConfig; shape: registry shape
    name ("decode_32k") or a ShapeCell. Returns a JSON-ready dict with
    the CiM macro's projected time/energy/throughput and the speedup /
    energy-reduction against the iso-capacity and iso-area NM baselines
    built from the same technology.

    ``calibration``: a fitted cost table (``repro.profile.calibrate.
    CalibrationTable`` — anything with ``predict_gemm_us(m, k, n)`` and
    ``version``/``backend`` attributes). When given, the same workload
    is additionally costed through the *measured* host-kernel fits and
    reported under ``out["calibrated"]`` next to the analytic CiM
    numbers — the measured-vs-modeled split DESIGN.md §11 describes.
    """
    cfg, shape = _resolve(arch, shape)
    layers = workload_layers(cfg, shape)

    def total(a: ArraySpec, n_arrays: int):
        cost = array_cost(a)
        t = e = 0.0
        macs = 0
        for layer, count in layers:
            lt, le = layer_cost(layer, a, n_arrays, macro, cost=cost)
            t += lt * count
            e += le * count
            macs += layer.macs * count
        return t, e, macs

    t_cim, e_cim, macs = total(array, macro.n_arrays)
    nm = array.with_design("NM")
    t_ic, e_ic, _ = total(nm, macro.n_arrays)
    nm_arrays_ia = iso_area_nm_arrays(array, macro)
    t_ia, e_ia, _ = total(nm, nm_arrays_ia)
    tokens = _token_bases(cfg, shape)["tokens"]
    calibrated = None
    if calibration is not None:
        if not getattr(calibration, "kernels", True):
            # an engine-only trace (e.g. launch/serve --profile) fits no
            # kernels — say so instead of KeyError-ing per layer below
            raise ValueError(
                "calibration table has no kernel fits to cost the workload "
                "with — capture eager execute events (profile.set_profiler) "
                "or run benchmarks/bench_calibrate.py to fit them"
            )
        t_us = sum(
            calibration.predict_gemm_us(layer.m, layer.k, layer.n) * count
            for layer, count in layers
        )
        calibrated = {
            "source": {
                "version": getattr(calibration, "version", None),
                "backend": getattr(calibration, "backend", None),
            },
            "time_us": t_us,
            "tok_s": tokens / max(t_us * 1e-6, 1e-12),
            # measured host kernels vs the analytic CiM projection —
            # how much faster the modeled array is than this host
            "cim_speedup_vs_host": (t_us * 1e3) / max(t_cim, 1e-12),
        }
    return {
        "arch": cfg.name,
        "family": cfg.family,
        "shape": shape.name,
        "kind": shape.kind,
        "array": array.name,
        "design": array.design,
        "tech": array.technology,
        "n_arrays": macro.n_arrays,
        "tokens_per_forward": tokens,
        "macs_per_forward": macs,
        "time_ns": t_cim,
        "energy_pj": e_cim,
        "tok_s": tokens / (t_cim * 1e-9),
        "pj_per_token": e_cim / max(tokens, 1),
        "iso_capacity": {
            "speedup": t_ic / t_cim,
            "energy_reduction": e_ic / e_cim,
        },
        "iso_area": {
            "nm_arrays": nm_arrays_ia,
            "speedup": t_ia / t_cim,
            "energy_reduction": e_ia / e_cim,
        },
        "calibrated": calibrated,
    }
