"""The paper's Section VI benchmark workloads (AlexNet, ResNet34,
Inception, LSTM, GRU) as GEMM layer lists. Dimensions follow the
standard published architectures. The repo's own registry architectures
are mapped in ``repro.hw.workload`` instead.
"""
from __future__ import annotations

from typing import Dict, List

from repro.hw.macro import GemmLayer, conv


def alexnet() -> List[GemmLayer]:
    return [
        conv("conv1", 55, 3, 11, 96),
        conv("conv2", 27, 96, 5, 256),
        conv("conv3", 13, 256, 3, 384),
        conv("conv4", 13, 384, 3, 384),
        conv("conv5", 13, 384, 3, 256),
        GemmLayer("fc6", 1, 9216, 4096),
        GemmLayer("fc7", 1, 4096, 4096),
        GemmLayer("fc8", 1, 4096, 1000),
    ]


def resnet34() -> List[GemmLayer]:
    layers = [conv("conv1", 112, 3, 7, 64)]
    stages = [(64, 3, 56), (128, 4, 28), (256, 6, 14), (512, 3, 7)]
    prev_c = 64
    for si, (c, blocks, hw) in enumerate(stages):
        for b in range(blocks):
            cin = prev_c if b == 0 else c
            layers.append(conv(f"s{si}b{b}c1", hw, cin, 3, c))
            layers.append(conv(f"s{si}b{b}c2", hw, c, 3, c))
            if b == 0 and cin != c:
                layers.append(conv(f"s{si}b{b}ds", hw, cin, 1, c))
        prev_c = c
    layers.append(GemmLayer("fc", 1, 512, 1000))
    return layers


def inception() -> List[GemmLayer]:
    """GoogLeNet(Inception-v1)-style workload: stem + 9 inception modules."""
    layers = [
        conv("stem1", 112, 3, 7, 64),
        conv("stem2", 56, 64, 3, 192),
    ]
    # (hw, c_in, [#1x1, #3x3red, #3x3, #5x5red, #5x5, pool_proj])
    modules = [
        (28, 192, (64, 96, 128, 16, 32, 32)),
        (28, 256, (128, 128, 192, 32, 96, 64)),
        (14, 480, (192, 96, 208, 16, 48, 64)),
        (14, 512, (160, 112, 224, 24, 64, 64)),
        (14, 512, (128, 128, 256, 24, 64, 64)),
        (14, 512, (112, 144, 288, 32, 64, 64)),
        (14, 528, (256, 160, 320, 32, 128, 128)),
        (7, 832, (256, 160, 320, 32, 128, 128)),
        (7, 832, (384, 192, 384, 48, 128, 128)),
    ]
    for i, (hw, cin, (c1, r3, c3, r5, c5, pp)) in enumerate(modules):
        layers += [
            conv(f"inc{i}_1x1", hw, cin, 1, c1),
            conv(f"inc{i}_3x3r", hw, cin, 1, r3),
            conv(f"inc{i}_3x3", hw, r3, 3, c3),
            conv(f"inc{i}_5x5r", hw, cin, 1, r5),
            conv(f"inc{i}_5x5", hw, r5, 5, c5),
            conv(f"inc{i}_pool", hw, cin, 1, pp),
        ]
    layers.append(GemmLayer("fc", 1, 1024, 1000))
    return layers


def lstm(hidden: int = 512, inp: int = 512, steps: int = 100) -> List[GemmLayer]:
    # 4 gates; input and recurrent GEMMs per step, batched over timesteps.
    return [
        GemmLayer("lstm_x", steps, inp, 4 * hidden),
        GemmLayer("lstm_h", steps, hidden, 4 * hidden),
        GemmLayer("proj", steps, hidden, inp),
    ]


def gru(hidden: int = 512, inp: int = 512, steps: int = 100) -> List[GemmLayer]:
    return [
        GemmLayer("gru_x", steps, inp, 3 * hidden),
        GemmLayer("gru_h", steps, hidden, 3 * hidden),
        GemmLayer("proj", steps, hidden, inp),
    ]


BENCHMARKS: Dict[str, List[GemmLayer]] = {}


def get_benchmarks() -> Dict[str, List[GemmLayer]]:
    if not BENCHMARKS:
        BENCHMARKS.update(
            AlexNet=alexnet(),
            ResNet34=resnet34(),
            Inception=inception(),
            LSTM=lstm(),
            GRU=gru(),
        )
    return BENCHMARKS
