"""``ArraySpec`` + array-level cost derivation (paper Section V).

An ``ArraySpec`` is the hardware mirror of ``CiMExecSpec``: a frozen,
declarative description of one memory array — which technology and
design it is built from plus its geometry — that every cost consumer
(``api.spec_cost_summary``, dry-run/roofline cells, the macro system
model, bench_array) binds to instead of module constants.

Cost derivation is generic over the registries: absolute per-operation
costs come from the technology's NM-baseline scale times the design's
normalized ratios. The paper's Fig 9/11 numbers are *not* the data
structure — they are derived by :func:`design_claims` and pinned as a
validation table (:func:`paper_validation_table`, compared bit-for-bit
in ``tests/test_hw.py``).

Conventions (unchanged from the paper):
  * a "MAC pass" is one full pass over all ``rows`` of a column set:
    NM = ``rows`` sequential row reads + digital MAC; CiM designs
    assert ``n_active`` rows per cycle (the latency/energy advantage is
    measured in the technology's normalized ratios, which were
    characterized at the paper's 256x256 / N_A=16 geometry).
  * ``adc_bits``-bit flash ADC plus one extra sense amp reads block
    partials 0..2**adc_bits exactly (the clamp bound ``adc_max``).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict

from repro.hw import registry as reg

# Paper geometry defaults: 512x256 binary arrays = 256x256 ternary words.
DEFAULT_ROWS = 256
DEFAULT_COLS = 256
DEFAULT_N_ACTIVE = 16
DEFAULT_ADC_BITS = 3
DEFAULT_PCUS = 32


@dataclasses.dataclass(frozen=True)
class ArraySpec:
    """Declarative description of one CiM/NM memory array.

    technology: a registered technology name (``hw.technologies()``).
    design:     a registered design name (``hw.designs()``).
    rows/cols:  ternary words (two binary cells per word).
    n_active:   rows asserted per cycle in CiM designs (paper N_A = 16).
    adc_bits:   flash-ADC precision; clamp bound = 2**adc_bits (+ the
                extra sense amp, i.e. 8 for 3 bits).
    clock_ghz:  digital periphery clock (PCU drain / post-processing);
                the analog array timing comes from the technology.
    pcus:       partial-sum compute units draining the columns.
    """
    technology: str = "8T-SRAM"
    design: str = "NM"
    rows: int = DEFAULT_ROWS
    cols: int = DEFAULT_COLS
    n_active: int = DEFAULT_N_ACTIVE
    adc_bits: int = DEFAULT_ADC_BITS
    clock_ghz: float = 1.0
    pcus: int = DEFAULT_PCUS

    def __post_init__(self):
        reg.get_technology(self.technology)   # friendly KeyError on typos
        reg.get_design(self.design)
        if self.rows <= 0 or self.cols <= 0:
            raise ValueError(f"bad geometry {self.rows}x{self.cols}")
        if self.n_active <= 0 or self.rows % self.n_active:
            raise ValueError(
                f"n_active must divide rows: {self.n_active} vs {self.rows}"
            )
        if self.adc_bits <= 0:
            raise ValueError(f"adc_bits must be positive, got {self.adc_bits}")
        if self.clock_ghz <= 0:
            raise ValueError(f"clock_ghz must be positive, got {self.clock_ghz}")
        if self.pcus <= 0 or self.cols % self.pcus:
            raise ValueError(f"pcus must divide cols: {self.pcus} vs {self.cols}")

    @property
    def adc_max(self) -> int:
        return 2 ** self.adc_bits

    @property
    def cycles_per_pass(self) -> int:
        """Array cycles for one full MAC pass over all rows."""
        if reg.get_design(self.design).cim:
            return self.rows // self.n_active
        return self.rows

    @property
    def name(self) -> str:
        """Canonical string form, re-parseable by :func:`parse_array_spec`."""
        return (f"{self.technology}/{self.design}/{self.rows}x{self.cols}"
                f"/a{self.n_active}")

    def with_design(self, design: str) -> "ArraySpec":
        return dataclasses.replace(self, design=design)


_GEOM_RE = re.compile(r"^(\d+)x(\d+)$")
_NACTIVE_RE = re.compile(r"^a(\d+)$")
_PCUS_RE = re.compile(r"^p(\d+)$")
_GRAMMAR = "TECH[/DESIGN][/RxC][/aN][/pP]"


def parse_array_spec(text: str) -> ArraySpec:
    """Parse ``TECH[/DESIGN][/RxC][/aN][/pP]`` into an ArraySpec.

    Examples: ``8T-SRAM`` (NM), ``3T-FEMFET/CiM-I``,
    ``8T-SRAM/CiM-II/256x256/a16``, ``8T-SRAM/CiM-I/96x96/a16/p32``.
    Unknown names and malformed tokens raise with the registered sets /
    grammar listed (the launch CLIs surface this directly); ArraySpec's
    own geometry validation errors are re-raised with the spec text
    attached.
    """
    parts = [p for p in str(text).split("/") if p]
    if not parts:
        raise ValueError(f"empty array spec (grammar: {_GRAMMAR})")
    kw: Dict[str, object] = {"technology": parts[0]}
    for p in parts[1:]:
        if m := _GEOM_RE.match(p):
            kw["rows"], kw["cols"] = int(m.group(1)), int(m.group(2))
        elif m := _NACTIVE_RE.match(p):
            kw["n_active"] = int(m.group(1))
        elif m := _PCUS_RE.match(p):
            kw["pcus"] = int(m.group(1))
        elif p in reg.designs():
            kw["design"] = p
        else:
            raise ValueError(
                f"unknown token {p!r} in array spec {text!r}: not a "
                f"geometry token and not a registered design "
                f"{list(reg.designs())} (grammar: {_GRAMMAR})"
            )
    if kw["technology"] not in reg.technologies():
        raise ValueError(
            f"unknown technology {kw['technology']!r} in array spec "
            f"{text!r}; registered: {list(reg.technologies())}"
        )
    try:
        return ArraySpec(**kw)  # type: ignore[arg-type]
    except ValueError as e:
        raise ValueError(f"invalid array spec {text!r}: {e}") from None


@dataclasses.dataclass(frozen=True)
class ArrayCost:
    """Absolute per-operation array costs, derived from the registries."""
    tech: str
    design: str
    mac_pass_ns: float     # one full rows x cols ternary MAC pass
    mac_pass_pj: float
    row_read_ns: float
    row_read_pj: float
    row_write_ns: float
    row_write_pj: float
    cell_area: float       # relative units (NM ternary cell of tech = 1.0)
    macro_area: float
    macs_per_pass: int = DEFAULT_ROWS * DEFAULT_COLS


def array_cost(array: ArraySpec) -> ArrayCost:
    """Derive absolute costs for one array: NM baseline scale x the
    design's normalized ratios (all 1.0 for NM itself)."""
    base = reg.get_technology(array.technology)
    m = reg.design_metrics(array.technology, array.design)
    # NM MAC pass: `rows` row reads + digital MACs (read/compute
    # pipelined, so latency is dominated by reads; energy adds both).
    nm_mac_ns = array.rows * max(base.t_read_ns, base.t_nm_mac_ns)
    nm_mac_pj = array.rows * (base.e_read_pj + base.e_nm_mac_pj)
    return ArrayCost(
        tech=array.technology,
        design=array.design,
        mac_pass_ns=nm_mac_ns * m.cim_latency_vs_nm,
        mac_pass_pj=nm_mac_pj * m.cim_energy_vs_nm,
        row_read_ns=base.t_read_ns * m.read_latency_vs_nm,
        row_read_pj=base.e_read_pj * m.read_energy_vs_nm,
        row_write_ns=base.t_write_ns * m.write_latency_vs_nm,
        row_write_pj=base.e_write_pj * m.write_energy_vs_nm,
        cell_area=m.cell_area_vs_nm,
        macro_area=m.macro_area_vs_nm,
        macs_per_pass=array.rows * array.cols,
    )


def design_claims(array: ArraySpec) -> Dict[str, float]:
    """The paper-style derived claims of one CiM array vs its own
    same-technology NM baseline (the quantities Figs 9/11 report)."""
    nm = array_cost(array.with_design("NM"))
    c = array_cost(array)
    return {
        "cim_latency_reduction_pct": 100.0 * (1 - c.mac_pass_ns / nm.mac_pass_ns),
        "cim_energy_reduction_pct": 100.0 * (1 - c.mac_pass_pj / nm.mac_pass_pj),
        "read_energy_overhead_pct": 100.0 * (c.row_read_pj / nm.row_read_pj - 1),
        "read_latency_overhead_pct": 100.0 * (c.row_read_ns / nm.row_read_ns - 1),
        "write_latency_overhead_pct": 100.0 * (c.row_write_ns / nm.row_write_ns - 1),
        "cell_area_overhead_pct": 100.0 * (c.cell_area - 1),
        "macro_area_ratio": c.macro_area,
    }


def paper_validation_table() -> Dict[str, Dict[str, Dict[str, float]]]:
    """The claims of Figs 9/11 as derived from this model, restricted to
    the paper's six (technology, design) pairs — what tests and
    EXPERIMENTS.md compare against the paper's text. Registered
    non-paper technologies intentionally never appear here; they show up
    in ``bench_array.rows()`` instead."""
    out: Dict[str, Dict[str, Dict[str, float]]] = {}
    for tech in reg.PAPER_TECHNOLOGIES:
        out[tech] = {}
        for design in ("CiM-I", "CiM-II"):
            out[tech][design] = design_claims(
                ArraySpec(technology=tech, design=design))
    return out


def flavor_comparison() -> Dict[str, Dict[str, float]]:
    """Section V.3: CiM II vs CiM I energy/latency/area ratios."""
    out = {}
    for tech in reg.PAPER_TECHNOLOGIES:
        c1 = array_cost(ArraySpec(technology=tech, design="CiM-I"))
        c2 = array_cost(ArraySpec(technology=tech, design="CiM-II"))
        out[tech] = {
            "energy_II_over_I": c2.mac_pass_pj / c1.mac_pass_pj,
            "latency_II_over_I": c2.mac_pass_ns / c1.mac_pass_ns,
            "cell_area_II_over_I": c2.cell_area / c1.cell_area,
        }
    return out
