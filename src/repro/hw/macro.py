"""``MacroSpec`` + TiM-DNN-style system model (paper Section VI),
generalized over :class:`repro.hw.array.ArraySpec`.

Maps GEMM workloads onto a macro of arrays and derives execution time
and energy. With the default paper macro (32 arrays of 256x256 ternary
cells, 32 PCUs per array) and the paper's DNN suite
(``repro.hw.dnn_suite``) this reproduces Figs 12/13; with
``repro.hw.workload`` it projects the repo's own registry architectures.

Model structure:

  * N_A = 16 rows asserted per cycle -> 16 cycles per full-column MAC
    pass; column partials are drained ``pcus`` at a time, so a pass
    takes ceil(cols/pcus) PCU drain slots overlapped with compute,
  * NM baselines: iso-capacity (same array count) and iso-area (more
    arrays; the paper's Section VI.A counts are pinned per (design,
    tech) as *calibration*, any other technology derives its count from
    its macro-area ratio),
  * weight reloading: layers larger than macro capacity are processed
    in weight tiles; writing a tile costs row writes, amortized over a
    weight-stationary batch,
  * a fixed per-output post-processing cost (quantization + activation
    in the digital periphery) identical across designs — the Amdahl
    term that brings the raw ~8.3x array-level CiM I advantage down to
    the ~6.6-7.1x system-level speedups the paper reports.

The post-processing rate is the single calibration constant; it was
fitted once so the 8T-SRAM CiM I iso-capacity average lands near the
paper's 6.74x, and then *everything else* (other technologies, flavors,
iso-area baselines, energy ratios) is a prediction of the model that
EXPERIMENTS.md compares against the paper's numbers.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Mapping, Optional, Sequence, Tuple

from repro.hw.array import ArrayCost, ArraySpec, array_cost

# Iso-area NM baseline array counts (paper Section VI.A) — pinned
# calibration for the paper's six (design, tech) pairs.
PAPER_ISO_AREA_NM_ARRAYS: Dict[str, Dict[str, int]] = {
    "CiM-I": {"8T-SRAM": 41, "3T-eDRAM": 48, "3T-FEMFET": 47},
    "CiM-II": {"8T-SRAM": 38, "3T-eDRAM": 42, "3T-FEMFET": 41},
}


@dataclasses.dataclass(frozen=True)
class MacroSpec:
    """Accelerator-level sizing and post-processing constants.

    n_arrays:           arrays in the macro (paper: 32 -> 2M ternary
                        words / 512 kB).
    post_ns_per_out /   calibrated digital post-processing (partial-sum
    post_pj_per_out:    reduce + quantize + activation) cost per output
                        element, identical for CiM and NM designs; the
                        time is per-cycle at the array's ``clock_ghz``.
    write_amortization: weight tiles are loaded once and reused across a
                        batch of inferences (weight-stationary steady
                        state, as in the TiM-DNN evaluation); write cost
                        is amortized over this batch. FEMFET is
                        non-volatile, so resident tiles persist across
                        power cycles as well.
    iso_area_pins:      (design -> tech -> NM array count) calibration
                        table for iso-area baselines; technologies not
                        pinned derive their count from the macro-area
                        ratio (:func:`iso_area_nm_arrays`).
    """
    n_arrays: int = 32
    post_ns_per_out: float = 0.4486
    post_pj_per_out: float = 31.5
    write_amortization: int = 16
    iso_area_pins: Mapping[str, Mapping[str, int]] = dataclasses.field(
        default_factory=lambda: PAPER_ISO_AREA_NM_ARRAYS
    )


PAPER_MACRO = MacroSpec()


def iso_area_nm_arrays(array: ArraySpec, macro: MacroSpec = PAPER_MACRO) -> int:
    """NM arrays fitting the CiM macro's silicon area: the paper's
    pinned counts where available, else derived from the design's
    macro-area ratio on this technology. The pins were measured at the
    paper's 32-array macro — a differently sized macro always derives
    (an iso-area NM baseline must have at least as many arrays as the
    CiM macro it matches, since CiM macro area > NM)."""
    if macro.n_arrays == PAPER_MACRO.n_arrays:
        pinned = macro.iso_area_pins.get(array.design, {}).get(array.technology)
        if pinned is not None:
            return pinned
    return max(macro.n_arrays, int(macro.n_arrays * array_cost(array).macro_area))


@dataclasses.dataclass(frozen=True)
class GemmLayer:
    """One DNN layer as a GEMM: out[M, N] = in[M, K] @ w[K, N].

    Convs are im2col-lowered (K = C_in * kh * kw, M = H_out * W_out).
    RNN steps: K = input + hidden, N = gates * hidden, M = timesteps.
    """
    name: str
    m: int
    k: int
    n: int

    @property
    def macs(self) -> int:
        return self.m * self.k * self.n


def conv(name: str, h_out: int, c_in: int, kh: int, c_out: int,
         kw: Optional[int] = None) -> GemmLayer:
    kw = kh if kw is None else kw
    return GemmLayer(name, h_out * h_out, c_in * kh * kw, c_out)


@dataclasses.dataclass(frozen=True)
class SystemResult:
    """One (benchmark, tech, design) row of the system-level evaluation:
    total time/energy/MACs of the benchmark's layers on ``n_arrays``
    arrays (the unit :func:`system_eval` aggregates over)."""

    benchmark: str
    tech: str
    design: str
    n_arrays: int
    time_ns: float
    energy_pj: float
    macs: int


def layer_cost(layer: GemmLayer, array: ArraySpec, n_arrays: int,
               macro: MacroSpec = PAPER_MACRO,
               cost: Optional[ArrayCost] = None) -> Tuple[float, float]:
    """(time_ns, energy_pj) for one GEMM layer on ``n_arrays`` arrays of
    ``array``'s kind. ``cost`` short-circuits the per-call derivation
    when the caller already holds it (hot loop over many layers)."""
    cost = array_cost(array) if cost is None else cost
    row_tiles = math.ceil(layer.k / array.rows)     # weight tiles along K
    col_tiles = math.ceil(layer.n / array.cols)     # weight tiles along N
    tiles = row_tiles * col_tiles

    total_passes = layer.m * tiles
    # Weight loading: each tile written once (weight-stationary reuse
    # over all M vectors and a batch of write_amortization inferences);
    # two binary rows per ternary row.
    write_rows = tiles * array.rows * 2 / macro.write_amortization
    # Arrays work in parallel across tiles and across input vectors.
    parallel_time = math.ceil(total_passes / n_arrays) * cost.mac_pass_ns
    write_time = write_rows / n_arrays * cost.row_write_ns
    post = layer.m * layer.n
    drain_slots = math.ceil(array.cols / array.pcus)
    post_ns = macro.post_ns_per_out / array.clock_ghz
    post_time = post * post_ns / (n_arrays * array.pcus / float(drain_slots))

    time_ns = parallel_time + write_time + post_time
    energy_pj = (
        total_passes * cost.mac_pass_pj
        + write_rows * cost.row_write_pj
        + post * macro.post_pj_per_out
    )
    return time_ns, energy_pj


def run_layers(name: str, layers: Sequence[GemmLayer], array: ArraySpec,
               macro: MacroSpec = PAPER_MACRO,
               n_arrays: Optional[int] = None) -> SystemResult:
    """Execute a GEMM workload on a macro of ``array``s."""
    n_arrays = macro.n_arrays if n_arrays is None else n_arrays
    cost = array_cost(array)
    t = e = 0.0
    macs = 0
    for layer in layers:
        lt, le = layer_cost(layer, array, n_arrays, macro, cost=cost)
        t += lt
        e += le
        macs += layer.macs
    return SystemResult(name, array.technology, array.design, n_arrays,
                        t, e, macs)


def run_system(benchmark: str, tech: str, design: str,
               n_arrays: Optional[int] = None,
               macro: MacroSpec = PAPER_MACRO) -> SystemResult:
    """Paper-suite entry point (Figs 12/13): run one named DNN benchmark
    on the default-geometry array of (tech, design)."""
    from repro.hw import dnn_suite

    layers = dnn_suite.get_benchmarks()[benchmark]
    array = ArraySpec(technology=tech, design=design)
    return run_layers(benchmark, layers, array, macro, n_arrays)


def speedup_and_energy(tech: str, design: str, baseline: str = "iso-capacity",
                       macro: MacroSpec = PAPER_MACRO) -> Dict[str, Dict[str, float]]:
    """Per-benchmark speedup and energy-reduction of ``design`` vs the
    NM baseline variant (Figs 12/13). Works for any registered
    technology — non-paper techs derive their iso-area sizing."""
    from repro.hw import dnn_suite

    from repro.hw import registry as reg

    if not reg.get_design(design).cim:
        raise ValueError(f"compare a CiM design against NM, not {design!r}")
    array = ArraySpec(technology=tech, design=design)
    if baseline == "iso-capacity":
        nm_arrays = macro.n_arrays
    elif baseline == "iso-area":
        nm_arrays = iso_area_nm_arrays(array, macro)
    else:
        raise ValueError(baseline)
    out: Dict[str, Dict[str, float]] = {}
    for bench in dnn_suite.get_benchmarks():
        cim = run_system(bench, tech, design, macro.n_arrays, macro)
        nm = run_system(bench, tech, "NM", nm_arrays, macro)
        out[bench] = {
            "speedup": nm.time_ns / cim.time_ns,
            "energy_reduction": nm.energy_pj / cim.energy_pj,
        }
    return out


def average_speedup(tech: str, design: str, baseline: str,
                    macro: MacroSpec = PAPER_MACRO) -> float:
    """Geometric-mean-free average of per-benchmark speedups of
    ``design`` on ``tech`` against ``baseline`` ("iso-capacity" /
    "iso-area") — the Figs 12/13 headline aggregation."""
    res = speedup_and_energy(tech, design, baseline, macro)
    vals = [v["speedup"] for v in res.values()]
    return float(sum(vals) / len(vals))


def average_energy_reduction(tech: str, design: str,
                             baseline: str = "iso-capacity",
                             macro: MacroSpec = PAPER_MACRO) -> float:
    """Average per-benchmark energy reduction of ``design`` on ``tech``
    against ``baseline`` (companion to :func:`average_speedup`)."""
    res = speedup_and_energy(tech, design, baseline, macro)
    vals = [v["energy_reduction"] for v in res.values()]
    return float(sum(vals) / len(vals))


# Paper-reported system-level averages (Figs 12/13 text) for validation.
PAPER_SYSTEM_SPEEDUP = {
    ("CiM-I", "iso-capacity"): {"8T-SRAM": 6.74, "3T-eDRAM": 6.59, "3T-FEMFET": 7.12},
    ("CiM-I", "iso-area"): {"8T-SRAM": 5.41, "3T-eDRAM": 4.63, "3T-FEMFET": 5.00},
    ("CiM-II", "iso-capacity"): {"8T-SRAM": 4.90, "3T-eDRAM": 4.78, "3T-FEMFET": 5.06},
    ("CiM-II", "iso-area"): {"8T-SRAM": 4.21, "3T-eDRAM": 3.85, "3T-FEMFET": 3.99},
}
PAPER_SYSTEM_ENERGY = {
    "CiM-I": {"8T-SRAM": 2.46, "3T-eDRAM": 2.52, "3T-FEMFET": 2.54},
    "CiM-II": {"8T-SRAM": 2.12, "3T-eDRAM": 2.14, "3T-FEMFET": 2.14},
}
