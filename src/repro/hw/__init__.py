"""``repro.hw`` — the declarative hardware API (DESIGN.md §7).

Mirror of the execution API: where ``repro.core.execution`` makes the
ternary-MAC *semantics* data (``CiMExecSpec`` + backend registry), this
package makes the *hardware* data —

  * :class:`ArraySpec` — one memory array (technology, design,
    geometry), validated against the technology / design registries,
  * :func:`register_technology` / :func:`register_design` — new memory
    cells (RRAM ternary synapses, ...) land as one registration of cost
    parameters; every consumer (bench_array, ``api.spec_cost_summary``,
    dry-run/roofline cells, the system projection) picks them up with
    zero edits,
  * :class:`MacroSpec` + the TiM-DNN-style system model (``hw.macro``),
  * :func:`project` — the repo's own registry architectures
    (transformer / SSM / hybrid / MoE / encdec / VLM) run through the
    accelerator model (``hw.workload``),
  * the paper's Figs 9/11 claims derived — not stored — and pinned as a
    validation table (``hw.array.paper_validation_table``).

``core/cost_model.py`` and ``core/accelerator.py`` are deprecated
compatibility shims over this package.
"""
from repro.hw.array import (  # noqa: F401
    ArrayCost,
    ArraySpec,
    array_cost,
    design_claims,
    flavor_comparison,
    paper_validation_table,
    parse_array_spec,
)
from repro.hw.macro import (  # noqa: F401
    GemmLayer,
    MacroSpec,
    PAPER_MACRO,
    PAPER_SYSTEM_ENERGY,
    PAPER_SYSTEM_SPEEDUP,
    SystemResult,
    average_energy_reduction,
    average_speedup,
    iso_area_nm_arrays,
    layer_cost,
    run_layers,
    run_system,
    speedup_and_energy,
)
from repro.hw.registry import (  # noqa: F401
    PAPER_DESIGNS,
    PAPER_TECHNOLOGIES,
    DesignMetrics,
    DesignSpec,
    TechnologySpec,
    cim_designs_of,
    design_for_flavor,
    design_metrics,
    designs,
    get_design,
    get_technology,
    register_design,
    register_technology,
    technologies,
    unregister_technology,
)
from repro.hw.workload import (  # noqa: F401
    WeightGemm,
    arch_gemms,
    project,
    workload_layers,
)
