"""Technology / design registries — the hardware half of the declarative
API (mirror of the kernel registry in ``repro.core.execution``).

The paper's array analysis (Section V) is parameterized by exactly two
things:

  * a **memory technology** — absolute NM-baseline timing/energy plus the
    normalized Fig 9/11 ratios of each CiM design against that baseline
    (8T-SRAM, 3T-eDRAM, 3T-FEMFET in the paper; RRAM ternary synapses or
    any future cell land here as one ``register_technology`` call), and
  * an **array design** — how the array computes (near-memory row-by-row
    readout vs in-memory multi-row assertion) and which execution-spec
    flavor it serves (NM, SiTe CiM I, SiTe CiM II).

Everything downstream (``hw.array`` cost derivation, the ``hw.macro``
system model, ``hw.workload`` projections, bench_array/bench_system,
``api.spec_cost_summary``) iterates these registries, so a new
technology registered with cost parameters only — zero edits to any
module — immediately shows up end to end.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Mapping, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class DesignMetrics:
    """Normalized-to-NM metrics of one CiM design on one technology.

    These ratios are the technology's *cost parameters* (for the paper's
    three technologies they come straight from Figs 9/11 and Section V
    text); the derived claims that the paper reports are computed from
    them in ``hw.array`` and pinned as a validation table — the split
    between calibration inputs and validated outputs.
    """
    cim_latency_vs_nm: float      # full MAC pass latency ratio
    cim_energy_vs_nm: float       # full MAC pass energy ratio
    read_latency_vs_nm: float
    read_energy_vs_nm: float
    write_latency_vs_nm: float
    write_energy_vs_nm: float
    cell_area_vs_nm: float        # ternary cell area ratio
    macro_area_vs_nm: float       # incl. peripherals (ADCs vs NM MAC unit)


@dataclasses.dataclass(frozen=True)
class TechnologySpec:
    """One memory technology: absolute NM-baseline scale + per-design ratios.

    t_read_ns / e_read_pj: one row read (a full row of bit-cell pairs
      sensed in parallel) and its energy.
    t_write_ns / e_write_pj: one row write.
    t_nm_mac_ns / e_nm_mac_pj: digital near-memory MAC of one row against
      the input element (pipelined with the next read in the NM design).
    leakage_mw: array standby power (0 for NVM — paper Section II.C).
    designs: design name -> DesignMetrics (the NM baseline itself is
      implicitly all-1.0 and need not be listed).
    """
    name: str
    t_read_ns: float
    e_read_pj: float
    t_write_ns: float
    e_write_pj: float
    t_nm_mac_ns: float
    e_nm_mac_pj: float
    leakage_mw: float
    designs: Mapping[str, DesignMetrics] = dataclasses.field(default_factory=dict)


@dataclasses.dataclass(frozen=True)
class DesignSpec:
    """One array design: how the array computes a MAC pass.

    cim:    True if multiple rows are asserted per cycle (computing in
            memory); False for the row-by-row near-memory readout.
    flavor: the ``CiMExecSpec.flavor`` this design serves ("I"/"II"),
            None for the NM baseline (``api.spec_design`` routes through
            this mapping).
    """
    name: str
    cim: bool
    flavor: Optional[str] = None
    description: str = ""


_NM_METRICS = DesignMetrics(1.0, 1.0, 1.0, 1.0, 1.0, 1.0, 1.0, 1.0)

_TECHNOLOGIES: Dict[str, TechnologySpec] = {}
_DESIGNS: Dict[str, DesignSpec] = {}


def register_technology(spec: TechnologySpec) -> TechnologySpec:
    """Register a memory technology. Every design named in
    ``spec.designs`` must already be registered (typos die early)."""
    if not spec.name:
        raise ValueError("technology needs a name")
    for d in spec.designs:
        if d not in _DESIGNS:
            raise ValueError(
                f"technology {spec.name!r} references unregistered design "
                f"{d!r} (known: {sorted(_DESIGNS)}); register_design first"
            )
    _TECHNOLOGIES[spec.name] = spec
    return spec


def register_design(spec: DesignSpec) -> DesignSpec:
    """Register a CiM/NM design point by name (returns ``spec`` so it
    can be used inline); technologies reference designs by these
    names."""
    if not spec.name:
        raise ValueError("design needs a name")
    _DESIGNS[spec.name] = spec
    return spec


def unregister_technology(name: str) -> None:
    """Remove a registered technology (test/tooling hygiene)."""
    _TECHNOLOGIES.pop(name, None)


def get_technology(name: str) -> TechnologySpec:
    """The registered :class:`TechnologySpec` for ``name``; raises
    KeyError listing the registered technologies."""
    try:
        return _TECHNOLOGIES[name]
    except KeyError:
        raise KeyError(
            f"unknown technology {name!r} (registered: {technologies()}); "
            f"add one with repro.hw.register_technology"
        ) from None


def get_design(name: str) -> DesignSpec:
    """The registered :class:`DesignSpec` for ``name``; raises KeyError
    listing the registered designs."""
    try:
        return _DESIGNS[name]
    except KeyError:
        raise KeyError(
            f"unknown design {name!r} (registered: {designs()}); "
            f"add one with repro.hw.register_design"
        ) from None


def technologies() -> Tuple[str, ...]:
    """Registered technology names, registration order."""
    return tuple(_TECHNOLOGIES)


def designs() -> Tuple[str, ...]:
    """Registered design names, registration order."""
    return tuple(_DESIGNS)


def design_metrics(tech: str, design: str) -> DesignMetrics:
    """Normalized ratios of ``design`` on ``tech`` (NM = all 1.0)."""
    t = get_technology(tech)
    d = get_design(design)
    if not d.cim:
        return _NM_METRICS
    try:
        return t.designs[design]
    except KeyError:
        raise KeyError(
            f"technology {tech!r} has no cost parameters for design "
            f"{design!r} (it provides: {sorted(t.designs)})"
        ) from None


def cim_designs_of(tech: str) -> Tuple[str, ...]:
    """The CiM designs a technology provides cost parameters for."""
    return tuple(d for d in get_technology(tech).designs if get_design(d).cim)


def design_for_flavor(flavor: str) -> str:
    """Map an execution-spec flavor onto its array design."""
    for d in _DESIGNS.values():
        if d.cim and d.flavor == flavor:
            return d.name
    raise KeyError(
        f"no registered CiM design serves flavor {flavor!r} "
        f"(designs: {designs()})"
    )


# ---------------------------------------------------------------------------
# Built-ins: the paper's designs and technologies (Figs 9/11, Section V)
# ---------------------------------------------------------------------------

register_design(DesignSpec(
    "NM", cim=False, flavor=None,
    description="near-memory baseline: row-by-row readout + digital MAC",
))
register_design(DesignSpec(
    "CiM-I", cim=True, flavor="I",
    description="SiTe CiM I: 16 rows asserted per cycle, cross-coupled cell",
))
register_design(DesignSpec(
    "CiM-II", cim=True, flavor="II",
    description="SiTe CiM II: one row per each of the 16 blocks per cycle",
))

# Fig. 9 (SiTe CiM I): "~88% lower latency" for all three technologies;
# energy savings 74 / 78 / 78%; read energy +22/24/17%, read latency
# +7/7/19%; write latency +4/4/10%, write energy comparable; cell area
# +18/34/34%; macro area 1.3x-1.53x (SRAM at the low end — its baseline
# cell is largest, so the relative ADC overhead is smallest; the paper
# gives the range, the per-tech split is our documented assumption).
# Fig. 11 (SiTe CiM II): MAC delay improvements 80 / 78 / 84%; energy
# 61 / 63 / 62%; read speed 2.4X / 2.6X / 1.8X lower; read energy
# +74/44/79%; write latency +8/10/3%; cell area +6%; macro 1.21x-1.33x.
# Absolute NM scale: 45nm PTM class numbers; SRAM fastest read, FEMFET
# slow high-voltage write (-5V reset / +4.8V set), eDRAM in between.
register_technology(TechnologySpec(
    name="8T-SRAM",
    t_read_ns=1.0, e_read_pj=12.0, t_write_ns=1.0, e_write_pj=14.0,
    t_nm_mac_ns=1.2, e_nm_mac_pj=22.0, leakage_mw=1.5,
    designs={
        "CiM-I": DesignMetrics(0.12, 0.26, 1.07, 1.22, 1.04, 1.00, 1.18, 1.30),
        "CiM-II": DesignMetrics(0.20, 0.39, 2.40, 1.74, 1.08, 1.00, 1.06, 1.21),
    },
))
register_technology(TechnologySpec(
    name="3T-eDRAM",
    t_read_ns=1.3, e_read_pj=10.0, t_write_ns=1.1, e_write_pj=11.0,
    t_nm_mac_ns=1.2, e_nm_mac_pj=22.0, leakage_mw=0.8,
    designs={
        "CiM-I": DesignMetrics(0.12, 0.22, 1.07, 1.24, 1.04, 1.00, 1.34, 1.53),
        "CiM-II": DesignMetrics(0.22, 0.37, 2.60, 1.44, 1.10, 1.00, 1.06, 1.33),
    },
))
register_technology(TechnologySpec(
    name="3T-FEMFET",
    t_read_ns=1.5, e_read_pj=10.0, t_write_ns=8.0, e_write_pj=30.0,
    t_nm_mac_ns=1.2, e_nm_mac_pj=22.0, leakage_mw=0.0,
    designs={
        "CiM-I": DesignMetrics(0.12, 0.22, 1.19, 1.17, 1.10, 1.00, 1.34, 1.53),
        "CiM-II": DesignMetrics(0.16, 0.38, 1.80, 1.79, 1.03, 1.00, 1.06, 1.33),
    },
))

# The paper's technology set — validation tables iterate these (a newly
# registered technology appears in cost/bench rows but is never silently
# compared against the paper's Figs).
PAPER_TECHNOLOGIES = ("8T-SRAM", "3T-eDRAM", "3T-FEMFET")
PAPER_DESIGNS = ("NM", "CiM-I", "CiM-II")
