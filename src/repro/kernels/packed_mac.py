"""Pallas TPU kernels: bitplane-packed ternary CiM matmul.

The SiTe CiM cell stores a ternary weight as two binary bit-cells (M1,
M2). These kernels keep weights in exactly that differential format,
packed 8-per-byte along K (repro.core.ternary.pack_ternary): two uint8
arrays of shape (K/8, N). Per ternary weight that is 2 bits of HBM
traffic — 8x less than int8 and 16x less than bf16, which is the win in
the weight-streaming-bound decode regime (see EXPERIMENTS.md §Perf).

Two variants share the format (DESIGN.md §9):

  * :func:`packed_cim_matmul` — the prefill-shaped kernel (M-tiled grid,
    bf16 operands, f32 accumulation). In-kernel, the bitplanes are
    expanded to ternary bf16 in VMEM (cheap VPU work overlapped with the
    MXU) and fed to the same a/b-decomposition CiM MAC as
    kernels/ternary_mac.py.
  * :func:`packed_cim_matmul_decode` — the decode-shaped (small-M)
    variant: the whole M extent rides inside every grid step (grid is
    (N, K) only), so each (k, j) plane tile is unpacked exactly once per
    call instead of once per M-tile, and the a/b event counts — small
    integers bounded by ``block`` — are computed and accumulated in
    int32 from int8 operands. Bit-identical to the prefill kernel
    (integer event counts are exact in both f32 and int32).

VMEM budget per grid step, default (bm, bk, bn) = (128, 256, 128):
  x: 128*256*2 = 64 KiB; packed planes: 2 * (256/8)*128 = 8 KiB;
  unpacked w: 256*128*2 = 64 KiB; out: 64 KiB; intermediates
  2*(256/16)*128*128*4 = 2 MiB  -> ~2.2 MiB, fine for double buffering.
Decode variant, default (bk, bn) = (256, 128) at M <= 8: the x tile is
8*256*1 = 2 KiB int8 and the intermediates 2*(256/16)*8*128*4 = 128 KiB
— the grid-step footprint shrinks ~16x with the M extent.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams; support both
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

DEFAULT_BLOCK = 16
DEFAULT_ADC_MAX = 8


def _unpack_plane_bits(plane: jax.Array, dtype) -> jax.Array:
    """(bk/8, bn) uint8 -> (bk, bn) {0,1} bits in ``dtype``, K-major."""
    kp, bn = plane.shape
    shifts = jax.lax.broadcasted_iota(jnp.uint8, (kp, 8, bn), 1)
    bits = (plane[:, None, :] >> shifts) & jnp.uint8(1)
    return bits.reshape(kp * 8, bn).astype(dtype)


def _unpack_plane(plane: jax.Array) -> jax.Array:
    """(bk/8, bn) uint8 -> (bk, bn) {0,1} float32 bits, K-major order."""
    return _unpack_plane_bits(plane, jnp.float32)


def _packed_kernel(x_ref, wp_ref, wn_ref, o_ref, *, sub, adc_max, cim):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...].astype(jnp.float32)  # (bm, bk)
    w = _unpack_plane(wp_ref[...]) - _unpack_plane(wn_ref[...])  # (bk, bn)
    bm, bk = x.shape
    bn = w.shape[-1]
    if not cim:
        o_ref[...] += jax.lax.dot_general(
            x, w, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        return
    kb = bk // sub
    xb = x.reshape(bm, kb, sub).swapaxes(0, 1)
    wb = w.reshape(kb, sub, bn)
    dims = (((2,), (1,)), ((0,), (0,)))
    p = jax.lax.dot_general(xb, wb, dims, preferred_element_type=jnp.float32)
    m = jax.lax.dot_general(
        jnp.abs(xb), jnp.abs(wb), dims, preferred_element_type=jnp.float32
    )
    a = (m + p) * 0.5
    b = (m - p) * 0.5
    part = jnp.minimum(a, adc_max) - jnp.minimum(b, adc_max)
    o_ref[...] += jnp.sum(part, axis=0)


@functools.partial(
    jax.jit,
    static_argnames=("block", "adc_max", "cim", "bm", "bk", "bn", "interpret"),
)
def packed_cim_matmul(
    x: jax.Array,
    w_pos: jax.Array,
    w_neg: jax.Array,
    *,
    block: int = DEFAULT_BLOCK,
    adc_max: int = DEFAULT_ADC_MAX,
    cim: bool = True,
    bm: int = 128,
    bk: int = 256,
    bn: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """x: (M, K) ternary values; w_pos/w_neg: (K/8, N) packed bitplanes.

    ``cim=True`` applies the per-16-block ADC clamp; ``cim=False`` is the
    exact (NM-baseline) product from the packed format.
    """
    m_dim, k_dim = x.shape
    kp, n_dim = w_pos.shape
    assert w_neg.shape == w_pos.shape
    assert kp * 8 == k_dim, (x.shape, w_pos.shape)
    assert m_dim % bm == 0 and k_dim % bk == 0 and n_dim % bn == 0
    assert bk % (8 * block) == 0 or not cim
    grid = (m_dim // bm, n_dim // bn, k_dim // bk)
    kernel = functools.partial(
        _packed_kernel, sub=block, adc_max=float(adc_max), cim=cim
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk // 8, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((bk // 8, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m_dim, n_dim), jnp.float32),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(x, w_pos, w_neg)


def _packed_decode_kernel(x_ref, wp_ref, wn_ref, o_ref, *, sub, adc_max, cim):
    @pl.when(pl.program_id(1) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...]  # (m, bk) int8 ternary values
    w = _unpack_plane_bits(wp_ref[...], jnp.int8) - _unpack_plane_bits(
        wn_ref[...], jnp.int8
    )  # (bk, bn) int8
    m, bk = x.shape
    bn = w.shape[-1]
    if not cim:
        o_ref[...] += jax.lax.dot_general(
            x, w, (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32
        )
        return
    kb = bk // sub
    xb = x.reshape(m, kb, sub).swapaxes(0, 1)
    wb = w.reshape(kb, sub, bn)
    dims = (((2,), (1,)), ((0,), (0,)))
    p = jax.lax.dot_general(xb, wb, dims, preferred_element_type=jnp.int32)
    mm = jax.lax.dot_general(
        jnp.abs(xb), jnp.abs(wb), dims, preferred_element_type=jnp.int32
    )
    # a/b are the RBL1/RBL2 discharge-event counts: small non-negative
    # integers bounded by `sub` (TiM-DNN's partial-sum range analysis),
    # so the halving and the clamp stay exact integer arithmetic
    a = (mm + p) // 2
    b = (mm - p) // 2
    part = jnp.minimum(a, adc_max) - jnp.minimum(b, adc_max)
    o_ref[...] += jnp.sum(part, axis=0)


@functools.partial(
    jax.jit,
    static_argnames=("block", "adc_max", "cim", "bk", "bn", "interpret"),
)
def packed_cim_matmul_decode(
    x: jax.Array,
    w_pos: jax.Array,
    w_neg: jax.Array,
    *,
    block: int = DEFAULT_BLOCK,
    adc_max: int = DEFAULT_ADC_MAX,
    cim: bool = True,
    bk: int = 256,
    bn: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Decode-shaped packed MAC: x (M, K) int8 ternary values with a
    *small* M (the whole extent rides in every grid step — callers pad M
    to the decode tile, 8, not to 128); w_pos/w_neg (K/8, N) packed
    bitplanes.

    The grid is (N/bn, K/bk): with no M grid dimension each (k, j) plane
    tile is unpacked exactly once per call, and the per-16-row a/b event
    counts accumulate in int32 (they are bounded by ``block``, so the
    integer pipeline is bit-identical to the f32 prefill kernel — pinned
    in tests/test_decode_fastpath.py). Returns int32 (M, N).
    """
    m_dim, k_dim = x.shape
    kp, n_dim = w_pos.shape
    assert w_neg.shape == w_pos.shape
    assert kp * 8 == k_dim, (x.shape, w_pos.shape)
    assert m_dim <= 128, f"decode kernel is for small M, got {m_dim}"
    assert k_dim % bk == 0 and n_dim % bn == 0
    assert bk % (8 * block) == 0 or not cim
    grid = (n_dim // bn, k_dim // bk)
    kernel = functools.partial(
        _packed_decode_kernel, sub=block, adc_max=int(adc_max), cim=cim
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((m_dim, bk), lambda j, k: (0, k)),
            pl.BlockSpec((bk // 8, bn), lambda j, k: (k, j)),
            pl.BlockSpec((bk // 8, bn), lambda j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((m_dim, bn), lambda j, k: (0, j)),
        out_shape=jax.ShapeDtypeStruct((m_dim, n_dim), jnp.int32),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(x, w_pos, w_neg)


def _packed_decode_stream_kernel(
    x_ref, w_ref, o_ref, *, sub, adc_max, cim, bk, nbuf, nk
):
    """Streaming decode body: K is not a grid dimension — the (k, j)
    plane tiles are hand-DMA'd from ``w_ref`` (ANY memory space, i.e.
    HBM on TPU) into an ``nbuf``-deep VMEM scratch while the previous
    tile's MAC runs. ``pl.run_scoped`` owns the scratch + DMA
    semaphores; the ``lax.fori_loop`` slot rotation is the same trace in
    interpret mode, so the fallback is bit-identical by construction.
    """
    j = pl.program_id(0)
    o_ref[...] = jnp.zeros_like(o_ref)
    x = x_ref[...]  # (m, K) int8 ternary values, whole K extent in VMEM
    m = x.shape[0]
    bn = o_ref.shape[-1]
    tk = bk // 4  # interleaved byte-rows per (k, j) tile: pos+neg

    def body(scratch, sem):
        def tile_dma(slot, kidx):
            return pltpu.make_async_copy(
                w_ref.at[pl.ds(kidx * tk, tk), pl.ds(j * bn, bn)],
                scratch.at[slot],
                sem.at[slot],
            )

        # Warm-up: the first nbuf-1 tiles go in flight before any MAC
        # (statically unrolled — these are the extra dma_start eqns the
        # tracing contract pins).
        for kidx in range(min(nbuf - 1, nk)):
            tile_dma(kidx, kidx).start()

        def step(i, carry):
            slot = jax.lax.rem(i, nbuf)

            @pl.when(i + nbuf - 1 < nk)
            def _prefetch():
                tile_dma(jax.lax.rem(i + nbuf - 1, nbuf), i + nbuf - 1).start()

            tile_dma(slot, i).wait()
            tile = scratch[slot]  # (bk//4, bn) uint8, pos/neg interleaved
            pair = tile.reshape(bk // 8, 2, bn)
            w = _unpack_plane_bits(pair[:, 0, :], jnp.int8) - _unpack_plane_bits(
                pair[:, 1, :], jnp.int8
            )  # (bk, bn) int8
            xc = jax.lax.dynamic_slice_in_dim(x, i * bk, bk, axis=1)
            if not cim:
                o_ref[...] += jax.lax.dot_general(
                    xc, w, (((1,), (0,)), ((), ())),
                    preferred_element_type=jnp.int32,
                )
                return carry
            kb = bk // sub
            xb = xc.reshape(m, kb, sub).swapaxes(0, 1)
            wb = w.reshape(kb, sub, bn)
            dims = (((2,), (1,)), ((0,), (0,)))
            p = jax.lax.dot_general(
                xb, wb, dims, preferred_element_type=jnp.int32
            )
            mm = jax.lax.dot_general(
                jnp.abs(xb), jnp.abs(wb), dims, preferred_element_type=jnp.int32
            )
            a = (mm + p) // 2
            b = (mm - p) // 2
            part = jnp.minimum(a, adc_max) - jnp.minimum(b, adc_max)
            o_ref[...] += jnp.sum(part, axis=0)
            return carry

        jax.lax.fori_loop(0, nk, step, 0)

    pl.run_scoped(
        body,
        scratch=pltpu.VMEM((nbuf, tk, bn), jnp.uint8),
        sem=pltpu.SemaphoreType.DMA((nbuf,)),
    )


@functools.partial(
    jax.jit,
    static_argnames=("block", "adc_max", "cim", "bk", "bn", "nbuf", "interpret"),
)
def packed_cim_matmul_decode_stream(
    x: jax.Array,
    w_int: jax.Array,
    *,
    block: int = DEFAULT_BLOCK,
    adc_max: int = DEFAULT_ADC_MAX,
    cim: bool = True,
    bk: int = 256,
    bn: int = 128,
    nbuf: int = 2,
    interpret: bool = False,
) -> jax.Array:
    """Double-buffered streaming variant of :func:`packed_cim_matmul_decode`.

    x: (M, K) int8 ternary values, small M (callers pad to the decode
    tile). ``w_int``: ONE (K/4, N) uint8 array holding both bitplanes in
    the layout-version-1 plane-interleaved ordering
    (``repro.core.ternary.interleave_planes``): byte-row 2r is the pos
    byte-row r, 2r+1 the neg byte-row r, so a single contiguous DMA
    fetches both planes of a (k, j) tile.

    The grid is (N/bn,) — K is streamed inside the kernel: while tile
    ``i``'s int32 a/b event-count MAC runs, tiles ``i+1 .. i+nbuf-1``
    are already in flight into the rotating VMEM scratch
    (``nbuf`` ∈ {2, 3} buffer slots, ``pltpu.make_async_copy`` against
    per-slot DMA semaphores). The MAC math is byte-for-byte the decode
    kernel's (int8 operands, int32 accumulation, integer halving and
    ADC clamp), so the result is bit-identical to
    :func:`packed_cim_matmul_decode` and the bitplane oracle — pinned in
    tests/test_stream_decode.py and by the
    ``execution.execute_packed.decode.stream`` tracing contract.
    Returns int32 (M, N).
    """
    m_dim, k_dim = x.shape
    rows, n_dim = w_int.shape
    assert rows * 4 == k_dim, (x.shape, w_int.shape)
    assert m_dim <= 128, f"stream decode kernel is for small M, got {m_dim}"
    assert k_dim % bk == 0 and n_dim % bn == 0
    assert bk % (8 * block) == 0 or not cim
    assert nbuf in (2, 3), f"buffer depth {nbuf} not in {{2, 3}}"
    nk = k_dim // bk
    kernel = functools.partial(
        _packed_decode_stream_kernel,
        sub=block, adc_max=int(adc_max), cim=cim, bk=bk, nbuf=nbuf, nk=nk,
    )
    return pl.pallas_call(
        kernel,
        grid=(n_dim // bn,),
        in_specs=[
            pl.BlockSpec((m_dim, k_dim), lambda j: (0, 0)),
            pl.BlockSpec(memory_space=pltpu.ANY),
        ],
        out_specs=pl.BlockSpec((m_dim, bn), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((m_dim, n_dim), jnp.int32),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel",),
        ),
        interpret=interpret,
    )(x, w_int)


# ---------------------------------------------------------------------------
# Tracing contracts (repro.analysis — DESIGN.md §10)
#
# The kernel-level invariants, declared next to the kernels they pin:
#
#   * the decode kernel's a/b event counts accumulate in int32 — an f32
#     accumulator would still be numerically exact (counts are bounded
#     by `block`) but silently abandons the integer ADC pipeline the
#     TiM-DNN macro contract costs against, and converts would creep
#     into the int8 decode datapath;
#   * the prefill kernel deliberately accumulates in f32 (bf16 MXU
#     operands) — pinned too, so a change to either side is a conscious
#     contract edit, not drift.
# ---------------------------------------------------------------------------

from repro.analysis.contracts import (  # noqa: E402
    TraceContract,
    forbid_convert,
    register_trace_contract,
)


def _decode_kernel_point():
    x = jnp.ones((8, 256), jnp.int8)
    planes = jnp.zeros((32, 128), jnp.uint8)

    def f(xv, wp, wn):
        return packed_cim_matmul_decode(xv, wp, wn, interpret=True)

    return f, (x, planes, planes)


def _prefill_kernel_point():
    x = jnp.ones((128, 256), jnp.bfloat16)
    planes = jnp.zeros((32, 128), jnp.uint8)

    def f(xv, wp, wn):
        return packed_cim_matmul(xv, wp, wn, interpret=True)

    return f, (x, planes, planes)


register_trace_contract(
    "kernels.packed_decode_kernel",
    _decode_kernel_point,
    TraceContract(
        max_host_callbacks=0,
        accum_dtype="int32",
        forbid_prims=(
            forbid_convert(
                from_kinds=("int",), to=("float32", "float64", "bfloat16"),
                within="pallas_call",
                reason="the decode kernel's int8/int32 event-count "
                       "datapath must not promote to float",
            ),
        ),
    ),
)

register_trace_contract(
    "kernels.packed_prefill_kernel",
    _prefill_kernel_point,
    TraceContract(max_host_callbacks=0, accum_dtype="float32"),
)


def _stream_kernel_point():
    x = jnp.ones((8, 512), jnp.int8)
    w_int = jnp.zeros((128, 256), jnp.uint8)  # (K/4, N) plane-interleaved

    def f(xv, wi):
        return packed_cim_matmul_decode_stream(xv, wi, interpret=True)

    return f, (x, w_int)


# The DMA-eqn pin is the overlap guarantee: exactly nbuf (= 2) dma_start
# eqns — the unrolled warm-up plus the single in-loop prefetch — and one
# dma_wait per trace. A kernel that quietly stopped prefetching (0 or 1
# starts) or began blocking per tile (more waits) breaks the pin before
# any benchmark notices.
register_trace_contract(
    "kernels.packed_decode_stream_kernel",
    _stream_kernel_point,
    TraceContract(
        max_host_callbacks=0,
        accum_dtype="int32",
        pin_prims=(("dma_start", 2), ("dma_wait", 1)),
        forbid_prims=(
            forbid_convert(
                from_kinds=("int",), to=("float32", "float64", "bfloat16"),
                within="pallas_call",
                reason="the streaming decode kernel keeps the int8/int32 "
                       "event-count datapath of the decode kernel",
            ),
        ),
    ),
)
