"""Pallas TPU kernels for the SiTe CiM compute hot-spot (the ternary MAC).

  * ternary_mac.py — blocked CiM matmul (a/b decomposition + ADC clamp)
    and the exact NM-baseline matmul kernel.
  * packed_mac.py  — bitplane-packed (2-bit) weight variant mirroring the
    differential M1/M2 memory layout; 8x HBM weight traffic reduction.
  * ops.py         — jit'd public wrappers (padding, batch dims, STE vjp).
  * ref.py         — pure-jnp oracles used by the allclose test sweeps.

Kernels target TPU (pl.pallas_call + BlockSpec VMEM tiling) and are
validated on CPU with interpret=True.
"""
from repro.kernels.ops import cim_matmul, exact_ternary_matmul  # noqa: F401
from repro.kernels.packed_mac import (  # noqa: F401
    packed_cim_matmul,
    packed_cim_matmul_decode,
)
from repro.kernels.ternary_mac import (  # noqa: F401
    ternary_cim_matmul,
    ternary_exact_matmul,
)
