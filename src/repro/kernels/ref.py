"""Pure-jnp reference oracles for the Pallas kernels.

These are deliberately simple and allocation-happy; every kernel in this
package is tested `assert_allclose` against these across shape/dtype
sweeps (tests/test_kernels.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

DEFAULT_BLOCK = 16   # N_A: rows asserted per CiM cycle
DEFAULT_ADC_MAX = 8  # 3-bit flash ADC + extra sense amp


def ref_cim_matmul(
    x: jax.Array,
    w: jax.Array,
    *,
    block: int = DEFAULT_BLOCK,
    adc_max: int = DEFAULT_ADC_MAX,
) -> jax.Array:
    """SiTe CiM semantics: per-`block` event counts a/b, clamped at
    ``adc_max``, accumulated across blocks. x: (M, K) ternary values,
    w: (K, N) ternary values. Returns f32 (M, N)."""
    m_, k = x.shape
    assert k % block == 0, (k, block)
    kb = k // block
    xf = x.astype(jnp.float32)
    wf = w.astype(jnp.float32)
    xb = xf.reshape(m_, kb, block)
    wb = wf.reshape(kb, block, -1)
    p = jnp.einsum("mki,kin->mkn", xb, wb)
    mm = jnp.einsum("mki,kin->mkn", jnp.abs(xb), jnp.abs(wb))
    a = (mm + p) * 0.5
    b = (mm - p) * 0.5
    part = jnp.minimum(a, float(adc_max)) - jnp.minimum(b, float(adc_max))
    return jnp.sum(part, axis=1)


def ref_exact_matmul(x: jax.Array, w: jax.Array) -> jax.Array:
    """Near-memory baseline: exact ternary matmul in f32."""
    return x.astype(jnp.float32) @ w.astype(jnp.float32)


def ref_packed_matmul(
    x: jax.Array,
    w_pos: jax.Array,
    w_neg: jax.Array,
    *,
    block: int = DEFAULT_BLOCK,
    adc_max: int = DEFAULT_ADC_MAX,
    cim: bool = True,
) -> jax.Array:
    """Oracle for the bitplane-packed kernel.

    w_pos/w_neg: (K // 8, N) uint8 — M1/M2 bitplanes packed 8-per-byte
    along K (repro.core.ternary.pack_ternary layout).
    """
    kp, n = w_pos.shape
    shifts = jnp.arange(8, dtype=jnp.uint8)
    bits_p = ((w_pos[:, None, :] >> shifts[None, :, None]) & 1).reshape(kp * 8, n)
    bits_n = ((w_neg[:, None, :] >> shifts[None, :, None]) & 1).reshape(kp * 8, n)
    w = bits_p.astype(jnp.float32) - bits_n.astype(jnp.float32)
    if cim:
        return ref_cim_matmul(x, w, block=block, adc_max=adc_max)
    return ref_exact_matmul(x, w)
