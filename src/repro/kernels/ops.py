"""Public jit'd wrappers over the ternary CiM kernels.

Layer code calls :func:`cim_matmul` — it handles arbitrary leading batch
dims, pads to kernel tiles, dispatches to the Pallas kernel on TPU (or its
interpret-mode twin / the pure-jnp formulation on CPU), and defines a
custom VJP: the backward pass treats the CiM array as a straight-through
exact matmul (standard STE practice for the clamp nonlinearity — the ADC
clamp is piecewise linear with slope 1 almost everywhere the forward
saturates rarely, see DESIGN.md).
"""
from __future__ import annotations

import functools
from typing import Literal

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.packed_mac import packed_cim_matmul  # noqa: F401 (re-export)
from repro.kernels.ternary_mac import (
    DEFAULT_ADC_MAX,
    DEFAULT_BLOCK,
    ternary_cim_matmul,
    ternary_exact_matmul,
)

Backend = Literal["auto", "pallas", "jnp"]


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_to(x: jax.Array, mult: int, axis: int) -> jax.Array:
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    cfg = [(0, 0)] * x.ndim
    cfg[axis] = (0, pad)
    return jnp.pad(x, cfg)


def _cim_forward(x2d, w, block, adc_max, backend):
    """(M, K) x (K, N) CiM product, tiles padded as needed."""
    m, k = x2d.shape
    n = w.shape[1]
    use_pallas = backend == "pallas" or (backend == "auto" and _on_tpu())
    if use_pallas:
        xp = _pad_to(_pad_to(x2d, 128, 0), 128, 1)
        wp = _pad_to(_pad_to(w, 128, 0), 128, 1)
        out = ternary_cim_matmul(
            xp.astype(jnp.bfloat16),
            wp.astype(jnp.bfloat16),
            block=block,
            adc_max=adc_max,
            interpret=not _on_tpu(),
        )
        return out[:m, :n]
    # jnp formulation — identical math, lowers everywhere (CPU dry-run,
    # autodiff tracing, sharded pjit).
    xp = _pad_to(x2d.astype(jnp.float32), block, 1)
    wp = _pad_to(w.astype(jnp.float32), block, 0)
    return ref.ref_cim_matmul(xp, wp, block=block, adc_max=adc_max)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4))
def cim_matmul(
    x: jax.Array,
    w: jax.Array,
    block: int = DEFAULT_BLOCK,
    adc_max: int = DEFAULT_ADC_MAX,
    backend: Backend = "auto",
) -> jax.Array:
    """Signed-ternary CiM matmul with STE gradients.

    x: (..., K) ternary values; w: (K, N) ternary values.
    Forward: per-``block`` ADC-clamped MAC. Backward: exact-matmul
    gradients (straight-through past the clamp).
    """
    lead = x.shape[:-1]
    x2d = x.reshape(-1, x.shape[-1])
    out = _cim_forward(x2d, w, block, adc_max, backend)
    return out.reshape(lead + (w.shape[1],)).astype(x.dtype)


def _cim_fwd(x, w, block, adc_max, backend):
    return cim_matmul(x, w, block, adc_max, backend), (x, w)


def _cim_bwd(block, adc_max, backend, res, g):
    x, w = res
    gf = g.astype(jnp.float32)
    dx = (gf @ w.astype(jnp.float32).T).astype(x.dtype)
    x2d = x.reshape(-1, x.shape[-1]).astype(jnp.float32)
    g2d = gf.reshape(-1, g.shape[-1])
    dw = (x2d.T @ g2d).astype(w.dtype)
    return dx, dw


cim_matmul.defvjp(_cim_fwd, _cim_bwd)


def exact_ternary_matmul(x: jax.Array, w: jax.Array, backend: Backend = "auto") -> jax.Array:
    """Near-memory baseline product (no clamp), kernel-backed on TPU."""
    lead = x.shape[:-1]
    x2d = x.reshape(-1, x.shape[-1])
    m, k = x2d.shape
    n = w.shape[1]
    use_pallas = backend == "pallas" or (backend == "auto" and _on_tpu())
    if use_pallas:
        xp = _pad_to(_pad_to(x2d, 128, 0), 512, 1)
        wp = _pad_to(_pad_to(w, 512, 0), 128, 1)
        out = ternary_exact_matmul(
            xp.astype(jnp.bfloat16), wp.astype(jnp.bfloat16),
            interpret=not _on_tpu(),
        )[:m, :n]
    else:
        out = ref.ref_exact_matmul(x2d, w)
    return out.reshape(lead + (n,)).astype(x.dtype)
