"""Deprecated jit'd wrappers over the ternary CiM kernels.

Historically layer code called :func:`cim_matmul` directly; dispatch now
lives in the declarative execution API (``repro.api`` /
``repro.core.execution``): a ``CiMExecSpec`` names the formulation,
backend, and packing, and a registry maps it to a kernel. The wrappers
below are kept for source compatibility — each one builds the equivalent
spec and forwards to ``execute(spec, x, w)``, which owns batch-dim
flattening, tile padding, dtype policy, and the STE custom_vjp (backward
treats the CiM array as a straight-through exact matmul — the ADC clamp
is piecewise linear with slope 1 almost everywhere, see DESIGN.md §4).
"""
from __future__ import annotations

from typing import Literal

import jax

from repro.kernels.packed_mac import packed_cim_matmul  # noqa: F401 (re-export)
from repro.kernels.ternary_mac import (  # noqa: F401 (re-export)
    DEFAULT_ADC_MAX,
    DEFAULT_BLOCK,
    ternary_cim_matmul,
    ternary_exact_matmul,
)

Backend = Literal["auto", "pallas", "jnp"]


def cim_matmul(
    x: jax.Array,
    w: jax.Array,
    block: int = DEFAULT_BLOCK,
    adc_max: int = DEFAULT_ADC_MAX,
    backend: Backend = "auto",
) -> jax.Array:
    """Deprecated alias — forwards to ``repro.api.execute`` with the
    "blocked" formulation.

    x: (..., K) ternary values; w: (K, N) ternary values.
    Forward: per-``block`` ADC-clamped MAC. Backward: exact-matmul
    gradients (straight-through past the clamp).
    """
    # import inside the function: repro.core.execution registers the
    # kernels from this package, so the module-level import would cycle
    from repro.core import execution as xapi

    spec = xapi.CiMExecSpec(
        formulation="blocked", backend=backend, block=block, adc_max=adc_max
    )
    return xapi.execute(spec, x, w)


def exact_ternary_matmul(x: jax.Array, w: jax.Array, backend: Backend = "auto") -> jax.Array:
    """Deprecated alias — forwards to ``repro.api.execute`` with the
    "exact" formulation (near-memory baseline, kernel-backed on TPU)."""
    from repro.core import execution as xapi

    return xapi.execute(xapi.CiMExecSpec(formulation="exact", backend=backend), x, w)
