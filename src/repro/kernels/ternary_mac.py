"""Pallas TPU kernel: signed-ternary CiM matmul (a/b decomposition + ADC clamp).

TPU-native formulation of the SiTe CiM array semantics (DESIGN.md §2):
for each 16-element block of the contraction dimension we need the event
counts

    a = (|x|·|w| + x·w) / 2,     b = (|x|·|w| - x·w) / 2

clamped at the ADC bound (8) and accumulated. Inside a (bm, bk, bn) tile
the kernel performs two batched dot_generals with the K-tile split into
``bk/16`` sub-blocks of 16 (the N_A row-assertion granularity), then the
elementwise clamp/recombine, accumulating into the output tile across the
K grid dimension.

VMEM budget per grid step (bf16 in, f32 acc):
    x tile: bm*bk*2 B, w tile: bk*bn*2 B, out tile: bm*bn*4 B,
    two (kb, bm, bn) f32 intermediates: 2*(bk/16)*bm*bn*4 B.
Default (bm, bk, bn) = (128, 128, 128): 32 KiB + 32 KiB + 64 KiB +
2*8*64 KiB = 1.15 MiB — comfortably inside the ~16 MiB VMEM of a v5e
core, leaving room for double buffering. All matmul dims are multiples of
the 128 MXU/lane width except the 16-deep sub-contractions, which are an
inherent cost of the faithful per-block ADC semantics (the hillclimbed
variant amortizes them — see kernels/ops.py and EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams; support both
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

DEFAULT_BLOCK = 16
DEFAULT_ADC_MAX = 8


def _cim_mac_kernel(x_ref, w_ref, o_ref, *, sub: int, adc_max: float, nk: int):
    """One (i, j, k) grid step: accumulate the CiM partial for this K tile."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...]  # (bm, bk) ternary values in bf16/f32
    w = w_ref[...]  # (bk, bn)
    bm, bk = x.shape
    bn = w.shape[-1]
    kb = bk // sub

    # (kb, bm, sub) x (kb, sub, bn) batched over the 16-row sub-blocks.
    xb = x.reshape(bm, kb, sub).swapaxes(0, 1)
    wb = w.reshape(kb, sub, bn)
    dims = (((2,), (1,)), ((0,), (0,)))
    p = jax.lax.dot_general(xb, wb, dims, preferred_element_type=jnp.float32)
    m = jax.lax.dot_general(
        jnp.abs(xb), jnp.abs(wb), dims, preferred_element_type=jnp.float32
    )
    a = (m + p) * 0.5
    b = (m - p) * 0.5
    part = jnp.minimum(a, adc_max) - jnp.minimum(b, adc_max)
    o_ref[...] += jnp.sum(part, axis=0)


@functools.partial(
    jax.jit,
    static_argnames=("block", "adc_max", "bm", "bk", "bn", "interpret"),
)
def ternary_cim_matmul(
    x: jax.Array,
    w: jax.Array,
    *,
    block: int = DEFAULT_BLOCK,
    adc_max: int = DEFAULT_ADC_MAX,
    bm: int = 128,
    bk: int = 128,
    bn: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """CiM ternary matmul. x: (M, K), w: (K, N), values in {-1, 0, 1}.

    Shapes must tile evenly (callers pad; repro.kernels.ops handles this).
    Returns f32 (M, N) with per-``block`` ADC clamping at ``adc_max``.
    """
    m_dim, k_dim = x.shape
    k2, n_dim = w.shape
    assert k_dim == k2, (x.shape, w.shape)
    assert m_dim % bm == 0 and k_dim % bk == 0 and n_dim % bn == 0, (
        x.shape,
        w.shape,
        (bm, bk, bn),
    )
    assert bk % block == 0, (bk, block)
    grid = (m_dim // bm, n_dim // bn, k_dim // bk)

    kernel = functools.partial(
        _cim_mac_kernel, sub=block, adc_max=float(adc_max), nk=grid[2]
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m_dim, n_dim), jnp.float32),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(x, w)


def _exact_mac_kernel(x_ref, w_ref, o_ref):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jax.lax.dot_general(
        x_ref[...],
        w_ref[...],
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


@functools.partial(
    jax.jit, static_argnames=("bm", "bk", "bn", "interpret")
)
def ternary_exact_matmul(
    x: jax.Array,
    w: jax.Array,
    *,
    bm: int = 128,
    bk: int = 512,
    bn: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Near-memory baseline kernel: exact ternary matmul with full-depth
    MXU contractions (no per-block clamp). Also the fast path of the
    clip-as-correction optimization."""
    m_dim, k_dim = x.shape
    _, n_dim = w.shape
    assert m_dim % bm == 0 and k_dim % bk == 0 and n_dim % bn == 0
    grid = (m_dim // bm, n_dim // bn, k_dim // bk)
    return pl.pallas_call(
        _exact_mac_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m_dim, n_dim), jnp.float32),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(x, w)
