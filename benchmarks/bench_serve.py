"""Serving benchmark: fused ragged-position decode vs the per-slot-loop
baseline, over one continuous-batching workload.

The paper's system-level claim (§IV: up to 7X throughput) rests on
keeping the CiM arrays busy with *batched* dot products; TiM-DNN
likewise amortizes array activations across a full batch. The serving
metric that tracks this is how much model work one decode step feeds the
arrays: the fused batcher runs one batched ``decode_step`` over all
slots at heterogeneous cache positions, the legacy baseline de-batches
into a static per-slot loop of single-row steps.

Reported per mode:
  * ``tok_s``                — end-to-end generated tokens / wall second
  * ``decode_steps``         — jitted decode dispatches for the workload
  * ``host_syncs``           — device->host fetches (fused: 1 per step)
  * ``host_syncs_per_token`` — serving-loop chattiness
  * ``compile_s``            — time to build + compile the step functions

The looped baseline is the pre-ragged-decode engine verbatim: its
per-slot prefill runs eagerly (never jitted) and recompiles nothing but
pays op-by-op dispatch for every request, and every active slot costs
one host sync per step — both counted against it here, because both are
what the fused path removes.

Emits ``BENCH_serve.json`` (CI uploads it as a workflow artifact; the
bench-smoke job fails if the file is missing or malformed).

``--tp N`` adds a tensor-parallel row: the same fused workload served
over an N-device ("data", "model") mesh (params/caches sharded by
``repro.dist.sharding``). On CPU the devices are virtual — forced below,
before the first jax import — so the row measures the *serving
discipline under sharding* (token identity, decode steps, host-sync
counts survive TP; see tests/test_tp_serve.py), not real TP speedup.

The ``cache_dtype`` sweep (always emitted) serves the same fused
workload under each KV-cache storage dtype (DESIGN.md §13) and reports,
per dtype: measured cache bytes per slot, the capacity multiplier vs
bf16 (how many quantized slots fit in the bf16 cache budget), and
whether fused serving stayed token-identical to per-request
``generate()`` under the same dtype (the correctness bar int8 must meet
exactly; ternary reports its greedy common-prefix length instead).

Runs the smoke config by default (matching the ``benchmarks.run``
harness, and CPU-feasible); ``--full`` opts into the full arch config.

Usage:
    PYTHONPATH=src python -m benchmarks.bench_serve [--full] [--tp N] [--out PATH]
"""
from __future__ import annotations

import sys

from repro.launch._boot import force_host_devices_for_tp

force_host_devices_for_tp(sys.argv)  # before the jax import below

import argparse
import json
import time

import jax

from repro.models import transformer as T
from repro.models.registry import get_config
from repro.profile import backend_block as _backend_block
from repro.serve.engine import ContinuousBatcher, Request


def _workload(cfg, n_requests: int, max_new: int):
    """Deterministic ragged request mix (prompt lengths 1-4, ragged max_new)."""
    return [
        Request(
            i,
            [1 + (i * 7 + j) % (cfg.vocab - 1) for j in range(1 + i % 4)],
            max_new=2 + i % max_new,
        )
        for i in range(n_requests)
    ]


def _run_mode(params, cfg, fused: bool, n_slots: int, s_max: int,
              n_requests: int, max_new: int, mesh=None):
    t0 = time.perf_counter()
    batcher = ContinuousBatcher(params, cfg, n_slots=n_slots, s_max=s_max,
                                fused=fused, mesh=mesh)
    # warm with the full workload once so the measured pass is steady-state
    # for BOTH modes (the looped baseline recompiles prefill per distinct
    # prompt length — charged to compile_s here, not to tok_s)
    for r in _workload(cfg, n_requests, max_new):
        batcher.submit(r)
    batcher.run()
    compile_s = time.perf_counter() - t0

    batcher.decode_steps = batcher.host_syncs = 0
    reqs = _workload(cfg, n_requests, max_new)
    for r in reqs:
        batcher.submit(r)
    t0 = time.perf_counter()
    batcher.run()
    wall = time.perf_counter() - t0
    assert all(r.done for r in reqs)
    tokens = sum(len(r.generated) for r in reqs)
    return {
        "mode": ("fused" if fused else "looped") if mesh is None
                else f"fused_tp{mesh.shape['model']}",
        "tokens": tokens,
        "wall_s": round(wall, 4),
        "tok_s": round(tokens / max(wall, 1e-9), 2),
        "decode_steps": batcher.decode_steps,
        "host_syncs": batcher.host_syncs,
        "host_syncs_per_token": round(batcher.host_syncs / max(tokens, 1), 3),
        "compile_s": round(compile_s, 4),
    }


def _cache_bytes_per_slot(cfg, n_slots: int, s_max: int) -> int:
    caches = T.init_caches(cfg, n_slots, s_max)
    total = sum(leaf.size * leaf.dtype.itemsize
                for leaf in jax.tree_util.tree_leaves(caches))
    return total // n_slots


def _cache_dtype_sweep(params, cfg, n_slots: int, s_max: int,
                       n_requests: int, max_new: int):
    """One fused serving row per KV-cache storage dtype, plus the
    capacity and correctness columns DESIGN.md §13 claims."""
    import dataclasses

    import jax.numpy as jnp
    import numpy as np

    from repro.serve.engine import generate

    rows = []
    bf16_bytes = None
    for cd in ("bf16", "int8", "ternary"):
        # per-row activation scales (DESIGN.md §9) so the identity
        # column isolates the cache dtype: under the default per-tensor
        # scale, co-batched rows couple and fused != generate for
        # reasons unrelated to the KV cache
        ccfg = cfg.replace(
            quant=dataclasses.replace(cfg.quant, cache_dtype=cd,
                                      act_scale="per_row"))
        row = _run_mode(params, ccfg, True, n_slots, s_max, n_requests,
                        max_new)
        row["mode"] = f"fused_{cd}"
        row["cache_dtype"] = cd
        per_slot = _cache_bytes_per_slot(ccfg, n_slots, s_max)
        row["cache_bytes_per_slot"] = per_slot
        if cd == "bf16":
            bf16_bytes = per_slot
        # how many quantized slots the bf16 cache budget holds
        row["capacity_vs_bf16"] = round(bf16_bytes / per_slot, 2)
        row["slots_at_equal_memory"] = int(n_slots * bf16_bytes // per_slot)
        # fused-vs-generate token identity under the same cache dtype
        batcher = ContinuousBatcher(params, ccfg, n_slots=n_slots,
                                    s_max=s_max, fused=True)
        reqs = _workload(cfg, n_requests, max_new)
        for r in reqs:
            batcher.submit(r)
        batcher.run()
        min_prefix = None
        matches = True
        for r in reqs:
            solo = np.asarray(generate(
                params, jnp.asarray([r.prompt], jnp.int32), ccfg,
                max_new=r.max_new, s_max=s_max))[0].tolist()
            prefix = 0
            for a, b in zip(r.generated, solo):
                if a != b:
                    break
                prefix += 1
            matches = matches and (r.generated == solo)
            min_prefix = prefix if min_prefix is None else min(min_prefix,
                                                               prefix)
        row["matches_generate"] = matches
        row["min_prefix_vs_generate"] = min_prefix
        rows.append(row)
    return rows


def run(smoke: bool = True, arch: str = "smollm-135m", n_slots: int = 4,
        s_max: int = 64, n_requests: int = 8, max_new: int = 6,
        tp: int = 0, out: str = "BENCH_serve.json"):
    cfg = get_config(arch, smoke=smoke)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    fused = _run_mode(params, cfg, True, n_slots, s_max, n_requests, max_new)
    looped = _run_mode(params, cfg, False, n_slots, s_max, n_requests, max_new)
    result = {
        "bench": "serve",
        "arch": arch,
        "smoke": smoke,
        "n_slots": n_slots,
        "s_max": s_max,
        "n_requests": n_requests,
        "backend": _backend_block(),
        "fused": fused,
        "looped": looped,
        "speedup_fused_over_looped": round(
            fused["tok_s"] / max(looped["tok_s"], 1e-9), 2),
        "host_sync_reduction": round(
            looped["host_syncs"] / max(fused["host_syncs"], 1), 2),
        "cache_dtype": _cache_dtype_sweep(params, cfg, n_slots, s_max,
                                          n_requests, max_new),
    }
    if tp > 1:
        from repro.launch.mesh import make_tp_mesh

        row = _run_mode(params, cfg, True, n_slots, s_max, n_requests,
                        max_new, mesh=make_tp_mesh(tp))
        row["tp"] = tp
        # the TP invariant the tests pin, surfaced in the artifact: same
        # serving discipline (steps + syncs) as the unsharded fused path
        row["host_syncs_match_fused"] = row["host_syncs"] == fused["host_syncs"]
        result["tp"] = row
    with open(out, "w") as f:
        json.dump(result, f, indent=2)
    print(json.dumps(result, indent=2))
    print(f"[bench_serve] wrote {out}")
    return result


def main(argv=None):
    ap = argparse.ArgumentParser()
    size = ap.add_mutually_exclusive_group()
    size.add_argument("--smoke", dest="smoke", action="store_true",
                      help="use the smoke config (the default; kept explicit "
                           "for CI invocations)")
    size.add_argument("--full", dest="smoke", action="store_false",
                      help="benchmark the full arch config instead of smoke")
    ap.set_defaults(smoke=True)
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--s-max", type=int, default=64)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=6)
    ap.add_argument("--tp", type=int, default=0, metavar="N",
                    help="also benchmark the fused path tensor-parallel "
                         "over an N-device mesh (emits a 'tp' row)")
    ap.add_argument("--out", default="BENCH_serve.json")
    args = ap.parse_args(argv)
    run(smoke=args.smoke, arch=args.arch, n_slots=args.slots, s_max=args.s_max,
        n_requests=args.requests, max_new=args.max_new, tp=args.tp,
        out=args.out)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
