"""Poisson traffic benchmark for the async serving front door.

Drives open-loop Poisson arrivals (``repro.profile.replay.
poisson_requests`` — the same arrival model ``replay.simulate``
consumes) through the REAL serving stack: TCP sockets, the HTTP→
WebSocket upgrade, per-token streaming, the replica router, and the
fused continuous-batching engine underneath. Nothing is shortcut
in-process — every request is a masked-client-frame WebSocket stream
against a live ``FrontDoor`` listener.

Reported per replica count (1 and 2):

  * ``goodput_tok_s``    — delivered tokens / wall second over the
    measured window (client-side clock, first arrival → last done);
  * ``ttft_us``          — p50/p99/mean time-to-first-token;
  * ``tok_latency_us``   — p50/p99/mean inter-token gap (decode cadence
    as a streaming client observes it);
  * ``queue_wait_us``    — admission → engine slot;
  * engine counters      — decode_steps / host_syncs / prefill_batches
    summed over replicas, plus ``host_syncs_match_fused``: the fused
    engine's one-host-fetch-per-step discipline (DESIGN.md §6,
    BENCH_serve.json's fused row) must survive the async front door
    unchanged — ``host_syncs == decode_steps + prefill_batches``
    exactly, per replica.

The headline gate: at a saturating arrival rate, 2-replica goodput must
beat 1-replica (``goodput_2r_gt_1r``) — replication across the router
actually buys throughput, it doesn't just shard the same queue.

**Modeled device pacing** (``--pace-us``, default 5000): each replica's
worker thread sleeps the modeled per-step device latency after every
real engine step, with the GIL released — the way accelerator compute
occupies a device without occupying the host. This is the same
functional-on-CPU / modeled-time split the rest of the repo uses
(hw.project, profile→calibrate→replay): on a CPU host every replica's
*functional* step shares the same cores, so raw wall time measures one
CPU no matter how many replicas exist; against the modeled device time,
replicas overlap exactly as independent CiM arrays would, and the
router's scaling behavior becomes measurable. The pacing lives in the
worker thread AROUND the jitted step — never inside it — so the traced
program, the host-sync counts, and every engine invariant are the
production ones (``host_syncs_match_fused`` checks this per row).

A warmup pass per configuration (every prefill bucket on every replica,
plus decode) runs before the measured window, and engine counters +
SLO aggregates are reset after it: compile time lands nowhere in the
SLOs, matching bench_serve's steady-state discipline.

Emits ``BENCH_traffic.json`` (CI validates it with
:func:`validate_result` and uploads it as a workflow artifact).

Usage:
    PYTHONPATH=src python -m benchmarks.bench_traffic [--smoke|--full]
        [--rate RPS] [--requests N] [--replicas-max K] [--out PATH]
"""
from __future__ import annotations

import sys

from repro.launch._boot import force_host_devices_for_tp

force_host_devices_for_tp(sys.argv)  # before the jax import below

import argparse
import asyncio
import json
import time
from typing import Any, Dict, List

import jax

from repro.models import transformer as T
from repro.models.registry import get_config
from repro.profile import backend_block
from repro.profile.replay import (
    ReplayRequest,
    poisson_requests,
    replay_traffic_bench,
)
from repro.serve.frontdoor.client import WSClient, http_json

#: stated predicted-vs-measured bound for the replay_check block: the
#: committed row's goodput and TTFT must be reproducible from its own
#: stated segment times through replay.simulate within this error
REPLAY_ERROR_BOUND_PCT = 35.0


def _prompt_for(rid: int, prompt_len: int, vocab: int) -> List[int]:
    """Deterministic prompt tokens (same recipe as bench_serve's
    workload, parameterized by the Poisson request's rid/len)."""
    return [1 + (rid * 7 + j) % (vocab - 1) for j in range(prompt_len)]


async def _warmup(door) -> None:
    """Compile every jitted entry point before the measured window: one
    request per pow2 prefill bucket (prompt lens 1/2/4) on EACH replica
    (least-loaded dispatch spreads consecutive submissions), each
    decoding 2 tokens."""
    tracked = []
    for _ in door.router.workers:
        for plen in (1, 2, 4):
            tracked.append(door.router.submit(list(range(1, plen + 1)), 2))
    for t in tracked:
        while True:
            kind, _ = await t.stream.get()
            if kind != "token":
                break
        door.router.forget(t.req.rid)


async def _drive_one(host: str, port: int, r: ReplayRequest, vocab: int,
                     t0: float) -> Dict[str, Any]:
    """One open-loop client: sleep until the request's Poisson arrival,
    then stream it over its own WebSocket connection."""
    delay = r.arrival_us * 1e-6 - (time.perf_counter() - t0)
    if delay > 0:
        await asyncio.sleep(delay)
    ws = await WSClient.connect(host, port)
    try:
        res = await ws.generate(
            _prompt_for(r.rid, r.prompt_len, vocab), r.max_new)
        return {"rid": r.rid, "tokens": res["tokens"], "done": res["done"]}
    except RuntimeError as e:  # admission control said no (queue_full)
        return {"rid": r.rid, "tokens": [],
                "rejected": getattr(e, "payload", {"error": str(e)})}
    finally:
        await ws.close()


async def _bench_replicas(params, cfg, *, replicas: int, tp: int,
                          rate_rps: float, n_requests: int, n_slots: int,
                          s_max: int, queue_limit: int, seed: int,
                          max_new: int, pace_us: float = 0.0) -> Dict[str, Any]:
    """Serve one Poisson workload through a fresh front door with
    ``replicas`` engines; return the artifact row."""
    from repro.launch.serve import build_frontdoor

    args = argparse.Namespace(
        replicas=replicas, tp=tp, profile=None, slots=n_slots, s_max=s_max,
        exec_spec=None, temperature=0.0, seed=seed, loop_decode=False,
        prepare_weights=False, compress_tp=False, queue_limit=queue_limit,
        host="127.0.0.1", port=0, pace_us=pace_us)
    door, _ = build_frontdoor(args, cfg, params, None)
    await door.start()
    try:
        await _warmup(door)
        for w in door.router.workers:
            b = w.batcher
            b.decode_steps = b.host_syncs = b.prefill_batches = 0
        door.tracker.reset()

        reqs = poisson_requests(rate_rps, seed=seed, n_requests=n_requests,
                                prompt_len_max=4, max_new=max_new)
        t0 = time.perf_counter()
        results = await asyncio.gather(*[
            _drive_one(door.host, door.port, r, cfg.vocab, t0) for r in reqs])
        wall = time.perf_counter() - t0
        status, stats = await http_json(door.host, door.port, "GET", "/stats")
        assert status == 200, status
    finally:
        await door.stop()

    served = [r for r in results if "rejected" not in r]
    tokens_client = sum(len(r["tokens"]) for r in served)
    slo = stats["slo"]
    eng = {"decode_steps": 0, "host_syncs": 0, "prefill_batches": 0}
    fused_ok = True
    for rep in stats["router"]["replicas"]:
        for k in eng:
            eng[k] += rep[k]
        # the fused-engine discipline, per replica: exactly one host
        # fetch per decode step + one per batched prefill, nothing from
        # the async layer
        fused_ok &= rep["host_syncs"] == (
            rep["decode_steps"] + rep["prefill_batches"])
    return {
        "replicas": replicas,
        "rate_rps": rate_rps,
        "step_pace_us": pace_us,
        "n_requests": n_requests,
        "served": len(served),
        "rejected": slo["requests"]["rejected"],
        "tokens_out": tokens_client,
        "tokens_server": slo["tokens_out"],
        "wall_s": round(wall, 4),
        "goodput_tok_s": round(tokens_client / max(wall, 1e-9), 2),
        "ttft_us": slo["slo_us"]["ttft"],
        "tok_latency_us": slo["slo_us"]["tok_latency"],
        "queue_wait_us": slo["slo_us"]["queue_wait"],
        "e2e_us": slo["slo_us"]["e2e"],
        **eng,
        "host_syncs_per_token": round(
            eng["host_syncs"] / max(tokens_client, 1), 3),
        "host_syncs_match_fused": bool(fused_ok),
    }


def run(smoke: bool = True, arch: str = "smollm-135m", n_slots: int = 4,
        s_max: int = 64, rate_rps: float = 300.0, n_requests: int = 32,
        max_new: int = 8, replicas_max: int = 2, tp: int = 1,
        queue_limit: int = 0, seed: int = 0, pace_us: float = 5000.0,
        out: str = "BENCH_traffic.json") -> Dict[str, Any]:
    cfg = get_config(arch, smoke=smoke)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    # generous default cap: the bench measures goodput under saturation,
    # not rejection behavior (tests/test_frontdoor.py pins the 429 path)
    queue_limit = queue_limit or max(n_requests + 8, 16)
    rows: Dict[str, Any] = {}
    for replicas in range(1, replicas_max + 1):
        rows[str(replicas)] = asyncio.run(_bench_replicas(
            params, cfg, replicas=replicas, tp=tp, rate_rps=rate_rps,
            n_requests=n_requests, n_slots=n_slots, s_max=s_max,
            queue_limit=queue_limit, seed=seed, max_new=max_new,
            pace_us=pace_us))
    g1 = rows["1"]["goodput_tok_s"]
    g2 = rows[str(replicas_max)]["goodput_tok_s"] if replicas_max > 1 else g1
    tokens_agree = all(
        r["tokens_out"] == r["tokens_server"] for r in rows.values())
    fused_ok = all(r["host_syncs_match_fused"] for r in rows.values())
    result = {
        "bench": "traffic",
        "arch": arch,
        "smoke": smoke,
        "backend": backend_block(),
        "n_slots": n_slots,
        "s_max": s_max,
        "queue_limit": queue_limit,
        "rate_rps": rate_rps,
        "step_pace_us": pace_us,
        "seed": seed,
        "n_requests": n_requests,
        "max_new": max_new,
        "rows": rows,
        "tokens_client_eq_server": tokens_agree,
        "goodput_2r_gt_1r": bool(replicas_max > 1 and g2 > g1),
        "validated": bool(
            tokens_agree and fused_ok
            and (replicas_max == 1 or g2 > g1)),
    }
    # close the predicted-vs-measured loop: the artifact must be
    # reproducible from its own stated segment times through
    # replay.simulate, within the stated bound (DESIGN.md §11)
    _, cmp = replay_traffic_bench(result, "1")
    result["replay_check"] = {
        "error_bound_pct": REPLAY_ERROR_BOUND_PCT,
        **cmp,
        "within_bound": bool(
            cmp["goodput_error_pct"] <= REPLAY_ERROR_BOUND_PCT
            and cmp["ttft_error_pct"] <= REPLAY_ERROR_BOUND_PCT),
    }
    validate_result(result)
    with open(out, "w") as f:
        json.dump(result, f, indent=2)
    print(json.dumps(result, indent=2))
    print(f"[bench_traffic] wrote {out}")
    return result


_ROW_FIELDS = (
    "replicas", "rate_rps", "step_pace_us", "n_requests", "served",
    "rejected", "tokens_out",
    "tokens_server", "wall_s", "goodput_tok_s", "ttft_us", "tok_latency_us",
    "queue_wait_us", "e2e_us", "decode_steps", "host_syncs",
    "prefill_batches", "host_syncs_per_token", "host_syncs_match_fused",
)


def validate_result(d) -> None:
    """Schema gate for BENCH_traffic.json (CI runs this on fresh smoke
    output AND the committed artifact). Raises ValueError on any
    malformation, on a broken fused host-sync discipline, and on an
    unvalidated run — a traffic artifact where adding a replica did not
    add goodput must not ship."""
    for field in ("bench", "arch", "smoke", "backend", "n_slots", "s_max",
                  "queue_limit", "rate_rps", "step_pace_us", "seed",
                  "n_requests", "max_new", "rows", "tokens_client_eq_server",
                  "goodput_2r_gt_1r", "replay_check", "validated"):
        if field not in d:
            raise ValueError(f"BENCH_traffic.json missing field {field!r}")
    if d["bench"] != "traffic":
        raise ValueError(f"bench field is {d['bench']!r}, not 'traffic'")
    b = d["backend"]
    if not isinstance(b, dict) or not all(
            f in b for f in ("platform", "device_kind", "device_count",
                             "interpret")):
        raise ValueError(
            f"backend must be the provenance block (platform/device_kind/"
            f"device_count/interpret), got {b!r}")
    rows = d["rows"]
    if "1" not in rows:
        raise ValueError("no 1-replica row")
    for key, row in rows.items():
        for field in _ROW_FIELDS:
            if field not in row:
                raise ValueError(f"rows[{key!r}] missing {field!r}")
        for pct in ("ttft_us", "tok_latency_us", "queue_wait_us", "e2e_us"):
            for stat in ("p50", "p99", "mean", "n"):
                if stat not in row[pct]:
                    raise ValueError(f"rows[{key!r}][{pct!r}] missing {stat!r}")
        if row["tokens_out"] <= 0:
            raise ValueError(f"rows[{key!r}] served no tokens")
        if not row["host_syncs_match_fused"]:
            raise ValueError(
                f"rows[{key!r}]: host_syncs != decode_steps + "
                "prefill_batches — the async front door broke the fused "
                "engine's one-host-fetch-per-step discipline")
    if not d["tokens_client_eq_server"]:
        raise ValueError("client-received token count disagrees with the "
                         "server's /stats tokens_out")
    if len(rows) > 1:
        g1 = rows["1"]["goodput_tok_s"]
        gmax = rows[str(max(int(k) for k in rows))]["goodput_tok_s"]
        if d["goodput_2r_gt_1r"] != (gmax > g1):
            raise ValueError("goodput_2r_gt_1r inconsistent with rows")
    rc = d["replay_check"]
    for field in ("error_bound_pct", "goodput_error_pct", "ttft_error_pct",
                  "within_bound"):
        if field not in rc:
            raise ValueError(f"replay_check missing {field!r}")
    bound = float(rc["error_bound_pct"])
    if rc["goodput_error_pct"] > bound or rc["ttft_error_pct"] > bound:
        raise ValueError(
            f"replay_check: predicted-vs-measured error exceeds the stated "
            f"{bound}% bound (goodput {rc['goodput_error_pct']}%, ttft "
            f"{rc['ttft_error_pct']}%) — the artifact is not reproducible "
            f"from its own segment times")
    if not rc["within_bound"]:
        raise ValueError("replay_check.within_bound is False")
    if not d["validated"]:
        raise ValueError("run not validated (goodput did not scale with "
                         "replicas, or an invariant failed)")


def main(argv=None):
    ap = argparse.ArgumentParser()
    size = ap.add_mutually_exclusive_group()
    size.add_argument("--smoke", dest="smoke", action="store_true",
                      help="use the smoke config (the default; kept explicit "
                           "for CI invocations)")
    size.add_argument("--full", dest="smoke", action="store_false",
                      help="benchmark the full arch config instead of smoke")
    ap.set_defaults(smoke=True)
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--s-max", type=int, default=64)
    ap.add_argument("--rate", type=float, default=300.0,
                    help="Poisson arrival rate (requests/s); the default "
                         "saturates the smoke engine so replica scaling "
                         "is visible")
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--replicas-max", type=int, default=2,
                    help="benchmark 1..K replicas (default 2)")
    ap.add_argument("--tp", type=int, default=1, metavar="N",
                    help="tensor-parallel degree per replica (disjoint "
                         "(1, tp) meshes via make_replica_meshes)")
    ap.add_argument("--queue-limit", type=int, default=0,
                    help="admission cap (0 = generous default: no "
                         "rejections in the measured window)")
    ap.add_argument("--pace-us", type=float, default=5000.0, dest="pace_us",
                    help="modeled per-step device latency (us), slept "
                         "off-GIL in each replica's worker thread — see "
                         "the module docstring; 0 measures raw functional "
                         "CPU (replica scaling then disappears on "
                         "few-core hosts)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="BENCH_traffic.json")
    args = ap.parse_args(argv)
    run(smoke=args.smoke, arch=args.arch, n_slots=args.slots,
        s_max=args.s_max, rate_rps=args.rate, n_requests=args.requests,
        max_new=args.max_new, replicas_max=args.replicas_max, tp=args.tp,
        queue_limit=args.queue_limit, seed=args.seed, pace_us=args.pace_us,
        out=args.out)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
