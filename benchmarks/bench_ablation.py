"""Ablations over the paper's key design choices.

The paper fixes N_A = 16 rows/cycle and a 3-bit ADC (clamp at 8) from
sense-margin + sparsity analysis (Sections III.2, IV.4). This benchmark
sweeps both knobs on a trained ternary classifier and on random ternary
GEMMs, reporting (i) task accuracy and (ii) MAC distortion vs the exact
product — quantifying how much architectural headroom the chosen point
leaves (the paper's choice should sit on the flat part of the curve).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import api
from repro.core.ternary import ternarize
from benchmarks.bench_accuracy import _train_ternary_mlp


def mac_distortion(block: int, adc_max: int, key, p_zero=0.55, n=64, k=1024, m=64):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    x = (jax.random.choice(k1, jnp.array([-1, 1]), (n, k))
         * jax.random.bernoulli(k3, 1 - p_zero, (n, k))).astype(jnp.int32)
    w = (jax.random.choice(k2, jnp.array([-1, 1]), (k, m))
         * jax.random.bernoulli(k4, 1 - p_zero, (k, m))).astype(jnp.int32)
    spec = api.CiMExecSpec(formulation="blocked", backend="jnp",
                           block=block, adc_max=adc_max)
    out = api.execute(spec, x, w).astype(jnp.float32)
    exact = (x @ w).astype(jnp.float32)
    rel = jnp.linalg.norm(out - exact) / jnp.maximum(jnp.linalg.norm(exact), 1e-9)
    return float(rel)


def run(csv: bool = True):
    (w1, w2), (xs, ys) = _train_ternary_mlp(jax.random.PRNGKey(0))

    def acc(block: int, adc_max: int) -> float:
        xt, sx = ternarize(xs)
        w1t, s1 = ternarize(w1, axis=(0,))
        spec = api.CiMExecSpec(formulation="blocked", backend="jnp",
                               block=block, adc_max=adc_max)
        h = api.execute(spec, xt.astype(jnp.int32), w1t.astype(jnp.int32))
        h = jax.nn.relu(h.astype(jnp.float32) * sx * s1)
        return float((jnp.argmax(h @ w2, -1) == ys).mean())

    rows = []
    key = jax.random.PRNGKey(42)
    # ADC sweep at the paper's N_A = 16
    for adc in (2, 4, 8, 12, 16):
        rows.append((f"adc_max={adc}_block=16", acc(16, adc),
                     f"gemm_rel_err={mac_distortion(16, adc, key):.4f}"))
    # block-size sweep at the matching ADC bound (adc = block/2: the
    # paper's 3-bit-for-16-rows proportionality)
    for block in (8, 16, 32, 64):
        rows.append((f"block={block}_adc={block//2}", acc(block, block // 2),
                     f"gemm_rel_err={mac_distortion(block, block // 2, key):.4f}"))
    if csv:
        print("name,accuracy,derived")
        for name, a, d in rows:
            print(f"{name},{a:.4f},{d}")
    return rows


if __name__ == "__main__":
    run()
