"""Paper Figs 9 & 11: array-level CiM/read/write latency+energy vs NM,
per technology and design — derived from the declarative hardware model
(``repro.hw``) and checked against the paper's reported percentages.

Rows iterate the *registries*: every registered technology x the CiM
designs it provides cost parameters for, so a technology registered at
runtime (``hw.register_technology``) appears here with zero edits. The
designs are named through the execution API too: a ``CiMExecSpec`` maps
onto each array design via ``repro.api.spec_design`` (exact MAC
semantics -> NM baseline; clamped formulations -> SiTe CiM, flavor
choosing the design), so the cost rows correspond one-to-one with specs
a model can actually serve under.

Emits ``BENCH_array.json`` (same contract as ``BENCH_serve.json``: CI
runs this in the bench-smoke job, validates the JSON and uploads it as
a workflow artifact). The ``paper_validation`` block carries the six
pinned (tech, design) Fig 9/11 rows; registered non-paper technologies
appear in ``rows`` only.
"""
from __future__ import annotations

import argparse
import json

from repro import api, hw


def _exec_spec_for(design: str):
    """The CiMExecSpec that executes on ``design``, or None when no
    registered execution flavor maps onto it (a cost-only design still
    gets rows — registry extensibility must not hinge on the execution
    API knowing the flavor)."""
    flavor = hw.get_design(design).flavor
    if flavor not in api.FLAVORS:
        return None
    spec = api.CiMExecSpec(formulation="blocked", flavor=flavor)
    # two designs sharing a flavor resolve to the first match only
    return spec if api.spec_design(spec) == design else None


def rows():
    out = []
    for tech in hw.technologies():
        for design in hw.cim_designs_of(tech):
            array = hw.ArraySpec(technology=tech, design=design)
            spec = _exec_spec_for(design)
            if spec is not None:
                cost = api.spec_cost_summary(spec, array=array)
                mac_ns, mac_pj = cost["mac_pass_ns"], cost["mac_pass_pj"]
            else:
                c = hw.array_cost(array)
                mac_ns, mac_pj = c.mac_pass_ns, c.mac_pass_pj
            claims = hw.design_claims(array)
            paper = tech in hw.PAPER_TECHNOLOGIES and design in ("CiM-I", "CiM-II")
            out.append({
                "figure": ("Fig9" if design == "CiM-I" else "Fig11") if paper else "",
                "tech": tech,
                "design": design,
                "spec": spec.name if spec is not None else "",
                "array": array.name,
                "mac_pass_ns": round(mac_ns, 2),
                "mac_pass_pj": round(mac_pj, 2),
                **{k: round(v, 2) for k, v in claims.items()},
            })
    return out


def run(csv: bool = True, out: str = "BENCH_array.json"):
    rs = rows()
    if csv:
        keys = list(rs[0].keys())
        print(",".join(keys))
        for r in rs:
            print(",".join(str(r[k]) for k in keys))
    from repro.profile import backend_block

    result = {
        "bench": "array",
        "backend": backend_block(),
        "technologies": list(hw.technologies()),
        "designs": list(hw.designs()),
        "rows": rs,
        "paper_validation": hw.paper_validation_table(),
    }
    if out:
        with open(out, "w") as f:
            json.dump(result, f, indent=2)
        print(f"[bench_array] wrote {out}")
    return rs


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_array.json")
    args = ap.parse_args(argv)
    run(out=args.out)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
