"""Paper Figs 9 & 11: array-level CiM/read/write latency+energy vs NM,
per technology and flavor — derived from the calibrated cost model and
checked against the paper's reported percentages.

The designs are named through the execution API: a ``CiMExecSpec`` maps
onto the paper's array designs via ``repro.api.spec_design`` (exact MAC
semantics -> NM baseline; clamped formulations -> SiTe CiM, flavor
choosing I vs II), so the cost rows correspond one-to-one with specs a
model can actually serve under.
"""
from __future__ import annotations

from repro import api
from repro.core import cost_model as cm

# the execution specs behind each of the paper's array designs
DESIGN_SPECS = {
    "CiM-I": api.CiMExecSpec(formulation="blocked", flavor="I"),
    "CiM-II": api.CiMExecSpec(formulation="blocked", flavor="II"),
}


def rows():
    out = []
    for tech in cm.TECHNOLOGIES:
        for design, spec in DESIGN_SPECS.items():
            assert api.spec_design(spec) == design
            t = cm.paper_validation_table()[tech][design]
            cost = api.spec_cost_summary(spec, tech)
            out.append({
                "figure": "Fig9" if design == "CiM-I" else "Fig11",
                "tech": tech,
                "design": design,
                "spec": spec.name,
                "mac_pass_ns": round(cost["mac_pass_ns"], 2),
                **{k: round(v, 2) for k, v in t.items()},
            })
    return out


def run(csv: bool = True):
    rs = rows()
    if csv:
        keys = list(rs[0].keys())
        print(",".join(keys))
        for r in rs:
            print(",".join(str(r[k]) for k in keys))
    return rs


if __name__ == "__main__":
    run()
