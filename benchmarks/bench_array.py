"""Paper Figs 9 & 11: array-level CiM/read/write latency+energy vs NM,
per technology and flavor — derived from the calibrated cost model and
checked against the paper's reported percentages."""
from __future__ import annotations

from repro.core import cost_model as cm


def rows():
    out = []
    for tech in cm.TECHNOLOGIES:
        for design in ("CiM-I", "CiM-II"):
            t = cm.paper_validation_table()[tech][design]
            out.append({
                "figure": "Fig9" if design == "CiM-I" else "Fig11",
                "tech": tech,
                "design": design,
                **{k: round(v, 2) for k, v in t.items()},
            })
    return out


def run(csv: bool = True):
    rs = rows()
    if csv:
        keys = list(rs[0].keys())
        print(",".join(keys))
        for r in rs:
            print(",".join(str(r[k]) for k in keys))
    return rs


if __name__ == "__main__":
    run()
