"""Benchmark harness — one module per paper table/figure.

  bench_array    — Figs 9/11  (array-level CiM/read/write vs NM, every
                   registered technology; emits BENCH_array.json)
  bench_system   — Figs 12/13 (system-level speedup/energy, 5 DNNs) +
                   registry-arch projections (emits BENCH_system.json)
  bench_accuracy — Section III.2 resilience (ADC clamp + sensing errors)
  bench_ablation — N_A / ADC-precision design-point sweep (Sections III.2, IV.4)
  bench_kernels  — kernel micro-bench (CPU wall time + cost profile)
  bench_mac      — decode-shaped MAC fast path vs the pre-pad path
                   (M sweep x packed/unpacked x exact/blocked; emits
                   BENCH_mac.json)
  bench_roofline — §Roofline table from the dry-run artifacts
  bench_serve    — serving throughput: fused ragged-position decode vs
                   the per-slot-loop baseline (emits BENCH_serve.json)
  bench_calibrate— profile -> calibrate -> replay: fit the cost model to
                   measured kernel/step times, replay a holdout serve
                   run, gate on prediction error (emits BENCH_calib.json)
  bench_traffic  — Poisson arrivals through the async front door: p50/p99
                   TTFT, per-token latency, goodput for 1 and 2 router
                   replicas (emits BENCH_traffic.json)

Usage: PYTHONPATH=src python -m benchmarks.run [--only <name>]
"""
from __future__ import annotations

import argparse
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    args = ap.parse_args()

    from benchmarks import (
        bench_ablation,
        bench_accuracy,
        bench_array,
        bench_calibrate,
        bench_kernels,
        bench_mac,
        bench_roofline,
        bench_serve,
        bench_system,
        bench_traffic,
    )

    suites = {
        "array": bench_array,
        "system": bench_system,
        "accuracy": bench_accuracy,
        "ablation": bench_ablation,
        "kernels": bench_kernels,
        "mac": bench_mac,
        "roofline": bench_roofline,
        "serve": bench_serve,
        "calibrate": bench_calibrate,
        "traffic": bench_traffic,
    }
    names = [args.only] if args.only else list(suites)
    for name in names:
        print(f"\n===== bench:{name} =====")
        suites[name].run()


if __name__ == "__main__":
    main()
