"""Calibration benchmark: profile → calibrate → replay, validated.

Three phases (DESIGN.md §11, docs/calibration.md):

  A. **Kernel sweep** — run the execution shim's registered jnp specs
     over a grid of decode/prefill (M, K, N) shapes with the profiler
     installed, so every eager ``execute``/``execute_packed`` call emits
     a timed trace event (repro.profile.trace).

  B. **Calibrate** — least-squares fit of the per-(spec, shape-class)
     cost models and per-arch serving-step overheads
     (repro.profile.calibrate) from a profiled *fit* serve run per arch,
     plus the tile winners the packed kernels would serve with.

  C. **Replay + validate** — replay each arch's *holdout* serve run (a
     different request mix, captured in a second profiled run) through
     the fitted table (repro.profile.replay) and compare the predicted
     decode-step p50 against the holdout's measured events. The run is
     ``validated`` iff every arch's p50 error is within
     ``error_bound_pct``.

The error bound is deliberately loose (40% smoke / 25% full): CPU CI
hosts are noisy shared machines and the fit run and holdout run are
separated in time — the bound asserts the calibration is *predictive*,
not that the host is quiet. Fit residuals for every kernel and engine
fit ship in the artifact so a drifting fit is visible before it fails.

Emits ``BENCH_calib.json`` (validated by :func:`validate_result` — the
CI bench-smoke and docs jobs both run it).

Usage:
    PYTHONPATH=src python -m benchmarks.bench_calibrate [--smoke|--full] [--out PATH]
"""
from __future__ import annotations

import argparse
import json

import jax
import jax.numpy as jnp

from repro import profile as P
from repro.core.execution import (
    CiMExecSpec,
    execute,
    execute_packed,
    get_backend,
    registered_specs,
    tiles_for,
)
from repro.models import transformer as T
from repro.models.registry import get_config
from repro.serve.engine import ContinuousBatcher, Request

#: (M, K, N) grid per shape class — small enough for CPU CI, spread
#: enough in M/K/N that the three fit coefficients are identifiable
SWEEP_SHAPES = {
    "smoke": {
        "decode": ((1, 256, 256), (4, 256, 512), (8, 512, 256)),
        "prefill": ((32, 256, 256), (64, 256, 512), (128, 512, 256)),
    },
    "full": {
        "decode": ((1, 1024, 1024), (4, 1024, 2048), (8, 2048, 1024)),
        "prefill": ((32, 1024, 1024), (128, 1024, 2048), (256, 2048, 1024)),
    },
}

ERROR_BOUND_PCT = {"smoke": 40.0, "full": 25.0}
REPEATS = 3


def _sweep_specs(smoke: bool):
    """The specs phase A times: every registered jnp entry (pallas
    interpret mode on CPU times the emulator, not a kernel — full mode
    only)."""
    out = []
    for spec in registered_specs():
        if smoke and spec.backend != "jnp":
            continue
        out.append(spec)
    return out


def _kernel_sweep(profiler, smoke: bool):
    """Phase A: emit REPEATS timed events per (spec, shape) with one
    untimed warmup call (compile outside the measurement)."""
    shapes = SWEEP_SHAPES["smoke" if smoke else "full"]
    specs = _sweep_specs(smoke)
    key = jax.random.PRNGKey(0)
    for spec in specs:
        for cls, grid in shapes.items():
            for m, k, n in grid:
                kx, kw = jax.random.split(jax.random.fold_in(key, m * k + n))
                x = jnp.sign(jax.random.normal(kx, (m, k))).astype(jnp.float32)
                w = jnp.sign(jax.random.normal(kw, (k, n))).astype(jnp.float32)
                if spec.packing == "bitplane_u8":
                    from repro.core import ternary as tern

                    planes = tern.pack_ternary(w.astype(jnp.int8), axis=0)

                    def call():
                        return execute_packed(spec, x, *planes)
                else:

                    def call():
                        return execute(spec, x, w)

                jax.block_until_ready(call())  # warmup, profiler off
                prev = P.set_profiler(profiler)
                try:
                    for _ in range(REPEATS):
                        call()
                finally:
                    P.set_profiler(prev)
    return [s.name for s in specs]


def _tile_winners(smoke: bool):
    """The tile winners the table records for ``autotune(calibration=)``.

    Smoke: the default tables' answers at representative shapes (no
    timing — interpret-mode pallas timing on CPU is meaningless and
    slow). Full: a real ``execution.autotune`` per tiled spec."""
    from repro.core import execution as X

    winners = {}
    for spec in registered_specs():
        entry = get_backend(spec)
        if entry.tiles is None:
            continue
        if smoke:
            winners[spec.name] = {
                "decode": tuple(tiles_for(spec, 4, 1024, 512)),
                "prefill": tuple(tiles_for(spec, 256, 1024, 512)),
            }
        else:
            report = X.autotune(spec)
            winners[spec.name] = {
                cls: tuple(r["tiles"]) for cls, r in report.items()
            }
    return winners


def _engine_runs(cfg, params):
    """Warm + fit + holdout profiled serve runs on ONE batcher (one set
    of jitted step closures — the warm run eats every compile so the
    measured runs are steady-state). Returns (fit_events,
    holdout_events)."""
    prof = P.Profiler()
    b = ContinuousBatcher(params, cfg, n_slots=4, s_max=64, seed=0,
                          profile=prof)

    def serve(requests):
        for r in requests:
            b.submit(r)
        b.run()
        assert all(r.done for r in requests)
        return len(prof.events)

    # 24 ragged requests -> ~30 decode steps per run: medians over ~10
    # steps were too noisy to cross-predict on shared CI hosts
    n0 = serve(_requests(cfg, 8, 6, salt=9))    # warm: compiles land here
    n1 = serve(_requests(cfg, 24, 8, salt=0))   # fit
    serve(_requests(cfg, 24, 8, salt=3))        # holdout
    return prof.events[n0:n1], prof.events[n1:]


def _requests(cfg, n_requests: int, max_new: int, salt: int = 0):
    """Deterministic ragged mix (same shape family as bench_serve)."""
    return [
        Request(
            i,
            [1 + (i * 7 + j + salt) % (cfg.vocab - 1)
             for j in range(1 + (i + salt) % 4)],
            max_new=2 + (i + salt) % max_new,
        )
        for i in range(n_requests)
    ]


def run(smoke: bool = True, archs=("smollm-135m", "mamba2-780m"),
        out: str = "BENCH_calib.json"):
    mode = "smoke" if smoke else "full"
    bound = ERROR_BOUND_PCT[mode]

    # -- phase A: kernel sweep ---------------------------------------------
    prof = P.Profiler()
    swept = _kernel_sweep(prof, smoke)
    kernel_events = list(prof.events)

    # -- phase B: per-arch fit runs + calibration ---------------------------
    fit_events = list(kernel_events)
    holdouts = {}
    for arch in archs:
        cfg = get_config(arch, smoke=smoke)
        params = T.init_params(jax.random.PRNGKey(0), cfg)
        fit, holdout = _engine_runs(cfg, params)
        fit_events += fit
        holdouts[arch] = holdout

    table = P.calibrate(
        fit_events,
        backend=jax.default_backend(),
        tile_winners=_tile_winners(smoke),
    )

    # -- phase C: replay the holdout mixes, gate on p50 error ---------------
    replay = {}
    validated = True
    for arch, events in holdouts.items():
        reqs = P.requests_from_trace(events)
        pred = P.simulate(table, arch, reqs, n_slots=4, s_max=64)
        cmp = P.compare_to_measured(pred, events)
        cmp["within_bound"] = cmp["p50_error_pct"] <= bound
        validated = validated and cmp["within_bound"]
        replay[arch] = cmp

    result = {
        "bench": "calibrate",
        "smoke": smoke,
        "backend": P.backend_block(),
        "error_bound_pct": bound,
        "kernel_sweep": {
            "specs": swept,
            "repeats": REPEATS,
            "n_events": len(kernel_events),
        },
        "fit_residuals": {
            "kernels": {k: f.residual_pct for k, f in table.kernels.items()},
            "engines": {k: f.residual_pct for k, f in table.engines.items()},
        },
        "table": table.to_json(),
        "replay": replay,
        "validated": validated,
    }
    validate_result(result)
    with open(out, "w") as f:
        json.dump(result, f, indent=2, sort_keys=True)
    print(json.dumps({k: v for k, v in result.items() if k != "table"},
                     indent=2, sort_keys=True))
    print(f"[bench_calibrate] wrote {out} (validated={validated})")
    return result


def validate_result(d) -> None:
    """Schema gate for BENCH_calib.json (CI runs this on the committed
    artifact and on fresh smoke output). Raises ValueError on any
    malformation; also raises if the run is not ``validated`` — an
    artifact whose replay missed its own stated bound must not ship."""
    for field in ("bench", "smoke", "backend", "error_bound_pct",
                  "kernel_sweep", "fit_residuals", "table", "replay",
                  "validated"):
        if field not in d:
            raise ValueError(f"BENCH_calib.json missing field {field!r}")
    if d["bench"] != "calibrate":
        raise ValueError(f"bench field is {d['bench']!r}, not 'calibrate'")
    b = d["backend"]
    if not isinstance(b, dict) or not all(
            f in b for f in ("platform", "device_kind", "device_count",
                             "interpret")):
        raise ValueError(
            f"backend must be the provenance block (platform/device_kind/"
            f"device_count/interpret), got {b!r}")
    table = P.CalibrationTable.from_json(d["table"])  # version + layout check
    if not table.kernels:
        raise ValueError("calibration table has no kernel fits")
    if not table.engines:
        raise ValueError("calibration table has no engine fits")
    bound = float(d["error_bound_pct"])
    if not d["replay"]:
        raise ValueError("no replay comparisons recorded")
    for arch, cmp in d["replay"].items():
        for field in ("predicted_p50_us", "measured_p50_us", "p50_error_pct",
                      "within_bound"):
            if field not in cmp:
                raise ValueError(f"replay[{arch!r}] missing {field!r}")
        if cmp["within_bound"] != (cmp["p50_error_pct"] <= bound):
            raise ValueError(f"replay[{arch!r}] within_bound is inconsistent "
                             f"with p50_error_pct vs the stated bound")
    if not d["validated"]:
        failed = [a for a, c in d["replay"].items() if not c["within_bound"]]
        raise ValueError(
            f"replay error exceeded the {bound}% bound for {failed} — "
            f"re-run on a quieter host or re-fit")
    if d["validated"] != all(c["within_bound"] for c in d["replay"].values()):
        raise ValueError("validated flag inconsistent with replay rows")


def main(argv=None):
    ap = argparse.ArgumentParser()
    size = ap.add_mutually_exclusive_group()
    size.add_argument("--smoke", dest="smoke", action="store_true",
                      help="smoke configs + jnp-only sweep (the default; "
                           "kept explicit for CI invocations)")
    size.add_argument("--full", dest="smoke", action="store_false",
                      help="full arch configs, all registered specs, real "
                           "autotune for tile winners")
    ap.set_defaults(smoke=True)
    ap.add_argument("--arch", action="append", default=None, metavar="ID",
                    help="arch(s) to fit + replay (repeatable; default "
                         "smollm-135m and mamba2-780m)")
    ap.add_argument("--out", default="BENCH_calib.json")
    args = ap.parse_args(argv)
    archs = tuple(args.arch) if args.arch else ("smollm-135m", "mamba2-780m")
    run(smoke=args.smoke, archs=archs, out=args.out)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
