"""Roofline table from the dry-run JSON artifacts (results/dryrun/*.json).

Prints the per-(arch x shape x mesh) three-term roofline and the summary
EXPERIMENTS.md §Roofline embeds. Falls back to a notice when the dry-run
has not been executed yet (it needs the 512-device env)."""
from __future__ import annotations

import glob
import json
import os

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")


def load_rows(results_dir: str = RESULTS):
    rows = []
    for p in sorted(glob.glob(os.path.join(results_dir, "*.json"))):
        with open(p) as f:
            d = json.load(f)
        if d.get("roofline"):
            rows.append(d["roofline"])
        elif d.get("error", "").startswith("SKIP"):
            rows.append({
                "arch": d["arch"], "shape": d["shape"], "mesh": d["mesh_name"],
                "skip": d["error"],
            })
    return rows


def run(csv: bool = True, results_dir: str = RESULTS):
    rows = load_rows(results_dir)
    if not rows:
        print("no dry-run artifacts found — run:")
        print("  PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod both --out results/dryrun")
        return []
    if csv:
        print("arch,shape,mesh,t_compute_s,t_memory_s,t_collective_s,"
              "bottleneck,roofline_fraction,useful_flops_ratio")
        for r in rows:
            if "skip" in r:
                print(f"{r['arch']},{r['shape']},{r['mesh']},,,,SKIP,,")
                continue
            print(
                f"{r['arch']},{r['shape']},{r['mesh']},"
                f"{r['t_compute_s']:.3e},{r['t_memory_s']:.3e},"
                f"{r['t_collective_s']:.3e},{r['bottleneck']},"
                f"{r['roofline_fraction']:.4f},{r['useful_flops_ratio']:.4f}"
            )
    return rows


if __name__ == "__main__":
    run()
