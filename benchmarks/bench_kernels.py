"""Kernel micro-benchmarks: wall time of the CiM formulations on this
host (CPU) + the TPU-target roofline characteristics of each kernel.

Every formulation is invoked through the declarative execution API
(``repro.api.execute`` with a ``CiMExecSpec``) — the same dispatch path
layer code uses — so the timings cover the shim (padding, dtype policy,
STE wrapper), not just the raw einsums. Wall-clock here characterizes
the *functional* implementations (the jnp forms XLA:CPU executes); the
Pallas kernels are timed in interpret mode only for sanity (they target
TPU). The derived column reports the analytic bytes/flops profile used
by EXPERIMENTS.md §Perf.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro import api


def _time(fn, *args, reps=5):
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6  # us


def rand_ternary(key, shape, p_zero=0.3):
    k1, k2 = jax.random.split(key)
    sign = jax.random.choice(k1, jnp.array([-1, 1]), shape)
    keep = jax.random.bernoulli(k2, 1 - p_zero, shape)
    return (sign * keep).astype(jnp.float32)


# (row name, spec, derived-profile note)
SPECS = [
    ("cim_blocked_jnp",
     api.CiMExecSpec(formulation="blocked", backend="jnp"), "flops=2x exact"),
    ("cim_corrected_jnp",
     api.CiMExecSpec(formulation="corrected", backend="jnp"), "flops=3x exact"),
    ("nm_exact_jnp",
     api.CiMExecSpec(formulation="exact", backend="jnp"), "flops=1x exact"),
    ("cim_fused_jnp",
     api.CiMExecSpec(formulation="fused", backend="jnp"), "kernel HLO structure"),
    ("cim_packed_jnp",
     api.CiMExecSpec(formulation="blocked", backend="jnp", packing="bitplane_u8"),
     "2-bit weight storage"),
    ("cim_bitplane_jnp",
     api.CiMExecSpec(formulation="bitplane", backend="jnp"), "structural oracle"),
]


def run(csv: bool = True):
    m, k, n = 256, 1024, 512
    kx, kw = jax.random.split(jax.random.PRNGKey(0))
    x = rand_ternary(kx, (m, k))
    w = rand_ternary(kw, (k, n))
    rows = []
    for name, spec, note in SPECS:
        fn = jax.jit(lambda x, w, s=spec: api.execute(s, x, w))
        reps = 2 if spec.formulation == "bitplane" else 5
        rows.append((name, _time(fn, x, w, reps=reps), note))

    if csv:
        print("name,us_per_call,derived")
        for name, us, d in rows:
            print(f"{name},{us:.1f},{d}")
    return rows


if __name__ == "__main__":
    run()
