"""Kernel micro-benchmarks: wall time of the CiM formulations on this
host (CPU) + the TPU-target roofline characteristics of each kernel.

Wall-clock here characterizes the *functional* implementations (the jnp
forms XLA:CPU executes); the Pallas kernels are timed in interpret mode
only for sanity (they target TPU). The derived column reports the
analytic bytes/flops profile used by EXPERIMENTS.md §Perf.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import site_cim as sc
from repro.kernels import ref


def _time(fn, *args, reps=5):
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6  # us


def rand_ternary(key, shape, p_zero=0.3):
    k1, k2 = jax.random.split(key)
    sign = jax.random.choice(k1, jnp.array([-1, 1]), shape)
    keep = jax.random.bernoulli(k2, 1 - p_zero, shape)
    return (sign * keep).astype(jnp.float32)


def run(csv: bool = True):
    m, k, n = 256, 1024, 512
    kx, kw = jax.random.split(jax.random.PRNGKey(0))
    x = rand_ternary(kx, (m, k))
    w = rand_ternary(kw, (k, n))
    flops_exact = 2 * m * k * n
    rows = []

    cim = jax.jit(lambda x, w: sc.site_cim_matmul(x, w))
    rows.append(("cim_blocked_jnp", _time(cim, x, w), f"flops={2*flops_exact}"))
    corr = jax.jit(lambda x, w: sc.site_cim_matmul_corrected(x, w))
    rows.append(("cim_corrected_jnp", _time(corr, x, w), f"flops={3*flops_exact}"))
    nm = jax.jit(lambda x, w: sc.nm_ternary_matmul(x, w))
    rows.append(("nm_exact_jnp", _time(nm, x, w), f"flops={flops_exact}"))
    bit = jax.jit(lambda x, w: sc.site_cim_matmul_bitplane(
        x.astype(jnp.int32), w.astype(jnp.int32)))
    rows.append(("cim_bitplane_jnp", _time(bit, x, w, reps=2), "structural oracle"))

    if csv:
        print("name,us_per_call,derived")
        for name, us, d in rows:
            print(f"{name},{us:.1f},{d}")
    return rows


if __name__ == "__main__":
    run()
