"""Paper's accuracy-resilience evidence (Section III.2 / [20][21]):
CiM clamping + sensing errors vs exact ternary execution, on a trained
ternary classifier. Reports accuracy deltas (paper: negligible at
error prob 3.1e-3)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import api
from repro.core.site_cim import SENSE_ERROR_PROB
from repro.core.ternary import ternarize


def _train_ternary_mlp(key, n=4096, d=64, h=128, classes=24, steps=80):
    # enough classes + noise that accuracy sits near (not at) the ceiling,
    # so degradation under injected errors is measurable
    k1, k2, k3, k4 = jax.random.split(key, 4)
    centers = jax.random.normal(k1, (classes, d)) * 1.1
    ys = jnp.arange(n) % classes
    xs = centers[ys] + jax.random.normal(k2, (n, d))
    w1 = jax.random.normal(k3, (d, h)) * 0.1
    w2 = jax.random.normal(k4, (h, classes)) * 0.1

    def fwd(w1, w2, x):
        xt, sx = ternarize(x)
        w1t, s1 = ternarize(w1, axis=(0,))
        hdn = jax.nn.relu((xt @ w1t) * sx * s1)
        return hdn @ w2

    def loss(w1, w2):
        lg = fwd(w1, w2, xs)
        return -jnp.take_along_axis(jax.nn.log_softmax(lg), ys[:, None], 1).mean()

    g = jax.jit(jax.grad(loss, argnums=(0, 1)))
    for _ in range(steps):
        g1, g2 = g(w1, w2)
        w1, w2 = w1 - 0.5 * g1, w2 - 0.5 * g2
    return (w1, w2), (xs, ys)


def run(csv: bool = True):
    (w1, w2), (xs, ys) = _train_ternary_mlp(jax.random.PRNGKey(0))

    def acc(mode, error_prob=0.0, key=None):
        xt, sx = ternarize(xs)
        w1t, s1 = ternarize(w1, axis=(0,))
        if mode == "exact":
            h = xt @ w1t
        else:
            spec = api.CiMExecSpec(formulation="blocked", backend="jnp",
                                   error_prob=error_prob)
            h = api.execute(
                spec, xt.astype(jnp.int32), w1t.astype(jnp.int32), key=key
            ).astype(jnp.float32)
        h = jax.nn.relu(h * sx * s1)
        lg = h @ w2
        return float((jnp.argmax(lg, -1) == ys).mean())

    rows = [
        ("exact_ternary_NM", acc("exact"), "baseline"),
        ("site_cim_clean", acc("cim"), "ADC clamp only"),
        ("site_cim_err_3.1e-3", acc("cim", SENSE_ERROR_PROB, jax.random.PRNGKey(7)),
         "paper's measured error prob"),
        ("site_cim_err_1e-2", acc("cim", 1e-2, jax.random.PRNGKey(8)), "3x the paper rate"),
        ("site_cim_err_1e-1", acc("cim", 1e-1, jax.random.PRNGKey(9)), "stress"),
    ]
    if csv:
        print("name,accuracy,derived")
        for name, a, d in rows:
            print(f"{name},{a:.4f},{d}")
    return rows


if __name__ == "__main__":
    run()
