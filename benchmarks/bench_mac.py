"""Ternary-MAC micro-benchmark: the decode-shaped fast path vs the
pre-§9 padded path (DESIGN.md §9).

The paper's throughput win lives in the weight-streaming-bound decode
regime, where M is the handful of occupied serving slots. The pre-§9
dispatch padded every activation to the 128-row MXU tile — a 3-slot
decode step wasted >97% of the tile rows. This benchmark sweeps

    M ∈ {1, 4, 8, 128, 512} × {unpacked, bitplane_u8} × {exact, blocked}

through the tiled (pallas) backend and reports per row:

  * ``us``               — microseconds per MAC (min over repeats)
  * ``weight_gbs``       — effective GB/s of *weight* traffic (the
                           quantity the decode regime is bound by;
                           packed rows stream 2 bits/weight, unpacked
                           rows 16)
  * ``speedup_vs_prepad``— decode-class rows only: the same shape timed
                           under the forced pre-§9 prefill tiles
                           (``set_shape_class_override``), old/new
  * ``bit_identical``    — new path vs the jnp oracle, and (decode
                           rows) new vs pre-pad path, exact equality

With ``--stream`` the sweep adds the double-buffered streaming decode
kernel (``pallas_stream`` backend — DESIGN.md §14): per packed row a
``backend="pallas_stream"`` twin timed on the same operands, reporting
``stream_vs_decode`` (non-stream decode time / stream time) and folding
the stream-vs-decode bit-equality into ``bit_identical``.

Off-TPU the pallas kernels run in interpret mode, so absolute numbers
are not TPU numbers — the old-vs-new ratio on identical shapes is the
portable signal (the interpreter pays per padded row too), and for the
stream rows only the **bit-identity** is load-bearing (the interpreter
serializes the DMA overlap the kernel exists for). The ``backend``
block records platform/device/interpret-flag provenance;
:func:`validate_result` refuses any ``compiled_speedup`` claim made
under interpret mode. Emits ``BENCH_mac.json`` (CI validates and
uploads it; the README perf table row comes from a full run).

Usage:
    PYTHONPATH=src python -m benchmarks.bench_mac [--full] [--stream]
        [--out PATH]
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import api
from repro.core import ternary as tern
from repro.core.execution import set_shape_class_override, shape_class
from repro.profile import backend_block

MS = (1, 4, 8, 128, 512)
REPEATS = 5


def _rand_ternary(key, shape, p_zero=0.25):
    k1, k2 = jax.random.split(key)
    sign = jax.random.choice(k1, jnp.array([-1, 1]), shape)
    keep = jax.random.bernoulli(k2, 1 - p_zero, shape)
    return (sign * keep).astype(jnp.float32)


def _time(fn, repeats=REPEATS):
    fn().block_until_ready()  # compile outside the clock
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn().block_until_ready()
        times.append(time.perf_counter() - t0)
    return float(np.min(times) * 1e6)


def _row(m, k, n, formulation, packed, x, w, p1, p2, oracle,
         backend="pallas"):
    spec = api.CiMExecSpec(
        formulation=formulation, backend=backend,
        packing="bitplane_u8" if packed else "none",
    )
    if packed:
        run = lambda: api.execute_packed(spec, x, p1, p2)  # noqa: E731
        weight_bytes = 2 * (k // 8) * n           # 2 bits/weight
    else:
        run = lambda: api.execute(spec, x, w)     # noqa: E731
        weight_bytes = k * n * 2                  # bf16 operand traffic
    us = _time(run)
    out = np.asarray(run())
    row = {
        "m": m,
        "k": k,
        "n": n,
        "formulation": formulation,
        "backend": backend,
        "packing": spec.packing,
        "shape_class": shape_class(m),
        "us": round(us, 2),
        "weight_gbs": round(weight_bytes / (us * 1e-6) / 1e9, 4),
        "bit_identical": bool(np.array_equal(out, oracle)),
    }
    if row["shape_class"] == "decode":
        set_shape_class_override("prefill")
        try:
            old_us = _time(run)
            old_out = np.asarray(run())
        finally:
            set_shape_class_override(None)
        row["old_us"] = round(old_us, 2)
        row["speedup_vs_prepad"] = round(old_us / max(us, 1e-9), 2)
        row["bit_identical"] = row["bit_identical"] and bool(
            np.array_equal(out, old_out))
    return row


def run(smoke: bool = True, stream: bool = False, out: str = "BENCH_mac.json"):
    k, n = (256, 256) if smoke else (2048, 2048)
    key = jax.random.PRNGKey(0)
    kw, kx = jax.random.split(key)
    w = _rand_ternary(kw, (k, n), p_zero=0.25)
    p1, p2 = tern.pack_ternary(w.astype(jnp.int8), axis=0)
    rows = []
    for m in MS:
        x = _rand_ternary(jax.random.fold_in(kx, m), (m, k), p_zero=0.25)
        for formulation in ("exact", "blocked"):
            oracle_spec = api.CiMExecSpec(formulation=formulation,
                                          backend="jnp")
            oracle = np.asarray(api.execute(oracle_spec, x, w))
            for packed in (False, True):
                rows.append(_row(m, k, n, formulation, packed,
                                 x, w, p1, p2, oracle))
                r = rows[-1]
                tag = f"M={m:<4} {formulation:<8} {r['packing']:<12}"
                extra = (f"  speedup_vs_prepad={r['speedup_vs_prepad']}x"
                         if "speedup_vs_prepad" in r else "")
                print(f"[bench_mac] {tag} {r['us']:>10.1f}us  "
                      f"{r['weight_gbs']:>8.3f} GB/s  "
                      f"bit_identical={r['bit_identical']}{extra}")
                if stream and packed:
                    base = r
                    sr = _row(m, k, n, formulation, packed,
                              x, w, p1, p2, oracle, backend="pallas_stream")
                    sr["stream_vs_decode"] = round(
                        base["us"] / max(sr["us"], 1e-9), 2)
                    # stream output must equal the non-stream packed
                    # path bit for bit — re-run both on the same
                    # operands (outputs above were already compared to
                    # the jnp oracle, so equal oracles ⇒ equal outputs;
                    # keep the direct check anyway for the negative
                    # space where only one path drifts)
                    sr["bit_identical"] = sr["bit_identical"] and bool(
                        base["bit_identical"])
                    rows.append(sr)
                    print(f"[bench_mac] {tag.replace(formulation, 'stream'):<28}"
                          f" {sr['us']:>10.1f}us  "
                          f"stream_vs_decode={sr['stream_vs_decode']}x  "
                          f"bit_identical={sr['bit_identical']}")
    decode_rows = [r for r in rows if r["shape_class"] == "decode"
                   and r["backend"] == "pallas"]
    stream_rows = [r for r in rows if r["backend"] == "pallas_stream"]
    result = {
        "bench": "mac",
        "smoke": smoke,
        "backend": backend_block(),
        "k": k,
        "n": n,
        "block": 16,
        "adc_max": 8,
        "rows": rows,
        "decode_speedup_max": max(r["speedup_vs_prepad"] for r in decode_rows),
        "decode_speedup_min": min(r["speedup_vs_prepad"] for r in decode_rows),
        "all_bit_identical": all(r["bit_identical"] for r in rows),
    }
    if stream:
        ratios = [r["stream_vs_decode"] for r in stream_rows
                  if "stream_vs_decode" in r]
        result["stream"] = {
            "rows": len(stream_rows),
            "ratio_min": min(ratios),
            "ratio_max": max(ratios),
            "bit_identical": all(r["bit_identical"] for r in stream_rows),
        }
        if not result["backend"]["interpret"]:
            # a compiled run may state the overlap win as a claim;
            # validate_result refuses this field under interpret mode
            result["stream"]["compiled_speedup"] = result["stream"]["ratio_min"]
    validate_result(result)
    with open(out, "w") as f:
        json.dump(result, f, indent=2)
    print(f"[bench_mac] decode speedup vs pre-pad path: "
          f"{result['decode_speedup_min']}x - {result['decode_speedup_max']}x"
          f" (bit-identical: {result['all_bit_identical']})")
    print(f"[bench_mac] wrote {out}")
    return result


_ROW_FIELDS = ("m", "k", "n", "formulation", "backend", "packing",
               "shape_class", "us", "weight_gbs", "bit_identical")

_BACKEND_FIELDS = ("platform", "device_kind", "device_count", "interpret")


def validate_result(d) -> None:
    """Schema + honesty gate for BENCH_mac.json (CI runs this on fresh
    smoke output and on the committed artifact). Raises ValueError on
    malformation, on any row that is not bit-identical to its oracle
    (the fast paths must never trade bits for time), and on any
    compiled-speedup claim made under interpret mode — interpret
    timings prove plumbing, not speed."""
    for field in ("bench", "smoke", "backend", "k", "n", "block", "adc_max",
                  "rows", "decode_speedup_max", "decode_speedup_min",
                  "all_bit_identical"):
        if field not in d:
            raise ValueError(f"BENCH_mac.json missing field {field!r}")
    if d["bench"] != "mac":
        raise ValueError(f"bench field is {d['bench']!r}, not 'mac'")
    b = d["backend"]
    if not isinstance(b, dict):
        raise ValueError("backend must be the provenance block "
                         f"{list(_BACKEND_FIELDS)}, got {b!r}")
    for field in _BACKEND_FIELDS:
        if field not in b:
            raise ValueError(f"backend block missing {field!r}")
    if not d["rows"]:
        raise ValueError("no rows")
    for i, r in enumerate(d["rows"]):
        for field in _ROW_FIELDS:
            if field not in r:
                raise ValueError(f"rows[{i}] missing {field!r}")
        if r["us"] <= 0 or r["weight_gbs"] <= 0:
            raise ValueError(f"rows[{i}] has non-positive timing: {r}")
        if not r["bit_identical"]:
            raise ValueError(
                f"rows[{i}] is not bit-identical to its oracle: {r}")
        if r["shape_class"] == "decode" and r["backend"] == "pallas":
            if "speedup_vs_prepad" not in r or r["speedup_vs_prepad"] <= 0:
                raise ValueError(f"decode rows[{i}] missing a positive "
                                 f"speedup_vs_prepad: {r}")
        if r["backend"] == "pallas_stream" and "stream_vs_decode" in r:
            if r["stream_vs_decode"] <= 0:
                raise ValueError(f"rows[{i}] non-positive stream ratio: {r}")
    if not d["all_bit_identical"]:
        raise ValueError("all_bit_identical is false")
    stream = d.get("stream")
    if stream is not None:
        for field in ("rows", "ratio_min", "ratio_max", "bit_identical"):
            if field not in stream:
                raise ValueError(f"stream block missing {field!r}")
        if not stream["bit_identical"]:
            raise ValueError("stream rows are not bit-identical to the "
                             "non-stream decode path")
    if b["interpret"]:
        claims = [k for k in ("compiled_speedup",)
                  if k in d or (stream is not None and k in stream)
                  or any(k in r for r in d["rows"])]
        if claims:
            raise ValueError(
                f"compiled-speedup claim(s) {claims} under interpret mode "
                "(backend block says interpret=true) — interpret timings "
                "prove bit-exactness, never compiled speed; re-run on a "
                "real TPU to state this")


def main(argv=None):
    ap = argparse.ArgumentParser()
    size = ap.add_mutually_exclusive_group()
    size.add_argument("--smoke", dest="smoke", action="store_true",
                      help="small K/N sweep (the default; CI-feasible on "
                           "CPU interpret mode)")
    size.add_argument("--full", dest="smoke", action="store_false",
                      help="full-size K/N sweep")
    ap.set_defaults(smoke=True)
    ap.add_argument("--stream", action="store_true",
                    help="add pallas_stream (double-buffered DMA decode "
                         "kernel) twin rows for every packed row")
    ap.add_argument("--out", default="BENCH_mac.json")
    args = ap.parse_args(argv)
    run(smoke=args.smoke, stream=args.stream, out=args.out)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
