"""Paper Figs 12 & 13: system-level speedup and energy reduction of SiTe
CiM I/II vs iso-capacity / iso-area NM baselines, per technology, over the
5-benchmark suite (AlexNet, ResNet34, Inception, LSTM, GRU)."""
from __future__ import annotations

from repro.core import accelerator as acc
from repro.core import cost_model as cm


def rows():
    out = []
    for design in ("CiM-I", "CiM-II"):
        for tech in cm.TECHNOLOGIES:
            for baseline in ("iso-capacity", "iso-area"):
                per = acc.speedup_and_energy(tech, design, baseline)
                for bench, v in per.items():
                    out.append({
                        "figure": "Fig12" if design == "CiM-I" else "Fig13",
                        "design": design,
                        "tech": tech,
                        "baseline": baseline,
                        "benchmark": bench,
                        "speedup": round(v["speedup"], 2),
                        "energy_reduction": round(v["energy_reduction"], 2),
                    })
                paper_s = acc.PAPER_SYSTEM_SPEEDUP[(design, baseline)][tech]
                out.append({
                    "figure": "Fig12" if design == "CiM-I" else "Fig13",
                    "design": design, "tech": tech, "baseline": baseline,
                    "benchmark": "AVERAGE",
                    "speedup": round(acc.average_speedup(tech, design, baseline), 2),
                    "energy_reduction": round(
                        acc.average_energy_reduction(tech, design, baseline), 2),
                    "paper_speedup": paper_s,
                    "paper_energy": acc.PAPER_SYSTEM_ENERGY[design][tech],
                })
    return out


def run(csv: bool = True):
    rs = rows()
    if csv:
        keys = ["figure", "design", "tech", "baseline", "benchmark",
                "speedup", "energy_reduction", "paper_speedup", "paper_energy"]
        print(",".join(keys))
        for r in rs:
            print(",".join(str(r.get(k, "")) for k in keys))
    return rs


if __name__ == "__main__":
    run()
