"""System-level projections through the TiM-DNN-style macro model.

Two sections:

  * **paper** (Figs 12 & 13): speedup and energy reduction of SiTe CiM
    I/II vs iso-capacity / iso-area NM baselines, per technology, over
    the paper's 5-benchmark suite (AlexNet, ResNet34, Inception, LSTM,
    GRU) — with the paper-reported averages attached for validation.
  * **projections** (the workload the paper never ran): every registry
    architecture (transformer / SSM / hybrid / MoE / encdec / VLM) x
    prefill/decode shape, run through the same macro model via
    ``repro.hw.project`` on every registered technology's CiM-I and
    CiM-II arrays — projected tokens/s, pJ/token, and the CiM-vs-NM
    speedups. A technology registered at runtime appears here with zero
    edits.

Emits ``BENCH_system.json`` (same contract as ``BENCH_serve.json``: CI
validates + uploads it in the bench-smoke job).
"""
from __future__ import annotations

import argparse
import json

from repro import hw

# registry cells projected by default: every arch, one prefill + one
# decode shape (both supported by all archs; pure cost-model math)
PROJECTION_SHAPES = ("prefill_32k", "decode_32k")


def rows():
    out = []
    for design in ("CiM-I", "CiM-II"):
        for tech in hw.PAPER_TECHNOLOGIES:
            for baseline in ("iso-capacity", "iso-area"):
                per = hw.speedup_and_energy(tech, design, baseline)
                for bench, v in per.items():
                    out.append({
                        "figure": "Fig12" if design == "CiM-I" else "Fig13",
                        "design": design,
                        "tech": tech,
                        "baseline": baseline,
                        "benchmark": bench,
                        "speedup": round(v["speedup"], 2),
                        "energy_reduction": round(v["energy_reduction"], 2),
                    })
                paper_s = hw.PAPER_SYSTEM_SPEEDUP[(design, baseline)][tech]
                out.append({
                    "figure": "Fig12" if design == "CiM-I" else "Fig13",
                    "design": design, "tech": tech, "baseline": baseline,
                    "benchmark": "AVERAGE",
                    "speedup": round(hw.average_speedup(tech, design, baseline), 2),
                    "energy_reduction": round(
                        hw.average_energy_reduction(tech, design, baseline), 2),
                    "paper_speedup": paper_s,
                    "paper_energy": hw.PAPER_SYSTEM_ENERGY[design][tech],
                })
    return out


def projection_rows(shapes=PROJECTION_SHAPES):
    """Registry archs through the macro model on every registered tech."""
    from repro.models.registry import ARCH_IDS

    out = []
    for arch in ARCH_IDS:
        for shape in shapes:
            for tech in hw.technologies():
                for design in hw.cim_designs_of(tech):
                    array = hw.ArraySpec(technology=tech, design=design)
                    p = hw.project(arch, shape, array)
                    out.append({
                        "arch": p["arch"],
                        "family": p["family"],
                        "shape": p["shape"],
                        "tech": tech,
                        "design": design,
                        "tok_s": round(p["tok_s"], 1),
                        "pj_per_token": round(p["pj_per_token"], 1),
                        "speedup_iso_capacity": round(
                            p["iso_capacity"]["speedup"], 2),
                        "speedup_iso_area": round(p["iso_area"]["speedup"], 2),
                        "energy_reduction": round(
                            p["iso_capacity"]["energy_reduction"], 2),
                    })
    return out


def run(csv: bool = True, out: str = "BENCH_system.json"):
    rs = rows()
    pr = projection_rows()
    if csv:
        keys = ["figure", "design", "tech", "baseline", "benchmark",
                "speedup", "energy_reduction", "paper_speedup", "paper_energy"]
        print(",".join(keys))
        for r in rs:
            print(",".join(str(r.get(k, "")) for k in keys))
        pkeys = ["arch", "family", "shape", "tech", "design", "tok_s",
                 "pj_per_token", "speedup_iso_capacity", "speedup_iso_area",
                 "energy_reduction"]
        print("\n" + ",".join(pkeys))
        for r in pr:
            print(",".join(str(r[k]) for k in pkeys))
    from repro.profile import backend_block

    result = {
        "bench": "system",
        "backend": backend_block(),
        "technologies": list(hw.technologies()),
        "rows": rs,
        "projections": pr,
    }
    if out:
        with open(out, "w") as f:
            json.dump(result, f, indent=2)
        print(f"[bench_system] wrote {out}")
    return rs


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_system.json")
    args = ap.parse_args(argv)
    run(out=args.out)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
