"""Serving example: continuous batching over a ternary-CiM LM.

Spins up the slot-pool batcher, submits a stream of requests with
different lengths, and decodes them concurrently — finished slots refill
from the queue without stalling the others. Every decode step is ONE
fused, jitted call over all slots at their own cache positions (the
ragged-position decode contract, DESIGN.md §6), with sampling on device
and a single host fetch per step.

Run: PYTHONPATH=src python examples/serve_ternary.py
"""
import time

import jax

from repro.models import transformer as T
from repro.models.registry import get_config
from repro.serve.engine import ContinuousBatcher, Request

def main():
    cfg = get_config("smollm-135m", smoke=True)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    batcher = ContinuousBatcher(params, cfg, n_slots=4, s_max=64)

    reqs = [Request(i, [1 + i % 7, 2, 3 + i % 5][: 1 + i % 3], max_new=4 + i % 6)
            for i in range(10)]
    for r in reqs:
        batcher.submit(r)

    t0 = time.perf_counter()
    batcher.run()
    dt = time.perf_counter() - t0
    total_toks = sum(len(r.generated) for r in reqs)
    stats = batcher.stats()
    print(f"served {len(reqs)} requests / {total_toks} tokens in "
          f"{stats['decode_steps']} fused decode steps, "
          f"{stats['host_syncs']} host syncs ({dt:.2f}s)")
    for r in reqs:
        assert r.done
        print(f"  req {r.rid}: prompt {r.prompt} -> {r.generated}")


if __name__ == "__main__":
    main()
