"""End-to-end driver: train a ~100M-param ternary (QAT) LM for a few
hundred steps with the full production substrate — sharded data pipeline,
AdamW, checkpoint/restart, straggler tracking.

The default config is the real smollm-135m (135M params) at a reduced
sequence length so a few hundred steps finish on CPU; pass --smoke for
the tiny config, --steps to change duration.

Run: PYTHONPATH=src python examples/train_ternary_lm.py --steps 300
"""
import argparse

from repro.data.pipeline import DataConfig, TokenPipeline
from repro.models.registry import get_config
from repro.optim.adamw import AdamWConfig
from repro.optim.schedules import warmup_cosine
from repro.train.trainer import TrainConfig, Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--smoke", action="store_true", help="tiny config (fast CPU run)")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--grad-compression", default=None, choices=[None, "bf16", "int8"])
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    print(f"training {cfg.name} ({cfg.param_count():,} params), "
          f"quant mode = {cfg.quant.mode}")
    pipe = TokenPipeline(DataConfig(
        vocab=cfg.vocab, seq_len=args.seq, global_batch=args.batch, seed=0))
    opt = AdamWConfig(lr=3e-4, schedule=warmup_cosine(20, args.steps))
    tcfg = TrainConfig(
        num_steps=args.steps, ckpt_dir=args.ckpt_dir, ckpt_every=50,
        log_every=10, grad_compression=args.grad_compression,
    )
    trainer = Trainer(cfg, opt, tcfg, pipe)
    log = trainer.run()
    print(f"\nfinal loss {log[-1]['loss']:.4f} (start {log[0]['loss']:.4f}); "
          f"stragglers: {len(trainer.straggler_steps)}; restarts: {trainer.restarts}")


if __name__ == "__main__":
    main()
