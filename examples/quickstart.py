"""Quickstart: the paper's technique in five minutes.

1. Build a ternary weight/input pair.
2. Compute the signed-ternary dot product through the declarative
   execution API (``repro.api``): exact near-memory, SiTe CiM array
   semantics (16-row ADC clamp), and the Pallas kernel backend
   (interpret mode on CPU) — one ``execute`` call each, the spec picks
   the kernel.
3. Show the array- and system-level cost model (the paper's Figs 9-13),
   mapped from the same specs.

Run: PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro import api, hw
from repro.core.ternary import pack_ternary, ternarize


def main():
    key = jax.random.PRNGKey(0)
    kx, kw = jax.random.split(key)
    # ternarize some float data (TWN threshold quantization)
    x_f = jax.random.normal(kx, (8, 256))
    w_f = jax.random.normal(kw, (256, 64))
    x_t, sx = ternarize(x_f)
    w_t, sw = ternarize(w_f, axis=(0,))
    print(f"input sparsity:  {float((x_t == 0).mean()):.2f}")
    print(f"weight sparsity: {float((w_t == 0).mean()):.2f}")

    xi = x_t.astype(jnp.int32)
    wi = w_t.astype(jnp.int32)
    # 1) exact near-memory ternary matmul (the paper's NM baseline)
    exact = api.execute(api.CiMExecSpec(formulation="exact", backend="jnp"), xi, wi)
    # 2) SiTe CiM: 16 rows per cycle, 3-bit ADC with clamp at 8
    cim_spec = api.CiMExecSpec(formulation="blocked", backend="jnp")
    cim = api.execute(cim_spec, xi, wi)
    # 3) the Pallas TPU kernel backend (interpret mode on CPU; the shim
    #    pads to MXU tiles) — same spec, different backend
    kern = api.execute(
        api.CiMExecSpec(formulation="blocked", backend="pallas"),
        x_t.astype(jnp.float32), w_t.astype(jnp.float32),
    )
    agree = bool(jnp.all(cim == kern.astype(jnp.int32)))
    clipped = int(jnp.sum(cim != exact))
    print(f"kernel == functional model: {agree}")
    print(f"outputs where the ADC clamp engaged: {clipped}/{cim.size}")

    # 2-bit differential storage (the memory-macro layout); the packed
    # kernel backend consumes exactly this via packing="bitplane_u8"
    wp, wn = pack_ternary(w_t.astype(jnp.int8), axis=0)
    print(f"weight bytes: fp32 {w_f.nbytes}, packed 2-bit {wp.nbytes + wn.nbytes}")

    # hardware model: the spec binds to a declarative ArraySpec
    design = api.spec_design(cim_spec)
    array = hw.ArraySpec(technology="8T-SRAM", design=design)
    cost = api.spec_cost_summary(cim_spec, array=array)
    print(f"\nspec {cim_spec.name} -> array {array.name}")
    t = hw.paper_validation_table()["8T-SRAM"][design]
    print(f"8T-SRAM SiTe CiM I vs near-memory (paper Fig 9):")
    print(f"  CiM latency reduction : {t['cim_latency_reduction_pct']:.0f}%  (paper: 88%)")
    print(f"  CiM energy reduction  : {t['cim_energy_reduction_pct']:.0f}%  (paper: 74%)")
    print(f"  MAC pass              : {cost['mac_pass_ns']:.0f} ns")
    s = hw.average_speedup("8T-SRAM", design, "iso-capacity")
    print(f"  system speedup (5 DNNs, iso-capacity): {s:.2f}x (paper: 6.74x)")
    p = hw.project("yi-34b", "decode_32k", array)
    print(f"  projected yi-34b decode on that array: {p['tok_s']:.0f} tok/s, "
          f"{p['iso_capacity']['speedup']:.1f}x vs iso-capacity NM")


if __name__ == "__main__":
    main()
