"""Quickstart: the paper's technique in five minutes.

1. Build a ternary weight/input pair.
2. Compute the signed-ternary dot product three ways: exact near-memory,
   SiTe CiM array semantics (16-row ADC clamp), and the Pallas kernel
   (interpret mode on CPU).
3. Show the array- and system-level cost model (the paper's Figs 9-13).

Run: PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core import site_cim as sc
from repro.core.ternary import pack_ternary, ternarize
from repro.kernels.ops import cim_matmul
from repro.core import cost_model as cm
from repro.core import accelerator as acc


def main():
    key = jax.random.PRNGKey(0)
    kx, kw = jax.random.split(key)
    # ternarize some float data (TWN threshold quantization)
    x_f = jax.random.normal(kx, (8, 256))
    w_f = jax.random.normal(kw, (256, 64))
    x_t, sx = ternarize(x_f)
    w_t, sw = ternarize(w_f, axis=(0,))
    print(f"input sparsity:  {float((x_t == 0).mean()):.2f}")
    print(f"weight sparsity: {float((w_t == 0).mean()):.2f}")

    # 1) exact near-memory ternary matmul (the paper's NM baseline)
    exact = sc.nm_ternary_matmul(x_t.astype(jnp.int32), w_t.astype(jnp.int32))
    # 2) SiTe CiM: 16 rows per cycle, 3-bit ADC with clamp at 8
    cim = sc.site_cim_matmul(x_t.astype(jnp.int32), w_t.astype(jnp.int32))
    # 3) the Pallas TPU kernel (interpret mode on CPU; pads to MXU tiles)
    kern = cim_matmul(
        x_t.astype(jnp.float32), w_t.astype(jnp.float32), 16, 8, "pallas"
    )
    agree = bool(jnp.all(cim == kern.astype(jnp.int32)))
    clipped = int(jnp.sum(cim != exact))
    print(f"kernel == functional model: {agree}")
    print(f"outputs where the ADC clamp engaged: {clipped}/{cim.size}")

    # 2-bit differential storage (the memory-macro layout)
    wp, wn = pack_ternary(w_t.astype(jnp.int8), axis=0)
    print(f"weight bytes: fp32 {w_f.nbytes}, packed 2-bit {wp.nbytes + wn.nbytes}")

    # cost model: the paper's headline numbers
    t = cm.paper_validation_table()["8T-SRAM"]["CiM-I"]
    print(f"\n8T-SRAM SiTe CiM I vs near-memory (paper Fig 9):")
    print(f"  CiM latency reduction : {t['cim_latency_reduction_pct']:.0f}%  (paper: 88%)")
    print(f"  CiM energy reduction  : {t['cim_energy_reduction_pct']:.0f}%  (paper: 74%)")
    s = acc.average_speedup("8T-SRAM", "CiM-I", "iso-capacity")
    print(f"  system speedup (5 DNNs, iso-capacity): {s:.2f}x (paper: 6.74x)")


if __name__ == "__main__":
    main()
