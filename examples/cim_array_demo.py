"""SiTe CiM array walkthrough: reproduce the paper's Fig 3-5 mechanics.

Shows the differential encoding, the truth table, multi-row MAC with the
3-bit ADC, sense-margin-driven clamping, and the sensing-error channel —
numerically, on the functional model.

Run: PYTHONPATH=src python examples/cim_array_demo.py
"""
import jax
import jax.numpy as jnp

from repro import api
from repro.core import site_cim as sc
from repro.core.ternary import to_bitplanes, block_overflow_rate

# the demo's array semantics, as a declarative execution spec
CIM = api.CiMExecSpec(formulation="blocked", backend="jnp")


def main():
    print("=== Fig 3(a): differential weight encoding (M1, M2) ===")
    for w in (1, 0, -1):
        m1, m2 = to_bitplanes(jnp.asarray(w))
        print(f"  W={w:+d} -> M1={int(m1)} M2={int(m2)}")

    print("\n=== Fig 3(d): scalar product truth table ===")
    print("        W=-1  W=0  W=+1")
    for i in (-1, 0, 1):
        row = [int(sc.scalar_product(jnp.asarray(i), jnp.asarray(w))) for w in (-1, 0, 1)]
        print(f"  I={i:+d}  {row[0]:+d}    {row[1]:+d}    {row[2]:+d}")

    print("\n=== Fig 4: multi-row MAC with 3-bit ADC (N_A = 16) ===")
    # 16 rows, engineered so a = 11 (+1 events) and b = 2 (-1 events)
    x = jnp.array([1] * 13 + [-1] * 3)
    w = jnp.array([1] * 11 + [0, 0] + [-1, 1, 0])
    a = int(jnp.sum((x * w) == 1))
    b = int(jnp.sum((x * w) == -1))
    exact = int(x @ w)
    cim = int(api.execute(CIM, x[None], w[:, None])[0, 0])
    print(f"  a={a} (+1 events), b={b} (-1 events)")
    print(f"  exact dot = a-b = {exact}")
    print(f"  CiM output = min(a,8)-min(b,8) = {cim}   <-- ADC clamp at 8")

    print("\n=== sparsity keeps overflow rare (Section III.2) ===")
    key = jax.random.PRNGKey(0)
    for p_zero in (0.0, 0.3, 0.6):
        k1, k2, k3, k4 = jax.random.split(key, 4)
        xs = (jax.random.choice(k1, jnp.array([-1, 1]), (64, 256))
              * jax.random.bernoulli(k3, 1 - p_zero, (64, 256))).astype(jnp.float32)
        ws = (jax.random.choice(k2, jnp.array([-1, 1]), (256, 64))
              * jax.random.bernoulli(k4, 1 - p_zero, (256, 64))).astype(jnp.float32)
        rate = float(block_overflow_rate(xs, ws))
        print(f"  sparsity {p_zero:.1f}: ADC overflow rate {rate:.4f}")

    print("\n=== sensing-error channel (total prob 3.1e-3, Section III.2) ===")
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(1), 3)
    xs = jax.random.randint(k1, (32, 256), -1, 2)
    ws = jax.random.randint(k2, (256, 32), -1, 2)
    clean = api.execute(CIM, xs, ws)
    noisy_spec = api.CiMExecSpec(formulation="blocked", backend="jnp",
                                 error_prob=sc.SENSE_ERROR_PROB)
    noisy = api.execute(noisy_spec, xs, ws, key=k3)
    n_diff = int(jnp.sum(clean != noisy))
    print(f"  outputs perturbed: {n_diff}/{clean.size} "
          f"(expected ~= 16 blocks x 3.1e-3 x {clean.size} = "
          f"{16 * 3.1e-3 * clean.size:.0f})")


if __name__ == "__main__":
    main()
